//! Compile-only stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! The `runtime::PjrtEngine` in the main crate is written against the real
//! `xla` crate's API, but that crate needs the native `xla_extension`
//! library at build time — which CI machines and most dev boxes don't
//! have. This stub mirrors exactly the API surface `PjrtEngine` uses so
//! that `cargo check --features pjrt` (and clippy over all targets)
//! succeeds everywhere, while every runtime entry point fails with a
//! clean, actionable error instead of linking against XLA.
//!
//! To actually execute AOT artifacts through PJRT, point the `xla` path
//! dependency in `rust/Cargo.toml` at the real crate (or a checkout of
//! xla-rs) and set `XLA_EXTENSION_DIR`; no Rust code changes are needed.

use std::fmt;
use std::path::{Path, PathBuf};

/// Error type matching the real crate's `Display`-able error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA runtime unavailable (this build uses the in-tree `xla` API stub; \
             point the `xla` path dependency at the real xla-rs crate to execute artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side tensor value (f64 payload — the artifacts are all float64).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1(v: &[f64]) -> Literal {
        Literal {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Copy out as a host vector. Only reachable after a successful
    /// execution, which the stub never produces.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    /// First element of a (scalar) literal.
    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(Error::unavailable("Literal::get_first_element"))
    }
}

/// Parsed HLO module (the stub records the source path only).
#[derive(Debug)]
pub struct HloModuleProto {
    path: PathBuf,
}

impl HloModuleProto {
    /// Parse an HLO-text artifact. The stub validates existence only; the
    /// real crate parses the module here.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error(format!("no such HLO artifact: {}", p.display())));
        }
        Ok(HloModuleProto {
            path: p.to_path_buf(),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. `cpu()` is the stub's hard stop: constructing a
/// client requires the native runtime, so it fails here — cleanly.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with host inputs; returns per-device, per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("XLA runtime unavailable"), "{err}");
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn data_paths_error_not_panic() {
        let l = Literal::vec1(&[1.0]);
        assert!(l.to_vec::<f64>().is_err());
        assert!(l.get_first_element::<f64>().is_err());
        assert!(l.to_tuple().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
