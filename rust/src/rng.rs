//! Deterministic, splittable pseudo-random number generation.
//!
//! ExaGeoStat's data generator must be reproducible across hardware
//! configurations (the paper seeds every experiment: `seed = 0`,
//! `seed = 1..100`).  We implement PCG64 (O'Neill 2014) with a SplitMix64
//! seeding stage so a single `u64` seed expands into independent streams,
//! plus normal variates via the Marsaglia polar method.  No external crates
//! are used (the vendored set has no `rand`).

/// SplitMix64: used to expand a small seed into PCG state/increment pairs
/// and to derive independent sub-streams (`Pcg64::split`).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSL-RR 128/64: a 128-bit LCG with a 64-bit xorshift-rotate output
/// permutation.  Period 2^128, passes BigCrush, and cheap enough that the
/// generator never shows up in profiles next to the O(n^3) Cholesky.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // must be odd
}

const PCG_MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Build a generator from a 64-bit seed (stream 0).
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::seed_stream(seed, 0)
    }

    /// Build a generator from a seed and a stream id; distinct stream ids
    /// give statistically independent sequences for the same seed.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ 0xA02B_DBF7_BB3C_0A7A_u64.wrapping_mul(stream.wrapping_add(1));
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let i0 = splitmix64(&mut sm);
        let i1 = splitmix64(&mut sm);
        let mut rng = Pcg64 {
            state: 0,
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MUL).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(((s0 as u128) << 64) | s1 as u128);
        rng.state = rng.state.wrapping_mul(PCG_MUL).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-task / per-tile
    /// parallel generation with deterministic results regardless of the
    /// execution order).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let b = self.next_u64();
        Pcg64::seed_stream(a, b)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's multiply-shift rejection-free mapping is fine here: the
        // tiny modulo bias of multiply-shift is irrelevant for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via the Marsaglia polar method (exact, no tables).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fill a slice with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.normal();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::seed_stream(7, 0);
        let mut b = Pcg64::seed_stream(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from_u64(4);
        let n = 200_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
            s4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s3 / nf).abs() < 0.05);
        assert!((s4 / nf - 3.0).abs() < 0.1, "kurtosis {}", s4 / nf);
    }

    #[test]
    fn below_bounds() {
        let mut rng = Pcg64::seed_from_u64(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(6);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::seed_from_u64(9);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let matches = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(matches, 0);
    }
}
