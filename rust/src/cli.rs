//! Tiny command-line argument parser (the offline substitute for `clap`).
//!
//! Grammar: `exageostat <subcommand> [--key value | --key=value | --flag]...`

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Parse a comma-separated f64 list, e.g. `--theta 1,0.1,0.5`.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad number {p:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("mle --n 1600 --theta=1,0.1,0.5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("mle"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 1600);
        assert_eq!(a.get_f64_list("theta", &[]).unwrap(), vec![1.0, 0.1, 0.5]);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("simulate --n abc");
        assert!(a.get_usize("n", 5).is_err());
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert_eq!(a.get_or("kernel", "ugsm-s"), "ugsm-s");
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse("mle --clb -1.5");
        assert_eq!(a.get_f64("clb", 0.0).unwrap(), -1.5);
    }
}
