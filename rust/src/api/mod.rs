//! The public API: the typed model layer ([`GeoModel`] /
//! [`ModelBuilder`]) plus the ExaGeoStatR Table-II surface, one Rust
//! method per R function with the same argument structure
//! (`hardware = list(...)`, `optimization = list(clb, cub, tol,
//! max_iters)`).
//!
//! The Table-II MLE entry points are retained as thin wrappers over the
//! builder (parity-tested in `rust/tests/api_client.rs`); new code
//! should build a [`GeoModel`] and either [`GeoModel::fit`] it directly
//! or submit it asynchronously through a `coordinator::Client` — see
//! the "API layers" section of DESIGN.md.

pub mod error;
pub mod model;

pub use error::{is_cancelled, is_timeout, ApiError};
pub use model::{GeoModel, ModelBuilder};

use crate::backend::{self, ArcEngine, Backend, Engine as _};
use crate::covariance::{kernel_by_name, CovKernel, DistanceMetric, Location};
use crate::likelihood::{EvalSession, ExecCtx, Variant};
use crate::optimizer::{self, Bounds, Method, OptOptions};
use crate::prediction::{self, FisherResult, MloeMmom, Prediction};
use crate::scheduler::pool::Policy;
use crate::scheduler::runtime::{CancelToken, Runtime, TaskError};
use crate::simulation::{self, GeoData};
use std::cell::RefCell;
use std::sync::Arc;

/// Default worker-thread count: the `EXAGEOSTAT_NCORES` environment
/// override when set (and positive), else the machine's available
/// parallelism.  The old default of `1` silently serialized everything.
pub fn default_ncores() -> usize {
    std::env::var("EXAGEOSTAT_NCORES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
        })
}

/// `hardware = list(ncores, ngpus, ts, pgrid, qgrid)` of `exageostat_init`.
/// `ngpus`, `pgrid`, `qgrid` configure the *simulated* accelerator /
/// cluster studies (Figs 6–7); execution on this machine uses `ncores`
/// threads with the chosen scheduling policy.
#[derive(Clone, Debug)]
pub struct Hardware {
    pub ncores: usize,
    pub ngpus: usize,
    pub ts: usize,
    pub pgrid: usize,
    pub qgrid: usize,
    pub policy: Policy,
}

impl Default for Hardware {
    fn default() -> Self {
        Hardware {
            // All available hardware threads (EXAGEOSTAT_NCORES overrides;
            // so does the CLI's --ncores).  Runtime construction warns when
            // a request oversubscribes the machine.
            ncores: default_ncores(),
            ngpus: 0,
            ts: 320,
            pgrid: 1,
            qgrid: 1,
            policy: Policy::Lws,
        }
    }
}

/// `optimization = list(clb, cub, tol, max_iters)` of the MLE functions.
#[derive(Clone, Debug)]
pub struct MleOptions {
    pub clb: Vec<f64>,
    pub cub: Vec<f64>,
    pub tol: f64,
    /// `0` = run to convergence (the paper's `max_iters = 0`).
    pub max_iters: usize,
    pub method: Method,
}

impl MleOptions {
    pub fn new(clb: Vec<f64>, cub: Vec<f64>, tol: f64, max_iters: usize) -> Self {
        MleOptions {
            clb,
            cub,
            tol,
            max_iters,
            method: Method::Bobyqa,
        }
    }
}

/// Result of an MLE run (`result$...` of the R API).
#[derive(Clone, Debug)]
pub struct MleResult {
    pub theta: Vec<f64>,
    pub loglik: f64,
    pub iters: usize,
    pub time_per_iter: f64,
    pub total_time: f64,
    pub history: Vec<f64>,
}

/// An initialized ExaGeoStat instance (`exageostat_init` ...
/// `exageostat_finalize`).  The compute backend is picked once, at
/// construction: [`ExaGeoStat::init`] honors `EXAGEOSTAT_BACKEND`
/// (`native|pjrt`), [`ExaGeoStat::init_with_backend`] selects explicitly.
///
/// Construction also spawns the **persistent task runtime**: `ncores`
/// worker threads that live for the instance's lifetime and execute
/// every task-graph job (simulation, all likelihood variants, kriging)
/// — the `starpu_init` / `starpu_shutdown` lifecycle of ExaGeoStat.
/// [`ExaGeoStat::finalize`] is a real shutdown: it drains in-flight
/// work and joins the workers.
pub struct ExaGeoStat {
    pub hw: Hardware,
    engine: ArcEngine,
    runtime: Arc<Runtime>,
}

impl ExaGeoStat {
    /// `exageostat_init(hardware)`.  Backend from `EXAGEOSTAT_BACKEND`,
    /// defaulting to the pure-Rust native engine.  Spawns the worker
    /// runtime.
    pub fn init(hw: Hardware) -> Self {
        let spec = crate::scheduler::placement::class_spec_for(hw.ncores.max(1));
        let runtime = Arc::new(Runtime::new_with_classes(&spec, hw.policy));
        ExaGeoStat {
            hw,
            engine: backend::default_engine(),
            runtime,
        }
    }

    /// `exageostat_init(hardware)` with an explicit compute backend.
    /// Fails cleanly when the backend is unavailable (e.g. `pjrt` without
    /// the cargo feature or without `make artifacts`).
    pub fn init_with_backend(hw: Hardware, b: Backend) -> anyhow::Result<Self> {
        let engine = backend::create_engine(b)?;
        let spec = crate::scheduler::placement::class_spec_for(hw.ncores.max(1));
        let runtime = Arc::new(Runtime::new_with_classes(&spec, hw.policy));
        Ok(ExaGeoStat {
            hw,
            engine,
            runtime,
        })
    }

    /// `exageostat_finalize()`: drain in-flight jobs and join the worker
    /// threads.  Contexts cloned from this instance must not submit
    /// afterwards (doing so panics).
    pub fn finalize(self) {
        self.runtime.shutdown();
    }

    /// The persistent worker runtime (shared by every [`ExecCtx`] this
    /// instance hands out).
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Name of the active compute backend (`"native"` or `"pjrt"`).
    pub fn backend_name(&self) -> &'static str {
        self.engine.name()
    }

    pub fn ctx(&self) -> ExecCtx {
        ExecCtx {
            ncores: self.hw.ncores.max(1),
            ts: self.hw.ts,
            policy: self.hw.policy,
            engine: self.engine.clone(),
            runtime: self.runtime.clone(),
            job_prio: 0,
            cancel: CancelToken::new(),
            shards: None,
            tile_budget: crate::linalg::tile::tile_budget_from_env(),
        }
    }

    #[cfg(test)]
    fn problem(
        &self,
        data: &GeoData,
        kernel: &str,
        dmetric: &str,
    ) -> anyhow::Result<(crate::likelihood::Problem, Arc<dyn CovKernel>)> {
        let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(kernel)?);
        let metric = DistanceMetric::parse(dmetric)?;
        let p = crate::likelihood::Problem {
            kernel: kernel.clone(),
            locs: Arc::new(data.locs.clone()),
            z: Arc::new(data.z.clone()),
            metric,
        };
        Ok((p, kernel))
    }

    /// `simulate_data_exact(kernel, theta, dmetric, n, seed)`.
    pub fn simulate_data_exact(
        &self,
        kernel: &str,
        theta: &[f64],
        dmetric: &str,
        n: usize,
        seed: u64,
    ) -> anyhow::Result<GeoData> {
        let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(kernel)?);
        let metric = DistanceMetric::parse(dmetric)?;
        simulation::simulate_data_exact(kernel, theta, n, metric, seed, &self.ctx())
    }

    /// `simulate_obs_exact(x, y, kernel, theta, dmetric)`.
    pub fn simulate_obs_exact(
        &self,
        x: &[f64],
        y: &[f64],
        kernel: &str,
        theta: &[f64],
        dmetric: &str,
        seed: u64,
    ) -> anyhow::Result<GeoData> {
        anyhow::ensure!(x.len() == y.len(), "x/y length mismatch");
        let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(kernel)?);
        let metric = DistanceMetric::parse(dmetric)?;
        let locs: Vec<Location> = x
            .iter()
            .zip(y)
            .map(|(&xi, &yi)| Location::new(xi, yi))
            .collect();
        simulation::simulate_obs_exact(kernel, theta, locs, metric, seed, &self.ctx())
    }

    /// Shared MLE driver over a likelihood variant: builds a
    /// [`GeoModel`] (which validates the whole configuration up front,
    /// with typed [`ApiError`]s — notably bounds arity and the DST/MP
    /// band vs. the tile grid, *before* the O(n^2) session setup) and
    /// fits it on this instance's persistent runtime.
    pub fn mle(
        &self,
        data: &GeoData,
        kernel: &str,
        dmetric: &str,
        opt: &MleOptions,
        variant: Variant,
    ) -> anyhow::Result<MleResult> {
        GeoModel::builder()
            .data(data.clone())
            .kernel(kernel)
            .metric(dmetric)
            .variant(variant)
            .options(opt.clone())
            .tile_size(self.hw.ts)
            .build()?
            .fit(self)
    }

    /// `exact_mle(data, kernel, dmetric, optimization)`.
    pub fn exact_mle(
        &self,
        data: &GeoData,
        kernel: &str,
        dmetric: &str,
        opt: &MleOptions,
    ) -> anyhow::Result<MleResult> {
        self.mle(data, kernel, dmetric, opt, Variant::Exact)
    }

    /// `dst_mle(...)` — Diagonal Super Tile approximation.
    pub fn dst_mle(
        &self,
        data: &GeoData,
        kernel: &str,
        dmetric: &str,
        opt: &MleOptions,
        band: usize,
    ) -> anyhow::Result<MleResult> {
        self.mle(data, kernel, dmetric, opt, Variant::Dst { band })
    }

    /// `tlr_mle(...)` — Tile Low-Rank approximation.
    pub fn tlr_mle(
        &self,
        data: &GeoData,
        kernel: &str,
        dmetric: &str,
        opt: &MleOptions,
        tol: f64,
        max_rank: usize,
    ) -> anyhow::Result<MleResult> {
        self.mle(data, kernel, dmetric, opt, Variant::Tlr { tol, max_rank })
    }

    /// `mp_mle(...)` — mixed-precision approximation.
    pub fn mp_mle(
        &self,
        data: &GeoData,
        kernel: &str,
        dmetric: &str,
        opt: &MleOptions,
        band: usize,
    ) -> anyhow::Result<MleResult> {
        self.mle(data, kernel, dmetric, opt, Variant::Mp { band })
    }

    /// Speculative exact MLE: race `starts.len()` optimizer lanes from
    /// different starting points over a pool of per-lane sessions and
    /// keep the first to converge (see [`mle_speculative`]).  Useful
    /// when the objective surface is multimodal or a good start is
    /// known only approximately — the losers are cancelled, not run to
    /// completion.
    pub fn exact_mle_speculative(
        &self,
        data: &GeoData,
        kernel: &str,
        dmetric: &str,
        opt: &MleOptions,
        starts: &[Vec<f64>],
    ) -> anyhow::Result<SpeculativeMle> {
        anyhow::ensure!(!starts.is_empty(), "speculative MLE needs at least one start");
        let k: Arc<dyn CovKernel> = Arc::from(kernel_by_name(kernel)?);
        let metric = DistanceMetric::parse(dmetric)?;
        let problem = crate::likelihood::Problem {
            kernel: k,
            locs: Arc::new(data.locs.clone()),
            z: Arc::new(data.z.clone()),
            metric,
        };
        let mut sessions = Vec::with_capacity(starts.len());
        for _ in starts {
            sessions.push(EvalSession::new(&problem, Variant::Exact, &self.ctx())?);
        }
        mle_speculative(&mut sessions, starts, opt)
    }

    /// `exact_predict(train, new, kernel, dmetric, est_theta)`.  The
    /// covariance factorization and forward solve run as one job on the
    /// instance's persistent runtime (tiled, parallel) rather than on a
    /// private dense path.
    pub fn exact_predict(
        &self,
        train: &GeoData,
        new_locs: &[Location],
        kernel: &str,
        dmetric: &str,
        theta: &[f64],
        with_variance: bool,
    ) -> anyhow::Result<Prediction> {
        let k: Arc<dyn CovKernel> = Arc::from(kernel_by_name(kernel)?);
        let metric = DistanceMetric::parse(dmetric)?;
        prediction::exact_predict_ctx(
            k,
            theta,
            &train.locs,
            &train.z,
            new_locs,
            metric,
            with_variance,
            &self.ctx(),
        )
    }

    /// `exact_fisher(...)`.
    pub fn exact_fisher(
        &self,
        locs: &[Location],
        kernel: &str,
        dmetric: &str,
        theta: &[f64],
    ) -> anyhow::Result<FisherResult> {
        let k = kernel_by_name(kernel)?;
        let metric = DistanceMetric::parse(dmetric)?;
        prediction::exact_fisher(k.as_ref(), theta, locs, metric)
    }

    /// `exact_mloe_mmom(...)`.
    pub fn exact_mloe_mmom(
        &self,
        obs_locs: &[Location],
        new_locs: &[Location],
        kernel: &str,
        dmetric: &str,
        theta_true: &[f64],
        theta_approx: &[f64],
    ) -> anyhow::Result<MloeMmom> {
        let k = kernel_by_name(kernel)?;
        let metric = DistanceMetric::parse(dmetric)?;
        prediction::exact_mloe_mmom(k.as_ref(), theta_true, theta_approx, obs_locs, new_locs, metric)
    }
}

/// Drive the optimizer over an existing [`EvalSession`].
///
/// This is the reusable core of [`ExaGeoStat::mle`]: the coordinator
/// calls it directly with sessions from its cache, so repeated MLE
/// requests on the same dataset skip the Morton/distance/workspace
/// setup entirely and only pay warm iterations.
///
/// The session's cancellation token (see [`EvalSession::set_cancel`])
/// is honoured between objective evaluations: when it fires, the
/// optimizer stops at its next iteration boundary and this function
/// returns [`ApiError::Cancelled`] — or [`ApiError::Timeout`] when the
/// token was fired by a deadline or the runtime watchdog.  An
/// evaluation failing with an infrastructure error ([`TaskError::Io`],
/// [`TaskError::Panic`], [`TaskError::Timeout`]) stops the search and
/// surfaces that error; numerical infeasibility (`Numerical`, the
/// non-SPD probes BOBYQA makes routinely) keeps steering the search
/// with `+inf` exactly as before.
pub fn mle_with_session(session: &mut EvalSession, opt: &MleOptions) -> anyhow::Result<MleResult> {
    mle_with_session_from(session, opt, None)
}

/// [`mle_with_session`] with an explicit starting point (in *parameter*
/// space, like the bounds).  `None` keeps the R package's default of
/// starting at the lower bounds; [`mle_speculative`] passes a distinct
/// start per racing candidate.  Out-of-bounds components are clamped.
pub fn mle_with_session_from(
    session: &mut EvalSession,
    opt: &MleOptions,
    start: Option<&[f64]>,
) -> anyhow::Result<MleResult> {
    let nparams = session.kernel().nparams();
    if opt.clb.len() != nparams || opt.cub.len() != nparams {
        return Err(ApiError::BoundsArity {
            kernel: session.kernel().name().to_string(),
            expected: nparams,
            got_clb: opt.clb.len(),
            got_cub: opt.cub.len(),
        }
        .into());
    }
    let cancel = session.cancel_token().clone();
    // Optimize in log-parameter space: Matérn parameters are positive
    // and the (sigma_sq, beta) profile is banana-shaped in linear
    // scale; the log transform conditions it (standard practice, and
    // what makes BOBYQA's quadratic models accurate here).
    let log_ok = opt.clb.iter().all(|&v| v > 0.0);
    // Default start: the lower bounds (what the R package does).  An
    // explicit start is clamped into the box, then mapped alongside it.
    let start_lin: Vec<f64> = match start {
        Some(s) => {
            anyhow::ensure!(
                s.len() == nparams,
                "start point has {} components, kernel needs {nparams}",
                s.len()
            );
            s.iter()
                .zip(opt.clb.iter().zip(&opt.cub))
                .map(|(&v, (&lo, &hi))| v.clamp(lo, hi))
                .collect()
        }
        None => opt.clb.clone(),
    };
    let (lo, hi, init): (Vec<f64>, Vec<f64>, Vec<f64>) = if log_ok {
        (
            opt.clb.iter().map(|v| v.ln()).collect(),
            opt.cub.iter().map(|v| v.ln()).collect(),
            start_lin.iter().map(|v| v.ln()).collect(),
        )
    } else {
        (opt.clb.clone(), opt.cub.clone(), start_lin)
    };
    let back = |x: &[f64]| -> Vec<f64> {
        if log_ok {
            x.iter().map(|v| v.exp()).collect()
        } else {
            x.to_vec()
        }
    };
    // The optimizer is stopped through a *mirror* token, not the request
    // token: infeasible-but-recoverable evaluations (non-SPD theta, i.e.
    // `TaskError::Numerical`) keep steering the search with +inf as they
    // always did, while infrastructure failures — task panics, spill I/O
    // errors, watchdog timeouts — latch the first error, fire the mirror,
    // and surface the latched error verbatim after the search unwinds.
    // Firing the request token itself would mislabel the job as
    // user-cancelled and defeat the coordinator's whole-job retry.
    let stop = CancelToken::new();
    let latched: RefCell<Option<anyhow::Error>> = RefCell::new(None);
    let mut objective = |x: &[f64]| -> f64 {
        if cancel.is_cancelled() {
            stop.cancel();
            return f64::INFINITY;
        }
        let theta = back(x);
        match session.eval(&theta) {
            Ok(l) => -l.loglik,
            Err(e) => {
                let infra = is_timeout(&e)
                    || e.chain().any(|c| {
                        matches!(
                            c.downcast_ref::<TaskError>(),
                            Some(TaskError::Panic(_) | TaskError::Io(_) | TaskError::Timeout(_))
                        )
                    });
                if infra {
                    if latched.borrow().is_none() {
                        *latched.borrow_mut() = Some(e);
                    }
                    stop.cancel();
                }
                f64::INFINITY
            }
        }
    };
    // Optional bounded restart from deterministically jittered in-box
    // points when the search never finds a positive-definite theta
    // (`EXAGEOSTAT_JITTER_RETRY=k`, default 0 = off so results stay
    // bit-identical to previous releases).
    let jitter_retries: usize = std::env::var("EXAGEOSTAT_JITTER_RETRY")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut attempt = 0usize;
    let r = loop {
        let opts = OptOptions {
            tol: opt.tol,
            max_iters: opt.max_iters,
            init: if attempt == 0 {
                init.clone()
            } else {
                jittered_init(&lo, &hi, attempt)
            },
            stop: Some(stop.clone()),
        };
        let r = optimizer::minimize(
            opt.method,
            &mut objective,
            Bounds::new(lo.clone(), hi.clone())?,
            &opts,
        );
        if r.fx.is_finite() || r.stopped || latched.borrow().is_some() || attempt >= jitter_retries {
            break r;
        }
        attempt += 1;
    };
    if let Some(e) = latched.into_inner() {
        return Err(e);
    }
    if r.stopped {
        // The optimizer *observed* the stop signal and cut the search
        // short; whatever iterate it holds is not an MLE.  Report the
        // cancellation as a typed, downcastable error — a token fired by
        // the deadline/watchdog machinery reports `Timeout`, a plain
        // cancellation reports `Cancelled`.  (Checking `r.stopped` rather
        // than re-reading the token avoids mislabeling a run whose token
        // fired only after the search converged.)
        return Err(if cancel.timed_out() {
            ApiError::Timeout.into()
        } else {
            ApiError::Cancelled.into()
        });
    }
    anyhow::ensure!(
        r.fx.is_finite(),
        "MLE failed: no positive-definite covariance found within bounds"
    );
    Ok(MleResult {
        theta: back(&r.x),
        loglik: -r.fx,
        iters: r.iters,
        time_per_iter: r.time_per_iter,
        total_time: r.total_time,
        history: r.history,
    })
}

/// Deterministic in-box restart point for attempt `a >= 1` of the
/// jitter-retry loop: low-discrepancy (golden-ratio / plastic-constant)
/// fractions of the box, so successive attempts probe distinct regions
/// without any RNG state — reruns are bit-reproducible.
fn jittered_init(lo: &[f64], hi: &[f64], attempt: usize) -> Vec<f64> {
    lo.iter()
        .zip(hi)
        .enumerate()
        .map(|(i, (&l, &h))| {
            let f = (attempt as f64 * 0.618_033_988_749_895
                + (i + 1) as f64 * 0.324_717_957_244_746)
                .fract();
            l + (h - l) * f
        })
        .collect()
}

/// Outcome of a speculative MLE race ([`mle_speculative`]).
#[derive(Clone, Debug)]
pub struct SpeculativeMle {
    /// The winning candidate's fit.
    pub result: MleResult,
    /// Index (into `sessions` / `starts`) of the winner.
    pub winner: usize,
    /// Runtime tasks the race *avoided* executing: once the winner
    /// converged, the losers' cancellation tokens fired and their
    /// queued-but-not-started tasks were retired unrun.  Measured as
    /// the delta of [`Runtime::tasks_skipped`] over the race.
    pub tasks_skipped: u64,
}

/// Race several MLE candidates speculatively and keep the first to
/// converge.
///
/// Each session gets its own optimizer driven from its own starting
/// point (`starts[i]`, parameter space, clamped into the bounds box).
/// All lanes share the instance's persistent worker runtime — the race
/// adds optimizer *threads*, not compute workers, so objective
/// evaluations from different lanes interleave on the same cores.  The
/// first lane whose optimizer converges wins; every other lane's
/// [`CancelToken`] fires immediately, its in-flight evaluation stops at
/// the next task boundary, and its never-started tasks are skipped (the
/// saving reported in [`SpeculativeMle::tasks_skipped`]).
///
/// `sessions` must all evaluate the same problem (same data/kernel/
/// variant) for the race to be meaningful; each needs its own workspace,
/// which is why the pool is a slice of sessions rather than one shared.
/// When every lane fails, the first lane's error is returned.
pub fn mle_speculative(
    sessions: &mut [EvalSession],
    starts: &[Vec<f64>],
    opt: &MleOptions,
) -> anyhow::Result<SpeculativeMle> {
    anyhow::ensure!(!sessions.is_empty(), "speculative MLE needs at least one session");
    anyhow::ensure!(
        sessions.len() == starts.len(),
        "{} sessions but {} start points",
        sessions.len(),
        starts.len()
    );
    let runtime = sessions[0].ctx().runtime.clone();
    let skipped_before = runtime.tasks_skipped();
    // One fresh token per lane: cached sessions may carry a fired token
    // from a previous race, and the loser-cancellation below must not
    // touch other lanes.
    let tokens: Vec<CancelToken> = (0..sessions.len()).map(|_| CancelToken::new()).collect();
    for (s, t) in sessions.iter_mut().zip(&tokens) {
        s.set_cancel(t.clone());
    }
    let (win, mut first_err) = std::thread::scope(|sc| {
        let (tx, rx) = std::sync::mpsc::channel::<(usize, anyhow::Result<MleResult>)>();
        for (i, (session, start)) in sessions.iter_mut().zip(starts).enumerate() {
            let tx = tx.clone();
            sc.spawn(move || {
                let r = mle_with_session_from(session, opt, Some(start.as_slice()));
                // The receiver hangs up after a winner; losers' sends
                // failing is expected.
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut win: Option<(usize, MleResult)> = None;
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        // Drain *all* lanes: scoped threads join at scope exit anyway,
        // so leaving the channel early would not return control sooner —
        // and cancelled lanes exit fast once their token fires.
        for (i, r) in rx.iter() {
            match r {
                Ok(res) => {
                    if win.is_none() {
                        for (j, t) in tokens.iter().enumerate() {
                            if j != i {
                                t.cancel();
                            }
                        }
                        win = Some((i, res));
                    }
                    // A slower lane that converged before its token
                    // fired is discarded: first convergence wins.
                }
                Err(e) => {
                    let keep = match &first_err {
                        Some((j, _)) => i < *j,
                        None => true,
                    };
                    if keep {
                        first_err = Some((i, e));
                    }
                }
            }
        }
        (win, first_err)
    });
    match win {
        Some((winner, result)) => Ok(SpeculativeMle {
            result,
            winner,
            tasks_skipped: runtime.tasks_skipped() - skipped_before,
        }),
        None => Err(first_err
            .take()
            .map(|(_, e)| e)
            .unwrap_or_else(|| anyhow::anyhow!("speculative MLE: no lane produced a result"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::likelihood;

    fn small_hw(ts: usize) -> Hardware {
        Hardware {
            ncores: 2,
            ngpus: 0,
            ts,
            pgrid: 1,
            qgrid: 1,
            policy: Policy::Prio,
        }
    }

    #[test]
    fn end_to_end_mle_recovers_parameters() {
        // Example-2 style: simulate at theta = (1, 0.1, 0.5), refit.
        let exa = ExaGeoStat::init(small_hw(64));
        let theta_true = [1.0, 0.1, 0.5];
        let data = exa
            .simulate_data_exact("ugsm-s", &theta_true, "euclidean", 400, 0)
            .unwrap();
        let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], 1e-5, 0);
        let r = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();
        // MLE invariant: fitted loglik >= loglik at truth.
        let (problem, _) = exa.problem(&data, "ugsm-s", "euclidean").unwrap();
        let at_truth =
            likelihood::loglik(&problem, &theta_true, Variant::Exact, &exa.ctx()).unwrap();
        assert!(
            r.loglik >= at_truth.loglik - 1e-3,
            "fit {} < truth {}",
            r.loglik,
            at_truth.loglik
        );
        // Parameter sanity (n=400: generous statistical tolerances).
        assert!((r.theta[0] - 1.0).abs() < 0.8, "sigma_sq {}", r.theta[0]);
        assert!(r.theta[1] > 0.02 && r.theta[1] < 0.5, "beta {}", r.theta[1]);
        assert!(r.theta[2] > 0.2 && r.theta[2] < 1.5, "nu {}", r.theta[2]);
        assert!(r.iters > 10);
        assert!(r.time_per_iter > 0.0);
    }

    #[test]
    fn variant_mles_run_and_agree_roughly() {
        let exa = ExaGeoStat::init(small_hw(32));
        let data = exa
            .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 128, 1)
            .unwrap();
        let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, 60);
        let exact = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();
        let dst = exa.dst_mle(&data, "ugsm-s", "euclidean", &opt, 2).unwrap();
        let tlr = exa
            .tlr_mle(&data, "ugsm-s", "euclidean", &opt, 1e-9, usize::MAX)
            .unwrap();
        let mp = exa.mp_mle(&data, "ugsm-s", "euclidean", &opt, 1).unwrap();
        for (name, r) in [("dst", &dst), ("tlr", &tlr), ("mp", &mp)] {
            for i in 0..3 {
                assert!(
                    (r.theta[i] - exact.theta[i]).abs() < 1.0,
                    "{name} theta[{i}]: {} vs {}",
                    r.theta[i],
                    exact.theta[i]
                );
            }
        }
    }

    #[test]
    fn speculative_mle_wins_and_reports_skips() {
        let exa = ExaGeoStat::init(small_hw(32));
        let data = exa
            .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 96, 9)
            .unwrap();
        let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, 40);
        let starts = vec![
            vec![0.5, 0.05, 0.4],
            vec![2.0, 0.3, 1.0],
            // Out-of-box start exercises the clamp.
            vec![10.0, 1e-6, 0.5],
        ];
        let spec = exa
            .exact_mle_speculative(&data, "ugsm-s", "euclidean", &opt, &starts)
            .unwrap();
        assert!(spec.winner < starts.len());
        assert!(spec.result.loglik.is_finite());
        assert!(spec.result.iters > 0);
        // A single-lane race has no losers to cancel: it degenerates to
        // a plain fit and skips nothing.
        let single = exa
            .exact_mle_speculative(&data, "ugsm-s", "euclidean", &opt, &starts[..1])
            .unwrap();
        assert_eq!(single.winner, 0);
        assert_eq!(single.tasks_skipped, 0);
        exa.finalize();
    }

    #[test]
    fn predict_round_trip_through_api() {
        let exa = ExaGeoStat::init(small_hw(32));
        let data = exa
            .simulate_data_exact("ugsm-s", &[1.0, 0.2, 1.0], "euclidean", 100, 2)
            .unwrap();
        let train = GeoData {
            locs: data.locs[..90].to_vec(),
            z: data.z[..90].to_vec(),
        };
        let target = &data.locs[90..];
        let pred = exa
            .exact_predict(&train, target, "ugsm-s", "euclidean", &[1.0, 0.2, 1.0], true)
            .unwrap();
        // kriging should beat predicting the mean (0)
        let mse_krig: f64 = pred
            .mean
            .iter()
            .zip(&data.z[90..])
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f64>()
            / 10.0;
        let mse_zero: f64 = data.z[90..].iter().map(|t| t * t).sum::<f64>() / 10.0;
        assert!(mse_krig < mse_zero, "kriging {mse_krig} vs zero {mse_zero}");
        let v = pred.variance.unwrap();
        assert!(v.iter().all(|&x| x >= 0.0 && x <= 1.0 + 1e-9));
    }

    #[test]
    fn api_surface_matches_table_ii() {
        // Compile-time presence check of every Table II function.
        let exa = ExaGeoStat::init(Hardware::default());
        let _: fn(&ExaGeoStat, &GeoData, &str, &str, &MleOptions) -> anyhow::Result<MleResult> =
            ExaGeoStat::exact_mle;
        let _ = ExaGeoStat::dst_mle;
        let _ = ExaGeoStat::tlr_mle;
        let _ = ExaGeoStat::mp_mle;
        let _ = ExaGeoStat::exact_predict;
        let _ = ExaGeoStat::exact_fisher;
        let _ = ExaGeoStat::exact_mloe_mmom;
        let _ = ExaGeoStat::simulate_data_exact;
        let _ = ExaGeoStat::simulate_obs_exact;
        exa.finalize();
    }

    #[test]
    fn backend_selected_at_init() {
        let exa = ExaGeoStat::init(Hardware::default());
        // Without EXAGEOSTAT_BACKEND the default is the native engine.
        if std::env::var("EXAGEOSTAT_BACKEND").is_err() {
            assert_eq!(exa.backend_name(), "native");
        }
        assert_eq!(exa.ctx().engine.name(), exa.backend_name());
        let native = ExaGeoStat::init_with_backend(Hardware::default(), Backend::Native).unwrap();
        assert_eq!(native.backend_name(), "native");
        exa.finalize();
        native.finalize();
    }

    #[test]
    fn wrong_param_count_rejected_with_typed_error() {
        let exa = ExaGeoStat::init(small_hw(32));
        let data = exa
            .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 30, 3)
            .unwrap();
        let opt = MleOptions::new(vec![0.01; 2], vec![5.0; 2], 1e-4, 10);
        // Legacy Table-II wrappers surface the builder's typed error.
        let err = exa
            .exact_mle(&data, "ugsm-s", "euclidean", &opt)
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ApiError>(),
                Some(ApiError::BoundsArity {
                    expected: 3,
                    got_clb: 2,
                    ..
                })
            ),
            "{err:#}"
        );
    }

    #[test]
    fn builder_fit_matches_legacy_wrapper() {
        let exa = ExaGeoStat::init(small_hw(32));
        let data = exa
            .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 80, 6)
            .unwrap();
        let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 15);
        let legacy = exa
            .dst_mle(&data, "ugsm-s", "euclidean", &opt, 1)
            .unwrap();
        let model = GeoModel::builder()
            .data(data)
            .variant(Variant::Dst { band: 1 })
            .options(opt)
            .tile_size(32)
            .build()
            .unwrap();
        let fit = model.fit(&exa).unwrap();
        assert_eq!(legacy.loglik.to_bits(), fit.loglik.to_bits());
        assert_eq!(legacy.iters, fit.iters);
        for (a, b) in legacy.theta.iter().zip(&fit.theta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
