//! Typed model layer: [`GeoModel`] + [`ModelBuilder`].
//!
//! The paper's Table-II surface is a family of positionally-parallel
//! entry points (`exact_mle` / `dst_mle` / `tlr_mle` / `mp_mle`), each
//! re-threading `clb`/`cub`/`tol`/`max_iters` plus variant-specific
//! knobs.  The builder replaces that fan-out with one typed object:
//!
//! ```no_run
//! # use exageostat::api::{ExaGeoStat, GeoModel, Hardware};
//! # use exageostat::likelihood::Variant;
//! # fn main() -> anyhow::Result<()> {
//! let exa = ExaGeoStat::init(Hardware::default());
//! let data = exa.simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 400, 0)?;
//! let model = GeoModel::builder()
//!     .data(data)
//!     .kernel("ugsm-s")
//!     .metric("euclidean")
//!     .variant(Variant::Dst { band: 2 })
//!     .bounds(vec![0.001; 3], vec![5.0; 3])
//!     .tol(1e-5)
//!     .build()?;
//! let fit = model.fit(&exa)?;
//! println!("theta_hat = {:?} ({} iters)", fit.theta, fit.iters);
//! # Ok(()) }
//! ```
//!
//! Everything is validated **once**, in [`ModelBuilder::build`] — bounds
//! arity against the kernel's parameter count, lower < upper, variant
//! knobs, and (when the tile size is known) the DST/MP band against the
//! tile grid — with typed [`ApiError`]s, instead of surfacing deep
//! inside the optimizer.  The legacy wrappers now route through this
//! builder, so they inherit the same early, typed validation.
//!
//! A built model runs either **synchronously** ([`GeoModel::fit`] on an
//! [`ExaGeoStat`] instance) or **asynchronously** through the serving
//! stack (`coordinator::Request::mle_from_model` → `Client::submit` →
//! `Ticket`); both routes drive the same [`EvalSession`] machinery and
//! produce bit-identical results (see `rust/tests/api_client.rs`).

use super::error::ApiError;
use super::{mle_with_session, ExaGeoStat, MleOptions, MleResult};
use crate::covariance::{kernel_by_name, CovKernel, DistanceMetric, Location};
use crate::likelihood::{EvalSession, Problem, Variant};
use crate::optimizer::{Bounds, Method};
use crate::simulation::GeoData;
use std::sync::Arc;

/// A fully-validated Gaussian-process model specification: dataset,
/// kernel, distance metric, likelihood variant and optimization
/// settings.  Build one with [`GeoModel::builder`].
///
/// The dataset is held as `Arc`'d vectors so [`GeoModel::problem`] —
/// and therefore every `fit` — shares it without copying (the builder
/// split the `GeoData` it was given exactly once).
#[derive(Clone)]
pub struct GeoModel {
    locs: Arc<Vec<Location>>,
    z: Arc<Vec<f64>>,
    kernel: Arc<dyn CovKernel>,
    kernel_name: String,
    metric: DistanceMetric,
    metric_name: String,
    variant: Variant,
    opt: MleOptions,
}

impl GeoModel {
    /// Start building a model (see the module docs for the flow).
    pub fn builder() -> ModelBuilder {
        ModelBuilder::default()
    }

    /// Observation sites (shared).
    pub fn locs(&self) -> &Arc<Vec<Location>> {
        &self.locs
    }

    /// Observation vector (shared; length `p * n` for p-variate kernels).
    pub fn z(&self) -> &Arc<Vec<f64>> {
        &self.z
    }

    /// Number of observation sites.
    pub fn n(&self) -> usize {
        self.locs.len()
    }

    /// Kernel name as registered with `kernel_by_name`.
    pub fn kernel_name(&self) -> &str {
        &self.kernel_name
    }

    /// Distance-metric name (`"euclidean"` / `"great-circle"` form).
    pub fn metric_name(&self) -> &str {
        &self.metric_name
    }

    /// The likelihood variant (with its configuration).
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// The validated optimization settings.
    pub fn options(&self) -> &MleOptions {
        &self.opt
    }

    /// The model as a likelihood [`Problem`] (zero-copy: the `Arc`'d
    /// data vectors are shared).
    pub fn problem(&self) -> Problem {
        Problem {
            kernel: self.kernel.clone(),
            locs: self.locs.clone(),
            z: self.z.clone(),
            metric: self.metric,
        }
    }

    /// Re-check the DST/MP band against the tile grid implied by `ts`
    /// (the one `build` could not fix if no tile size was given).
    pub fn validate_tile_grid(&self, ts: usize) -> anyhow::Result<()> {
        let dim = self.kernel.nvariates() * self.locs.len();
        let ntiles = dim.div_ceil(ts.max(1)).max(1);
        if let Variant::Dst { band } | Variant::Mp { band } = self.variant {
            if band >= ntiles {
                return Err(ApiError::BandTooLarge { band, ntiles }.into());
            }
        }
        Ok(())
    }

    /// Fit the model by maximum likelihood on `exa`'s persistent
    /// runtime (the synchronous route; submit through a
    /// `coordinator::Client` for the asynchronous one).
    pub fn fit(&self, exa: &ExaGeoStat) -> anyhow::Result<MleResult> {
        self.validate_tile_grid(exa.hw.ts)?;
        let ctx = exa.ctx();
        let mut session = EvalSession::new(&self.problem(), self.variant, &ctx)?;
        mle_with_session(&mut session, &self.opt)
    }
}

impl std::fmt::Debug for GeoModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GeoModel")
            .field("n", &self.locs.len())
            .field("kernel", &self.kernel_name)
            .field("metric", &self.metric_name)
            .field("variant", &self.variant)
            .field("opt", &self.opt)
            .finish()
    }
}

/// Builder for [`GeoModel`] — every setter is optional except
/// [`ModelBuilder::data`]; [`ModelBuilder::build`] validates the whole
/// configuration at once (typed [`ApiError`]s for the machine-matchable
/// cases).
#[derive(Clone, Debug, Default)]
pub struct ModelBuilder {
    data: Option<GeoData>,
    kernel: Option<String>,
    metric: Option<String>,
    variant: Option<Variant>,
    clb: Option<Vec<f64>>,
    cub: Option<Vec<f64>>,
    tol: Option<f64>,
    max_iters: Option<usize>,
    method: Option<Method>,
    tile_size: Option<usize>,
}

impl ModelBuilder {
    /// The dataset to fit (required; taken by value — the builder
    /// `Arc`s it once at `build`, and no further copy ever happens).
    pub fn data(mut self, data: GeoData) -> Self {
        self.data = Some(data);
        self
    }

    /// Like [`ModelBuilder::data`] from a shared allocation (unwrapped
    /// without copying when this is the only reference).
    pub fn data_arc(mut self, data: Arc<GeoData>) -> Self {
        self.data = Some(Arc::try_unwrap(data).unwrap_or_else(|a| (*a).clone()));
        self
    }

    /// Covariance kernel by registry name (default `"ugsm-s"`).
    pub fn kernel(mut self, name: &str) -> Self {
        self.kernel = Some(name.to_string());
        self
    }

    /// Distance metric by name (default `"euclidean"`).
    pub fn metric(mut self, name: &str) -> Self {
        self.metric = Some(name.to_string());
        self
    }

    /// Likelihood variant (default [`Variant::Exact`]).
    pub fn variant(mut self, v: Variant) -> Self {
        self.variant = Some(v);
        self
    }

    /// Optimization box constraints, one entry per kernel parameter
    /// (default `0.001..=5.0` per parameter, the serving defaults).
    pub fn bounds(mut self, clb: Vec<f64>, cub: Vec<f64>) -> Self {
        self.clb = Some(clb);
        self.cub = Some(cub);
        self
    }

    /// Objective tolerance (default `1e-4`).
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = Some(tol);
        self
    }

    /// Max objective evaluations, `0` = run to convergence (default).
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = Some(max_iters);
        self
    }

    /// Optimizer choice (default [`Method::Bobyqa`]).
    pub fn method(mut self, m: Method) -> Self {
        self.method = Some(m);
        self
    }

    /// Adopt a whole legacy `optimization = list(...)` block at once
    /// (how the Table-II wrappers route through the builder).
    pub fn options(mut self, opt: MleOptions) -> Self {
        self.clb = Some(opt.clb);
        self.cub = Some(opt.cub);
        self.tol = Some(opt.tol);
        self.max_iters = Some(opt.max_iters);
        self.method = Some(opt.method);
        self
    }

    /// Tile size the model will execute with.  When set, `build` also
    /// validates the DST/MP band against the tile grid; when not,
    /// that check is deferred to [`GeoModel::fit`] / the coordinator,
    /// which know the hardware configuration.
    pub fn tile_size(mut self, ts: usize) -> Self {
        self.tile_size = Some(ts);
        self
    }

    /// Validate the configuration and produce the immutable model.
    pub fn build(self) -> anyhow::Result<GeoModel> {
        let GeoData { locs, z } = self.data.ok_or(ApiError::BuilderIncomplete("data"))?;
        let kernel_name = self.kernel.unwrap_or_else(|| "ugsm-s".to_string());
        let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(&kernel_name)?);
        let metric_name = self.metric.unwrap_or_else(|| "euclidean".to_string());
        let metric = DistanceMetric::parse(&metric_name)?;
        let variant = self.variant.unwrap_or(Variant::Exact);

        let nparams = kernel.nparams();
        let dim = kernel.nvariates() * locs.len();
        anyhow::ensure!(
            z.len() == dim,
            "z has length {} but kernel/locations imply {}",
            z.len(),
            dim
        );

        let clb = self.clb.unwrap_or_else(|| vec![0.001; nparams]);
        let cub = self.cub.unwrap_or_else(|| vec![5.0; nparams]);
        if clb.len() != nparams || cub.len() != nparams {
            return Err(ApiError::BoundsArity {
                kernel: kernel_name,
                expected: nparams,
                got_clb: clb.len(),
                got_cub: cub.len(),
            }
            .into());
        }
        // lower < upper, per coordinate (same rule the optimizer
        // enforces — just hoisted to construction time).
        Bounds::new(clb.clone(), cub.clone())?;

        match variant {
            Variant::Tlr { tol, max_rank } => {
                anyhow::ensure!(
                    tol.is_finite() && tol > 0.0,
                    "TLR tolerance must be finite and positive, got {tol}"
                );
                anyhow::ensure!(max_rank >= 1, "TLR max_rank must be >= 1");
            }
            Variant::Dst { band } | Variant::Mp { band } => {
                if let Some(ts) = self.tile_size {
                    let ntiles = dim.div_ceil(ts.max(1)).max(1);
                    if band >= ntiles {
                        return Err(ApiError::BandTooLarge { band, ntiles }.into());
                    }
                }
            }
            Variant::Exact => {}
        }

        let opt = MleOptions {
            clb,
            cub,
            tol: self.tol.unwrap_or(1e-4),
            max_iters: self.max_iters.unwrap_or(0),
            method: self.method.unwrap_or(Method::Bobyqa),
        };
        Ok(GeoModel {
            locs: Arc::new(locs),
            z: Arc::new(z),
            kernel,
            kernel_name,
            metric,
            metric_name,
            variant,
            opt,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covariance::Location;
    use crate::rng::Pcg64;

    fn toy_data(n: usize, seed: u64) -> GeoData {
        let mut rng = Pcg64::seed_from_u64(seed);
        GeoData {
            locs: (0..n)
                .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
                .collect(),
            z: (0..n).map(|_| rng.normal()).collect(),
        }
    }

    #[test]
    fn builder_defaults_and_overrides() {
        let m = GeoModel::builder().data(toy_data(20, 0)).build().unwrap();
        assert_eq!(m.kernel_name(), "ugsm-s");
        assert_eq!(m.metric_name(), "euclidean");
        assert_eq!(m.variant(), Variant::Exact);
        assert_eq!(m.options().clb, vec![0.001; 3]);
        assert_eq!(m.options().max_iters, 0);

        let m = GeoModel::builder()
            .data(toy_data(20, 0))
            .variant(Variant::Tlr {
                tol: 1e-7,
                max_rank: 16,
            })
            .bounds(vec![0.01; 3], vec![2.0; 3])
            .tol(1e-6)
            .max_iters(50)
            .method(Method::NelderMead)
            .build()
            .unwrap();
        assert_eq!(m.options().cub, vec![2.0; 3]);
        assert_eq!(m.options().tol, 1e-6);
        assert_eq!(m.options().max_iters, 50);
        assert_eq!(m.options().method, Method::NelderMead);
    }

    #[test]
    fn builder_rejects_missing_data_and_bad_kernel() {
        let err = GeoModel::builder().build().unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ApiError>(),
            Some(ApiError::BuilderIncomplete("data"))
        ));
        assert!(GeoModel::builder()
            .data(toy_data(10, 1))
            .kernel("no-such-kernel")
            .build()
            .is_err());
    }

    #[test]
    fn builder_rejects_bounds_arity_with_typed_error() {
        let err = GeoModel::builder()
            .data(toy_data(10, 2))
            .bounds(vec![0.01; 2], vec![5.0; 3])
            .build()
            .unwrap_err();
        match err.downcast_ref::<ApiError>() {
            Some(ApiError::BoundsArity {
                expected, got_clb, ..
            }) => {
                assert_eq!(*expected, 3);
                assert_eq!(*got_clb, 2);
            }
            other => panic!("wrong error: {other:?} ({err:#})"),
        }
        // inverted bounds rejected too
        assert!(GeoModel::builder()
            .data(toy_data(10, 2))
            .bounds(vec![5.0; 3], vec![0.01; 3])
            .build()
            .is_err());
    }

    #[test]
    fn builder_rejects_band_covering_the_tile_grid() {
        // 40 points, ts 16 -> 3x3 tile grid; band 3 covers everything.
        let err = GeoModel::builder()
            .data(toy_data(40, 3))
            .variant(Variant::Dst { band: 3 })
            .tile_size(16)
            .build()
            .unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ApiError>(),
            Some(ApiError::BandTooLarge { band: 3, ntiles: 3 })
        ));
        // band 2 (= ntiles - 1, the exact-equivalent limit) is fine
        assert!(GeoModel::builder()
            .data(toy_data(40, 3))
            .variant(Variant::Dst { band: 2 })
            .tile_size(16)
            .build()
            .is_ok());
        // without a tile size the check defers to fit/coordinator
        let m = GeoModel::builder()
            .data(toy_data(40, 3))
            .variant(Variant::Mp { band: 3 })
            .build()
            .unwrap();
        assert!(m.validate_tile_grid(16).is_err());
        assert!(m.validate_tile_grid(8).is_ok()); // 5x5 grid
    }

    #[test]
    fn builder_rejects_bad_tlr_knobs() {
        for (tol, max_rank) in [(0.0, 8), (f64::NAN, 8), (1e-7, 0)] {
            assert!(GeoModel::builder()
                .data(toy_data(10, 4))
                .variant(Variant::Tlr { tol, max_rank })
                .build()
                .is_err());
        }
    }
}
