//! Typed errors for the public API.
//!
//! The legacy Table-II entry points reported misconfiguration through
//! ad-hoc `anyhow!` strings, and some of it (bounds of the wrong
//! length) only surfaced deep inside the optimizer.  The builder and
//! client layers validate up front and return these variants instead;
//! callers that care can `downcast_ref::<ApiError>()`, everyone else
//! still sees a readable message through `anyhow`.

use std::fmt;

/// Machine-matchable error cases of the model/client API.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ApiError {
    /// `clb`/`cub` do not both have one entry per kernel parameter.
    BoundsArity {
        /// Kernel name (e.g. `"ugsm-s"`).
        kernel: String,
        /// The kernel's parameter count.
        expected: usize,
        /// Length of the supplied lower-bound vector.
        got_clb: usize,
        /// Length of the supplied upper-bound vector.
        got_cub: usize,
    },
    /// A DST/MP `band` at least the tile-grid size: every tile is
    /// already in band, so the request is either a misunderstanding of
    /// `band` or should have been `Variant::Exact`.
    BandTooLarge {
        /// The requested band.
        band: usize,
        /// Tiles per matrix dimension for this problem and tile size.
        ntiles: usize,
    },
    /// A required builder field was never set.
    BuilderIncomplete(&'static str),
    /// The job was cancelled before it produced a result.
    Cancelled,
    /// The job exceeded its deadline (or the runtime watchdog's stall
    /// threshold) and was cancelled with a timeout reason.
    Timeout,
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::BoundsArity {
                kernel,
                expected,
                got_clb,
                got_cub,
            } => write!(
                f,
                "kernel {kernel:?} expects {expected} parameters in clb/cub \
                 (got {got_clb} and {got_cub})"
            ),
            ApiError::BandTooLarge { band, ntiles } => write!(
                f,
                "band {band} covers the whole {ntiles}x{ntiles} tile grid \
                 (use band < {ntiles}, or Variant::Exact)"
            ),
            ApiError::BuilderIncomplete(field) => {
                write!(f, "ModelBuilder is missing required field `{field}`")
            }
            ApiError::Cancelled => write!(f, "job cancelled"),
            ApiError::Timeout => write!(f, "job timed out"),
        }
    }
}

impl std::error::Error for ApiError {}

/// Does `err` represent a cancellation (an [`ApiError::Cancelled`]
/// anywhere in its chain)?
pub fn is_cancelled(err: &anyhow::Error) -> bool {
    err.chain()
        .any(|e| matches!(e.downcast_ref::<ApiError>(), Some(ApiError::Cancelled)))
}

/// Does `err` represent a deadline/watchdog timeout (an
/// [`ApiError::Timeout`] or a raw
/// [`crate::scheduler::runtime::TaskError::Timeout`] anywhere in its
/// chain)?  The latter matters for paths that surface the runtime's
/// typed error without an API-layer wrapper — e.g. a watchdog-flagged
/// job latched by the MLE objective — which must still be classified a
/// timeout (counted in `stats().timeouts`, never job-retried).
pub fn is_timeout(err: &anyhow::Error) -> bool {
    use crate::scheduler::runtime::TaskError;
    err.chain().any(|e| {
        matches!(e.downcast_ref::<ApiError>(), Some(ApiError::Timeout))
            || matches!(e.downcast_ref::<TaskError>(), Some(TaskError::Timeout(_)))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_downcast() {
        let e: anyhow::Error = ApiError::BoundsArity {
            kernel: "ugsm-s".into(),
            expected: 3,
            got_clb: 2,
            got_cub: 3,
        }
        .into();
        assert!(e.to_string().contains("3 parameters"));
        assert!(matches!(
            e.downcast_ref::<ApiError>(),
            Some(ApiError::BoundsArity { expected: 3, .. })
        ));
        assert!(!is_cancelled(&e));
        let c: anyhow::Error = ApiError::Cancelled.into();
        assert!(is_cancelled(&c));
        // context layers must not hide the marker
        let wrapped = c.context("request 7");
        assert!(is_cancelled(&wrapped));
    }
}
