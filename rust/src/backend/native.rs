//! Pure-Rust compute engine: the default backend, with no external
//! dependencies — Matérn (and every other Table III kernel) tile
//! generation through `covariance::kernels`, dense log-likelihood through
//! `linalg::cholesky`. Handles general smoothness nu (Bessel K path) and
//! arbitrary tile shapes.

use super::{Engine, EngineLogLik};
use crate::covariance::{
    build_cov_dense, cov_from_dist, fill_cov_tile, CovKernel, DistBlock, DistanceMetric, Location,
};
use crate::linalg::cholesky::dense_chol_solve;

/// The always-available pure-Rust backend.
#[derive(Copy, Clone, Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> NativeEngine {
        NativeEngine
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn fill_tile(
        &self,
        kernel: &dyn CovKernel,
        theta: &[f64],
        locs: &[Location],
        metric: DistanceMetric,
        row0: usize,
        col0: usize,
        h: usize,
        w: usize,
        dist: Option<&DistBlock>,
        out: &mut [f64],
    ) {
        match dist {
            Some(block) if block.h == h && block.w == w => {
                cov_from_dist(kernel, theta, locs.len(), row0, col0, block, out);
            }
            _ => fill_cov_tile(kernel, theta, locs, metric, row0, col0, h, w, out),
        }
    }

    fn loglik(
        &self,
        kernel: &dyn CovKernel,
        theta: &[f64],
        locs: &[Location],
        z: &[f64],
        metric: DistanceMetric,
    ) -> anyhow::Result<EngineLogLik> {
        let dim = kernel.nvariates() * locs.len();
        anyhow::ensure!(
            z.len() == dim,
            "z has length {} but kernel/locations imply {dim}",
            z.len()
        );
        kernel.validate(theta)?;
        let mut sigma = build_cov_dense(kernel, theta, locs, metric);
        let (logdet, y) = dense_chol_solve(&mut sigma, z).map_err(|e| {
            anyhow::anyhow!(
                "covariance not positive definite at pivot {} (theta = {theta:?})",
                e.pivot
            )
        })?;
        let sse: f64 = y.iter().map(|v| v * v).sum();
        let loglik =
            -0.5 * sse - 0.5 * logdet - 0.5 * dim as f64 * (2.0 * std::f64::consts::PI).ln();
        Ok(EngineLogLik {
            loglik,
            logdet,
            sse,
        })
    }
}
