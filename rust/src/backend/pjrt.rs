//! PJRT compute engine (cargo feature `pjrt`): executes the AOT-compiled
//! JAX/Pallas artifacts through [`crate::runtime::PjrtEngine`], and falls
//! back to [`NativeEngine`] for anything the artifacts don't cover —
//! non-lowered tile sizes, rectangular edge tiles, multivariate kernels,
//! non-half-integer smoothness, or a missing/failed artifact.
//!
//! The artifact contract (see `python/compile/aot.py`): univariate
//! Matérn (`ugsm-s`), Euclidean distance, `theta = (sigma_sq, beta, nu)`
//! with nu in {0.5, 1.5, 2.5}, square `ts x ts` tiles for the lowered
//! sizes, and fixed-size `loglik_n{n}` graphs.

use super::native::NativeEngine;
use super::{Engine, EngineLogLik};
use crate::covariance::{CovKernel, DistBlock, DistanceMetric, Location};
use crate::runtime::PjrtEngine;

/// Is `nu` one of the half-integer smoothness values the Pallas kernel
/// implements in closed form?
fn half_integer_nu(nu: f64) -> bool {
    [0.5, 1.5, 2.5].iter().any(|v| (nu - v).abs() < 1e-12)
}

/// The PJRT-backed engine: artifacts where possible, native elsewhere.
pub struct PjrtBackend {
    inner: PjrtEngine,
    fallback: NativeEngine,
    tile_sizes: Vec<usize>,
}

impl PjrtBackend {
    /// Wrap an existing runtime engine.
    pub fn new(inner: PjrtEngine) -> PjrtBackend {
        let tile_sizes = inner.available_tile_sizes();
        PjrtBackend {
            inner,
            fallback: NativeEngine::new(),
            tile_sizes,
        }
    }

    /// Construct from the default artifact directory (fails cleanly when
    /// `make artifacts` has not run or the XLA runtime is unavailable).
    pub fn from_default() -> anyhow::Result<PjrtBackend> {
        Ok(PjrtBackend::new(PjrtEngine::from_default()?))
    }

    /// Can the tile artifact serve this request exactly?
    #[allow(clippy::too_many_arguments)]
    fn tile_covered(
        &self,
        kernel: &dyn CovKernel,
        theta: &[f64],
        locs: &[Location],
        metric: DistanceMetric,
        row0: usize,
        col0: usize,
        h: usize,
        w: usize,
    ) -> bool {
        kernel.nvariates() == 1
            && kernel.name() == "ugsm-s"
            && metric == DistanceMetric::Euclidean
            && theta.len() == 3
            && half_integer_nu(theta[2])
            && h == w
            && self.tile_sizes.contains(&h)
            && row0 + h <= locs.len()
            && col0 + w <= locs.len()
    }
}

impl Engine for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn fill_tile(
        &self,
        kernel: &dyn CovKernel,
        theta: &[f64],
        locs: &[Location],
        metric: DistanceMetric,
        row0: usize,
        col0: usize,
        h: usize,
        w: usize,
        dist: Option<&DistBlock>,
        out: &mut [f64],
    ) {
        // The artifact computes distances on-device from the coordinate
        // blocks, so a precomputed `dist` is irrelevant on the artifact
        // path; any miss falls back to native *with* the cache, keeping
        // warm-iteration behaviour consistent across backends.
        if self.tile_covered(kernel, theta, locs, metric, row0, col0, h, w) {
            let rows = &locs[row0..row0 + h];
            let cols = &locs[col0..col0 + w];
            if let Ok(tile) = self.inner.matern_tile(h, rows, cols, theta) {
                out[..h * w].copy_from_slice(&tile);
                return;
            }
        }
        self.fallback
            .fill_tile(kernel, theta, locs, metric, row0, col0, h, w, dist, out);
    }

    fn loglik(
        &self,
        kernel: &dyn CovKernel,
        theta: &[f64],
        locs: &[Location],
        z: &[f64],
        metric: DistanceMetric,
    ) -> anyhow::Result<EngineLogLik> {
        let covered = kernel.nvariates() == 1
            && kernel.name() == "ugsm-s"
            && metric == DistanceMetric::Euclidean
            && theta.len() == 3
            && half_integer_nu(theta[2])
            && z.len() == locs.len();
        if covered {
            // The artifact set only contains `loglik_n{n}` for the lowered
            // problem sizes; any miss (size, parse, execute) falls through
            // to the native dense path.
            if let Ok((loglik, logdet, sse)) = self.inner.loglik(locs, z, theta) {
                return Ok(EngineLogLik {
                    loglik,
                    logdet,
                    sse,
                });
            }
        }
        self.fallback.loglik(kernel, theta, locs, z, metric)
    }
}
