//! Pluggable compute backends for the likelihood hot path.
//!
//! The paper's premise is portability: the same exact Gaussian
//! log-likelihood must run on whatever parallel architecture is available.
//! This module is that seam on the Rust side — an [`Engine`] trait with
//! two implementations:
//!
//! * [`native::NativeEngine`] — pure Rust (Matérn tiles via
//!   `covariance::kernels`, dense log-likelihood via `linalg::cholesky`);
//!   always available, no external dependencies, the default.
//! * `pjrt::PjrtBackend` (cargo feature `pjrt`, off by default) — the
//!   AOT-compiled JAX/Pallas artifacts executed through the PJRT client in
//!   [`crate::runtime`], falling back to the native kernels for any shape
//!   or parameter the artifacts don't cover.
//!
//! Selection happens once, at context construction
//! ([`crate::likelihood::ExecCtx`] / [`crate::api::ExaGeoStat::init`]),
//! and can be overridden with `EXAGEOSTAT_BACKEND=native|pjrt`. See
//! `DESIGN.md` §2 for the backend-selection table.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::covariance::{CovKernel, DistBlock, DistanceMetric, Location};
use std::sync::{Arc, OnceLock};

/// Shared handle to a compute engine (cheap to clone into task closures).
pub type ArcEngine = Arc<dyn Engine>;

/// Result of a dense (small-problem / oracle) log-likelihood evaluation.
#[derive(Copy, Clone, Debug)]
pub struct EngineLogLik {
    pub loglik: f64,
    pub logdet: f64,
    pub sse: f64,
}

/// A compute backend for the two kernel families of the MLE pipeline:
/// covariance-tile generation (the `dcmg` task body) and the fixed-size
/// dense log-likelihood graph.
pub trait Engine: Send + Sync {
    /// Stable backend name (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Fill one `h x w` covariance tile at global offset `(row0, col0)`
    /// into the column-major buffer `out` (length >= `h * w`).
    ///
    /// `dist` is the warm-iteration fast path: when an
    /// [`EvalSession`](crate::likelihood::EvalSession) has precomputed
    /// this tile's distances, implementations should evaluate the kernel
    /// straight from the cached block instead of redoing the metric work.
    /// Passing `None` must produce the identical tile from `locs` alone.
    ///
    /// Infallible by contract: implementations that can miss (e.g. no
    /// lowered artifact for this tile size) must fall back to the native
    /// kernels rather than fail — tile tasks run inside the scheduler
    /// where errors cannot propagate.
    #[allow(clippy::too_many_arguments)]
    fn fill_tile(
        &self,
        kernel: &dyn CovKernel,
        theta: &[f64],
        locs: &[Location],
        metric: DistanceMetric,
        row0: usize,
        col0: usize,
        h: usize,
        w: usize,
        dist: Option<&DistBlock>,
        out: &mut [f64],
    );

    /// Dense exact log-likelihood of `z` at `locs` under `kernel(theta)`
    /// (the small-problem MLE objective and the parity-test oracle).
    fn loglik(
        &self,
        kernel: &dyn CovKernel,
        theta: &[f64],
        locs: &[Location],
        z: &[f64],
        metric: DistanceMetric,
    ) -> anyhow::Result<EngineLogLik>;
}

/// Backend selector (the value of `EXAGEOSTAT_BACKEND`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust kernels (general nu, any tile size). Always available.
    Native,
    /// AOT Pallas artifacts through PJRT (requires the `pjrt` feature and
    /// `make artifacts`); uncovered shapes fall back to native.
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> anyhow::Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => anyhow::bail!("unknown backend {other:?} (native|pjrt)"),
        }
    }
}

/// Instantiate an engine for an explicit backend choice.
pub fn create_engine(backend: Backend) -> anyhow::Result<ArcEngine> {
    match backend {
        Backend::Native => Ok(Arc::new(native::NativeEngine::new())),
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => Ok(Arc::new(pjrt::PjrtBackend::from_default()?)),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => anyhow::bail!(
            "this binary was built without the `pjrt` feature; \
             rebuild with `cargo build --features pjrt`"
        ),
    }
}

/// Process-wide default engine, honoring `EXAGEOSTAT_BACKEND=native|pjrt`.
///
/// Resolved once and memoized. A requested-but-unavailable backend (bad
/// name, feature off, artifacts missing) degrades to the native engine
/// with a warning on stderr — the default path must never panic on a
/// machine without XLA or artifacts.
pub fn default_engine() -> ArcEngine {
    static ENGINE: OnceLock<ArcEngine> = OnceLock::new();
    ENGINE
        .get_or_init(|| match std::env::var("EXAGEOSTAT_BACKEND") {
            Ok(name) => match Backend::parse(&name).and_then(create_engine) {
                Ok(engine) => engine,
                Err(err) => {
                    eprintln!(
                        "warning: EXAGEOSTAT_BACKEND={name} unavailable ({err:#}); \
                         falling back to the native backend"
                    );
                    Arc::new(native::NativeEngine::new())
                }
            },
            Err(_) => Arc::new(native::NativeEngine::new()),
        })
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covariance::fill_cov_tile;
    use crate::likelihood::testutil::{dense_oracle, small_problem};

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert!(Backend::parse("tpu").is_err());
    }

    #[test]
    fn native_engine_matches_dense_oracle() {
        // The satellite parity requirement: NativeEngine::loglik agrees
        // with the likelihood oracle to 1e-10.
        let engine = create_engine(Backend::Native).unwrap();
        assert_eq!(engine.name(), "native");
        for (n, seed) in [(40usize, 1u64), (60, 2)] {
            let p = small_problem(n, seed);
            for theta in [[1.0, 0.1, 0.5], [2.0, 0.2, 1.5]] {
                let want = dense_oracle(&p, &theta);
                let got = engine
                    .loglik(p.kernel.as_ref(), &theta, &p.locs, &p.z, p.metric)
                    .unwrap();
                assert!(
                    (got.loglik - want.loglik).abs() < 1e-10,
                    "n={n} theta={theta:?}: {} vs {}",
                    got.loglik,
                    want.loglik
                );
                assert!((got.logdet - want.logdet).abs() < 1e-10);
                assert!((got.sse - want.sse).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn native_fill_tile_matches_covariance_fill() {
        let engine = default_engine();
        let p = small_problem(30, 3);
        let theta = [1.3, 0.2, 1.0];
        let (row0, col0, h, w) = (4usize, 9usize, 7usize, 6usize);
        let mut got = vec![0.0; h * w];
        engine.fill_tile(
            p.kernel.as_ref(),
            &theta,
            &p.locs,
            p.metric,
            row0,
            col0,
            h,
            w,
            None,
            &mut got,
        );
        let mut want = vec![0.0; h * w];
        fill_cov_tile(
            p.kernel.as_ref(),
            &theta,
            &p.locs,
            p.metric,
            row0,
            col0,
            h,
            w,
            &mut want,
        );
        assert_eq!(got, want);
        // Precomputed-distance fast path: identical tile.
        let block = crate::covariance::build_dist_block(&p.locs, p.metric, row0, col0, h, w);
        let mut cached = vec![0.0; h * w];
        engine.fill_tile(
            p.kernel.as_ref(),
            &theta,
            &p.locs,
            p.metric,
            row0,
            col0,
            h,
            w,
            Some(&block),
            &mut cached,
        );
        assert_eq!(cached, want);
    }

    #[test]
    fn non_spd_is_clean_error_not_panic() {
        let engine = create_engine(Backend::Native).unwrap();
        let p = small_problem(10, 4);
        let mut locs = (*p.locs).clone();
        locs[1] = locs[0]; // exact duplicate => singular covariance
        let err = engine
            .loglik(p.kernel.as_ref(), &[1.0, 0.1, 0.5], &locs, &p.z, p.metric)
            .unwrap_err();
        assert!(err.to_string().contains("not positive definite"), "{err}");
    }
}
