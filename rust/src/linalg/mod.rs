//! Dense and tiled linear algebra substrate (the Chameleon / HiCMA
//! analogue — DESIGN.md §4.1–4.2).

pub mod blas;
pub mod cholesky;
pub mod lowrank;
pub mod matrix;
pub mod svd;
pub mod tile;

pub use blas::NotSpd;
pub use matrix::Matrix;
