//! Operand packing and reusable per-thread pack workspaces.
//!
//! GotoBLAS-style GEMM re-lays blocks of A and B into strip-contiguous
//! buffers so the micro-kernel streams them at unit stride.  The original
//! implementation allocated those buffers with a fresh `Vec` inside every
//! call — per *task* on the runtime workers, i.e. thousands of heap
//! round-trips per MLE iteration.  Here the buffers live in a
//! thread-local [`PackWs`]: the persistent `scheduler::Runtime` workers
//! grow them once (or are pre-grown via
//! `Runtime::prewarm_workers` + [`reserve_pack_workspaces`]) and every
//! warm tile task after that packs into already-owned memory.
//!
//! Growth events are counted in a process-global counter
//! ([`pack_buffer_allocs`], re-exported through `testkit`) — the
//! telemetry behind the "warm iterations perform zero pack-buffer
//! allocations" regression test, the pack-workspace sibling of the
//! `tile_matrix_allocs` counter from the session layer.  The counter is
//! global (not thread-local) because the allocations happen on worker
//! threads while the asserting test observes from the submitting thread.

use super::gemm::{KC, MC};
use super::simd::{MR32, MR64, NR32, NR64};
use super::Trans;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Borrowed read-only matrix storage in either precision (the mixed-
/// precision seam: MP off-band tiles are `F32`, everything else `F64`).
#[derive(Copy, Clone)]
pub enum MatRef<'a> {
    /// Full-precision column-major storage.
    F64(&'a [f64]),
    /// Demoted column-major storage (MP off-band tiles).
    F32(&'a [f32]),
}

impl<'a> MatRef<'a> {
    /// Element at linear index `idx`, demoted to f32 (the MP compute
    /// precision; exact for `F32`, a rounding for `F64`).
    #[inline]
    pub fn get_f32(&self, idx: usize) -> f32 {
        match self {
            MatRef::F64(s) => s[idx] as f32,
            MatRef::F32(s) => s[idx],
        }
    }

    /// Is this the demoted representation?
    pub fn is_f32(&self) -> bool {
        matches!(self, MatRef::F32(_))
    }

    /// The same matrix starting at linear offset `off` (column-major
    /// sub-panel with unchanged leading dimension).
    #[inline]
    pub fn slice_from(self, off: usize) -> MatRef<'a> {
        match self {
            MatRef::F64(s) => MatRef::F64(&s[off..]),
            MatRef::F32(s) => MatRef::F32(&s[off..]),
        }
    }
}

/// Borrowed mutable matrix storage in either precision.
pub enum MatMut<'a> {
    /// Full-precision column-major storage.
    F64(&'a mut [f64]),
    /// Demoted column-major storage (MP off-band tiles).
    F32(&'a mut [f32]),
}

impl<'a> MatMut<'a> {
    /// Reborrow (so a `MatMut` can be handed to a callee and used again).
    #[inline]
    pub fn rb(&mut self) -> MatMut<'_> {
        match self {
            MatMut::F64(s) => MatMut::F64(s),
            MatMut::F32(s) => MatMut::F32(s),
        }
    }

    /// The same matrix starting at linear offset `off`.
    #[inline]
    pub fn slice_from(self, off: usize) -> MatMut<'a> {
        match self {
            MatMut::F64(s) => MatMut::F64(&mut s[off..]),
            MatMut::F32(s) => MatMut::F32(&mut s[off..]),
        }
    }

    /// Shared view of the same storage.
    #[inline]
    pub fn view(&self) -> MatRef<'_> {
        match self {
            MatMut::F64(s) => MatRef::F64(s),
            MatMut::F32(s) => MatRef::F32(s),
        }
    }

    /// Is this the demoted representation?
    pub fn is_f32(&self) -> bool {
        matches!(self, MatMut::F32(_))
    }
}

/// Process-global count of pack/stage buffer growth events (heap
/// allocations performed by [`grown`]); see the module docs.
static PACK_BUFFER_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Same events, counted per thread (for tests whose kernel calls all
    /// run on the asserting thread — immune to concurrent test threads).
    static PACK_BUFFER_ALLOCS_LOCAL: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

/// Pack/stage buffer allocations performed by the whole process so far.
/// Global on purpose: the allocations of interest happen on runtime
/// *worker* threads while the regression test observes from the
/// submitting thread (run it in a dedicated test binary — concurrent
/// kernel-running tests in the same process would perturb the count).
pub fn pack_buffer_allocs() -> u64 {
    PACK_BUFFER_ALLOCS.load(Ordering::SeqCst)
}

/// Pack/stage buffer allocations performed by the current thread.
pub fn pack_buffer_allocs_this_thread() -> u64 {
    PACK_BUFFER_ALLOCS_LOCAL.with(|c| c.get())
}

/// Per-thread reusable buffers for packing and precision staging.
#[derive(Default)]
pub(super) struct PackWs {
    /// Packed A block, f64 path.
    pub pa64: Vec<f64>,
    /// Packed B panel, f64 path.
    pub pb64: Vec<f64>,
    /// Packed A block, f32 path.
    pub pa32: Vec<f32>,
    /// Packed B panel, f32 path.
    pub pb32: Vec<f32>,
    /// f64 staging area (MP tile generation before demotion).
    pub stage64: Vec<f64>,
    /// f32 staging area (triangular-factor demotion for MP TRSM).
    pub stage32: Vec<f32>,
}

thread_local! {
    static WS: RefCell<PackWs> = RefCell::new(PackWs::default());
}

/// Run `f` with this thread's pack workspace.  Re-entrant calls (which
/// the current kernels never make — packing callers do not nest) fall
/// back to a fresh, uncounted-after-drop workspace rather than panicking.
pub(super) fn with_ws<R>(f: impl FnOnce(&mut PackWs) -> R) -> R {
    WS.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ws) => f(&mut ws),
        Err(_) => f(&mut PackWs::default()),
    })
}

/// Make `v` at least `len` elements long, counting real reallocations in
/// [`pack_buffer_allocs`].  Contents beyond the previous length are
/// unspecified — every consumer fully overwrites the region it reads.
pub(super) fn grown<T: Copy + Default>(v: &mut Vec<T>, len: usize) -> &mut [T] {
    if v.len() < len {
        if v.capacity() < len {
            PACK_BUFFER_ALLOCS.fetch_add(1, Ordering::SeqCst);
            PACK_BUFFER_ALLOCS_LOCAL.with(|c| c.set(c.get() + 1));
        }
        v.resize(len, T::default());
    }
    &mut v[..len]
}

/// Grow the *current thread's* pack and stage buffers to the worst-case
/// footprint of tile-level kernels at tile size `ts`.  Called through
/// `Runtime::prewarm_workers` when an `EvalSession` is built, so warm
/// iterations start with fully-grown worker workspaces.
pub fn reserve_pack_workspaces(ts: usize) {
    let ts = ts.max(1);
    let kc = KC.min(ts);
    let pa64_cap = MC.min(ts).div_ceil(MR64) * MR64 * kc;
    let pb64_cap = ts.div_ceil(NR64) * NR64 * kc;
    let pa32_cap = MC.min(ts).div_ceil(MR32) * MR32 * kc;
    let pb32_cap = ts.div_ceil(NR32) * NR32 * kc;
    with_ws(|ws| {
        let _ = grown(&mut ws.pa64, pa64_cap);
        let _ = grown(&mut ws.pb64, pb64_cap);
        let _ = grown(&mut ws.pa32, pa32_cap);
        let _ = grown(&mut ws.pb32, pb32_cap);
        let _ = grown(&mut ws.stage64, ts * ts);
        let _ = grown(&mut ws.stage32, ts * ts);
    });
}

/// Run `f` with a reusable f64 staging buffer of `len` elements (zero
/// warm allocations; contents on entry are unspecified).  Used by the MP
/// generation tasks to evaluate the covariance kernel in f64 before
/// demoting into an f32-stored tile.
pub fn with_stage_f64<R>(len: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    with_ws(|ws| f(grown(&mut ws.stage64, len)))
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack an `mc x kc` block of op(A) into MR64-row strips, zero padded.
/// `op(A)[i, p]` with `i` in `[i0, i0+mc)`, `p` in `[p0, p0+kc)`.
/// `out` must hold `mc.div_ceil(MR64) * kc * MR64` elements.
#[allow(clippy::too_many_arguments)]
pub(super) fn pack_a64(
    ta: Trans,
    a: &[f64],
    lda: usize,
    i0: usize,
    p0: usize,
    mc: usize,
    kc: usize,
    out: &mut [f64],
) {
    let strips = mc.div_ceil(MR64);
    for s in 0..strips {
        let ib = s * MR64;
        let mr = MR64.min(mc - ib);
        let dst_base = s * kc * MR64;
        for p in 0..kc {
            let dst = &mut out[dst_base + p * MR64..dst_base + p * MR64 + MR64];
            match ta {
                Trans::N => {
                    let col = p0 + p;
                    for i in 0..mr {
                        dst[i] = a[(i0 + ib + i) + col * lda];
                    }
                }
                Trans::T => {
                    for i in 0..mr {
                        dst[i] = a[(p0 + p) + (i0 + ib + i) * lda];
                    }
                }
            }
            for i in mr..MR64 {
                dst[i] = 0.0;
            }
        }
    }
}

/// Pack a `kc x nc` block of op(B) into NR64-column strips, zero padded.
/// `out` must hold `nc.div_ceil(NR64) * kc * NR64` elements.
#[allow(clippy::too_many_arguments)]
pub(super) fn pack_b64(
    tb: Trans,
    b: &[f64],
    ldb: usize,
    p0: usize,
    j0: usize,
    kc: usize,
    nc: usize,
    out: &mut [f64],
) {
    let strips = nc.div_ceil(NR64);
    for s in 0..strips {
        let jb = s * NR64;
        let nr = NR64.min(nc - jb);
        let dst_base = s * kc * NR64;
        for p in 0..kc {
            let dst = &mut out[dst_base + p * NR64..dst_base + p * NR64 + NR64];
            match tb {
                Trans::N => {
                    for j in 0..nr {
                        dst[j] = b[(p0 + p) + (j0 + jb + j) * ldb];
                    }
                }
                Trans::T => {
                    for j in 0..nr {
                        dst[j] = b[(j0 + jb + j) + (p0 + p) * ldb];
                    }
                }
            }
            for j in nr..NR64 {
                dst[j] = 0.0;
            }
        }
    }
}

/// f32-path `pack_a64` analogue (MR32 strips); the source may be either
/// precision — f64 sources are demoted during the copy, which is where
/// the MP path's in-band operands get rounded for an off-band product.
#[allow(clippy::too_many_arguments)]
pub(super) fn pack_a32(
    ta: Trans,
    a: MatRef<'_>,
    lda: usize,
    i0: usize,
    p0: usize,
    mc: usize,
    kc: usize,
    out: &mut [f32],
) {
    match a {
        MatRef::F64(s) => pack_a32_from(s, |v| v as f32, ta, lda, i0, p0, mc, kc, out),
        MatRef::F32(s) => pack_a32_from(s, |v| v, ta, lda, i0, p0, mc, kc, out),
    }
}

#[allow(clippy::too_many_arguments)]
fn pack_a32_from<S: Copy>(
    a: &[S],
    conv: impl Fn(S) -> f32,
    ta: Trans,
    lda: usize,
    i0: usize,
    p0: usize,
    mc: usize,
    kc: usize,
    out: &mut [f32],
) {
    let strips = mc.div_ceil(MR32);
    for s in 0..strips {
        let ib = s * MR32;
        let mr = MR32.min(mc - ib);
        let dst_base = s * kc * MR32;
        for p in 0..kc {
            let dst = &mut out[dst_base + p * MR32..dst_base + p * MR32 + MR32];
            match ta {
                Trans::N => {
                    let col = p0 + p;
                    for i in 0..mr {
                        dst[i] = conv(a[(i0 + ib + i) + col * lda]);
                    }
                }
                Trans::T => {
                    for i in 0..mr {
                        dst[i] = conv(a[(p0 + p) + (i0 + ib + i) * lda]);
                    }
                }
            }
            for i in mr..MR32 {
                dst[i] = 0.0;
            }
        }
    }
}

/// f32-path `pack_b64` analogue (NR32 strips), mixed-precision source.
#[allow(clippy::too_many_arguments)]
pub(super) fn pack_b32(
    tb: Trans,
    b: MatRef<'_>,
    ldb: usize,
    p0: usize,
    j0: usize,
    kc: usize,
    nc: usize,
    out: &mut [f32],
) {
    match b {
        MatRef::F64(s) => pack_b32_from(s, |v| v as f32, tb, ldb, p0, j0, kc, nc, out),
        MatRef::F32(s) => pack_b32_from(s, |v| v, tb, ldb, p0, j0, kc, nc, out),
    }
}

#[allow(clippy::too_many_arguments)]
fn pack_b32_from<S: Copy>(
    b: &[S],
    conv: impl Fn(S) -> f32,
    tb: Trans,
    ldb: usize,
    p0: usize,
    j0: usize,
    kc: usize,
    nc: usize,
    out: &mut [f32],
) {
    let strips = nc.div_ceil(NR32);
    for s in 0..strips {
        let jb = s * NR32;
        let nr = NR32.min(nc - jb);
        let dst_base = s * kc * NR32;
        for p in 0..kc {
            let dst = &mut out[dst_base + p * NR32..dst_base + p * NR32 + NR32];
            match tb {
                Trans::N => {
                    for j in 0..nr {
                        dst[j] = conv(b[(p0 + p) + (j0 + jb + j) * ldb]);
                    }
                }
                Trans::T => {
                    for j in 0..nr {
                        dst[j] = conv(b[(j0 + jb + j) + (p0 + p) * ldb]);
                    }
                }
            }
            for j in nr..NR32 {
                dst[j] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grown_counts_only_reallocations() {
        // Thread-local counter: concurrent test threads cannot perturb it.
        let before = pack_buffer_allocs_this_thread();
        let global_before = pack_buffer_allocs();
        let mut v: Vec<f64> = Vec::new();
        let _ = grown(&mut v, 100);
        assert_eq!(pack_buffer_allocs_this_thread(), before + 1, "cold growth counted");
        let _ = grown(&mut v, 64);
        let _ = grown(&mut v, 100);
        assert_eq!(pack_buffer_allocs_this_thread(), before + 1, "warm reuse uncounted");
        let _ = grown(&mut v, 1000);
        assert!(pack_buffer_allocs_this_thread() >= before + 2, "re-growth counted");
        assert!(pack_buffer_allocs() >= global_before + 2, "global mirror advanced");
    }

    #[test]
    fn reserve_makes_tile_packs_warm() {
        // After reserving for ts, packing any block that fits a ts-tile
        // op must not grow the workspace.
        reserve_pack_workspaces(96);
        let before = pack_buffer_allocs_this_thread();
        with_ws(|ws| {
            let _ = grown(&mut ws.pa64, MC.min(96).div_ceil(MR64) * MR64 * KC.min(96));
            let _ = grown(&mut ws.pb64, 96usize.div_ceil(NR64) * NR64 * KC.min(96));
            let _ = grown(&mut ws.stage64, 96 * 96);
            let _ = grown(&mut ws.stage32, 96 * 96);
        });
        assert_eq!(pack_buffer_allocs_this_thread(), before);
    }

    #[test]
    fn pack32_demotes_f64_sources() {
        // 3x2 col-major matrix, N-trans pack of the whole block.
        let a = [1.0f64 + 1e-12, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![7.0f32; MR32 * 2];
        pack_a32(Trans::N, MatRef::F64(&a), 3, 0, 0, 3, 2, &mut out);
        assert_eq!(out[0], 1.0f32, "f64 value rounded through f32");
        assert_eq!(out[1], 2.0);
        assert_eq!(out[2], 3.0);
        assert_eq!(out[3], 0.0, "zero padded to MR32");
        assert_eq!(out[MR32], 4.0, "second k-slice");
    }

    #[test]
    fn mat_ref_get_f32_both_precisions() {
        let a64 = [std::f64::consts::PI];
        let a32 = [std::f32::consts::PI];
        assert_eq!(MatRef::F64(&a64).get_f32(0), std::f64::consts::PI as f32);
        assert_eq!(MatRef::F32(&a32).get_f32(0), std::f32::consts::PI);
        assert!(!MatRef::F64(&a64).is_f32());
        assert!(MatRef::F32(&a32).is_f32());
    }
}
