//! From-scratch BLAS-3 kernels (the vendored crate set has no BLAS, and the
//! paper's whole point is that these kernels are the building blocks the
//! task runtime schedules).
//!
//! Everything is column-major with an explicit leading dimension so the same
//! routines serve both full matrices and `ts x ts` tiles.  The module is
//! organized as a runtime-dispatched kernel core:
//!
//! * [`simd`](self::simd_level) — CPU-feature detection picks an AVX2+FMA,
//!   NEON or scalar micro-kernel once per process
//!   (`EXAGEOSTAT_SIMD=auto|avx2|neon|scalar` overrides, like
//!   `EXAGEOSTAT_BACKEND`); the scalar kernel doubles as the conformance
//!   oracle.
//! * `pack` — GotoBLAS-style operand packing into reusable thread-local
//!   workspaces: persistent runtime workers perform **zero** pack-buffer
//!   heap allocations warm (counted by [`pack_buffer_allocs`], the pack
//!   sibling of `tile_matrix_allocs`).
//! * `gemm` — the MC/KC/NC cache-blocked macro-kernel ([`dgemm_raw`]) and
//!   the mixed-precision [`gemm_mp`] (f32 micro-kernel compute, f64
//!   accumulate at tile boundaries) behind the MP variant.
//! * `tri` — blocked SYRK/TRSM delegating their bulk FLOPs to the packed
//!   gemm (naive column-oriented versions retained as oracles), POTRF
//!   riding the same routines, and the vector-level kernels.
//!
//! See EXPERIMENTS.md §Kernel roofline for measured throughput and the
//! dispatch-vs-scalar ratios (`rust/benches/kernel_roofline.rs`).

mod gemm;
mod pack;
mod simd;
mod tri;

pub use gemm::{dgemm_naive, dgemm_raw, dgemm_raw_at, gemm_mp, gemm_mp_at};
pub use pack::{
    pack_buffer_allocs, pack_buffer_allocs_this_thread, reserve_pack_workspaces, with_stage_f64,
    MatMut, MatRef,
};
pub use simd::{detected_simd, set_simd_override, simd_level, SimdLevel};
pub use tri::{
    dgemv_f32a, dgemv_raw, dpotrf_raw, dpotrf_unblocked, dsyrk_ln_naive, dsyrk_ln_raw, dtrmv_ln,
    dtrsm_llnn_naive, dtrsm_llnn_raw, dtrsm_lltn_naive, dtrsm_lltn_raw, dtrsm_rltn_naive,
    dtrsm_rltn_raw, dtrsv_ln, dtrsv_lt, syrk_ln_mp, trsm_rltn_mp, NotSpd,
};

use super::matrix::Matrix;

/// Transpose flag for gemm-like routines.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Trans {
    /// Use the operand as stored.
    N,
    /// Use the operand transposed.
    T,
}

/// Matrix-level gemm wrapper: `C <- alpha*op(A)*op(B) + beta*C`.
pub fn dgemm(ta: bool, tb: bool, alpha: f64, a: &Matrix, b: &Matrix, beta: f64, c: &mut Matrix) {
    let ta = if ta { Trans::T } else { Trans::N };
    let tb = if tb { Trans::T } else { Trans::N };
    let (m, k) = match ta {
        Trans::N => (a.rows(), a.cols()),
        Trans::T => (a.cols(), a.rows()),
    };
    let n = match tb {
        Trans::N => b.cols(),
        Trans::T => b.rows(),
    };
    let kb = match tb {
        Trans::N => b.rows(),
        Trans::T => b.cols(),
    };
    assert_eq!(k, kb, "gemm inner dims");
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    let lda = a.rows();
    let ldb = b.rows();
    let ldc = c.rows();
    dgemm_raw(
        ta,
        tb,
        m,
        n,
        k,
        alpha,
        a.as_slice(),
        lda,
        b.as_slice(),
        ldb,
        beta,
        c.as_mut_slice(),
        ldc,
    );
}

/// Matrix-level Cholesky: factor `A = L L^T` in place (lower), returning
/// the log-determinant of `A` (`2 * sum log L_ii`).
pub fn dpotrf(a: &mut Matrix) -> Result<f64, NotSpd> {
    assert!(a.is_square());
    let n = a.rows();
    dpotrf_raw(n, a.as_mut_slice(), n)?;
    let mut logdet = 0.0;
    for i in 0..n {
        logdet += a[(i, i)].ln();
    }
    Ok(2.0 * logdet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, m: usize, n: usize) -> Vec<f64> {
        (0..m * n).map(|_| rng.normal()).collect()
    }

    #[allow(clippy::too_many_arguments)]
    fn gemm_oracle(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        beta: f64,
        c: &mut [f64],
        ldc: usize,
    ) {
        for j in 0..n {
            for i in 0..m {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = match ta {
                        Trans::N => a[i + p * lda],
                        Trans::T => a[p + i * lda],
                    };
                    let bv = match tb {
                        Trans::N => b[p + j * ldb],
                        Trans::T => b[j + p * ldb],
                    };
                    acc += av * bv;
                }
                c[i + j * ldc] = alpha * acc + beta * c[i + j * ldc];
            }
        }
    }

    #[test]
    fn gemm_all_trans_combos_match_oracle() {
        let mut rng = Pcg64::seed_from_u64(11);
        for &(m, n, k) in &[(1, 1, 1), (3, 5, 7), (17, 9, 33), (64, 64, 64), (100, 37, 250)] {
            for &ta in &[Trans::N, Trans::T] {
                for &tb in &[Trans::N, Trans::T] {
                    let (ar, ac) = match ta {
                        Trans::N => (m, k),
                        Trans::T => (k, m),
                    };
                    let (br, bc) = match tb {
                        Trans::N => (k, n),
                        Trans::T => (n, k),
                    };
                    let a = rand_mat(&mut rng, ar, ac);
                    let b = rand_mat(&mut rng, br, bc);
                    let c0 = rand_mat(&mut rng, m, n);
                    let mut c1 = c0.clone();
                    let mut c2 = c0.clone();
                    dgemm_raw(ta, tb, m, n, k, 1.3, &a, ar, &b, br, 0.7, &mut c1, m);
                    gemm_oracle(ta, tb, m, n, k, 1.3, &a, ar, &b, br, 0.7, &mut c2, m);
                    let err = c1
                        .iter()
                        .zip(&c2)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0, f64::max);
                    assert!(err < 1e-9, "({m},{n},{k}) {ta:?}{tb:?} err={err}");
                }
            }
        }
    }

    #[test]
    fn gemm_beta_zero_ignores_nan_in_c() {
        // beta=0 must overwrite C even if it held NaN (LAPACK convention).
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![f64::NAN; 4];
        dgemm_raw(Trans::N, Trans::N, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 2);
        assert_eq!(c, a);
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = Pcg64::seed_from_u64(12);
        for &(n, k) in &[(5, 3), (32, 32), (65, 17), (128, 40)] {
            let a = rand_mat(&mut rng, n, k);
            let mut c1 = vec![0.5; n * n];
            let mut c2 = c1.clone();
            dsyrk_ln_raw(n, k, -1.0, &a, n, 1.0, &mut c1, n);
            gemm_oracle(Trans::N, Trans::T, n, n, k, -1.0, &a, n, &a, n, 1.0, &mut c2, n);
            // compare lower triangle only
            for j in 0..n {
                for i in j..n {
                    assert!(
                        (c1[i + j * n] - c2[i + j * n]).abs() < 1e-10,
                        "({n},{k}) at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn syrk_blocked_matches_naive_oracle() {
        let mut rng = Pcg64::seed_from_u64(18);
        for &(n, k) in &[(7usize, 5usize), (33, 20), (100, 64), (130, 17)] {
            let a = rand_mat(&mut rng, n, k);
            let c0 = rand_mat(&mut rng, n, n);
            for beta in [0.0, 1.0, 0.3] {
                let mut c1 = c0.clone();
                let mut c2 = c0.clone();
                dsyrk_ln_raw(n, k, -1.0, &a, n, beta, &mut c1, n);
                dsyrk_ln_naive(n, k, -1.0, &a, n, beta, &mut c2, n);
                for j in 0..n {
                    for i in j..n {
                        let d = (c1[i + j * n] - c2[i + j * n]).abs();
                        assert!(d < 1e-10, "({n},{k}) beta={beta} at ({i},{j}): {d}");
                    }
                }
            }
        }
    }

    /// Build a well-conditioned SPD matrix A = B B^T + n*I.
    fn rand_spd(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let b = rand_mat(rng, n, n);
        let mut a = vec![0.0; n * n];
        dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &b, n, &b, n, 0.0, &mut a, n);
        for i in 0..n {
            a[i + i * n] += n as f64;
        }
        a
    }

    #[test]
    fn potrf_reconstructs() {
        let mut rng = Pcg64::seed_from_u64(13);
        for &n in &[1usize, 2, 5, 33, 64, 100, 130] {
            let a = rand_spd(&mut rng, n);
            let mut l = a.clone();
            dpotrf_raw(n, &mut l, n).unwrap();
            // zero strict upper
            for j in 0..n {
                for i in 0..j {
                    l[i + j * n] = 0.0;
                }
            }
            let mut rec = vec![0.0; n * n];
            dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &l, n, &l, n, 0.0, &mut rec, n);
            let scale = a.iter().map(|v| v.abs()).fold(0.0, f64::max);
            let err = a
                .iter()
                .zip(&rec)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(err / scale < 1e-12, "n={n} rel err {}", err / scale);
        }
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        let e = dpotrf_raw(2, &mut a, 2);
        assert!(e.is_err());
        assert_eq!(e.unwrap_err().pivot, 1);
    }

    #[test]
    fn trsm_rltn_inverts_panel_update() {
        let mut rng = Pcg64::seed_from_u64(14);
        let n = 24;
        let m = 40;
        let mut l = rand_spd(&mut rng, n);
        dpotrf_raw(n, &mut l, n).unwrap();
        let x = rand_mat(&mut rng, m, n);
        // B = X * L^T  =>  trsm(B) == X
        let mut b = vec![0.0; m * n];
        dgemm_raw(Trans::N, Trans::T, m, n, n, 1.0, &x, m, &l, n, 0.0, &mut b, m);
        // but L has garbage upper; zero it for the multiply oracle
        // (dgemm used it) — redo with cleaned L.
        for j in 0..n {
            for i in 0..j {
                l[i + j * n] = 0.0;
            }
        }
        let mut b2 = vec![0.0; m * n];
        dgemm_raw(Trans::N, Trans::T, m, n, n, 1.0, &x, m, &l, n, 0.0, &mut b2, m);
        dtrsm_rltn_raw(m, n, &l, n, &mut b2, m);
        let err = b2
            .iter()
            .zip(&x)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-10, "err={err}");
    }

    #[test]
    fn trsm_blocked_matches_naive_oracles() {
        // Sizes straddling the 64-wide block boundary, ldb > m.
        let mut rng = Pcg64::seed_from_u64(19);
        for &(m, n) in &[(40usize, 100usize), (130, 70), (100, 130)] {
            // rltn: L is n x n.
            let mut l = rand_spd(&mut rng, n);
            dpotrf_raw(n, &mut l, n).unwrap();
            let b0 = rand_mat(&mut rng, m, n);
            let mut b1 = b0.clone();
            let mut b2 = b0.clone();
            dtrsm_rltn_raw(m, n, &l, n, &mut b1, m);
            dtrsm_rltn_naive(m, n, &l, n, &mut b2, m);
            let err = b1
                .iter()
                .zip(&b2)
                .map(|(p, q)| (p - q).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-10, "rltn ({m},{n}): {err}");

            // llnn / lltn: L is m x m.
            let mut lm = rand_spd(&mut rng, m);
            dpotrf_raw(m, &mut lm, m).unwrap();
            let c0 = rand_mat(&mut rng, m, n);
            let mut c1 = c0.clone();
            let mut c2 = c0.clone();
            dtrsm_llnn_raw(m, n, &lm, m, &mut c1, m);
            dtrsm_llnn_naive(m, n, &lm, m, &mut c2, m);
            let err = c1
                .iter()
                .zip(&c2)
                .map(|(p, q)| (p - q).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-10, "llnn ({m},{n}): {err}");

            let mut d1 = c0.clone();
            let mut d2 = c0.clone();
            dtrsm_lltn_raw(m, n, &lm, m, &mut d1, m);
            dtrsm_lltn_naive(m, n, &lm, m, &mut d2, m);
            let err = d1
                .iter()
                .zip(&d2)
                .map(|(p, q)| (p - q).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-10, "lltn ({m},{n}): {err}");
        }
    }

    #[test]
    fn trsm_llnn_and_lltn_solve() {
        let mut rng = Pcg64::seed_from_u64(15);
        let n = 30;
        let mut l = rand_spd(&mut rng, n);
        dpotrf_raw(n, &mut l, n).unwrap();
        for j in 0..n {
            for i in 0..j {
                l[i + j * n] = 0.0;
            }
        }
        let x = rand_mat(&mut rng, n, 3);
        // b = L x; solve gives x back.
        let mut b = vec![0.0; n * 3];
        dgemm_raw(Trans::N, Trans::N, n, 3, n, 1.0, &l, n, &x, n, 0.0, &mut b, n);
        dtrsm_llnn_raw(n, 3, &l, n, &mut b, n);
        let err = b.iter().zip(&x).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10);
        // b = L^T x; lltn solve gives x back.
        let mut b = vec![0.0; n * 3];
        dgemm_raw(Trans::T, Trans::N, n, 3, n, 1.0, &l, n, &x, n, 0.0, &mut b, n);
        dtrsm_lltn_raw(n, 3, &l, n, &mut b, n);
        let err = b.iter().zip(&x).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10);
    }

    #[test]
    fn trsm_rltn_mp_tracks_f64_solve_at_f32_scale() {
        let mut rng = Pcg64::seed_from_u64(20);
        // n = 16 exercises the unblocked diagonal solve, n = 100 the
        // blocked path (bulk update through the mixed packed gemm).
        for (m, n) in [(24usize, 16usize), (40, 100)] {
            let mut l = rand_spd(&mut rng, n);
            dpotrf_raw(n, &mut l, n).unwrap();
            let b0 = rand_mat(&mut rng, m, n);
            let mut bf = b0.clone();
            dtrsm_rltn_naive(m, n, &l, n, &mut bf, m);
            let mut b32: Vec<f32> = b0.iter().map(|&v| v as f32).collect();
            trsm_rltn_mp(m, n, &l, n, &mut b32, m);
            let err = b32
                .iter()
                .zip(&bf)
                .map(|(p, q)| (*p as f64 - q).abs())
                .fold(0.0, f64::max);
            let scale = bf.iter().map(|v| v.abs()).fold(1.0, f64::max);
            assert!(err / scale < 1e-4, "({m},{n}) rel err {}", err / scale);
        }
    }

    #[test]
    fn gemv_matches_matvec() {
        let mut rng = Pcg64::seed_from_u64(16);
        let (m, n) = (13, 9);
        let a = rand_mat(&mut rng, m, n);
        let x = rand_mat(&mut rng, n, 1);
        let mut y = vec![0.0; m];
        dgemv_raw(Trans::N, m, n, 1.0, &a, m, &x, 0.0, &mut y);
        for i in 0..m {
            let mut acc = 0.0;
            for j in 0..n {
                acc += a[i + j * m] * x[j];
            }
            assert!((y[i] - acc).abs() < 1e-12);
        }
        // transposed
        let xt = rand_mat(&mut rng, m, 1);
        let mut yt = vec![0.0; n];
        dgemv_raw(Trans::T, m, n, 2.0, &a, m, &xt, 0.0, &mut yt);
        for j in 0..n {
            let mut acc = 0.0;
            for i in 0..m {
                acc += a[i + j * m] * xt[i];
            }
            assert!((yt[j] - 2.0 * acc).abs() < 1e-12);
        }
        // f32-stored A: same product at f32 scale.
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let mut y32 = vec![0.0; m];
        dgemv_f32a(m, n, 1.0, &a32, m, &x, &mut y32);
        for i in 0..m {
            assert!((y32[i] - y[i]).abs() < 1e-5, "{} vs {}", y32[i], y[i]);
        }
    }

    #[test]
    fn trmv_inverts_trsv() {
        let mut rng = Pcg64::seed_from_u64(17);
        let n = 20;
        let mut l = rand_spd(&mut rng, n);
        dpotrf_raw(n, &mut l, n).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y = x.clone();
        dtrmv_ln(n, &l, n, &mut y); // y = L x
        dtrsv_ln(n, &l, n, &mut y); // back to x
        let err = y.iter().zip(&x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-11, "{err}");
    }

    #[test]
    fn potrf_logdet_matches_known() {
        // diag(4, 9) => logdet = ln 36
        let mut a = Matrix::zeros(2, 2);
        a[(0, 0)] = 4.0;
        a[(1, 1)] = 9.0;
        let ld = dpotrf(&mut a).unwrap();
        assert!((ld - 36f64.ln()).abs() < 1e-14);
    }

    #[test]
    fn mixed_syrk_tracks_f64_at_f32_scale() {
        let mut rng = Pcg64::seed_from_u64(22);
        let (n, k) = (40usize, 28usize);
        let a: Vec<f64> = (0..n * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let c0: Vec<f64> = (0..n * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut cf = c0.clone();
        dsyrk_ln_raw(n, k, -1.0, &a, n, 1.0, &mut cf, n);
        let mut cm = c0.clone();
        syrk_ln_mp(n, k, -1.0, MatRef::F32(&a32), n, 1.0, MatMut::F64(&mut cm), n);
        for j in 0..n {
            for i in j..n {
                let d = (cf[i + j * n] - cm[i + j * n]).abs();
                assert!(d < 1e-4, "({i},{j}): {d}");
            }
        }
    }
}
