//! Triangular and symmetric kernels: SYRK, the TRSM family, POTRF,
//! GEMV/TRMV/TRSV — plus the mixed-precision variants the MP tile tasks
//! dispatch to.
//!
//! The blocked TRSM/SYRK routines delegate their bulk FLOPs to the packed
//! [`super::gemm::dgemm_raw`] macro-kernel (and therefore to the dispatched
//! SIMD micro-kernels); only O(block²·NB) work remains in the
//! column-oriented diagonal solves.  The previous column-at-a-time
//! implementations are retained as `*_naive` — they are the conformance
//! oracles in `rust/tests/simd_kernels.rs` and the small-problem paths.

use super::gemm::{dgemm_raw, gemm_mp};
use super::pack::{self, MatMut, MatRef};
use super::Trans;

/// Column block width of the blocked triangular solves; below this the
/// naive routine runs directly.
const TRSM_NB: usize = 64;

// ---------------------------------------------------------------------------
// syrk
// ---------------------------------------------------------------------------

/// Symmetric rank-k update, lower, no-trans:
/// `C <- alpha * A * A^T + beta * C` touching only the lower triangle.
/// `A` is `n x k`, `C` is `n x n`.  Bulk FLOPs (the below-diagonal
/// panels) run through the packed gemm; only the `NB x NB` diagonal
/// blocks use the naive symmetric update.
#[allow(clippy::too_many_arguments)]
pub fn dsyrk_ln_raw(
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    const NB: usize = 32;
    if beta != 1.0 {
        for j in 0..n {
            for i in j..n {
                let v = &mut c[i + j * ldc];
                *v = if beta == 0.0 { 0.0 } else { *v * beta };
            }
        }
    }
    let mut j0 = 0;
    while j0 < n {
        let nb = NB.min(n - j0);
        // Diagonal block: naive symmetric update (small).
        for j in j0..j0 + nb {
            for i in j..j0 + nb {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[i + p * lda] * a[j + p * lda];
                }
                c[i + j * ldc] += alpha * acc;
            }
        }
        // Below-diagonal panel: gemm (i in [j0+nb, n), columns j0..j0+nb).
        let m = n - (j0 + nb);
        if m > 0 {
            // C[j0+nb.., j0..j0+nb] += alpha * A[j0+nb..,:] * A[j0..j0+nb,:]^T
            let coff = (j0 + nb) + j0 * ldc;
            dgemm_raw(
                Trans::N,
                Trans::T,
                m,
                nb,
                k,
                alpha,
                &a[j0 + nb..],
                lda,
                &a[j0..],
                lda,
                1.0,
                &mut c[coff..],
                ldc,
            );
        }
        j0 += nb;
    }
}

/// Reference triple-loop SYRK (lower): the conformance oracle for
/// [`dsyrk_ln_raw`], with identical beta semantics.
#[allow(clippy::too_many_arguments)]
pub fn dsyrk_ln_naive(
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    for j in 0..n {
        for i in j..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i + p * lda] * a[j + p * lda];
            }
            let v = &mut c[i + j * ldc];
            *v = if beta == 0.0 { 0.0 } else { *v * beta };
            *v += alpha * acc;
        }
    }
}

/// Mixed-precision SYRK: `C <- alpha * A * A^T + beta * C` (lower) where
/// either side may be f32.  Products and `k`-accumulation run in f32
/// (f64 sources demoted on read), the merge into C happens in C's own
/// precision — used by the MP tiled Cholesky's diagonal updates, whose
/// panel operand is an f32 off-band tile while C is the f64 diagonal.
#[allow(clippy::too_many_arguments)]
pub fn syrk_ln_mp(
    n: usize,
    k: usize,
    alpha: f64,
    a: MatRef<'_>,
    lda: usize,
    beta: f64,
    mut c: MatMut<'_>,
    ldc: usize,
) {
    if let (MatRef::F64(af), MatMut::F64(cf)) = (a, c.rb()) {
        return dsyrk_ln_raw(n, k, alpha, af, lda, beta, cf, ldc);
    }
    const NB: usize = 32;
    // Beta-scale the lower triangle in C's precision.
    if beta != 1.0 {
        match &mut c {
            MatMut::F64(s) => {
                for j in 0..n {
                    for i in j..n {
                        let v = &mut s[i + j * ldc];
                        *v = if beta == 0.0 { 0.0 } else { *v * beta };
                    }
                }
            }
            MatMut::F32(s) => {
                let bt = beta as f32;
                for j in 0..n {
                    for i in j..n {
                        let v = &mut s[i + j * ldc];
                        *v = if beta == 0.0 { 0.0 } else { *v * bt };
                    }
                }
            }
        }
    }
    let mut j0 = 0;
    while j0 < n {
        let nb = NB.min(n - j0);
        // Diagonal block: naive mixed update (f32 products, merge in C's
        // precision).
        for j in j0..j0 + nb {
            for i in j..j0 + nb {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a.get_f32(i + p * lda) * a.get_f32(j + p * lda);
                }
                match &mut c {
                    MatMut::F64(s) => s[i + j * ldc] += alpha * acc as f64,
                    MatMut::F32(s) => s[i + j * ldc] += alpha as f32 * acc,
                }
            }
        }
        // Below-diagonal panel through the mixed packed gemm.
        let m = n - (j0 + nb);
        if m > 0 {
            let coff = (j0 + nb) + j0 * ldc;
            gemm_mp(
                Trans::N,
                Trans::T,
                m,
                nb,
                k,
                alpha,
                a.slice_from(j0 + nb),
                lda,
                a.slice_from(j0),
                lda,
                1.0,
                c.rb().slice_from(coff),
                ldc,
            );
        }
        j0 += nb;
    }
}

// ---------------------------------------------------------------------------
// trsm / trsv
// ---------------------------------------------------------------------------

/// `B <- B * L^{-T}` (Right, Lower, Transpose, Non-unit), column at a
/// time: the small-problem path and the conformance oracle of the
/// blocked [`dtrsm_rltn_raw`].
pub fn dtrsm_rltn_naive(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    // Column j of X: X[:,j] = (B[:,j] - sum_{k<j} X[:,k] * L[j,k]) / L[j,j]
    for j in 0..n {
        for kk in 0..j {
            let ljk = l[j + kk * ldl];
            if ljk != 0.0 {
                let (head, tail) = b.split_at_mut(j * ldb);
                let xk = &head[kk * ldb..kk * ldb + m];
                let xj = &mut tail[..m];
                for i in 0..m {
                    xj[i] -= xk[i] * ljk;
                }
            }
        }
        let inv = 1.0 / l[j + j * ldl];
        for v in &mut b[j * ldb..j * ldb + m] {
            *v *= inv;
        }
    }
}

/// `B <- B * L^{-T}` (Right, Lower, Transpose, Non-unit), blocked.
/// This is the TRSM used by the tiled Cholesky panel update.
/// `B` is `m x n`, `L` is `n x n` lower triangular.
///
/// Column blocks of X are solved left to right; the bulk update
/// `B_J -= X[:, <J] * L[J, <J]^T` is one packed gemm per block, so the
/// O(m n²) FLOPs ride the SIMD micro-kernel and only the O(m n NB)
/// diagonal solves stay column-oriented.
pub fn dtrsm_rltn_raw(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    if n <= TRSM_NB {
        return dtrsm_rltn_naive(m, n, l, ldl, b, ldb);
    }
    let mut j0 = 0;
    while j0 < n {
        let nb = TRSM_NB.min(n - j0);
        if j0 > 0 {
            // B[:, j0..j0+nb] -= X[:, 0..j0] * (L[j0..j0+nb, 0..j0])^T
            let (head, tail) = b.split_at_mut(j0 * ldb);
            dgemm_raw(
                Trans::N,
                Trans::T,
                m,
                nb,
                j0,
                -1.0,
                head,
                ldb,
                &l[j0..],
                ldl,
                1.0,
                tail,
                ldb,
            );
        }
        dtrsm_rltn_naive(m, nb, &l[j0 + j0 * ldl..], ldl, &mut b[j0 * ldb..], ldb);
        j0 += nb;
    }
}

/// Mixed-precision RLTN TRSM for the MP tiled Cholesky panel: the
/// off-band panel tile `B` is stored f32 while the factored diagonal `L`
/// is f64.  Blocked like [`dtrsm_rltn_raw`]: the bulk update runs through
/// the mixed packed gemm (f32 micro-kernel, `L` demoted while packing) —
/// MP's half-width arithmetic on the off-band bulk — and only the
/// diagonal-block solves use the column-oriented f32 loop below.
pub fn trsm_rltn_mp(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f32], ldb: usize) {
    if n <= TRSM_NB {
        return trsm_rltn_mp_unblocked(m, n, l, ldl, b, ldb);
    }
    let mut j0 = 0;
    while j0 < n {
        let nb = TRSM_NB.min(n - j0);
        if j0 > 0 {
            // B[:, j0..j0+nb] -= X[:, 0..j0] * (L[j0..j0+nb, 0..j0])^T
            let (head, tail) = b.split_at_mut(j0 * ldb);
            gemm_mp(
                Trans::N,
                Trans::T,
                m,
                nb,
                j0,
                -1.0,
                MatRef::F32(head),
                ldb,
                MatRef::F64(&l[j0..]),
                ldl,
                1.0,
                MatMut::F32(tail),
                ldb,
            );
        }
        trsm_rltn_mp_unblocked(m, nb, &l[j0 + j0 * ldl..], ldl, &mut b[j0 * ldb..], ldb);
        j0 += nb;
    }
}

/// Diagonal-block solve of [`trsm_rltn_mp`]: `L`'s lower triangle is
/// demoted once into the thread-local stage buffer, then the
/// column-oriented solve runs in f32.
fn trsm_rltn_mp_unblocked(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f32], ldb: usize) {
    pack::with_ws(|ws| {
        let l32 = pack::grown(&mut ws.stage32, n * n);
        for j in 0..n {
            for i in j..n {
                l32[i + j * n] = l[i + j * ldl] as f32;
            }
        }
        // Column solve in f32 (upper triangle of l32 is unspecified and
        // never read).
        for j in 0..n {
            for kk in 0..j {
                let ljk = l32[j + kk * n];
                if ljk != 0.0 {
                    let (head, tail) = b.split_at_mut(j * ldb);
                    let xk = &head[kk * ldb..kk * ldb + m];
                    let xj = &mut tail[..m];
                    for i in 0..m {
                        xj[i] -= xk[i] * ljk;
                    }
                }
            }
            let inv = 1.0 / l32[j + j * n];
            for v in &mut b[j * ldb..j * ldb + m] {
                *v *= inv;
            }
        }
    })
}

/// `B <- L^{-1} * B` (Left, Lower, No-trans, Non-unit), column at a
/// time: small-problem path and conformance oracle of
/// [`dtrsm_llnn_raw`].  `L` is `m x m`, `B` is `m x n`.
pub fn dtrsm_llnn_naive(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    for j in 0..n {
        let col = &mut b[j * ldb..j * ldb + m];
        for kk in 0..m {
            let xk = col[kk] / l[kk + kk * ldl];
            col[kk] = xk;
            if xk != 0.0 {
                for i in kk + 1..m {
                    col[i] -= xk * l[i + kk * ldl];
                }
            }
        }
    }
}

/// `B <- L^{-1} * B` (Left, Lower, No-trans, Non-unit), blocked forward
/// substitution: after each `NB`-row diagonal solve, the trailing rows
/// are updated with one packed gemm.  Used by the tiled forward solve.
pub fn dtrsm_llnn_raw(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    if m <= TRSM_NB {
        return dtrsm_llnn_naive(m, n, l, ldl, b, ldb);
    }
    let mut k0 = 0;
    while k0 < m {
        let nb = TRSM_NB.min(m - k0);
        dtrsm_llnn_naive(nb, n, &l[k0 + k0 * ldl..], ldl, &mut b[k0..], ldb);
        let k1 = k0 + nb;
        if k1 < m {
            // B[k1.., :] -= L[k1.., k0..k1] * B[k0..k1, :]
            // SAFETY: gemm reads rows [k0, k1) and writes rows [k1, m)
            // of `b` — disjoint row ranges of the same buffer.
            let (bk, brest) = unsafe { split_rows(b, k0, k1) };
            dgemm_raw(
                Trans::N,
                Trans::N,
                m - k1,
                n,
                nb,
                -1.0,
                &l[k1 + k0 * ldl..],
                ldl,
                bk,
                ldb,
                1.0,
                brest,
                ldb,
            );
        }
        k0 = k1;
    }
}

/// `B <- L^{-T} * B` (Left, Lower, Transpose, Non-unit), column at a
/// time: small-problem path and conformance oracle of
/// [`dtrsm_lltn_raw`].
pub fn dtrsm_lltn_naive(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    for j in 0..n {
        let col = &mut b[j * ldb..j * ldb + m];
        for kk in (0..m).rev() {
            let mut acc = col[kk];
            for i in kk + 1..m {
                acc -= l[i + kk * ldl] * col[i];
            }
            col[kk] = acc / l[kk + kk * ldl];
        }
    }
}

/// `B <- L^{-T} * B` (Left, Lower, Transpose, Non-unit), blocked backward
/// substitution (bottom block first; the bulk update of each block above
/// is one packed gemm against the already-solved rows below).
pub fn dtrsm_lltn_raw(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    if m <= TRSM_NB {
        return dtrsm_lltn_naive(m, n, l, ldl, b, ldb);
    }
    let nblocks = m.div_ceil(TRSM_NB);
    for blk in (0..nblocks).rev() {
        let k0 = blk * TRSM_NB;
        let nb = TRSM_NB.min(m - k0);
        let k1 = k0 + nb;
        if k1 < m {
            // B[k0..k1, :] -= (L[k1.., k0..k1])^T * B[k1.., :]
            // SAFETY: gemm reads rows [k1, m) and writes rows [k0, k1)
            // of `b` — disjoint row ranges of the same buffer.
            let (blow, bk) = unsafe { split_rows(b, k1, k0) };
            dgemm_raw(
                Trans::T,
                Trans::N,
                nb,
                n,
                m - k1,
                -1.0,
                &l[k1 + k0 * ldl..],
                ldl,
                blow,
                ldb,
                1.0,
                bk,
                ldb,
            );
        }
        dtrsm_lltn_naive(nb, n, &l[k0 + k0 * ldl..], ldl, &mut b[k0..], ldb);
    }
}

/// Aliased row split of a column-major buffer: a shared view starting at
/// row offset `r_off` and a mutable view starting at row offset `w_off`.
///
/// # Safety
/// The caller must only read rows the mutable side never writes (the
/// trsm updates touch disjoint row ranges; columns interleave in memory,
/// which is why `split_at_mut` cannot express this).  Like
/// [`split_panel`] (the same pattern, predating this routine), the two
/// slices overlap in extent even though every element access is
/// disjoint — accepted here for parity with the crate's established
/// aliasing style (see also `TilePtr`); a strict-provenance rewrite
/// would thread raw pointers into the gemm kernels instead.
unsafe fn split_rows(b: &mut [f64], r_off: usize, w_off: usize) -> (&[f64], &mut [f64]) {
    let base = b.as_mut_ptr();
    let len = b.len();
    let r = std::slice::from_raw_parts(base.add(r_off), len - r_off);
    let w = std::slice::from_raw_parts_mut(base.add(w_off), len - w_off);
    (r, w)
}

/// Triangular matrix-vector product `x <- L x` (lower, no-trans, non-unit),
/// used by the exact GRF sampler (`z = L e`).
pub fn dtrmv_ln(n: usize, l: &[f64], ldl: usize, x: &mut [f64]) {
    for i in (0..n).rev() {
        let mut acc = 0.0;
        for k in 0..=i {
            acc += l[i + k * ldl] * x[k];
        }
        x[i] = acc;
    }
}

/// Triangular solve with a single vector: `x <- L^{-1} x`.  Vector
/// solves are memory-bound; the column-oriented naive routine is the
/// right tool (no packing win at n = 1).
pub fn dtrsv_ln(n: usize, l: &[f64], ldl: usize, x: &mut [f64]) {
    dtrsm_llnn_naive(n, 1, l, ldl, x, n);
}

/// Triangular solve with a single vector: `x <- L^{-T} x`.
pub fn dtrsv_lt(n: usize, l: &[f64], ldl: usize, x: &mut [f64]) {
    dtrsm_lltn_naive(n, 1, l, ldl, x, n);
}

// ---------------------------------------------------------------------------
// gemv
// ---------------------------------------------------------------------------

/// `y <- alpha * op(A) x + beta * y` for col-major `A (m x n)`.
#[allow(clippy::too_many_arguments)]
pub fn dgemv_raw(
    ta: Trans,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    x: &[f64],
    beta: f64,
    y: &mut [f64],
) {
    let (ylen, _xlen) = match ta {
        Trans::N => (m, n),
        Trans::T => (n, m),
    };
    if beta == 0.0 {
        for v in &mut y[..ylen] {
            *v = 0.0;
        }
    } else if beta != 1.0 {
        for v in &mut y[..ylen] {
            *v *= beta;
        }
    }
    match ta {
        Trans::N => {
            for j in 0..n {
                let xj = alpha * x[j];
                if xj != 0.0 {
                    let col = &a[j * lda..j * lda + m];
                    for i in 0..m {
                        y[i] += col[i] * xj;
                    }
                }
            }
        }
        Trans::T => {
            for j in 0..n {
                let col = &a[j * lda..j * lda + m];
                let mut acc = 0.0;
                for i in 0..m {
                    acc += col[i] * x[i];
                }
                y[j] += alpha * acc;
            }
        }
    }
}

/// `y <- y + alpha * A x` with an f32-stored `A` (m x n, col-major) and
/// f64 vectors: the MP forward solve's off-band update (promotion to f64
/// per element is free relative to the memory traffic).
pub fn dgemv_f32a(m: usize, n: usize, alpha: f64, a: &[f32], lda: usize, x: &[f64], y: &mut [f64]) {
    for j in 0..n {
        let xj = alpha * x[j];
        if xj != 0.0 {
            let col = &a[j * lda..j * lda + m];
            for i in 0..m {
                y[i] += col[i] as f64 * xj;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// potrf
// ---------------------------------------------------------------------------

/// Error from a failed Cholesky factorization (matrix not SPD at pivot `k`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NotSpd {
    /// Index of the first non-positive pivot.
    pub pivot: usize,
}

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix is not positive definite (non-positive pivot at {})",
            self.pivot
        )
    }
}
impl std::error::Error for NotSpd {}

/// Unblocked lower Cholesky on an `n x n` column-major buffer.
pub fn dpotrf_unblocked(n: usize, a: &mut [f64], lda: usize) -> Result<(), NotSpd> {
    for j in 0..n {
        // a[j,j] -= sum_{k<j} a[j,k]^2
        let mut d = a[j + j * lda];
        for k in 0..j {
            let v = a[j + k * lda];
            d -= v * v;
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(NotSpd { pivot: j });
        }
        let dj = d.sqrt();
        a[j + j * lda] = dj;
        let inv = 1.0 / dj;
        // Column update: a[i,j] = (a[i,j] - sum_k a[i,k] a[j,k]) / dj
        for k in 0..j {
            let ajk = a[j + k * lda];
            if ajk != 0.0 {
                let (c_k, c_j) = {
                    // split borrows: column k is before column j
                    let (head, tail) = a.split_at_mut(j * lda);
                    (&head[k * lda..k * lda + n], &mut tail[..n])
                };
                for i in j + 1..n {
                    c_j[i] -= c_k[i] * ajk;
                }
            }
        }
        for i in j + 1..n {
            a[i + j * lda] *= inv;
        }
    }
    Ok(())
}

/// Blocked lower Cholesky (right-looking) on a column-major buffer.  The
/// panel and trailing updates ride the blocked [`dtrsm_rltn_raw`] /
/// [`dsyrk_ln_raw`] and therefore the packed, SIMD-dispatched gemm.
pub fn dpotrf_raw(n: usize, a: &mut [f64], lda: usize) -> Result<(), NotSpd> {
    const NB: usize = 64;
    if n <= NB {
        return dpotrf_unblocked(n, a, lda);
    }
    let mut k = 0;
    while k < n {
        let nb = NB.min(n - k);
        // Factor diagonal block.
        dpotrf_unblocked_at(a, lda, k, nb).map_err(|e| NotSpd { pivot: k + e.pivot })?;
        let rest = n - (k + nb);
        if rest > 0 {
            // Panel: A[k+nb.., k..k+nb] <- A[k+nb.., k..k+nb] * L_kk^{-T}
            {
                let (lcol, bcol) = split_panel(a, lda, k, nb);
                dtrsm_rltn_raw(rest, nb, lcol, lda, bcol, lda);
            }
            // Trailing update: A[k+nb.., k+nb..] -= P * P^T (lower).
            let poff = (k + nb) + k * lda;
            let coff = (k + nb) + (k + nb) * lda;
            // Safety note: syrk reads the panel and writes the trailing
            // sub-matrix; they do not overlap (different column ranges,
            // and within shared columns syrk only touches cols >= k+nb).
            let (pan, trail) = a.split_at_mut(coff);
            dsyrk_ln_raw(rest, nb, -1.0, &pan[poff..], lda, 1.0, trail, lda);
        }
        k += nb;
    }
    Ok(())
}

/// Unblocked potrf on the `nb x nb` diagonal block starting at `(k, k)`.
fn dpotrf_unblocked_at(a: &mut [f64], lda: usize, k: usize, nb: usize) -> Result<(), NotSpd> {
    // Work on the sub-buffer starting at (k,k) with the same lda.
    let off = k + k * lda;
    dpotrf_unblocked(nb, &mut a[off..], lda)
}

/// Split borrows for the panel TRSM: returns (L_kk block cols, panel cols),
/// both starting at row offsets appropriate for `lda` indexing.
fn split_panel(a: &mut [f64], lda: usize, k: usize, nb: usize) -> (&[f64], &mut [f64]) {
    // L_kk lives at (k, k); the panel at (k+nb, k).  Same columns k..k+nb,
    // different rows, so we cannot split by column.  Use raw pointers with
    // disjoint-row access (the TRSM reads rows [k, k+nb) and writes rows
    // [k+nb, ...)).
    let base = a.as_mut_ptr();
    unsafe {
        let l = std::slice::from_raw_parts(base.add(k + k * lda), a.len() - (k + k * lda));
        let b = std::slice::from_raw_parts_mut(
            base.add((k + nb) + k * lda),
            a.len() - ((k + nb) + k * lda),
        );
        (l, b)
    }
}
