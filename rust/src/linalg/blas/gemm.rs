//! Packed-blocked GEMM: the one macro-kernel every BLAS-3 routine in the
//! crate (SYRK, the blocked TRSMs, and through them tiled/blocked POTRF)
//! funnels its bulk FLOPs through.
//!
//! `dgemm_raw` is the f64 entry point; `gemm_mp` is the mixed-precision
//! entry point the MP variant's tile tasks use — any f32 operand routes
//! the product through the f32 micro-kernel (operands demoted during
//! packing, accumulation into an f64 destination happens in f64 at the
//! micro-tile boundary).  Both dispatch to the micro-kernel selected by
//! [`super::simd::simd_level`]; the `_at` forms take an explicit level
//! for the conformance suite and the roofline bench.

use super::pack::{self, MatMut, MatRef};
use super::simd::{self, MR32, MR64, NR32, NR64, SimdLevel};
use super::Trans;

/// Cache blocking parameters (f64): KC*MR*8 ≈ L1-resident A strip,
/// MC*KC*8 ≈ L2-resident A block.  Shared with the f32 path (whose
/// footprint is half) and with the workspace-reserve sizing in `pack`.
pub(super) const KC: usize = 256;
pub(super) const MC: usize = 128;

/// Below this `m*n*k` the naive triple loop beats packing overhead.
const NAIVE_CUTOFF: usize = 16 * 16 * 16;

/// General matrix multiply on raw column-major buffers:
/// `C <- alpha * op(A) * op(B) + beta * C` where `op(A)` is `m x k` and
/// `op(B)` is `k x n`.  Dispatches to the process-wide SIMD level.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_raw(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    dgemm_raw_at(simd::simd_level(), ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// [`dgemm_raw`] at an explicit dispatch `level` (conformance/bench API:
/// lets one process compare e.g. the AVX2 path against the scalar
/// oracle without touching global state).
#[allow(clippy::too_many_arguments)]
pub fn dgemm_raw_at(
    level: SimdLevel,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    // Scale C by beta first (packed kernel accumulates).
    if beta == 0.0 {
        for j in 0..n {
            for v in &mut c[j * ldc..j * ldc + m] {
                *v = 0.0;
            }
        }
    } else if beta != 1.0 {
        for j in 0..n {
            for v in &mut c[j * ldc..j * ldc + m] {
                *v *= beta;
            }
        }
    }
    if k == 0 || alpha == 0.0 {
        return;
    }

    // Small problems: naive triple loop beats packing overhead.
    if m * n * k <= NAIVE_CUTOFF {
        dgemm_naive(ta, tb, m, n, k, alpha, a, lda, b, ldb, c, ldc);
        return;
    }

    pack::with_ws(|ws| {
        let ws = &mut *ws;
        let mut p0 = 0;
        while p0 < k {
            let kc = KC.min(k - p0);
            // B panel is packed once per (p0) and reused across A blocks.
            let nstrips = n.div_ceil(NR64);
            let pb = pack::grown(&mut ws.pb64, nstrips * kc * NR64);
            pack::pack_b64(tb, b, ldb, p0, 0, kc, n, pb);
            let mut i0 = 0;
            while i0 < m {
                let mc = MC.min(m - i0);
                let mstrips = mc.div_ceil(MR64);
                let pa = pack::grown(&mut ws.pa64, mstrips * kc * MR64);
                pack::pack_a64(ta, a, lda, i0, p0, mc, kc, pa);
                for js in 0..nstrips {
                    let j = js * NR64;
                    let nr = NR64.min(n - j);
                    let pbs = &pb[js * kc * NR64..(js + 1) * kc * NR64];
                    for is in 0..mstrips {
                        let i = is * MR64;
                        let mr = MR64.min(mc - i);
                        let pas = &pa[is * kc * MR64..(is + 1) * kc * MR64];
                        let coff = (i0 + i) + j * ldc;
                        if mr == MR64 && nr == NR64 {
                            simd::run_mk64(level, kc, alpha, pas, pbs, &mut c[coff..], ldc);
                        } else {
                            simd::mk64_edge(kc, alpha, pas, pbs, &mut c[coff..], ldc, mr, nr);
                        }
                    }
                }
                i0 += mc;
            }
            p0 += kc;
        }
    });
}

/// Reference triple-loop gemm (also the oracle in tests).
#[allow(clippy::too_many_arguments)]
pub fn dgemm_naive(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
) {
    let at = |i: usize, p: usize| -> f64 {
        match ta {
            Trans::N => a[i + p * lda],
            Trans::T => a[p + i * lda],
        }
    };
    let bt = |p: usize, j: usize| -> f64 {
        match tb {
            Trans::N => b[p + j * ldb],
            Trans::T => b[j + p * ldb],
        }
    };
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0;
            for p in 0..k {
                acc += at(i, p) * bt(p, j);
            }
            c[i + j * ldc] += alpha * acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Mixed precision
// ---------------------------------------------------------------------------

/// Mixed-precision GEMM over tagged operands:
/// `C <- alpha * op(A) * op(B) + beta * C`.
///
/// All-f64 operands take the plain [`dgemm_raw`] path.  If *any* operand
/// is f32 (an MP off-band tile), the product runs through the f32
/// micro-kernel: f64 sources are demoted while packing, the micro-tile
/// product accumulates in f32 over `k`, and the merge into an f64
/// destination happens in f64 — "f64 accumulate at tile boundaries".
#[allow(clippy::too_many_arguments)]
pub fn gemm_mp(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: MatRef<'_>,
    lda: usize,
    b: MatRef<'_>,
    ldb: usize,
    beta: f64,
    c: MatMut<'_>,
    ldc: usize,
) {
    gemm_mp_at(simd::simd_level(), ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// [`gemm_mp`] at an explicit dispatch level (conformance/bench API).
#[allow(clippy::too_many_arguments)]
pub fn gemm_mp_at(
    level: SimdLevel,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: MatRef<'_>,
    lda: usize,
    b: MatRef<'_>,
    ldb: usize,
    beta: f64,
    c: MatMut<'_>,
    ldc: usize,
) {
    match (a, b, c) {
        (MatRef::F64(a), MatRef::F64(b), MatMut::F64(c)) => {
            dgemm_raw_at(level, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
        }
        (a, b, mut c) => {
            if m == 0 || n == 0 {
                return;
            }
            scale_beta_mp(&mut c, m, n, beta, ldc);
            if k == 0 || alpha == 0.0 {
                return;
            }
            if m * n * k <= NAIVE_CUTOFF {
                gemm_mp_naive(ta, tb, m, n, k, alpha, a, lda, b, ldb, &mut c, ldc);
                return;
            }
            pack::with_ws(|ws| {
                let ws = &mut *ws;
                let mut p0 = 0;
                while p0 < k {
                    let kc = KC.min(k - p0);
                    let nstrips = n.div_ceil(NR32);
                    let pb = pack::grown(&mut ws.pb32, nstrips * kc * NR32);
                    pack::pack_b32(tb, b, ldb, p0, 0, kc, n, pb);
                    let mut i0 = 0;
                    while i0 < m {
                        let mc = MC.min(m - i0);
                        let mstrips = mc.div_ceil(MR32);
                        let pa = pack::grown(&mut ws.pa32, mstrips * kc * MR32);
                        pack::pack_a32(ta, a, lda, i0, p0, mc, kc, pa);
                        for js in 0..nstrips {
                            let j = js * NR32;
                            let nr = NR32.min(n - j);
                            let pbs = &pb[js * kc * NR32..(js + 1) * kc * NR32];
                            for is in 0..mstrips {
                                let i = is * MR32;
                                let mr = MR32.min(mc - i);
                                let pas = &pa[is * kc * MR32..(is + 1) * kc * MR32];
                                let coff = (i0 + i) + j * ldc;
                                let mut out = [0.0f32; MR32 * NR32];
                                simd::run_mk32(level, kc, pas, pbs, &mut out);
                                store_mp(&out, alpha, &mut c, coff, ldc, mr, nr);
                            }
                        }
                        i0 += mc;
                    }
                    p0 += kc;
                }
            });
        }
    }
}

/// Scale the `m x n` destination by beta in its own precision
/// (`beta == 0` overwrites, LAPACK convention — NaNs in C are ignored).
fn scale_beta_mp(c: &mut MatMut<'_>, m: usize, n: usize, beta: f64, ldc: usize) {
    if beta == 1.0 {
        return;
    }
    match c {
        MatMut::F64(s) => {
            for j in 0..n {
                for v in &mut s[j * ldc..j * ldc + m] {
                    *v = if beta == 0.0 { 0.0 } else { *v * beta };
                }
            }
        }
        MatMut::F32(s) => {
            let bt = beta as f32;
            for j in 0..n {
                for v in &mut s[j * ldc..j * ldc + m] {
                    *v = if beta == 0.0 { 0.0 } else { *v * bt };
                }
            }
        }
    }
}

/// Merge one micro-tile product into the destination: the f64 arm is the
/// "f64 accumulate at tile boundaries" step of the MP design.
fn store_mp(
    out: &[f32; MR32 * NR32],
    alpha: f64,
    c: &mut MatMut<'_>,
    coff: usize,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    match c {
        MatMut::F64(s) => {
            for j in 0..nr {
                let cj = &mut s[coff + j * ldc..coff + j * ldc + mr];
                let oj = &out[j * MR32..j * MR32 + mr];
                for i in 0..mr {
                    cj[i] += alpha * oj[i] as f64;
                }
            }
        }
        MatMut::F32(s) => {
            let al = alpha as f32;
            for j in 0..nr {
                let cj = &mut s[coff + j * ldc..coff + j * ldc + mr];
                let oj = &out[j * MR32..j * MR32 + mr];
                for i in 0..mr {
                    cj[i] += al * oj[i];
                }
            }
        }
    }
}

/// Naive mixed-precision triple loop (small problems + oracle): f32
/// products and f32 accumulation over `k`, destination merge in its own
/// precision — the same arithmetic the packed path performs.
#[allow(clippy::too_many_arguments)]
fn gemm_mp_naive(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: MatRef<'_>,
    lda: usize,
    b: MatRef<'_>,
    ldb: usize,
    c: &mut MatMut<'_>,
    ldc: usize,
) {
    let at = |i: usize, p: usize| -> f32 {
        match ta {
            Trans::N => a.get_f32(i + p * lda),
            Trans::T => a.get_f32(p + i * lda),
        }
    };
    let bt = |p: usize, j: usize| -> f32 {
        match tb {
            Trans::N => b.get_f32(p + j * ldb),
            Trans::T => b.get_f32(j + p * ldb),
        }
    };
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += at(i, p) * bt(p, j);
            }
            match c {
                MatMut::F64(s) => s[i + j * ldc] += alpha * acc as f64,
                MatMut::F32(s) => s[i + j * ldc] += alpha as f32 * acc,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, m: usize, n: usize) -> Vec<f64> {
        (0..m * n).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn mixed_path_matches_f64_at_f32_scale() {
        let mut rng = Pcg64::seed_from_u64(41);
        for &(m, n, k) in &[(5usize, 4usize, 3usize), (33, 29, 40), (64, 64, 64)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let c0 = rand_mat(&mut rng, m, n);
            let mut cref = c0.clone();
            dgemm_raw(Trans::N, Trans::N, m, n, k, 1.2, &a, m, &b, k, 0.5, &mut cref, m);
            // f32 A against f64 B into f64 C: f32-scale agreement.
            let mut cmp = c0.clone();
            gemm_mp(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.2,
                MatRef::F32(&a32),
                m,
                MatRef::F64(&b),
                k,
                0.5,
                MatMut::F64(&mut cmp),
                m,
            );
            let err = cmp
                .iter()
                .zip(&cref)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-4 * k as f64, "({m},{n},{k}) err {err}");
            assert!(err > 0.0 || k == 0, "f32 path should not be bit-exact");
        }
    }

    #[test]
    fn mixed_all_f64_operands_take_exact_path() {
        // Pinned to an explicit level: the implicit-dispatch entry points
        // would race the process-global override another test may flip.
        let mut rng = Pcg64::seed_from_u64(42);
        let (m, n, k) = (23, 17, 31);
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, n, k);
        let c0 = rand_mat(&mut rng, m, n);
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        let level = SimdLevel::Scalar;
        dgemm_raw_at(level, Trans::N, Trans::T, m, n, k, -1.0, &a, m, &b, n, 1.0, &mut c1, m);
        gemm_mp_at(
            level,
            Trans::N,
            Trans::T,
            m,
            n,
            k,
            -1.0,
            MatRef::F64(&a),
            m,
            MatRef::F64(&b),
            n,
            1.0,
            MatMut::F64(&mut c2),
            m,
        );
        assert_eq!(c1, c2, "all-f64 mixed call must be bit-identical");
    }

    #[test]
    fn mixed_beta_zero_overwrites_nan_f32_dest() {
        let a32 = vec![1.0f32, 2.0, 3.0, 4.0];
        let b32 = vec![1.0f32, 0.0, 0.0, 1.0];
        let mut c = vec![f32::NAN; 4];
        gemm_mp(
            Trans::N,
            Trans::N,
            2,
            2,
            2,
            1.0,
            MatRef::F32(&a32),
            2,
            MatRef::F32(&b32),
            2,
            0.0,
            MatMut::F32(&mut c),
            2,
        );
        assert_eq!(c, a32);
    }

    #[test]
    fn forced_levels_agree_on_mixed_path() {
        // Packed-vs-packed across levels (scalar vs detected): exercises
        // run_mk32 store layout; tight f32 tolerance since both paths do
        // the identical f32 packing.
        let mut rng = Pcg64::seed_from_u64(43);
        let (m, n, k) = (47, 38, 52);
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm_mp_at(
            SimdLevel::Scalar,
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            MatRef::F32(&a),
            m,
            MatRef::F32(&b),
            k,
            0.0,
            MatMut::F32(&mut c1),
            m,
        );
        gemm_mp_at(
            simd::detected_simd(),
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            MatRef::F32(&a),
            m,
            MatRef::F32(&b),
            k,
            0.0,
            MatMut::F32(&mut c2),
            m,
        );
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() <= 1e-4, "{x} vs {y}");
        }
    }
}
