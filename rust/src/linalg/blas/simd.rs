//! Runtime-dispatched SIMD micro-kernels.
//!
//! The packed GEMM macro-kernel (see `gemm.rs`) funnels every bulk FLOP of
//! the crate through one of the register micro-kernels below.  Which
//! implementation runs is decided **once per process** by CPU-feature
//! detection ([`simd_level`]), overridable with
//! `EXAGEOSTAT_SIMD=auto|avx2|neon|scalar` (surfaced exactly like
//! `EXAGEOSTAT_BACKEND`) and, for benches/tests that need to compare paths
//! in-process, with [`set_simd_override`].
//!
//! The scalar micro-kernel is kept unconditionally: it is the conformance
//! oracle the SIMD paths are tested against (`rust/tests/simd_kernels.rs`,
//! tolerance 1e-13 — the only permitted divergence is FMA vs separate
//! multiply/add rounding), and the fallback on hardware without AVX2+FMA
//! or NEON.
//!
//! Register-block geometry (shared by every implementation so packing and
//! results are layout-identical across dispatch levels):
//!
//! * f64: `MR64 x NR64 = 8 x 6` — AVX2 keeps the 12 accumulators in ymm
//!   registers (2 x 4 lanes per column), NEON in 24 `float64x2_t`.
//! * f32: `MR32 x NR32 = 16 x 6` — twice the lane width at the same
//!   register budget; this is what makes the MP compute path pay off.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// f64 micro-tile rows (A-panel strip height).
pub(super) const MR64: usize = 8;
/// f64 micro-tile columns (B-panel strip width).
pub(super) const NR64: usize = 6;
/// f32 micro-tile rows.
pub(super) const MR32: usize = 16;
/// f32 micro-tile columns.
pub(super) const NR32: usize = 6;

/// Which micro-kernel implementation the dispatcher runs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable Rust loops — always available, the conformance oracle.
    Scalar,
    /// `std::arch::x86_64` AVX2 + FMA (requires both CPU features).
    Avx2,
    /// `std::arch::aarch64` NEON.
    Neon,
}

impl SimdLevel {
    /// Stable lowercase name (the `EXAGEOSTAT_SIMD` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Can this level execute on the current CPU?
    pub fn is_available(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            SimdLevel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdLevel::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }
}

/// Best level the current CPU supports (ignores env and override).
pub fn detected_simd() -> SimdLevel {
    if SimdLevel::Avx2.is_available() {
        SimdLevel::Avx2
    } else if SimdLevel::Neon.is_available() {
        SimdLevel::Neon
    } else {
        SimdLevel::Scalar
    }
}

/// Resolve `EXAGEOSTAT_SIMD` once.  Unknown names warn and fall back to
/// auto-detection; a named level the CPU cannot run warns and falls back
/// to scalar (never to an illegal-instruction crash).
fn base_level() -> SimdLevel {
    static BASE: OnceLock<SimdLevel> = OnceLock::new();
    *BASE.get_or_init(|| match std::env::var("EXAGEOSTAT_SIMD") {
        Err(_) => detected_simd(),
        Ok(v) => match v.as_str() {
            "auto" => detected_simd(),
            "scalar" => SimdLevel::Scalar,
            "avx2" => checked_request(SimdLevel::Avx2),
            "neon" => checked_request(SimdLevel::Neon),
            other => {
                eprintln!(
                    "warning: EXAGEOSTAT_SIMD={other:?} not recognized \
                     (auto|avx2|neon|scalar); auto-detecting"
                );
                detected_simd()
            }
        },
    })
}

fn checked_request(level: SimdLevel) -> SimdLevel {
    if level.is_available() {
        level
    } else {
        eprintln!(
            "warning: EXAGEOSTAT_SIMD={} requested but this CPU does not \
             support it; falling back to the scalar kernels",
            level.name()
        );
        SimdLevel::Scalar
    }
}

/// In-process override (0 = none); lets benches and the conformance suite
/// compare dispatch paths without re-exec'ing under a different env.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force a dispatch level for the whole process (benches / tests only —
/// production selection is `EXAGEOSTAT_SIMD`).  Requests for a level the
/// CPU cannot run are ignored; returns whether the override (or reset,
/// for `None`) was applied.
pub fn set_simd_override(level: Option<SimdLevel>) -> bool {
    let code = match level {
        None => 0,
        Some(l) if !l.is_available() => return false,
        Some(SimdLevel::Scalar) => 1,
        Some(SimdLevel::Avx2) => 2,
        Some(SimdLevel::Neon) => 3,
    };
    OVERRIDE.store(code, Ordering::SeqCst);
    true
}

/// The micro-kernel level every BLAS-3 call in this process dispatches to.
#[inline]
pub fn simd_level() -> SimdLevel {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => SimdLevel::Scalar,
        2 => SimdLevel::Avx2,
        3 => SimdLevel::Neon,
        _ => base_level(),
    }
}

// ---------------------------------------------------------------------------
// f64 micro-kernels: C(MR64 x NR64) += alpha * PA(MR64 x k) * PB(k x NR64)
// ---------------------------------------------------------------------------

/// Portable full-tile f64 micro-kernel (also the conformance oracle).
fn mk64_scalar(k: usize, alpha: f64, pa: &[f64], pb: &[f64], c: &mut [f64], ldc: usize) {
    // Accumulate in registers; `ab[j*MR64 + i]` = C(i, j).
    let mut ab = [0.0f64; MR64 * NR64];
    let mut pa_off = 0;
    let mut pb_off = 0;
    for _ in 0..k {
        let a = &pa[pa_off..pa_off + MR64];
        let b = &pb[pb_off..pb_off + NR64];
        // Fully unrolled so LLVM vectorizes to the widest baseline lanes.
        for j in 0..NR64 {
            let bj = b[j];
            let abj = &mut ab[j * MR64..(j + 1) * MR64];
            for i in 0..MR64 {
                abj[i] += a[i] * bj;
            }
        }
        pa_off += MR64;
        pb_off += NR64;
    }
    for j in 0..NR64 {
        let cj = &mut c[j * ldc..j * ldc + MR64];
        let abj = &ab[j * MR64..(j + 1) * MR64];
        for i in 0..MR64 {
            cj[i] += alpha * abj[i];
        }
    }
}

/// Like the full kernel but writes only the valid `mr x nr` corner (edge
/// strips).  Edges are O(perimeter) work, so they always run this scalar
/// path regardless of dispatch level — the levels therefore differ only
/// on full tiles.
#[allow(clippy::too_many_arguments)]
pub(super) fn mk64_edge(
    k: usize,
    alpha: f64,
    pa: &[f64],
    pb: &[f64],
    c: &mut [f64],
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut ab = [0.0f64; MR64 * NR64];
    let mut pa_off = 0;
    let mut pb_off = 0;
    for _ in 0..k {
        let a = &pa[pa_off..pa_off + MR64];
        let b = &pb[pb_off..pb_off + NR64];
        for j in 0..NR64 {
            let bj = b[j];
            let abj = &mut ab[j * MR64..(j + 1) * MR64];
            for i in 0..MR64 {
                abj[i] += a[i] * bj;
            }
        }
        pa_off += MR64;
        pb_off += NR64;
    }
    for j in 0..nr {
        for i in 0..mr {
            c[i + j * ldc] += alpha * ab[j * MR64 + i];
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mk64_avx2(k: usize, alpha: f64, pa: &[f64], pb: &[f64], c: &mut [f64], ldc: usize) {
    use std::arch::x86_64::*;
    debug_assert!(pa.len() >= k * MR64 && pb.len() >= k * NR64);
    debug_assert!(c.len() >= (NR64 - 1) * ldc + MR64);
    // 12 ymm accumulators: two 4-lane halves per column.
    let mut acc = [_mm256_setzero_pd(); 2 * NR64];
    let mut ap = pa.as_ptr();
    let mut bp = pb.as_ptr();
    for _ in 0..k {
        let a0 = _mm256_loadu_pd(ap);
        let a1 = _mm256_loadu_pd(ap.add(4));
        for j in 0..NR64 {
            let b = _mm256_set1_pd(*bp.add(j));
            acc[2 * j] = _mm256_fmadd_pd(a0, b, acc[2 * j]);
            acc[2 * j + 1] = _mm256_fmadd_pd(a1, b, acc[2 * j + 1]);
        }
        ap = ap.add(MR64);
        bp = bp.add(NR64);
    }
    let va = _mm256_set1_pd(alpha);
    for j in 0..NR64 {
        let cp = c.as_mut_ptr().add(j * ldc);
        _mm256_storeu_pd(cp, _mm256_fmadd_pd(va, acc[2 * j], _mm256_loadu_pd(cp)));
        let cp4 = cp.add(4);
        _mm256_storeu_pd(cp4, _mm256_fmadd_pd(va, acc[2 * j + 1], _mm256_loadu_pd(cp4)));
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mk64_neon(k: usize, alpha: f64, pa: &[f64], pb: &[f64], c: &mut [f64], ldc: usize) {
    use std::arch::aarch64::*;
    debug_assert!(pa.len() >= k * MR64 && pb.len() >= k * NR64);
    debug_assert!(c.len() >= (NR64 - 1) * ldc + MR64);
    // 24 q-register accumulators: four 2-lane quarters per column.
    let mut acc = [vdupq_n_f64(0.0); 4 * NR64];
    let mut ap = pa.as_ptr();
    let mut bp = pb.as_ptr();
    for _ in 0..k {
        let a0 = vld1q_f64(ap);
        let a1 = vld1q_f64(ap.add(2));
        let a2 = vld1q_f64(ap.add(4));
        let a3 = vld1q_f64(ap.add(6));
        for j in 0..NR64 {
            let b = vdupq_n_f64(*bp.add(j));
            acc[4 * j] = vfmaq_f64(acc[4 * j], a0, b);
            acc[4 * j + 1] = vfmaq_f64(acc[4 * j + 1], a1, b);
            acc[4 * j + 2] = vfmaq_f64(acc[4 * j + 2], a2, b);
            acc[4 * j + 3] = vfmaq_f64(acc[4 * j + 3], a3, b);
        }
        ap = ap.add(MR64);
        bp = bp.add(NR64);
    }
    let va = vdupq_n_f64(alpha);
    for j in 0..NR64 {
        let cp = c.as_mut_ptr().add(j * ldc);
        for q in 0..4 {
            let p = cp.add(2 * q);
            vst1q_f64(p, vfmaq_f64(vld1q_f64(p), acc[4 * j + q], va));
        }
    }
}

/// Dispatch one full f64 micro-tile at `level`.
///
/// `c` must hold at least `(NR64 - 1) * ldc + MR64` elements.
#[inline]
pub(super) fn run_mk64(
    level: SimdLevel,
    k: usize,
    alpha: f64,
    pa: &[f64],
    pb: &[f64],
    c: &mut [f64],
    ldc: usize,
) {
    match level {
        SimdLevel::Scalar => mk64_scalar(k, alpha, pa, pb, c, ldc),
        SimdLevel::Avx2 => {
            // SAFETY: `Avx2` is only reachable through `simd_level()` /
            // `set_simd_override`, both of which verify CPU support.
            #[cfg(target_arch = "x86_64")]
            unsafe {
                mk64_avx2(k, alpha, pa, pb, c, ldc)
            };
            #[cfg(not(target_arch = "x86_64"))]
            mk64_scalar(k, alpha, pa, pb, c, ldc);
        }
        SimdLevel::Neon => {
            // SAFETY: as above — `Neon` implies NEON support was detected.
            #[cfg(target_arch = "aarch64")]
            unsafe {
                mk64_neon(k, alpha, pa, pb, c, ldc)
            };
            #[cfg(not(target_arch = "aarch64"))]
            mk64_scalar(k, alpha, pa, pb, c, ldc);
        }
    }
}

// ---------------------------------------------------------------------------
// f32 micro-kernels: OUT(MR32 x NR32) = PA(MR32 x k) * PB(k x NR32)
//
// Compute-only: the product is written (not accumulated) into a stack
// tile; the caller applies alpha and merges into the destination, which
// is how the MP path accumulates f32 products into f64 tiles at tile
// boundaries.
// ---------------------------------------------------------------------------

fn mk32_scalar(k: usize, pa: &[f32], pb: &[f32], out: &mut [f32; MR32 * NR32]) {
    let mut ab = [0.0f32; MR32 * NR32];
    let mut pa_off = 0;
    let mut pb_off = 0;
    for _ in 0..k {
        let a = &pa[pa_off..pa_off + MR32];
        let b = &pb[pb_off..pb_off + NR32];
        for j in 0..NR32 {
            let bj = b[j];
            let abj = &mut ab[j * MR32..(j + 1) * MR32];
            for i in 0..MR32 {
                abj[i] += a[i] * bj;
            }
        }
        pa_off += MR32;
        pb_off += NR32;
    }
    *out = ab;
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mk32_avx2(k: usize, pa: &[f32], pb: &[f32], out: &mut [f32; MR32 * NR32]) {
    use std::arch::x86_64::*;
    debug_assert!(pa.len() >= k * MR32 && pb.len() >= k * NR32);
    let mut acc = [_mm256_setzero_ps(); 2 * NR32];
    let mut ap = pa.as_ptr();
    let mut bp = pb.as_ptr();
    for _ in 0..k {
        let a0 = _mm256_loadu_ps(ap);
        let a1 = _mm256_loadu_ps(ap.add(8));
        for j in 0..NR32 {
            let b = _mm256_set1_ps(*bp.add(j));
            acc[2 * j] = _mm256_fmadd_ps(a0, b, acc[2 * j]);
            acc[2 * j + 1] = _mm256_fmadd_ps(a1, b, acc[2 * j + 1]);
        }
        ap = ap.add(MR32);
        bp = bp.add(NR32);
    }
    for j in 0..NR32 {
        let op = out.as_mut_ptr().add(j * MR32);
        _mm256_storeu_ps(op, acc[2 * j]);
        _mm256_storeu_ps(op.add(8), acc[2 * j + 1]);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mk32_neon(k: usize, pa: &[f32], pb: &[f32], out: &mut [f32; MR32 * NR32]) {
    use std::arch::aarch64::*;
    debug_assert!(pa.len() >= k * MR32 && pb.len() >= k * NR32);
    let mut acc = [vdupq_n_f32(0.0); 4 * NR32];
    let mut ap = pa.as_ptr();
    let mut bp = pb.as_ptr();
    for _ in 0..k {
        let a0 = vld1q_f32(ap);
        let a1 = vld1q_f32(ap.add(4));
        let a2 = vld1q_f32(ap.add(8));
        let a3 = vld1q_f32(ap.add(12));
        for j in 0..NR32 {
            let b = vdupq_n_f32(*bp.add(j));
            acc[4 * j] = vfmaq_f32(acc[4 * j], a0, b);
            acc[4 * j + 1] = vfmaq_f32(acc[4 * j + 1], a1, b);
            acc[4 * j + 2] = vfmaq_f32(acc[4 * j + 2], a2, b);
            acc[4 * j + 3] = vfmaq_f32(acc[4 * j + 3], a3, b);
        }
        ap = ap.add(MR32);
        bp = bp.add(NR32);
    }
    for j in 0..NR32 {
        let op = out.as_mut_ptr().add(j * MR32);
        for q in 0..4 {
            vst1q_f32(op.add(4 * q), acc[4 * j + q]);
        }
    }
}

/// Dispatch one full f32 micro-tile at `level` (compute-only; see above).
#[inline]
pub(super) fn run_mk32(
    level: SimdLevel,
    k: usize,
    pa: &[f32],
    pb: &[f32],
    out: &mut [f32; MR32 * NR32],
) {
    match level {
        SimdLevel::Scalar => mk32_scalar(k, pa, pb, out),
        SimdLevel::Avx2 => {
            // SAFETY: `Avx2` implies detection succeeded (see run_mk64).
            #[cfg(target_arch = "x86_64")]
            unsafe {
                mk32_avx2(k, pa, pb, out)
            };
            #[cfg(not(target_arch = "x86_64"))]
            mk32_scalar(k, pa, pb, out);
        }
        SimdLevel::Neon => {
            // SAFETY: `Neon` implies detection succeeded (see run_mk64).
            #[cfg(target_arch = "aarch64")]
            unsafe {
                mk32_neon(k, pa, pb, out)
            };
            #[cfg(not(target_arch = "aarch64"))]
            mk32_scalar(k, pa, pb, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detected_level_is_available() {
        assert!(detected_simd().is_available());
        assert!(SimdLevel::Scalar.is_available());
    }

    #[test]
    fn override_rejects_unavailable_levels_without_mutating() {
        // Exactly one of Avx2/Neon can be available on any one arch, so
        // at least one of these requests must be rejected — and a
        // rejected request must not change dispatch.  The accept/reset
        // path (which mutates process-global state and would race other
        // lib tests' implicit-dispatch calls) is exercised in the
        // dedicated integration binary `rust/tests/simd_kernels.rs`.
        let a = SimdLevel::Avx2;
        let n = SimdLevel::Neon;
        assert!(!(a.is_available() && n.is_available()));
        for l in [a, n] {
            if !l.is_available() {
                let before = simd_level();
                assert!(!set_simd_override(Some(l)));
                assert_eq!(simd_level(), before);
                assert_ne!(simd_level(), l);
            }
        }
        // The un-overridden level is the env/detection resolution.
        assert!(base_level().is_available());
    }

    #[test]
    fn names_round_trip_the_env_vocabulary() {
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
        assert_eq!(SimdLevel::Neon.name(), "neon");
    }

    #[test]
    fn micro_kernels_agree_full_tile() {
        // Direct micro-kernel-level parity at the detected level (the
        // integration suite covers the whole gemm; this pins the kernel
        // itself).
        let k = 37;
        let pa: Vec<f64> = (0..k * MR64).map(|i| ((i * 7) % 13) as f64 / 13.0 - 0.5).collect();
        let pb: Vec<f64> = (0..k * NR64).map(|i| ((i * 5) % 11) as f64 / 11.0 - 0.5).collect();
        let mut c1 = vec![0.25f64; MR64 * NR64];
        let mut c2 = c1.clone();
        mk64_scalar(k, 1.5, &pa, &pb, &mut c1, MR64);
        run_mk64(detected_simd(), k, 1.5, &pa, &pb, &mut c2, MR64);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-13, "{x} vs {y}");
        }

        let pa: Vec<f32> = (0..k * MR32).map(|i| ((i * 7) % 13) as f32 / 13.0 - 0.5).collect();
        let pb: Vec<f32> = (0..k * NR32).map(|i| ((i * 5) % 11) as f32 / 11.0 - 0.5).collect();
        let mut o1 = [0.0f32; MR32 * NR32];
        let mut o2 = [0.0f32; MR32 * NR32];
        mk32_scalar(k, &pa, &pb, &mut o1);
        run_mk32(detected_simd(), k, &pa, &pb, &mut o2);
        for (x, y) in o1.iter().zip(&o2) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
