//! From-scratch BLAS-3 kernels (the vendored crate set has no BLAS, and the
//! paper's whole point is that these kernels are the building blocks the
//! task runtime schedules).
//!
//! Everything is column-major with an explicit leading dimension so the same
//! routines serve both full matrices and `ts x ts` tiles.  `dgemm` uses a
//! GotoBLAS-style packed algorithm (MC/KC/NC cache blocking + an MR x NR
//! register micro-kernel); the remaining routines are column-oriented
//! LAPACK-style implementations.  See EXPERIMENTS.md §Perf for measured
//! throughput.

use super::matrix::Matrix;

/// Transpose flag for gemm-like routines.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Trans {
    N,
    T,
}

// ---------------------------------------------------------------------------
// gemm
// ---------------------------------------------------------------------------

/// Micro-kernel register block: C(MR x NR) += A(MR x k) * B(k x NR).
const MR: usize = 8;
const NR: usize = 6;
/// Cache blocking parameters (f64): KC*MR*8 ≈ L1-resident A strip,
/// MC*KC*8 ≈ L2-resident A block.
const KC: usize = 256;
const MC: usize = 128;

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_kernel(k: usize, alpha: f64, pa: &[f64], pb: &[f64], c: &mut [f64], ldc: usize) {
    // Accumulate in registers; `ab[j*MR + i]` = C(i, j).
    let mut ab = [0.0f64; MR * NR];
    let mut pa_off = 0;
    let mut pb_off = 0;
    for _ in 0..k {
        let a = &pa[pa_off..pa_off + MR];
        let b = &pb[pb_off..pb_off + NR];
        // Fully unrolled so LLVM vectorizes to fma lanes.
        for j in 0..NR {
            let bj = b[j];
            let abj = &mut ab[j * MR..(j + 1) * MR];
            for i in 0..MR {
                abj[i] += a[i] * bj;
            }
        }
        pa_off += MR;
        pb_off += NR;
    }
    for j in 0..NR {
        let cj = &mut c[j * ldc..j * ldc + MR];
        let abj = &ab[j * MR..(j + 1) * MR];
        for i in 0..MR {
            cj[i] += alpha * abj[i];
        }
    }
}

/// Like `micro_kernel` but writes only the valid `mr x nr` corner (edge case).
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel_edge(
    k: usize,
    alpha: f64,
    pa: &[f64],
    pb: &[f64],
    c: &mut [f64],
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut ab = [0.0f64; MR * NR];
    let mut pa_off = 0;
    let mut pb_off = 0;
    for _ in 0..k {
        let a = &pa[pa_off..pa_off + MR];
        let b = &pb[pb_off..pb_off + NR];
        for j in 0..NR {
            let bj = b[j];
            let abj = &mut ab[j * MR..(j + 1) * MR];
            for i in 0..MR {
                abj[i] += a[i] * bj;
            }
        }
        pa_off += MR;
        pb_off += NR;
    }
    for j in 0..nr {
        for i in 0..mr {
            c[i + j * ldc] += alpha * ab[j * MR + i];
        }
    }
}

/// Pack an `mc x kc` block of op(A) into MR-row strips, zero padded.
/// `op(A)[i, p]` with `i` in `[i0, i0+mc)`, `p` in `[p0, p0+kc)`.
#[inline]
#[allow(clippy::too_many_arguments)]
fn pack_a(
    ta: Trans,
    a: &[f64],
    lda: usize,
    i0: usize,
    p0: usize,
    mc: usize,
    kc: usize,
    out: &mut Vec<f64>,
) {
    let strips = mc.div_ceil(MR);
    out.clear();
    out.resize(strips * kc * MR, 0.0);
    for s in 0..strips {
        let ib = s * MR;
        let mr = MR.min(mc - ib);
        let dst_base = s * kc * MR;
        for p in 0..kc {
            let dst = &mut out[dst_base + p * MR..dst_base + p * MR + MR];
            match ta {
                Trans::N => {
                    let col = p0 + p;
                    for i in 0..mr {
                        dst[i] = a[(i0 + ib + i) + col * lda];
                    }
                }
                Trans::T => {
                    for i in 0..mr {
                        dst[i] = a[(p0 + p) + (i0 + ib + i) * lda];
                    }
                }
            }
            for i in mr..MR {
                dst[i] = 0.0;
            }
        }
    }
}

/// Pack a `kc x nc` block of op(B) into NR-column strips, zero padded.
#[inline]
#[allow(clippy::too_many_arguments)]
fn pack_b(
    tb: Trans,
    b: &[f64],
    ldb: usize,
    p0: usize,
    j0: usize,
    kc: usize,
    nc: usize,
    out: &mut Vec<f64>,
) {
    let strips = nc.div_ceil(NR);
    out.clear();
    out.resize(strips * kc * NR, 0.0);
    for s in 0..strips {
        let jb = s * NR;
        let nr = NR.min(nc - jb);
        let dst_base = s * kc * NR;
        for p in 0..kc {
            let dst = &mut out[dst_base + p * NR..dst_base + p * NR + NR];
            match tb {
                Trans::N => {
                    for j in 0..nr {
                        dst[j] = b[(p0 + p) + (j0 + jb + j) * ldb];
                    }
                }
                Trans::T => {
                    for j in 0..nr {
                        dst[j] = b[(j0 + jb + j) + (p0 + p) * ldb];
                    }
                }
            }
            for j in nr..NR {
                dst[j] = 0.0;
            }
        }
    }
}

/// General matrix multiply on raw column-major buffers:
/// `C <- alpha * op(A) * op(B) + beta * C` where `op(A)` is `m x k` and
/// `op(B)` is `k x n`.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_raw(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    // Scale C by beta first (packed kernel accumulates).
    if beta == 0.0 {
        for j in 0..n {
            for v in &mut c[j * ldc..j * ldc + m] {
                *v = 0.0;
            }
        }
    } else if beta != 1.0 {
        for j in 0..n {
            for v in &mut c[j * ldc..j * ldc + m] {
                *v *= beta;
            }
        }
    }
    if k == 0 || alpha == 0.0 {
        return;
    }

    // Small problems: naive triple loop beats packing overhead.
    if m * n * k <= 16 * 16 * 16 {
        dgemm_naive(ta, tb, m, n, k, alpha, a, lda, b, ldb, c, ldc);
        return;
    }

    let mut pa = Vec::new();
    let mut pb = Vec::new();
    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        // B panel is packed once per (p0) and reused across the A blocks.
        pack_b(tb, b, ldb, p0, 0, kc, n, &mut pb);
        let mut i0 = 0;
        while i0 < m {
            let mc = MC.min(m - i0);
            pack_a(ta, a, lda, i0, p0, mc, kc, &mut pa);
            let mstrips = mc.div_ceil(MR);
            let nstrips = n.div_ceil(NR);
            for js in 0..nstrips {
                let j = js * NR;
                let nr = NR.min(n - j);
                let pbs = &pb[js * kc * NR..(js + 1) * kc * NR];
                for is in 0..mstrips {
                    let i = is * MR;
                    let mr = MR.min(mc - i);
                    let pas = &pa[is * kc * MR..(is + 1) * kc * MR];
                    let coff = (i0 + i) + j * ldc;
                    if mr == MR && nr == NR {
                        micro_kernel(kc, alpha, pas, pbs, &mut c[coff..], ldc);
                    } else {
                        micro_kernel_edge(kc, alpha, pas, pbs, &mut c[coff..], ldc, mr, nr);
                    }
                }
            }
            i0 += mc;
        }
        p0 += kc;
    }
}

/// Reference triple-loop gemm (also the oracle in tests).
#[allow(clippy::too_many_arguments)]
pub fn dgemm_naive(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
) {
    let at = |i: usize, p: usize| -> f64 {
        match ta {
            Trans::N => a[i + p * lda],
            Trans::T => a[p + i * lda],
        }
    };
    let bt = |p: usize, j: usize| -> f64 {
        match tb {
            Trans::N => b[p + j * ldb],
            Trans::T => b[j + p * ldb],
        }
    };
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0;
            for p in 0..k {
                acc += at(i, p) * bt(p, j);
            }
            c[i + j * ldc] += alpha * acc;
        }
    }
}

/// Matrix-level gemm wrapper: `C <- alpha*op(A)*op(B) + beta*C`.
pub fn dgemm(ta: bool, tb: bool, alpha: f64, a: &Matrix, b: &Matrix, beta: f64, c: &mut Matrix) {
    let ta = if ta { Trans::T } else { Trans::N };
    let tb = if tb { Trans::T } else { Trans::N };
    let (m, k) = match ta {
        Trans::N => (a.rows(), a.cols()),
        Trans::T => (a.cols(), a.rows()),
    };
    let n = match tb {
        Trans::N => b.cols(),
        Trans::T => b.rows(),
    };
    let kb = match tb {
        Trans::N => b.rows(),
        Trans::T => b.cols(),
    };
    assert_eq!(k, kb, "gemm inner dims");
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    let lda = a.rows();
    let ldb = b.rows();
    let ldc = c.rows();
    dgemm_raw(
        ta,
        tb,
        m,
        n,
        k,
        alpha,
        a.as_slice(),
        lda,
        b.as_slice(),
        ldb,
        beta,
        c.as_mut_slice(),
        ldc,
    );
}

// ---------------------------------------------------------------------------
// syrk
// ---------------------------------------------------------------------------

/// Symmetric rank-k update, lower, no-trans:
/// `C <- alpha * A * A^T + beta * C` touching only the lower triangle.
/// `A` is `n x k`, `C` is `n x n`.
#[allow(clippy::too_many_arguments)]
pub fn dsyrk_ln_raw(
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    // Delegate to gemm for the bulk (full square), then it is still correct
    // for the lower triangle; but to halve the work we do a block-column
    // version: for each block of columns, gemm only the rows >= block start.
    const NB: usize = 32;
    if beta != 1.0 {
        for j in 0..n {
            for i in j..n {
                let v = &mut c[i + j * ldc];
                *v = if beta == 0.0 { 0.0 } else { *v * beta };
            }
        }
    }
    let mut j0 = 0;
    while j0 < n {
        let nb = NB.min(n - j0);
        // Diagonal block: naive symmetric update (small).
        for j in j0..j0 + nb {
            for i in j..j0 + nb {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[i + p * lda] * a[j + p * lda];
                }
                c[i + j * ldc] += alpha * acc;
            }
        }
        // Below-diagonal panel: gemm (i in [j0+nb, n), columns j0..j0+nb).
        let m = n - (j0 + nb);
        if m > 0 {
            // C[j0+nb.., j0..j0+nb] += alpha * A[j0+nb..,:] * A[j0..j0+nb,:]^T
            let coff = (j0 + nb) + j0 * ldc;
            dgemm_raw(
                Trans::N,
                Trans::T,
                m,
                nb,
                k,
                alpha,
                &a[j0 + nb..],
                lda,
                &a[j0..],
                lda,
                1.0,
                &mut c[coff..],
                ldc,
            );
        }
        j0 += nb;
    }
}

// ---------------------------------------------------------------------------
// trsm / trsv
// ---------------------------------------------------------------------------

/// `B <- B * L^{-T}` (Right, Lower, Transpose, Non-unit).
/// This is the TRSM used by the tiled Cholesky panel update.
/// `B` is `m x n`, `L` is `n x n` lower triangular.
pub fn dtrsm_rltn_raw(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    // Column j of X: X[:,j] = (B[:,j] - sum_{k<j} X[:,k] * L[j,k]) / L[j,j]
    for j in 0..n {
        for kk in 0..j {
            let ljk = l[j + kk * ldl];
            if ljk != 0.0 {
                let (head, tail) = b.split_at_mut(j * ldb);
                let xk = &head[kk * ldb..kk * ldb + m];
                let xj = &mut tail[..m];
                for i in 0..m {
                    xj[i] -= xk[i] * ljk;
                }
            }
        }
        let inv = 1.0 / l[j + j * ldl];
        for v in &mut b[j * ldb..j * ldb + m] {
            *v *= inv;
        }
    }
}

/// `B <- L^{-1} * B` (Left, Lower, No-trans, Non-unit).  `L` is `m x m`,
/// `B` is `m x n`.  Used by the tiled forward substitution.
pub fn dtrsm_llnn_raw(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    for j in 0..n {
        let col = &mut b[j * ldb..j * ldb + m];
        for kk in 0..m {
            let xk = col[kk] / l[kk + kk * ldl];
            col[kk] = xk;
            if xk != 0.0 {
                for i in kk + 1..m {
                    col[i] -= xk * l[i + kk * ldl];
                }
            }
        }
    }
}

/// `B <- L^{-T} * B` (Left, Lower, Transpose, Non-unit): backward
/// substitution, used to apply `Sigma^{-1} = L^{-T} L^{-1}`.
pub fn dtrsm_lltn_raw(m: usize, n: usize, l: &[f64], ldl: usize, b: &mut [f64], ldb: usize) {
    for j in 0..n {
        let col = &mut b[j * ldb..j * ldb + m];
        for kk in (0..m).rev() {
            let mut acc = col[kk];
            for i in kk + 1..m {
                acc -= l[i + kk * ldl] * col[i];
            }
            col[kk] = acc / l[kk + kk * ldl];
        }
    }
}

/// Triangular matrix-vector product `x <- L x` (lower, no-trans, non-unit),
/// used by the exact GRF sampler (`z = L e`).
pub fn dtrmv_ln(n: usize, l: &[f64], ldl: usize, x: &mut [f64]) {
    for i in (0..n).rev() {
        let mut acc = 0.0;
        for k in 0..=i {
            acc += l[i + k * ldl] * x[k];
        }
        x[i] = acc;
    }
}

/// Triangular solve with a single vector: `x <- L^{-1} x`.
pub fn dtrsv_ln(n: usize, l: &[f64], ldl: usize, x: &mut [f64]) {
    dtrsm_llnn_raw(n, 1, l, ldl, x, n);
}

/// Triangular solve with a single vector: `x <- L^{-T} x`.
pub fn dtrsv_lt(n: usize, l: &[f64], ldl: usize, x: &mut [f64]) {
    dtrsm_lltn_raw(n, 1, l, ldl, x, n);
}

// ---------------------------------------------------------------------------
// gemv
// ---------------------------------------------------------------------------

/// `y <- alpha * op(A) x + beta * y` for col-major `A (m x n)`.
#[allow(clippy::too_many_arguments)]
pub fn dgemv_raw(
    ta: Trans,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    x: &[f64],
    beta: f64,
    y: &mut [f64],
) {
    let (ylen, _xlen) = match ta {
        Trans::N => (m, n),
        Trans::T => (n, m),
    };
    if beta == 0.0 {
        for v in &mut y[..ylen] {
            *v = 0.0;
        }
    } else if beta != 1.0 {
        for v in &mut y[..ylen] {
            *v *= beta;
        }
    }
    match ta {
        Trans::N => {
            for j in 0..n {
                let xj = alpha * x[j];
                if xj != 0.0 {
                    let col = &a[j * lda..j * lda + m];
                    for i in 0..m {
                        y[i] += col[i] * xj;
                    }
                }
            }
        }
        Trans::T => {
            for j in 0..n {
                let col = &a[j * lda..j * lda + m];
                let mut acc = 0.0;
                for i in 0..m {
                    acc += col[i] * x[i];
                }
                y[j] += alpha * acc;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// potrf
// ---------------------------------------------------------------------------

/// Error from a failed Cholesky factorization (matrix not SPD at pivot `k`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NotSpd {
    pub pivot: usize,
}

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix is not positive definite (non-positive pivot at {})",
            self.pivot
        )
    }
}
impl std::error::Error for NotSpd {}

/// Unblocked lower Cholesky on an `n x n` column-major buffer.
pub fn dpotrf_unblocked(n: usize, a: &mut [f64], lda: usize) -> Result<(), NotSpd> {
    for j in 0..n {
        // a[j,j] -= sum_{k<j} a[j,k]^2
        let mut d = a[j + j * lda];
        for k in 0..j {
            let v = a[j + k * lda];
            d -= v * v;
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(NotSpd { pivot: j });
        }
        let dj = d.sqrt();
        a[j + j * lda] = dj;
        let inv = 1.0 / dj;
        // Column update: a[i,j] = (a[i,j] - sum_k a[i,k] a[j,k]) / dj
        for k in 0..j {
            let ajk = a[j + k * lda];
            if ajk != 0.0 {
                let (c_k, c_j) = {
                    // split borrows: column k is before column j
                    let (head, tail) = a.split_at_mut(j * lda);
                    (&head[k * lda..k * lda + n], &mut tail[..n])
                };
                for i in j + 1..n {
                    c_j[i] -= c_k[i] * ajk;
                }
            }
        }
        for i in j + 1..n {
            a[i + j * lda] *= inv;
        }
    }
    Ok(())
}

/// Blocked lower Cholesky (right-looking) on a column-major buffer.
pub fn dpotrf_raw(n: usize, a: &mut [f64], lda: usize) -> Result<(), NotSpd> {
    const NB: usize = 64;
    if n <= NB {
        return dpotrf_unblocked(n, a, lda);
    }
    let mut k = 0;
    while k < n {
        let nb = NB.min(n - k);
        // Factor diagonal block.
        dpotrf_unblocked_at(a, lda, k, nb).map_err(|e| NotSpd { pivot: k + e.pivot })?;
        let rest = n - (k + nb);
        if rest > 0 {
            // Panel: A[k+nb.., k..k+nb] <- A[k+nb.., k..k+nb] * L_kk^{-T}
            {
                let (lcol, bcol) = split_panel(a, lda, k, nb);
                dtrsm_rltn_raw(rest, nb, lcol, lda, bcol, lda);
            }
            // Trailing update: A[k+nb.., k+nb..] -= P * P^T (lower).
            let poff = (k + nb) + k * lda;
            let coff = (k + nb) + (k + nb) * lda;
            // Safety note: syrk reads the panel and writes the trailing
            // sub-matrix; they do not overlap (different column ranges,
            // and within shared columns syrk only touches cols >= k+nb).
            let (pan, trail) = a.split_at_mut(coff);
            dsyrk_ln_raw(rest, nb, -1.0, &pan[poff..], lda, 1.0, trail, lda);
        }
        k += nb;
    }
    Ok(())
}

/// Unblocked potrf on the `nb x nb` diagonal block starting at `(k, k)`.
fn dpotrf_unblocked_at(a: &mut [f64], lda: usize, k: usize, nb: usize) -> Result<(), NotSpd> {
    // Work on the sub-buffer starting at (k,k) with the same lda.
    let off = k + k * lda;
    dpotrf_unblocked(nb, &mut a[off..], lda)
}

/// Split borrows for the panel TRSM: returns (L_kk block cols, panel cols),
/// both starting at row offsets appropriate for `lda` indexing.
fn split_panel(a: &mut [f64], lda: usize, k: usize, nb: usize) -> (&[f64], &mut [f64]) {
    // L_kk lives at (k, k); the panel at (k+nb, k).  Same columns k..k+nb,
    // different rows, so we cannot split by column.  Use raw pointers with
    // disjoint-row access (the TRSM reads rows [k, k+nb) and writes rows
    // [k+nb, ...)).
    let base = a.as_mut_ptr();
    unsafe {
        let l = std::slice::from_raw_parts(base.add(k + k * lda), a.len() - (k + k * lda));
        let b = std::slice::from_raw_parts_mut(
            base.add((k + nb) + k * lda),
            a.len() - ((k + nb) + k * lda),
        );
        (l, b)
    }
}

/// Matrix-level Cholesky: factor `A = L L^T` in place (lower), returning
/// the log-determinant of `A` (`2 * sum log L_ii`).
pub fn dpotrf(a: &mut Matrix) -> Result<f64, NotSpd> {
    assert!(a.is_square());
    let n = a.rows();
    dpotrf_raw(n, a.as_mut_slice(), n)?;
    let mut logdet = 0.0;
    for i in 0..n {
        logdet += a[(i, i)].ln();
    }
    Ok(2.0 * logdet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, m: usize, n: usize) -> Vec<f64> {
        (0..m * n).map(|_| rng.normal()).collect()
    }

    fn gemm_oracle(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        beta: f64,
        c: &mut [f64],
        ldc: usize,
    ) {
        for j in 0..n {
            for i in 0..m {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = match ta {
                        Trans::N => a[i + p * lda],
                        Trans::T => a[p + i * lda],
                    };
                    let bv = match tb {
                        Trans::N => b[p + j * ldb],
                        Trans::T => b[j + p * ldb],
                    };
                    acc += av * bv;
                }
                c[i + j * ldc] = alpha * acc + beta * c[i + j * ldc];
            }
        }
    }

    #[test]
    fn gemm_all_trans_combos_match_oracle() {
        let mut rng = Pcg64::seed_from_u64(11);
        for &(m, n, k) in &[(1, 1, 1), (3, 5, 7), (17, 9, 33), (64, 64, 64), (100, 37, 250)] {
            for &ta in &[Trans::N, Trans::T] {
                for &tb in &[Trans::N, Trans::T] {
                    let (ar, ac) = match ta {
                        Trans::N => (m, k),
                        Trans::T => (k, m),
                    };
                    let (br, bc) = match tb {
                        Trans::N => (k, n),
                        Trans::T => (n, k),
                    };
                    let a = rand_mat(&mut rng, ar, ac);
                    let b = rand_mat(&mut rng, br, bc);
                    let c0 = rand_mat(&mut rng, m, n);
                    let mut c1 = c0.clone();
                    let mut c2 = c0.clone();
                    dgemm_raw(ta, tb, m, n, k, 1.3, &a, ar, &b, br, 0.7, &mut c1, m);
                    gemm_oracle(ta, tb, m, n, k, 1.3, &a, ar, &b, br, 0.7, &mut c2, m);
                    let err = c1
                        .iter()
                        .zip(&c2)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0, f64::max);
                    assert!(err < 1e-9, "({m},{n},{k}) {ta:?}{tb:?} err={err}");
                }
            }
        }
    }

    #[test]
    fn gemm_beta_zero_ignores_nan_in_c() {
        // beta=0 must overwrite C even if it held NaN (LAPACK convention).
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![f64::NAN; 4];
        dgemm_raw(Trans::N, Trans::N, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 2);
        assert_eq!(c, a);
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = Pcg64::seed_from_u64(12);
        for &(n, k) in &[(5, 3), (32, 32), (65, 17), (128, 40)] {
            let a = rand_mat(&mut rng, n, k);
            let mut c1 = vec![0.5; n * n];
            let mut c2 = c1.clone();
            dsyrk_ln_raw(n, k, -1.0, &a, n, 1.0, &mut c1, n);
            gemm_oracle(Trans::N, Trans::T, n, n, k, -1.0, &a, n, &a, n, 1.0, &mut c2, n);
            // compare lower triangle only
            for j in 0..n {
                for i in j..n {
                    assert!(
                        (c1[i + j * n] - c2[i + j * n]).abs() < 1e-10,
                        "({n},{k}) at ({i},{j})"
                    );
                }
            }
        }
    }

    /// Build a well-conditioned SPD matrix A = B B^T + n*I.
    fn rand_spd(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let b = rand_mat(rng, n, n);
        let mut a = vec![0.0; n * n];
        dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &b, n, &b, n, 0.0, &mut a, n);
        for i in 0..n {
            a[i + i * n] += n as f64;
        }
        a
    }

    #[test]
    fn potrf_reconstructs() {
        let mut rng = Pcg64::seed_from_u64(13);
        for &n in &[1usize, 2, 5, 33, 64, 100, 130] {
            let a = rand_spd(&mut rng, n);
            let mut l = a.clone();
            dpotrf_raw(n, &mut l, n).unwrap();
            // zero strict upper
            for j in 0..n {
                for i in 0..j {
                    l[i + j * n] = 0.0;
                }
            }
            let mut rec = vec![0.0; n * n];
            dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &l, n, &l, n, 0.0, &mut rec, n);
            let scale = a.iter().map(|v| v.abs()).fold(0.0, f64::max);
            let err = a
                .iter()
                .zip(&rec)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(err / scale < 1e-12, "n={n} rel err {}", err / scale);
        }
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        let e = dpotrf_raw(2, &mut a, 2);
        assert!(e.is_err());
        assert_eq!(e.unwrap_err().pivot, 1);
    }

    #[test]
    fn trsm_rltn_inverts_panel_update() {
        let mut rng = Pcg64::seed_from_u64(14);
        let n = 24;
        let m = 40;
        let mut l = rand_spd(&mut rng, n);
        dpotrf_raw(n, &mut l, n).unwrap();
        let x = rand_mat(&mut rng, m, n);
        // B = X * L^T  =>  trsm(B) == X
        let mut b = vec![0.0; m * n];
        dgemm_raw(Trans::N, Trans::T, m, n, n, 1.0, &x, m, &l, n, 0.0, &mut b, m);
        // but L has garbage upper; zero it for the multiply oracle
        // (dgemm used it) — redo with cleaned L.
        for j in 0..n {
            for i in 0..j {
                l[i + j * n] = 0.0;
            }
        }
        let mut b2 = vec![0.0; m * n];
        dgemm_raw(Trans::N, Trans::T, m, n, n, 1.0, &x, m, &l, n, 0.0, &mut b2, m);
        dtrsm_rltn_raw(m, n, &l, n, &mut b2, m);
        let err = b2
            .iter()
            .zip(&x)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-10, "err={err}");
    }

    #[test]
    fn trsm_llnn_and_lltn_solve() {
        let mut rng = Pcg64::seed_from_u64(15);
        let n = 30;
        let mut l = rand_spd(&mut rng, n);
        dpotrf_raw(n, &mut l, n).unwrap();
        for j in 0..n {
            for i in 0..j {
                l[i + j * n] = 0.0;
            }
        }
        let x = rand_mat(&mut rng, n, 3);
        // b = L x; solve gives x back.
        let mut b = vec![0.0; n * 3];
        dgemm_raw(Trans::N, Trans::N, n, 3, n, 1.0, &l, n, &x, n, 0.0, &mut b, n);
        dtrsm_llnn_raw(n, 3, &l, n, &mut b, n);
        let err = b.iter().zip(&x).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10);
        // b = L^T x; lltn solve gives x back.
        let mut b = vec![0.0; n * 3];
        dgemm_raw(Trans::T, Trans::N, n, 3, n, 1.0, &l, n, &x, n, 0.0, &mut b, n);
        dtrsm_lltn_raw(n, 3, &l, n, &mut b, n);
        let err = b.iter().zip(&x).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10);
    }

    #[test]
    fn gemv_matches_matvec() {
        let mut rng = Pcg64::seed_from_u64(16);
        let (m, n) = (13, 9);
        let a = rand_mat(&mut rng, m, n);
        let x = rand_mat(&mut rng, n, 1);
        let mut y = vec![0.0; m];
        dgemv_raw(Trans::N, m, n, 1.0, &a, m, &x, 0.0, &mut y);
        for i in 0..m {
            let mut acc = 0.0;
            for j in 0..n {
                acc += a[i + j * m] * x[j];
            }
            assert!((y[i] - acc).abs() < 1e-12);
        }
        // transposed
        let xt = rand_mat(&mut rng, m, 1);
        let mut yt = vec![0.0; n];
        dgemv_raw(Trans::T, m, n, 2.0, &a, m, &xt, 0.0, &mut yt);
        for j in 0..n {
            let mut acc = 0.0;
            for i in 0..m {
                acc += a[i + j * m] * xt[i];
            }
            assert!((yt[j] - 2.0 * acc).abs() < 1e-12);
        }
    }

    #[test]
    fn trmv_inverts_trsv() {
        let mut rng = Pcg64::seed_from_u64(17);
        let n = 20;
        let mut l = rand_spd(&mut rng, n);
        dpotrf_raw(n, &mut l, n).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y = x.clone();
        dtrmv_ln(n, &l, n, &mut y); // y = L x
        dtrsv_ln(n, &l, n, &mut y); // back to x
        let err = y.iter().zip(&x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-11, "{err}");
    }

    #[test]
    fn potrf_logdet_matches_known() {
        // diag(4, 9) => logdet = ln 36
        let mut a = Matrix::zeros(2, 2);
        a[(0, 0)] = 4.0;
        a[(1, 1)] = 9.0;
        let ld = dpotrf(&mut a).unwrap();
        assert!((ld - 36f64.ln()).abs() < 1e-14);
    }
}
