//! Column-major dense matrix type used throughout the linear-algebra
//! substrate (the "Chameleon analogue" — see DESIGN.md §4).
//!
//! Storage is column-major (`a[i + j*ld]`) to match LAPACK conventions and
//! the tile layout used by the tiled Cholesky.

use std::fmt;

/// Dense column-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero-filled `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            data: vec![0.0; rows * cols],
            rows,
            cols,
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a column-major slice.
    pub fn from_col_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix {
            data: data.to_vec(),
            rows,
            cols,
        }
    }

    /// Build from a row-major slice (convenience for tests / literals).
    pub fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = data[i * cols + j];
            }
        }
        m
    }

    /// Build element-wise from a function.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Column-major backing slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Column `j` as a slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Transpose (out of place).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self * other` using the optimized gemm kernel.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut c = Matrix::zeros(self.rows, other.cols);
        super::blas::dgemm(
            false,
            false,
            1.0,
            self,
            other,
            0.0,
            &mut c,
        );
        c
    }

    /// Matrix-vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        let mut y = vec![0.0; self.rows];
        for j in 0..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                let col = self.col(j);
                for i in 0..self.rows {
                    y[i] += col[i] * xj;
                }
            }
        }
        y
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Copy a rectangular block `src[si.., sj..]` of shape `(h, w)` into
    /// `self` at `(di, dj)`.
    pub fn copy_block(
        &mut self,
        di: usize,
        dj: usize,
        src: &Matrix,
        si: usize,
        sj: usize,
        h: usize,
        w: usize,
    ) {
        for j in 0..w {
            for i in 0..h {
                self[(di + i, dj + j)] = src[(si + i, sj + j)];
            }
        }
    }

    /// Symmetrize in place from the lower triangle (used after generating
    /// only the lower half of a covariance matrix).
    pub fn symmetrize_from_lower(&mut self) {
        assert!(self.is_square());
        for j in 0..self.cols {
            for i in 0..j {
                self.data[i + j * self.rows] = self.data[j + i * self.rows];
            }
        }
    }

    /// Zero the strict upper triangle (used to produce an L factor view).
    pub fn zero_upper(&mut self) {
        assert!(self.is_square());
        for j in 1..self.cols {
            for i in 0..j.min(self.rows) {
                self.data[i + j * self.rows] = 0.0;
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i + j * self.rows]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i + j * self.rows]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>12.5e} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > cmax { "..." } else { "" })?;
        }
        if self.rows > rmax {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_round_trip() {
        let mut m = Matrix::zeros(3, 4);
        m[(2, 3)] = 7.5;
        assert_eq!(m[(2, 3)], 7.5);
        assert_eq!(m.as_slice()[2 + 3 * 3], 7.5);
    }

    #[test]
    fn from_row_major_matches_index() {
        let m = Matrix::from_row_major(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        assert_eq!(m[(1, 2)], 6.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(5, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn matvec_identity() {
        let m = Matrix::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn matmul_small_oracle() {
        let a = Matrix::from_row_major(2, 2, &[1., 2., 3., 4.]);
        let b = Matrix::from_row_major(2, 2, &[5., 6., 7., 8.]);
        let c = a.matmul(&b);
        let expect = Matrix::from_row_major(2, 2, &[19., 22., 43., 50.]);
        assert!(c.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn symmetrize() {
        let mut m = Matrix::from_fn(3, 3, |i, j| if i >= j { (i + j) as f64 } else { -99.0 });
        m.symmetrize_from_lower();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
    }

    #[test]
    fn fro_norm() {
        let m = Matrix::from_row_major(2, 2, &[3., 0., 0., 4.]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-14);
    }
}
