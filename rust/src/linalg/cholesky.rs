//! Tiled Cholesky factorization and triangular solves, expressed as
//! sequential task flows over a [`TileMatrix`] — the computational core of
//! ExaGeoStat's exact MLE (Abdulah et al. 2018a, Alg. 1).
//!
//! The right-looking tiled algorithm emits the classic POTRF/TRSM/SYRK/GEMM
//! DAG; an optional tile bandwidth restricts updates to a band of tiles,
//! which is exactly the Diagonal-Super-Tile (DST) approximation of Fig 1(b).

use super::blas::{
    dgemv_f32a, dgemv_raw, dpotrf_raw, dtrsm_rltn_raw, dtrsv_ln, gemm_mp, syrk_ln_mp,
    trsm_rltn_mp, MatMut, MatRef, Trans,
};
use super::tile::{TileMatrix, TileVector};
use crate::scheduler::{Access, Handle, TaskGraph, TaskKind};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

/// Scheduler handles for the lower tiles of a [`TileMatrix`].
pub struct TileHandles {
    nt: usize,
    h: Vec<Handle>,
}

impl TileHandles {
    pub fn register(g: &mut TaskGraph, nt: usize) -> Self {
        TileHandles {
            nt,
            h: g.register_many(nt * (nt + 1) / 2),
        }
    }
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> Handle {
        debug_assert!(i >= j && i < self.nt);
        self.h[i * (i + 1) / 2 + j]
    }
    pub fn nt(&self) -> usize {
        self.nt
    }
}

/// Shared failure slot: holds `pivot + 1` of the first non-SPD pivot, or 0.
pub type FailFlag = Arc<AtomicI64>;

pub fn new_fail_flag() -> FailFlag {
    Arc::new(AtomicI64::new(0))
}

/// Check a fail flag after graph execution.
pub fn check_fail(flag: &FailFlag) -> Result<(), crate::linalg::blas::NotSpd> {
    let v = flag.load(Ordering::Acquire);
    if v == 0 {
        Ok(())
    } else {
        Err(crate::linalg::blas::NotSpd {
            pivot: (v - 1) as usize,
        })
    }
}

/// Is tile (i, j) inside the retained band? `band = None` means dense
/// (exact); `band = Some(b)` keeps tiles with `i - j <= b` (DST: `b = 0` is
/// diagonal-only, `b = 1` matches Fig 1(b)'s "two-diagonal tiles").
#[inline]
pub fn in_band(band: Option<usize>, i: usize, j: usize) -> bool {
    match band {
        None => true,
        Some(b) => i - j <= b, // callers guarantee i >= j
    }
}

/// Submit the tiled (optionally band-restricted) lower Cholesky of `a`
/// in place.  On a non-SPD pivot the fail flag records the global pivot
/// index; downstream tasks still run (NaNs propagate harmlessly) and the
/// caller checks the flag after execution.
///
/// Tiles are dispatched on their **storage precision**: an all-f64
/// matrix takes exactly the plain kernel paths, while a mixed-precision
/// matrix ([`TileMatrix::zeros_mp`]) routes every task touching an
/// f32-stored off-band tile through the f32 compute kernels
/// (`gemm_mp` / `syrk_ln_mp` / `trsm_rltn_mp`) — the MP variant's
/// half-width arithmetic on the off-band bulk.  Diagonal tiles are
/// always f64, so POTRF itself is unchanged.
pub fn submit_tiled_potrf(
    g: &mut TaskGraph,
    a: &TileMatrix,
    hs: &TileHandles,
    band: Option<usize>,
    fail: &FailFlag,
) {
    let nt = a.nt();
    let ts = a.ts();
    for k in 0..nt {
        let hk = a.tile_rows(k);
        // POTRF on diagonal tile (k, k)
        {
            let p = a.tile_ptr(k, k);
            let fail = fail.clone();
            let pivot_base = (k * ts) as i64;
            g.submit(
                TaskKind::POTRF,
                &[(hs.at(k, k), Access::RW)],
                a.tile_bytes_at(k, k),
                move || {
                    // SAFETY: STF ordering gives exclusive access.
                    let t = unsafe { p.as_mut() };
                    if let Err(e) = dpotrf_raw(hk, t, hk) {
                        let _ = fail.compare_exchange(
                            0,
                            pivot_base + e.pivot as i64 + 1,
                            Ordering::AcqRel,
                            Ordering::Relaxed,
                        );
                    }
                },
            );
        }
        // Panel TRSMs
        for i in k + 1..nt {
            if !in_band(band, i, k) {
                continue;
            }
            let hi = a.tile_rows(i);
            let l = a.tile_ptr(k, k);
            let b = a.tile_ptr(i, k);
            g.submit(
                TaskKind::TRSM,
                &[(hs.at(k, k), Access::R), (hs.at(i, k), Access::RW)],
                a.tile_bytes_at(k, k) + a.tile_bytes_at(i, k),
                move || {
                    // SAFETY: STF ordering.  Diagonal factors are always
                    // f64; the panel tile may be an MP off-band f32 tile.
                    let lt = unsafe { l.as_ref() };
                    match unsafe { b.mat_mut() } {
                        MatMut::F64(bt) => dtrsm_rltn_raw(hi, hk, lt, hk, bt, hi),
                        MatMut::F32(bt) => trsm_rltn_mp(hi, hk, lt, hk, bt, hi),
                    }
                },
            );
        }
        // Trailing updates
        for i in k + 1..nt {
            if !in_band(band, i, k) {
                continue;
            }
            let hi = a.tile_rows(i);
            // SYRK on diagonal (i, i)
            {
                let src = a.tile_ptr(i, k);
                let dst = a.tile_ptr(i, i);
                g.submit(
                    TaskKind::SYRK,
                    &[(hs.at(i, k), Access::R), (hs.at(i, i), Access::RW)],
                    a.tile_bytes_at(i, k) + a.tile_bytes_at(i, i),
                    move || {
                        // SAFETY: STF ordering.  syrk_ln_mp fast-paths
                        // the all-f64 case to dsyrk_ln_raw itself; an
                        // f32 panel source (MP) takes the mixed kernels.
                        let s = unsafe { src.mat_ref() };
                        let d = unsafe { dst.mat_mut() };
                        syrk_ln_mp(hi, hk, -1.0, s, hi, 1.0, d, hi);
                    },
                );
            }
            // GEMMs on (i, j), k < j < i
            for j in k + 1..i {
                if !in_band(band, i, j) || !in_band(band, j, k) {
                    continue;
                }
                let hj = a.tile_rows(j);
                let ai = a.tile_ptr(i, k);
                let aj = a.tile_ptr(j, k);
                let c = a.tile_ptr(i, j);
                g.submit(
                    TaskKind::GEMM,
                    &[
                        (hs.at(i, k), Access::R),
                        (hs.at(j, k), Access::R),
                        (hs.at(i, j), Access::RW),
                    ],
                    a.tile_bytes_at(i, k) + a.tile_bytes_at(j, k) + a.tile_bytes_at(i, j),
                    move || {
                        // SAFETY: STF ordering.  gemm_mp fast-paths the
                        // all-f64 case to dgemm_raw itself; any f32
                        // operand (MP off-band tile) routes the product
                        // through the f32 micro-kernel path.
                        let a_ = unsafe { ai.mat_ref() };
                        let b_ = unsafe { aj.mat_ref() };
                        let c_ = unsafe { c.mat_mut() };
                        gemm_mp(Trans::N, Trans::T, hi, hj, hk, -1.0, a_, hi, b_, hj, 1.0, c_, hi);
                    },
                );
            }
        }
    }
}

/// Submit the tiled forward substitution `y <- L^{-1} y` against the factor
/// produced by [`submit_tiled_potrf`] (same band).
pub fn submit_tiled_forward_solve(
    g: &mut TaskGraph,
    l: &TileMatrix,
    hs: &TileHandles,
    y: &TileVector,
    yh: &[Handle],
) {
    submit_tiled_forward_solve_banded(g, l, hs, y, yh, None)
}

/// Band-aware forward substitution (zero tiles outside the band are
/// skipped — they contribute nothing).
pub fn submit_tiled_forward_solve_banded(
    g: &mut TaskGraph,
    l: &TileMatrix,
    hs: &TileHandles,
    y: &TileVector,
    yh: &[Handle],
    band: Option<usize>,
) {
    let nt = l.nt();
    for i in 0..nt {
        let hi = l.tile_rows(i);
        for j in 0..i {
            if !in_band(band, i, j) {
                continue;
            }
            let wj = l.tile_cols(j);
            let lij = l.tile_ptr(i, j);
            let yj = y.seg_ptr(j);
            let yi = y.seg_ptr(i);
            g.submit(
                TaskKind::GEMM,
                &[
                    (hs.at(i, j), Access::R),
                    (yh[j], Access::R),
                    (yh[i], Access::RW),
                ],
                l.tile_bytes_at(i, j),
                move || {
                    // SAFETY: STF ordering.  Off-band factor tiles may
                    // be f32-stored (MP); vector segments are f64.
                    let yjs = unsafe { yj.as_ref() };
                    let yis = unsafe { yi.as_mut() };
                    match unsafe { lij.mat_ref() } {
                        MatRef::F64(lt) => {
                            dgemv_raw(Trans::N, hi, wj, -1.0, lt, hi, yjs, 1.0, yis);
                        }
                        MatRef::F32(lt) => {
                            dgemv_f32a(hi, wj, -1.0, lt, hi, yjs, yis);
                        }
                    }
                },
            );
        }
        let lii = l.tile_ptr(i, i);
        let yi = y.seg_ptr(i);
        g.submit(
            TaskKind::TRSM,
            &[(hs.at(i, i), Access::R), (yh[i], Access::RW)],
            l.tile_bytes_at(i, i),
            move || {
                // SAFETY: STF ordering.
                let lt = unsafe { lii.as_ref() };
                let ys = unsafe { yi.as_mut() };
                dtrsv_ln(hi, lt, hi, ys);
            },
        );
    }
}

/// Dense-path convenience: factor, forward-solve and return
/// `(logdet, L^{-1} z)` — used by the baselines and small-problem paths.
pub fn dense_chol_solve(
    sigma: &mut crate::linalg::matrix::Matrix,
    z: &[f64],
) -> Result<(f64, Vec<f64>), crate::linalg::blas::NotSpd> {
    let logdet = crate::linalg::blas::dpotrf(sigma)?;
    let n = sigma.rows();
    let mut y = z.to_vec();
    dtrsv_ln(n, sigma.as_slice(), n, &mut y);
    Ok((logdet, y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::rng::Pcg64;
    use crate::scheduler::pool::{self, Policy};

    fn rand_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = Matrix::zeros(n, n);
        crate::linalg::blas::dgemm(false, true, 1.0, &b, &b, 0.0, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    fn tiled_factor(a: &Matrix, ts: usize, workers: usize, policy: Policy) -> TileMatrix {
        let tm = TileMatrix::from_dense_lower(a, ts);
        let mut g = TaskGraph::new();
        let hs = TileHandles::register(&mut g, tm.nt());
        let fail = new_fail_flag();
        submit_tiled_potrf(&mut g, &tm, &hs, None, &fail);
        pool::run(&mut g, workers, policy);
        check_fail(&fail).unwrap();
        tm
    }

    #[test]
    fn tiled_potrf_matches_dense_over_shapes() {
        let mut rng = Pcg64::seed_from_u64(31);
        for &(n, ts) in &[(8usize, 4usize), (16, 4), (30, 7), (64, 16), (100, 32), (33, 40)] {
            let a = rand_spd(&mut rng, n);
            let mut dense = a.clone();
            crate::linalg::blas::dpotrf(&mut dense).unwrap();
            dense.zero_upper();
            let tm = tiled_factor(&a, ts, 4, Policy::Lws);
            let lt = tm.to_dense_lower();
            let err = lt.max_abs_diff(&dense);
            assert!(err < 1e-10, "n={n} ts={ts}: err {err}");
        }
    }

    #[test]
    fn tiled_potrf_all_policies_agree() {
        let mut rng = Pcg64::seed_from_u64(32);
        let a = rand_spd(&mut rng, 48);
        let reference = tiled_factor(&a, 16, 1, Policy::Eager).to_dense_lower();
        for policy in [Policy::Eager, Policy::Prio, Policy::Lws, Policy::Random] {
            for workers in [2usize, 4, 8] {
                let tm = tiled_factor(&a, 16, workers, policy);
                let err = tm.to_dense_lower().max_abs_diff(&reference);
                assert!(err < 1e-12, "{policy:?} {workers}w: {err}");
            }
        }
    }

    #[test]
    fn tiled_potrf_detects_non_spd() {
        // indefinite matrix: flag must trip with a sensible pivot
        let n = 12;
        let mut a = Matrix::eye(n);
        a[(6, 6)] = -1.0;
        let tm = TileMatrix::from_dense_lower(&a, 4);
        let mut g = TaskGraph::new();
        let hs = TileHandles::register(&mut g, tm.nt());
        let fail = new_fail_flag();
        submit_tiled_potrf(&mut g, &tm, &hs, None, &fail);
        pool::run(&mut g, 2, Policy::Lws);
        let err = check_fail(&fail).unwrap_err();
        assert_eq!(err.pivot, 6);
    }

    #[test]
    fn forward_solve_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(33);
        let n = 50;
        let ts = 16;
        let a = rand_spd(&mut rng, n);
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        // dense reference
        let mut dense = a.clone();
        let (_ld, yref) = dense_chol_solve(&mut dense, &z).unwrap();

        // tiled
        let tm = TileMatrix::from_dense_lower(&a, ts);
        let mut g = TaskGraph::new();
        let hs = TileHandles::register(&mut g, tm.nt());
        let fail = new_fail_flag();
        submit_tiled_potrf(&mut g, &tm, &hs, None, &fail);
        let tv = TileVector::from_slice(&z, ts);
        let yh = g.register_many(tv.nt());
        submit_tiled_forward_solve(&mut g, &tm, &hs, &tv, &yh);
        pool::run(&mut g, 4, Policy::Prio);
        check_fail(&fail).unwrap();

        let y = tv.to_vec();
        let err = y
            .iter()
            .zip(&yref)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-10, "err {err}");
    }

    #[test]
    fn band_restriction_skips_far_tiles() {
        // With band = Some(0) only diagonal tiles factor; far tiles remain
        // whatever they were (they are ignored by the banded solve).
        let mut rng = Pcg64::seed_from_u64(34);
        let n = 32;
        let ts = 8;
        let a = rand_spd(&mut rng, n);
        let tm = TileMatrix::from_dense_lower(&a, ts);
        let before_far = tm.tile(3, 0).to_vec();
        let mut g = TaskGraph::new();
        let hs = TileHandles::register(&mut g, tm.nt());
        let fail = new_fail_flag();
        submit_tiled_potrf(&mut g, &tm, &hs, Some(0), &fail);
        pool::run(&mut g, 2, Policy::Lws);
        check_fail(&fail).unwrap();
        assert_eq!(tm.tile(3, 0).to_vec(), before_far, "far tile untouched");
        // diagonal blocks factored: each equals dense potrf of the block
        for t in 0..tm.nt() {
            let h = tm.tile_rows(t);
            let mut blk = Matrix::from_fn(h, h, |i, j| {
                let (gi, gj) = (t * ts + i, t * ts + j);
                if gi >= gj {
                    a[(gi, gj)]
                } else {
                    a[(gj, gi)]
                }
            });
            crate::linalg::blas::dpotrf(&mut blk).unwrap();
            for lj in 0..h {
                for li in lj..h {
                    let got = tm.tile(t, t)[li + lj * h];
                    assert!((got - blk[(li, lj)]).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn mp_storage_factors_at_f32_scale() {
        // A mixed-precision tile matrix (off-band tiles stored f32, their
        // updates running the f32 compute kernels) must reproduce the
        // dense f64 factor to f32-scale accuracy — and diagonal tiles
        // must stay genuinely f64.
        let mut rng = Pcg64::seed_from_u64(35);
        let n = 48;
        let ts = 8;
        let a = rand_spd(&mut rng, n);
        let mut dense = a.clone();
        crate::linalg::blas::dpotrf(&mut dense).unwrap();
        dense.zero_upper();

        let mut tm = TileMatrix::zeros_mp(n, ts, 0);
        for gi in 0..n {
            for gj in 0..=gi {
                tm.set(gi, gj, a[(gi, gj)]);
            }
        }
        assert!(tm.tile_is_f32(2, 0) && !tm.tile_is_f32(1, 1));
        let mut g = TaskGraph::new();
        let hs = TileHandles::register(&mut g, tm.nt());
        let fail = new_fail_flag();
        submit_tiled_potrf(&mut g, &tm, &hs, None, &fail);
        pool::run(&mut g, 2, Policy::Lws);
        check_fail(&fail).unwrap();

        let lt = tm.to_dense_lower();
        let scale = dense
            .as_slice()
            .iter()
            .map(|v| v.abs())
            .fold(1.0, f64::max);
        let err = lt.max_abs_diff(&dense);
        assert!(err / scale < 1e-4, "rel err {}", err / scale);
        assert!(err > 0.0, "f32 path should not be bit-exact");
    }

    #[test]
    fn in_band_predicate() {
        assert!(in_band(None, 10, 0));
        assert!(in_band(Some(2), 5, 3));
        assert!(!in_band(Some(1), 5, 3));
        assert!(in_band(Some(0), 4, 4));
    }
}
