//! Tile storage: the data layout ExaGeoStat's task-based algorithms operate
//! on (Fig 1 of the paper).  A symmetric `n x n` matrix is split into
//! `nt x nt` tiles of size `ts` (edge tiles are smaller); only the lower
//! triangle of tiles is stored.  Each tile is a contiguous column-major
//! buffer — one scheduler data handle per tile.
//!
//! Tiles carry a **storage precision**: ordinarily every tile is f64, but
//! a mixed-precision matrix ([`TileMatrix::zeros_mp`]) stores its off-band
//! tiles as genuine f32 buffers — half the memory traffic, and the tiled
//! Cholesky routes their updates through the f32 micro-kernel path
//! (`linalg::blas::gemm_mp`), which is what makes the MP variant of
//! Fig 1(d) a measured speedup rather than a simulated rounding.
//!
//! Tiles also carry a **residency**: a matrix built with
//! [`TileMatrix::zeros_spill`] keeps its buffers in a budget-bounded
//! [`TileStore`] that spills cold tiles to an unlinked temp file and
//! faults them back in on [`TileStore::pin`] — the out-of-core layer
//! that lets one machine factor a covariance whose dense tile set
//! exceeds RAM (the ExaGeoStat out-of-core regime of arxiv 1708.02835).
//! Eviction is plan-aware rather than LRU: the executor feeds each
//! tile's next-use step from the `ExecutionPlan`, so the store evicts
//! the tile it will need *latest* (Belady's rule on the known schedule)
//! and drops finished panels without a write-out.  The ordinary
//! resident path never touches the store — `store` is `None` and every
//! accessor compiles to exactly the pre-spill code.

use crate::linalg::blas::{MatMut, MatRef};
use crate::linalg::matrix::Matrix;
use crate::scheduler::faults;
use std::cell::Cell;
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

thread_local! {
    /// Per-thread count of [`TileMatrix`] buffer allocations — the
    /// testkit telemetry behind the allocation-regression tests that
    /// guard `EvalSession`'s workspace-reuse invariant (warm optimizer
    /// iterations must construct zero new tile matrices).  Thread-local
    /// so parallel tests cannot perturb each other's counts; sessions
    /// allocate on the calling thread, never inside worker tasks.
    static TILE_MATRIX_ALLOCS: Cell<u64> = Cell::new(0);
}

/// Number of `TileMatrix` allocations performed by the current thread.
pub fn tile_matrix_allocs() -> u64 {
    TILE_MATRIX_ALLOCS.with(|c| c.get())
}

/// Process-wide spill/prefetch telemetry (the out-of-core analogue of
/// `pack_buffer_allocs`): tests assert these stay flat on the resident
/// path and move under a tiny budget.  Global atomics, not thread-local —
/// the prefetch I/O lane runs on its own thread and must land in the
/// same counters as the executor's demand faults.
static TILE_SPILL_WRITES: AtomicU64 = AtomicU64::new(0);
static TILE_SPILL_READS: AtomicU64 = AtomicU64::new(0);
static TILE_PREFETCHES: AtomicU64 = AtomicU64::new(0);

/// Tiles written out to the spill file (evictions of live data).
pub fn tile_spill_writes() -> u64 {
    TILE_SPILL_WRITES.load(Ordering::Relaxed)
}
/// Tiles read back from the spill file (demand faults + prefetches).
pub fn tile_spill_reads() -> u64 {
    TILE_SPILL_READS.load(Ordering::Relaxed)
}
/// Tiles brought resident ahead of use by the prefetch I/O lane.
pub fn tile_prefetches() -> u64 {
    TILE_PREFETCHES.load(Ordering::Relaxed)
}

/// Parse a human-friendly byte budget: a plain integer with an optional
/// `K`/`M`/`G` (or `KB`/`MB`/`GB`) suffix, case-insensitive.  `"0"`,
/// `"off"`, `"none"` and `"unbounded"` — and anything unparseable —
/// mean *no budget* (`None`), i.e. the fully-resident fast path.
pub fn parse_budget(s: &str) -> Option<usize> {
    let t = s.trim();
    if t.is_empty() {
        return None;
    }
    match t.to_ascii_lowercase().as_str() {
        "0" | "off" | "none" | "unbounded" => return None,
        _ => {}
    }
    let mut digits = t;
    let mut mult = 1usize;
    if let Some(rest) = digits.strip_suffix(['b', 'B']) {
        digits = rest;
    }
    if let Some(rest) = digits.strip_suffix(['k', 'K']) {
        digits = rest;
        mult = 1 << 10;
    } else if let Some(rest) = digits.strip_suffix(['m', 'M']) {
        digits = rest;
        mult = 1 << 20;
    } else if let Some(rest) = digits.strip_suffix(['g', 'G']) {
        digits = rest;
        mult = 1 << 30;
    }
    digits
        .trim()
        .parse::<usize>()
        .ok()
        .map(|v| v.saturating_mul(mult))
        .filter(|&v| v > 0)
}

/// The `EXAGEOSTAT_TILE_BUDGET` environment knob (bytes, suffixes per
/// [`parse_budget`]): the peak-resident ceiling every budgeted
/// `TileMatrix` workspace is built with.  Unset/off = fully resident.
pub fn tile_budget_from_env() -> Option<usize> {
    std::env::var("EXAGEOSTAT_TILE_BUDGET")
        .ok()
        .and_then(|v| parse_budget(&v))
}

/// The mixed-precision storage rule, in one place: is lower tile
/// (i, j), `i >= j`, kept in full precision under `band`?
/// [`TileMatrix::zeros_mp`] allocates by this predicate and
/// `likelihood::mp::is_f64_tile` delegates to it, so the workspace
/// layout and the MP variant's semantics cannot drift apart.
#[inline]
pub fn mp_tile_is_f64(band: usize, i: usize, j: usize) -> bool {
    i - j <= band
}

/// One tile's storage, in its precision.
enum TileBuf {
    F64(Box<[f64]>),
    F32(Box<[f32]>),
}

/// Raw pointer to a tile buffer that tasks capture, tagged with the
/// tile's storage precision.
///
/// SAFETY: the scheduler's STF dependency inference guarantees that a
/// writer has exclusive access and readers never overlap a writer, so
/// aliased `&mut` access cannot occur at runtime.  The pointee (the
/// `TileMatrix`) outlives graph execution because every submission path
/// waits on its `JobHandle` before the storage goes out of scope (the
/// handle also waits on `Drop` — see `scheduler::runtime`).
#[derive(Copy, Clone)]
pub enum TilePtr {
    /// Full-precision tile.
    F64 {
        /// Base pointer of the column-major buffer.
        ptr: *mut f64,
        /// Buffer length in elements.
        len: usize,
    },
    /// Demoted (MP off-band) tile.
    F32 {
        /// Base pointer of the column-major buffer.
        ptr: *mut f32,
        /// Buffer length in elements.
        len: usize,
    },
}

unsafe impl Send for TilePtr {}
unsafe impl Sync for TilePtr {}

impl TilePtr {
    /// Borrow as a mutable f64 slice (the common, all-f64 paths).
    ///
    /// # Panics
    /// Panics on an f32-stored tile — precision-aware tasks use
    /// [`TilePtr::mat_mut`] instead.
    ///
    /// # Safety
    /// Caller must guarantee exclusive access for the duration of the
    /// borrow (the scheduler provides this via dependency ordering).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn as_mut(&self) -> &mut [f64] {
        match *self {
            TilePtr::F64 { ptr, len } => std::slice::from_raw_parts_mut(ptr, len),
            TilePtr::F32 { .. } => panic!("TilePtr::as_mut on an f32-stored tile"),
        }
    }

    /// Borrow as a shared f64 slice.
    ///
    /// # Panics
    /// Panics on an f32-stored tile — see [`TilePtr::mat_ref`].
    ///
    /// # Safety
    /// Caller must guarantee no concurrent writer (scheduler-provided).
    pub unsafe fn as_ref(&self) -> &[f64] {
        match *self {
            TilePtr::F64 { ptr, len } => std::slice::from_raw_parts(ptr, len),
            TilePtr::F32 { .. } => panic!("TilePtr::as_ref on an f32-stored tile"),
        }
    }

    /// Precision-tagged shared borrow (the MP-aware task bodies).
    ///
    /// # Safety
    /// Caller must guarantee no concurrent writer (scheduler-provided).
    pub unsafe fn mat_ref(&self) -> MatRef<'_> {
        match *self {
            TilePtr::F64 { ptr, len } => MatRef::F64(std::slice::from_raw_parts(ptr, len)),
            TilePtr::F32 { ptr, len } => MatRef::F32(std::slice::from_raw_parts(ptr, len)),
        }
    }

    /// Precision-tagged mutable borrow (the MP-aware task bodies).
    ///
    /// # Safety
    /// Caller must guarantee exclusive access (scheduler-provided).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn mat_mut(&self) -> MatMut<'_> {
        match *self {
            TilePtr::F64 { ptr, len } => MatMut::F64(std::slice::from_raw_parts_mut(ptr, len)),
            TilePtr::F32 { ptr, len } => MatMut::F32(std::slice::from_raw_parts_mut(ptr, len)),
        }
    }

    /// Buffer length in elements.
    pub fn len(&self) -> usize {
        match *self {
            TilePtr::F64 { len, .. } | TilePtr::F32 { len, .. } => len,
        }
    }

    /// Is the buffer empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Is this an f32-stored (MP off-band) tile?
    pub fn is_f32(&self) -> bool {
        matches!(self, TilePtr::F32 { .. })
    }

    /// A placeholder for pointer tables whose real entries are installed
    /// per-task by the out-of-core executor.  Well-aligned, zero-length,
    /// never dereferenced before being overwritten by a pinned pointer.
    pub fn dangling() -> TilePtr {
        TilePtr::F64 {
            ptr: std::ptr::NonNull::dangling().as_ptr(),
            len: 0,
        }
    }
}

/// Residency of one [`TileStore`] slot.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
enum SlotState {
    /// No data anywhere: either never materialized, or dropped after its
    /// last plan use.  Pinning materializes zeros.
    Empty,
    /// Buffer in memory; counted against the budget.
    Resident,
    /// The prefetch lane is reading this tile off disk; counted against
    /// the budget already.  Pinners wait on the store's condvar.
    Loading,
    /// Data lives in the spill file at the slot's fixed offset.
    Spilled,
}

/// `next_use` value for a tile with no known upcoming use but live data
/// (the default outside plan execution): evictable, but must spill.
const NEXT_USE_FAR: u64 = u64::MAX - 1;
/// `next_use` value for a tile the plan never reads again: dropped on
/// eviction/unpin without a write-out (eager panel release).
const NEXT_USE_DEAD: u64 = u64::MAX;

struct Slot {
    /// Tile data when `Resident`; an empty boxed slice otherwise.
    buf: TileBuf,
    state: SlotState,
    /// In-flight task references: a pinned slot is never evicted, so
    /// running kernels cannot fault mid-operation.
    pins: u32,
    /// Elements in the tile (rows * cols).
    elems: usize,
    /// Storage precision (fixed at construction by the MP band rule).
    f32_tile: bool,
    /// Resident footprint in bytes (`elems` * element width).
    bytes: usize,
    /// Fixed byte offset in the spill file.
    offset: u64,
    /// Plan step of the next use ([`NEXT_USE_FAR`] = unknown,
    /// [`NEXT_USE_DEAD`] = never again).  Eviction picks the maximum.
    next_use: u64,
}

fn empty_buf(f32_tile: bool) -> TileBuf {
    if f32_tile {
        TileBuf::F32(Vec::new().into_boxed_slice())
    } else {
        TileBuf::F64(Vec::new().into_boxed_slice())
    }
}

fn alloc_buf(elems: usize, f32_tile: bool) -> TileBuf {
    if f32_tile {
        TileBuf::F32(vec![0.0f32; elems].into_boxed_slice())
    } else {
        TileBuf::F64(vec![0.0f64; elems].into_boxed_slice())
    }
}

/// Raw byte view of a tile buffer for spill-file I/O.  f64/f32 → u8
/// reinterpretation is always valid and round-trips bit-exactly.
fn buf_bytes(buf: &TileBuf) -> &[u8] {
    unsafe {
        match buf {
            TileBuf::F64(t) => std::slice::from_raw_parts(t.as_ptr() as *const u8, t.len() * 8),
            TileBuf::F32(t) => std::slice::from_raw_parts(t.as_ptr() as *const u8, t.len() * 4),
        }
    }
}

fn buf_bytes_mut(buf: &mut TileBuf) -> &mut [u8] {
    unsafe {
        match buf {
            TileBuf::F64(t) => {
                std::slice::from_raw_parts_mut(t.as_mut_ptr() as *mut u8, t.len() * 8)
            }
            TileBuf::F32(t) => {
                std::slice::from_raw_parts_mut(t.as_mut_ptr() as *mut u8, t.len() * 4)
            }
        }
    }
}

fn tile_ptr_of(buf: &TileBuf) -> TilePtr {
    match buf {
        TileBuf::F64(t) => TilePtr::F64 {
            ptr: t.as_ptr() as *mut f64,
            len: t.len(),
        },
        TileBuf::F32(t) => TilePtr::F32 {
            ptr: t.as_ptr() as *mut f32,
            len: t.len(),
        },
    }
}

struct StoreInner {
    slots: Vec<Slot>,
    resident_bytes: usize,
    peak_resident_bytes: usize,
}

/// Budget-bounded backing store for an out-of-core [`TileMatrix`].
///
/// Slots are addressed by the matrix's lower-triangular linear index
/// (`i * (i + 1) / 2 + j`).  The protocol the executor follows:
///
/// 1. [`TileStore::pin`] every tile a task touches (the pointer is
///    stable until the matching [`TileStore::unpin`] — pinned slots are
///    never evicted, so kernels cannot fault mid-operation).
/// 2. Run the task's ops.
/// 3. [`TileStore::set_next_use`] each tile from the plan schedule
///    (`None` = last use just happened), then [`TileStore::unpin`].
///
/// Eviction (inside `pin`, when materializing would exceed the budget)
/// picks the unpinned resident slot with the **greatest** `next_use` —
/// Belady's offline rule, exact here because the plan is the future.
/// Dead tiles are dropped without a write-out.  A budgeted matrix
/// therefore does *not* retain its factor after execution: dropped
/// slots read back as zeros.  Every consumer (log-det, solve) runs
/// inside the plan, so nothing outside tests ever re-reads the factor.
///
/// [`TileStore::prefetch`] (called from the executor's dedicated I/O
/// thread) brings a spilled tile resident ahead of use when there is
/// headroom, overlapping disk reads with compute.
pub struct TileStore {
    /// Peak-resident ceiling in bytes (clamped at construction to
    /// [`TileStore::MIN_TILES`] full tiles).
    budget: usize,
    /// One full-size f64 tile in bytes (`ts * ts * 8`).
    tile_bytes: usize,
    /// Unlinked spill file: pread/pwrite at fixed per-slot offsets.
    file: File,
    inner: Mutex<StoreInner>,
    /// Wakes pinners blocked on a `Loading` slot.
    loaded: Condvar,
}

impl TileStore {
    /// Minimum budget, in full-size tiles.  A task pins at most three
    /// tiles (the Gemm operand set) and the single-lane prefetcher may
    /// hold one more `Loading`; with one tile of slack on each side the
    /// store can always honor a pin without exceeding the budget, so
    /// `peak_resident_bytes() <= budget()` is an invariant, not a goal.
    pub const MIN_TILES: usize = 6;

    fn new(slots: Vec<Slot>, ts: usize, budget_bytes: usize) -> std::io::Result<TileStore> {
        let tile_bytes = ts * ts * std::mem::size_of::<f64>();
        static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "exageostat-spill-{}-{}.bin",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let file = File::options()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        // Unlink immediately: on unix the open fd keeps the storage
        // alive, and the spill data can never outlive the process.
        std::fs::remove_file(&path)?;
        Ok(TileStore {
            budget: budget_bytes.max(Self::MIN_TILES * tile_bytes),
            tile_bytes,
            file,
            inner: Mutex::new(StoreInner {
                slots,
                resident_bytes: 0,
                peak_resident_bytes: 0,
            }),
            loaded: Condvar::new(),
        })
    }

    /// Effective budget in bytes (after the [`TileStore::MIN_TILES`]
    /// clamp).
    pub fn budget(&self) -> usize {
        self.budget
    }
    /// Bytes currently resident (including `Loading` reservations).
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }
    /// High-water mark of [`TileStore::resident_bytes`] over the store's
    /// lifetime — the number the budget bounds.
    pub fn peak_resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().peak_resident_bytes
    }

    /// Pin slot `idx` resident and return its (stable-until-unpin)
    /// pointer, reading spilled data back from disk.  An I/O failure
    /// (disk or injected — see `scheduler::faults`) leaves the slot
    /// spilled and the store consistent; the error propagates to the
    /// executor as `TaskError::Io` instead of aborting the process.
    pub fn pin(&self, idx: usize) -> std::io::Result<TilePtr> {
        self.pin_impl(idx, true)
    }

    /// [`TileStore::pin`] for a tile whose first touched op fully
    /// overwrites it (a `Generate`): materializes zeros without reading
    /// stale spilled data back — half the I/O on warm re-evaluations.
    pub fn pin_for_write(&self, idx: usize) -> std::io::Result<TilePtr> {
        self.pin_impl(idx, false)
    }

    /// One spill-file read with the fault-injection hook and a bounded
    /// retry: spill reads are idempotent (the on-disk bytes are
    /// immutable between write-out and the next write-out), so a
    /// transient failure is retried up to the shared task-retry budget
    /// before propagating.
    fn read_slot(&self, buf: &mut [u8], offset: u64, site: &'static str) -> std::io::Result<()> {
        let budget = faults::task_retry_limit();
        let mut attempt = 0usize;
        loop {
            let res =
                faults::maybe_io_error(site).and_then(|()| self.file.read_exact_at(buf, offset));
            match res {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if attempt < budget {
                        attempt += 1;
                        faults::note_task_retry();
                        continue;
                    }
                    return Err(e);
                }
            }
        }
    }

    fn pin_impl(&self, idx: usize, read_back: bool) -> std::io::Result<TilePtr> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            match inner.slots[idx].state {
                SlotState::Loading => inner = self.loaded.wait(inner).unwrap(),
                SlotState::Resident => break,
                s @ (SlotState::Empty | SlotState::Spilled) => {
                    let need = inner.slots[idx].bytes;
                    self.make_room(&mut inner, need, idx)?;
                    let slot = &mut inner.slots[idx];
                    let mut buf = alloc_buf(slot.elems, slot.f32_tile);
                    if read_back && s == SlotState::Spilled {
                        // Error path: the slot is still `Spilled` and
                        // `resident_bytes` untouched — a later pin can
                        // retry cleanly.
                        self.read_slot(buf_bytes_mut(&mut buf), slot.offset, "spill read")?;
                        TILE_SPILL_READS.fetch_add(1, Ordering::Relaxed);
                    }
                    slot.buf = buf;
                    slot.state = SlotState::Resident;
                    inner.resident_bytes += need;
                    inner.peak_resident_bytes =
                        inner.peak_resident_bytes.max(inner.resident_bytes);
                    break;
                }
            }
        }
        let slot = &mut inner.slots[idx];
        slot.pins += 1;
        Ok(tile_ptr_of(&slot.buf))
    }

    /// Release one pin.  A slot whose last use has passed
    /// (`set_next_use(_, None)`) is dropped here the moment its last pin
    /// goes — the eager finished-panel release of the left-looking sweep.
    pub fn unpin(&self, idx: usize) {
        let mut inner = self.inner.lock().unwrap();
        let slot = &mut inner.slots[idx];
        debug_assert!(slot.pins > 0, "unpin without pin (slot {idx})");
        slot.pins -= 1;
        if slot.pins == 0 && slot.next_use == NEXT_USE_DEAD && slot.state == SlotState::Resident {
            slot.buf = empty_buf(slot.f32_tile);
            slot.state = SlotState::Empty;
            let bytes = slot.bytes;
            inner.resident_bytes -= bytes;
        }
    }

    /// Record slot `idx`'s next plan step (`None` = the plan never
    /// touches it again).  Dead unpinned residents are dropped on the
    /// spot; the executor normally calls this while still holding the
    /// pin, deferring the drop to [`TileStore::unpin`].
    pub fn set_next_use(&self, idx: usize, step: Option<u64>) {
        let mut inner = self.inner.lock().unwrap();
        let slot = &mut inner.slots[idx];
        slot.next_use = step.unwrap_or(NEXT_USE_DEAD);
        if slot.next_use == NEXT_USE_DEAD
            && slot.pins == 0
            && slot.state == SlotState::Resident
        {
            slot.buf = empty_buf(slot.f32_tile);
            slot.state = SlotState::Empty;
            let bytes = slot.bytes;
            inner.resident_bytes -= bytes;
        }
    }

    /// Bring a spilled slot resident ahead of use, from the dedicated
    /// I/O lane.  Only proceeds with two full tiles of headroom below
    /// the budget (never evicts, never blocks the executor beyond the
    /// brief slot-state flip), and reads the file **outside** the lock
    /// so demand pins of other tiles proceed concurrently.  Returns
    /// whether a read was started.  On a read failure the `Loading`
    /// reservation is rolled back (slot returns to `Spilled`, bytes
    /// un-reserved, waiters woken) and the error propagates — the
    /// prefetch lane forwards it to the executor, which stops cleanly.
    pub fn prefetch(&self, idx: usize) -> std::io::Result<bool> {
        let (elems, f32_tile, offset, need);
        {
            let mut inner = self.inner.lock().unwrap();
            let slot = &inner.slots[idx];
            if slot.state != SlotState::Spilled {
                return Ok(false);
            }
            need = slot.bytes;
            if inner.resident_bytes + need + 2 * self.tile_bytes > self.budget {
                return Ok(false);
            }
            (elems, f32_tile, offset) = (slot.elems, slot.f32_tile, slot.offset);
            inner.slots[idx].state = SlotState::Loading;
            inner.resident_bytes += need;
            inner.peak_resident_bytes = inner.peak_resident_bytes.max(inner.resident_bytes);
        }
        let mut buf = alloc_buf(elems, f32_tile);
        if let Err(e) = self.read_slot(buf_bytes_mut(&mut buf), offset, "prefetch read") {
            // Roll the reservation back under the lock so a demand pin
            // blocked on `Loading` wakes and retries the read itself.
            let mut inner = self.inner.lock().unwrap();
            let slot = &mut inner.slots[idx];
            debug_assert_eq!(slot.state, SlotState::Loading);
            slot.state = SlotState::Spilled;
            inner.resident_bytes -= need;
            self.loaded.notify_all();
            return Err(e);
        }
        TILE_SPILL_READS.fetch_add(1, Ordering::Relaxed);
        TILE_PREFETCHES.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let slot = &mut inner.slots[idx];
        debug_assert_eq!(slot.state, SlotState::Loading);
        slot.buf = buf;
        slot.state = SlotState::Resident;
        self.loaded.notify_all();
        Ok(true)
    }

    /// One spill-file write with the fault-injection hook and the same
    /// bounded retry as [`TileStore::read_slot`] (write-out of a
    /// resident buffer is idempotent).
    fn write_slot(&self, buf: &[u8], offset: u64) -> std::io::Result<()> {
        let budget = faults::task_retry_limit();
        let mut attempt = 0usize;
        loop {
            let res = faults::maybe_io_error("spill write")
                .and_then(|()| self.file.write_all_at(buf, offset));
            match res {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if attempt < budget {
                        attempt += 1;
                        faults::note_task_retry();
                        continue;
                    }
                    return Err(e);
                }
            }
        }
    }

    /// Evict until `need` more bytes fit, skipping `keep` and anything
    /// pinned or loading.  Victim = greatest `next_use` (Belady).  If
    /// everything left is pinned/loading the pin proceeds anyway — the
    /// [`TileStore::MIN_TILES`] clamp sizes the budget so that worst
    /// case still lands under it.  A failed write-out leaves the victim
    /// resident (nothing lost) and propagates the error.
    fn make_room(&self, inner: &mut StoreInner, need: usize, keep: usize) -> std::io::Result<()> {
        while inner.resident_bytes + need > self.budget {
            let mut victim: Option<(usize, u64)> = None;
            for (i, s) in inner.slots.iter().enumerate() {
                if i == keep || s.pins != 0 || s.state != SlotState::Resident {
                    continue;
                }
                let farther = match victim {
                    None => true,
                    Some((_, nu)) => s.next_use > nu,
                };
                if farther {
                    victim = Some((i, s.next_use));
                }
            }
            let Some((v, _)) = victim else { break };
            let slot = &mut inner.slots[v];
            if slot.next_use != NEXT_USE_DEAD {
                self.write_slot(buf_bytes(&slot.buf), slot.offset)?;
                TILE_SPILL_WRITES.fetch_add(1, Ordering::Relaxed);
                slot.state = SlotState::Spilled;
            } else {
                // Dead by the schedule: the value is never read again,
                // so dropping beats a wasted write-out.
                slot.state = SlotState::Empty;
            }
            slot.buf = empty_buf(slot.f32_tile);
            let bytes = slot.bytes;
            inner.resident_bytes -= bytes;
        }
        Ok(())
    }
}

/// Lower-triangular tile storage for a symmetric matrix.
pub struct TileMatrix {
    n: usize,
    ts: usize,
    nt: usize,
    /// `Some(band)` for mixed-precision storage: tiles with
    /// `i - j > band` are f32.  `None` = every tile f64.
    mp_band: Option<usize>,
    /// Lower tiles, indexed by `tri_index(i, j)` for `i >= j`.  Empty in
    /// out-of-core mode, where `store` owns the slots instead.
    tiles: Vec<TileBuf>,
    /// `Some` for a budget-bounded out-of-core matrix
    /// ([`TileMatrix::zeros_spill`]); `None` = fully resident.
    store: Option<TileStore>,
}

impl TileMatrix {
    /// Allocate a zeroed tile matrix for an `n x n` symmetric matrix with
    /// tile size `ts`.  Every tile is f64.
    pub fn zeros(n: usize, ts: usize) -> Self {
        Self::zeros_with(n, ts, None)
    }

    /// Allocate a zeroed **mixed-precision** tile matrix: tiles within
    /// `band` of the diagonal (`i - j <= band`) are f64, the rest are
    /// stored as f32 (`likelihood::mp::is_f64_tile` is the same rule).
    pub fn zeros_mp(n: usize, ts: usize, band: usize) -> Self {
        Self::zeros_with(n, ts, Some(band))
    }

    fn zeros_with(n: usize, ts: usize, mp_band: Option<usize>) -> Self {
        assert!(n > 0 && ts > 0);
        TILE_MATRIX_ALLOCS.with(|c| c.set(c.get() + 1));
        let nt = n.div_ceil(ts);
        let mut tiles = Vec::with_capacity(nt * (nt + 1) / 2);
        for i in 0..nt {
            for j in 0..=i {
                let h = Self::dim_at(n, ts, i);
                let w = Self::dim_at(n, ts, j);
                let f32_tile = match mp_band {
                    Some(band) => !mp_tile_is_f64(band, i, j),
                    None => false,
                };
                tiles.push(if f32_tile {
                    TileBuf::F32(vec![0.0f32; h * w].into_boxed_slice())
                } else {
                    TileBuf::F64(vec![0.0f64; h * w].into_boxed_slice())
                });
            }
        }
        TileMatrix {
            n,
            ts,
            nt,
            mp_band,
            tiles,
            store: None,
        }
    }

    /// Allocate an **out-of-core** tile matrix: no tile is materialized
    /// up front, and at most `budget_bytes` of tiles (clamped up to
    /// [`TileStore::MIN_TILES`] full tiles) are ever resident at once —
    /// the rest live in an unlinked spill file.  `mp_band` selects
    /// mixed-precision storage exactly as [`TileMatrix::zeros_mp`].
    ///
    /// Such a matrix is executed by the serial plan-order spill sweep in
    /// `pipeline::run_tiled` (which branches on [`TileMatrix::store`]);
    /// direct buffer accessors ([`TileMatrix::tile`],
    /// [`TileMatrix::tile_ptr`], …) panic, while element-level
    /// [`TileMatrix::get`]/[`TileMatrix::set`] pin through the store.
    pub fn zeros_spill(
        n: usize,
        ts: usize,
        mp_band: Option<usize>,
        budget_bytes: usize,
    ) -> std::io::Result<Self> {
        assert!(n > 0 && ts > 0);
        TILE_MATRIX_ALLOCS.with(|c| c.set(c.get() + 1));
        let nt = n.div_ceil(ts);
        let mut slots = Vec::with_capacity(nt * (nt + 1) / 2);
        let mut offset = 0u64;
        for i in 0..nt {
            for j in 0..=i {
                let elems = Self::dim_at(n, ts, i) * Self::dim_at(n, ts, j);
                let f32_tile = match mp_band {
                    Some(band) => !mp_tile_is_f64(band, i, j),
                    None => false,
                };
                let bytes = elems * if f32_tile { 4 } else { 8 };
                slots.push(Slot {
                    buf: empty_buf(f32_tile),
                    state: SlotState::Empty,
                    pins: 0,
                    elems,
                    f32_tile,
                    bytes,
                    offset,
                    next_use: NEXT_USE_FAR,
                });
                offset += bytes as u64;
            }
        }
        Ok(TileMatrix {
            n,
            ts,
            nt,
            mp_band,
            tiles: Vec::new(),
            store: Some(TileStore::new(slots, ts, budget_bytes)?),
        })
    }

    /// The out-of-core backing store, if this matrix is budgeted.
    pub fn store(&self) -> Option<&TileStore> {
        self.store.as_ref()
    }

    /// Linear store-slot index of lower tile (i, j) — the index
    /// [`TileStore`] methods take.
    pub fn slot_index(&self, i: usize, j: usize) -> usize {
        self.tri_index(i, j)
    }

    #[inline]
    fn dim_at(n: usize, ts: usize, i: usize) -> usize {
        ts.min(n - i * ts)
    }

    /// Matrix dimension.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }
    /// Tile size.
    #[inline]
    pub fn ts(&self) -> usize {
        self.ts
    }
    /// Number of tile rows/cols.
    #[inline]
    pub fn nt(&self) -> usize {
        self.nt
    }
    /// Mixed-precision band this matrix was allocated with (`None` for
    /// all-f64 storage).
    #[inline]
    pub fn mp_band(&self) -> Option<usize> {
        self.mp_band
    }
    /// Height (= local leading dimension) of tile row `i`.
    #[inline]
    pub fn tile_rows(&self, i: usize) -> usize {
        Self::dim_at(self.n, self.ts, i)
    }
    /// Width of tile column `j`.
    #[inline]
    pub fn tile_cols(&self, j: usize) -> usize {
        Self::dim_at(self.n, self.ts, j)
    }

    /// Linear index of lower tile (i, j), i >= j.
    #[inline]
    fn tri_index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i >= j && i < self.nt, "lower tile ({i},{j})");
        i * (i + 1) / 2 + j
    }

    /// Is tile (i, j) stored in f32?  Decided by the MP band rule, so it
    /// answers identically for resident and out-of-core matrices.
    pub fn tile_is_f32(&self, i: usize, j: usize) -> bool {
        debug_assert!(i >= j && i < self.nt, "lower tile ({i},{j})");
        match self.mp_band {
            Some(band) => !mp_tile_is_f64(band, i, j),
            None => false,
        }
    }

    #[inline]
    fn assert_resident(&self, what: &str) {
        assert!(
            self.store.is_none(),
            "{what} on an out-of-core TileMatrix: tiles are not directly \
             addressable; pin through store() or run via the spill executor"
        );
    }

    /// Borrow f64 tile (i, j), i >= j.  Panics on an f32-stored tile
    /// (use [`TileMatrix::tile_f32`]) and on an out-of-core matrix.
    pub fn tile(&self, i: usize, j: usize) -> &[f64] {
        self.assert_resident("tile()");
        match &self.tiles[self.tri_index(i, j)] {
            TileBuf::F64(t) => t,
            TileBuf::F32(_) => panic!("tile ({i},{j}) is f32-stored; use tile_f32"),
        }
    }

    /// Borrow f32 tile (i, j).  Panics on an f64-stored tile and on an
    /// out-of-core matrix.
    pub fn tile_f32(&self, i: usize, j: usize) -> &[f32] {
        self.assert_resident("tile_f32()");
        match &self.tiles[self.tri_index(i, j)] {
            TileBuf::F32(t) => t,
            TileBuf::F64(_) => panic!("tile ({i},{j}) is f64-stored; use tile"),
        }
    }

    /// Mutably borrow f64 tile (i, j), i >= j.  Panics on an f32 tile
    /// and on an out-of-core matrix.
    pub fn tile_mut(&mut self, i: usize, j: usize) -> &mut [f64] {
        self.assert_resident("tile_mut()");
        let idx = self.tri_index(i, j);
        match &mut self.tiles[idx] {
            TileBuf::F64(t) => t,
            TileBuf::F32(_) => panic!("tile ({i},{j}) is f32-stored; use tile_f32"),
        }
    }

    /// Raw pointer for task capture (precision-tagged).  Panics on an
    /// out-of-core matrix, whose pointers are only stable while pinned —
    /// use [`TileStore::pin`] via [`TileMatrix::store`].
    pub fn tile_ptr(&self, i: usize, j: usize) -> TilePtr {
        self.assert_resident("tile_ptr()");
        let idx = self.tri_index(i, j);
        match &self.tiles[idx] {
            TileBuf::F64(t) => TilePtr::F64 {
                ptr: t.as_ptr() as *mut f64,
                len: t.len(),
            },
            TileBuf::F32(t) => TilePtr::F32 {
                ptr: t.as_ptr() as *mut f32,
                len: t.len(),
            },
        }
    }

    /// Element access (symmetric: (i, j) with i < j reads the mirrored
    /// lower entry; f32 tiles are promoted).  For tests and small-scale
    /// assembly only.
    pub fn get(&self, gi: usize, gj: usize) -> f64 {
        let (gi, gj) = if gi >= gj { (gi, gj) } else { (gj, gi) };
        let (ti, li) = (gi / self.ts, gi % self.ts);
        let (tj, lj) = (gj / self.ts, gj % self.ts);
        let h = self.tile_rows(ti);
        let idx = self.tri_index(ti, tj);
        if let Some(st) = &self.store {
            // Test/assembly-only accessor: a disk error here has no
            // recovery seam, so it stays fatal.
            let p = st.pin(idx).expect("tile spill read (element get)");
            // SAFETY: the pin keeps the buffer alive and unshared with
            // any writer for the duration of this read.
            let v = unsafe {
                match p.mat_ref() {
                    MatRef::F64(t) => t[li + lj * h],
                    MatRef::F32(t) => t[li + lj * h] as f64,
                }
            };
            st.unpin(idx);
            return v;
        }
        match &self.tiles[idx] {
            TileBuf::F64(t) => t[li + lj * h],
            TileBuf::F32(t) => t[li + lj * h] as f64,
        }
    }

    /// Set an element (mirrored into the lower triangle; demoted on an
    /// f32 tile).
    pub fn set(&mut self, gi: usize, gj: usize, v: f64) {
        let (gi, gj) = if gi >= gj { (gi, gj) } else { (gj, gi) };
        let (ti, li) = (gi / self.ts, gi % self.ts);
        let (tj, lj) = (gj / self.ts, gj % self.ts);
        let h = self.tile_rows(ti);
        let idx = self.tri_index(ti, tj);
        if let Some(st) = &self.store {
            let p = st.pin(idx).expect("tile spill read (element set)");
            // SAFETY: exclusive access — `&mut self` plus the pin.
            unsafe {
                match p.mat_mut() {
                    MatMut::F64(t) => t[li + lj * h] = v,
                    MatMut::F32(t) => t[li + lj * h] = v as f32,
                }
            }
            st.unpin(idx);
            return;
        }
        match &mut self.tiles[idx] {
            TileBuf::F64(t) => t[li + lj * h] = v,
            TileBuf::F32(t) => t[li + lj * h] = v as f32,
        }
    }

    /// Import the lower triangle of a dense symmetric matrix (all-f64
    /// storage).
    pub fn from_dense_lower(m: &Matrix, ts: usize) -> Self {
        assert!(m.is_square());
        let n = m.rows();
        let mut tm = TileMatrix::zeros(n, ts);
        for ti in 0..tm.nt {
            for tj in 0..=ti {
                let h = tm.tile_rows(ti);
                let w = tm.tile_cols(tj);
                let idx = tm.tri_index(ti, tj);
                let TileBuf::F64(tile) = &mut tm.tiles[idx] else {
                    unreachable!("zeros() allocates f64 tiles only");
                };
                for lj in 0..w {
                    for li in 0..h {
                        let gi = ti * ts + li;
                        let gj = tj * ts + lj;
                        // lower access (gi >= gj guaranteed except inside
                        // diagonal tiles where we mirror)
                        tile[li + lj * h] = if gi >= gj { m[(gi, gj)] } else { m[(gj, gi)] };
                    }
                }
            }
        }
        tm
    }

    /// Export to a dense matrix (symmetrized).  Tests / small scale only.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for gi in 0..self.n {
            for gj in 0..=gi {
                let v = self.get(gi, gj);
                m[(gi, gj)] = v;
                m[(gj, gi)] = v;
            }
        }
        m
    }

    /// Export the lower-triangular factor (upper forced to zero), as after
    /// an in-place tiled Cholesky.
    pub fn to_dense_lower(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for gi in 0..self.n {
            for gj in 0..=gi {
                m[(gi, gj)] = self.get(gi, gj);
            }
        }
        m
    }

    /// Sum of `f` over diagonal elements (e.g. log-determinant terms).
    pub fn diag_sum(&self, f: impl Fn(f64) -> f64) -> f64 {
        (0..self.n).map(|i| f(self.get(i, i))).sum()
    }

    /// Total bytes of one full-size f64 tile (the legacy uniform cost
    /// hint; for precision-aware per-tile costs use
    /// [`TileMatrix::tile_bytes_at`]).
    pub fn tile_bytes(&self) -> usize {
        self.ts * self.ts * std::mem::size_of::<f64>()
    }

    /// Bytes of tile (i, j)'s actual storage — f32 tiles are half-width,
    /// so MP task cost hints and the DES transfer model see the variant's
    /// real (halved) off-band memory traffic.
    pub fn tile_bytes_at(&self, i: usize, j: usize) -> usize {
        let elems = self.tile_rows(i) * self.tile_cols(j);
        if self.tile_is_f32(i, j) {
            elems * std::mem::size_of::<f32>()
        } else {
            elems * std::mem::size_of::<f64>()
        }
    }
}

/// A vector split into `ts`-sized segments aligned with a [`TileMatrix`].
pub struct TileVector {
    /// Total length.
    pub n: usize,
    /// Segment size (matches the tile size of the paired matrix).
    pub ts: usize,
    segs: Vec<Box<[f64]>>,
}

impl TileVector {
    /// Split `x` into `ts`-sized segments.
    pub fn from_slice(x: &[f64], ts: usize) -> Self {
        let n = x.len();
        let nt = n.div_ceil(ts);
        let segs = (0..nt)
            .map(|i| {
                let lo = i * ts;
                let hi = n.min(lo + ts);
                x[lo..hi].to_vec().into_boxed_slice()
            })
            .collect();
        TileVector { n, ts, segs }
    }

    /// Number of segments.
    pub fn nt(&self) -> usize {
        self.segs.len()
    }
    /// Refill the segments from `x` without reallocating (workspace reuse
    /// across optimizer iterations; `x` must have the original length).
    pub fn load(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.n, "TileVector::load length mismatch");
        for (i, s) in self.segs.iter_mut().enumerate() {
            let lo = i * self.ts;
            s.copy_from_slice(&x[lo..lo + s.len()]);
        }
    }
    /// Borrow segment `i`.
    pub fn seg(&self, i: usize) -> &[f64] {
        &self.segs[i]
    }
    /// Mutably borrow segment `i`.
    pub fn seg_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.segs[i]
    }
    /// Raw pointer to segment `i` for task capture (always f64).
    pub fn seg_ptr(&self, i: usize) -> TilePtr {
        TilePtr::F64 {
            ptr: self.segs[i].as_ptr() as *mut f64,
            len: self.segs[i].len(),
        }
    }
    /// Concatenate back into one vector.
    pub fn to_vec(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n);
        for s in &self.segs {
            out.extend_from_slice(s);
        }
        out
    }
    /// Squared Euclidean norm.
    pub fn dot_self(&self) -> f64 {
        self.segs
            .iter()
            .flat_map(|s| s.iter())
            .map(|v| v * v)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::scheduler::faults::FaultPlan;

    #[test]
    fn tile_dims_with_edges() {
        let tm = TileMatrix::zeros(10, 4); // tiles: 4,4,2
        assert_eq!(tm.nt(), 3);
        assert_eq!(tm.tile_rows(0), 4);
        assert_eq!(tm.tile_rows(2), 2);
        assert_eq!(tm.tile(2, 1).len(), 2 * 4);
        assert_eq!(tm.tile(2, 2).len(), 4);
        assert_eq!(tm.mp_band(), None);
    }

    #[test]
    fn dense_round_trip() {
        let mut rng = Pcg64::seed_from_u64(21);
        let n = 23;
        let mut m = Matrix::from_fn(n, n, |_, _| rng.normal());
        // make symmetric
        let mt = m.transpose();
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] = 0.5 * (m[(i, j)] + mt[(i, j)]);
            }
        }
        let tm = TileMatrix::from_dense_lower(&m, 5);
        let back = tm.to_dense();
        assert!(m.max_abs_diff(&back) < 1e-15);
    }

    #[test]
    fn get_set_symmetric() {
        let mut tm = TileMatrix::zeros(7, 3);
        tm.set(1, 5, 4.25); // upper -> stored mirrored
        assert_eq!(tm.get(1, 5), 4.25);
        assert_eq!(tm.get(5, 1), 4.25);
    }

    #[test]
    fn diag_sum_logdet_form() {
        let mut tm = TileMatrix::zeros(4, 2);
        for i in 0..4 {
            tm.set(i, i, (i + 1) as f64);
        }
        let want: f64 = (1..=4).map(|v| (v as f64).ln()).sum();
        assert!((tm.diag_sum(f64::ln) - want).abs() < 1e-14);
    }

    #[test]
    fn tile_vector_segments() {
        let x: Vec<f64> = (0..11).map(|v| v as f64).collect();
        let tv = TileVector::from_slice(&x, 4);
        assert_eq!(tv.nt(), 3);
        assert_eq!(tv.seg(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(tv.seg(2), &[8.0, 9.0, 10.0]);
        assert_eq!(tv.to_vec(), x);
        let ds: f64 = x.iter().map(|v| v * v).sum();
        assert!((tv.dot_self() - ds).abs() < 1e-12);
    }

    #[test]
    fn tile_vector_load_reuses_segments() {
        let x: Vec<f64> = (0..11).map(|v| v as f64).collect();
        let mut tv = TileVector::from_slice(&x, 4);
        let y: Vec<f64> = (0..11).map(|v| (v * v) as f64).collect();
        tv.load(&y);
        assert_eq!(tv.to_vec(), y);
    }

    #[test]
    fn alloc_counter_tracks_this_thread() {
        let before = tile_matrix_allocs();
        let _a = TileMatrix::zeros(8, 4);
        let _b = TileMatrix::zeros_mp(8, 4, 0);
        assert_eq!(tile_matrix_allocs(), before + 2);
    }

    #[test]
    fn tile_ptr_round_trip() {
        let tm = TileMatrix::zeros(4, 2);
        let p = tm.tile_ptr(1, 0);
        assert!(!p.is_f32());
        unsafe {
            p.as_mut()[0] = 3.5;
        }
        assert_eq!(tm.tile(1, 0)[0], 3.5);
        assert_eq!(tm.get(2, 0), 3.5);
    }

    #[test]
    fn mp_layout_demotes_off_band_tiles_only() {
        // 4 tile rows, band 1: tiles with i - j > 1 are f32.
        let tm = TileMatrix::zeros_mp(16, 4, 1);
        assert_eq!(tm.mp_band(), Some(1));
        for i in 0..tm.nt() {
            for j in 0..=i {
                assert_eq!(tm.tile_is_f32(i, j), i - j > 1, "({i},{j})");
            }
        }
        // Full band: no tile demoted, equivalent to zeros() layout.
        let full = TileMatrix::zeros_mp(16, 4, 3);
        for i in 0..full.nt() {
            for j in 0..=i {
                assert!(!full.tile_is_f32(i, j));
            }
        }
    }

    #[test]
    fn mp_get_set_round_through_f32() {
        let mut tm = TileMatrix::zeros_mp(16, 4, 0);
        let v = 1.0 + 1e-12; // not representable in f32
        tm.set(12, 1, v); // far off-band tile (3,0): f32
        assert_eq!(tm.get(12, 1), 1.0, "stored through f32");
        tm.set(1, 2, v); // diagonal tile: f64
        assert_eq!(tm.get(1, 2), v);
    }

    #[test]
    fn parse_budget_suffixes_and_off_words() {
        assert_eq!(parse_budget("4096"), Some(4096));
        assert_eq!(parse_budget("16K"), Some(16 << 10));
        assert_eq!(parse_budget("2m"), Some(2 << 20));
        assert_eq!(parse_budget("1GB"), Some(1 << 30));
        assert_eq!(parse_budget(" 8kb "), Some(8 << 10));
        assert_eq!(parse_budget("0"), None);
        assert_eq!(parse_budget("off"), None);
        assert_eq!(parse_budget("Unbounded"), None);
        assert_eq!(parse_budget(""), None);
        assert_eq!(parse_budget("lots"), None);
    }

    #[test]
    fn spill_round_trip_preserves_data_and_respects_budget() {
        // 10 tile rows of ts=4 → 55 tiles; budget clamps to 6 tiles
        // (768 B), far below the ~14 KB dense set, so sets/gets churn
        // through the spill file.
        let mut tm = TileMatrix::zeros_spill(40, 4, None, 1).unwrap();
        let st_budget = tm.store().unwrap().budget();
        assert_eq!(st_budget, TileStore::MIN_TILES * 4 * 4 * 8);
        let w0 = tile_spill_writes();
        for i in 0..40 {
            for j in 0..=i {
                tm.set(i, j, (i * 40 + j) as f64 + 0.5);
            }
        }
        for i in 0..40 {
            for j in 0..=i {
                assert_eq!(tm.get(i, j), (i * 40 + j) as f64 + 0.5, "({i},{j})");
            }
        }
        let st = tm.store().unwrap();
        assert!(tile_spill_writes() > w0, "tiny budget must force spills");
        assert!(st.peak_resident_bytes() <= st.budget());
        assert!(st.resident_bytes() <= st.budget());
    }

    #[test]
    fn store_pin_protocol_and_dead_release() {
        let tm = TileMatrix::zeros_spill(8, 4, None, 1 << 20).unwrap();
        let st = tm.store().unwrap();
        let idx = tm.slot_index(1, 0);
        let p = st.pin(idx).unwrap();
        unsafe { p.as_mut()[0] = 7.0 };
        // Double pin returns the same buffer.
        let p2 = st.pin(idx).unwrap();
        assert_eq!(unsafe { p2.as_ref()[0] }, 7.0);
        st.unpin(idx);
        // Mark dead while still pinned: the drop happens at last unpin.
        st.set_next_use(idx, None);
        let before = st.resident_bytes();
        st.unpin(idx);
        assert!(st.resident_bytes() < before, "dead tile released eagerly");
        // A dead tile re-pins as zeros (never written out).
        assert_eq!(tm.get(4, 0), 0.0);
    }

    #[test]
    fn store_prefetch_restores_spilled_tile() {
        // Budget of exactly the clamp: 6 full tiles resident max.
        let tm = TileMatrix::zeros_spill(48, 4, None, 1).unwrap();
        let st = tm.store().unwrap();
        // Touch every diagonal tile; early ones spill.
        let nt = tm.nt();
        for t in 0..nt {
            let p = st.pin(tm.slot_index(t, t)).unwrap();
            unsafe { p.as_mut()[0] = t as f64 + 1.0 };
            st.unpin(tm.slot_index(t, t));
        }
        let idx = tm.slot_index(0, 0);
        // (0,0) must be spilled by now; prefetch requires headroom, so
        // release the budget first by marking late tiles dead.
        for t in 3..nt {
            st.set_next_use(tm.slot_index(t, t), None);
        }
        let pf0 = tile_prefetches();
        assert!(st.prefetch(idx).unwrap(), "spilled tile with headroom prefetches");
        assert!(!st.prefetch(idx).unwrap(), "already resident: prefetch declines");
        assert_eq!(tile_prefetches(), pf0 + 1);
        assert_eq!(tm.get(0, 0), 1.0, "prefetched data intact");
    }

    #[test]
    fn injected_io_fault_surfaces_as_error_and_store_recovers() {
        let _guard = faults::fault_test_lock();
        faults::set_fault_plan(None);
        faults::set_task_retry_override(Some(0));
        // Tiny budget: pinning every diagonal tile forces write-outs.
        let tm = TileMatrix::zeros_spill(48, 4, None, 1).unwrap();
        let st = tm.store().unwrap();
        for t in 0..tm.nt() {
            let p = st.pin(tm.slot_index(t, t)).unwrap();
            unsafe { p.as_mut()[0] = t as f64 + 1.0 };
            st.unpin(tm.slot_index(t, t));
        }
        // Arm a certain I/O fault with no retry budget: the next demand
        // read of a spilled tile must fail with a typed error...
        faults::set_fault_plan(FaultPlan::parse("io:1"));
        let idx = tm.slot_index(0, 0);
        let err = st.pin(idx).unwrap_err();
        assert!(err.to_string().contains("injected i/o fault"), "{err}");
        // ...leaving the slot spilled and consistent: disarm and the
        // same pin succeeds with the original data intact.
        faults::set_fault_plan(None);
        faults::set_task_retry_override(None);
        let p = st.pin(idx).unwrap();
        assert_eq!(unsafe { p.as_ref()[0] }, 1.0, "data survives the fault");
        st.unpin(idx);
        assert!(st.resident_bytes() <= st.budget());
    }

    #[test]
    fn spill_read_retry_rides_out_transient_io_faults() {
        let _guard = faults::fault_test_lock();
        faults::set_fault_plan(None);
        let tm = TileMatrix::zeros_spill(48, 4, None, 1).unwrap();
        let st = tm.store().unwrap();
        for t in 0..tm.nt() {
            let p = st.pin(tm.slot_index(t, t)).unwrap();
            unsafe { p.as_mut()[0] = t as f64 + 1.0 };
            st.unpin(tm.slot_index(t, t));
        }
        // Certain fault but one retry: the retry redraws the stream, so
        // with rate 1.0 it fails even with retries — use a high budget
        // against a certain fault to prove the *bounded* give-up, and a
        // zero rate to prove the retry path is not taken when clean.
        faults::set_task_retry_override(Some(2));
        faults::set_fault_plan(FaultPlan::parse("io:1"));
        let r0 = faults::tasks_retried();
        let idx = tm.slot_index(0, 0);
        assert!(st.pin(idx).is_err(), "certain fault exhausts the budget");
        assert_eq!(faults::tasks_retried(), r0 + 2, "both retries consumed");
        faults::set_fault_plan(None);
        faults::set_task_retry_override(None);
        assert_eq!(unsafe { st.pin(idx).unwrap().as_ref()[0] }, 1.0);
        st.unpin(idx);
    }

    #[test]
    fn out_of_core_direct_accessors_panic() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let tm = TileMatrix::zeros_spill(8, 4, None, 1 << 20).unwrap();
        assert!(catch_unwind(AssertUnwindSafe(|| tm.tile_ptr(0, 0))).is_err());
        assert!(catch_unwind(AssertUnwindSafe(|| tm.tile(0, 0))).is_err());
    }

    #[test]
    fn mp_spill_layout_matches_resident_rule() {
        let tm = TileMatrix::zeros_spill(16, 4, Some(1), 1 << 20).unwrap();
        assert_eq!(tm.mp_band(), Some(1));
        for i in 0..tm.nt() {
            for j in 0..=i {
                assert_eq!(tm.tile_is_f32(i, j), i - j > 1, "({i},{j})");
            }
        }
        let mut tm = tm;
        let v = 1.0 + 1e-12;
        tm.set(12, 1, v); // off-band: stored f32 even out-of-core
        assert_eq!(tm.get(12, 1), 1.0);
    }

    #[test]
    fn mp_tile_ptr_mat_mut_round_trip() {
        let tm = TileMatrix::zeros_mp(16, 4, 0);
        let p = tm.tile_ptr(2, 0);
        assert!(p.is_f32());
        match unsafe { p.mat_mut() } {
            MatMut::F32(s) => s[0] = 2.5,
            MatMut::F64(_) => panic!("expected f32 tile"),
        }
        assert_eq!(tm.tile_f32(2, 0)[0], 2.5);
        assert_eq!(tm.get(8, 0), 2.5);
        match unsafe { p.mat_ref() } {
            MatRef::F32(s) => assert_eq!(s[0], 2.5),
            MatRef::F64(_) => panic!("expected f32 tile"),
        }
    }
}
