//! Tile storage: the data layout ExaGeoStat's task-based algorithms operate
//! on (Fig 1 of the paper).  A symmetric `n x n` matrix is split into
//! `nt x nt` tiles of size `ts` (edge tiles are smaller); only the lower
//! triangle of tiles is stored.  Each tile is a contiguous column-major
//! buffer — one scheduler data handle per tile.
//!
//! Tiles carry a **storage precision**: ordinarily every tile is f64, but
//! a mixed-precision matrix ([`TileMatrix::zeros_mp`]) stores its off-band
//! tiles as genuine f32 buffers — half the memory traffic, and the tiled
//! Cholesky routes their updates through the f32 micro-kernel path
//! (`linalg::blas::gemm_mp`), which is what makes the MP variant of
//! Fig 1(d) a measured speedup rather than a simulated rounding.

use crate::linalg::blas::{MatMut, MatRef};
use crate::linalg::matrix::Matrix;
use std::cell::Cell;

thread_local! {
    /// Per-thread count of [`TileMatrix`] buffer allocations — the
    /// testkit telemetry behind the allocation-regression tests that
    /// guard `EvalSession`'s workspace-reuse invariant (warm optimizer
    /// iterations must construct zero new tile matrices).  Thread-local
    /// so parallel tests cannot perturb each other's counts; sessions
    /// allocate on the calling thread, never inside worker tasks.
    static TILE_MATRIX_ALLOCS: Cell<u64> = Cell::new(0);
}

/// Number of `TileMatrix` allocations performed by the current thread.
pub fn tile_matrix_allocs() -> u64 {
    TILE_MATRIX_ALLOCS.with(|c| c.get())
}

/// The mixed-precision storage rule, in one place: is lower tile
/// (i, j), `i >= j`, kept in full precision under `band`?
/// [`TileMatrix::zeros_mp`] allocates by this predicate and
/// `likelihood::mp::is_f64_tile` delegates to it, so the workspace
/// layout and the MP variant's semantics cannot drift apart.
#[inline]
pub fn mp_tile_is_f64(band: usize, i: usize, j: usize) -> bool {
    i - j <= band
}

/// One tile's storage, in its precision.
enum TileBuf {
    F64(Box<[f64]>),
    F32(Box<[f32]>),
}

/// Raw pointer to a tile buffer that tasks capture, tagged with the
/// tile's storage precision.
///
/// SAFETY: the scheduler's STF dependency inference guarantees that a
/// writer has exclusive access and readers never overlap a writer, so
/// aliased `&mut` access cannot occur at runtime.  The pointee (the
/// `TileMatrix`) outlives graph execution because every submission path
/// waits on its `JobHandle` before the storage goes out of scope (the
/// handle also waits on `Drop` — see `scheduler::runtime`).
#[derive(Copy, Clone)]
pub enum TilePtr {
    /// Full-precision tile.
    F64 {
        /// Base pointer of the column-major buffer.
        ptr: *mut f64,
        /// Buffer length in elements.
        len: usize,
    },
    /// Demoted (MP off-band) tile.
    F32 {
        /// Base pointer of the column-major buffer.
        ptr: *mut f32,
        /// Buffer length in elements.
        len: usize,
    },
}

unsafe impl Send for TilePtr {}
unsafe impl Sync for TilePtr {}

impl TilePtr {
    /// Borrow as a mutable f64 slice (the common, all-f64 paths).
    ///
    /// # Panics
    /// Panics on an f32-stored tile — precision-aware tasks use
    /// [`TilePtr::mat_mut`] instead.
    ///
    /// # Safety
    /// Caller must guarantee exclusive access for the duration of the
    /// borrow (the scheduler provides this via dependency ordering).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn as_mut(&self) -> &mut [f64] {
        match *self {
            TilePtr::F64 { ptr, len } => std::slice::from_raw_parts_mut(ptr, len),
            TilePtr::F32 { .. } => panic!("TilePtr::as_mut on an f32-stored tile"),
        }
    }

    /// Borrow as a shared f64 slice.
    ///
    /// # Panics
    /// Panics on an f32-stored tile — see [`TilePtr::mat_ref`].
    ///
    /// # Safety
    /// Caller must guarantee no concurrent writer (scheduler-provided).
    pub unsafe fn as_ref(&self) -> &[f64] {
        match *self {
            TilePtr::F64 { ptr, len } => std::slice::from_raw_parts(ptr, len),
            TilePtr::F32 { .. } => panic!("TilePtr::as_ref on an f32-stored tile"),
        }
    }

    /// Precision-tagged shared borrow (the MP-aware task bodies).
    ///
    /// # Safety
    /// Caller must guarantee no concurrent writer (scheduler-provided).
    pub unsafe fn mat_ref(&self) -> MatRef<'_> {
        match *self {
            TilePtr::F64 { ptr, len } => MatRef::F64(std::slice::from_raw_parts(ptr, len)),
            TilePtr::F32 { ptr, len } => MatRef::F32(std::slice::from_raw_parts(ptr, len)),
        }
    }

    /// Precision-tagged mutable borrow (the MP-aware task bodies).
    ///
    /// # Safety
    /// Caller must guarantee exclusive access (scheduler-provided).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn mat_mut(&self) -> MatMut<'_> {
        match *self {
            TilePtr::F64 { ptr, len } => MatMut::F64(std::slice::from_raw_parts_mut(ptr, len)),
            TilePtr::F32 { ptr, len } => MatMut::F32(std::slice::from_raw_parts_mut(ptr, len)),
        }
    }

    /// Buffer length in elements.
    pub fn len(&self) -> usize {
        match *self {
            TilePtr::F64 { len, .. } | TilePtr::F32 { len, .. } => len,
        }
    }

    /// Is the buffer empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Is this an f32-stored (MP off-band) tile?
    pub fn is_f32(&self) -> bool {
        matches!(self, TilePtr::F32 { .. })
    }
}

/// Lower-triangular tile storage for a symmetric matrix.
pub struct TileMatrix {
    n: usize,
    ts: usize,
    nt: usize,
    /// `Some(band)` for mixed-precision storage: tiles with
    /// `i - j > band` are f32.  `None` = every tile f64.
    mp_band: Option<usize>,
    /// Lower tiles, indexed by `tri_index(i, j)` for `i >= j`.
    tiles: Vec<TileBuf>,
}

impl TileMatrix {
    /// Allocate a zeroed tile matrix for an `n x n` symmetric matrix with
    /// tile size `ts`.  Every tile is f64.
    pub fn zeros(n: usize, ts: usize) -> Self {
        Self::zeros_with(n, ts, None)
    }

    /// Allocate a zeroed **mixed-precision** tile matrix: tiles within
    /// `band` of the diagonal (`i - j <= band`) are f64, the rest are
    /// stored as f32 (`likelihood::mp::is_f64_tile` is the same rule).
    pub fn zeros_mp(n: usize, ts: usize, band: usize) -> Self {
        Self::zeros_with(n, ts, Some(band))
    }

    fn zeros_with(n: usize, ts: usize, mp_band: Option<usize>) -> Self {
        assert!(n > 0 && ts > 0);
        TILE_MATRIX_ALLOCS.with(|c| c.set(c.get() + 1));
        let nt = n.div_ceil(ts);
        let mut tiles = Vec::with_capacity(nt * (nt + 1) / 2);
        for i in 0..nt {
            for j in 0..=i {
                let h = Self::dim_at(n, ts, i);
                let w = Self::dim_at(n, ts, j);
                let f32_tile = match mp_band {
                    Some(band) => !mp_tile_is_f64(band, i, j),
                    None => false,
                };
                tiles.push(if f32_tile {
                    TileBuf::F32(vec![0.0f32; h * w].into_boxed_slice())
                } else {
                    TileBuf::F64(vec![0.0f64; h * w].into_boxed_slice())
                });
            }
        }
        TileMatrix {
            n,
            ts,
            nt,
            mp_band,
            tiles,
        }
    }

    #[inline]
    fn dim_at(n: usize, ts: usize, i: usize) -> usize {
        ts.min(n - i * ts)
    }

    /// Matrix dimension.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }
    /// Tile size.
    #[inline]
    pub fn ts(&self) -> usize {
        self.ts
    }
    /// Number of tile rows/cols.
    #[inline]
    pub fn nt(&self) -> usize {
        self.nt
    }
    /// Mixed-precision band this matrix was allocated with (`None` for
    /// all-f64 storage).
    #[inline]
    pub fn mp_band(&self) -> Option<usize> {
        self.mp_band
    }
    /// Height (= local leading dimension) of tile row `i`.
    #[inline]
    pub fn tile_rows(&self, i: usize) -> usize {
        Self::dim_at(self.n, self.ts, i)
    }
    /// Width of tile column `j`.
    #[inline]
    pub fn tile_cols(&self, j: usize) -> usize {
        Self::dim_at(self.n, self.ts, j)
    }

    /// Linear index of lower tile (i, j), i >= j.
    #[inline]
    fn tri_index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i >= j && i < self.nt, "lower tile ({i},{j})");
        i * (i + 1) / 2 + j
    }

    /// Is tile (i, j) stored in f32?
    pub fn tile_is_f32(&self, i: usize, j: usize) -> bool {
        matches!(self.tiles[self.tri_index(i, j)], TileBuf::F32(_))
    }

    /// Borrow f64 tile (i, j), i >= j.  Panics on an f32-stored tile
    /// (use [`TileMatrix::tile_f32`]).
    pub fn tile(&self, i: usize, j: usize) -> &[f64] {
        match &self.tiles[self.tri_index(i, j)] {
            TileBuf::F64(t) => t,
            TileBuf::F32(_) => panic!("tile ({i},{j}) is f32-stored; use tile_f32"),
        }
    }

    /// Borrow f32 tile (i, j).  Panics on an f64-stored tile.
    pub fn tile_f32(&self, i: usize, j: usize) -> &[f32] {
        match &self.tiles[self.tri_index(i, j)] {
            TileBuf::F32(t) => t,
            TileBuf::F64(_) => panic!("tile ({i},{j}) is f64-stored; use tile"),
        }
    }

    /// Mutably borrow f64 tile (i, j), i >= j.  Panics on an f32 tile.
    pub fn tile_mut(&mut self, i: usize, j: usize) -> &mut [f64] {
        let idx = self.tri_index(i, j);
        match &mut self.tiles[idx] {
            TileBuf::F64(t) => t,
            TileBuf::F32(_) => panic!("tile ({i},{j}) is f32-stored; use tile_f32"),
        }
    }

    /// Raw pointer for task capture (precision-tagged).
    pub fn tile_ptr(&self, i: usize, j: usize) -> TilePtr {
        let idx = self.tri_index(i, j);
        match &self.tiles[idx] {
            TileBuf::F64(t) => TilePtr::F64 {
                ptr: t.as_ptr() as *mut f64,
                len: t.len(),
            },
            TileBuf::F32(t) => TilePtr::F32 {
                ptr: t.as_ptr() as *mut f32,
                len: t.len(),
            },
        }
    }

    /// Element access (symmetric: (i, j) with i < j reads the mirrored
    /// lower entry; f32 tiles are promoted).  For tests and small-scale
    /// assembly only.
    pub fn get(&self, gi: usize, gj: usize) -> f64 {
        let (gi, gj) = if gi >= gj { (gi, gj) } else { (gj, gi) };
        let (ti, li) = (gi / self.ts, gi % self.ts);
        let (tj, lj) = (gj / self.ts, gj % self.ts);
        let h = self.tile_rows(ti);
        match &self.tiles[self.tri_index(ti, tj)] {
            TileBuf::F64(t) => t[li + lj * h],
            TileBuf::F32(t) => t[li + lj * h] as f64,
        }
    }

    /// Set an element (mirrored into the lower triangle; demoted on an
    /// f32 tile).
    pub fn set(&mut self, gi: usize, gj: usize, v: f64) {
        let (gi, gj) = if gi >= gj { (gi, gj) } else { (gj, gi) };
        let (ti, li) = (gi / self.ts, gi % self.ts);
        let (tj, lj) = (gj / self.ts, gj % self.ts);
        let h = self.tile_rows(ti);
        let idx = self.tri_index(ti, tj);
        match &mut self.tiles[idx] {
            TileBuf::F64(t) => t[li + lj * h] = v,
            TileBuf::F32(t) => t[li + lj * h] = v as f32,
        }
    }

    /// Import the lower triangle of a dense symmetric matrix (all-f64
    /// storage).
    pub fn from_dense_lower(m: &Matrix, ts: usize) -> Self {
        assert!(m.is_square());
        let n = m.rows();
        let mut tm = TileMatrix::zeros(n, ts);
        for ti in 0..tm.nt {
            for tj in 0..=ti {
                let h = tm.tile_rows(ti);
                let w = tm.tile_cols(tj);
                let idx = tm.tri_index(ti, tj);
                let TileBuf::F64(tile) = &mut tm.tiles[idx] else {
                    unreachable!("zeros() allocates f64 tiles only");
                };
                for lj in 0..w {
                    for li in 0..h {
                        let gi = ti * ts + li;
                        let gj = tj * ts + lj;
                        // lower access (gi >= gj guaranteed except inside
                        // diagonal tiles where we mirror)
                        tile[li + lj * h] = if gi >= gj { m[(gi, gj)] } else { m[(gj, gi)] };
                    }
                }
            }
        }
        tm
    }

    /// Export to a dense matrix (symmetrized).  Tests / small scale only.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for gi in 0..self.n {
            for gj in 0..=gi {
                let v = self.get(gi, gj);
                m[(gi, gj)] = v;
                m[(gj, gi)] = v;
            }
        }
        m
    }

    /// Export the lower-triangular factor (upper forced to zero), as after
    /// an in-place tiled Cholesky.
    pub fn to_dense_lower(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for gi in 0..self.n {
            for gj in 0..=gi {
                m[(gi, gj)] = self.get(gi, gj);
            }
        }
        m
    }

    /// Sum of `f` over diagonal elements (e.g. log-determinant terms).
    pub fn diag_sum(&self, f: impl Fn(f64) -> f64) -> f64 {
        (0..self.n).map(|i| f(self.get(i, i))).sum()
    }

    /// Total bytes of one full-size f64 tile (the legacy uniform cost
    /// hint; for precision-aware per-tile costs use
    /// [`TileMatrix::tile_bytes_at`]).
    pub fn tile_bytes(&self) -> usize {
        self.ts * self.ts * std::mem::size_of::<f64>()
    }

    /// Bytes of tile (i, j)'s actual storage — f32 tiles are half-width,
    /// so MP task cost hints and the DES transfer model see the variant's
    /// real (halved) off-band memory traffic.
    pub fn tile_bytes_at(&self, i: usize, j: usize) -> usize {
        let elems = self.tile_rows(i) * self.tile_cols(j);
        match &self.tiles[self.tri_index(i, j)] {
            TileBuf::F64(_) => elems * std::mem::size_of::<f64>(),
            TileBuf::F32(_) => elems * std::mem::size_of::<f32>(),
        }
    }
}

/// A vector split into `ts`-sized segments aligned with a [`TileMatrix`].
pub struct TileVector {
    /// Total length.
    pub n: usize,
    /// Segment size (matches the tile size of the paired matrix).
    pub ts: usize,
    segs: Vec<Box<[f64]>>,
}

impl TileVector {
    /// Split `x` into `ts`-sized segments.
    pub fn from_slice(x: &[f64], ts: usize) -> Self {
        let n = x.len();
        let nt = n.div_ceil(ts);
        let segs = (0..nt)
            .map(|i| {
                let lo = i * ts;
                let hi = n.min(lo + ts);
                x[lo..hi].to_vec().into_boxed_slice()
            })
            .collect();
        TileVector { n, ts, segs }
    }

    /// Number of segments.
    pub fn nt(&self) -> usize {
        self.segs.len()
    }
    /// Refill the segments from `x` without reallocating (workspace reuse
    /// across optimizer iterations; `x` must have the original length).
    pub fn load(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.n, "TileVector::load length mismatch");
        for (i, s) in self.segs.iter_mut().enumerate() {
            let lo = i * self.ts;
            s.copy_from_slice(&x[lo..lo + s.len()]);
        }
    }
    /// Borrow segment `i`.
    pub fn seg(&self, i: usize) -> &[f64] {
        &self.segs[i]
    }
    /// Mutably borrow segment `i`.
    pub fn seg_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.segs[i]
    }
    /// Raw pointer to segment `i` for task capture (always f64).
    pub fn seg_ptr(&self, i: usize) -> TilePtr {
        TilePtr::F64 {
            ptr: self.segs[i].as_ptr() as *mut f64,
            len: self.segs[i].len(),
        }
    }
    /// Concatenate back into one vector.
    pub fn to_vec(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n);
        for s in &self.segs {
            out.extend_from_slice(s);
        }
        out
    }
    /// Squared Euclidean norm.
    pub fn dot_self(&self) -> f64 {
        self.segs
            .iter()
            .flat_map(|s| s.iter())
            .map(|v| v * v)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn tile_dims_with_edges() {
        let tm = TileMatrix::zeros(10, 4); // tiles: 4,4,2
        assert_eq!(tm.nt(), 3);
        assert_eq!(tm.tile_rows(0), 4);
        assert_eq!(tm.tile_rows(2), 2);
        assert_eq!(tm.tile(2, 1).len(), 2 * 4);
        assert_eq!(tm.tile(2, 2).len(), 4);
        assert_eq!(tm.mp_band(), None);
    }

    #[test]
    fn dense_round_trip() {
        let mut rng = Pcg64::seed_from_u64(21);
        let n = 23;
        let mut m = Matrix::from_fn(n, n, |_, _| rng.normal());
        // make symmetric
        let mt = m.transpose();
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] = 0.5 * (m[(i, j)] + mt[(i, j)]);
            }
        }
        let tm = TileMatrix::from_dense_lower(&m, 5);
        let back = tm.to_dense();
        assert!(m.max_abs_diff(&back) < 1e-15);
    }

    #[test]
    fn get_set_symmetric() {
        let mut tm = TileMatrix::zeros(7, 3);
        tm.set(1, 5, 4.25); // upper -> stored mirrored
        assert_eq!(tm.get(1, 5), 4.25);
        assert_eq!(tm.get(5, 1), 4.25);
    }

    #[test]
    fn diag_sum_logdet_form() {
        let mut tm = TileMatrix::zeros(4, 2);
        for i in 0..4 {
            tm.set(i, i, (i + 1) as f64);
        }
        let want: f64 = (1..=4).map(|v| (v as f64).ln()).sum();
        assert!((tm.diag_sum(f64::ln) - want).abs() < 1e-14);
    }

    #[test]
    fn tile_vector_segments() {
        let x: Vec<f64> = (0..11).map(|v| v as f64).collect();
        let tv = TileVector::from_slice(&x, 4);
        assert_eq!(tv.nt(), 3);
        assert_eq!(tv.seg(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(tv.seg(2), &[8.0, 9.0, 10.0]);
        assert_eq!(tv.to_vec(), x);
        let ds: f64 = x.iter().map(|v| v * v).sum();
        assert!((tv.dot_self() - ds).abs() < 1e-12);
    }

    #[test]
    fn tile_vector_load_reuses_segments() {
        let x: Vec<f64> = (0..11).map(|v| v as f64).collect();
        let mut tv = TileVector::from_slice(&x, 4);
        let y: Vec<f64> = (0..11).map(|v| (v * v) as f64).collect();
        tv.load(&y);
        assert_eq!(tv.to_vec(), y);
    }

    #[test]
    fn alloc_counter_tracks_this_thread() {
        let before = tile_matrix_allocs();
        let _a = TileMatrix::zeros(8, 4);
        let _b = TileMatrix::zeros_mp(8, 4, 0);
        assert_eq!(tile_matrix_allocs(), before + 2);
    }

    #[test]
    fn tile_ptr_round_trip() {
        let tm = TileMatrix::zeros(4, 2);
        let p = tm.tile_ptr(1, 0);
        assert!(!p.is_f32());
        unsafe {
            p.as_mut()[0] = 3.5;
        }
        assert_eq!(tm.tile(1, 0)[0], 3.5);
        assert_eq!(tm.get(2, 0), 3.5);
    }

    #[test]
    fn mp_layout_demotes_off_band_tiles_only() {
        // 4 tile rows, band 1: tiles with i - j > 1 are f32.
        let tm = TileMatrix::zeros_mp(16, 4, 1);
        assert_eq!(tm.mp_band(), Some(1));
        for i in 0..tm.nt() {
            for j in 0..=i {
                assert_eq!(tm.tile_is_f32(i, j), i - j > 1, "({i},{j})");
            }
        }
        // Full band: no tile demoted, equivalent to zeros() layout.
        let full = TileMatrix::zeros_mp(16, 4, 3);
        for i in 0..full.nt() {
            for j in 0..=i {
                assert!(!full.tile_is_f32(i, j));
            }
        }
    }

    #[test]
    fn mp_get_set_round_through_f32() {
        let mut tm = TileMatrix::zeros_mp(16, 4, 0);
        let v = 1.0 + 1e-12; // not representable in f32
        tm.set(12, 1, v); // far off-band tile (3,0): f32
        assert_eq!(tm.get(12, 1), 1.0, "stored through f32");
        tm.set(1, 2, v); // diagonal tile: f64
        assert_eq!(tm.get(1, 2), v);
    }

    #[test]
    fn mp_tile_ptr_mat_mut_round_trip() {
        let tm = TileMatrix::zeros_mp(16, 4, 0);
        let p = tm.tile_ptr(2, 0);
        assert!(p.is_f32());
        match unsafe { p.mat_mut() } {
            MatMut::F32(s) => s[0] = 2.5,
            MatMut::F64(_) => panic!("expected f32 tile"),
        }
        assert_eq!(tm.tile_f32(2, 0)[0], 2.5);
        assert_eq!(tm.get(8, 0), 2.5);
        match unsafe { p.mat_ref() } {
            MatRef::F32(s) => assert_eq!(s[0], 2.5),
            MatRef::F64(_) => panic!("expected f32 tile"),
        }
    }
}
