//! Tile storage: the data layout ExaGeoStat's task-based algorithms operate
//! on (Fig 1 of the paper).  A symmetric `n x n` matrix is split into
//! `nt x nt` tiles of size `ts` (edge tiles are smaller); only the lower
//! triangle of tiles is stored.  Each tile is a contiguous column-major
//! buffer — one scheduler data handle per tile.

use crate::linalg::matrix::Matrix;
use std::cell::Cell;

thread_local! {
    /// Per-thread count of [`TileMatrix`] buffer allocations — the
    /// testkit telemetry behind the allocation-regression tests that
    /// guard `EvalSession`'s workspace-reuse invariant (warm optimizer
    /// iterations must construct zero new tile matrices).  Thread-local
    /// so parallel tests cannot perturb each other's counts; sessions
    /// allocate on the calling thread, never inside worker tasks.
    static TILE_MATRIX_ALLOCS: Cell<u64> = Cell::new(0);
}

/// Number of `TileMatrix` allocations performed by the current thread.
pub fn tile_matrix_allocs() -> u64 {
    TILE_MATRIX_ALLOCS.with(|c| c.get())
}

/// Raw pointer to a tile buffer that tasks capture.
///
/// SAFETY: the scheduler's STF dependency inference guarantees that a
/// writer has exclusive access and readers never overlap a writer, so
/// aliased `&mut` access cannot occur at runtime.  The pointee (the
/// `TileMatrix`) outlives graph execution because every submission path
/// waits on its `JobHandle` before the storage goes out of scope (the
/// handle also waits on `Drop` — see `scheduler::runtime`).
#[derive(Copy, Clone)]
pub struct TilePtr {
    ptr: *mut f64,
    len: usize,
}

unsafe impl Send for TilePtr {}
unsafe impl Sync for TilePtr {}

impl TilePtr {
    /// # Safety
    /// Caller must guarantee exclusive access for the duration of the
    /// borrow (the scheduler provides this via dependency ordering).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn as_mut(&self) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
    /// # Safety
    /// Caller must guarantee no concurrent writer (scheduler-provided).
    pub unsafe fn as_ref(&self) -> &[f64] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Lower-triangular tile storage for a symmetric matrix.
pub struct TileMatrix {
    n: usize,
    ts: usize,
    nt: usize,
    /// Lower tiles, indexed by `tri_index(i, j)` for `i >= j`.
    tiles: Vec<Box<[f64]>>,
}

impl TileMatrix {
    /// Allocate a zeroed tile matrix for an `n x n` symmetric matrix with
    /// tile size `ts`.
    pub fn zeros(n: usize, ts: usize) -> Self {
        assert!(n > 0 && ts > 0);
        TILE_MATRIX_ALLOCS.with(|c| c.set(c.get() + 1));
        let nt = n.div_ceil(ts);
        let mut tiles = Vec::with_capacity(nt * (nt + 1) / 2);
        for i in 0..nt {
            for j in 0..=i {
                let h = Self::dim_at(n, ts, i);
                let w = Self::dim_at(n, ts, j);
                tiles.push(vec![0.0; h * w].into_boxed_slice());
            }
        }
        TileMatrix { n, ts, nt, tiles }
    }

    #[inline]
    fn dim_at(n: usize, ts: usize, i: usize) -> usize {
        ts.min(n - i * ts)
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }
    #[inline]
    pub fn ts(&self) -> usize {
        self.ts
    }
    /// Number of tile rows/cols.
    #[inline]
    pub fn nt(&self) -> usize {
        self.nt
    }
    /// Height (= local leading dimension) of tile row `i`.
    #[inline]
    pub fn tile_rows(&self, i: usize) -> usize {
        Self::dim_at(self.n, self.ts, i)
    }
    /// Width of tile column `j`.
    #[inline]
    pub fn tile_cols(&self, j: usize) -> usize {
        Self::dim_at(self.n, self.ts, j)
    }

    /// Linear index of lower tile (i, j), i >= j.
    #[inline]
    fn tri_index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i >= j && i < self.nt, "lower tile ({i},{j})");
        i * (i + 1) / 2 + j
    }

    /// Borrow tile (i, j), i >= j.
    pub fn tile(&self, i: usize, j: usize) -> &[f64] {
        &self.tiles[self.tri_index(i, j)]
    }

    /// Mutably borrow tile (i, j), i >= j.
    pub fn tile_mut(&mut self, i: usize, j: usize) -> &mut [f64] {
        let idx = self.tri_index(i, j);
        &mut self.tiles[idx]
    }

    /// Raw pointer for task capture.
    pub fn tile_ptr(&self, i: usize, j: usize) -> TilePtr {
        let idx = self.tri_index(i, j);
        let t = &self.tiles[idx];
        TilePtr {
            ptr: t.as_ptr() as *mut f64,
            len: t.len(),
        }
    }

    /// Element access (symmetric: (i, j) with i < j reads the mirrored
    /// lower entry).  For tests and small-scale assembly only.
    pub fn get(&self, gi: usize, gj: usize) -> f64 {
        let (gi, gj) = if gi >= gj { (gi, gj) } else { (gj, gi) };
        let (ti, li) = (gi / self.ts, gi % self.ts);
        let (tj, lj) = (gj / self.ts, gj % self.ts);
        let h = self.tile_rows(ti);
        self.tile(ti, tj)[li + lj * h]
    }

    pub fn set(&mut self, gi: usize, gj: usize, v: f64) {
        let (gi, gj) = if gi >= gj { (gi, gj) } else { (gj, gi) };
        let (ti, li) = (gi / self.ts, gi % self.ts);
        let (tj, lj) = (gj / self.ts, gj % self.ts);
        let h = self.tile_rows(ti);
        self.tile_mut(ti, tj)[li + lj * h] = v;
    }

    /// Import the lower triangle of a dense symmetric matrix.
    pub fn from_dense_lower(m: &Matrix, ts: usize) -> Self {
        assert!(m.is_square());
        let n = m.rows();
        let mut tm = TileMatrix::zeros(n, ts);
        for ti in 0..tm.nt {
            for tj in 0..=ti {
                let h = tm.tile_rows(ti);
                let w = tm.tile_cols(tj);
                let idx = tm.tri_index(ti, tj);
                let tile = &mut tm.tiles[idx];
                for lj in 0..w {
                    for li in 0..h {
                        let gi = ti * ts + li;
                        let gj = tj * ts + lj;
                        // lower access (gi >= gj guaranteed except inside
                        // diagonal tiles where we mirror)
                        tile[li + lj * h] = if gi >= gj { m[(gi, gj)] } else { m[(gj, gi)] };
                    }
                }
            }
        }
        tm
    }

    /// Export to a dense matrix (symmetrized).  Tests / small scale only.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for gi in 0..self.n {
            for gj in 0..=gi {
                let v = self.get(gi, gj);
                m[(gi, gj)] = v;
                m[(gj, gi)] = v;
            }
        }
        m
    }

    /// Export the lower-triangular factor (upper forced to zero), as after
    /// an in-place tiled Cholesky.
    pub fn to_dense_lower(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for gi in 0..self.n {
            for gj in 0..=gi {
                m[(gi, gj)] = self.get(gi, gj);
            }
        }
        m
    }

    /// Sum of `f` over diagonal elements (e.g. log-determinant terms).
    pub fn diag_sum(&self, f: impl Fn(f64) -> f64) -> f64 {
        (0..self.n).map(|i| f(self.get(i, i))).sum()
    }

    /// Total bytes of one tile (for the DES transfer model).
    pub fn tile_bytes(&self) -> usize {
        self.ts * self.ts * std::mem::size_of::<f64>()
    }
}

/// A vector split into `ts`-sized segments aligned with a [`TileMatrix`].
pub struct TileVector {
    pub n: usize,
    pub ts: usize,
    segs: Vec<Box<[f64]>>,
}

impl TileVector {
    pub fn from_slice(x: &[f64], ts: usize) -> Self {
        let n = x.len();
        let nt = n.div_ceil(ts);
        let segs = (0..nt)
            .map(|i| {
                let lo = i * ts;
                let hi = n.min(lo + ts);
                x[lo..hi].to_vec().into_boxed_slice()
            })
            .collect();
        TileVector { n, ts, segs }
    }

    pub fn nt(&self) -> usize {
        self.segs.len()
    }
    /// Refill the segments from `x` without reallocating (workspace reuse
    /// across optimizer iterations; `x` must have the original length).
    pub fn load(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.n, "TileVector::load length mismatch");
        for (i, s) in self.segs.iter_mut().enumerate() {
            let lo = i * self.ts;
            s.copy_from_slice(&x[lo..lo + s.len()]);
        }
    }
    pub fn seg(&self, i: usize) -> &[f64] {
        &self.segs[i]
    }
    pub fn seg_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.segs[i]
    }
    pub fn seg_ptr(&self, i: usize) -> TilePtr {
        TilePtr {
            ptr: self.segs[i].as_ptr() as *mut f64,
            len: self.segs[i].len(),
        }
    }
    pub fn to_vec(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n);
        for s in &self.segs {
            out.extend_from_slice(s);
        }
        out
    }
    pub fn dot_self(&self) -> f64 {
        self.segs
            .iter()
            .flat_map(|s| s.iter())
            .map(|v| v * v)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn tile_dims_with_edges() {
        let tm = TileMatrix::zeros(10, 4); // tiles: 4,4,2
        assert_eq!(tm.nt(), 3);
        assert_eq!(tm.tile_rows(0), 4);
        assert_eq!(tm.tile_rows(2), 2);
        assert_eq!(tm.tile(2, 1).len(), 2 * 4);
        assert_eq!(tm.tile(2, 2).len(), 4);
    }

    #[test]
    fn dense_round_trip() {
        let mut rng = Pcg64::seed_from_u64(21);
        let n = 23;
        let mut m = Matrix::from_fn(n, n, |_, _| rng.normal());
        // make symmetric
        let mt = m.transpose();
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] = 0.5 * (m[(i, j)] + mt[(i, j)]);
            }
        }
        let tm = TileMatrix::from_dense_lower(&m, 5);
        let back = tm.to_dense();
        assert!(m.max_abs_diff(&back) < 1e-15);
    }

    #[test]
    fn get_set_symmetric() {
        let mut tm = TileMatrix::zeros(7, 3);
        tm.set(1, 5, 4.25); // upper -> stored mirrored
        assert_eq!(tm.get(1, 5), 4.25);
        assert_eq!(tm.get(5, 1), 4.25);
    }

    #[test]
    fn diag_sum_logdet_form() {
        let mut tm = TileMatrix::zeros(4, 2);
        for i in 0..4 {
            tm.set(i, i, (i + 1) as f64);
        }
        let want: f64 = (1..=4).map(|v| (v as f64).ln()).sum();
        assert!((tm.diag_sum(f64::ln) - want).abs() < 1e-14);
    }

    #[test]
    fn tile_vector_segments() {
        let x: Vec<f64> = (0..11).map(|v| v as f64).collect();
        let tv = TileVector::from_slice(&x, 4);
        assert_eq!(tv.nt(), 3);
        assert_eq!(tv.seg(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(tv.seg(2), &[8.0, 9.0, 10.0]);
        assert_eq!(tv.to_vec(), x);
        let ds: f64 = x.iter().map(|v| v * v).sum();
        assert!((tv.dot_self() - ds).abs() < 1e-12);
    }

    #[test]
    fn tile_vector_load_reuses_segments() {
        let x: Vec<f64> = (0..11).map(|v| v as f64).collect();
        let mut tv = TileVector::from_slice(&x, 4);
        let y: Vec<f64> = (0..11).map(|v| (v * v) as f64).collect();
        tv.load(&y);
        assert_eq!(tv.to_vec(), y);
    }

    #[test]
    fn alloc_counter_tracks_this_thread() {
        let before = tile_matrix_allocs();
        let _a = TileMatrix::zeros(8, 4);
        let _b = TileMatrix::zeros(8, 4);
        assert_eq!(tile_matrix_allocs(), before + 2);
    }

    #[test]
    fn tile_ptr_round_trip() {
        let tm = TileMatrix::zeros(4, 2);
        let p = tm.tile_ptr(1, 0);
        unsafe {
            p.as_mut()[0] = 3.5;
        }
        assert_eq!(tm.tile(1, 0)[0], 3.5);
        assert_eq!(tm.get(2, 0), 3.5);
    }
}
