//! Low-rank tile algebra for the Tile Low-Rank (TLR) approximation
//! (Fig 1(c); Abdulah et al. 2018b / HiCMA).
//!
//! An off-diagonal tile `A (m x n)` is stored as `U V^T` with `U (m x k)`,
//! `V (n x k)`; `k` is chosen so the discarded singular values fall below
//! `tol * s_max`.  The TLR Cholesky needs four operations on these tiles,
//! implemented here: compression, right-TRSM, SYRK into a dense diagonal
//! tile, and the low-rank GEMM update with recompression.

use super::blas::{dgemm, dtrsm_llnn_raw};
use super::matrix::Matrix;
use super::svd::{jacobi_svd, qr_thin};

/// Truncation rule shared by compression and recompression.
#[derive(Copy, Clone, Debug)]
pub struct LrOpts {
    /// Relative singular-value cutoff: keep `s_i >= tol * s_0`.
    pub tol: f64,
    /// Hard rank cap (paper: "the k most significant singular values").
    pub max_rank: usize,
}

impl Default for LrOpts {
    fn default() -> Self {
        LrOpts {
            tol: 1e-7,
            max_rank: usize::MAX,
        }
    }
}

/// A tile in `U V^T` form.
#[derive(Clone, Debug)]
pub struct LrTile {
    pub u: Matrix,
    pub v: Matrix,
}

impl LrTile {
    pub fn rows(&self) -> usize {
        self.u.rows()
    }
    pub fn cols(&self) -> usize {
        self.v.rows()
    }
    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    /// Exact-zero tile.
    pub fn zero(m: usize, n: usize) -> Self {
        LrTile {
            u: Matrix::zeros(m, 0),
            v: Matrix::zeros(n, 0),
        }
    }

    /// Compress a dense `m x n` tile (column-major slice).
    pub fn compress(m: usize, n: usize, data: &[f64], opts: LrOpts) -> Self {
        assert_eq!(data.len(), m * n);
        let a = Matrix::from_col_major(m, n, data);
        // jacobi_svd needs rows >= cols; transpose if wide.
        let (u, s, v, transposed) = if m >= n {
            let (u, s, v) = jacobi_svd(&a);
            (u, s, v, false)
        } else {
            let (u, s, v) = jacobi_svd(&a.transpose());
            (u, s, v, true)
        };
        let k = chosen_rank(&s, opts);
        let (mut uk, mut vk) = (Matrix::zeros(a.rows(), k), Matrix::zeros(a.cols(), k));
        for j in 0..k {
            for i in 0..a.rows() {
                uk[(i, j)] = if transposed { v[(i, j)] } else { u[(i, j)] } * s[j];
            }
            for i in 0..a.cols() {
                vk[(i, j)] = if transposed { u[(i, j)] } else { v[(i, j)] };
            }
        }
        LrTile { u: uk, v: vk }
    }

    /// Compress a dense tile by partial-pivoted **Adaptive Cross
    /// Approximation** (the compressor HiCMA/STARS-H use for large
    /// problems): `O(k m n)` instead of Jacobi-SVD's `O(min(m,n) m n)`
    /// per sweep.  Falls back to exact behaviour at `tol = 0` (full rank).
    /// §Perf: 5–20x faster than `compress` at typical TLR ranks.
    pub fn compress_aca(m: usize, n: usize, data: &[f64], opts: LrOpts) -> Self {
        assert_eq!(data.len(), m * n);
        let max_rank = opts.max_rank.min(m.min(n));
        let mut resid = data.to_vec();
        let mut us: Vec<Vec<f64>> = Vec::new();
        let mut vs: Vec<Vec<f64>> = Vec::new();
        // reference magnitude for the stopping rule
        let a_max = data.iter().fold(0.0f64, |acc, v| acc.max(v.abs()));
        if a_max == 0.0 {
            return LrTile::zero(m, n);
        }
        let thresh = opts.tol * a_max;
        for _ in 0..max_rank {
            // global pivot on the residual (partial pivoting on full
            // residual is affordable here because the dense tile is
            // already materialized by the generation task)
            let (mut pi, mut pj, mut pmax) = (0usize, 0usize, 0.0f64);
            for j in 0..n {
                for i in 0..m {
                    let v = resid[i + j * m].abs();
                    if v > pmax {
                        pmax = v;
                        pi = i;
                        pj = j;
                    }
                }
            }
            if pmax <= thresh {
                break;
            }
            let pivot = resid[pi + pj * m];
            // u = R[:, pj] / pivot ; v = R[pi, :]
            let u: Vec<f64> = (0..m).map(|i| resid[i + pj * m] / pivot).collect();
            let v: Vec<f64> = (0..n).map(|j| resid[pi + j * m]).collect();
            // R -= u v^T
            for j in 0..n {
                let vj = v[j];
                if vj != 0.0 {
                    let col = &mut resid[j * m..j * m + m];
                    for i in 0..m {
                        col[i] -= u[i] * vj;
                    }
                }
            }
            us.push(u);
            vs.push(v);
        }
        let k = us.len();
        let mut u = Matrix::zeros(m, k);
        let mut v = Matrix::zeros(n, k);
        for c in 0..k {
            for i in 0..m {
                u[(i, c)] = us[c][i];
            }
            for j in 0..n {
                v[(j, c)] = vs[c][j];
            }
        }
        let mut t = LrTile { u, v };
        // One SVD-based recompression pass trims ACA's overshoot in rank.
        if k > 1 {
            t.recompress(opts);
        }
        t
    }

    /// Densify: `U V^T`.
    pub fn to_dense(&self) -> Matrix {
        let mut d = Matrix::zeros(self.rows(), self.cols());
        if self.rank() > 0 {
            dgemm(false, true, 1.0, &self.u, &self.v, 0.0, &mut d);
        }
        d
    }

    /// `A <- A L^{-T}` for lower-triangular `L (n x n)`:
    /// `U V^T L^{-T} = U (L^{-1} V)^T`, i.e. solve in the V factor only.
    pub fn trsm_right_lt(&mut self, l: &[f64], ldl: usize) {
        let n = self.cols();
        let k = self.rank();
        if k > 0 {
            dtrsm_llnn_raw(n, k, l, ldl, self.v.as_mut_slice(), n);
        }
    }

    /// Dense SYRK-style update `C <- C - (U V^T)(U V^T)^T`
    /// = `C - U (V^T V) U^T`, touching all of `C (m x m)` (the tiled
    /// Cholesky only reads its lower triangle).
    pub fn syrk_into(&self, c: &mut Matrix) {
        let k = self.rank();
        if k == 0 {
            return;
        }
        let mut w = Matrix::zeros(k, k);
        dgemm(true, false, 1.0, &self.v, &self.v, 0.0, &mut w); // V^T V
        let mut t = Matrix::zeros(self.rows(), k);
        dgemm(false, false, 1.0, &self.u, &w, 0.0, &mut t); // U W
        dgemm(false, true, -1.0, &t, &self.u, 1.0, c); // C -= U W U^T
    }

    /// Low-rank product `A B^T` where `A = Ua Va^T (m x p)` and
    /// `B = Ub Vb^T (n x p)`: result is `(Ua (Va^T Vb)) Ub^T`, rank
    /// `min(ka, kb)` without recompression.
    pub fn lr_abt(a: &LrTile, b: &LrTile) -> LrTile {
        let (ka, kb) = (a.rank(), b.rank());
        if ka == 0 || kb == 0 {
            return LrTile::zero(a.rows(), b.rows());
        }
        let mut m = Matrix::zeros(ka, kb);
        dgemm(true, false, 1.0, &a.v, &b.v, 0.0, &mut m); // Va^T Vb
        let mut u = Matrix::zeros(a.rows(), kb);
        dgemm(false, false, 1.0, &a.u, &m, 0.0, &mut u); // Ua (Va^T Vb)
        LrTile {
            u,
            v: b.u.clone(),
        }
    }

    /// `self <- self + alpha * other`, followed by recompression
    /// (QR + small SVD — the standard TLR rounding).
    pub fn add_scaled(&mut self, alpha: f64, other: &LrTile, opts: LrOpts) {
        assert_eq!(self.rows(), other.rows());
        assert_eq!(self.cols(), other.cols());
        let (k1, k2) = (self.rank(), other.rank());
        if k2 == 0 {
            return;
        }
        if k1 == 0 {
            let mut u = other.u.clone();
            for v in u.as_mut_slice() {
                *v *= alpha;
            }
            self.u = u;
            self.v = other.v.clone();
            self.recompress(opts);
            return;
        }
        let m = self.rows();
        let n = self.cols();
        let k = k1 + k2;
        let mut bu = Matrix::zeros(m, k);
        let mut bv = Matrix::zeros(n, k);
        bu.copy_block(0, 0, &self.u, 0, 0, m, k1);
        bv.copy_block(0, 0, &self.v, 0, 0, n, k1);
        for j in 0..k2 {
            for i in 0..m {
                bu[(i, k1 + j)] = alpha * other.u[(i, j)];
            }
            for i in 0..n {
                bv[(i, k1 + j)] = other.v[(i, j)];
            }
        }
        self.u = bu;
        self.v = bv;
        self.recompress(opts);
    }

    /// Recompress `U V^T` to the target tolerance:
    /// `U = Qu Ru`, `V = Qv Rv`, `Ru Rv^T = X S Y^T`,
    /// `U' = Qu X_r S_r`, `V' = Qv Y_r`.
    pub fn recompress(&mut self, opts: LrOpts) {
        let k = self.rank();
        if k == 0 {
            return;
        }
        let m = self.rows();
        let n = self.cols();
        if k >= m.min(n) {
            // cheaper to go through a dense SVD
            let d = self.to_dense();
            *self = LrTile::compress(m, n, d.as_slice(), opts);
            return;
        }
        let (qu, ru) = qr_thin(&self.u);
        let (qv, rv) = qr_thin(&self.v);
        let mut core = Matrix::zeros(k, k);
        dgemm(false, true, 1.0, &ru, &rv, 0.0, &mut core);
        let (x, s, y) = jacobi_svd(&core);
        let r = chosen_rank(&s, opts);
        let mut xs = Matrix::zeros(k, r);
        for j in 0..r {
            for i in 0..k {
                xs[(i, j)] = x[(i, j)] * s[j];
            }
        }
        let mut yr = Matrix::zeros(k, r);
        for j in 0..r {
            for i in 0..k {
                yr[(i, j)] = y[(i, j)];
            }
        }
        let mut u = Matrix::zeros(m, r);
        dgemm(false, false, 1.0, &qu, &xs, 0.0, &mut u);
        let mut v = Matrix::zeros(n, r);
        dgemm(false, false, 1.0, &qv, &yr, 0.0, &mut v);
        self.u = u;
        self.v = v;
    }

    /// `y_i <- y_i - (U V^T) y_j` (forward-solve update with an LR tile):
    /// `w = V^T y_j (k)`, `y_i -= U w`.
    pub fn gemv_sub(&self, yj: &[f64], yi: &mut [f64]) {
        let k = self.rank();
        if k == 0 {
            return;
        }
        let n = self.cols();
        let m = self.rows();
        let mut w = vec![0.0; k];
        super::blas::dgemv_raw(
            super::blas::Trans::T,
            n,
            k,
            1.0,
            self.v.as_slice(),
            n,
            yj,
            0.0,
            &mut w,
        );
        super::blas::dgemv_raw(
            super::blas::Trans::N,
            m,
            k,
            -1.0,
            self.u.as_slice(),
            m,
            &w,
            1.0,
            yi,
        );
    }

    /// Storage footprint in doubles (paper's TLR memory-saving metric).
    pub fn storage_len(&self) -> usize {
        (self.rows() + self.cols()) * self.rank()
    }
}

fn chosen_rank(s: &[f64], opts: LrOpts) -> usize {
    if s.is_empty() || s[0] <= 0.0 {
        return 0;
    }
    let cutoff = opts.tol * s[0];
    let mut k = s.iter().take_while(|&&sv| sv > cutoff).count();
    k = k.min(opts.max_rank).max(1);
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{dgemm_raw, dpotrf_raw, Trans};
    use crate::rng::Pcg64;

    fn smooth_tile(m: usize, n: usize) -> Vec<f64> {
        // Matérn-like smooth kernel between two separated clusters of 1-D
        // points — numerically low rank.
        let mut d = vec![0.0; m * n];
        for j in 0..n {
            for i in 0..m {
                let xi = i as f64 / m as f64;
                let yj = 3.0 + j as f64 / n as f64;
                d[i + j * m] = (-(xi - yj).abs()).exp();
            }
        }
        d
    }

    #[test]
    fn compress_smooth_tile_low_rank() {
        let (m, n) = (32, 32);
        let d = smooth_tile(m, n);
        let t = LrTile::compress(m, n, &d, LrOpts { tol: 1e-9, max_rank: usize::MAX });
        assert!(t.rank() <= 8, "rank {} too high for smooth tile", t.rank());
        let rec = t.to_dense();
        let a = Matrix::from_col_major(m, n, &d);
        assert!(a.max_abs_diff(&rec) < 1e-8);
    }

    #[test]
    fn aca_matches_svd_compression() {
        let (m, n) = (32, 24);
        let d = smooth_tile(m, n);
        let opts = LrOpts { tol: 1e-9, max_rank: usize::MAX };
        let svd = LrTile::compress(m, n, &d, opts);
        let aca = LrTile::compress_aca(m, n, &d, opts);
        let a = Matrix::from_col_major(m, n, &d);
        assert!(a.max_abs_diff(&svd.to_dense()) < 1e-7);
        assert!(a.max_abs_diff(&aca.to_dense()) < 1e-7, "aca reconstruction");
        // comparable rank (ACA may overshoot by a couple before recompress)
        assert!(aca.rank() <= svd.rank() + 3, "{} vs {}", aca.rank(), svd.rank());
    }

    #[test]
    fn aca_zero_and_cap() {
        let t = LrTile::compress_aca(8, 8, &[0.0; 64], LrOpts::default());
        assert_eq!(t.rank(), 0);
        let mut rng = Pcg64::seed_from_u64(77);
        let d: Vec<f64> = (0..16 * 16).map(|_| rng.normal()).collect();
        let t = LrTile::compress_aca(16, 16, &d, LrOpts { tol: 0.0, max_rank: 4 });
        assert!(t.rank() <= 4);
    }

    #[test]
    fn compress_wide_tile() {
        let (m, n) = (8, 20);
        let d = smooth_tile(m, n);
        let t = LrTile::compress(m, n, &d, LrOpts::default());
        let rec = t.to_dense();
        let a = Matrix::from_col_major(m, n, &d);
        assert!(a.max_abs_diff(&rec) < 1e-6);
    }

    #[test]
    fn max_rank_cap_respected() {
        let mut rng = Pcg64::seed_from_u64(51);
        let (m, n) = (16, 16);
        let d: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        let t = LrTile::compress(m, n, &d, LrOpts { tol: 0.0, max_rank: 5 });
        assert_eq!(t.rank(), 5);
    }

    #[test]
    fn trsm_right_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(52);
        let n = 16;
        // SPD -> L
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut l = Matrix::zeros(n, n);
        dgemm(false, true, 1.0, &b, &b, 0.0, &mut l);
        for i in 0..n {
            l[(i, i)] += n as f64;
        }
        dpotrf_raw(n, l.as_mut_slice(), n).unwrap();
        l.zero_upper();
        let d = smooth_tile(n, n);
        // dense reference: D * L^{-T}
        let mut dref = d.clone();
        crate::linalg::blas::dtrsm_rltn_raw(n, n, l.as_slice(), n, &mut dref, n);
        // LR path
        let mut t = LrTile::compress(n, n, &d, LrOpts { tol: 1e-12, max_rank: usize::MAX });
        t.trsm_right_lt(l.as_slice(), n);
        let got = t.to_dense();
        let want = Matrix::from_col_major(n, n, &dref);
        assert!(got.max_abs_diff(&want) < 1e-8, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn syrk_into_matches_dense() {
        let n = 16;
        let d = smooth_tile(n, n);
        let t = LrTile::compress(n, n, &d, LrOpts { tol: 1e-13, max_rank: usize::MAX });
        let mut c_lr = Matrix::eye(n);
        t.syrk_into(&mut c_lr);
        // dense reference: C - D D^T
        let mut c_ref = Matrix::eye(n);
        let dm = Matrix::from_col_major(n, n, &d);
        dgemm(false, true, -1.0, &dm, &dm, 1.0, &mut c_ref);
        assert!(c_lr.max_abs_diff(&c_ref) < 1e-9);
    }

    #[test]
    fn lr_abt_and_add_match_dense_gemm() {
        let (m, n, p) = (20, 14, 16);
        let da = smooth_tile(m, p);
        let db = smooth_tile(n, p);
        let dc = smooth_tile(m, n);
        let opts = LrOpts { tol: 1e-12, max_rank: usize::MAX };
        let a = LrTile::compress(m, p, &da, opts);
        let b = LrTile::compress(n, p, &db, opts);
        let mut c = LrTile::compress(m, n, &dc, opts);
        // C <- C - A B^T  (the TLR gemm update)
        let prod = LrTile::lr_abt(&a, &b);
        c.add_scaled(-1.0, &prod, opts);
        // dense reference
        let mut cref = dc.clone();
        dgemm_raw(
            Trans::N,
            Trans::T,
            m,
            n,
            p,
            -1.0,
            &da,
            m,
            &db,
            n,
            1.0,
            &mut cref,
            m,
        );
        let got = c.to_dense();
        let want = Matrix::from_col_major(m, n, &cref);
        assert!(got.max_abs_diff(&want) < 1e-8, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn gemv_sub_matches_dense() {
        let (m, n) = (12, 10);
        let d = smooth_tile(m, n);
        let t = LrTile::compress(m, n, &d, LrOpts { tol: 1e-13, max_rank: usize::MAX });
        let yj: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut yi = vec![1.0; m];
        t.gemv_sub(&yj, &mut yi);
        // dense
        let mut yref = vec![1.0; m];
        crate::linalg::blas::dgemv_raw(Trans::N, m, n, -1.0, &d, m, &yj, 1.0, &mut yref);
        for i in 0..m {
            assert!((yi[i] - yref[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_tile_is_noop() {
        let t = LrTile::zero(6, 6);
        assert_eq!(t.rank(), 0);
        let mut c = Matrix::eye(6);
        t.syrk_into(&mut c);
        assert!(c.max_abs_diff(&Matrix::eye(6)) == 0.0);
        let mut y = vec![2.0; 6];
        t.gemv_sub(&[1.0; 6], &mut y);
        assert_eq!(y, vec![2.0; 6]);
    }

    #[test]
    fn storage_savings_reported() {
        let (m, n) = (64, 64);
        let d = smooth_tile(m, n);
        let t = LrTile::compress(m, n, &d, LrOpts { tol: 1e-7, max_rank: usize::MAX });
        assert!(t.storage_len() < m * n / 2, "{} vs {}", t.storage_len(), m * n);
    }
}
