//! Small dense factorizations used by the Tile Low-Rank (TLR) path:
//! one-sided Jacobi SVD and thin Householder QR.
//!
//! ExaGeoStat compresses off-diagonal tiles with SVD (paper §II-A); tiles
//! are at most a few hundred square, where Jacobi is simple, accurate, and
//! fast enough (compression happens once per tile per MLE iteration).

use super::matrix::Matrix;

/// Thin SVD `A = U diag(s) V^T` with `A` of shape `m x n`, `m >= n`.
/// Returns `(U (m x n), s (n), V (n x n))`, singular values descending.
pub fn jacobi_svd(a: &Matrix) -> (Matrix, Vec<f64>, Matrix) {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "jacobi_svd requires m >= n (got {m} x {n})");
    let mut u = a.clone();
    let mut v = Matrix::eye(n);
    let eps = 1e-14;
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                // Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                {
                    let cp = u.col(p);
                    let cq = u.col(q);
                    for i in 0..m {
                        app += cp[i] * cp[i];
                        aqq += cq[i] * cq[i];
                        apq += cp[i] * cq[i];
                    }
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation.
                let zeta = (aqq - app) / (2.0 * apq);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // rotate columns p, q of U and V
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // Extract singular values, normalize U columns, sort descending.
    let mut s: Vec<f64> = (0..n)
        .map(|j| u.col(j).iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].total_cmp(&s[i]));
    let mut us = Matrix::zeros(m, n);
    let mut vs = Matrix::zeros(n, n);
    let mut ss = vec![0.0; n];
    for (newj, &oldj) in order.iter().enumerate() {
        ss[newj] = s[oldj];
        let scale = if s[oldj] > 0.0 { 1.0 / s[oldj] } else { 0.0 };
        for i in 0..m {
            us[(i, newj)] = u[(i, oldj)] * scale;
        }
        for i in 0..n {
            vs[(i, newj)] = v[(i, oldj)];
        }
    }
    s = ss;
    (us, s, vs)
}

/// Thin Householder QR: `A = Q R` with `A (m x k)`, `m >= k`; returns
/// `(Q (m x k) with orthonormal columns, R (k x k) upper triangular)`.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let m = a.rows();
    let k = a.cols();
    assert!(m >= k, "qr_thin requires m >= k (got {m} x {k})");
    let mut r = a.clone();
    // Store Householder vectors in-place below the diagonal; taus separate.
    let mut taus = vec![0.0f64; k];
    for j in 0..k {
        // Compute Householder vector for column j, rows j..m.
        let mut normx = 0.0;
        for i in j..m {
            normx += r[(i, j)] * r[(i, j)];
        }
        normx = normx.sqrt();
        if normx == 0.0 {
            taus[j] = 0.0;
            continue;
        }
        let alpha = r[(j, j)];
        let beta = -alpha.signum() * normx;
        let tau = (beta - alpha) / beta;
        taus[j] = tau;
        let scale = 1.0 / (alpha - beta);
        for i in j + 1..m {
            r[(i, j)] *= scale;
        }
        r[(j, j)] = beta;
        // Apply reflector to trailing columns.
        for jj in j + 1..k {
            let mut dot = r[(j, jj)];
            for i in j + 1..m {
                dot += r[(i, j)] * r[(i, jj)];
            }
            dot *= tau;
            r[(j, jj)] -= dot;
            for i in j + 1..m {
                let vij = r[(i, j)];
                r[(i, jj)] -= dot * vij;
            }
        }
    }
    // Form Q by applying reflectors to identity columns (back to front).
    let mut q = Matrix::zeros(m, k);
    for j in 0..k {
        q[(j, j)] = 1.0;
    }
    for j in (0..k).rev() {
        let tau = taus[j];
        if tau == 0.0 {
            continue;
        }
        for jj in j..k {
            let mut dot = q[(j, jj)];
            for i in j + 1..m {
                dot += r[(i, j)] * q[(i, jj)];
            }
            dot *= tau;
            q[(j, jj)] -= dot;
            for i in j + 1..m {
                let vij = r[(i, j)];
                q[(i, jj)] -= dot * vij;
            }
        }
    }
    // Zero the sub-diagonal of R.
    let mut rr = Matrix::zeros(k, k);
    for j in 0..k {
        for i in 0..=j {
            rr[(i, j)] = r[(i, j)];
        }
    }
    (q, rr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, m: usize, n: usize) -> Matrix {
        Matrix::from_fn(m, n, |_, _| rng.normal())
    }

    fn reconstruct_svd(u: &Matrix, s: &[f64], v: &Matrix) -> Matrix {
        let mut usv = Matrix::zeros(u.rows(), v.rows());
        let mut us = u.clone();
        for j in 0..s.len() {
            for i in 0..u.rows() {
                us[(i, j)] *= s[j];
            }
        }
        crate::linalg::blas::dgemm(false, true, 1.0, &us, v, 0.0, &mut usv);
        usv
    }

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Pcg64::seed_from_u64(41);
        for &(m, n) in &[(4usize, 4usize), (10, 6), (32, 32), (50, 12)] {
            let a = rand_mat(&mut rng, m, n);
            let (u, s, v) = jacobi_svd(&a);
            let rec = reconstruct_svd(&u, &s, &v);
            let err = a.max_abs_diff(&rec);
            assert!(err < 1e-10, "({m},{n}): err {err}");
            // descending singular values
            for w in s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            // orthonormal U columns
            for p in 0..n {
                for q in 0..n {
                    let dot: f64 = (0..m).map(|i| u[(i, p)] * u[(i, q)]).sum();
                    let want = if p == q { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-10, "U^T U ({p},{q}) = {dot}");
                }
            }
        }
    }

    #[test]
    fn svd_rank_deficient() {
        // rank-2 matrix: outer products
        let mut rng = Pcg64::seed_from_u64(42);
        let (m, n, r) = (20, 10, 2);
        let b = rand_mat(&mut rng, m, r);
        let c = rand_mat(&mut rng, n, r);
        let mut a = Matrix::zeros(m, n);
        crate::linalg::blas::dgemm(false, true, 1.0, &b, &c, 0.0, &mut a);
        let (_u, s, _v) = jacobi_svd(&a);
        assert!(s[0] > 1.0e-8);
        assert!(s[1] > 1.0e-8);
        for &sv in &s[2..] {
            assert!(sv < 1e-9 * s[0], "trailing sv {sv}");
        }
    }

    #[test]
    fn svd_matches_known_diagonal() {
        let a = Matrix::from_row_major(2, 2, &[3.0, 0.0, 0.0, -2.0]);
        let (_u, s, _v) = jacobi_svd(&a);
        assert!((s[0] - 3.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn qr_reconstructs_and_orthonormal() {
        let mut rng = Pcg64::seed_from_u64(43);
        for &(m, k) in &[(5usize, 5usize), (12, 4), (40, 17)] {
            let a = rand_mat(&mut rng, m, k);
            let (q, r) = qr_thin(&a);
            let rec = q.matmul(&r);
            assert!(a.max_abs_diff(&rec) < 1e-10, "({m},{k})");
            for p in 0..k {
                for s in 0..k {
                    let dot: f64 = (0..m).map(|i| q[(i, p)] * q[(i, s)]).sum();
                    let want = if p == s { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-10);
                }
            }
            // R upper triangular
            for j in 0..k {
                for i in j + 1..k {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        }
    }
}
