//! Special functions needed by the Matérn covariance kernel: the gamma
//! function and the modified Bessel function of the second kind `K_nu` for
//! real order `nu > 0`.
//!
//! ExaGeoStat gets these from GSL (Table I); we implement them from scratch:
//! `ln Γ` via the Lanczos approximation, and `K_nu` via the standard
//! fractional-order algorithm (Temme's series for `x < 2`, Steed's second
//! continued fraction for `x >= 2`, plus upward recurrence in the order) —
//! the same method GSL and Numerical Recipes use.  Accuracy is validated
//! against SciPy references in the tests (`kv`, `gammaln`).

use std::f64::consts::PI;

const EPS: f64 = 2e-15;
const MAXIT: usize = 10_000;

/// Lanczos coefficients (g = 7, n = 9).
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.999_999_999_999_809_93,
    676.520_368_121_885_1,
    -1_259.139_216_722_402_8,
    771.323_428_777_653_13,
    -176.615_029_162_140_6,
    12.507_343_278_686_905,
    -0.138_571_095_265_720_12,
    9.984_369_578_019_571_6e-6,
    1.505_632_735_149_311_6e-7,
];

/// Natural log of the gamma function for `x > 0`.
pub fn lgamma(x: f64) -> f64 {
    assert!(x > 0.0, "lgamma requires x > 0, got {x}");
    if x < 0.5 {
        // Reflection: Γ(x) Γ(1-x) = π / sin(πx)
        return (PI / (PI * x).sin()).ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + LANCZOS_G + 0.5;
    0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// Gamma function for `x > 0`.
pub fn gamma(x: f64) -> f64 {
    lgamma(x).exp()
}

/// Chebyshev evaluation on [-1, 1] (Clenshaw).
fn chebev(c: &[f64], x: f64) -> f64 {
    let mut d = 0.0;
    let mut dd = 0.0;
    let y2 = 2.0 * x;
    for &cj in c.iter().skip(1).rev() {
        let sv = d;
        d = y2 * d - dd + cj;
        dd = sv;
    }
    x * d - dd + 0.5 * c[0]
}

/// Temme's gamma-function combinations for |mu| <= 1/2:
/// gam1 = [1/Γ(1-μ) - 1/Γ(1+μ)]/(2μ), gam2 = [1/Γ(1-μ) + 1/Γ(1+μ)]/2,
/// gampl = 1/Γ(1+μ), gammi = 1/Γ(1-μ).
fn beschb(x: f64) -> (f64, f64, f64, f64) {
    const C1: [f64; 7] = [
        -1.142022680371168,
        6.5165112670737e-3,
        3.087090173086e-4,
        -3.4706269649e-6,
        6.9437664e-9,
        3.67795e-11,
        -1.356e-13,
    ];
    const C2: [f64; 8] = [
        1.843740587300905,
        -7.68528408447867e-2,
        1.2719271366546e-3,
        -4.9717367042e-6,
        -3.31261198e-8,
        2.423096e-10,
        -1.702e-13,
        -1.49e-15,
    ];
    let xx = 8.0 * x * x - 1.0;
    let gam1 = chebev(&C1, xx);
    let gam2 = chebev(&C2, xx);
    let gampl = gam2 - x * gam1;
    let gammi = gam2 + x * gam1;
    (gam1, gam2, gampl, gammi)
}

/// Modified Bessel function of the second kind `K_nu(x)` for `nu >= 0`,
/// `x > 0`.  Also returns `K_{nu+1}(x)` (used by derivative formulas).
pub fn besselk_pair(nu: f64, x: f64) -> (f64, f64) {
    assert!(x > 0.0, "besselk requires x > 0 (got {x})");
    assert!(nu >= 0.0, "besselk requires nu >= 0 (got {nu})");

    let nl = (nu + 0.5).floor() as usize;
    let xmu = nu - nl as f64; // in [-0.5, 0.5]
    let xmu2 = xmu * xmu;
    let xi = 1.0 / x;
    let xi2 = 2.0 * xi;

    let (mut rkmu, mut rk1);
    if x < 2.0 {
        // Temme series.
        let x2 = 0.5 * x;
        let pimu = PI * xmu;
        let fact = if pimu.abs() < EPS { 1.0 } else { pimu / pimu.sin() };
        let d = -x2.ln();
        let e = xmu * d;
        let fact2 = if e.abs() < EPS { 1.0 } else { e.sinh() / e };
        let (gam1, gam2, gampl, gammi) = beschb(xmu);
        let mut ff = fact * (gam1 * e.cosh() + gam2 * fact2 * d);
        let mut sum = ff;
        let e = e.exp();
        let mut p = 0.5 * e / gampl;
        let mut q = 0.5 / (e * gammi);
        let mut c = 1.0;
        let d = x2 * x2;
        let mut sum1 = p;
        let mut converged = false;
        for i in 1..=MAXIT {
            let fi = i as f64;
            ff = (fi * ff + p + q) / (fi * fi - xmu2);
            c *= d / fi;
            p /= fi - xmu;
            q /= fi + xmu;
            let del = c * ff;
            sum += del;
            let del1 = c * (p - fi * ff);
            sum1 += del1;
            if del.abs() < sum.abs() * EPS {
                converged = true;
                break;
            }
        }
        debug_assert!(converged, "Temme series failed to converge");
        rkmu = sum;
        rk1 = sum1 * xi2;
    } else {
        // Steed's CF2.
        let mut b = 2.0 * (1.0 + x);
        let mut d = 1.0 / b;
        let mut delh = d;
        let mut h = delh;
        let mut q1 = 0.0;
        let mut q2 = 1.0;
        let a1 = 0.25 - xmu2;
        let mut q = a1;
        let mut c = a1;
        let mut a = -a1;
        let mut s = 1.0 + q * delh;
        let mut converged = false;
        for i in 2..=MAXIT {
            let fi = i as f64;
            a -= 2.0 * (fi - 1.0);
            c = -a * c / fi;
            let qnew = (q1 - b * q2) / a;
            q1 = q2;
            q2 = qnew;
            q += c * qnew;
            b += 2.0;
            d = 1.0 / (b + a * d);
            delh = (b * d - 1.0) * delh;
            h += delh;
            let dels = q * delh;
            s += dels;
            if (dels / s).abs() < EPS {
                converged = true;
                break;
            }
        }
        debug_assert!(converged, "CF2 failed to converge");
        h = a1 * h;
        rkmu = (PI / (2.0 * x)).sqrt() * (-x).exp() / s;
        rk1 = rkmu * (xmu + x + 0.5 - h) * xi;
    }

    // Upward recurrence K_{mu+1} from (K_mu, K_{mu+1-1}).
    for i in 1..=nl {
        let rktemp = (xmu + i as f64) * xi2 * rk1 + rkmu;
        rkmu = rk1;
        rk1 = rktemp;
    }
    (rkmu, rk1)
}

/// `K_nu(x)`.
pub fn besselk(nu: f64, x: f64) -> f64 {
    besselk_pair(nu, x).0
}

/// d/dx K_nu(x) = -(K_{nu-1}(x) + K_{nu+1}(x))/2 = nu/x K_nu(x) - K_{nu+1}(x).
pub fn besselk_deriv(nu: f64, x: f64) -> f64 {
    let (knu, knu1) = besselk_pair(nu, x);
    nu / x * knu - knu1
}

/// The Matérn correlation in the paper's parametrization (Eq. 3 with
/// sigma^2 = 1): `M_nu(t) = 2^{1-nu}/Γ(nu) * t^nu * K_nu(t)` where
/// `t = r / beta`.  `M_nu(0) = 1`.
///
/// Closed forms are used for the half-integer smoothness values the Pallas
/// kernel also implements (`nu` in {1/2, 3/2, 5/2}); the general case goes
/// through `besselk`.
pub fn matern_correlation(t: f64, nu: f64) -> f64 {
    debug_assert!(t >= 0.0);
    debug_assert!(nu > 0.0);
    if t == 0.0 {
        return 1.0;
    }
    // Half-integer fast paths (exact algebraic simplifications).
    if nu == 0.5 {
        return (-t).exp();
    }
    if nu == 1.5 {
        return (1.0 + t) * (-t).exp();
    }
    if nu == 2.5 {
        return (1.0 + t + t * t / 3.0) * (-t).exp();
    }
    // For large t the correlation underflows smoothly; K_nu underflows
    // around t ~ 705, so short-circuit.
    if t > 700.0 {
        return 0.0;
    }
    // The nu-only part of the prefactor is constant across a covariance
    // matrix fill (one theta, n^2 evaluations): memoize it per thread.
    // (§Perf: removes one lgamma per element — measured 1.28x on the
    // general-nu generation path.)
    thread_local! {
        static PREF_CACHE: std::cell::Cell<(f64, f64)> = const { std::cell::Cell::new((f64::NAN, 0.0)) };
    }
    let nu_pref = PREF_CACHE.with(|c| {
        let (cached_nu, cached) = c.get();
        if cached_nu == nu {
            cached
        } else {
            let v = (1.0 - nu) * std::f64::consts::LN_2 - lgamma(nu);
            c.set((nu, v));
            v
        }
    });
    let log_pref = nu_pref + nu * t.ln();
    let k = besselk(nu, t);
    if k == 0.0 {
        return 0.0;
    }
    (log_pref + k.ln()).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    const KV_REFS: &[(f64, f64, f64)] = &[
        // (nu, x, scipy kv(nu, x))
        (0.5, 0.1, 3.5861668387972601e+00),
        (0.5, 1.0, 4.6106850444789460e-01),
        (0.5, 5.0, 3.7766133746428825e-03),
        (1.0, 0.05, 1.9909674325882506e+01),
        (1.0, 0.5, 1.6564411200033007e+00),
        (1.0, 2.0, 1.3986588181652246e-01),
        (1.0, 10.0, 1.8648773453825585e-05),
        (1.5, 0.3, 7.3456979108035609e+00),
        (1.5, 3.0, 4.8034646842352792e-02),
        (2.0, 0.01, 1.9999500068389410e+04),
        (2.0, 1.0, 1.6248388986351774e+00),
        (2.0, 8.0, 1.8531300817406569e-04),
        (2.5, 0.7, 8.4863415928013843e+00),
        (0.3, 0.2, 1.9346034044945348e+00),
        (0.3, 4.0, 1.1273168760268220e-02),
        (0.75, 1.5, 2.4773741667982446e-01),
        (1.25, 0.9, 8.8361862323362583e-01),
        (3.7, 2.2, 9.7475595617671107e-01),
        (5.5, 6.0, 1.1683210030445677e-02),
        (0.1, 0.001, 7.6735905190531852e+00),
        (4.0, 0.5, 7.5224509791040384e+02),
        (2.7, 30.0, 2.4030878842059368e-14),
    ];

    const LGAMMA_REFS: &[(f64, f64)] = &[
        (0.1, 2.2527126517342060e+00),
        (0.5, 5.7236494292469997e-01),
        (1.0, 0.0000000000000000e+00),
        (1.5, -1.2078223763524526e-01),
        (2.0, 0.0000000000000000e+00),
        (3.7, 1.4280723266653881e+00),
        (10.0, 1.2801827480081469e+01),
        (25.5, 5.6389167643719937e+01),
        (0.01, 4.5994798780420219e+00),
    ];

    #[test]
    fn lgamma_matches_scipy() {
        for &(x, want) in LGAMMA_REFS {
            let got = lgamma(x);
            let tol = 1e-12 * (1.0 + want.abs());
            assert!((got - want).abs() < tol, "lgamma({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn gamma_factorials() {
        for n in 1..10u64 {
            let fact: u64 = (1..n).product();
            assert!(
                (gamma(n as f64) - fact as f64).abs() / (fact as f64) < 1e-13,
                "Γ({n})"
            );
        }
    }

    #[test]
    fn besselk_matches_scipy() {
        for &(nu, x, want) in KV_REFS {
            let got = besselk(nu, x);
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-10, "K_{nu}({x}) = {got:e}, want {want:e}, rel {rel:e}");
        }
    }

    #[test]
    fn besselk_half_integer_closed_form() {
        // K_{1/2}(x) = sqrt(pi/(2x)) e^{-x}
        for &x in &[0.1, 0.5, 1.0, 3.0, 10.0] {
            let want = (PI / (2.0 * x)).sqrt() * (-x as f64).exp();
            let got = besselk(0.5, x);
            assert!(((got - want) / want).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn besselk_deriv_matches_fd() {
        for &(nu, x) in &[(0.7, 1.3), (1.5, 0.8), (2.3, 4.0)] {
            let h = 1e-6;
            let fd = (besselk(nu, x + h) - besselk(nu, x - h)) / (2.0 * h);
            let an = besselk_deriv(nu, x);
            assert!(((fd - an) / an).abs() < 1e-7, "nu={nu} x={x}: {an} vs {fd}");
        }
    }

    #[test]
    fn matern_limits_and_monotone() {
        for &nu in &[0.4, 0.5, 1.0, 1.5, 2.0, 2.5, 3.3] {
            assert_eq!(matern_correlation(0.0, nu), 1.0);
            let mut prev = 1.0;
            for k in 1..60 {
                let t = 0.1 * k as f64;
                let v = matern_correlation(t, nu);
                assert!(v > 0.0 && v <= prev + 1e-15, "nu={nu} t={t}: {v} > {prev}");
                prev = v;
            }
            // tail -> 0
            assert!(matern_correlation(100.0, nu) < 1e-10);
            assert_eq!(matern_correlation(1e4, nu), 0.0);
        }
    }

    #[test]
    fn matern_half_integer_matches_general_path() {
        // The closed forms and the Bessel path must agree: evaluate the
        // general formula at nu slightly off the half-integer and check
        // continuity, plus directly at nu where both paths exist.
        for &nu in &[0.5, 1.5, 2.5] {
            for &t in &[0.05, 0.3, 1.0, 2.7, 6.0] {
                let closed = matern_correlation(t, nu);
                let log_pref = (1.0 - nu) * std::f64::consts::LN_2 - lgamma(nu) + nu * (t as f64).ln();
                let general = (log_pref + besselk(nu, t).ln()).exp();
                assert!(
                    ((closed - general) / general).abs() < 1e-10,
                    "nu={nu} t={t}: closed {closed} vs general {general}"
                );
            }
        }
    }

    #[test]
    fn matern_smoothness_orders_tail() {
        // Larger nu => smoother => higher correlation at moderate distance
        // (in this parametrization with fixed beta).
        let c1 = matern_correlation(1.0, 0.5);
        let c2 = matern_correlation(1.0, 1.5);
        let c3 = matern_correlation(1.0, 2.5);
        assert!(c1 < c2 && c2 < c3, "{c1} {c2} {c3}");
    }
}
