//! Covariance kernels (Table III of the paper), distance metrics, and
//! covariance-matrix assembly.
//!
//! The kernel registry mirrors the `kernel = "..."` strings of the R API:
//! `ugsm-s`, `ugsmn-s`, `bgsfm-s`, `bgspm-s`, `tgspm-s`, `ugsm-st`,
//! `bgsm-st`.  Multivariate kernels produce a `p*n x p*n` covariance with
//! variate-major ordering (variate 0 block first), matching ExaGeoStat.

pub mod bessel;
pub mod kernels;

use crate::linalg::matrix::Matrix;

/// Mean Earth radius in km, used by the great-circle metric.
pub const EARTH_RADIUS_KM: f64 = 6371.0;

/// Distance metric between 2-D coordinates (paper: `dmetric` argument).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DistanceMetric {
    /// Euclidean distance in the plane.
    Euclidean,
    /// Great-circle (haversine) distance; coordinates are (longitude,
    /// latitude) in degrees, result in km.
    GreatCircle,
}

impl DistanceMetric {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "euclidean" => Ok(DistanceMetric::Euclidean),
            "great_circle" => Ok(DistanceMetric::GreatCircle),
            other => anyhow::bail!("unknown dmetric {other:?} (euclidean|great_circle)"),
        }
    }
}

/// A spatio-temporal observation site.  `t` is 0 for purely spatial models.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct Location {
    pub x: f64,
    pub y: f64,
    pub t: f64,
}

impl Location {
    pub fn new(x: f64, y: f64) -> Self {
        Location { x, y, t: 0.0 }
    }
    pub fn new_st(x: f64, y: f64, t: f64) -> Self {
        Location { x, y, t }
    }
}

/// Spatial distance between two sites under `metric`.
#[inline]
pub fn distance(metric: DistanceMetric, a: &Location, b: &Location) -> f64 {
    match metric {
        DistanceMetric::Euclidean => {
            let dx = a.x - b.x;
            let dy = a.y - b.y;
            (dx * dx + dy * dy).sqrt()
        }
        DistanceMetric::GreatCircle => haversine_km(a.x, a.y, b.x, b.y),
    }
}

/// Haversine great-circle distance; inputs are (lon, lat) in degrees.
pub fn haversine_km(lon1: f64, lat1: f64, lon2: f64, lat2: f64) -> f64 {
    let to_rad = std::f64::consts::PI / 180.0;
    let phi1 = lat1 * to_rad;
    let phi2 = lat2 * to_rad;
    let dphi = (lat2 - lat1) * to_rad;
    let dlmb = (lon2 - lon1) * to_rad;
    let a = (dphi / 2.0).sin().powi(2) + phi1.cos() * phi2.cos() * (dlmb / 2.0).sin().powi(2);
    2.0 * EARTH_RADIUS_KM * a.sqrt().min(1.0).asin()
}

/// Morton (Z-order) permutation of 2-D locations.
///
/// ExaGeoStat sorts locations along a space-filling curve before tiling so
/// that each tile covers a spatially contiguous cluster — that is what
/// makes off-diagonal tiles low-rank (TLR) and far tiles negligible (DST).
/// The permutation leaves the likelihood invariant (simultaneous row/col
/// permutation of `Sigma` and `z`).
pub fn morton_perm(locs: &[Location]) -> Vec<usize> {
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for l in locs {
        xmin = xmin.min(l.x);
        xmax = xmax.max(l.x);
        ymin = ymin.min(l.y);
        ymax = ymax.max(l.y);
    }
    let xs = if xmax > xmin { xmax - xmin } else { 1.0 };
    let ys = if ymax > ymin { ymax - ymin } else { 1.0 };
    let code = |l: &Location| -> u64 {
        let xi = (((l.x - xmin) / xs) * 65535.0) as u64;
        let yi = (((l.y - ymin) / ys) * 65535.0) as u64;
        interleave16(xi) | (interleave16(yi) << 1)
    };
    let mut idx: Vec<usize> = (0..locs.len()).collect();
    idx.sort_by_key(|&i| code(&locs[i]));
    idx
}

/// Spread the low 16 bits of `v` into even bit positions.
fn interleave16(mut v: u64) -> u64 {
    v &= 0xFFFF;
    v = (v | (v << 16)) & 0x0000_FFFF_0000_FFFF;
    v = (v | (v << 8)) & 0x00FF_00FF_00FF_00FF;
    v = (v | (v << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    v = (v | (v << 2)) & 0x3333_3333_3333_3333;
    v = (v | (v << 1)) & 0x5555_5555_5555_5555;
    v
}

/// A stationary (cross-)covariance kernel.
///
/// `cov` evaluates the covariance between variate `a` at site `si` and
/// variate `b` at site `sj`, given the spatial distance `d`, the temporal
/// lag `u`, and whether the two sites are the same physical location
/// (`same_site`, used for nugget terms — floating-point distance alone
/// cannot distinguish a true replicate from a near-duplicate).
pub trait CovKernel: Send + Sync {
    /// Registry name (matches the R API string).
    fn name(&self) -> &'static str;
    /// Number of parameters in `theta`.
    fn nparams(&self) -> usize;
    /// Parameter names, for CLI/report output.
    fn param_names(&self) -> &'static [&'static str];
    /// Number of variates `p` (1 for univariate kernels).
    fn nvariates(&self) -> usize {
        1
    }
    /// Check that `theta` is in the kernel's valid parameter set.
    fn validate(&self, theta: &[f64]) -> anyhow::Result<()>;
    /// Evaluate the (cross-)covariance.
    fn cov(&self, theta: &[f64], d: f64, u: f64, a: usize, b: usize, same_site: bool) -> f64;
}

/// Look up a kernel by its registry name (Table III).
pub fn kernel_by_name(name: &str) -> anyhow::Result<Box<dyn CovKernel>> {
    kernels::by_name(name)
}

/// Assemble the full (variate-major) covariance matrix for `locs` under
/// `kernel(theta)`.  Output dimension is `p*n x p*n`.
pub fn build_cov_dense(
    kernel: &dyn CovKernel,
    theta: &[f64],
    locs: &[Location],
    metric: DistanceMetric,
) -> Matrix {
    let n = locs.len();
    let p = kernel.nvariates();
    let dim = p * n;
    let mut m = Matrix::zeros(dim, dim);
    for a in 0..p {
        for b in 0..=a {
            for j in 0..n {
                let start_i = if a == b { j } else { 0 };
                for i in start_i..n {
                    let d = distance(metric, &locs[i], &locs[j]);
                    let u = (locs[i].t - locs[j].t).abs();
                    let v = kernel.cov(theta, d, u, a, b, i == j);
                    m[(a * n + i, b * n + j)] = v;
                }
            }
        }
    }
    m.symmetrize_from_lower();
    m
}

/// Assemble a rectangular cross-covariance block between `rows` and `cols`
/// site lists (used by kriging: Sigma_{*,obs}); univariate only.
pub fn build_cross_cov(
    kernel: &dyn CovKernel,
    theta: &[f64],
    rows: &[Location],
    cols: &[Location],
    metric: DistanceMetric,
) -> Matrix {
    assert_eq!(kernel.nvariates(), 1, "cross-cov helper is univariate");
    let mut m = Matrix::zeros(rows.len(), cols.len());
    for j in 0..cols.len() {
        for i in 0..rows.len() {
            let d = distance(metric, &rows[i], &cols[j]);
            let u = (rows[i].t - cols[j].t).abs();
            m[(i, j)] = kernel.cov(theta, d, u, 0, 0, false);
        }
    }
    m
}

/// Fill one `ts x ts` (or edge-sized) tile of the covariance matrix into a
/// raw column-major buffer.  This is the unit of work the task scheduler
/// dispatches ("dcmg" task in ExaGeoStat), and the computation the L1
/// Pallas kernel implements for the PJRT backend.
#[allow(clippy::too_many_arguments)]
pub fn fill_cov_tile(
    kernel: &dyn CovKernel,
    theta: &[f64],
    locs: &[Location],
    metric: DistanceMetric,
    row0: usize,
    col0: usize,
    h: usize,
    w: usize,
    out: &mut [f64],
) {
    let n = locs.len();
    let p = kernel.nvariates();
    debug_assert!(out.len() >= h * w);
    for j in 0..w {
        let gj = col0 + j;
        let (b, sj) = (gj / n, gj % n);
        for i in 0..h {
            let gi = row0 + i;
            let (a, si) = (gi / n, gi % n);
            debug_assert!(a < p && b < p);
            let d = distance(metric, &locs[si], &locs[sj]);
            let u = (locs[si].t - locs[sj].t).abs();
            out[i + j * h] = kernel.cov(theta, d, u, a, b, si == sj);
        }
    }
}

/// Precomputed distances (and temporal lags) for one covariance tile,
/// laid out column-major like the tile buffer it feeds.
///
/// Distances depend only on the locations and metric — both immutable
/// across optimizer iterations — so an MLE run computes them once (in a
/// [`DistCache`]) and every subsequent `theta` evaluation reads them back
/// instead of redoing the `sqrt`/haversine work per element.
pub struct DistBlock {
    pub h: usize,
    pub w: usize,
    /// Column-major spatial distances, length `h * w`.
    pub d: Box<[f64]>,
    /// Column-major temporal lags; `None` when every site has `t = 0`
    /// (purely spatial data), in which case the lag is 0 everywhere.
    pub u: Option<Box<[f64]>>,
}

/// Compute the distance block for the tile at global offset
/// `(row0, col0)` of the `p*n`-dimensional covariance.  Global index `g`
/// maps to site `g % n` (variate-major ordering), so the same function
/// serves univariate and multivariate kernels.
pub fn build_dist_block(
    locs: &[Location],
    metric: DistanceMetric,
    row0: usize,
    col0: usize,
    h: usize,
    w: usize,
) -> DistBlock {
    let has_time = locs.iter().any(|l| l.t != 0.0);
    build_dist_block_inner(locs, metric, row0, col0, h, w, has_time)
}

/// [`build_dist_block`] with the (whole-location-set) `has_time` scan
/// hoisted out — [`DistCache::build`] computes it once for all blocks.
#[allow(clippy::too_many_arguments)]
fn build_dist_block_inner(
    locs: &[Location],
    metric: DistanceMetric,
    row0: usize,
    col0: usize,
    h: usize,
    w: usize,
    has_time: bool,
) -> DistBlock {
    let n = locs.len();
    let mut d = vec![0.0f64; h * w].into_boxed_slice();
    let mut u = has_time.then(|| vec![0.0f64; h * w].into_boxed_slice());
    for j in 0..w {
        let sj = (col0 + j) % n;
        for i in 0..h {
            let si = (row0 + i) % n;
            d[i + j * h] = distance(metric, &locs[si], &locs[sj]);
            if let Some(u) = u.as_mut() {
                u[i + j * h] = (locs[si].t - locs[sj].t).abs();
            }
        }
    }
    DistBlock { h, w, d, u }
}

/// Fill a covariance tile from a precomputed [`DistBlock`] — the warm-path
/// counterpart of [`fill_cov_tile`].  For diagonal tiles (`row0 == col0`,
/// square) only the lower triangle is evaluated and mirrored, which is
/// exact for any valid (cross-)covariance: swapping `(variate, site)`
/// pairs leaves the kernel value unchanged.
#[allow(clippy::too_many_arguments)]
pub fn cov_from_dist(
    kernel: &dyn CovKernel,
    theta: &[f64],
    nsites: usize,
    row0: usize,
    col0: usize,
    dist: &DistBlock,
    out: &mut [f64],
) {
    let (h, w) = (dist.h, dist.w);
    debug_assert!(out.len() >= h * w);
    let diagonal = row0 == col0 && h == w;
    for j in 0..w {
        let gj = col0 + j;
        let (b, sj) = (gj / nsites, gj % nsites);
        let i0 = if diagonal { j } else { 0 };
        for i in i0..h {
            let gi = row0 + i;
            let (a, si) = (gi / nsites, gi % nsites);
            let d = dist.d[i + j * h];
            let u = dist.u.as_ref().map_or(0.0, |u| u[i + j * h]);
            let v = kernel.cov(theta, d, u, a, b, si == sj);
            out[i + j * h] = v;
            if diagonal {
                out[j + i * h] = v;
            }
        }
    }
}

/// Per-tile distance cache for one tile grid (dimension `p*n`, tile size
/// `ts`) — the iteration-invariant half of covariance generation.
///
/// Blocks are `Arc`-shared so scheduler tasks can capture them without
/// copying.  An optional tile `band` (the DST structure) skips blocks that
/// the banded factorization never reads.
pub struct DistCache {
    dim: usize,
    ts: usize,
    nt: usize,
    blocks: Vec<Option<std::sync::Arc<DistBlock>>>,
}

impl DistCache {
    /// Build the cache for `p`-variate data at `locs` under `metric`.
    /// `band = None` caches every lower tile; `band = Some(b)` only tiles
    /// with `i - j <= b`.
    pub fn build(
        locs: &[Location],
        metric: DistanceMetric,
        p: usize,
        ts: usize,
        band: Option<usize>,
    ) -> DistCache {
        let dim = p * locs.len();
        let nt = dim.div_ceil(ts);
        let tile_dim = |i: usize| ts.min(dim - i * ts);
        let has_time = locs.iter().any(|l| l.t != 0.0);
        let mut blocks = Vec::with_capacity(nt * (nt + 1) / 2);
        for i in 0..nt {
            for j in 0..=i {
                let keep = match band {
                    None => true,
                    Some(b) => i - j <= b,
                };
                blocks.push(keep.then(|| {
                    std::sync::Arc::new(build_dist_block_inner(
                        locs,
                        metric,
                        i * ts,
                        j * ts,
                        tile_dim(i),
                        tile_dim(j),
                        has_time,
                    ))
                }));
            }
        }
        DistCache {
            dim,
            ts,
            nt,
            blocks,
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }
    #[inline]
    pub fn ts(&self) -> usize {
        self.ts
    }
    #[inline]
    pub fn nt(&self) -> usize {
        self.nt
    }

    /// The cached block for lower tile `(i, j)`, if retained at build time.
    pub fn block(&self, i: usize, j: usize) -> Option<std::sync::Arc<DistBlock>> {
        debug_assert!(i >= j && i < self.nt);
        self.blocks[i * (i + 1) / 2 + j].clone()
    }

    /// Cached doubles (telemetry: the memory cost of warm iterations).
    pub fn storage_len(&self) -> usize {
        self.blocks
            .iter()
            .flatten()
            .map(|b| b.d.len() + b.u.as_ref().map_or(0, |u| u.len()))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn euclidean_distance() {
        let a = Location::new(0.0, 0.0);
        let b = Location::new(3.0, 4.0);
        assert!((distance(DistanceMetric::Euclidean, &a, &b) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn great_circle_known_values() {
        // Equator quarter-circumference: (0,0) to (90E,0).
        let d = haversine_km(0.0, 0.0, 90.0, 0.0);
        let want = std::f64::consts::PI / 2.0 * EARTH_RADIUS_KM;
        assert!((d - want).abs() < 1e-6, "{d} vs {want}");
        // Pole to pole through lat.
        let d = haversine_km(10.0, -90.0, 10.0, 90.0);
        assert!((d - std::f64::consts::PI * EARTH_RADIUS_KM).abs() < 1e-6);
        // Symmetry + identity.
        assert_eq!(haversine_km(20.0, 30.0, 20.0, 30.0), 0.0);
        let ab = haversine_km(12.0, 45.0, 13.0, 46.0);
        let ba = haversine_km(13.0, 46.0, 12.0, 45.0);
        assert!((ab - ba).abs() < 1e-12);
    }

    #[test]
    fn metric_parse() {
        assert_eq!(
            DistanceMetric::parse("euclidean").unwrap(),
            DistanceMetric::Euclidean
        );
        assert_eq!(
            DistanceMetric::parse("great_circle").unwrap(),
            DistanceMetric::GreatCircle
        );
        assert!(DistanceMetric::parse("manhattan").is_err());
    }

    #[test]
    fn dense_cov_is_symmetric_with_sigma2_diag() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [1.7, 0.1, 0.5];
        let locs: Vec<Location> = (0..20)
            .map(|i| Location::new((i % 5) as f64 * 0.2, (i / 5) as f64 * 0.25))
            .collect();
        let m = build_cov_dense(k.as_ref(), &theta, &locs, DistanceMetric::Euclidean);
        for i in 0..20 {
            assert!((m[(i, i)] - 1.7).abs() < 1e-14);
            for j in 0..20 {
                assert_eq!(m[(i, j)], m[(j, i)]);
                assert!(m[(i, j)] > 0.0 && m[(i, j)] <= 1.7 + 1e-14);
            }
        }
    }

    #[test]
    fn tile_fill_matches_dense() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [1.0, 0.2, 1.5];
        let locs: Vec<Location> = (0..13)
            .map(|i| {
                let f = i as f64;
                Location::new((f * 0.37).fract(), (f * 0.71).fract())
            })
            .collect();
        let dense = build_cov_dense(k.as_ref(), &theta, &locs, DistanceMetric::Euclidean);
        let (row0, col0, h, w) = (3, 7, 6, 5);
        let mut tile = vec![0.0; h * w];
        fill_cov_tile(
            k.as_ref(),
            &theta,
            &locs,
            DistanceMetric::Euclidean,
            row0,
            col0,
            h,
            w,
            &mut tile,
        );
        for j in 0..w {
            for i in 0..h {
                assert_eq!(tile[i + j * h], dense[(row0 + i, col0 + j)]);
            }
        }
    }

    #[test]
    fn morton_perm_is_permutation_and_clusters() {
        let mut locs = Vec::new();
        // two well-separated clusters interleaved in index order
        for i in 0..20 {
            let f = i as f64 / 20.0;
            locs.push(Location::new(0.05 + 0.1 * f, 0.05 + 0.1 * f));
            locs.push(Location::new(0.9 + 0.05 * f, 0.9 + 0.05 * f));
        }
        let perm = morton_perm(&locs);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..40).collect::<Vec<_>>());
        // after sorting, the first half must be one spatial cluster
        let first_cluster_low = perm[..20].iter().all(|&i| locs[i].x < 0.5);
        let first_cluster_high = perm[..20].iter().all(|&i| locs[i].x > 0.5);
        assert!(
            first_cluster_low || first_cluster_high,
            "morton order should separate the clusters"
        );
    }

    #[test]
    fn cov_from_dist_matches_direct_fill() {
        // Univariate and bivariate, including a diagonal tile (mirrored
        // fill) and an off-diagonal rectangular tile.
        let locs: Vec<Location> = (0..11)
            .map(|i| {
                let f = i as f64;
                Location::new((f * 0.29).fract(), (f * 0.61).fract())
            })
            .collect();
        for (name, theta) in [
            ("ugsm-s", vec![1.2, 0.2, 1.0]),
            ("ugsmn-s", vec![1.0, 0.15, 0.8, 0.4]),
            ("bgspm-s", vec![1.0, 1.4, 0.2, 0.6, 1.2, 0.3]),
        ] {
            let k = kernel_by_name(name).unwrap();
            let dim = k.nvariates() * locs.len();
            let ts = 5; // does not divide dim for either p
            let nt = dim.div_ceil(ts);
            let tile_dim = |i: usize| ts.min(dim - i * ts);
            for i in 0..nt {
                for j in 0..=i {
                    let (h, w) = (tile_dim(i), tile_dim(j));
                    let (r0, c0) = (i * ts, j * ts);
                    let block = build_dist_block(&locs, DistanceMetric::Euclidean, r0, c0, h, w);
                    assert_eq!((block.h, block.w), (h, w));
                    let mut got = vec![0.0; h * w];
                    cov_from_dist(k.as_ref(), &theta, locs.len(), r0, c0, &block, &mut got);
                    let mut want = vec![0.0; h * w];
                    fill_cov_tile(
                        k.as_ref(),
                        &theta,
                        &locs,
                        DistanceMetric::Euclidean,
                        r0,
                        c0,
                        h,
                        w,
                        &mut want,
                    );
                    for e in 0..h * w {
                        assert!(
                            (got[e] - want[e]).abs() < 1e-15,
                            "{name} tile ({i},{j}) entry {e}: {} vs {}",
                            got[e],
                            want[e]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dist_cache_band_skips_offband_blocks() {
        let locs: Vec<Location> = (0..20)
            .map(|i| Location::new((i as f64 * 0.37).fract(), (i as f64 * 0.71).fract()))
            .collect();
        let full = DistCache::build(&locs, DistanceMetric::Euclidean, 1, 6, None);
        assert_eq!(full.nt(), 4);
        assert_eq!(full.dim(), 20);
        let banded = DistCache::build(&locs, DistanceMetric::Euclidean, 1, 6, Some(1));
        for i in 0..4 {
            for j in 0..=i {
                assert!(full.block(i, j).is_some());
                assert_eq!(banded.block(i, j).is_some(), i - j <= 1);
            }
        }
        assert!(banded.storage_len() < full.storage_len());
        // spatial data: no temporal-lag plane cached
        assert!(full.block(1, 0).unwrap().u.is_none());
        // spatio-temporal data: lag plane present and correct
        let st: Vec<Location> = (0..8)
            .map(|i| Location::new_st(i as f64 * 0.1, 0.0, (i % 3) as f64))
            .collect();
        let c = DistCache::build(&st, DistanceMetric::Euclidean, 1, 4, None);
        let b = c.block(1, 0).unwrap();
        let u = b.u.as_ref().expect("temporal lags cached");
        assert_eq!(u[0], (st[4].t - st[0].t).abs());
    }

    #[test]
    fn cross_cov_shape_and_values() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [2.0, 0.3, 0.5];
        let rows = vec![Location::new(0.0, 0.0), Location::new(1.0, 1.0)];
        let cols = vec![Location::new(0.0, 0.0)];
        let m = build_cross_cov(k.as_ref(), &theta, &rows, &cols, DistanceMetric::Euclidean);
        assert_eq!((m.rows(), m.cols()), (2, 1));
        assert!((m[(0, 0)] - 2.0).abs() < 1e-14); // zero distance
        assert!(m[(1, 0)] < 2.0);
    }
}
