//! The seven covariance kernels of Table III.
//!
//! All kernels use the paper's Matérn parametrization (Eq. 3): correlation
//! `M_nu(d / beta)` with `M` from [`super::bessel::matern_correlation`].
//! Multivariate kernels follow the parsimonious / flexible multivariate
//! Matérn of Gneiting, Kleiber & Schlather (2010); the space-time kernels
//! use the Gneiting (2002) non-separable class, which is what ExaGeoStat's
//! space-time kernels implement.

use super::bessel::{gamma, matern_correlation};
use super::CovKernel;
use anyhow::{bail, ensure, Result};

fn ensure_pos(theta: &[f64], names: &[&str], idx: &[usize]) -> Result<()> {
    for &i in idx {
        ensure!(
            theta[i] > 0.0 && theta[i].is_finite(),
            "parameter {} = {} must be positive and finite",
            names[i],
            theta[i]
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// ugsm-s: univariate Gaussian stationary Matérn — space
// ---------------------------------------------------------------------------

/// `theta = (sigma_sq, beta, nu)`:
/// `C(d) = sigma_sq * M_nu(d / beta)`.
pub struct UgsmS;

impl CovKernel for UgsmS {
    fn name(&self) -> &'static str {
        "ugsm-s"
    }
    fn nparams(&self) -> usize {
        3
    }
    fn param_names(&self) -> &'static [&'static str] {
        &["sigma_sq", "beta", "nu"]
    }
    fn validate(&self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == 3, "ugsm-s expects 3 parameters");
        ensure_pos(theta, self.param_names(), &[0, 1, 2])
    }
    fn cov(&self, theta: &[f64], d: f64, _u: f64, _a: usize, _b: usize, _same: bool) -> f64 {
        theta[0] * matern_correlation(d / theta[1], theta[2])
    }
}

// ---------------------------------------------------------------------------
// ugsmn-s: univariate Matérn with nugget — space
// ---------------------------------------------------------------------------

/// `theta = (sigma_sq, beta, nu, tau_sq)`:
/// `C(d) = sigma_sq * M_nu(d / beta) + tau_sq * 1{same site}`.
pub struct UgsmnS;

impl CovKernel for UgsmnS {
    fn name(&self) -> &'static str {
        "ugsmn-s"
    }
    fn nparams(&self) -> usize {
        4
    }
    fn param_names(&self) -> &'static [&'static str] {
        &["sigma_sq", "beta", "nu", "tau_sq"]
    }
    fn validate(&self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == 4, "ugsmn-s expects 4 parameters");
        ensure_pos(theta, self.param_names(), &[0, 1, 2])?;
        ensure!(theta[3] >= 0.0, "tau_sq must be non-negative");
        Ok(())
    }
    fn cov(&self, theta: &[f64], d: f64, _u: f64, _a: usize, _b: usize, same: bool) -> f64 {
        let c = theta[0] * matern_correlation(d / theta[1], theta[2]);
        if same {
            c + theta[3]
        } else {
            c
        }
    }
}

// ---------------------------------------------------------------------------
// bgspm-s: bivariate parsimonious Matérn — space
// ---------------------------------------------------------------------------

/// Maximum admissible cross-correlation for the parsimonious Matérn in
/// d = 2 dimensions (Gneiting, Kleiber & Schlather 2010, Thm 3):
/// `rho^2 <= Γ(nu1 + 1) Γ(nu2 + 1) / (Γ(nu1) Γ(nu2)) * Γ(nu12)^2 / Γ(nu12 + 1)^2`
/// with `nu12 = (nu1 + nu2) / 2`.
pub fn parsimonious_rho_max(nu1: f64, nu2: f64) -> f64 {
    let nu12 = 0.5 * (nu1 + nu2);
    let num = gamma(nu1 + 1.0) * gamma(nu2 + 1.0) / (gamma(nu1) * gamma(nu2));
    let den = (gamma(nu12 + 1.0) / gamma(nu12)).powi(2);
    (num / den).sqrt()
}

/// `theta = (sigma1_sq, sigma2_sq, beta, nu1, nu2, rho)`:
/// `C_aa(d) = sigma_a^2 M_{nu_a}(d/beta)`,
/// `C_12(d) = rho sigma_1 sigma_2 M_{(nu1+nu2)/2}(d/beta)`.
pub struct BgspmS;

impl CovKernel for BgspmS {
    fn name(&self) -> &'static str {
        "bgspm-s"
    }
    fn nparams(&self) -> usize {
        6
    }
    fn param_names(&self) -> &'static [&'static str] {
        &["sigma1_sq", "sigma2_sq", "beta", "nu1", "nu2", "rho"]
    }
    fn nvariates(&self) -> usize {
        2
    }
    fn validate(&self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == 6, "bgspm-s expects 6 parameters");
        ensure_pos(theta, self.param_names(), &[0, 1, 2, 3, 4])?;
        let rho_max = parsimonious_rho_max(theta[3], theta[4]);
        ensure!(
            theta[5].abs() <= rho_max,
            "rho = {} violates parsimonious validity bound {rho_max:.4}",
            theta[5]
        );
        Ok(())
    }
    fn cov(&self, theta: &[f64], d: f64, _u: f64, a: usize, b: usize, _same: bool) -> f64 {
        let (s1, s2, beta, nu1, nu2, rho) =
            (theta[0], theta[1], theta[2], theta[3], theta[4], theta[5]);
        let t = d / beta;
        match (a, b) {
            (0, 0) => s1 * matern_correlation(t, nu1),
            (1, 1) => s2 * matern_correlation(t, nu2),
            _ => rho * (s1 * s2).sqrt() * matern_correlation(t, 0.5 * (nu1 + nu2)),
        }
    }
}

// ---------------------------------------------------------------------------
// bgsfm-s: bivariate flexible Matérn — space
// ---------------------------------------------------------------------------

/// `theta = (sigma1_sq, sigma2_sq, beta1, beta2, beta12, nu1, nu2, nu12, rho)`:
/// each marginal / cross component has its own range and smoothness.
/// Validity: we enforce the sufficient conditions of Gneiting et al. (2010,
/// Thm 3 full model): `nu12 >= (nu1 + nu2)/2`, `1/beta12^2 >= (1/beta1^2 +
/// 1/beta2^2)/2` and a rho bound computed from the parameters.
pub struct BgsfmS;

/// Sufficient rho bound for the flexible bivariate Matérn (d = 2).
pub fn flexible_rho_max(
    beta1: f64,
    beta2: f64,
    beta12: f64,
    nu1: f64,
    nu2: f64,
    nu12: f64,
) -> f64 {
    // Gneiting-Kleiber-Schlather (2010) eq. (9) specialised to d=2, with
    // a_i = 1/beta_i (their scale convention):
    let a1 = 1.0 / beta1;
    let a2 = 1.0 / beta2;
    let a12 = 1.0 / beta12;
    let d_half = 1.0; // d/2 with d = 2
    let num = gamma(nu1 + d_half).sqrt() * gamma(nu2 + d_half).sqrt() * gamma(nu12)
        / (gamma(nu1).sqrt() * gamma(nu2).sqrt() * gamma(nu12 + d_half));
    let scale = a1.powf(nu1) * a2.powf(nu2) / a12.powf(2.0 * nu12)
        * a12.powf(2.0 * nu12)
        / (a1.powf(nu1) * a2.powf(nu2));
    // The infimum term over t >= 0 equals 1 under the enforced
    // beta/nu ordering constraints, so the bound reduces to `num`.
    num * scale
}

impl CovKernel for BgsfmS {
    fn name(&self) -> &'static str {
        "bgsfm-s"
    }
    fn nparams(&self) -> usize {
        9
    }
    fn param_names(&self) -> &'static [&'static str] {
        &[
            "sigma1_sq",
            "sigma2_sq",
            "beta1",
            "beta2",
            "beta12",
            "nu1",
            "nu2",
            "nu12",
            "rho",
        ]
    }
    fn nvariates(&self) -> usize {
        2
    }
    fn validate(&self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == 9, "bgsfm-s expects 9 parameters");
        ensure_pos(theta, self.param_names(), &[0, 1, 2, 3, 4, 5, 6, 7])?;
        let (b1, b2, b12) = (theta[2], theta[3], theta[4]);
        let (nu1, nu2, nu12) = (theta[5], theta[6], theta[7]);
        ensure!(
            nu12 >= 0.5 * (nu1 + nu2) - 1e-12,
            "validity requires nu12 >= (nu1 + nu2)/2"
        );
        ensure!(
            1.0 / (b12 * b12) >= 0.5 * (1.0 / (b1 * b1) + 1.0 / (b2 * b2)) - 1e-12,
            "validity requires 1/beta12^2 >= (1/beta1^2 + 1/beta2^2)/2"
        );
        let rho_max = flexible_rho_max(b1, b2, b12, nu1, nu2, nu12);
        ensure!(
            theta[8].abs() <= rho_max,
            "rho = {} violates flexible validity bound {rho_max:.4}",
            theta[8]
        );
        Ok(())
    }
    fn cov(&self, theta: &[f64], d: f64, _u: f64, a: usize, b: usize, _same: bool) -> f64 {
        let (s1, s2) = (theta[0], theta[1]);
        let (b1, b2, b12) = (theta[2], theta[3], theta[4]);
        let (nu1, nu2, nu12) = (theta[5], theta[6], theta[7]);
        let rho = theta[8];
        match (a, b) {
            (0, 0) => s1 * matern_correlation(d / b1, nu1),
            (1, 1) => s2 * matern_correlation(d / b2, nu2),
            _ => rho * (s1 * s2).sqrt() * matern_correlation(d / b12, nu12),
        }
    }
}

// ---------------------------------------------------------------------------
// tgspm-s: trivariate parsimonious Matérn — space
// ---------------------------------------------------------------------------

/// `theta = (s1, s2, s3, beta, nu1, nu2, nu3, rho12, rho13, rho23)`:
/// parsimonious trivariate Matérn; cross smoothness `(nu_a + nu_b)/2`,
/// common range `beta`.  Validity: each pairwise rho within the
/// parsimonious bound and the 3x3 correlation matrix positive definite.
pub struct TgspmS;

impl CovKernel for TgspmS {
    fn name(&self) -> &'static str {
        "tgspm-s"
    }
    fn nparams(&self) -> usize {
        10
    }
    fn param_names(&self) -> &'static [&'static str] {
        &[
            "sigma1_sq",
            "sigma2_sq",
            "sigma3_sq",
            "beta",
            "nu1",
            "nu2",
            "nu3",
            "rho12",
            "rho13",
            "rho23",
        ]
    }
    fn nvariates(&self) -> usize {
        3
    }
    fn validate(&self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == 10, "tgspm-s expects 10 parameters");
        ensure_pos(theta, self.param_names(), &[0, 1, 2, 3, 4, 5, 6])?;
        let nus = [theta[4], theta[5], theta[6]];
        let rhos = [(0, 1, theta[7]), (0, 2, theta[8]), (1, 2, theta[9])];
        for &(a, b, rho) in &rhos {
            let bound = parsimonious_rho_max(nus[a], nus[b]);
            ensure!(
                rho.abs() <= bound,
                "rho{}{} = {rho} violates bound {bound:.4}",
                a + 1,
                b + 1
            );
        }
        // 3x3 colocated correlation matrix must be PD.
        let (r12, r13, r23) = (theta[7], theta[8], theta[9]);
        let det = 1.0 + 2.0 * r12 * r13 * r23 - r12 * r12 - r13 * r13 - r23 * r23;
        ensure!(det > 0.0, "correlation matrix not positive definite");
        Ok(())
    }
    fn cov(&self, theta: &[f64], d: f64, _u: f64, a: usize, b: usize, _same: bool) -> f64 {
        let s = [theta[0], theta[1], theta[2]];
        let beta = theta[3];
        let nus = [theta[4], theta[5], theta[6]];
        let t = d / beta;
        if a == b {
            return s[a] * matern_correlation(t, nus[a]);
        }
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let rho = match (lo, hi) {
            (0, 1) => theta[7],
            (0, 2) => theta[8],
            _ => theta[9],
        };
        rho * (s[a] * s[b]).sqrt() * matern_correlation(t, 0.5 * (nus[a] + nus[b]))
    }
}

// ---------------------------------------------------------------------------
// ugsm-st: univariate Matérn — space-time (Gneiting non-separable class)
// ---------------------------------------------------------------------------

/// `theta = (sigma_sq, beta_s, beta_t, nu, alpha, gamma_ns)` with
/// `psi(u) = (1 + (u/beta_t)^{2 alpha})` and
/// `C(d, u) = sigma_sq / psi(u) * M_nu( (d/beta_s) / psi(u)^{gamma_ns/2} )`.
/// `alpha` in (0, 1] is the temporal smoothness; `gamma_ns` in [0, 1] the
/// space-time interaction (0 = separable).
pub struct UgsmSt;

impl CovKernel for UgsmSt {
    fn name(&self) -> &'static str {
        "ugsm-st"
    }
    fn nparams(&self) -> usize {
        6
    }
    fn param_names(&self) -> &'static [&'static str] {
        &["sigma_sq", "beta_s", "beta_t", "nu", "alpha", "gamma_ns"]
    }
    fn validate(&self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == 6, "ugsm-st expects 6 parameters");
        ensure_pos(theta, self.param_names(), &[0, 1, 2, 3])?;
        ensure!(
            theta[4] > 0.0 && theta[4] <= 1.0,
            "alpha must be in (0, 1], got {}",
            theta[4]
        );
        ensure!(
            (0.0..=1.0).contains(&theta[5]),
            "gamma_ns must be in [0, 1], got {}",
            theta[5]
        );
        Ok(())
    }
    fn cov(&self, theta: &[f64], d: f64, u: f64, _a: usize, _b: usize, _same: bool) -> f64 {
        let (s, bs, bt, nu, alpha, g) =
            (theta[0], theta[1], theta[2], theta[3], theta[4], theta[5]);
        let psi = 1.0 + (u / bt).powf(2.0 * alpha);
        s / psi * matern_correlation((d / bs) / psi.powf(0.5 * g), nu)
    }
}

// ---------------------------------------------------------------------------
// bgsm-st: bivariate Matérn — space-time
// ---------------------------------------------------------------------------

/// Parsimonious bivariate version of the Gneiting space-time kernel:
/// `theta = (s1, s2, beta_s, beta_t, nu1, nu2, alpha, gamma_ns, rho)`;
/// marginals use `nu_a`, the cross term uses `(nu1+nu2)/2`, all share the
/// same space-time geometry.
pub struct BgsmSt;

impl CovKernel for BgsmSt {
    fn name(&self) -> &'static str {
        "bgsm-st"
    }
    fn nparams(&self) -> usize {
        9
    }
    fn param_names(&self) -> &'static [&'static str] {
        &[
            "sigma1_sq",
            "sigma2_sq",
            "beta_s",
            "beta_t",
            "nu1",
            "nu2",
            "alpha",
            "gamma_ns",
            "rho",
        ]
    }
    fn nvariates(&self) -> usize {
        2
    }
    fn validate(&self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == 9, "bgsm-st expects 9 parameters");
        ensure_pos(theta, self.param_names(), &[0, 1, 2, 3, 4, 5])?;
        ensure!(theta[6] > 0.0 && theta[6] <= 1.0, "alpha in (0,1]");
        ensure!((0.0..=1.0).contains(&theta[7]), "gamma_ns in [0,1]");
        let rho_max = parsimonious_rho_max(theta[4], theta[5]);
        ensure!(
            theta[8].abs() <= rho_max,
            "rho = {} violates bound {rho_max:.4}",
            theta[8]
        );
        Ok(())
    }
    fn cov(&self, theta: &[f64], d: f64, u: f64, a: usize, b: usize, _same: bool) -> f64 {
        let (s1, s2, bs, bt) = (theta[0], theta[1], theta[2], theta[3]);
        let (nu1, nu2, alpha, g, rho) = (theta[4], theta[5], theta[6], theta[7], theta[8]);
        let psi = 1.0 + (u / bt).powf(2.0 * alpha);
        let t = (d / bs) / psi.powf(0.5 * g);
        let corr = |nu: f64| matern_correlation(t, nu) / psi;
        match (a, b) {
            (0, 0) => s1 * corr(nu1),
            (1, 1) => s2 * corr(nu2),
            _ => rho * (s1 * s2).sqrt() * corr(0.5 * (nu1 + nu2)),
        }
    }
}

/// Registry lookup by Table III name.
pub fn by_name(name: &str) -> Result<Box<dyn CovKernel>> {
    Ok(match name {
        "ugsm-s" => Box::new(UgsmS),
        "ugsmn-s" => Box::new(UgsmnS),
        "bgsfm-s" => Box::new(BgsfmS),
        "bgspm-s" => Box::new(BgspmS),
        "tgspm-s" => Box::new(TgspmS),
        "ugsm-st" => Box::new(UgsmSt),
        "bgsm-st" => Box::new(BgsmSt),
        other => bail!("unknown kernel {other:?}; see Table III for supported names"),
    })
}

/// All registry names (Table III).
pub const ALL_KERNELS: &[&str] = &[
    "ugsm-s", "ugsmn-s", "bgsfm-s", "bgspm-s", "tgspm-s", "ugsm-st", "bgsm-st",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covariance::{build_cov_dense, DistanceMetric, Location};
    use crate::linalg::blas::dpotrf;
    use crate::rng::Pcg64;

    fn rand_locs(rng: &mut Pcg64, n: usize, st: bool) -> Vec<Location> {
        (0..n)
            .map(|i| {
                Location::new_st(
                    rng.next_f64(),
                    rng.next_f64(),
                    if st { (i % 5) as f64 * 0.3 } else { 0.0 },
                )
            })
            .collect()
    }

    /// Valid example parameters for each kernel.
    fn example_theta(name: &str) -> Vec<f64> {
        match name {
            "ugsm-s" => vec![1.0, 0.1, 0.5],
            "ugsmn-s" => vec![1.0, 0.1, 0.5, 0.2],
            "bgspm-s" => vec![1.0, 1.5, 0.1, 0.5, 1.0, 0.4],
            "bgsfm-s" => vec![1.0, 1.2, 0.12, 0.1, 0.08, 0.5, 1.0, 0.9, 0.3],
            "tgspm-s" => vec![1.0, 1.2, 0.8, 0.1, 0.5, 1.0, 1.5, 0.3, 0.2, 0.25],
            "ugsm-st" => vec![1.0, 0.1, 1.0, 0.5, 0.8, 0.5],
            "bgsm-st" => vec![1.0, 1.3, 0.1, 1.0, 0.5, 1.0, 0.8, 0.5, 0.4],
            other => panic!("no example for {other}"),
        }
    }

    #[test]
    fn registry_covers_table_iii() {
        for &name in ALL_KERNELS {
            let k = by_name(name).unwrap();
            assert_eq!(k.name(), name);
            assert_eq!(k.param_names().len(), k.nparams());
            let theta = example_theta(name);
            assert_eq!(theta.len(), k.nparams(), "{name}");
            k.validate(&theta).unwrap();
        }
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn all_kernels_yield_spd_covariance() {
        // The acid test for kernel validity: the covariance of random
        // locations must admit a Cholesky factorization.
        let mut rng = Pcg64::seed_from_u64(77);
        for &name in ALL_KERNELS {
            let k = by_name(name).unwrap();
            let st = name.ends_with("-st");
            let locs = rand_locs(&mut rng, 24, st);
            let theta = example_theta(name);
            let mut m = build_cov_dense(k.as_ref(), &theta, &locs, DistanceMetric::Euclidean);
            // tiny jitter for numerical safety at colocated variates
            for i in 0..m.rows() {
                m[(i, i)] += 1e-10;
            }
            dpotrf(&mut m).unwrap_or_else(|e| panic!("{name}: covariance not SPD: {e}"));
        }
    }

    #[test]
    fn nugget_only_on_same_site() {
        let k = by_name("ugsmn-s").unwrap();
        let theta = [1.0, 0.1, 0.5, 0.3];
        assert!((k.cov(&theta, 0.0, 0.0, 0, 0, true) - 1.3).abs() < 1e-15);
        // same distance but physically different site: no nugget
        assert!((k.cov(&theta, 0.0, 0.0, 0, 0, false) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn parsimonious_bound_sane() {
        // equal smoothness => bound is 1
        assert!((parsimonious_rho_max(1.0, 1.0) - 1.0).abs() < 1e-12);
        // different smoothness => bound < 1
        let b = parsimonious_rho_max(0.5, 2.5);
        assert!(b < 1.0 && b > 0.0, "{b}");
        // invalid rho rejected
        let k = by_name("bgspm-s").unwrap();
        let theta = [1.0, 1.0, 0.1, 0.5, 2.5, 0.99];
        assert!(k.validate(&theta).is_err());
    }

    #[test]
    fn space_time_separable_when_gamma_zero() {
        let k = by_name("ugsm-st").unwrap();
        let theta = [2.0, 0.1, 1.0, 0.5, 1.0, 0.0];
        // separable: C(d,u) = sigma^2 * M(d/beta_s) * 1/psi(u)
        let d = 0.15;
        let u = 0.7;
        let c = k.cov(&theta, d, u, 0, 0, false);
        let psi = 1.0 + (u / 1.0f64).powf(2.0);
        let want = 2.0 / psi * matern_correlation(d / 0.1, 0.5);
        assert!((c - want).abs() < 1e-14);
        // purely spatial slice reduces to ugsm-s
        let c0 = k.cov(&theta, d, 0.0, 0, 0, false);
        let ks = by_name("ugsm-s").unwrap();
        assert!((c0 - ks.cov(&[2.0, 0.1, 0.5], d, 0.0, 0, 0, false)).abs() < 1e-14);
    }

    #[test]
    fn space_time_decays_in_time() {
        let k = by_name("ugsm-st").unwrap();
        let theta = example_theta("ugsm-st");
        let mut prev = f64::INFINITY;
        for i in 0..10 {
            let c = k.cov(&theta, 0.1, i as f64 * 0.5, 0, 0, false);
            assert!(c < prev);
            prev = c;
        }
    }

    #[test]
    fn bivariate_cross_symmetry() {
        for name in ["bgspm-s", "bgsfm-s", "bgsm-st"] {
            let k = by_name(name).unwrap();
            let theta = example_theta(name);
            let c12 = k.cov(&theta, 0.2, 0.1, 0, 1, false);
            let c21 = k.cov(&theta, 0.2, 0.1, 1, 0, false);
            assert_eq!(c12, c21, "{name}");
        }
    }

    #[test]
    fn flexible_rejects_invalid_geometry() {
        let k = by_name("bgsfm-s").unwrap();
        // nu12 < (nu1+nu2)/2 must be rejected
        let theta = [1.0, 1.0, 0.1, 0.1, 0.1, 1.0, 1.0, 0.5, 0.1];
        assert!(k.validate(&theta).is_err());
    }

    #[test]
    fn trivariate_pd_check() {
        let k = by_name("tgspm-s").unwrap();
        // rho triple that makes the correlation matrix indefinite
        let theta = [1.0, 1.0, 1.0, 0.1, 1.0, 1.0, 1.0, 0.9, 0.9, -0.9];
        assert!(k.validate(&theta).is_err());
    }
}
