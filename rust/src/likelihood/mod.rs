//! Gaussian log-likelihood engines: the four computation variants of
//! Fig 1 — Exact (dense tiles), DST (diagonal super tile), TLR (tile
//! low-rank) and MP (mixed precision) — sharing one tiled-Cholesky design.
//!
//! All engines evaluate, for data `z` at locations `locs` under
//! `kernel(theta)`:
//!
//! ```text
//! l(theta) = -1/2 z^T Sigma^{-1} z - 1/2 log|Sigma| - n/2 log(2 pi)
//! ```
//!
//! via `Sigma = L L^T`, `y = L^{-1} z`, `sse = y^T y`,
//! `log|Sigma| = 2 sum_i log L_ii`.

pub mod exact;
pub mod mp;
pub mod session;
pub mod tlr;

pub use session::EvalSession;

use crate::backend::{ArcEngine, Engine as _};
use crate::covariance::{CovKernel, DistanceMetric, Location};
use crate::pipeline::shard::{shard_set_from_env, ShardSet};
use crate::scheduler::pool::Policy;
use crate::scheduler::profile::Profile;
use crate::scheduler::runtime::{CancelToken, JobHandle, Runtime};
use crate::scheduler::TaskGraph;
use std::sync::Arc;

/// Which covariance representation to use (Fig 1).
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum Variant {
    /// Fully dense tiles (exact likelihood).
    Exact,
    /// Diagonal Super Tile: keep tiles within `band` of the diagonal,
    /// annihilate the rest (`band = 1` reproduces Fig 1(b)).
    Dst { band: usize },
    /// Tile Low-Rank: off-diagonal tiles SVD-compressed to `tol` /
    /// `max_rank`.
    Tlr { tol: f64, max_rank: usize },
    /// Mixed precision: off-band tiles stored in f32 (band tiles stay f64).
    Mp { band: usize },
}

/// Execution context shared by the engines (the `exageostat_init`
/// hardware settings), plus the compute backend picked at construction
/// (`EXAGEOSTAT_BACKEND=native|pjrt` overrides the default — see
/// [`crate::backend::default_engine`]) and the **persistent task
/// runtime**: `ncores` worker threads are spawned once, here, and every
/// task-graph job of this context (likelihood pipelines, simulation,
/// kriging) is multiplexed onto them.  Clones share the same runtime.
#[derive(Clone)]
pub struct ExecCtx {
    /// Worker count of `runtime` (descriptive; execution always follows
    /// the runtime).  Build contexts through the constructors so these
    /// fields cannot disagree with the runtime that actually executes.
    pub ncores: usize,
    pub ts: usize,
    /// Scheduling policy of `runtime` (descriptive — see `ncores`).
    pub policy: Policy,
    /// Compute backend for covariance generation and dense likelihood.
    pub engine: ArcEngine,
    /// Long-lived worker runtime (shut down when the last clone drops,
    /// or explicitly via `ExaGeoStat::finalize`).
    pub runtime: Arc<Runtime>,
    /// Job priority for graphs submitted through this context: the
    /// coordinator's per-request fairness tie-break (0 = default).
    pub job_prio: u8,
    /// Cancellation token carried into every job submitted through this
    /// context: once fired, workers skip this context's not-yet-started
    /// tasks and the MLE driver stops between objective evaluations.
    /// Defaults to a fresh (never-fired) token.
    pub cancel: CancelToken,
    /// Optional shard set: when present (and the problem is large enough
    /// — see `ShardSet::min_nt`), tiled pipelines are partitioned 2-D
    /// block-cyclic across its runtimes instead of running as one job on
    /// `runtime` (`pipeline::shard`).  `ExecCtx::with_engine` attaches
    /// one from `EXAGEOSTAT_SHARDS`; the coordinator route attaches its
    /// own via `Coordinator::attach_shards`.
    pub shards: Option<Arc<ShardSet>>,
    /// Out-of-core tile budget in bytes: `Some` makes every tiled
    /// workspace allocated through this context a budget-bounded
    /// spill-backed matrix (`TileMatrix::zeros_spill`), executed by the
    /// plan-aware spill sweep.  `None` (the default) is the fully
    /// resident fast path — zero overhead, bit-identical to pre-budget
    /// behaviour.  `ExecCtx::with_engine` seeds this from
    /// `EXAGEOSTAT_TILE_BUDGET`; the coordinator route plumbs its
    /// `--mem-budget` share instead.
    pub tile_budget: Option<usize>,
}

impl ExecCtx {
    pub fn new(ncores: usize, ts: usize, policy: Policy) -> ExecCtx {
        ExecCtx::with_engine(ncores, ts, policy, crate::backend::default_engine())
    }

    /// Build a context around an explicit compute backend, spawning a
    /// fresh runtime of `ncores` workers.  The worker-class layout comes
    /// from `EXAGEOSTAT_WORKER_CLASSES` / `--worker-classes` (fitted to
    /// `ncores`; default: one homogeneous `Cpu` class — identical to the
    /// pre-class runtime).
    pub fn with_engine(ncores: usize, ts: usize, policy: Policy, engine: ArcEngine) -> ExecCtx {
        let ncores = ncores.max(1);
        let spec = crate::scheduler::placement::class_spec_for(ncores);
        ExecCtx {
            ncores,
            ts,
            policy,
            engine,
            runtime: Arc::new(Runtime::new_with_classes(&spec, policy)),
            job_prio: 0,
            cancel: CancelToken::new(),
            shards: shard_set_from_env(),
            tile_budget: crate::linalg::tile::tile_budget_from_env(),
        }
    }

    /// Build a context that *shares* an existing runtime (the coordinator
    /// hands every request the same one).
    pub fn with_runtime(runtime: Arc<Runtime>, ts: usize, engine: ArcEngine) -> ExecCtx {
        ExecCtx {
            ncores: runtime.nworkers(),
            ts,
            policy: runtime.policy(),
            engine,
            runtime,
            job_prio: 0,
            cancel: CancelToken::new(),
            shards: None,
            tile_budget: crate::linalg::tile::tile_budget_from_env(),
        }
    }

    /// Allocate the tiled factor workspace this context's budget calls
    /// for: fully resident without a budget, spill-backed under one.
    /// `mp_band` selects mixed-precision storage (the MP variant).
    pub fn alloc_tile_matrix(&self, n: usize) -> anyhow::Result<crate::linalg::tile::TileMatrix> {
        self.alloc_tile_matrix_mp(n, None)
    }

    /// See [`ExecCtx::alloc_tile_matrix`].
    pub fn alloc_tile_matrix_mp(
        &self,
        n: usize,
        mp_band: Option<usize>,
    ) -> anyhow::Result<crate::linalg::tile::TileMatrix> {
        use crate::linalg::tile::TileMatrix;
        if let Some(budget) = self.tile_budget {
            match TileMatrix::zeros_spill(n, self.ts, mp_band, budget) {
                Ok(tm) => return Ok(tm),
                Err(e) => {
                    // No spill file (tmpdir full, read-only, …): degrade
                    // to resident mode — correct but unbudgeted — rather
                    // than failing every request up front.
                    eprintln!(
                        "exageostat: warning: cannot create tile spill store ({e}); \
                         memory budget disabled, running fully resident"
                    );
                }
            }
        }
        Ok(match mp_band {
            Some(band) => TileMatrix::zeros_mp(n, self.ts, band),
            None => TileMatrix::zeros(n, self.ts),
        })
    }

    /// Submit a task graph as one job on this context's runtime,
    /// carrying the context's job priority and cancellation token.
    pub fn submit(&self, g: TaskGraph) -> JobHandle {
        self.runtime.submit_job(g, self.job_prio, self.cancel.clone())
    }

    /// Submit a task graph and block until it completes.
    ///
    /// # Panics
    /// Re-raises the first task panic ([`JobHandle::wait`] semantics).
    /// Recovery-aware callers use [`ExecCtx::run_graph_result`].
    pub fn run_graph(&self, g: TaskGraph) -> Profile {
        self.submit(g).wait()
    }

    /// [`ExecCtx::run_graph`] reporting the job's first
    /// [`TaskError`](crate::scheduler::runtime::TaskError) as a value
    /// instead of re-raising it — the pipeline's recovery seam.
    pub fn run_graph_result(
        &self,
        g: TaskGraph,
    ) -> Result<Profile, crate::scheduler::runtime::TaskError> {
        self.submit(g).wait_result()
    }
}

impl Default for ExecCtx {
    fn default() -> Self {
        ExecCtx::new(1, 320, Policy::Lws)
    }
}

impl std::fmt::Debug for ExecCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecCtx")
            .field("ncores", &self.ncores)
            .field("ts", &self.ts)
            .field("policy", &self.policy)
            .field("backend", &self.engine.name())
            .finish()
    }
}

/// Result of one likelihood evaluation.
#[derive(Copy, Clone, Debug)]
pub struct LogLik {
    pub loglik: f64,
    pub logdet: f64,
    pub sse: f64,
    pub n: usize,
}

impl LogLik {
    pub fn assemble(logdet: f64, sse: f64, n: usize) -> LogLik {
        let loglik =
            -0.5 * sse - 0.5 * logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
        LogLik {
            loglik,
            logdet,
            sse,
            n,
        }
    }
}

/// Problem description handed to an engine (everything immutable and
/// shareable across optimizer iterations).
pub struct Problem {
    pub kernel: Arc<dyn CovKernel>,
    pub locs: Arc<Vec<Location>>,
    pub z: Arc<Vec<f64>>,
    pub metric: DistanceMetric,
}

impl Problem {
    /// Observation-vector length (`p * n` for multivariate kernels).
    pub fn dim(&self) -> usize {
        self.kernel.nvariates() * self.locs.len()
    }
}

/// Evaluate the log-likelihood under the chosen variant.
pub fn loglik(
    problem: &Problem,
    theta: &[f64],
    variant: Variant,
    ctx: &ExecCtx,
) -> anyhow::Result<LogLik> {
    anyhow::ensure!(
        problem.z.len() == problem.dim(),
        "z has length {} but kernel/locations imply {}",
        problem.z.len(),
        problem.dim()
    );
    problem.kernel.validate(theta)?;
    match variant {
        Variant::Exact => exact::loglik(problem, theta, None, ctx),
        Variant::Dst { band } => exact::loglik(problem, theta, Some(band), ctx),
        Variant::Tlr { tol, max_rank } => tlr::loglik(problem, theta, tol, max_rank, ctx),
        Variant::Mp { band } => mp::loglik(problem, theta, band, ctx),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::covariance::kernel_by_name;
    use crate::rng::Pcg64;

    /// Small reference problem: irregular locations + GRF-ish data
    /// (the data need not be a true GRF sample for likelihood-value
    /// comparisons between engines).
    pub fn small_problem(n: usize, seed: u64) -> Problem {
        let mut rng = Pcg64::seed_from_u64(seed);
        let locs: Vec<Location> = (0..n)
            .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
            .collect();
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        Problem {
            kernel: kernel_by_name("ugsm-s").unwrap().into(),
            locs: Arc::new(locs),
            z: Arc::new(z),
            metric: DistanceMetric::Euclidean,
        }
    }

    /// Dense-oracle log-likelihood (plain Cholesky, no tiles).
    pub fn dense_oracle(p: &Problem, theta: &[f64]) -> LogLik {
        let mut sigma =
            crate::covariance::build_cov_dense(p.kernel.as_ref(), theta, &p.locs, p.metric);
        let (logdet, y) =
            crate::linalg::cholesky::dense_chol_solve(&mut sigma, &p.z).expect("SPD");
        let sse = y.iter().map(|v| v * v).sum();
        LogLik::assemble(logdet, sse, p.dim())
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn variants_agree_in_their_exact_limits() {
        let p = small_problem(60, 1);
        let theta = [1.0, 0.1, 0.5];
        let ctx = ExecCtx::new(2, 16, Policy::Prio);
        let oracle = dense_oracle(&p, &theta);
        let exact = loglik(&p, &theta, Variant::Exact, &ctx).unwrap();
        assert!(
            (exact.loglik - oracle.loglik).abs() < 1e-8,
            "exact {} vs oracle {}",
            exact.loglik,
            oracle.loglik
        );
        // DST with full bandwidth == exact
        let nt = 60usize.div_ceil(16);
        let dst = loglik(&p, &theta, Variant::Dst { band: nt - 1 }, &ctx).unwrap();
        assert!((dst.loglik - oracle.loglik).abs() < 1e-8);
        // TLR with tol -> 0 == exact
        let tlr = loglik(
            &p,
            &theta,
            Variant::Tlr {
                tol: 1e-14,
                max_rank: usize::MAX,
            },
            &ctx,
        )
        .unwrap();
        assert!(
            (tlr.loglik - oracle.loglik).abs() < 1e-6,
            "tlr {} vs oracle {}",
            tlr.loglik,
            oracle.loglik
        );
        // MP with full band == exact
        let mp = loglik(&p, &theta, Variant::Mp { band: nt - 1 }, &ctx).unwrap();
        assert!((mp.loglik - oracle.loglik).abs() < 1e-8);
    }

    #[test]
    fn approximations_close_but_not_exact() {
        let p = small_problem(80, 2);
        let theta = [1.0, 0.05, 0.5]; // short range => band approx is good
        let ctx = ExecCtx::new(1, 16, Policy::Eager);
        let oracle = dense_oracle(&p, &theta);
        let dst = loglik(&p, &theta, Variant::Dst { band: 1 }, &ctx).unwrap();
        let mp = loglik(&p, &theta, Variant::Mp { band: 0 }, &ctx).unwrap();
        let tlr = loglik(
            &p,
            &theta,
            Variant::Tlr {
                tol: 1e-4,
                max_rank: 8,
            },
            &ctx,
        )
        .unwrap();
        // MP should be closer to exact than DST with the same band=0 logic,
        // since it rounds instead of zeroing (the paper's motivation).
        let dst0 = loglik(&p, &theta, Variant::Dst { band: 0 }, &ctx).unwrap();
        let err_dst0 = (dst0.loglik - oracle.loglik).abs();
        let err_mp = (mp.loglik - oracle.loglik).abs();
        assert!(
            err_mp < err_dst0,
            "MP {err_mp} should beat DST(0) {err_dst0}"
        );
        // All approximations in a sane neighbourhood.
        for (name, v) in [("dst", dst.loglik), ("mp", mp.loglik), ("tlr", tlr.loglik)] {
            let rel = (v - oracle.loglik).abs() / oracle.loglik.abs();
            assert!(rel < 0.2, "{name}: {v} vs {}", oracle.loglik);
        }
        // TLR accuracy is controlled by its tolerance knob.
        let tlr_tight = loglik(
            &p,
            &theta,
            Variant::Tlr {
                tol: 1e-8,
                max_rank: usize::MAX,
            },
            &ctx,
        )
        .unwrap();
        let err_tlr = (tlr.loglik - oracle.loglik).abs();
        let err_tight = (tlr_tight.loglik - oracle.loglik).abs();
        assert!(
            err_tight < err_tlr.max(1e-9),
            "tight {err_tight} vs loose {err_tlr}"
        );
    }

    #[test]
    fn rejects_bad_theta_and_shape() {
        let p = small_problem(10, 3);
        let ctx = ExecCtx::default();
        assert!(loglik(&p, &[1.0, -0.1, 0.5], Variant::Exact, &ctx).is_err());
        let mut bad = small_problem(10, 4);
        bad.z = Arc::new(vec![0.0; 7]);
        assert!(loglik(&bad, &[1.0, 0.1, 0.5], Variant::Exact, &ctx).is_err());
    }
}
