//! `EvalSession` — the iteration-aware evaluation layer between
//! [`Problem`] and the four likelihood engines.
//!
//! The MLE hot loop (Table V: hundreds of BOBYQA iterations) re-evaluates
//! the likelihood at a new `theta` while everything else — locations,
//! metric, kernel, tile grid, data vector — stays fixed.  The plain
//! [`super::loglik`] entry point treats every call as cold: it reorders
//! locations, recomputes every pairwise distance and allocates a fresh
//! [`TileMatrix`] each time.  A session hoists all of that out of the
//! loop:
//!
//! * **Morton permutation** resolved once (per variant, matching the cold
//!   paths' reordering rules exactly, so results are bit-compatible);
//! * **distance-tile cache** ([`DistCache`]): per-tile `Arc`-shared
//!   blocks of spatial distances (and temporal lags when present),
//!   metric-resolved once — warm generation evaluates the kernel straight
//!   from the cache through the [`crate::backend::Engine::fill_tile`]
//!   fast path, and mirrors diagonal tiles instead of evaluating their
//!   upper halves;
//! * **workspace reuse**: the `TileMatrix` factor storage (mixed
//!   precision for the MP variant) and the `TileVector` solve vector are
//!   allocated once and reloaded per iteration, and the runtime workers'
//!   thread-local pack buffers are pre-grown at session build
//!   (`Runtime::prewarm_workers` → `blas::reserve_pack_workspaces`), so
//!   warm iterations perform zero large allocations — on the submitting
//!   thread *and* on the workers (guarded by the `tile_matrix_allocs`
//!   and `pack_buffer_allocs` regression tests).
//!
//! `api::ExaGeoStat::mle` routes every optimizer objective evaluation
//! through a session; one-shot callers can keep using `likelihood::loglik`.

use super::{exact, mp, ExecCtx, LogLik, Problem, Variant};
use crate::covariance::{morton_perm, DistCache};
use crate::linalg::lowrank::LrOpts;
use crate::linalg::tile::{TileMatrix, TileVector};
use std::sync::Arc;

/// Reusable factor + solve-vector storage for the tiled variants
/// (exact / DST / MP).  TLR owns no equivalent: its low-rank tiles are
/// rank-adaptive per `theta`, so their storage is intrinsically
/// per-iteration.
struct TiledWorkspace {
    a: TileMatrix,
    y: TileVector,
}

/// One MLE run's evaluation state: construct once, call
/// [`EvalSession::eval`] per optimizer iteration.
pub struct EvalSession {
    variant: Variant,
    ctx: ExecCtx,
    /// Locations/kernel/metric/data in final (possibly Morton-permuted)
    /// order; `problem.z` is the observation vector warm solves reload.
    problem: Problem,
    dist: Arc<DistCache>,
    tiled: Option<TiledWorkspace>,
    /// TLR forward-solve scratch (reused across iterations).
    y_scratch: Vec<f64>,
    evals: usize,
}

impl EvalSession {
    /// Build a session for `variant`.  Validates the data shape, applies
    /// the variant's location reordering, precomputes the distance tiles
    /// and allocates the iteration workspace.
    pub fn new(problem: &Problem, variant: Variant, ctx: &ExecCtx) -> anyhow::Result<EvalSession> {
        let dim = problem.dim();
        anyhow::ensure!(
            problem.z.len() == dim,
            "z has length {} but kernel/locations imply {}",
            problem.z.len(),
            dim
        );
        if let Variant::Tlr { .. } = variant {
            anyhow::ensure!(
                problem.kernel.nvariates() == 1,
                "TLR path currently supports univariate kernels"
            );
        }
        // Reordering rules must mirror the cold paths exactly (the warm
        // result is then identical): DST and TLR Morton-sort univariate
        // problems; exact and MP evaluate in user order.
        let permute = match variant {
            Variant::Exact => false,
            Variant::Dst { .. } => problem.kernel.nvariates() == 1,
            Variant::Mp { .. } => false,
            Variant::Tlr { .. } => true,
        };
        let (locs, z) = if permute {
            let perm = morton_perm(&problem.locs);
            let locs: Vec<_> = perm.iter().map(|&i| problem.locs[i]).collect();
            let z: Vec<f64> = perm.iter().map(|&i| problem.z[i]).collect();
            (Arc::new(locs), Arc::new(z))
        } else {
            (problem.locs.clone(), problem.z.clone())
        };
        // Only DST never touches off-band tiles; the other variants need
        // the full lower triangle of distance blocks.
        let band = match variant {
            Variant::Dst { band } => Some(band),
            _ => None,
        };
        let dist = Arc::new(DistCache::build(
            &locs,
            problem.metric,
            problem.kernel.nvariates(),
            ctx.ts,
            band,
        ));
        let tiled = match variant {
            Variant::Tlr { .. } => None,
            // MP stores off-band tiles as f32 — the workspace must carry
            // the same per-tile precision layout the pipeline expects.
            // A context with a tile budget gets a spill-backed workspace
            // instead (same layout, peak-resident <= budget), persisting
            // across warm iterations like the resident one.
            Variant::Mp { band } => Some(TiledWorkspace {
                a: ctx.alloc_tile_matrix_mp(dim, Some(band))?,
                y: TileVector::from_slice(&z, ctx.ts),
            }),
            _ => Some(TiledWorkspace {
                a: ctx.alloc_tile_matrix(dim)?,
                y: TileVector::from_slice(&z, ctx.ts),
            }),
        };
        // Best-effort: grow every runtime worker's thread-local pack
        // workspace up front, so even the first evaluation's tile kernels
        // run allocation-free (warm iterations are guarded by the
        // pack-buffer regression test either way).  Deduplicated per
        // runtime by tile size: repeat session builds on a shared
        // (coordinator) runtime skip it.
        let ts = ctx.ts;
        ctx.runtime
            .prewarm_workers_once(ts, move || crate::linalg::blas::reserve_pack_workspaces(ts));
        Ok(EvalSession {
            variant,
            ctx: ctx.clone(),
            problem: Problem {
                kernel: problem.kernel.clone(),
                locs,
                z,
                metric: problem.metric,
            },
            dist,
            tiled,
            y_scratch: Vec::new(),
            evals: 0,
        })
    }

    /// Evaluate the log-likelihood at `theta`.  Warm calls reuse the
    /// cached distances and workspaces; the value matches a cold
    /// [`super::loglik`] on the original problem.
    pub fn eval(&mut self, theta: &[f64]) -> anyhow::Result<LogLik> {
        self.evals += 1;
        self.problem.kernel.validate(theta)?;
        match self.variant {
            Variant::Exact => self.eval_tiled(theta, None, false),
            Variant::Dst { band } => self.eval_tiled(theta, Some(band), false),
            Variant::Mp { band } => self.eval_tiled(theta, Some(band), true),
            Variant::Tlr { tol, max_rank } => self.eval_tlr(theta, tol, max_rank),
        }
    }

    fn eval_tiled(
        &mut self,
        theta: &[f64],
        band: Option<usize>,
        mp: bool,
    ) -> anyhow::Result<LogLik> {
        let ws = self.tiled.as_mut().expect("tiled workspace present");
        ws.y.load(&self.problem.z);
        if mp {
            mp::run_pipeline(
                &self.problem,
                theta,
                band.unwrap_or(0),
                &self.ctx,
                Some(&*self.dist),
                &ws.a,
                &ws.y,
            )
        } else {
            exact::run_pipeline(
                &self.problem,
                theta,
                band,
                &self.ctx,
                Some(&*self.dist),
                &ws.a,
                &ws.y,
            )
        }
    }

    fn eval_tlr(&mut self, theta: &[f64], tol: f64, max_rank: usize) -> anyhow::Result<LogLik> {
        let opts = LrOpts { tol, max_rank };
        self.y_scratch.clear();
        self.y_scratch.extend_from_slice(&self.problem.z);
        let out = crate::pipeline::run_tlr(
            &self.problem,
            theta,
            opts,
            &self.ctx,
            Some(&*self.dist),
            &mut self.y_scratch,
        )?;
        if let Some(pivot) = out.not_spd {
            return Err(anyhow::Error::new(
                crate::scheduler::runtime::TaskError::Numerical(format!(
                    "TLR covariance not positive definite at pivot {pivot}"
                )),
            ));
        }
        let sse = self.y_scratch.iter().map(|v| v * v).sum();
        Ok(LogLik::assemble(out.logdet, sse, self.problem.dim()))
    }

    /// Evaluations performed so far (successful or failed).
    pub fn evals(&self) -> usize {
        self.evals
    }

    /// The covariance kernel this session evaluates (the coordinator and
    /// `api::mle_with_session` read its arity/name for validation).
    pub fn kernel(&self) -> &dyn crate::covariance::CovKernel {
        self.problem.kernel.as_ref()
    }

    /// Set the job priority this session's submissions carry from now
    /// on.  The coordinator applies the *current* request's priority
    /// before driving a cached session (whose captured context would
    /// otherwise keep the priority of the request that built it).
    pub fn set_job_prio(&mut self, prio: u8) {
        self.ctx.job_prio = prio;
    }

    /// The cancellation token this session's submissions carry (the
    /// MLE driver polls it between objective evaluations).
    pub fn cancel_token(&self) -> &crate::scheduler::runtime::CancelToken {
        &self.ctx.cancel
    }

    /// Bind this session to `token` from now on: like
    /// [`EvalSession::set_job_prio`], the coordinator rebinds a cached
    /// session to the *current* request's token (the captured context
    /// would otherwise keep — possibly already-fired — the token of the
    /// request that built it).
    pub fn set_cancel(&mut self, token: crate::scheduler::runtime::CancelToken) {
        self.ctx.cancel = token;
    }

    /// The variant this session evaluates.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Doubles held by the distance cache (memory telemetry).
    pub fn dist_storage_len(&self) -> usize {
        self.dist.storage_len()
    }

    /// The execution context this session drives evaluations through
    /// (runtime telemetry, budget introspection).
    pub fn ctx(&self) -> &ExecCtx {
        &self.ctx
    }

    /// Effective out-of-core tile budget of this session's workspace in
    /// bytes, `None` when the workspace is fully resident (no budget, or
    /// a TLR session — TLR tiles are rank-adaptive heap storage).
    pub fn tile_budget(&self) -> Option<usize> {
        self.tiled
            .as_ref()
            .and_then(|ws| ws.a.store())
            .map(|st| st.budget())
    }

    /// High-water mark of resident tile bytes in this session's
    /// out-of-core workspace (`None` when fully resident).  The number
    /// the budget bounds: `peak_resident_tile_bytes() <= tile_budget()`
    /// is asserted by the spill test suite.
    pub fn peak_resident_tile_bytes(&self) -> Option<usize> {
        self.tiled
            .as_ref()
            .and_then(|ws| ws.a.store())
            .map(|st| st.peak_resident_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::likelihood;
    use crate::likelihood::testutil::small_problem;
    use crate::scheduler::pool::Policy;

    #[test]
    fn session_matches_cold_loglik_for_every_variant() {
        let p = small_problem(50, 7);
        let theta = [1.1, 0.08, 0.5];
        let ctx = ExecCtx::new(2, 16, Policy::Lws);
        let nt = 50usize.div_ceil(16);
        for variant in [
            Variant::Exact,
            Variant::Dst { band: 1 },
            Variant::Dst { band: nt - 1 },
            Variant::Mp { band: 1 },
            Variant::Tlr {
                tol: 1e-7,
                max_rank: usize::MAX,
            },
        ] {
            let cold = likelihood::loglik(&p, &theta, variant, &ctx).unwrap();
            let mut s = EvalSession::new(&p, variant, &ctx).unwrap();
            for pass in 0..3 {
                let warm = s.eval(&theta).unwrap();
                assert!(
                    (warm.loglik - cold.loglik).abs() < 1e-12,
                    "{variant:?} pass {pass}: warm {} vs cold {}",
                    warm.loglik,
                    cold.loglik
                );
                assert!((warm.logdet - cold.logdet).abs() < 1e-12);
                assert!((warm.sse - cold.sse).abs() < 1e-12);
            }
            assert_eq!(s.evals(), 3);
        }
    }

    #[test]
    fn session_rejects_bad_shapes() {
        let mut p = small_problem(10, 8);
        let ctx = ExecCtx::new(1, 4, Policy::Eager);
        p.z = Arc::new(vec![0.0; 7]);
        assert!(EvalSession::new(&p, Variant::Exact, &ctx).is_err());
        let p2 = small_problem(10, 9);
        let mut s = EvalSession::new(&p2, Variant::Exact, &ctx).unwrap();
        assert!(s.eval(&[1.0, -0.1, 0.5]).is_err());
        // a failed eval does not poison the session
        assert!(s.eval(&[1.0, 0.1, 0.5]).is_ok());
    }

    #[test]
    fn non_spd_theta_reported_then_recoverable() {
        // Duplicate locations without nugget => singular covariance; the
        // session must surface the error and stay usable (BOBYQA probes
        // infeasible corners routinely).
        let mut p = small_problem(12, 10);
        let mut locs = (*p.locs).clone();
        locs[5] = locs[4];
        p.locs = Arc::new(locs);
        let ctx = ExecCtx::new(1, 4, Policy::Eager);
        let mut s = EvalSession::new(&p, Variant::Exact, &ctx).unwrap();
        let err = s.eval(&[1.0, 0.1, 0.5]).unwrap_err();
        assert!(err.to_string().contains("not positive definite"), "{err}");
    }
}
