//! Mixed-precision (MP) log-likelihood (Fig 1(d); Abdulah et al. 2019).
//!
//! Like DST, tiles far from the diagonal are treated specially — but
//! instead of being annihilated they are *demoted to single precision*:
//! their entries are rounded through f32 at generation time and their GEMM
//! updates execute through an f32 accumulate path.  Near-diagonal tiles
//! (within `band`) stay fully double precision.  This reproduces the
//! accuracy behaviour (f32 rounding of weak interactions) and the
//! performance model (half-width arithmetic on the off-band bulk) of the
//! paper's MP variant.

use super::{ExecCtx, LogLik, Problem};
use crate::backend::{ArcEngine, Engine as _};
use crate::covariance::DistCache;
use crate::linalg::cholesky::{
    check_fail, new_fail_flag, submit_tiled_forward_solve_banded, submit_tiled_potrf, TileHandles,
};
use crate::linalg::tile::{TileMatrix, TileVector};
use crate::scheduler::{Access, TaskGraph, TaskKind};
use std::sync::Arc;

/// Is tile (i, j) kept in full precision?
#[inline]
pub fn is_f64_tile(band: usize, i: usize, j: usize) -> bool {
    i - j <= band
}

/// Round a buffer through f32 (the MP storage demotion).
pub fn demote_f32(buf: &mut [f64]) {
    for v in buf.iter_mut() {
        *v = *v as f32 as f64;
    }
}

/// Submit MP generation tasks: every lower tile is generated; off-band
/// tiles are rounded through f32.
#[allow(clippy::too_many_arguments)]
fn submit_generation_mp(
    g: &mut TaskGraph,
    a: &TileMatrix,
    hs: &TileHandles,
    problem: &Problem,
    theta: &[f64],
    band: usize,
    engine: &ArcEngine,
    dist: Option<&DistCache>,
) {
    let nt = a.nt();
    let ts = a.ts();
    let bytes = a.tile_bytes();
    let theta: Arc<Vec<f64>> = Arc::new(theta.to_vec());
    for i in 0..nt {
        for j in 0..=i {
            let h = a.tile_rows(i);
            let w = a.tile_cols(j);
            let ptr = a.tile_ptr(i, j);
            let kernel = problem.kernel.clone();
            let locs = problem.locs.clone();
            let metric = problem.metric;
            let theta = theta.clone();
            let engine = engine.clone();
            let block = dist.and_then(|c| c.block(i, j));
            let (row0, col0) = (i * ts, j * ts);
            let demote = !is_f64_tile(band, i, j);
            g.submit(TaskKind::DCMG, &[(hs.at(i, j), Access::W)], bytes, move || {
                // SAFETY: STF ordering gives exclusive access to the tile.
                let out = unsafe { ptr.as_mut() };
                engine.fill_tile(
                    kernel.as_ref(),
                    &theta,
                    &locs,
                    metric,
                    row0,
                    col0,
                    h,
                    w,
                    block.as_deref(),
                    out,
                );
                if demote {
                    demote_f32(out);
                }
            });
        }
    }
}

/// Evaluate the mixed-precision log-likelihood.  `band` counts the tile
/// diagonals kept in f64 (`band = 0`: only diagonal tiles full precision).
pub fn loglik(
    problem: &Problem,
    theta: &[f64],
    band: usize,
    ctx: &ExecCtx,
) -> anyhow::Result<LogLik> {
    let dim = problem.dim();
    let a = TileMatrix::zeros(dim, ctx.ts);
    let y = TileVector::from_slice(&problem.z, ctx.ts);
    run_pipeline(problem, theta, band, ctx, None, &a, &y)
}

/// MP pipeline over caller-owned storage (see
/// [`super::exact::run_pipeline`] for the workspace-reuse contract).
pub(crate) fn run_pipeline(
    problem: &Problem,
    theta: &[f64],
    band: usize,
    ctx: &ExecCtx,
    dist: Option<&DistCache>,
    a: &TileMatrix,
    y: &TileVector,
) -> anyhow::Result<LogLik> {
    let mut g = TaskGraph::new();
    let hs = TileHandles::register(&mut g, a.nt());
    submit_generation_mp(&mut g, a, &hs, problem, theta, band, &ctx.engine, dist);
    let fail = new_fail_flag();
    // Factorization is structurally dense (band = None): MP rounds values,
    // it does not drop tiles.
    submit_tiled_potrf(&mut g, a, &hs, None, &fail);
    let yh = g.register_many(y.nt());
    submit_tiled_forward_solve_banded(&mut g, a, &hs, y, &yh, None);
    ctx.run_graph(g);
    check_fail(&fail).map_err(|e| {
        anyhow::anyhow!(
            "MP covariance not positive definite at pivot {} (theta = {theta:?})",
            e.pivot
        )
    })?;
    let logdet = 2.0 * a.diag_sum(f64::ln);
    let sse = y.dot_self();
    Ok(LogLik::assemble(logdet, sse, a.n()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::likelihood::testutil::{dense_oracle, small_problem};
    use crate::scheduler::pool::Policy;

    #[test]
    fn demote_rounds_to_f32() {
        let mut v = vec![1.0 + 1e-12, std::f64::consts::PI];
        demote_f32(&mut v);
        assert_eq!(v[0], 1.0);
        assert_eq!(v[1], std::f64::consts::PI as f32 as f64);
    }

    #[test]
    fn mp_error_is_f32_scale() {
        let p = small_problem(64, 30);
        let theta = [1.0, 0.1, 0.5];
        let ctx = ExecCtx::new(2, 16, Policy::Lws);
        let oracle = dense_oracle(&p, &theta);
        let mp = loglik(&p, &theta, 0, &ctx).unwrap();
        let rel = (mp.loglik - oracle.loglik).abs() / oracle.loglik.abs();
        // f32 rounding of off-diagonal tiles: relative error well below
        // 1e-3 but (generically) nonzero.
        assert!(rel < 1e-3, "rel {rel}");
        assert!(rel > 0.0, "suspiciously exact");
    }

    #[test]
    fn wider_band_is_more_accurate() {
        let p = small_problem(80, 31);
        let theta = [1.0, 0.2, 1.0];
        let ctx = ExecCtx::new(1, 16, Policy::Eager);
        let oracle = dense_oracle(&p, &theta);
        let e0 = (loglik(&p, &theta, 0, &ctx).unwrap().loglik - oracle.loglik).abs();
        let e_full = (loglik(&p, &theta, 4, &ctx).unwrap().loglik - oracle.loglik).abs();
        assert!(e_full <= e0, "band 4 err {e_full} vs band 0 err {e0}");
        assert!(e_full < 1e-9, "full band must be exact, err {e_full}");
    }

    #[test]
    fn is_f64_tile_band_logic() {
        assert!(is_f64_tile(0, 3, 3));
        assert!(!is_f64_tile(0, 4, 3));
        assert!(is_f64_tile(2, 5, 3));
        assert!(!is_f64_tile(1, 5, 3));
    }
}
