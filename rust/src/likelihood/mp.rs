//! Mixed-precision (MP) log-likelihood (Fig 1(d); Abdulah et al. 2019).
//!
//! Like DST, tiles far from the diagonal are treated specially — but
//! instead of being annihilated they are *demoted to single precision*:
//! off-band tiles are **stored as f32** ([`TileMatrix::zeros_mp`]) and
//! every factorization update touching them executes through the f32
//! micro-kernel path (`linalg::blas::gemm_mp` and friends — operands
//! demoted while packing, f64 accumulation at tile boundaries).
//! Near-diagonal tiles (within `band`) stay fully double precision.
//! This reproduces both the accuracy behaviour (f32 rounding of weak
//! interactions) and the performance behaviour (half-width storage and
//! arithmetic on the off-band bulk) of the paper's MP variant — a
//! measured speedup, not a simulated rounding.

use super::{ExecCtx, LogLik, Problem};
use crate::covariance::DistCache;
use crate::linalg::tile::{TileMatrix, TileVector};

/// Is tile (i, j) kept in full precision?  Delegates to the single
/// storage-rule predicate next to [`TileMatrix::zeros_mp`], so the MP
/// semantics and the workspace layout cannot drift apart.
#[inline]
pub fn is_f64_tile(band: usize, i: usize, j: usize) -> bool {
    crate::linalg::tile::mp_tile_is_f64(band, i, j)
}

/// Round a buffer through f32 (the MP storage demotion — what storing a
/// tile as f32 does value-wise; kept for tests and oracles).
pub fn demote_f32(buf: &mut [f64]) {
    for v in buf.iter_mut() {
        *v = *v as f32 as f64;
    }
}

/// Evaluate the mixed-precision log-likelihood.  `band` counts the tile
/// diagonals kept in f64 (`band = 0`: only diagonal tiles full precision).
pub fn loglik(
    problem: &Problem,
    theta: &[f64],
    band: usize,
    ctx: &ExecCtx,
) -> anyhow::Result<LogLik> {
    let dim = problem.dim();
    // Budgeted contexts get an out-of-core MP workspace (same f32
    // off-band layout, spill-backed); unbudgeted ones stay resident.
    let a = ctx.alloc_tile_matrix_mp(dim, Some(band))?;
    let y = TileVector::from_slice(&problem.z, ctx.ts);
    run_pipeline(problem, theta, band, ctx, None, &a, &y)
}

/// MP pipeline over caller-owned storage (see
/// [`super::exact::run_pipeline`] for the workspace-reuse contract).
/// `a` must be mixed-precision storage allocated with the same `band`
/// ([`TileMatrix::zeros_mp`]).
pub(crate) fn run_pipeline(
    problem: &Problem,
    theta: &[f64],
    band: usize,
    ctx: &ExecCtx,
    dist: Option<&DistCache>,
    a: &TileMatrix,
    y: &TileVector,
) -> anyhow::Result<LogLik> {
    debug_assert_eq!(a.mp_band(), Some(band), "workspace band mismatch");
    // The *structural* band is None: MP demotes values and arithmetic,
    // it does not drop tiles — the per-tile precision dispatch rides on
    // `a`'s mixed-precision storage layout inside the pipeline runner.
    let out = crate::pipeline::run_tiled(problem, theta, ctx, dist, a, Some(y), None, true)?;
    if let Some(pivot) = out.not_spd {
        return Err(anyhow::Error::new(crate::scheduler::runtime::TaskError::Numerical(
            format!("MP covariance not positive definite at pivot {pivot} (theta = {theta:?})"),
        )));
    }
    Ok(LogLik::assemble(out.logdet, y.dot_self(), a.n()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::likelihood::testutil::{dense_oracle, small_problem};
    use crate::scheduler::pool::Policy;

    #[test]
    fn demote_rounds_to_f32() {
        let mut v = vec![1.0 + 1e-12, std::f64::consts::PI];
        demote_f32(&mut v);
        assert_eq!(v[0], 1.0);
        assert_eq!(v[1], std::f64::consts::PI as f32 as f64);
    }

    #[test]
    fn mp_error_is_f32_scale() {
        let p = small_problem(64, 30);
        let theta = [1.0, 0.1, 0.5];
        let ctx = ExecCtx::new(2, 16, Policy::Lws);
        let oracle = dense_oracle(&p, &theta);
        let mp = loglik(&p, &theta, 0, &ctx).unwrap();
        let rel = (mp.loglik - oracle.loglik).abs() / oracle.loglik.abs();
        // f32 storage + f32 off-band compute: relative error well below
        // 1e-3 but (generically) nonzero.
        assert!(rel < 1e-3, "rel {rel}");
        assert!(rel > 0.0, "suspiciously exact");
    }

    #[test]
    fn wider_band_is_more_accurate() {
        let p = small_problem(80, 31);
        let theta = [1.0, 0.2, 1.0];
        let ctx = ExecCtx::new(1, 16, Policy::Eager);
        let oracle = dense_oracle(&p, &theta);
        let e0 = (loglik(&p, &theta, 0, &ctx).unwrap().loglik - oracle.loglik).abs();
        let e_full = (loglik(&p, &theta, 4, &ctx).unwrap().loglik - oracle.loglik).abs();
        assert!(e_full <= e0, "band 4 err {e_full} vs band 0 err {e0}");
        assert!(e_full < 1e-9, "full band must be exact, err {e_full}");
    }

    #[test]
    fn is_f64_tile_band_logic() {
        assert!(is_f64_tile(0, 3, 3));
        assert!(!is_f64_tile(0, 4, 3));
        assert!(is_f64_tile(2, 5, 3));
        assert!(!is_f64_tile(1, 5, 3));
    }
}
