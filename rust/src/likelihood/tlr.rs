//! Tile Low-Rank (TLR) log-likelihood (Fig 1(c); Abdulah et al. 2018b).
//!
//! Diagonal tiles stay dense; off-diagonal tiles are SVD-compressed to
//! `U V^T` form.  The TLR Cholesky follows the same right-looking schedule
//! as the dense one with the low-rank operation set of
//! [`crate::linalg::lowrank`]:
//!
//! * `POTRF`   — dense, on diagonal tiles;
//! * `LR_TRSM` — `A_ik <- A_ik L_kk^{-T}` updates only the V factor;
//! * `LR_SYRK` — `A_ii <- A_ii - U (V^T V) U^T` (dense result);
//! * `LR_GEMM` — `A_ij <- A_ij - U_ik (V_ik^T V_jk) U_jk^T` + recompression.
//!
//! The factorization here is executed loop-parallel per panel (the inner
//! `i`/`(i,j)` loops are independent); on this single-core testbed the
//! loops run serially (see DESIGN.md "Hardware adaptation").

use super::{ExecCtx, LogLik, Problem};
use crate::backend::{ArcEngine, Engine as _};
use crate::covariance::DistCache;
use crate::linalg::blas::{dpotrf_raw, dtrsv_ln};
use crate::linalg::lowrank::{LrOpts, LrTile};
use crate::linalg::matrix::Matrix;

/// TLR representation of a symmetric covariance matrix.
pub struct TlrMatrix {
    pub n: usize,
    pub ts: usize,
    pub nt: usize,
    /// Dense diagonal tiles (column-major, `h x h`).
    pub diag: Vec<Matrix>,
    /// Lower off-diagonal tiles in low-rank form, indexed `(i, j), i > j`.
    pub low: Vec<LrTile>,
}

impl TlrMatrix {
    fn low_index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i > j && i < self.nt);
        // strictly-lower triangular packing
        i * (i - 1) / 2 + j
    }
    pub fn low_tile(&self, i: usize, j: usize) -> &LrTile {
        &self.low[self.low_index(i, j)]
    }

    /// Total stored doubles (the paper's TLR memory-footprint metric).
    pub fn storage_len(&self) -> usize {
        let d: usize = self.diag.iter().map(|m| m.rows() * m.cols()).sum();
        let l: usize = self.low.iter().map(|t| t.storage_len()).sum();
        d + l
    }

    /// Dense storage it replaces (lower triangle incl. diagonal tiles).
    pub fn dense_storage_len(&self) -> usize {
        let mut total = 0;
        for i in 0..self.nt {
            for j in 0..=i {
                let h = self.ts.min(self.n - i * self.ts);
                let w = self.ts.min(self.n - j * self.ts);
                total += h * w;
            }
        }
        total
    }

    /// Per-tile rank map for the Fig 1(c) visualisation.
    pub fn rank_map(&self) -> Vec<Vec<usize>> {
        (0..self.nt)
            .map(|i| (0..i).map(|j| self.low_tile(i, j).rank()).collect())
            .collect()
    }
}

/// Generate the TLR covariance: dense diagonal + compressed off-diagonal
/// (through the default compute backend).
pub fn generate(problem: &Problem, theta: &[f64], opts: LrOpts, ts: usize) -> TlrMatrix {
    let engine = crate::backend::default_engine();
    generate_with(problem, theta, opts, ts, &engine, None)
}

/// Generate the TLR covariance against an explicit backend engine.
/// `dist` is the tile-aligned distance cache of a warm
/// [`super::EvalSession`] iteration (same tile grid: `ts` over
/// `problem.dim()`).
pub fn generate_with(
    problem: &Problem,
    theta: &[f64],
    opts: LrOpts,
    ts: usize,
    engine: &ArcEngine,
    dist: Option<&DistCache>,
) -> TlrMatrix {
    let n = problem.dim();
    let nt = n.div_ceil(ts);
    let dim = |i: usize| ts.min(n - i * ts);
    let mut diag = Vec::with_capacity(nt);
    let mut low = Vec::with_capacity(nt * (nt - 1) / 2);
    let mut buf = vec![0.0f64; ts * ts];
    let fill = |i: usize, j: usize, h: usize, w: usize, buf: &mut [f64]| {
        let block = dist.and_then(|c| c.block(i, j));
        engine.fill_tile(
            problem.kernel.as_ref(),
            theta,
            &problem.locs,
            problem.metric,
            i * ts,
            j * ts,
            h,
            w,
            block.as_deref(),
            buf,
        );
    };
    for i in 0..nt {
        for j in 0..i {
            let (h, w) = (dim(i), dim(j));
            fill(i, j, h, w, &mut buf);
            low.push(LrTile::compress_aca(h, w, &buf[..h * w], opts));
        }
        let h = dim(i);
        fill(i, i, h, h, &mut buf);
        diag.push(Matrix::from_col_major(h, h, &buf[..h * h]));
    }
    TlrMatrix {
        n,
        ts,
        nt,
        diag,
        low,
    }
}

/// In-place TLR Cholesky.  Returns the log-determinant on success.
pub fn tlr_potrf(a: &mut TlrMatrix, opts: LrOpts) -> anyhow::Result<f64> {
    let nt = a.nt;
    for k in 0..nt {
        // POTRF on dense diagonal tile k.
        {
            let d = &mut a.diag[k];
            let h = d.rows();
            dpotrf_raw(h, d.as_mut_slice(), h).map_err(|e| {
                anyhow::Error::new(crate::scheduler::runtime::TaskError::Numerical(format!(
                    "TLR covariance not positive definite at pivot {}",
                    k * a.ts + e.pivot
                )))
            })?;
            d.zero_upper();
        }
        // LR_TRSM down the panel.
        for i in k + 1..nt {
            let (l_ptr, h) = {
                let d = &a.diag[k];
                (d.as_slice().as_ptr(), d.rows())
            };
            // SAFETY: diag[k] and low[(i,k)] are distinct allocations.
            let l = unsafe { std::slice::from_raw_parts(l_ptr, h * h) };
            let idx = a.low_index(i, k);
            a.low[idx].trsm_right_lt(l, h);
        }
        // Trailing updates.
        for i in k + 1..nt {
            let idx_ik = a.low_index(i, k);
            // LR_SYRK into dense diagonal i.
            let (aik, diag_i) = {
                let (low, diag) = (&a.low, &mut a.diag);
                (&low[idx_ik], &mut diag[i])
            };
            aik.syrk_into(diag_i);
            // LR_GEMM into (i, j) for k < j < i.
            for j in k + 1..i {
                let idx_jk = a.low_index(j, k);
                let idx_ij = a.low_index(i, j);
                let prod = LrTile::lr_abt(&a.low[idx_ik], &a.low[idx_jk]);
                a.low[idx_ij].add_scaled(-1.0, &prod, opts);
            }
        }
    }
    let mut logdet = 0.0;
    for d in &a.diag {
        for i in 0..d.rows() {
            logdet += d[(i, i)].ln();
        }
    }
    Ok(2.0 * logdet)
}

/// Forward substitution `y <- L^{-1} y` against a TLR factor.
pub fn tlr_forward_solve(a: &TlrMatrix, y: &mut [f64]) {
    let ts = a.ts;
    let n = a.n;
    for i in 0..a.nt {
        let lo = i * ts;
        let hi = n.min(lo + ts);
        for j in 0..i {
            let jlo = j * ts;
            let jhi = n.min(jlo + ts);
            // split-borrow y into [jlo..jhi] (read) and [lo..hi] (write)
            let (head, tail) = y.split_at_mut(lo);
            a.low_tile(i, j).gemv_sub(&head[jlo..jhi], &mut tail[..hi - lo]);
        }
        let d = &a.diag[i];
        dtrsv_ln(hi - lo, d.as_slice(), d.rows(), &mut y[lo..hi]);
    }
}

/// TLR log-likelihood entry point.
///
/// Locations are Morton-reordered first (as ExaGeoStat does) so that tiles
/// cover spatially contiguous clusters — the property that makes
/// off-diagonal tiles low-rank.  The permutation is applied to `z` as
/// well, which leaves the likelihood value invariant.
pub fn loglik(
    problem: &Problem,
    theta: &[f64],
    tol: f64,
    max_rank: usize,
    ctx: &ExecCtx,
) -> anyhow::Result<LogLik> {
    anyhow::ensure!(
        problem.kernel.nvariates() == 1,
        "TLR path currently supports univariate kernels"
    );
    let opts = LrOpts { tol, max_rank };
    let perm = crate::covariance::morton_perm(&problem.locs);
    let locs: Vec<_> = perm.iter().map(|&i| problem.locs[i]).collect();
    let mut y: Vec<f64> = perm.iter().map(|&i| problem.z[i]).collect();
    let sorted = Problem {
        kernel: problem.kernel.clone(),
        locs: std::sync::Arc::new(locs),
        z: std::sync::Arc::new(Vec::new()),
        metric: problem.metric,
    };
    let out = crate::pipeline::run_tlr(&sorted, theta, opts, ctx, None, &mut y)?;
    if let Some(pivot) = out.not_spd {
        return Err(anyhow::Error::new(crate::scheduler::runtime::TaskError::Numerical(
            format!("TLR covariance not positive definite at pivot {pivot}"),
        )));
    }
    let sse = y.iter().map(|v| v * v).sum();
    Ok(LogLik::assemble(out.logdet, sse, problem.dim()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::likelihood::testutil::{dense_oracle, small_problem};
    use crate::likelihood::ExecCtx;
    use crate::scheduler::pool::Policy;

    fn tight() -> LrOpts {
        LrOpts {
            tol: 1e-13,
            max_rank: usize::MAX,
        }
    }

    #[test]
    fn tlr_factor_reconstructs_at_tight_tolerance() {
        let p = small_problem(40, 20);
        let theta = [1.0, 0.15, 0.5];
        let mut a = generate(&p, &theta, tight(), 10);
        let dense =
            crate::covariance::build_cov_dense(p.kernel.as_ref(), &theta, &p.locs, p.metric);
        // factor both
        let mut lref = dense.clone();
        crate::linalg::blas::dpotrf(&mut lref).unwrap();
        lref.zero_upper();
        tlr_potrf(&mut a, tight()).unwrap();
        // compare L via reconstruction of a few tiles
        for i in 0..a.nt {
            for j in 0..i {
                let got = a.low_tile(i, j).to_dense();
                for c in 0..got.cols() {
                    for r in 0..got.rows() {
                        let want = lref[(i * 10 + r, j * 10 + c)];
                        assert!(
                            (got[(r, c)] - want).abs() < 1e-7,
                            "tile ({i},{j}) at ({r},{c}): {} vs {want}",
                            got[(r, c)]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tlr_loglik_converges_to_exact_as_tol_shrinks() {
        let p = small_problem(64, 21);
        let theta = [1.0, 0.1, 1.0];
        let oracle = dense_oracle(&p, &theta);
        let ctx = ExecCtx::new(1, 16, Policy::Eager);
        let mut prev_err = f64::INFINITY;
        for tol in [1e-2, 1e-5, 1e-9, 1e-13] {
            let r = loglik(&p, &theta, tol, usize::MAX, &ctx).unwrap();
            let err = (r.loglik - oracle.loglik).abs();
            assert!(
                err <= prev_err * 1.5 + 1e-9,
                "tol {tol}: err {err} worse than {prev_err}"
            );
            prev_err = err;
        }
        assert!(prev_err < 1e-6, "final err {prev_err}");
    }

    #[test]
    fn tlr_saves_storage_on_smooth_fields() {
        // Compression pays off once tiles are well separated (large nt):
        // this mirrors the paper's regime, where TLR targets n >> ts.
        let p = small_problem(256, 22);
        // Morton-sort first (as the loglik path does): tiles become
        // spatially contiguous clusters, which is what compresses.
        let perm = crate::covariance::morton_perm(&p.locs);
        let locs: Vec<_> = perm.iter().map(|&i| p.locs[i]).collect();
        let p = Problem {
            kernel: p.kernel.clone(),
            locs: std::sync::Arc::new(locs),
            z: p.z.clone(),
            metric: p.metric,
        };
        // long range + smooth => strongly compressible off-diagonal tiles
        let theta = [1.0, 0.5, 1.5];
        let a = generate(&p, &theta, LrOpts { tol: 1e-5, max_rank: usize::MAX }, 32);
        assert!(
            a.storage_len() < a.dense_storage_len(),
            "{} !< {}",
            a.storage_len(),
            a.dense_storage_len()
        );
        let ranks = a.rank_map();
        // far-apart tile should compress well below full rank
        assert!(ranks[7][0] < 24, "far tile rank {}", ranks[7][0]);
    }

    #[test]
    fn rank_cap_limits_accuracy_gracefully() {
        let p = small_problem(48, 23);
        let theta = [1.0, 0.1, 0.5];
        let ctx = ExecCtx::new(1, 12, Policy::Eager);
        let oracle = dense_oracle(&p, &theta);
        let r_cap = loglik(&p, &theta, 1e-13, 3, &ctx).unwrap();
        let r_free = loglik(&p, &theta, 1e-13, usize::MAX, &ctx).unwrap();
        let err_cap = (r_cap.loglik - oracle.loglik).abs();
        let err_free = (r_free.loglik - oracle.loglik).abs();
        assert!(err_free < err_cap, "{err_free} !< {err_cap}");
        assert!(err_cap / oracle.loglik.abs() < 0.5, "cap error unreasonable");
    }
}
