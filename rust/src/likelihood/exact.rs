//! Exact (and DST, via the band parameter) log-likelihood: one task graph
//! covering covariance generation (`dcmg`), tiled Cholesky, forward solve
//! and the scalar reductions — the full pipeline StarPU executes in
//! ExaGeoStat's `MLE_alg` (Abdulah et al. 2018a, Alg. 1).

use super::{ExecCtx, LogLik, Problem};
use crate::backend::{ArcEngine, Engine as _};
use crate::covariance::DistCache;
use crate::linalg::cholesky::{in_band, TileHandles};
use crate::linalg::tile::{TileMatrix, TileVector};
use crate::scheduler::{Access, TaskGraph, TaskKind};
use std::sync::Arc;

/// Submit generation tasks: fill each retained lower tile of `a` from the
/// covariance kernel through the default compute backend.  Mirrors
/// ExaGeoStat's `dcmg` codelet.
pub fn submit_generation(
    g: &mut TaskGraph,
    a: &TileMatrix,
    hs: &TileHandles,
    problem: &Problem,
    theta: &[f64],
    band: Option<usize>,
) {
    let engine = crate::backend::default_engine();
    submit_generation_with(g, a, hs, problem, theta, band, &engine, None);
}

/// Submit generation tasks against an explicit backend engine (the
/// likelihood hot path passes `ctx.engine`).  `dist` is the per-tile
/// distance cache of a warm [`super::EvalSession`] iteration; each task
/// captures its tile's `Arc`-shared block so the engine can skip the
/// metric work.  `a` must be all-f64 storage — the MP variant, whose
/// off-band tiles are f32-stored, generates through the pipeline
/// runner's precision-aware op (demote-on-store via a reusable f64
/// stage).
///
/// This legacy STF emitter is no longer on the likelihood hot path
/// (which lowers through `crate::pipeline`); it remains the reference
/// layer the planner's task-count parity tests compare against.
#[allow(clippy::too_many_arguments)]
pub fn submit_generation_with(
    g: &mut TaskGraph,
    a: &TileMatrix,
    hs: &TileHandles,
    problem: &Problem,
    theta: &[f64],
    band: Option<usize>,
    engine: &ArcEngine,
    dist: Option<&DistCache>,
) {
    let nt = a.nt();
    let ts = a.ts();
    let theta: Arc<Vec<f64>> = Arc::new(theta.to_vec());
    for i in 0..nt {
        for j in 0..=i {
            if !in_band(band, i, j) {
                continue;
            }
            let bytes = a.tile_bytes_at(i, j);
            let h = a.tile_rows(i);
            let w = a.tile_cols(j);
            let ptr = a.tile_ptr(i, j);
            let kernel = problem.kernel.clone();
            let locs = problem.locs.clone();
            let metric = problem.metric;
            let theta = theta.clone();
            let engine = engine.clone();
            let block = dist.and_then(|c| c.block(i, j));
            let (row0, col0) = (i * ts, j * ts);
            g.submit(TaskKind::DCMG, &[(hs.at(i, j), Access::W)], bytes, move || {
                // SAFETY: STF ordering gives exclusive access to the tile.
                let out = unsafe { ptr.as_mut() };
                engine.fill_tile(
                    kernel.as_ref(),
                    &theta,
                    &locs,
                    metric,
                    row0,
                    col0,
                    h,
                    w,
                    block.as_deref(),
                    out,
                );
            });
        }
    }
}

/// Evaluate the exact (band = None) or DST (band = Some(b)) log-likelihood.
///
/// For DST the locations are Morton-reordered first (as ExaGeoStat always
/// does): tiles then cover spatially contiguous clusters, so the
/// annihilated off-band tiles carry only weak long-range correlations —
/// without the reordering the banded matrix easily loses positive
/// definiteness.  The permutation is likelihood-invariant.
pub fn loglik(
    problem: &Problem,
    theta: &[f64],
    band: Option<usize>,
    ctx: &ExecCtx,
) -> anyhow::Result<LogLik> {
    let dim = problem.dim();
    let sorted_storage;
    let (problem, z): (&Problem, std::borrow::Cow<'_, [f64]>) =
        if band.is_some() && problem.kernel.nvariates() == 1 {
            let perm = crate::covariance::morton_perm(&problem.locs);
            let locs: Vec<_> = perm.iter().map(|&i| problem.locs[i]).collect();
            let z: Vec<f64> = perm.iter().map(|&i| problem.z[i]).collect();
            sorted_storage = Problem {
                kernel: problem.kernel.clone(),
                locs: Arc::new(locs),
                z: Arc::new(Vec::new()),
                metric: problem.metric,
            };
            (&sorted_storage, std::borrow::Cow::Owned(z))
        } else {
            (problem, std::borrow::Cow::Borrowed(problem.z.as_slice()))
        };
    // Budgeted contexts get an out-of-core workspace (spill-backed,
    // peak-resident <= budget); unbudgeted ones the resident fast path.
    let a = ctx.alloc_tile_matrix(dim)?;
    let y = TileVector::from_slice(&z, ctx.ts);
    run_pipeline(problem, theta, band, ctx, None, &a, &y)
}

/// The generation → tiled-Cholesky → forward-solve → reduction pipeline
/// over caller-owned storage.  The cold path ([`loglik`]) allocates `a`
/// and `y` fresh; a warm [`super::EvalSession`] iteration passes its
/// reusable workspace (with `y` already reloaded) plus the distance
/// cache, so no large allocation happens here.
///
/// `problem` must already be in final (possibly Morton-permuted) order;
/// every retained tile of `a` is fully overwritten by generation, so
/// stale factor values from a previous iteration are harmless.
pub(crate) fn run_pipeline(
    problem: &Problem,
    theta: &[f64],
    band: Option<usize>,
    ctx: &ExecCtx,
    dist: Option<&DistCache>,
    a: &TileMatrix,
    y: &TileVector,
) -> anyhow::Result<LogLik> {
    // Lower through the pipeline IR and the fusion planner; the plan
    // runs as one job on the context's persistent runtime — no threads
    // are spawned here, warm MLE iterations reuse the parked workers.
    let out = crate::pipeline::run_tiled(problem, theta, ctx, dist, a, Some(y), band, true)?;
    if let Some(pivot) = out.not_spd {
        // Typed so the MLE driver can tell recoverable infeasibility
        // (steer the search away) from infrastructure failures.
        return Err(anyhow::Error::new(crate::scheduler::runtime::TaskError::Numerical(
            format!("covariance not positive definite at pivot {pivot} (theta = {theta:?})"),
        )));
    }
    Ok(LogLik::assemble(out.logdet, y.dot_self(), a.n()))
}

/// Tile occupancy map for Fig 1 visualisation: returns, for each lower
/// tile, `'D'` (dense) or `'.'` (annihilated) under the DST band.
pub fn structure_map(n: usize, ts: usize, band: Option<usize>) -> Vec<String> {
    let nt = n.div_ceil(ts);
    (0..nt)
        .map(|i| {
            (0..=i)
                .map(|j| if in_band(band, i, j) { 'D' } else { '.' })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::likelihood::testutil::{dense_oracle, small_problem};
    use crate::scheduler::pool::Policy;

    #[test]
    fn matches_dense_oracle_across_tile_sizes() {
        let p = small_problem(45, 10);
        let theta = [1.3, 0.2, 1.5];
        let oracle = dense_oracle(&p, &theta);
        for ts in [8usize, 16, 45, 64] {
            let ctx = ExecCtx::new(2, ts, Policy::Lws);
            let r = loglik(&p, &theta, None, &ctx).unwrap();
            assert!(
                (r.loglik - oracle.loglik).abs() < 1e-8,
                "ts={ts}: {} vs {}",
                r.loglik,
                oracle.loglik
            );
            assert!((r.sse - oracle.sse).abs() < 1e-8);
            assert!((r.logdet - oracle.logdet).abs() < 1e-8);
        }
    }

    #[test]
    fn non_spd_theta_is_reported() {
        // Duplicate locations without nugget => singular covariance.
        let mut p = small_problem(12, 11);
        let mut locs = (*p.locs).clone();
        locs[5] = locs[4];
        p.locs = std::sync::Arc::new(locs);
        let ctx = ExecCtx::new(1, 4, Policy::Eager);
        let err = loglik(&p, &[1.0, 0.1, 0.5], None, &ctx).unwrap_err();
        assert!(err.to_string().contains("not positive definite"), "{err}");
    }

    #[test]
    fn dst_band_zero_is_block_diagonal_loglik() {
        // With band 0 the likelihood decomposes over diagonal blocks.
        // Pre-sort by Morton order so the engine's internal reordering is
        // the identity and the block oracle below matches.
        let p0 = small_problem(32, 12);
        let perm = crate::covariance::morton_perm(&p0.locs);
        let p = Problem {
            kernel: p0.kernel.clone(),
            locs: std::sync::Arc::new(perm.iter().map(|&i| p0.locs[i]).collect()),
            z: std::sync::Arc::new(perm.iter().map(|&i| p0.z[i]).collect()),
            metric: p0.metric,
        };
        let theta = [1.0, 0.1, 0.5];
        let ts = 8;
        let ctx = ExecCtx::new(1, ts, Policy::Eager);
        let r = loglik(&p, &theta, Some(0), &ctx).unwrap();
        // oracle: sum of per-block dense logliks
        let mut want_logdet = 0.0;
        let mut want_sse = 0.0;
        for b in 0..4 {
            let lo = b * ts;
            let hi = 32.min(lo + ts);
            let locs = p.locs[lo..hi].to_vec();
            let sub = Problem {
                kernel: p.kernel.clone(),
                locs: std::sync::Arc::new(locs),
                z: std::sync::Arc::new(p.z[lo..hi].to_vec()),
                metric: p.metric,
            };
            let o = dense_oracle(&sub, &theta);
            want_logdet += o.logdet;
            want_sse += o.sse;
        }
        assert!((r.logdet - want_logdet).abs() < 1e-9);
        assert!((r.sse - want_sse).abs() < 1e-9);
    }

    #[test]
    fn structure_map_shapes() {
        let m = structure_map(40, 10, Some(1));
        assert_eq!(m.len(), 4);
        assert_eq!(m[0], "D");
        assert_eq!(m[1], "DD");
        assert_eq!(m[2], ".DD");
        assert_eq!(m[3], "..DD");
        let dense = structure_map(40, 10, None);
        assert!(dense.iter().all(|row| row.chars().all(|c| c == 'D')));
    }
}
