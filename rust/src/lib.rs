//! # exageostat
//!
//! A from-scratch reproduction of **ExaGeoStatR** (Abdulah et al., 2019):
//! large-scale Gaussian-process maximum-likelihood estimation, simulation
//! and prediction for environmental data science, built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — task-based tiled linear algebra (StarPU +
//!   Chameleon/HiCMA analogues), the MLE driver with a BOBYQA-style
//!   optimizer, kriging / Fisher / MLOE-MMOM tools, the synthetic data
//!   generator, and the GeoR/fields baseline analogues.
//! * **L2/L1 (python/, build-time only)** — the Matérn covariance tile as
//!   a Pallas kernel inside a JAX log-likelihood graph, AOT-lowered to HLO
//!   text and executed from Rust through PJRT (`runtime` module, behind
//!   the `pjrt` cargo feature; the `backend` module selects between the
//!   pure-Rust engine and PJRT at context construction).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index,
//! and `EXPERIMENTS.md` for the benchmark telemetry schemas
//! (§Kernel roofline, §Time per iteration, §Serving) and what "good"
//! looks like for each reproduced result.

pub mod api;
pub mod backend;
pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod covariance;
pub mod data;
pub mod likelihood;
pub mod linalg;
pub mod optimizer;
pub mod pipeline;
pub mod prediction;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod simulation;
pub mod testkit;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
