//! The PJRT execution engine proper (cargo feature `pjrt`): a PJRT CPU
//! client plus a compile cache of loaded executables.
//!
//! Two entry points mirror the two artifact families:
//! * [`PjrtEngine::matern_tile`] — one covariance tile (the `dcmg` task
//!   body as lowered from the L1 Pallas kernel);
//! * [`PjrtEngine::loglik`] — the full fixed-size log-likelihood graph
//!   (L2), used by the small-problem MLE and the parity tests.

use super::default_artifact_dir;
use crate::covariance::Location;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A PJRT CPU client plus a compile cache of loaded executables.
pub struct PjrtEngine {
    dir: PathBuf,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtEngine {
    /// Create an engine reading artifacts from `dir`.
    pub fn new(dir: &Path) -> anyhow::Result<Self> {
        anyhow::ensure!(
            dir.join("manifest.txt").exists(),
            "artifact directory {dir:?} missing manifest.txt — run `make artifacts`"
        );
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT: {e}"))?;
        Ok(PjrtEngine {
            dir: dir.to_path_buf(),
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Create from the default artifact location.
    pub fn from_default() -> anyhow::Result<Self> {
        Self::new(&default_artifact_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by stem (e.g. `matern_tile_ts64`),
    /// memoized per engine.
    fn executable(&self, stem: &str) -> anyhow::Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(stem) {
            return Ok(());
        }
        let path = self.dir.join(format!("{stem}.hlo.txt"));
        anyhow::ensure!(path.exists(), "missing artifact {path:?} — run `make artifacts`");
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {stem}: {e}"))?;
        cache.insert(stem.to_string(), exe);
        Ok(())
    }

    fn run(&self, stem: &str, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        self.executable(stem)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(stem).expect("just inserted");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {stem}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {stem}: {e}"))?;
        // aot.py lowers with return_tuple=True.
        result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {stem}: {e}"))
    }

    /// Evaluate one `ts x ts` Matérn covariance tile through the lowered
    /// Pallas kernel.  `rows`/`cols` are the tile's coordinate blocks;
    /// output is **column-major** (ready for the tiled Cholesky).
    pub fn matern_tile(
        &self,
        ts: usize,
        rows: &[Location],
        cols: &[Location],
        theta: &[f64],
    ) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(rows.len() == ts && cols.len() == ts, "tile shape mismatch");
        anyhow::ensure!(theta.len() == 3, "ugsm-s theta has 3 entries");
        let stem = format!("matern_tile_ts{ts}");
        let pack = |ls: &[Location]| -> anyhow::Result<xla::Literal> {
            let mut flat = Vec::with_capacity(ts * 2);
            for l in ls {
                flat.push(l.x);
                flat.push(l.y);
            }
            xla::Literal::vec1(&flat)
                .reshape(&[ts as i64, 2])
                .map_err(|e| anyhow::anyhow!("pack coords: {e}"))
        };
        let x1 = pack(rows)?;
        let x2 = pack(cols)?;
        let th = xla::Literal::vec1(theta);
        let outs = self.run(&stem, &[x1, x2, th])?;
        let row_major = outs[0]
            .to_vec::<f64>()
            .map_err(|e| anyhow::anyhow!("tile out: {e}"))?;
        anyhow::ensure!(row_major.len() == ts * ts, "tile output size");
        // row-major (jax) -> column-major (tiles)
        let mut col_major = vec![0.0; ts * ts];
        for i in 0..ts {
            for j in 0..ts {
                col_major[i + j * ts] = row_major[i * ts + j];
            }
        }
        Ok(col_major)
    }

    /// Evaluate the fixed-size exact log-likelihood artifact:
    /// returns `(loglik, logdet, sse)`.
    pub fn loglik(
        &self,
        locs: &[Location],
        z: &[f64],
        theta: &[f64],
    ) -> anyhow::Result<(f64, f64, f64)> {
        let n = locs.len();
        anyhow::ensure!(z.len() == n, "z length");
        anyhow::ensure!(theta.len() == 3, "theta length");
        let stem = format!("loglik_n{n}");
        let mut flat = Vec::with_capacity(n * 2);
        for l in locs {
            flat.push(l.x);
            flat.push(l.y);
        }
        let locs_lit = xla::Literal::vec1(&flat)
            .reshape(&[n as i64, 2])
            .map_err(|e| anyhow::anyhow!("pack locs: {e}"))?;
        let z_lit = xla::Literal::vec1(z);
        let th = xla::Literal::vec1(theta);
        let outs = self.run(&stem, &[locs_lit, z_lit, th])?;
        anyhow::ensure!(outs.len() == 3, "loglik artifact returns 3 scalars");
        let get = |l: &xla::Literal| -> anyhow::Result<f64> {
            l.get_first_element::<f64>()
                .map_err(|e| anyhow::anyhow!("scalar out: {e}"))
        };
        Ok((get(&outs[0])?, get(&outs[1])?, get(&outs[2])?))
    }

    /// Tile sizes with a lowered artifact available.
    pub fn available_tile_sizes(&self) -> Vec<usize> {
        let mut sizes = Vec::new();
        if let Ok(manifest) = std::fs::read_to_string(self.dir.join("manifest.txt")) {
            for line in manifest.lines() {
                if let Some(rest) = line.strip_prefix("matern_tile_ts") {
                    if let Some(ts) = rest.split('.').next().and_then(|s| s.parse().ok()) {
                        sizes.push(ts);
                    }
                }
            }
        }
        sizes.sort_unstable();
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::super::artifacts_available;
    use super::*;
    use crate::covariance::{fill_cov_tile, kernel_by_name, DistanceMetric};
    use crate::rng::Pcg64;

    fn rand_locs(n: usize, seed: u64) -> Vec<Location> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n)
            .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
            .collect()
    }

    /// Gate: these tests require `make artifacts` to have run AND a real
    /// `xla` crate (the in-tree stub cannot construct a PJRT client).
    fn engine() -> Option<PjrtEngine> {
        if !artifacts_available() {
            eprintln!("skipping PJRT test: artifacts not built");
            return None;
        }
        match PjrtEngine::from_default() {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping PJRT test: {e:#}");
                None
            }
        }
    }

    #[test]
    fn pjrt_tile_matches_native_kernel() {
        let Some(eng) = engine() else { return };
        let kernel = kernel_by_name("ugsm-s").unwrap();
        for &ts in &[32usize, 64] {
            let rows = rand_locs(ts, 101 + ts as u64);
            let cols = rand_locs(ts, 202 + ts as u64);
            for theta in [[1.0, 0.1, 0.5], [2.5, 0.2, 1.5], [0.7, 0.05, 2.5]] {
                let got = eng.matern_tile(ts, &rows, &cols, &theta).unwrap();
                // native: build combined loc list and use fill_cov_tile on
                // the rectangular block (rows 0..ts, cols ts..2ts)
                let mut all = rows.clone();
                all.extend_from_slice(&cols);
                let mut want = vec![0.0; ts * ts];
                fill_cov_tile(
                    kernel.as_ref(),
                    &theta,
                    &all,
                    DistanceMetric::Euclidean,
                    0,
                    ts,
                    ts,
                    ts,
                    &mut want,
                );
                let err = got
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f64::max);
                assert!(err < 1e-12, "ts={ts} theta={theta:?}: err {err}");
            }
        }
    }

    #[test]
    fn pjrt_loglik_matches_rust_exact() {
        let Some(eng) = engine() else { return };
        let n = 256;
        let locs = rand_locs(n, 303);
        let mut rng = Pcg64::seed_from_u64(304);
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let theta = [1.0, 0.1, 0.5];
        let (ll, logdet, sse) = eng.loglik(&locs, &z, &theta).unwrap();
        // Rust exact engine on the same problem
        let problem = crate::likelihood::Problem {
            kernel: kernel_by_name("ugsm-s").unwrap().into(),
            locs: std::sync::Arc::new(locs),
            z: std::sync::Arc::new(z),
            metric: DistanceMetric::Euclidean,
        };
        let ctx = crate::likelihood::ExecCtx::new(1, 64, crate::scheduler::pool::Policy::Eager);
        let want =
            crate::likelihood::loglik(&problem, &theta, crate::likelihood::Variant::Exact, &ctx)
                .unwrap();
        // The artifact adds 1e-10 jitter; tolerances account for it.
        assert!(
            (ll - want.loglik).abs() < 1e-4 * want.loglik.abs(),
            "pjrt {ll} vs rust {}",
            want.loglik
        );
        assert!((logdet - want.logdet).abs() < 1e-3 * want.logdet.abs().max(1.0));
        assert!((sse - want.sse).abs() < 1e-4 * want.sse.abs());
    }

    #[test]
    fn manifest_lists_tile_sizes() {
        let Some(eng) = engine() else { return };
        let sizes = eng.available_tile_sizes();
        assert!(sizes.contains(&32) && sizes.contains(&64), "{sizes:?}");
        assert!(eng.platform().to_lowercase().contains("cpu") || !eng.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let Some(eng) = engine() else { return };
        let rows = rand_locs(16, 1);
        let err = eng.matern_tile(16, &rows, &rows, &[1.0, 0.1, 0.5]).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
