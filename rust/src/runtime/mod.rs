//! PJRT runtime bridge: loads the AOT-compiled HLO-text artifacts emitted
//! by `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! This is the seam between L3 (this crate) and L2/L1 (the JAX/Pallas
//! compile path): `make artifacts` runs Python exactly once; afterwards the
//! Rust binary loads `artifacts/*.hlo.txt` through the `xla` crate's PJRT
//! CPU client and Python is never on the request path.
//!
//! The artifact *discovery* helpers ([`default_artifact_dir`],
//! [`artifacts_available`]) are always compiled — tests and examples gate
//! on them. The execution engine (`PjrtEngine`) needs the `xla` crate
//! and therefore lives behind the `pjrt` cargo feature (off by default);
//! likelihood code should not use it directly but go through the
//! [`crate::backend`] `Engine` trait, which falls back to the native
//! kernels when PJRT is unavailable. See `DESIGN.md` §2.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

/// Default artifact directory, overridable with `EXAGEOSTAT_ARTIFACTS`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("EXAGEOSTAT_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Walk up from the current directory looking for `artifacts/`.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Are the AOT artifacts available? (Tests gate on this so `cargo test`
/// works before `make artifacts`.)
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_discovery_never_panics() {
        // With or without artifacts on disk, discovery must return a path
        // and a boolean — no panics on a clean machine.
        let dir = default_artifact_dir();
        let available = artifacts_available();
        assert_eq!(available, dir.join("manifest.txt").exists());
    }
}
