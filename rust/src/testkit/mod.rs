//! Minimal property-testing harness (the offline substitute for `proptest`
//! — see DESIGN.md substitution table).
//!
//! `forall` runs a property over `cases` randomly generated inputs.  On
//! failure it panics with the case index and the root seed so the exact
//! failing input can be replayed deterministically:
//!
//! ```no_run
//! use exageostat::testkit::forall;
//! forall(0xBEEF, 100, |rng| rng.uniform(0.0, 1.0), |x| assert!(*x < 1.0));
//! ```

use crate::rng::Pcg64;

/// Per-thread [`crate::linalg::tile::TileMatrix`] allocation counter —
/// the telemetry behind the allocation-regression tests that pin
/// [`crate::likelihood::EvalSession`]'s workspace-reuse invariant.
pub use crate::linalg::tile::tile_matrix_allocs;

/// Process-global pack/stage buffer allocation counter from the BLAS
/// packing layer — the telemetry behind the "warm iterations perform
/// zero pack-buffer allocations on runtime workers" regression test.
/// Global because the allocations happen on worker threads while the
/// test observes from the submitting thread: assert deltas only in a
/// dedicated test binary (see `rust/tests/pack_alloc.rs`), where no
/// concurrent test can run kernels.
pub use crate::linalg::blas::pack_buffer_allocs;

/// Process-global out-of-core tile-store counters (spill write-outs,
/// demand read-backs, completed prefetches) — the telemetry behind the
/// spill regression tests ("a tiny budget forces spill traffic; the
/// resident fast path performs none").  Global for the same reason as
/// [`pack_buffer_allocs`]: the I/O happens on the store's prefetch lane
/// and on runtime workers, while tests observe deltas from the
/// submitting thread — so assert deltas only under serialization (see
/// `rust/tests/spill.rs`).
pub use crate::linalg::tile::{tile_prefetches, tile_spill_reads, tile_spill_writes};

/// Process-wide count of worker threads spawned by
/// [`crate::scheduler::runtime::Runtime`]s — the telemetry behind the
/// runtime-lifecycle regression tests ("a full MLE run spawns exactly
/// `ncores` threads; warm iterations spawn zero").  Note this counter is
/// global: tests asserting deltas must serialize against other
/// runtime-creating tests in the same process (see
/// `rust/tests/runtime_lifecycle.rs`).
pub use crate::scheduler::runtime::worker_threads_spawned;

/// Fault-injection harness surface for chaos tests: the seeded
/// [`FaultPlan`] (install with [`set_fault_plan`], clear with `None`),
/// process-global injection/recovery counters, and the per-process
/// retry/watchdog/quarantine overrides.  The plan and the counters are
/// process-global, so chaos tests must hold [`fault_test_lock`] for
/// their whole armed section — otherwise a concurrent test binary
/// thread would see injected faults it never asked for (see
/// `rust/tests/chaos.rs`).
pub use crate::scheduler::faults::{
    fault_test_lock, faults_injected, injected_io_errors, injected_panics, injected_stalls,
    set_fault_plan, set_task_retry_override, tasks_retried, FaultPlan,
};

/// Recovery-policy overrides re-exported beside the injector so a chaos
/// test configures the whole failure model from one import: whole-job
/// retry ([`crate::coordinator::set_job_retry_override`]), watchdog
/// stall factor, and worker-class quarantine threshold.
pub use crate::coordinator::set_job_retry_override;
pub use crate::scheduler::placement::set_quarantine_override;
pub use crate::scheduler::runtime::set_watchdog_override;

/// Nearest-rank percentile of an **ascending-sorted** slice, `p` in
/// [0, 1].  Shared by the `serve` subcommand and the serving bench so
/// their latency quantiles cannot drift apart.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run `prop` on `cases` inputs drawn by `gen` from a seeded RNG.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T),
) {
    let mut root = Pcg64::seed_from_u64(seed);
    for case in 0..cases {
        let mut case_rng = root.split(case as u64);
        let input = gen(&mut case_rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&input)));
        if let Err(e) = result {
            let msg = crate::scheduler::runtime::panic_message(e.as_ref());
            panic!(
                "property failed at case {case}/{cases} (seed {seed:#x}):\n  input: {input:?}\n  cause: {msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::covariance::Location;
    use crate::rng::Pcg64;

    /// Uniform locations in the unit square.
    pub fn locations(rng: &mut Pcg64, n: usize) -> Vec<Location> {
        (0..n)
            .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
            .collect()
    }

    /// A random valid ugsm-s parameter vector.
    pub fn ugsm_theta(rng: &mut Pcg64) -> [f64; 3] {
        [
            rng.uniform(0.2, 3.0),          // sigma_sq
            rng.uniform(0.03, 0.4),         // beta
            [0.5, 1.0, 1.5, 2.0][rng.below(4)], // nu
        ]
    }

    /// Random vector of standard normals.
    pub fn normals(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_true_property() {
        forall(1, 50, |rng| rng.uniform(-1.0, 1.0), |x| {
            assert!(x.abs() <= 1.0);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failure_with_seed() {
        forall(2, 50, |rng| rng.below(10), |&x| {
            assert!(x < 9, "found the bad case");
        });
    }

    #[test]
    fn forall_is_deterministic() {
        let mut seen_a = Vec::new();
        forall(3, 10, |rng| rng.next_u64(), |&x| seen_a.push(x));
        let mut seen_b = Vec::new();
        forall(3, 10, |rng| rng.next_u64(), |&x| seen_b.push(x));
        assert_eq!(seen_a, seen_b);
    }
}
