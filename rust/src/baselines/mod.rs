//! Baseline MLE implementations mirroring the two R packages the paper
//! compares against (Table IV):
//!
//! * [`georlike_mle`] — GeoR's `likfit`: sequential dense Cholesky,
//!   Nelder–Mead, estimates a constant mean (as the data mean, which the
//!   paper notes is how GeoR effectively treats it) plus
//!   `(sigma_sq, beta, nu)`.
//! * [`fieldslike_mle`] — fields' `MLESpatialProcess`: sequential dense
//!   Cholesky, BFGS, smoothness `nu` held fixed, estimates
//!   `(sigma_sq, beta)`.
//!
//! Both deliberately use the *plain* (non-tiled, single-thread) dense path:
//! the Table V / Fig 5 comparisons measure exactly this
//! sequential-vs-task-parallel gap.

use crate::covariance::{build_cov_dense, kernel_by_name, DistanceMetric};
use crate::optimizer::{self, Bounds, Method, OptOptions};
use crate::simulation::GeoData;

/// Result of a baseline fit.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    /// Estimated `(sigma_sq, beta, nu)` (nu echoed back if fixed).
    pub theta: Vec<f64>,
    /// Estimated constant mean (GeoR-like only).
    pub mean: f64,
    pub loglik: f64,
    pub iters: usize,
    pub time_per_iter: f64,
    pub total_time: f64,
}

/// Dense sequential negative log-likelihood for ugsm-s at `theta`,
/// `z` assumed centred.  Returns +inf on non-SPD.
///
/// Fidelity note: this path uses the *unblocked* reference factorization
/// (`dpotrf_unblocked`), standing in for the reference-BLAS builds the R
/// packages typically run on.  The cache-blocked tiled kernels are
/// ExaGeoStat's (Chameleon's) advantage and belong only to the
/// `exact_mle` side of the comparison — that is precisely the sequential
/// part of the Table V / Fig 5 gap; the parallel part is projected by the
/// fig3 DES on this single-core testbed.
pub fn dense_negloglik(
    locs: &[crate::covariance::Location],
    z: &[f64],
    theta: &[f64],
    metric: DistanceMetric,
) -> f64 {
    let kernel = kernel_by_name("ugsm-s").expect("ugsm-s");
    if kernel.validate(theta).is_err() {
        return f64::INFINITY;
    }
    let mut sigma = build_cov_dense(kernel.as_ref(), theta, locs, metric);
    let n = z.len();
    if crate::linalg::blas::dpotrf_unblocked(n, sigma.as_mut_slice(), n).is_err() {
        return f64::INFINITY;
    }
    let mut y = z.to_vec();
    crate::linalg::blas::dtrsv_ln(n, sigma.as_slice(), n, &mut y);
    let sse: f64 = y.iter().map(|v| v * v).sum();
    let logdet: f64 = 2.0 * (0..n).map(|i| sigma[(i, i)].ln()).sum::<f64>();
    0.5 * sse + 0.5 * logdet + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
}

/// GeoR-like fit: centre by the sample mean, Nelder–Mead over
/// `(sigma_sq, beta, nu)` starting from `clb` (paper protocol).
pub fn georlike_mle(
    data: &GeoData,
    metric: DistanceMetric,
    clb: &[f64],
    cub: &[f64],
    tol: f64,
    max_iters: usize,
) -> anyhow::Result<BaselineResult> {
    anyhow::ensure!(clb.len() == 3 && cub.len() == 3, "ugsm-s has 3 parameters");
    let mean = data.z.iter().sum::<f64>() / data.z.len() as f64;
    let zc: Vec<f64> = data.z.iter().map(|v| v - mean).collect();
    let locs = data.locs.clone();
    let bounds = Bounds::new(clb.to_vec(), cub.to_vec())?;
    let opts = OptOptions {
        tol,
        max_iters,
        init: clb.to_vec(),
        stop: None,
    };
    let r = optimizer::minimize(
        Method::NelderMead,
        |theta| dense_negloglik(&locs, &zc, theta, metric),
        bounds,
        &opts,
    );
    Ok(BaselineResult {
        theta: r.x.clone(),
        mean,
        loglik: -r.fx,
        iters: r.iters,
        time_per_iter: r.time_per_iter,
        total_time: r.total_time,
    })
}

/// fields-like fit: BFGS over `(sigma_sq, beta)` with `nu` fixed (the
/// paper fixes it at the true value — "an advantageous favor for fields").
pub fn fieldslike_mle(
    data: &GeoData,
    metric: DistanceMetric,
    fixed_nu: f64,
    clb: &[f64],
    cub: &[f64],
    tol: f64,
    max_iters: usize,
) -> anyhow::Result<BaselineResult> {
    anyhow::ensure!(clb.len() >= 2 && cub.len() >= 2, "need sigma_sq/beta bounds");
    let locs = data.locs.clone();
    let z = data.z.clone();
    let bounds = Bounds::new(clb[..2].to_vec(), cub[..2].to_vec())?;
    let opts = OptOptions {
        tol,
        max_iters,
        init: clb[..2].to_vec(),
        stop: None,
    };
    let r = optimizer::minimize(
        Method::Bfgs,
        |t2| dense_negloglik(&locs, &z, &[t2[0], t2[1], fixed_nu], metric),
        bounds,
        &opts,
    );
    Ok(BaselineResult {
        theta: vec![r.x[0], r.x[1], fixed_nu],
        mean: 0.0,
        loglik: -r.fx,
        iters: r.iters,
        time_per_iter: r.time_per_iter,
        total_time: r.total_time,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covariance::kernel_by_name;
    use crate::likelihood::ExecCtx;
    use crate::simulation::simulate_data_exact;
    use std::sync::Arc;

    fn sim(n: usize, seed: u64) -> GeoData {
        let k: Arc<dyn crate::covariance::CovKernel> =
            Arc::from(kernel_by_name("ugsm-s").unwrap());
        simulate_data_exact(
            k,
            &[1.0, 0.1, 0.5],
            n,
            DistanceMetric::Euclidean,
            seed,
            &ExecCtx::new(1, 64, crate::scheduler::pool::Policy::Eager),
        )
        .unwrap()
    }

    #[test]
    fn georlike_improves_on_start_and_stays_in_bounds() {
        let data = sim(150, 5);
        let clb = [0.01, 0.01, 0.01];
        let cub = [5.0, 5.0, 5.0];
        let r = georlike_mle(&data, DistanceMetric::Euclidean, &clb, &cub, 1e-5, 400).unwrap();
        let f_start = dense_negloglik(&data.locs, &data.z, &clb, DistanceMetric::Euclidean);
        assert!(-r.loglik < f_start, "no improvement");
        for i in 0..3 {
            assert!(r.theta[i] >= clb[i] && r.theta[i] <= cub[i]);
        }
        assert!(r.iters > 0 && r.time_per_iter > 0.0);
    }

    #[test]
    fn fieldslike_fixes_nu() {
        let data = sim(120, 6);
        let r = fieldslike_mle(
            &data,
            DistanceMetric::Euclidean,
            0.5,
            &[0.01, 0.01],
            &[5.0, 5.0],
            1e-5,
            300,
        )
        .unwrap();
        assert_eq!(r.theta[2], 0.5);
        assert!(r.theta[0] > 0.0 && r.theta[1] > 0.0);
    }

    #[test]
    fn loglik_at_estimate_beats_truth_neighbourhood() {
        // MLE property: fitted loglik >= loglik at the generating theta
        // (up to optimizer tolerance).
        let data = sim(150, 7);
        let r = georlike_mle(
            &data,
            DistanceMetric::Euclidean,
            &[0.01, 0.01, 0.01],
            &[5.0, 5.0, 5.0],
            1e-6,
            600,
        )
        .unwrap();
        let mean = data.z.iter().sum::<f64>() / data.z.len() as f64;
        let zc: Vec<f64> = data.z.iter().map(|v| v - mean).collect();
        let f_truth = dense_negloglik(&data.locs, &zc, &[1.0, 0.1, 0.5], DistanceMetric::Euclidean);
        assert!(
            -r.loglik <= f_truth + 1e-3,
            "fit {} vs truth {}",
            -r.loglik,
            f_truth
        );
    }
}
