//! Prediction-stage tools of Table II: kriging (`exact_predict`), the
//! Fisher information matrix (`exact_fisher`) and the MLOE/MMOM prediction-
//! efficiency metrics (`exact_mloe_mmom`, Hong et al. 2021).

use crate::covariance::{build_cov_dense, build_cross_cov, CovKernel, DistanceMetric, Location};
use crate::likelihood::{ExecCtx, Problem};
use crate::linalg::blas::{dpotrf, dtrsm_llnn_raw, dtrsv_ln, dtrsv_lt};
use crate::linalg::matrix::Matrix;
use crate::linalg::tile::{TileMatrix, TileVector};
use std::sync::Arc;

/// Kriging output.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub mean: Vec<f64>,
    /// Kriging variance per predicted location (`None` if not requested).
    pub variance: Option<Vec<f64>>,
}

/// Shared kriging algebra given the Cholesky factor `l` of the obs
/// covariance and `alpha = Sigma^{-1} z`:
/// `mean = C_no alpha`, `var_j = C(0) - || L^{-1} c_j ||^2`.
#[allow(clippy::too_many_arguments)]
fn krig_from_factor(
    kernel: &dyn CovKernel,
    theta: &[f64],
    l: &Matrix,
    alpha: &[f64],
    obs_locs: &[Location],
    new_locs: &[Location],
    metric: DistanceMetric,
    with_variance: bool,
) -> Prediction {
    let n = obs_locs.len();
    let m = new_locs.len();
    // C_on: obs x new cross-covariance (column per new location)
    let c_on = build_cross_cov(kernel, theta, obs_locs, new_locs, metric);
    let mut mean = vec![0.0; m];
    for j in 0..m {
        mean[j] = c_on
            .col(j)
            .iter()
            .zip(alpha)
            .map(|(c, av)| c * av)
            .sum::<f64>();
    }

    let variance = if with_variance {
        // W = L^{-1} C_on; var_j = C(0) - ||W_:,j||^2
        let mut w = c_on.clone();
        dtrsm_llnn_raw(n, m, l.as_slice(), n, w.as_mut_slice(), n);
        let c0 = kernel.cov(theta, 0.0, 0.0, 0, 0, true);
        Some(
            (0..m)
                .map(|j| {
                    let s: f64 = w.col(j).iter().map(|v| v * v).sum();
                    (c0 - s).max(0.0)
                })
                .collect(),
        )
    } else {
        None
    };

    Prediction { mean, variance }
}

/// Exact simple kriging with a global neighbourhood (univariate kernels):
/// `mean = C_no Sigma^{-1} z`, `var_i = C(0) - || L^{-1} c_i ||^2`.
/// Dense single-threaded reference path; the API routes through
/// [`exact_predict_ctx`], which factors on the task runtime instead.
pub fn exact_predict(
    kernel: &dyn CovKernel,
    theta: &[f64],
    obs_locs: &[Location],
    obs_z: &[f64],
    new_locs: &[Location],
    metric: DistanceMetric,
    with_variance: bool,
) -> anyhow::Result<Prediction> {
    anyhow::ensure!(kernel.nvariates() == 1, "exact_predict is univariate");
    anyhow::ensure!(obs_locs.len() == obs_z.len(), "obs shape mismatch");
    kernel.validate(theta)?;
    let n = obs_locs.len();

    let mut l = build_cov_dense(kernel, theta, obs_locs, metric);
    dpotrf(&mut l).map_err(|e| anyhow::anyhow!("kriging covariance not SPD: {e}"))?;

    // alpha = Sigma^{-1} z
    let mut alpha = obs_z.to_vec();
    dtrsv_ln(n, l.as_slice(), n, &mut alpha);
    dtrsv_lt(n, l.as_slice(), n, &mut alpha);

    Ok(krig_from_factor(
        kernel,
        theta,
        &l,
        &alpha,
        obs_locs,
        new_locs,
        metric,
        with_variance,
    ))
}

/// Exact kriging with the O(n^3) work — covariance generation, tiled
/// Cholesky and the forward solve — submitted as **one job** on the
/// context's persistent runtime, exactly like a likelihood evaluation
/// (only the O(n^2 m) cross-covariance algebra stays on the calling
/// thread).  Numerically identical to [`exact_predict`].
#[allow(clippy::too_many_arguments)]
pub fn exact_predict_ctx(
    kernel: Arc<dyn CovKernel>,
    theta: &[f64],
    obs_locs: &[Location],
    obs_z: &[f64],
    new_locs: &[Location],
    metric: DistanceMetric,
    with_variance: bool,
    ctx: &ExecCtx,
) -> anyhow::Result<Prediction> {
    anyhow::ensure!(kernel.nvariates() == 1, "exact_predict is univariate");
    anyhow::ensure!(obs_locs.len() == obs_z.len(), "obs shape mismatch");
    anyhow::ensure!(!obs_locs.is_empty(), "kriging needs observations");
    kernel.validate(theta)?;
    let n = obs_locs.len();

    let problem = Problem {
        kernel: kernel.clone(),
        locs: Arc::new(obs_locs.to_vec()),
        z: Arc::new(Vec::new()),
        metric,
    };
    let a = TileMatrix::zeros(n, ctx.ts);
    let y = TileVector::from_slice(obs_z, ctx.ts);
    // Generate + factor + forward-solve through the pipeline IR (no
    // log-det: kriging only needs the factor and w = L^{-1} z).
    let out = crate::pipeline::run_tiled(&problem, theta, ctx, None, &a, Some(&y), None, false)?;
    if let Some(pivot) = out.not_spd {
        anyhow::bail!("kriging covariance not SPD at pivot {pivot}");
    }

    // y now holds w = L^{-1} z; finish alpha = L^{-T} w densely.
    let l = a.to_dense_lower();
    let mut alpha = y.to_vec();
    dtrsv_lt(n, l.as_slice(), n, &mut alpha);

    Ok(krig_from_factor(
        kernel.as_ref(),
        theta,
        &l,
        &alpha,
        obs_locs,
        new_locs,
        metric,
        with_variance,
    ))
}

/// Fisher information of the covariance parameters at `theta`:
/// `F_ij = 1/2 tr(Sigma^{-1} dSigma_i Sigma^{-1} dSigma_j)`, with the
/// covariance derivatives taken by central finite differences (the
/// smoothness derivative has no tractable closed form — d/dnu hits
/// dK_nu/dnu).  Also returns asymptotic standard errors
/// `sqrt(diag(F^{-1}))`.
pub struct FisherResult {
    pub fisher: Matrix,
    pub std_errs: Vec<f64>,
}

pub fn exact_fisher(
    kernel: &dyn CovKernel,
    theta: &[f64],
    locs: &[Location],
    metric: DistanceMetric,
) -> anyhow::Result<FisherResult> {
    kernel.validate(theta)?;
    let p = theta.len();
    let dim = kernel.nvariates() * locs.len();

    let mut l = build_cov_dense(kernel, theta, locs, metric);
    dpotrf(&mut l).map_err(|e| anyhow::anyhow!("fisher covariance not SPD: {e}"))?;

    // W_i = Sigma^{-1} dSigma_i  (solve for each parameter)
    let mut ws: Vec<Matrix> = Vec::with_capacity(p);
    for i in 0..p {
        let h = 1e-5 * (1.0 + theta[i].abs());
        let mut tp = theta.to_vec();
        tp[i] += h;
        let mut tm = theta.to_vec();
        tm[i] -= h;
        // keep within validity (e.g. rho bounds): fall back to forward diff
        let (sp, sm, denom) = if kernel.validate(&tm).is_ok() {
            (
                build_cov_dense(kernel, &tp, locs, metric),
                build_cov_dense(kernel, &tm, locs, metric),
                2.0 * h,
            )
        } else {
            (
                build_cov_dense(kernel, &tp, locs, metric),
                build_cov_dense(kernel, theta, locs, metric),
                h,
            )
        };
        let mut d = Matrix::zeros(dim, dim);
        for c in 0..dim {
            for r in 0..dim {
                d[(r, c)] = (sp[(r, c)] - sm[(r, c)]) / denom;
            }
        }
        // Solve Sigma W = dSigma: W = L^{-T} (L^{-1} dSigma)
        dtrsm_llnn_raw(dim, dim, l.as_slice(), dim, d.as_mut_slice(), dim);
        crate::linalg::blas::dtrsm_lltn_raw(dim, dim, l.as_slice(), dim, d.as_mut_slice(), dim);
        ws.push(d);
    }

    let mut f = Matrix::zeros(p, p);
    for i in 0..p {
        for j in 0..=i {
            // tr(W_i W_j) = sum_{r,c} W_i[r,c] * W_j[c,r]
            let mut tr = 0.0;
            for c in 0..dim {
                for r in 0..dim {
                    tr += ws[i][(r, c)] * ws[j][(c, r)];
                }
            }
            f[(i, j)] = 0.5 * tr;
            f[(j, i)] = 0.5 * tr;
        }
    }

    // std errs from F^{-1} diagonal
    let mut lf = f.clone();
    let std_errs = match dpotrf(&mut lf) {
        Ok(_) => {
            let mut errs = Vec::with_capacity(p);
            for i in 0..p {
                let mut e = vec![0.0; p];
                e[i] = 1.0;
                dtrsv_ln(p, lf.as_slice(), p, &mut e);
                dtrsv_lt(p, lf.as_slice(), p, &mut e);
                errs.push(e[i].max(0.0).sqrt());
            }
            errs
        }
        Err(_) => vec![f64::NAN; p],
    };

    Ok(FisherResult {
        fisher: f,
        std_errs,
    })
}

/// MLOE / MMOM prediction-efficiency metrics (Hong et al. 2021):
/// compares kriging under an approximate parameter vector `theta_a`
/// against the truth `theta_t`.
///
/// * MLOE — mean loss of efficiency: `mean(E_t(y_a)/E_t(y_t) - 1) >= 0`.
/// * MMOM — mean misspecification of the mean square error:
///   `mean(E_a(y_a)/E_t(y_a) - 1)`.
#[derive(Copy, Clone, Debug)]
pub struct MloeMmom {
    pub mloe: f64,
    pub mmom: f64,
}

pub fn exact_mloe_mmom(
    kernel: &dyn CovKernel,
    theta_true: &[f64],
    theta_approx: &[f64],
    obs_locs: &[Location],
    new_locs: &[Location],
    metric: DistanceMetric,
) -> anyhow::Result<MloeMmom> {
    anyhow::ensure!(kernel.nvariates() == 1, "mloe/mmom is univariate");
    kernel.validate(theta_true)?;
    kernel.validate(theta_approx)?;
    let n = obs_locs.len();

    let mut lt = build_cov_dense(kernel, theta_true, obs_locs, metric);
    let sigma_t = lt.clone();
    dpotrf(&mut lt).map_err(|e| anyhow::anyhow!("true covariance not SPD: {e}"))?;
    let mut la = build_cov_dense(kernel, theta_approx, obs_locs, metric);
    dpotrf(&mut la).map_err(|e| anyhow::anyhow!("approx covariance not SPD: {e}"))?;

    let c0_t = kernel.cov(theta_true, 0.0, 0.0, 0, 0, true);
    let c0_a = kernel.cov(theta_approx, 0.0, 0.0, 0, 0, true);

    let mut sum_loe = 0.0;
    let mut sum_mom = 0.0;
    for s0 in new_locs {
        let ct: Vec<f64> = obs_locs
            .iter()
            .map(|s| {
                let d = crate::covariance::distance(metric, s, s0);
                kernel.cov(theta_true, d, (s.t - s0.t).abs(), 0, 0, false)
            })
            .collect();
        let ca: Vec<f64> = obs_locs
            .iter()
            .map(|s| {
                let d = crate::covariance::distance(metric, s, s0);
                kernel.cov(theta_approx, d, (s.t - s0.t).abs(), 0, 0, false)
            })
            .collect();
        // w_t = Sigma_t^{-1} c_t ; w_a = Sigma_a^{-1} c_a
        let solve = |l: &Matrix, c: &[f64]| -> Vec<f64> {
            let mut w = c.to_vec();
            dtrsv_ln(n, l.as_slice(), n, &mut w);
            dtrsv_lt(n, l.as_slice(), n, &mut w);
            w
        };
        let wt = solve(&lt, &ct);
        let wa = solve(&la, &ca);
        // E_t(y_t) = c0 - c_t' w_t
        let et_t = c0_t - dot(&ct, &wt);
        // E_t(y_a) = c0 - 2 w_a' c_t + w_a' Sigma_t w_a
        let sw = sigma_t.matvec(&wa);
        let et_a = c0_t - 2.0 * dot(&wa, &ct) + dot(&wa, &sw);
        // E_a(y_a) = c0_a - c_a' w_a
        let ea_a = c0_a - dot(&ca, &wa);
        if et_t > 1e-14 && et_a > 1e-14 {
            sum_loe += et_a / et_t - 1.0;
            sum_mom += ea_a / et_a - 1.0;
        }
    }
    let m = new_locs.len() as f64;
    Ok(MloeMmom {
        mloe: sum_loe / m,
        mmom: sum_mom / m,
    })
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covariance::kernel_by_name;
    use crate::rng::Pcg64;

    fn setup(n: usize, seed: u64) -> (Vec<Location>, Vec<f64>) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let locs: Vec<Location> = (0..n)
            .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
            .collect();
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (locs, z)
    }

    #[test]
    fn kriging_interpolates_observations() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [1.0, 0.2, 1.5];
        let (locs, z) = setup(30, 71);
        let pred = exact_predict(
            k.as_ref(),
            &theta,
            &locs,
            &z,
            &locs[..5],
            DistanceMetric::Euclidean,
            true,
        )
        .unwrap();
        for i in 0..5 {
            assert!(
                (pred.mean[i] - z[i]).abs() < 1e-7,
                "pred {} vs obs {}",
                pred.mean[i],
                z[i]
            );
            assert!(pred.variance.as_ref().unwrap()[i] < 1e-7);
        }
    }

    #[test]
    fn runtime_routed_kriging_matches_dense_path() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [1.1, 0.15, 1.0];
        let (locs, z) = setup(60, 76);
        let new_locs: Vec<Location> = (0..7)
            .map(|i| Location::new(0.1 + 0.1 * i as f64, 0.3))
            .collect();
        let dense = exact_predict(
            k.as_ref(),
            &theta,
            &locs,
            &z,
            &new_locs,
            DistanceMetric::Euclidean,
            true,
        )
        .unwrap();
        let k_arc: Arc<dyn CovKernel> = Arc::from(kernel_by_name("ugsm-s").unwrap());
        for ncores in [1usize, 3] {
            let ctx = ExecCtx::new(ncores, 16, crate::scheduler::pool::Policy::Prio);
            let tiled = exact_predict_ctx(
                k_arc.clone(),
                &theta,
                &locs,
                &z,
                &new_locs,
                DistanceMetric::Euclidean,
                true,
                &ctx,
            )
            .unwrap();
            for j in 0..new_locs.len() {
                assert!(
                    (tiled.mean[j] - dense.mean[j]).abs() < 1e-10,
                    "ncores={ncores} mean[{j}]: {} vs {}",
                    tiled.mean[j],
                    dense.mean[j]
                );
                let (vt, vd) = (
                    tiled.variance.as_ref().unwrap()[j],
                    dense.variance.as_ref().unwrap()[j],
                );
                assert!((vt - vd).abs() < 1e-10, "ncores={ncores} var[{j}]");
            }
        }
    }

    #[test]
    fn kriging_variance_grows_with_distance() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [1.0, 0.1, 0.5];
        let (locs, z) = setup(40, 72);
        let new_locs = vec![
            Location::new(locs[0].x + 0.01, locs[0].y), // near an obs
            Location::new(5.0, 5.0),                    // far away
        ];
        let pred = exact_predict(
            k.as_ref(),
            &theta,
            &locs,
            &z,
            &new_locs,
            DistanceMetric::Euclidean,
            true,
        )
        .unwrap();
        let v = pred.variance.unwrap();
        assert!(v[0] < v[1], "{} !< {}", v[0], v[1]);
        // far away: variance ~ sigma^2, mean ~ 0 (prior)
        assert!((v[1] - 1.0).abs() < 1e-6);
        assert!(pred.mean[1].abs() < 1e-6);
    }

    #[test]
    fn fisher_is_symmetric_pd_and_scales_with_n() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [1.0, 0.1, 0.5];
        let (locs, _) = setup(36, 73);
        let f1 = exact_fisher(k.as_ref(), &theta, &locs[..18], DistanceMetric::Euclidean).unwrap();
        let f2 = exact_fisher(k.as_ref(), &theta, &locs, DistanceMetric::Euclidean).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert!((f2.fisher[(i, j)] - f2.fisher[(j, i)]).abs() < 1e-9);
            }
            // more data => more information => smaller std errs
            assert!(
                f2.std_errs[i] < f1.std_errs[i] * 1.2,
                "param {i}: {} vs {}",
                f2.std_errs[i],
                f1.std_errs[i]
            );
            assert!(f2.fisher[(i, i)] > 0.0);
        }
    }

    #[test]
    fn mloe_mmom_zero_at_truth() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta = [1.0, 0.1, 0.5];
        let (locs, _) = setup(25, 74);
        let new_locs = vec![Location::new(0.5, 0.5), Location::new(0.2, 0.8)];
        let r = exact_mloe_mmom(
            k.as_ref(),
            &theta,
            &theta,
            &locs,
            &new_locs,
            DistanceMetric::Euclidean,
        )
        .unwrap();
        assert!(r.mloe.abs() < 1e-10, "mloe {}", r.mloe);
        assert!(r.mmom.abs() < 1e-10, "mmom {}", r.mmom);
    }

    #[test]
    fn mloe_positive_under_misspecification() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let theta_t = [1.0, 0.1, 0.5];
        let theta_a = [1.0, 0.4, 2.0]; // badly wrong range + smoothness
        let (locs, _) = setup(30, 75);
        let new_locs: Vec<Location> = (0..10)
            .map(|i| Location::new(0.05 + 0.09 * i as f64, 0.45))
            .collect();
        let r = exact_mloe_mmom(
            k.as_ref(),
            &theta_t,
            &theta_a,
            &locs,
            &new_locs,
            DistanceMetric::Euclidean,
        )
        .unwrap();
        assert!(r.mloe > 0.0, "mloe {}", r.mloe);
    }
}
