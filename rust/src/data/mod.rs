//! Dataset I/O and the synthetic sea-surface-temperature system used by
//! the Section-IV tutorial (Figs 8–9, Table VI).

pub mod csv;
pub mod sst;
