//! Synthetic Agulhas-current sea-surface-temperature system — the
//! substitute for the satellite dataset of Section IV (see DESIGN.md §5).
//!
//! The paper's data: 331 days over a 72 x 240 grid (~25 km) off South
//! Africa, with gaps from (1) land, (2) satellite orbital clipping and
//! (3) cloud cover; days with more than 50% missing are dropped; a linear
//! mean in (lon, lat) is removed by OLS, and the residual field is fitted
//! with a Matérn GRF.  Table VI reports per-day estimates centred near
//! `(sigma_sq, beta, nu) ~ (6.3, 3.0, 0.91)`.
//!
//! We generate days with *known* ground truth: a linear-gradient mean
//! field plus an exactly-sampled Matérn GRF, masked by procedural land /
//! orbital-wedge / cloud processes.  The default grid is scaled down from
//! 72 x 240 so the exact `O(n^3)` fits of the tutorial run in seconds on
//! this testbed (documented in EXPERIMENTS.md §SST workload scaling); the
//! full paper shape is a config change.

use crate::covariance::{DistanceMetric, Location};
use crate::likelihood::ExecCtx;
use crate::rng::Pcg64;
use crate::simulation::simulate_obs_exact;
use std::sync::Arc;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct SstConfig {
    /// Grid height (latitude cells); paper: 72.
    pub ny: usize,
    /// Grid width (longitude cells); paper: 240.
    pub nx: usize,
    /// Number of days; paper: 331.
    pub days: usize,
    pub seed: u64,
    /// Longitude range (degrees E).
    pub lon0: f64,
    pub lon1: f64,
    /// Latitude range (degrees N, southern hemisphere = negative).
    pub lat0: f64,
    pub lat1: f64,
}

impl Default for SstConfig {
    fn default() -> Self {
        SstConfig {
            ny: 24,
            nx: 80,
            days: 331,
            seed: 2004, // the dataset year
            lon0: 10.0,
            lon1: 40.0,
            lat0: -46.0,
            lat1: -28.0,
        }
    }
}

/// One generated day.
#[derive(Clone, Debug)]
pub struct SstDay {
    pub day: usize,
    /// Full truth field (ny*nx, row-major by latitude row).
    pub truth: Vec<f64>,
    /// Observed field: `NaN` where masked.
    pub observed: Vec<f64>,
    /// Mask reason per cell: 0 = valid, 1 = land, 2 = orbit, 3 = cloud.
    pub mask: Vec<u8>,
    /// Grid cell coordinates (lon, lat), aligned with `truth`.
    pub locs: Vec<Location>,
    /// True GRF parameters for this day `(sigma_sq, beta, nu)`.
    pub theta_true: [f64; 3],
    /// True mean coefficients `(c, a_lon, b_lat)`.
    pub mean_coef: [f64; 3],
}

impl SstDay {
    pub fn valid_fraction(&self) -> f64 {
        self.mask.iter().filter(|&&m| m == 0).count() as f64 / self.mask.len() as f64
    }

    /// Valid observations as (locations, values).
    pub fn valid_observations(&self) -> (Vec<Location>, Vec<f64>) {
        let mut locs = Vec::new();
        let mut z = Vec::new();
        for i in 0..self.mask.len() {
            if self.mask[i] == 0 {
                locs.push(self.locs[i]);
                z.push(self.observed[i]);
            }
        }
        (locs, z)
    }

    /// Gap cells that should be predicted (orbit/cloud, not land).
    pub fn predictable_gaps(&self) -> (Vec<Location>, Vec<f64>) {
        let mut locs = Vec::new();
        let mut truth = Vec::new();
        for i in 0..self.mask.len() {
            if self.mask[i] == 2 || self.mask[i] == 3 {
                locs.push(self.locs[i]);
                truth.push(self.truth[i]);
            }
        }
        (locs, truth)
    }
}

/// Smooth value noise on the grid (bilinear interpolation of a coarse
/// random lattice) — drives the cloud mask.
fn value_noise(ny: usize, nx: usize, cells: usize, rng: &mut Pcg64) -> Vec<f64> {
    let gy = cells + 1;
    let gx = cells * 3 + 1;
    let lattice: Vec<f64> = (0..gy * gx).map(|_| rng.next_f64()).collect();
    let mut out = vec![0.0; ny * nx];
    for r in 0..ny {
        for c in 0..nx {
            let fy = r as f64 / ny as f64 * (gy - 1) as f64;
            let fx = c as f64 / nx as f64 * (gx - 1) as f64;
            let (y0, x0) = (fy.floor() as usize, fx.floor() as usize);
            let (y1, x1) = ((y0 + 1).min(gy - 1), (x0 + 1).min(gx - 1));
            let (ty, tx) = (fy - y0 as f64, fx - x0 as f64);
            let v00 = lattice[y0 * gx + x0];
            let v01 = lattice[y0 * gx + x1];
            let v10 = lattice[y1 * gx + x0];
            let v11 = lattice[y1 * gx + x1];
            out[r * nx + c] =
                v00 * (1.0 - ty) * (1.0 - tx) + v01 * (1.0 - ty) * tx + v10 * ty * (1.0 - tx)
                    + v11 * ty * tx;
        }
    }
    out
}

/// Generate day `day` (0-based).  Deterministic in `(cfg.seed, day)`.
pub fn generate_day(cfg: &SstConfig, day: usize, ctx: &ExecCtx) -> anyhow::Result<SstDay> {
    let mut rng = Pcg64::seed_stream(cfg.seed, day as u64);
    let (ny, nx) = (cfg.ny, cfg.nx);
    let n = ny * nx;

    // Grid locations (lon, lat in degrees; row-major latitude-first).
    let mut locs = Vec::with_capacity(n);
    for r in 0..ny {
        let lat = cfg.lat0 + (cfg.lat1 - cfg.lat0) * (r as f64 + 0.5) / ny as f64;
        for c in 0..nx {
            let lon = cfg.lon0 + (cfg.lon1 - cfg.lon0) * (c as f64 + 0.5) / nx as f64;
            locs.push(Location::new(lon, lat));
        }
    }

    // Day-specific true parameters: Table VI-centred, with seasonal drift.
    let season = (2.0 * std::f64::consts::PI * day as f64 / 365.0).sin();
    let sigma_sq = (6.3 + 1.2 * season + rng.normal() * 0.8).clamp(3.0, 14.5);
    let beta = (3.0 + 0.3 * season + rng.normal() * 0.35).clamp(1.8, 4.8);
    let nu = (0.91 + rng.normal() * 0.035).clamp(0.78, 1.05);
    let theta_true = [sigma_sq, beta, nu];

    // Mean field: strong latitudinal gradient (3.5..25.5 C, as in Fig 8),
    // a weak longitudinal term, seasonal offset.
    let a_lon = 0.05 + 0.02 * season;
    let b_lat = 22.0 / (cfg.lat1 - cfg.lat0); // ~1.2 C per degree
    let c0 = 20.0 + 1.5 * season - b_lat * (cfg.lat1 + cfg.lat0) / 2.0 - a_lon * (cfg.lon0 + cfg.lon1) / 2.0;
    let mean_coef = [c0, a_lon, b_lat];

    // Exact GRF sample on the grid (tiled Cholesky path).
    let kernel: Arc<dyn crate::covariance::CovKernel> =
        Arc::from(crate::covariance::kernel_by_name("ugsm-s")?);
    let eps = simulate_obs_exact(
        kernel,
        &theta_true,
        locs.clone(),
        DistanceMetric::Euclidean,
        cfg.seed ^ (day as u64).wrapping_mul(0x9E37_79B9),
        ctx,
    )?;

    let mut truth = vec![0.0; n];
    for i in 0..n {
        truth[i] = c0 + a_lon * locs[i].x + b_lat * locs[i].y + eps.z[i];
    }

    // --- masks ---
    let mut mask = vec![0u8; n];
    // (1) Land: procedural coastline in the north-west (South Africa),
    // plus two small islands to the south (as in Fig 8).
    for r in 0..ny {
        for c in 0..nx {
            let i = r * nx + c;
            let lon = locs[i].x;
            let lat = locs[i].y;
            let coast = cfg.lat1 - 4.5 - 0.22 * (lon - cfg.lon0) + 1.3 * ((lon - cfg.lon0) * 0.45).sin();
            if lat > coast && lon < cfg.lon0 + 0.6 * (cfg.lon1 - cfg.lon0) {
                mask[i] = 1;
            }
            // islands
            for (ilon, ilat) in [(37.8, -46.6), (37.9, -46.4)] {
                let d2 = (lon - ilon).powi(2) + (lat - ilat).powi(2);
                if d2 < 0.35 {
                    mask[i] = 1;
                }
            }
        }
    }
    // (2) Orbital wedges: diagonal bands whose phase shifts per day.
    let phase = rng.next_f64() * 30.0;
    let orbit_width = rng.uniform(0.04, 0.11);
    for r in 0..ny {
        for c in 0..nx {
            let i = r * nx + c;
            if mask[i] != 0 {
                continue;
            }
            let s = (locs[i].x + 0.55 * locs[i].y + phase) / 14.0;
            if s.fract().abs() < orbit_width {
                mask[i] = 2;
            }
        }
    }
    // (3) Clouds: thresholded smooth noise; threshold drawn per day so the
    // missing fraction varies from day to day (paper: some days >50%).
    let noise = value_noise(ny, nx, 4, &mut rng);
    let cloudiness = rng.uniform(0.25, 0.75);
    for i in 0..n {
        if mask[i] == 0 && noise[i] > 1.0 - cloudiness * 0.55 {
            mask[i] = 3;
        }
    }

    let observed: Vec<f64> = (0..n)
        .map(|i| if mask[i] == 0 { truth[i] } else { f64::NAN })
        .collect();

    Ok(SstDay {
        day,
        truth,
        observed,
        mask,
        locs,
        theta_true,
        mean_coef,
    })
}

/// Stream the configured days lazily: each `next()` generates exactly
/// one [`SstDay`] (grid + GRF sample + masks) and hands it off, so the
/// resident footprint of a whole-campaign sweep is one day's field —
/// not `days × ny × nx` — matching the out-of-core posture of the rest
/// of the pipeline.  Deterministic per `(cfg.seed, day)` exactly like
/// calling [`generate_day`] in a loop.
pub fn stream_days<'a>(
    cfg: &'a SstConfig,
    ctx: &'a ExecCtx,
) -> impl Iterator<Item = anyhow::Result<SstDay>> + 'a {
    (0..cfg.days).map(move |day| generate_day(cfg, day, ctx))
}

/// OLS fit of `z ~ 1 + lon + lat` (the tutorial's first stage).
/// Returns `(coef = [c, a, b], residuals)`.
pub fn ols_linear_mean(locs: &[Location], z: &[f64]) -> ([f64; 3], Vec<f64>) {
    assert_eq!(locs.len(), z.len());
    // normal equations X'X beta = X'z for X = [1, lon, lat]
    let n = locs.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy, mut syy, mut sz, mut sxz, mut syz) =
        (0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
    for (l, &zi) in locs.iter().zip(z) {
        sx += l.x;
        sy += l.y;
        sxx += l.x * l.x;
        sxy += l.x * l.y;
        syy += l.y * l.y;
        sz += zi;
        sxz += l.x * zi;
        syz += l.y * zi;
    }
    let mut ata = [n, sx, sy, sx, sxx, sxy, sy, sxy, syy];
    let mut atz = [sz, sxz, syz];
    // 3x3 Cholesky solve
    crate::linalg::blas::dpotrf_raw(3, &mut ata, 3).expect("OLS normal equations SPD");
    crate::linalg::blas::dtrsv_ln(3, &ata, 3, &mut atz);
    crate::linalg::blas::dtrsv_lt(3, &ata, 3, &mut atz);
    let coef = [atz[0], atz[1], atz[2]];
    let resid: Vec<f64> = locs
        .iter()
        .zip(z)
        .map(|(l, &zi)| zi - coef[0] - coef[1] * l.x - coef[2] * l.y)
        .collect();
    (coef, resid)
}

/// Simple quantile (linear interpolation) for Table VI style summaries.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ExecCtx {
        ExecCtx::new(1, 128, crate::scheduler::pool::Policy::Eager)
    }

    fn tiny_cfg() -> SstConfig {
        SstConfig {
            ny: 12,
            nx: 40,
            days: 4,
            ..SstConfig::default()
        }
    }

    #[test]
    fn day_generation_shapes_and_determinism() {
        let cfg = tiny_cfg();
        let d1 = generate_day(&cfg, 0, &ctx()).unwrap();
        assert_eq!(d1.truth.len(), 480);
        assert_eq!(d1.locs.len(), 480);
        let d2 = generate_day(&cfg, 0, &ctx()).unwrap();
        // NaN != NaN, so compare bit patterns for determinism.
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&d1.observed), bits(&d2.observed));
        assert_eq!(d1.mask, d2.mask);
        let d3 = generate_day(&cfg, 1, &ctx()).unwrap();
        assert_ne!(bits(&d1.truth), bits(&d3.truth));
    }

    #[test]
    fn masks_have_all_three_causes() {
        let cfg = tiny_cfg();
        let mut seen = [false; 4];
        for day in 0..4 {
            let d = generate_day(&cfg, day, &ctx()).unwrap();
            for &m in &d.mask {
                seen[m as usize] = true;
            }
        }
        assert!(seen[0], "some valid cells");
        assert!(seen[1], "land");
        assert!(seen[2], "orbit wedges");
        assert!(seen[3], "clouds");
    }

    #[test]
    fn observed_nan_iff_masked() {
        let d = generate_day(&tiny_cfg(), 2, &ctx()).unwrap();
        for i in 0..d.mask.len() {
            assert_eq!(d.mask[i] != 0, d.observed[i].is_nan());
        }
        let (locs, z) = d.valid_observations();
        assert_eq!(locs.len(), z.len());
        assert!(z.iter().all(|v| v.is_finite()));
        assert!((d.valid_fraction() - locs.len() as f64 / 480.0).abs() < 1e-12);
    }

    #[test]
    fn temperature_gradient_matches_agulhas() {
        // northern rows warmer than southern rows (southern hemisphere)
        let d = generate_day(&tiny_cfg(), 0, &ctx()).unwrap();
        let cfg = tiny_cfg();
        let north: f64 = d.truth[(cfg.ny - 1) * cfg.nx..].iter().sum::<f64>() / cfg.nx as f64;
        let south: f64 = d.truth[..cfg.nx].iter().sum::<f64>() / cfg.nx as f64;
        assert!(
            north > south + 5.0,
            "north {north} vs south {south} (gradient missing)"
        );
    }

    #[test]
    fn stream_days_matches_loop_generation() {
        let cfg = tiny_cfg();
        let ctx = ctx();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut days = 0;
        for (day, d) in stream_days(&cfg, &ctx).enumerate() {
            let d = d.unwrap();
            assert_eq!(d.day, day);
            let direct = generate_day(&cfg, day, &ctx).unwrap();
            assert_eq!(bits(&d.observed), bits(&direct.observed));
            assert_eq!(d.mask, direct.mask);
            days += 1;
        }
        assert_eq!(days, cfg.days);
    }

    #[test]
    fn ols_recovers_linear_mean() {
        let cfg = tiny_cfg();
        let d = generate_day(&cfg, 3, &ctx()).unwrap();
        let (locs, z) = d.valid_observations();
        let (coef, resid) = ols_linear_mean(&locs, &z);
        // lat coefficient dominates and is estimated within a loose band
        assert!(
            (coef[2] - d.mean_coef[2]).abs() < 0.5 * d.mean_coef[2].abs(),
            "lat coef {} vs truth {}",
            coef[2],
            d.mean_coef[2]
        );
        // residuals are centred
        let mean_r: f64 = resid.iter().sum::<f64>() / resid.len() as f64;
        assert!(mean_r.abs() < 1e-8);
    }

    #[test]
    fn quantile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }
}
