//! CSV persistence for `GeoData` (`x,y,z` columns, matching the example
//! datasets the ExaGeoStat project publishes).

use crate::covariance::Location;
use crate::simulation::GeoData;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write `data` as `x,y,z` CSV with a header row.
pub fn write_geodata(path: &Path, data: &GeoData) -> anyhow::Result<()> {
    anyhow::ensure!(
        data.z.len() == data.locs.len(),
        "csv writer supports univariate data"
    );
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "x,y,z")?;
    for (l, z) in data.locs.iter().zip(&data.z) {
        writeln!(w, "{},{},{}", l.x, l.y, z)?;
    }
    Ok(())
}

/// Rows per chunk of the streaming reader when the caller does not pick
/// a size ([`read_geodata`] uses it): big enough to amortize per-chunk
/// overhead, small enough that a chunk is a bounded allocation
/// (~1.5 MB) regardless of file size.
pub const READ_CHUNK_ROWS: usize = 1 << 16;

/// Parse one non-header CSV row (`lineno` is 0-based, for messages).
fn parse_row(t: &str, lineno: usize) -> anyhow::Result<(Location, f64)> {
    let mut parts = t.split(',');
    let mut parse = |what: &str| -> anyhow::Result<f64> {
        parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing {what}", lineno + 1))?
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad {what}", lineno + 1))
    };
    let x = parse("x")?;
    let y = parse("y")?;
    let zv = parse("z")?;
    Ok((Location::new(x, y), zv))
}

/// Streaming CSV reader: an iterator of up-to-`chunk`-row [`GeoData`]
/// batches (see [`read_geodata_chunks`]).  Holds one `BufRead` line
/// buffer plus the chunk being built — resident memory is bounded by
/// the chunk size, not the file size.
pub struct GeoDataChunks {
    lines: std::iter::Enumerate<std::io::Lines<std::io::BufReader<std::fs::File>>>,
    chunk: usize,
    done: bool,
}

/// Open `path` for chunked reading: each `next()` yields the following
/// `chunk` data rows as one [`GeoData`] batch (the last batch may be
/// short).  Header and blank lines are skipped as in [`read_geodata`].
/// A parse/IO error ends the stream after being yielded once.
pub fn read_geodata_chunks(path: &Path, chunk: usize) -> anyhow::Result<GeoDataChunks> {
    let f = std::fs::File::open(path)?;
    Ok(GeoDataChunks {
        lines: std::io::BufReader::new(f).lines().enumerate(),
        chunk: chunk.max(1),
        done: false,
    })
}

impl Iterator for GeoDataChunks {
    type Item = anyhow::Result<GeoData>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        let mut locs = Vec::new();
        let mut z = Vec::new();
        while locs.len() < self.chunk {
            let Some((lineno, line)) = self.lines.next() else {
                self.done = true;
                break;
            };
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    self.done = true;
                    return Some(Err(e.into()));
                }
            };
            let t = line.trim();
            if t.is_empty() || (lineno == 0 && t.starts_with(|c: char| c.is_alphabetic())) {
                continue;
            }
            match parse_row(t, lineno) {
                Ok((loc, zv)) => {
                    locs.push(loc);
                    z.push(zv);
                }
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            }
        }
        if locs.is_empty() {
            None
        } else {
            Some(Ok(GeoData { locs, z }))
        }
    }
}

/// Read `x,y,z` CSV (header optional) whole, via the chunked reader.
pub fn read_geodata(path: &Path) -> anyhow::Result<GeoData> {
    let mut locs = Vec::new();
    let mut z = Vec::new();
    for chunk in read_geodata_chunks(path, READ_CHUNK_ROWS)? {
        let c = chunk?;
        locs.extend(c.locs);
        z.extend(c.z);
    }
    anyhow::ensure!(!locs.is_empty(), "no data rows in {path:?}");
    Ok(GeoData { locs, z })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let data = GeoData {
            locs: vec![Location::new(0.1, 0.2), Location::new(0.5, -1.0)],
            z: vec![3.25, -0.5],
        };
        let dir = std::env::temp_dir().join("exageostat_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        write_geodata(&path, &data).unwrap();
        let back = read_geodata(&path).unwrap();
        assert_eq!(back.locs.len(), 2);
        assert_eq!(back.z, data.z);
        assert!((back.locs[1].y + 1.0).abs() < 1e-15);
    }

    #[test]
    fn chunked_read_matches_whole_and_bounds_batches() {
        let dir = std::env::temp_dir().join("exageostat_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("chunks.csv");
        let data = GeoData {
            locs: (0..23)
                .map(|i| Location::new(i as f64 * 0.1, -(i as f64)))
                .collect(),
            z: (0..23).map(|i| i as f64 / 7.0).collect(),
        };
        write_geodata(&path, &data).unwrap();
        let whole = read_geodata(&path).unwrap();
        // chunk = 5: batches of 5,5,5,5,3; concatenation bit-identical.
        let mut sizes = Vec::new();
        let mut locs = Vec::new();
        let mut z = Vec::new();
        for c in read_geodata_chunks(&path, 5).unwrap() {
            let c = c.unwrap();
            assert!(c.n() <= 5);
            sizes.push(c.n());
            locs.extend(c.locs);
            z.extend(c.z);
        }
        assert_eq!(sizes, vec![5, 5, 5, 5, 3]);
        assert_eq!(z.len(), whole.z.len());
        for (a, b) in z.iter().zip(&whole.z) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in locs.iter().zip(&whole.locs) {
            assert_eq!(a.x.to_bits(), b.x.to_bits());
            assert_eq!(a.y.to_bits(), b.y.to_bits());
        }
    }

    #[test]
    fn chunked_read_surfaces_error_once_then_ends() {
        let dir = std::env::temp_dir().join("exageostat_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_chunk.csv");
        std::fs::write(&path, "x,y,z\n1,2,3\n4,oops,6\n7,8,9\n").unwrap();
        let mut it = read_geodata_chunks(&path, 1).unwrap();
        assert!(it.next().unwrap().is_ok());
        assert!(it.next().unwrap().is_err());
        assert!(it.next().is_none(), "stream ends after the error");
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("exageostat_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "x,y,z\n1,notanumber,3\n").unwrap();
        assert!(read_geodata(&path).is_err());
        std::fs::write(&path, "x,y,z\n").unwrap();
        assert!(read_geodata(&path).is_err());
    }
}
