//! CSV persistence for `GeoData` (`x,y,z` columns, matching the example
//! datasets the ExaGeoStat project publishes).

use crate::covariance::Location;
use crate::simulation::GeoData;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write `data` as `x,y,z` CSV with a header row.
pub fn write_geodata(path: &Path, data: &GeoData) -> anyhow::Result<()> {
    anyhow::ensure!(
        data.z.len() == data.locs.len(),
        "csv writer supports univariate data"
    );
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "x,y,z")?;
    for (l, z) in data.locs.iter().zip(&data.z) {
        writeln!(w, "{},{},{}", l.x, l.y, z)?;
    }
    Ok(())
}

/// Read `x,y,z` CSV (header optional).
pub fn read_geodata(path: &Path) -> anyhow::Result<GeoData> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut locs = Vec::new();
    let mut z = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || (lineno == 0 && t.starts_with(|c: char| c.is_alphabetic())) {
            continue;
        }
        let mut parts = t.split(',');
        let parse = |p: Option<&str>, what: &str| -> anyhow::Result<f64> {
            p.ok_or_else(|| anyhow::anyhow!("line {}: missing {what}", lineno + 1))?
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("line {}: bad {what}", lineno + 1))
        };
        let x = parse(parts.next(), "x")?;
        let y = parse(parts.next(), "y")?;
        let zv = parse(parts.next(), "z")?;
        locs.push(Location::new(x, y));
        z.push(zv);
    }
    anyhow::ensure!(!locs.is_empty(), "no data rows in {path:?}");
    Ok(GeoData { locs, z })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let data = GeoData {
            locs: vec![Location::new(0.1, 0.2), Location::new(0.5, -1.0)],
            z: vec![3.25, -0.5],
        };
        let dir = std::env::temp_dir().join("exageostat_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        write_geodata(&path, &data).unwrap();
        let back = read_geodata(&path).unwrap();
        assert_eq!(back.locs.len(), 2);
        assert_eq!(back.z, data.z);
        assert!((back.locs[1].y + 1.0).abs() < 1e-15);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("exageostat_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "x,y,z\n1,notanumber,3\n").unwrap();
        assert!(read_geodata(&path).is_err());
        std::fs::write(&path, "x,y,z\n").unwrap();
        assert!(read_geodata(&path).is_err());
    }
}
