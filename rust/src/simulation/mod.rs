//! Synthetic dataset generation — the paper's "large-scale synthetic data
//! generator" (§II-A, Example 1): exact GRF realizations `z = L e` with
//! `Sigma = L L^T` from the tiled Cholesky.

use crate::covariance::{CovKernel, DistanceMetric, Location};
use crate::likelihood::{ExecCtx, Problem};
use crate::linalg::blas::{dgemv_raw, dtrmv_ln, Trans};
use crate::linalg::tile::TileMatrix;
use crate::rng::Pcg64;
use std::sync::Arc;

/// A simulated (or observed) geostatistical dataset:
/// the `data = list(x, y, z)` of the R API.
#[derive(Clone, Debug)]
pub struct GeoData {
    pub locs: Vec<Location>,
    /// Length `p * n` for `p`-variate kernels (variate-major).
    pub z: Vec<f64>,
}

impl GeoData {
    pub fn n(&self) -> usize {
        self.locs.len()
    }
    /// Into the likelihood problem form.
    pub fn into_problem(self, kernel: Arc<dyn CovKernel>, metric: DistanceMetric) -> Problem {
        Problem {
            kernel,
            locs: Arc::new(self.locs),
            z: Arc::new(self.z),
            metric,
        }
    }
}

/// Location layouts supported by the generator.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum LocationGen {
    /// `n` iid uniform points in the unit square (`simulate_data_exact`).
    IrregularUniform,
    /// `ceil(sqrt(n))^2 >= n` regular grid on [0, 1]^2, truncated to `n`.
    RegularGrid,
    /// ExaGeoStat's layout (Abdulah et al. 2018a): a sqrt(n) x sqrt(n)
    /// grid jittered uniformly within each cell, then shuffled.
    PerturbedGrid,
}

/// Generate locations.
pub fn gen_locations(gen: LocationGen, n: usize, rng: &mut Pcg64) -> Vec<Location> {
    match gen {
        LocationGen::IrregularUniform => (0..n)
            .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
            .collect(),
        LocationGen::RegularGrid => {
            let side = (n as f64).sqrt().ceil() as usize;
            let mut locs = Vec::with_capacity(n);
            'outer: for j in 0..side {
                for i in 0..side {
                    if locs.len() >= n {
                        break 'outer;
                    }
                    locs.push(Location::new(
                        (i + 1) as f64 / side as f64,
                        (j + 1) as f64 / side as f64,
                    ));
                }
            }
            locs
        }
        LocationGen::PerturbedGrid => {
            let side = (n as f64).sqrt().ceil() as usize;
            let mut locs = Vec::with_capacity(side * side);
            for j in 0..side {
                for i in 0..side {
                    let jit_x = rng.uniform(-0.4, 0.4);
                    let jit_y = rng.uniform(-0.4, 0.4);
                    locs.push(Location::new(
                        (i as f64 + 0.5 + jit_x) / side as f64,
                        (j as f64 + 0.5 + jit_y) / side as f64,
                    ));
                }
            }
            rng.shuffle(&mut locs);
            locs.truncate(n);
            locs
        }
    }
}

/// Exact GRF sampling at given locations: build `Sigma`, factor it with the
/// tiled Cholesky, return `z = L e` with `e ~ N(0, I)`.
/// This is `simulate_obs_exact` of the R API.
pub fn simulate_obs_exact(
    kernel: Arc<dyn CovKernel>,
    theta: &[f64],
    locs: Vec<Location>,
    metric: DistanceMetric,
    seed: u64,
    ctx: &ExecCtx,
) -> anyhow::Result<GeoData> {
    kernel.validate(theta)?;
    let p = kernel.nvariates();
    let dim = p * locs.len();
    let problem = Problem {
        kernel,
        locs: Arc::new(locs),
        z: Arc::new(Vec::new()),
        metric,
    };
    // Generate + factor Sigma (tiled, parallel) through the pipeline IR
    // (no solve, no log-det: simulation only needs the factor).
    let a = TileMatrix::zeros(dim, ctx.ts);
    let out = crate::pipeline::run_tiled(&problem, theta, ctx, None, &a, None, None, false)?;
    if let Some(pivot) = out.not_spd {
        anyhow::bail!("simulation covariance not SPD at pivot {pivot}");
    }

    // z = L e, computed tile-block-wise:
    // z_i = L_ii e_i (trmv) + sum_{j<i} L_ij e_j (gemv)
    let mut rng = Pcg64::seed_stream(seed, 0xD474);
    let mut e = vec![0.0; dim];
    rng.fill_normal(&mut e);
    let ts = ctx.ts;
    let nt = a.nt();
    let mut z = vec![0.0; dim];
    for i in 0..nt {
        let h = a.tile_rows(i);
        let lo = i * ts;
        let mut zi = e[lo..lo + h].to_vec();
        let diag = a.tile(i, i);
        dtrmv_ln(h, diag, h, &mut zi);
        for j in 0..i {
            let w = a.tile_cols(j);
            let jlo = j * ts;
            dgemv_raw(
                Trans::N,
                h,
                w,
                1.0,
                a.tile(i, j),
                h,
                &e[jlo..jlo + w],
                1.0,
                &mut zi,
            );
        }
        z[lo..lo + h].copy_from_slice(&zi);
    }

    let locs = Arc::try_unwrap(problem.locs).unwrap();
    Ok(GeoData { locs, z })
}

/// `simulate_data_exact` of the R API: random irregular locations in the
/// unit square + exact GRF sample.
pub fn simulate_data_exact(
    kernel: Arc<dyn CovKernel>,
    theta: &[f64],
    n: usize,
    metric: DistanceMetric,
    seed: u64,
    ctx: &ExecCtx,
) -> anyhow::Result<GeoData> {
    let mut rng = Pcg64::seed_stream(seed, 0x10C5);
    let locs = gen_locations(LocationGen::IrregularUniform, n, &mut rng);
    simulate_obs_exact(kernel, theta, locs, metric, seed, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covariance::kernel_by_name;

    fn ctx() -> ExecCtx {
        ExecCtx::new(2, 32, crate::scheduler::pool::Policy::Lws)
    }

    #[test]
    fn location_generators_shapes() {
        let mut rng = Pcg64::seed_from_u64(61);
        for gen in [
            LocationGen::IrregularUniform,
            LocationGen::RegularGrid,
            LocationGen::PerturbedGrid,
        ] {
            let locs = gen_locations(gen, 100, &mut rng);
            assert_eq!(locs.len(), 100, "{gen:?}");
            for l in &locs {
                assert!(l.x.is_finite() && l.y.is_finite());
            }
        }
        // regular grid exactly n when square
        let locs = gen_locations(LocationGen::RegularGrid, 16, &mut rng);
        assert_eq!(locs.len(), 16);
        assert!((locs[0].x - 0.25).abs() < 1e-12);
    }

    #[test]
    fn simulation_is_deterministic_per_seed() {
        let k = kernel_by_name("ugsm-s").unwrap();
        let k: Arc<dyn crate::covariance::CovKernel> = Arc::from(k);
        let d1 = simulate_data_exact(
            k.clone(),
            &[1.0, 0.1, 0.5],
            50,
            DistanceMetric::Euclidean,
            7,
            &ctx(),
        )
        .unwrap();
        let d2 = simulate_data_exact(
            k.clone(),
            &[1.0, 0.1, 0.5],
            50,
            DistanceMetric::Euclidean,
            7,
            &ctx(),
        )
        .unwrap();
        assert_eq!(d1.z, d2.z);
        let d3 = simulate_data_exact(
            k,
            &[1.0, 0.1, 0.5],
            50,
            DistanceMetric::Euclidean,
            8,
            &ctx(),
        )
        .unwrap();
        assert_ne!(d1.z, d3.z);
    }

    #[test]
    fn sample_has_correct_covariance_structure() {
        // Monte-Carlo check: across many replicates the empirical
        // covariance of (z_0, z_1) approaches Sigma entries.
        let k: Arc<dyn crate::covariance::CovKernel> =
            Arc::from(kernel_by_name("ugsm-s").unwrap());
        let theta = [2.0, 0.2, 0.5];
        let locs = vec![
            Location::new(0.1, 0.1),
            Location::new(0.15, 0.1),
            Location::new(0.9, 0.9),
        ];
        let sigma = crate::covariance::build_cov_dense(
            k.as_ref(),
            &theta,
            &locs,
            DistanceMetric::Euclidean,
        );
        let reps = 4000;
        let mut acc = [[0.0f64; 3]; 3];
        for r in 0..reps {
            let d = simulate_obs_exact(
                k.clone(),
                &theta,
                locs.clone(),
                DistanceMetric::Euclidean,
                1000 + r as u64,
                &ctx(),
            )
            .unwrap();
            for i in 0..3 {
                for j in 0..3 {
                    acc[i][j] += d.z[i] * d.z[j] / reps as f64;
                }
            }
        }
        for i in 0..3 {
            for j in 0..3 {
                let want = sigma[(i, j)];
                let got = acc[i][j];
                assert!(
                    (got - want).abs() < 0.15 * (1.0 + want.abs()),
                    "cov[{i}][{j}]: got {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn multivariate_sample_length() {
        let k: Arc<dyn crate::covariance::CovKernel> =
            Arc::from(kernel_by_name("bgspm-s").unwrap());
        let theta = [1.0, 1.5, 0.1, 0.5, 1.0, 0.4];
        let d = simulate_data_exact(k, &theta, 20, DistanceMetric::Euclidean, 3, &ctx()).unwrap();
        assert_eq!(d.locs.len(), 20);
        assert_eq!(d.z.len(), 40);
    }
}
