//! Persistent task runtime: long-lived workers + a concurrent job queue.
//!
//! StarPU initializes its runtime once per hardware context
//! (`starpu_init`) and multiplexes every subsequently submitted task DAG
//! onto the same worker set; ExaGeoStat inherits that lifecycle — a
//! 500-iteration MLE run pays the thread-spawn cost exactly once.  The
//! original [`super::pool::run`] executor instead spawned and joined
//! `ncores` OS threads on *every* graph execution, which both taxes the
//! MLE hot loop and makes concurrent serving structurally impossible
//! (one graph owns the whole pool).
//!
//! [`Runtime`] fixes the lifecycle:
//!
//! * workers are spawned once, at [`Runtime::new`] (ExaGeoStat's
//!   `exageostat_init`), and live until [`Runtime::shutdown`] / `Drop`
//!   (`exageostat_finalize`) — parked on a condvar while idle;
//! * whole task graphs are submitted as **jobs**
//!   ([`Runtime::submit`] → [`JobHandle`]); any number of jobs may be in
//!   flight at once, their ready tasks interleaved under the same
//!   `eager` / `prio` / `lws` / `random` policies as before, with the
//!   job's priority (then job age) as the tie-break under `prio`;
//! * [`JobHandle::wait`] blocks until the job's last task retires and
//!   returns the per-job execution [`Profile`].
//!
//! # Safety contract
//!
//! Task closures routinely capture raw [`crate::linalg::tile::TilePtr`]s
//! into caller-owned tile storage.  The old scoped-thread pool pinned
//! that storage alive by construction; with a persistent runtime the
//! *handle* carries the obligation: the job must be waited on before the
//! storage a graph references is dropped.  `JobHandle` therefore waits
//! for completion on `Drop` as well, so simply keeping the handle in
//! scope alongside the storage (what every pipeline in this crate does)
//! is sufficient.

use super::placement::{note_class_failure, slow_factor, ClassSpec, ClassStat, WorkerClass};
use super::pool::Policy;
use super::profile::{ClassCostModel, Profile, TaskRecord};
use super::{faults, Access, TaskGraph, TaskKind};
use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock, Weak};
use std::time::{Duration, Instant};

/// Process-wide count of worker threads ever spawned by any [`Runtime`]
/// (re-exported through `testkit`): the telemetry behind the
/// "a full MLE run spawns exactly `ncores` threads" regression tests.
static WORKER_THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Worker threads spawned by all runtimes of this process so far.
pub fn worker_threads_spawned() -> u64 {
    WORKER_THREADS_SPAWNED.load(Ordering::SeqCst)
}

/// Best-effort extraction of a panic payload's message.  Every place
/// that catches a panic to report it later — the workers here, the
/// client runners, `testkit::forall` — goes through this one helper so
/// panic reporting stays consistent.
pub fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<String>()
        .cloned()
        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// Typed failure of a task (and, aggregated, of a job) — replacing the
/// former first-panic-string so recovery layers can tell a crashed
/// kernel from a disk hiccup from a numerical breakdown from a deadline
/// (DESIGN.md §2j).  Carried in `JobState`, surfaced by
/// [`JobHandle::wait_result`], and converted into `api::ApiError`
/// variants at the API boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TaskError {
    /// A task closure panicked (caught on the worker; message kept).
    Panic(String),
    /// Spill-store or other I/O failed (tile read/write, prefetch).
    Io(String),
    /// Numerical breakdown — e.g. POTRF hit a non-positive-definite
    /// pivot where that is an error rather than a steerable value.
    Numerical(String),
    /// The job exceeded a deadline or the runtime watchdog's
    /// stall threshold and was cancelled with a timeout reason.
    Timeout(String),
}

impl std::fmt::Display for TaskError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TaskError::Panic(m) => write!(f, "task panicked: {m}"),
            TaskError::Io(m) => write!(f, "task i/o error: {m}"),
            TaskError::Numerical(m) => write!(f, "numerical error: {m}"),
            TaskError::Timeout(m) => write!(f, "timed out: {m}"),
        }
    }
}

impl std::error::Error for TaskError {}

/// Shared state behind a [`CancelToken`]: the monotone cancel flag plus
/// an optional *reason* bit distinguishing a deadline/watchdog firing
/// from an ordinary cancellation.
#[derive(Debug, Default)]
struct CancelInner {
    flag: AtomicBool,
    timeout: AtomicBool,
}

/// Cooperative cancellation token shared between a job's submitter and
/// the workers (and, higher up the stack, between a serving client and
/// the optimizer loop — see `api::mle_with_session`).
///
/// Cancellation is *advisory and monotonic*: once cancelled, a token
/// stays cancelled.  Workers consult the token before starting each
/// task of a cancelled job and skip the not-yet-started ones (already
/// running tasks finish — tile kernels are short); the optimizer
/// consults it between objective evaluations.  Cloning shares the flag.
///
/// A token fired via [`CancelToken::cancel_with_timeout`] (deadline
/// expiry, runtime watchdog) additionally reports
/// [`CancelToken::timed_out`], which the pipeline layers use to report
/// `Timeout` instead of `Cancelled`.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<CancelInner>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation (idempotent, takes effect at the next
    /// task/iteration boundary).
    pub fn cancel(&self) {
        self.0.flag.store(true, Ordering::SeqCst);
    }

    /// Cancel with a *timeout* reason: same skip semantics as
    /// [`CancelToken::cancel`], but [`CancelToken::timed_out`] reports
    /// true so the failure surfaces as `Timeout`, not `Cancelled`.
    /// The reason is set before the flag — any observer of the flag
    /// sees the reason.
    pub fn cancel_with_timeout(&self) {
        self.0.timeout.store(true, Ordering::SeqCst);
        self.0.flag.store(true, Ordering::SeqCst);
    }

    /// Has [`CancelToken::cancel`] been called on this token (or any
    /// clone of it)?
    pub fn is_cancelled(&self) -> bool {
        self.0.flag.load(Ordering::SeqCst)
    }

    /// Was this token cancelled for a deadline/watchdog timeout?
    pub fn timed_out(&self) -> bool {
        self.0.timeout.load(Ordering::SeqCst)
    }
}

/// Executable metadata of one task within a submitted job.
struct JobTask {
    kind: TaskKind,
    bytes: usize,
    /// Index into `Shared::classes` — resolved at submission from the
    /// task's [`WorkerClass`] annotation (default class when absent or
    /// when this runtime lacks the requested class).
    class: usize,
    succs: Vec<usize>,
}

/// Completion state of a job.
struct JobState {
    done: bool,
    wall: Duration,
    /// First task failure, typed; re-raised by [`JobHandle::wait`] on
    /// the waiting thread (the old scoped pool surfaced task panics via
    /// `join().unwrap()`) or returned by [`JobHandle::wait_result`].
    error: Option<TaskError>,
}

/// One submitted task graph, shared between the queues, the workers and
/// the caller's [`JobHandle`].
struct JobInner {
    /// Submission sequence number (older jobs win priority ties).
    seq: u64,
    /// Job-level priority: tie-break between jobs under the `prio`
    /// policy (higher runs first at equal task priority).
    priority: u8,
    /// Cancellation flag: workers skip (but still retire) every task
    /// they pop after the token fires.
    cancel: CancelToken,
    /// Tasks popped after cancellation and therefore never executed.
    skipped: AtomicUsize,
    tasks: Vec<JobTask>,
    /// Each closure is taken exactly once; the lock is uncontended.
    cells: Vec<Mutex<Option<Box<dyn FnOnce() + Send>>>>,
    /// Per-task timing slot, written exactly once by the executing
    /// worker (per-task locks, so workers never contend on a shared
    /// profile — the persistent-runtime equivalent of the old pool's
    /// per-worker local profiles).
    records: Vec<Mutex<Option<TaskRecord>>>,
    preds: Vec<AtomicUsize>,
    remaining: AtomicUsize,
    state: Mutex<JobState>,
    done_cv: Condvar,
    t0: Instant,
    /// Milliseconds from `t0` of the last task retirement — the
    /// watchdog's progress signal (only written on watchdog-enabled
    /// runtimes; the default hot path never touches it).
    last_progress_ms: AtomicU64,
    /// Process-global `(faults_injected, tasks_retried)` snapshot at
    /// submission; `wait_ref` reports the delta in the job's profile
    /// (best-effort attribution under concurrent jobs).
    fault_base: (u64, u64),
}

/// A task that became ready, bound to its job.
struct Ready {
    job: Arc<JobInner>,
    task: usize,
}

/// Priority-heap entry: ordered by (task priority, job priority, older
/// job first, older task first) so the pop order is deterministic.
struct HeapEntry {
    key: (u8, u8, std::cmp::Reverse<u64>, std::cmp::Reverse<usize>),
    ready: Ready,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// One worker class's contiguous slice of the worker/queue arrays.
/// A single all-`Cpu` range `{start: 0, count: nworkers}` makes every
/// queue/steal formula below reduce exactly to the pre-class runtime.
struct ClassRange {
    class: WorkerClass,
    start: usize,
    count: usize,
}

/// State shared between the workers and the submitting threads.
struct Shared {
    policy: Policy,
    nworkers: usize,
    /// Worker classes, in spec order; non-empty, counts sum to
    /// `nworkers`, ranges tile `0..nworkers` contiguously.
    classes: Vec<ClassRange>,
    /// Worker index -> index into `classes`.
    worker_class: Vec<usize>,
    /// Workers simulating the `Slow` class sleep `(slow_factor-1)` x each
    /// task's measured duration after running it (values untouched —
    /// results stay bit-identical, only timing changes).
    worker_slow: Vec<bool>,
    /// Class hosting unannotated tasks: the `Cpu` range if present,
    /// else class 0.
    default_class: usize,
    /// eager uses the first slot of each class range only; lws/random
    /// use one deque per worker.
    queues: Vec<Mutex<VecDeque<Ready>>>,
    /// One priority heap per class (prio policy).
    heaps: Vec<Mutex<BinaryHeap<HeapEntry>>>,
    cv: Condvar,
    cv_guard: Mutex<()>,
    /// Queued-but-not-popped ready tasks per class (guards against
    /// missed wakeups; workers park against their own class's counter).
    pending: Vec<AtomicUsize>,
    shutdown: AtomicBool,
    /// Submission gate: submits hold a read lock while seeding their
    /// job, shutdown takes the write lock before raising the flag — so
    /// a submit that passed the shutdown check can never seed tasks
    /// onto already-joined workers (which would hang its waiter).
    lifecycle: RwLock<()>,
    rng_state: AtomicUsize,
    tasks_executed: AtomicU64,
    /// Tasks retired without running because their job's cancellation
    /// token had fired — the work a won speculative race (or a client
    /// disconnect) saved.  Mirrors `tasks_executed` for stats.
    tasks_skipped: AtomicU64,
    /// Per-class counters (placement telemetry): tasks routed at push,
    /// tasks executed, intra-class steals.
    class_placed: Vec<AtomicU64>,
    class_executed: Vec<AtomicU64>,
    class_stolen: Vec<AtomicU64>,
    /// Measured per-(kind, class) costs, accumulated across jobs to feed
    /// the placer.  Only written on heterogeneous runtimes (>1 class) —
    /// the homogeneous hot path never takes this lock — or when the
    /// watchdog is on (it thresholds against the measured task mean).
    cost_stats: Mutex<ClassCostModel>,
    /// Watchdog enabled for this runtime (`EXAGEOSTAT_WATCHDOG` factor
    /// or the in-process override at build time).
    watchdog_on: bool,
    /// Jobs the watchdog scans (only populated when `watchdog_on`).
    live_jobs: Mutex<Vec<Weak<JobInner>>>,
}

impl Shared {
    /// Resolve a task's class annotation to a class index on *this*
    /// runtime.  Unknown/absent classes fall back to the default class so
    /// a placed graph remains runnable on any runtime.
    fn class_index(&self, class: Option<WorkerClass>) -> usize {
        match class {
            Some(c) => self
                .classes
                .iter()
                .position(|r| r.class == c)
                .unwrap_or(self.default_class),
            None => self.default_class,
        }
    }

    fn push(&self, r: Ready, local: usize) {
        let prio = r.job.tasks[r.task].kind.priority;
        let ci = r.job.tasks[r.task].class;
        let rg = &self.classes[ci];
        match self.policy {
            Policy::Eager => self.queues[rg.start].lock().unwrap().push_back(r),
            Policy::Prio => {
                let key = (
                    prio,
                    r.job.priority,
                    std::cmp::Reverse(r.job.seq),
                    std::cmp::Reverse(r.task),
                );
                self.heaps[ci].lock().unwrap().push(HeapEntry { key, ready: r });
            }
            Policy::Lws => self.queues[rg.start + local % rg.count]
                .lock()
                .unwrap()
                .push_back(r),
            Policy::Random => {
                // xorshift over an atomic — cheap, contention-tolerant
                let s = self.rng_state.fetch_add(0x9E3779B9, Ordering::Relaxed);
                let mut x = s.wrapping_mul(0x2545F4914F6CDD1D) ^ 0x1234_5678;
                x ^= x >> 17;
                self.queues[rg.start + x % rg.count]
                    .lock()
                    .unwrap()
                    .push_back(r)
            }
        }
        self.class_placed[ci].fetch_add(1, Ordering::Relaxed);
        self.pending[ci].fetch_add(1, Ordering::Release);
        // wake sleepers
        let _g = self.cv_guard.lock().unwrap();
        self.cv.notify_all();
    }

    fn pop(&self, me: usize) -> Option<Ready> {
        let ci = self.worker_class[me];
        let rg = &self.classes[ci];
        let got = match self.policy {
            Policy::Eager => self.queues[rg.start].lock().unwrap().pop_front(),
            Policy::Prio => self.heaps[ci].lock().unwrap().pop().map(|e| e.ready),
            Policy::Lws => {
                // local LIFO first (cache locality), then steal FIFO —
                // victims confined to this worker's class.
                let mine = self.queues[me].lock().unwrap().pop_back();
                mine.or_else(|| {
                    (1..rg.count).find_map(|off| {
                        let v = rg.start + (me - rg.start + off) % rg.count;
                        let r = self.queues[v].lock().unwrap().pop_front();
                        if r.is_some() {
                            self.class_stolen[ci].fetch_add(1, Ordering::Relaxed);
                        }
                        r
                    })
                })
            }
            Policy::Random => {
                let mine = self.queues[me].lock().unwrap().pop_front();
                mine.or_else(|| {
                    (1..rg.count).find_map(|off| {
                        let v = rg.start + (me - rg.start + off) % rg.count;
                        let r = self.queues[v].lock().unwrap().pop_front();
                        if r.is_some() {
                            self.class_stolen[ci].fetch_add(1, Ordering::Relaxed);
                        }
                        r
                    })
                })
            }
        };
        if got.is_some() {
            self.pending[ci].fetch_sub(1, Ordering::AcqRel);
        }
        got
    }
}

/// Run one ready task and release its successors (worker side).
///
/// A panicking closure is caught so the worker survives and the job
/// still drains (successors run on whatever the task left behind, the
/// same NaN-propagation philosophy as the Cholesky fail flag); the
/// panic message is recorded and re-raised by [`JobHandle::wait`].
fn execute(shared: &Arc<Shared>, r: Ready, w: usize) {
    let Ready { job, task } = r;
    // Take the closure either way: a skipped task must still drop its
    // captures (e.g. Arc'd operands) so storage is released.
    let run = job.cells[task].lock().unwrap().take();
    if job.cancel.is_cancelled() {
        // Cancelled job: retire the task without running it.  The
        // successor release / remaining bookkeeping below still happens
        // so the job drains and its waiter wakes.
        drop(run);
        job.skipped.fetch_add(1, Ordering::Relaxed);
        shared.tasks_skipped.fetch_add(1, Ordering::Relaxed);
    } else {
        let t0 = Instant::now();
        if let Some(f) = run {
            // AssertUnwindSafe: the only state f touches is job-owned tile
            // storage, and a panicked job is reported, never reused.
            if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
                let msg = panic_message(p.as_ref());
                // Quarantine telemetry: repeated failures on a non-CPU
                // class mark it ineligible for future placement.
                note_class_failure(shared.classes[shared.worker_class[w]].class);
                let mut st = job.state.lock().unwrap();
                if st.error.is_none() {
                    st.error = Some(TaskError::Panic(msg));
                }
            }
        }
        if shared.worker_slow[w] {
            // Slow-class simulation: stretch this task's wall time by the
            // throttle factor.  The closure already ran unmodified, so
            // results are bit-identical — only the clock differs.
            let f = slow_factor();
            if f > 1.0 {
                std::thread::sleep(t0.elapsed().mul_f64(f - 1.0));
            }
        }
        let dur = t0.elapsed();
        shared.tasks_executed.fetch_add(1, Ordering::Relaxed);
        let ci = shared.worker_class[w];
        shared.class_executed[ci].fetch_add(1, Ordering::Relaxed);
        if shared.classes.len() > 1 || shared.watchdog_on {
            shared.cost_stats.lock().unwrap().record(
                job.tasks[task].kind,
                shared.classes[ci].class,
                dur.as_secs_f64(),
            );
        }
        *job.records[task].lock().unwrap() = Some(TaskRecord {
            worker: w,
            kind: job.tasks[task].kind,
            dur,
            bytes: job.tasks[task].bytes,
        });
    }
    if shared.watchdog_on {
        // Progress heartbeat: the watchdog only flags a job whose
        // *last retirement* is stale, so a slow-but-moving graph is
        // never killed.
        job.last_progress_ms
            .store(job.t0.elapsed().as_millis() as u64, Ordering::Relaxed);
    }
    for &s in &job.tasks[task].succs {
        if job.preds[s].fetch_sub(1, Ordering::AcqRel) == 1 {
            shared.push(
                Ready {
                    job: job.clone(),
                    task: s,
                },
                w,
            );
        }
    }
    if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        let mut st = job.state.lock().unwrap();
        st.wall = job.t0.elapsed();
        st.done = true;
        job.done_cv.notify_all();
    }
}

/// Worker main loop: drain ready tasks, park while idle, exit on
/// shutdown once no work is queued.
fn worker_loop(shared: Arc<Shared>, w: usize) {
    let ci = shared.worker_class[w];
    loop {
        if let Some(r) = shared.pop(w) {
            execute(&shared, r, w);
            continue;
        }
        let g = shared.cv_guard.lock().unwrap();
        if shared.pending[ci].load(Ordering::Acquire) > 0 {
            continue; // a push raced our empty pop — retry
        }
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        // Parked.  Pushes increment `pending` before notifying under
        // `cv_guard` and we re-checked `pending` under the same guard,
        // so wakeups cannot be missed; the long timeout is purely a
        // belt-and-braces backstop and costs ~2 wakeups/sec while idle.
        let _ = shared
            .cv
            .wait_timeout(g, Duration::from_millis(500))
            .unwrap();
    }
}

fn warn_if_oversubscribed(nworkers: usize) {
    let avail = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    if nworkers > avail {
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            eprintln!(
                "exageostat: warning: {nworkers} worker threads requested but only \
                 {avail} hardware threads available; oversubscribing"
            );
        });
    }
}

/// The persistent task runtime (see module docs).
pub struct Runtime {
    shared: Arc<Shared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Class of each worker index, for per-class profile attribution
    /// (shared cheaply with every [`JobHandle`]).
    worker_classes: Arc<Vec<WorkerClass>>,
    spawned: AtomicU64,
    next_seq: AtomicU64,
    /// High-water mark of [`Runtime::prewarm_workers_once`] keys already
    /// served (worker-local state persists for the process, so repeat
    /// prewarms at the same or smaller key are pure overhead).  A mutex —
    /// held across the prewarm itself — not an atomic: the mark must not
    /// advance before the warm-up actually completed, or a concurrent
    /// caller at the same key returns onto cold workers.
    prewarm_mark: Mutex<usize>,
    /// Watchdog thread handle (only when `EXAGEOSTAT_WATCHDOG` / the
    /// test override enables one); joined on shutdown after workers.
    watchdog: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Test override for the watchdog stall factor (`f64::to_bits`;
/// `u64::MAX` = no override, fall back to the environment).
static WATCHDOG_OVERRIDE: AtomicU64 = AtomicU64::new(u64::MAX);

/// Force (`Some(factor)`) or clear (`None`) the watchdog stall factor
/// for runtimes built after this call, ignoring `EXAGEOSTAT_WATCHDOG`.
/// Test hook — serialize with `faults::fault_test_lock`.
pub fn set_watchdog_override(factor: Option<f64>) {
    let bits = match factor {
        Some(f) => f.to_bits(),
        None => u64::MAX,
    };
    WATCHDOG_OVERRIDE.store(bits, Ordering::SeqCst);
}

/// Watchdog stall factor: a job whose last task retirement is older
/// than `factor × mean task cost` (with an absolute floor) is flagged
/// as hung.  `None` (the default — no `EXAGEOSTAT_WATCHDOG`) disables
/// the watchdog thread entirely; the hot path then never touches the
/// progress heartbeat.
fn watchdog_factor() -> Option<f64> {
    let bits = WATCHDOG_OVERRIDE.load(Ordering::SeqCst);
    if bits != u64::MAX {
        return Some(f64::from_bits(bits)).filter(|f| *f > 0.0);
    }
    static ENV: OnceLock<Option<f64>> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("EXAGEOSTAT_WATCHDOG")
            .ok()
            .and_then(|s| s.trim().parse::<f64>().ok())
            .filter(|f| *f > 0.0)
    })
}

/// Minimum stall threshold in milliseconds, so sparse cost samples or
/// micro-tasks never trip the watchdog on scheduling noise.
fn watchdog_floor_ms() -> u64 {
    static ENV: OnceLock<u64> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("EXAGEOSTAT_WATCHDOG_FLOOR_MS")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(250)
    })
}

/// Watchdog main loop: every 50 ms, compare each live job's time since
/// last task retirement against `factor × mean task cost` (measured by
/// the runtime's own [`ClassCostModel`]) with [`watchdog_floor_ms`] as
/// an absolute floor, and convert a stalled job into a timeout via its
/// own [`CancelToken`] — [`JobHandle::wait_result`] then reports
/// [`TaskError::Timeout`].  Stalled *running* tasks keep their worker
/// (there is no preemption), but the job drains by skipping everything
/// not yet started, so waiters wake promptly.
fn watchdog_loop(shared: Arc<Shared>, factor: f64) {
    while !shared.shutdown.load(Ordering::Acquire) {
        std::thread::sleep(Duration::from_millis(50));
        let mean = shared.cost_stats.lock().unwrap().mean_all();
        let threshold_ms = match mean {
            Some(m) => (factor * m * 1e3).max(watchdog_floor_ms() as f64) as u64,
            None => watchdog_floor_ms(),
        };
        let mut jobs = shared.live_jobs.lock().unwrap();
        jobs.retain(|wk| {
            let Some(job) = wk.upgrade() else { return false };
            if job.state.lock().unwrap().done {
                return false;
            }
            if job.cancel.is_cancelled() {
                return true; // already draining; keep until done
            }
            let elapsed = job.t0.elapsed().as_millis() as u64;
            let last = job.last_progress_ms.load(Ordering::Relaxed);
            if elapsed.saturating_sub(last) > threshold_ms {
                job.cancel.cancel_with_timeout();
            }
            true
        });
    }
}

impl Runtime {
    /// Spawn `nworkers.max(1)` worker threads under `policy`, all in one
    /// `Cpu` class — the exact pre-heterogeneity runtime (hermetic: never
    /// consults `EXAGEOSTAT_WORKER_CLASSES`; callers that want env-driven
    /// classes resolve a [`ClassSpec`] via `placement::class_spec_for`
    /// and use [`Runtime::new_with_classes`]).  Warns (once per process)
    /// when the request oversubscribes the machine.
    pub fn new(nworkers: usize, policy: Policy) -> Runtime {
        Self::build(&ClassSpec::homogeneous(nworkers), policy, false)
    }

    /// Spawn one worker pool per class in `spec` (empty classes dropped).
    /// Queues, priority heaps and work-stealing are confined within each
    /// class; tasks annotated with a class run only on its workers.  A
    /// single-class spec behaves bit-for-bit like [`Runtime::new`].
    pub fn new_with_classes(spec: &ClassSpec, policy: Policy) -> Runtime {
        Self::build(spec, policy, false)
    }

    /// Class-*blind* variant for policy experiments (the baseline the
    /// placement bench compares against): same worker mix — `Slow`
    /// workers are still throttled — but all workers share one
    /// scheduling class, so any worker may pick up any task.
    pub fn new_with_classes_blind(spec: &ClassSpec, policy: Policy) -> Runtime {
        Self::build(spec, policy, true)
    }

    fn build(spec: &ClassSpec, policy: Policy, blind: bool) -> Runtime {
        let entries: Vec<(WorkerClass, usize)> = {
            let mut e: Vec<(WorkerClass, usize)> =
                spec.classes.iter().copied().filter(|c| c.1 > 0).collect();
            if e.is_empty() {
                e.push((WorkerClass::Cpu, 1));
            }
            e
        };
        let nworkers: usize = entries.iter().map(|e| e.1).sum();
        warn_if_oversubscribed(nworkers);
        let mut worker_names: Vec<WorkerClass> = Vec::with_capacity(nworkers);
        let mut worker_slow: Vec<bool> = Vec::with_capacity(nworkers);
        for &(class, count) in &entries {
            for _ in 0..count {
                worker_names.push(class);
                worker_slow.push(class == WorkerClass::Slow);
            }
        }
        let classes: Vec<ClassRange> = if blind {
            vec![ClassRange {
                class: WorkerClass::Cpu,
                start: 0,
                count: nworkers,
            }]
        } else {
            let mut out = Vec::with_capacity(entries.len());
            let mut start = 0;
            for &(class, count) in &entries {
                out.push(ClassRange {
                    class,
                    start,
                    count,
                });
                start += count;
            }
            out
        };
        let nclasses = classes.len();
        let default_class = classes
            .iter()
            .position(|r| r.class == WorkerClass::Cpu)
            .unwrap_or(0);
        let worker_class: Vec<usize> = if blind {
            vec![0; nworkers]
        } else {
            (0..nworkers)
                .map(|w| {
                    classes
                        .iter()
                        .position(|r| w >= r.start && w < r.start + r.count)
                        .unwrap()
                })
                .collect()
        };
        let shared = Arc::new(Shared {
            policy,
            nworkers,
            classes,
            worker_class,
            worker_slow,
            default_class,
            queues: (0..nworkers).map(|_| Mutex::new(VecDeque::new())).collect(),
            heaps: (0..nclasses).map(|_| Mutex::new(BinaryHeap::new())).collect(),
            cv: Condvar::new(),
            cv_guard: Mutex::new(()),
            pending: (0..nclasses).map(|_| AtomicUsize::new(0)).collect(),
            shutdown: AtomicBool::new(false),
            lifecycle: RwLock::new(()),
            rng_state: AtomicUsize::new(0x5DEECE66),
            tasks_executed: AtomicU64::new(0),
            tasks_skipped: AtomicU64::new(0),
            class_placed: (0..nclasses).map(|_| AtomicU64::new(0)).collect(),
            class_executed: (0..nclasses).map(|_| AtomicU64::new(0)).collect(),
            class_stolen: (0..nclasses).map(|_| AtomicU64::new(0)).collect(),
            cost_stats: Mutex::new(ClassCostModel::default()),
            watchdog_on: watchdog_factor().is_some(),
            live_jobs: Mutex::new(Vec::new()),
        });
        let rt = Runtime {
            shared: shared.clone(),
            workers: Mutex::new(Vec::with_capacity(nworkers)),
            worker_classes: Arc::new(worker_names),
            spawned: AtomicU64::new(0),
            next_seq: AtomicU64::new(0),
            prewarm_mark: Mutex::new(0),
            watchdog: Mutex::new(None),
        };
        {
            let mut ws = rt.workers.lock().unwrap();
            for w in 0..nworkers {
                let sh = shared.clone();
                WORKER_THREADS_SPAWNED.fetch_add(1, Ordering::SeqCst);
                rt.spawned.fetch_add(1, Ordering::SeqCst);
                ws.push(
                    std::thread::Builder::new()
                        .name(format!("exa-worker-{w}"))
                        .spawn(move || worker_loop(sh, w))
                        .expect("spawn runtime worker"),
                );
            }
        }
        if let Some(factor) = watchdog_factor() {
            // Not a worker: excluded from the spawn telemetry so the
            // `threads_spawned == nworkers` invariant (and the lifecycle
            // tests that assert it) holds with the watchdog enabled.
            let sh = shared.clone();
            *rt.watchdog.lock().unwrap() = Some(
                std::thread::Builder::new()
                    .name("exa-watchdog".into())
                    .spawn(move || watchdog_loop(sh, factor))
                    .expect("spawn runtime watchdog"),
            );
        }
        rt
    }

    /// Number of worker threads serving this runtime.
    pub fn nworkers(&self) -> usize {
        self.shared.nworkers
    }

    /// Live class layout: `(class, worker count)` in range order.
    pub fn classes(&self) -> Vec<(WorkerClass, usize)> {
        self.shared
            .classes
            .iter()
            .map(|r| (r.class, r.count))
            .collect()
    }

    /// Number of scheduling classes (1 = homogeneous).
    pub fn nclasses(&self) -> usize {
        self.shared.classes.len()
    }

    /// Class of worker `w` (scheduling class — a blind runtime reports
    /// one merged class regardless of throttling).
    pub fn worker_class_of(&self, w: usize) -> WorkerClass {
        self.shared.classes[self.shared.worker_class[w]].class
    }

    /// Per-class placement/execution/steal counters since startup.
    pub fn class_stats(&self) -> Vec<ClassStat> {
        self.shared
            .classes
            .iter()
            .enumerate()
            .map(|(ci, r)| ClassStat {
                class: r.class,
                workers: r.count,
                tasks_placed: self.shared.class_placed[ci].load(Ordering::Relaxed),
                tasks_executed: self.shared.class_executed[ci].load(Ordering::Relaxed),
                steals: self.shared.class_stolen[ci].load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Measured per-(kind, class) cost model accumulated across all jobs
    /// (empty on homogeneous runtimes — the hot path skips recording).
    pub fn cost_model_by_class(&self) -> ClassCostModel {
        self.shared.cost_stats.lock().unwrap().clone()
    }

    /// Scheduling policy the workers dispatch under.
    pub fn policy(&self) -> Policy {
        self.shared.policy
    }

    /// OS threads this runtime has spawned over its whole lifetime
    /// (invariant: equals [`Runtime::nworkers`] — jobs never spawn).
    pub fn threads_spawned(&self) -> u64 {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Tasks executed across all jobs so far.
    pub fn tasks_executed(&self) -> u64 {
        self.shared.tasks_executed.load(Ordering::Relaxed)
    }

    /// Tasks retired without running across all jobs so far — the work
    /// saved by cancellation (a lost speculative MLE candidate, a client
    /// disconnect).  Counterpart of [`Runtime::tasks_executed`].
    pub fn tasks_skipped(&self) -> u64 {
        self.shared.tasks_skipped.load(Ordering::Relaxed)
    }

    /// Ready tasks currently queued but not yet picked up by a worker —
    /// the backpressure signal the streaming serve loop admits requests
    /// against (`coordinator::serve_stream`).
    pub fn queue_depth(&self) -> usize {
        self.shared
            .pending
            .iter()
            .map(|p| p.load(Ordering::Acquire))
            .sum()
    }

    /// Has [`Runtime::shutdown`] run?
    pub fn is_shut_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::Acquire)
    }

    /// Submit a whole task graph as one job (priority 0).
    pub fn submit(&self, graph: TaskGraph) -> JobHandle {
        self.submit_with_priority(graph, 0)
    }

    /// Submit a job with an explicit job priority (the coordinator's
    /// per-request fairness knob; only the `prio` policy consults it,
    /// as a tie-break between equal-priority tasks of different jobs).
    ///
    /// # Panics
    /// Panics if the runtime has been shut down — submitting after
    /// `finalize` is a caller bug, not a recoverable condition.
    pub fn submit_with_priority(&self, graph: TaskGraph, priority: u8) -> JobHandle {
        self.submit_job(graph, priority, CancelToken::new())
    }

    /// Submit a job bound to an external [`CancelToken`] (the full form
    /// of [`Runtime::submit_with_priority`]).  Firing the token — from
    /// [`JobHandle::cancel`] or any clone held elsewhere, e.g. a serving
    /// client's ticket — makes workers skip every task of this job they
    /// have not started yet; the job still drains (skipped tasks retire
    /// and release their successors) so waiting on the handle never
    /// hangs.
    ///
    /// # Panics
    /// Panics if the runtime has been shut down, as above.
    pub fn submit_job(&self, mut graph: TaskGraph, priority: u8, cancel: CancelToken) -> JobHandle {
        // Held for the whole submission (incl. seeding): shutdown takes
        // the write side before joining workers, so a job that passes
        // the check below is fully enqueued while workers still live.
        let _gate = self.shared.lifecycle.read().unwrap();
        assert!(
            !self.shared.shutdown.load(Ordering::Acquire),
            "Runtime::submit after shutdown (exageostat_finalize already ran)"
        );
        let n = graph.tasks.len();
        let mut tasks = Vec::with_capacity(n);
        let mut cells = Vec::with_capacity(n);
        let mut records = Vec::with_capacity(n);
        let mut preds = Vec::with_capacity(n);
        for t in graph.tasks.iter_mut() {
            cells.push(Mutex::new(t.run.take()));
            records.push(Mutex::new(None));
            preds.push(AtomicUsize::new(t.npred));
            tasks.push(JobTask {
                kind: t.kind,
                bytes: t.bytes,
                class: self.shared.class_index(t.class),
                succs: std::mem::take(&mut t.succs),
            });
        }
        let job = Arc::new(JobInner {
            seq: self.next_seq.fetch_add(1, Ordering::Relaxed),
            priority,
            cancel,
            skipped: AtomicUsize::new(0),
            tasks,
            cells,
            records,
            preds,
            remaining: AtomicUsize::new(n),
            state: Mutex::new(JobState {
                done: n == 0,
                wall: Duration::ZERO,
                error: None,
            }),
            done_cv: Condvar::new(),
            t0: Instant::now(),
            last_progress_ms: AtomicU64::new(0),
            fault_base: (faults::faults_injected(), faults::tasks_retried()),
        });
        if self.shared.watchdog_on {
            self.shared
                .live_jobs
                .lock()
                .unwrap()
                .push(Arc::downgrade(&job));
        }
        // Seed the ready set.  The slot choice only spreads lws/random
        // seeds across workers; released tasks later use the releasing
        // worker's slot.
        for id in 0..n {
            if job.preds[id].load(Ordering::Relaxed) == 0 {
                self.shared.push(
                    Ready {
                        job: job.clone(),
                        task: id,
                    },
                    (job.seq as usize).wrapping_add(id),
                );
            }
        }
        JobHandle {
            job,
            nworkers: self.shared.nworkers,
            worker_classes: self.worker_classes.clone(),
            consumed: false,
        }
    }

    /// Park-proof convenience: submit and wait.
    pub fn run(&self, graph: TaskGraph) -> Profile {
        self.submit(graph).wait()
    }

    /// Run `f` once per worker, **best effort** on distribution: one
    /// independent task per worker is submitted, and each task spin-waits
    /// (bounded) until all of them have started, so on an idle runtime
    /// every worker executes exactly one.  On a busy runtime the barrier
    /// times out and some workers may run `f` more than once or not at
    /// all — acceptable for its purpose: growing worker-local state ahead
    /// of time (e.g. `linalg::blas::reserve_pack_workspaces`, called by
    /// `EvalSession::new` so tile kernels start allocation-free).
    /// Blocks until the prewarm jobs complete.
    ///
    /// Heterogeneous runtimes prewarm **per class**: each class gets its
    /// own barrier over its own worker count, with the tasks pinned to
    /// that class — a `Slow`/`Accel` worker can never satisfy a `Cpu`
    /// barrier slot (or vice versa), which the old single shared barrier
    /// allowed.
    pub fn prewarm_workers(&self, f: impl Fn() + Send + Sync + 'static) {
        let f = Arc::new(f);
        let mut handles = Vec::with_capacity(self.shared.classes.len());
        for rg in &self.shared.classes {
            let n = rg.count;
            let arrived = Arc::new(AtomicUsize::new(0));
            // One shared deadline from submission time: on a busy runtime
            // the whole prewarm costs at most this bound, it never
            // serializes per-task waits.  Kept short — on an idle runtime
            // the barrier completes in microseconds, and under contention
            // distribution is best-effort anyway; the spin only burns
            // otherwise-idle workers until then.
            let deadline = Instant::now() + Duration::from_millis(50);
            let mut g = TaskGraph::new();
            let hs = g.register_many(n);
            for h in hs {
                let f = f.clone();
                let arrived = arrived.clone();
                let id = g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                    arrived.fetch_add(1, Ordering::SeqCst);
                    while arrived.load(Ordering::SeqCst) < n && Instant::now() < deadline {
                        std::thread::yield_now();
                    }
                    f();
                });
                g.set_class(id, rg.class);
            }
            handles.push(self.submit(g));
        }
        for h in handles {
            h.wait();
        }
    }

    /// [`Runtime::prewarm_workers`], deduplicated by a monotone `key`:
    /// runs only if no earlier call used a key `>= key` on this runtime.
    /// Worker-local workspaces persist for the process, so e.g. session
    /// builds pass their tile size — the first build (per tile-size
    /// high-water mark) pays the prewarm, later ones skip it entirely
    /// (the serving path builds a session on every cache miss).
    pub fn prewarm_workers_once(&self, key: usize, f: impl Fn() + Send + Sync + 'static) {
        // The lock is held across the prewarm barrier: the previous
        // `fetch_max` scheme advanced the mark *before* warming, so a
        // concurrent caller at the same key could return — and start
        // submitting real work — while the workers were still cold.
        // Now losers block until the winner's barrier completes.
        let mut mark = self.prewarm_mark.lock().unwrap();
        if *mark >= key {
            return;
        }
        self.prewarm_workers(f);
        *mark = key;
    }

    /// Stop accepting jobs, drain queued work, join all workers.
    /// Idempotent; also invoked by `Drop`.  A submit that raced ahead
    /// of the flag finishes seeding first (lifecycle gate) and its job
    /// is drained before the workers exit; any later submit panics.
    pub fn shutdown(&self) {
        {
            // Wait out in-flight submissions, then close the gate.
            let _gate = self.shared.lifecycle.write().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
        }
        {
            let _g = self.shared.cv_guard.lock().unwrap();
            self.shared.cv.notify_all();
        }
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        if let Some(h) = self.watchdog.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("nworkers", &self.shared.nworkers)
            .field("policy", &self.shared.policy)
            .field("tasks_executed", &self.tasks_executed())
            .field("shut_down", &self.is_shut_down())
            .finish()
    }
}

/// Handle to an in-flight job.  `wait()` returns the job's execution
/// profile; dropping the handle without waiting blocks until the job
/// finishes (see the module-level safety contract).
#[must_use = "a job's operand storage must outlive it — keep the handle and wait()"]
pub struct JobHandle {
    job: Arc<JobInner>,
    nworkers: usize,
    worker_classes: Arc<Vec<WorkerClass>>,
    consumed: bool,
}

impl JobHandle {
    /// Block until every task of the job has retired; returns the job's
    /// profile (wall = submit → last-task-retired).
    ///
    /// # Panics
    /// Re-raises the first task panic of the job on this thread, the
    /// behaviour the old scoped pool had via `join().unwrap()`.
    pub fn wait(mut self) -> Profile {
        self.consumed = true;
        let (profile, error) = self.wait_ref();
        match error {
            // Message shape kept from the pre-taxonomy runtime: callers
            // (and the panic-propagation test) downcast the String and
            // look for the original task message inside it.
            Some(TaskError::Panic(msg)) => panic!("runtime job task panicked: {msg}"),
            Some(e) => panic!("runtime job task failed: {e}"),
            None => profile,
        }
    }

    /// Like [`JobHandle::wait`] but reports the job's first
    /// [`TaskError`] as a value instead of re-raising it — the entry
    /// point for recovery layers (coordinator whole-job retry, chaos
    /// tests) that must survive injected faults.
    pub fn wait_result(mut self) -> Result<Profile, TaskError> {
        self.consumed = true;
        let (profile, error) = self.wait_ref();
        match error {
            Some(e) => Err(e),
            None => Ok(profile),
        }
    }

    /// Non-blocking completion probe.
    pub fn is_done(&self) -> bool {
        self.job.state.lock().unwrap().done
    }

    /// Cancel the job: workers skip every task they have not started
    /// yet (already-running tasks finish).  The job still drains, so a
    /// subsequent [`JobHandle::wait`] returns promptly; its profile
    /// reports only the tasks that actually executed, with the skipped
    /// count in [`Profile::tasks_skipped`].
    pub fn cancel(&self) {
        self.job.cancel.cancel();
    }

    /// Has this job's cancellation token fired?
    pub fn is_cancelled(&self) -> bool {
        self.job.cancel.is_cancelled()
    }

    /// The job's cancellation token (cloneable; firing any clone
    /// cancels the job).
    pub fn cancel_token(&self) -> &CancelToken {
        &self.job.cancel
    }

    /// Tasks retired without executing because the job was cancelled
    /// (final once the job is done).
    pub fn tasks_skipped(&self) -> usize {
        self.job.skipped.load(Ordering::Relaxed)
    }

    fn wait_ref(&self) -> (Profile, Option<TaskError>) {
        let (wall, mut error) = {
            let mut st = self.job.state.lock().unwrap();
            while !st.done {
                st = self.job.done_cv.wait(st).unwrap();
            }
            (st.wall, st.error.take())
        };
        if error.is_none() && self.job.cancel.timed_out() {
            // Watchdog (or a deadline holder) converted the hang into a
            // cancellation: surface it as a typed timeout, not a silent
            // partially-skipped profile.
            error = Some(TaskError::Timeout(format!(
                "job stalled; cancelled by watchdog after {:.1}s",
                wall.as_secs_f64()
            )));
        }
        let mut p = Profile::new(self.nworkers);
        p.worker_classes = (*self.worker_classes).clone();
        for slot in &self.job.records {
            if let Some(rec) = *slot.lock().unwrap() {
                p.records.push(rec);
            }
        }
        p.wall = wall;
        p.tasks_skipped = self.job.skipped.load(Ordering::Relaxed);
        // Process-global counter deltas since submission: best-effort
        // under concurrent jobs (a neighbour's faults can leak in), but
        // exact in the single-job tests that assert on them.
        p.faults_injected = faults::faults_injected().saturating_sub(self.job.fault_base.0);
        p.tasks_retried = faults::tasks_retried().saturating_sub(self.job.fault_base.1);
        (p, error)
    }
}

impl Drop for JobHandle {
    fn drop(&mut self) {
        if !self.consumed {
            // Swallow any task panic here: re-raising from Drop during
            // an unwind would abort.  `wait()` is the reporting path.
            let _ = self.wait_ref();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Access, TaskKind};
    use std::sync::atomic::AtomicUsize;

    fn all_policies() -> [Policy; 4] {
        [Policy::Eager, Policy::Prio, Policy::Lws, Policy::Random]
    }

    fn counting_graph(tasks: usize, counter: &Arc<AtomicUsize>) -> TaskGraph {
        let mut g = TaskGraph::new();
        let hs = g.register_many(8);
        for i in 0..tasks {
            let c = counter.clone();
            g.submit(TaskKind::GEMM, &[(hs[i % 8], Access::RW)], 0, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        g
    }

    #[test]
    fn one_runtime_many_jobs_every_policy() {
        for policy in all_policies() {
            let rt = Runtime::new(3, policy);
            let counter = Arc::new(AtomicUsize::new(0));
            for _ in 0..5 {
                let prof = rt.submit(counting_graph(60, &counter)).wait();
                assert_eq!(prof.total_tasks(), 60, "{policy:?}");
                assert_eq!(prof.nworkers, 3);
            }
            assert_eq!(counter.load(Ordering::SeqCst), 300, "{policy:?}");
            assert_eq!(rt.threads_spawned(), 3, "{policy:?}");
            assert_eq!(rt.tasks_executed(), 300);
            rt.shutdown();
        }
    }

    #[test]
    fn overlapping_jobs_all_complete() {
        for policy in all_policies() {
            let rt = Runtime::new(2, policy);
            let counter = Arc::new(AtomicUsize::new(0));
            let handles: Vec<JobHandle> = (0..6)
                .map(|_| rt.submit(counting_graph(40, &counter)))
                .collect();
            for h in handles {
                assert_eq!(h.wait().total_tasks(), 40, "{policy:?}");
            }
            assert_eq!(counter.load(Ordering::SeqCst), 240, "{policy:?}");
        }
    }

    #[test]
    fn dependencies_respected_across_concurrent_jobs() {
        // Two RW chains submitted as separate jobs: each must preserve
        // its own program order even while interleaved.
        for policy in all_policies() {
            let rt = Runtime::new(3, policy);
            let mut handles = Vec::new();
            let mut orders = Vec::new();
            for _job in 0..2 {
                let order = Arc::new(Mutex::new(Vec::new()));
                let mut g = TaskGraph::new();
                let h = g.register();
                for i in 0..30 {
                    let o = order.clone();
                    g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                        o.lock().unwrap().push(i);
                    });
                }
                orders.push(order);
                handles.push(rt.submit(g));
            }
            for h in handles {
                h.wait();
            }
            for order in orders {
                let got = order.lock().unwrap().clone();
                assert_eq!(got, (0..30).collect::<Vec<_>>(), "{policy:?}");
            }
        }
    }

    #[test]
    fn empty_job_completes_immediately() {
        let rt = Runtime::new(2, Policy::Eager);
        let prof = rt.submit(TaskGraph::new()).wait();
        assert_eq!(prof.total_tasks(), 0);
    }

    #[test]
    fn dropped_handle_joins_job() {
        let rt = Runtime::new(2, Policy::Lws);
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let _h = rt.submit(counting_graph(50, &counter));
            // handle dropped without wait(): Drop must block until done
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    #[should_panic(expected = "after shutdown")]
    fn submit_after_shutdown_panics() {
        let rt = Runtime::new(1, Policy::Eager);
        rt.shutdown();
        let counter = Arc::new(AtomicUsize::new(0));
        let h = rt.submit(counting_graph(1, &counter));
        std::mem::forget(h); // unreachable; avoid a hanging Drop if reached
    }

    #[test]
    fn task_panic_propagates_to_wait_and_runtime_survives() {
        let rt = Runtime::new(2, Policy::Eager);
        let mut g = TaskGraph::new();
        let h = g.register();
        g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, || {
            panic!("boom in task")
        });
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            rt.submit(g).wait();
        }));
        let msg = format!("{:?}", res.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("boom in task"), "{msg}");
        // The worker caught the panic: the runtime keeps serving jobs
        // on the same threads.
        let counter = Arc::new(AtomicUsize::new(0));
        rt.submit(counting_graph(10, &counter)).wait();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
        assert_eq!(rt.threads_spawned(), 2);
    }

    #[test]
    fn cancelled_job_skips_not_yet_started_tasks() {
        // Single worker pinned inside a stall task: everything queued
        // behind it is provably not-yet-started when we cancel.
        let rt = Runtime::new(1, Policy::Eager);
        let gate = Arc::new(AtomicUsize::new(0));
        let started = Arc::new(AtomicUsize::new(0));
        let mut stall = TaskGraph::new();
        let h = stall.register();
        {
            let gate = gate.clone();
            let started = started.clone();
            stall.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                started.store(1, Ordering::SeqCst);
                while gate.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                }
            });
        }
        let stall_h = rt.submit(stall);
        while started.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }

        // 25 independent tasks: all seeded ready, none can start while
        // the worker stalls.
        let counter = Arc::new(AtomicUsize::new(0));
        let independent = |counter: &Arc<AtomicUsize>| {
            let mut g = TaskGraph::new();
            let hs = g.register_many(25);
            for h in hs {
                let c = counter.clone();
                g.submit(TaskKind::GEMM, &[(h, Access::RW)], 0, move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            g
        };
        let victim = rt.submit(independent(&counter));
        assert!(victim.tasks_skipped() == 0 && !victim.is_cancelled());
        assert!(rt.queue_depth() >= 25, "queued behind the stall");
        victim.cancel();
        gate.store(1, Ordering::SeqCst);
        stall_h.wait();
        let prof = victim.wait();
        // Strictly fewer tasks executed than a completed run of the
        // same graph, and every skipped task accounted for.
        assert_eq!(counter.load(Ordering::SeqCst), 0);
        assert_eq!(prof.total_tasks(), 0);
        assert_eq!(prof.tasks_skipped, 25);
        // The runtime survives and a fresh identical job completes.
        let done = rt.submit(independent(&counter)).wait();
        assert_eq!(done.total_tasks(), 25);
        assert_eq!(done.tasks_skipped, 0);
        assert!(prof.total_tasks() < done.total_tasks());
        rt.shutdown();
    }

    #[test]
    fn mid_job_cancel_executes_prefix_only() {
        // RW chain: tasks run strictly in order on one worker; cancel
        // fires from inside task 5, so tasks 6.. are skipped.
        let rt = Runtime::new(1, Policy::Eager);
        let token = CancelToken::new();
        let ran = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let h = g.register();
        for i in 0..20 {
            let ran = ran.clone();
            let token = token.clone();
            g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                ran.fetch_add(1, Ordering::SeqCst);
                if i == 5 {
                    token.cancel();
                }
            });
        }
        let handle = rt.submit_job(g, 0, token.clone());
        let prof = handle.wait();
        assert_eq!(ran.load(Ordering::SeqCst), 6);
        assert_eq!(prof.total_tasks(), 6);
        assert_eq!(prof.tasks_skipped, 14);
        assert!(token.is_cancelled());
        rt.shutdown();
    }

    #[test]
    fn prewarm_runs_once_per_worker_when_idle() {
        let rt = Runtime::new(3, Policy::Lws);
        let runs = Arc::new(AtomicUsize::new(0));
        let threads = Arc::new(Mutex::new(std::collections::HashSet::new()));
        {
            let runs = runs.clone();
            let threads = threads.clone();
            rt.prewarm_workers(move || {
                runs.fetch_add(1, Ordering::SeqCst);
                threads.lock().unwrap().insert(std::thread::current().id());
            });
        }
        // Exactly nworkers executions; thread distribution is
        // best-effort (barrier-gated, so ≥1 and usually all 3).
        assert_eq!(runs.load(Ordering::SeqCst), 3);
        assert!(!threads.lock().unwrap().is_empty());
        // The keyed variant runs once per high-water mark: a repeat at
        // the same key is a no-op, a larger key runs again.
        {
            let runs = runs.clone();
            rt.prewarm_workers_once(16, move || {
                runs.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(runs.load(Ordering::SeqCst), 6);
        {
            let runs = runs.clone();
            rt.prewarm_workers_once(16, move || {
                runs.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(runs.load(Ordering::SeqCst), 6, "same key skips");
        {
            let runs = runs.clone();
            rt.prewarm_workers_once(32, move || {
                runs.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(runs.load(Ordering::SeqCst), 9, "larger key reruns");
        // The runtime stays fully usable afterwards.
        let counter = Arc::new(AtomicUsize::new(0));
        rt.submit(counting_graph(12, &counter)).wait();
        assert_eq!(counter.load(Ordering::SeqCst), 12);
        rt.shutdown();
    }

    #[test]
    fn prio_tie_break_prefers_higher_job_priority() {
        // Single worker, prio policy: stall the worker, queue one task
        // from a low-priority job and one from a high-priority job (same
        // task kind), and check the high-priority job's task runs first.
        let rt = Runtime::new(1, Policy::Prio);
        let gate = Arc::new(AtomicUsize::new(0));
        let started = Arc::new(AtomicUsize::new(0));
        let order = Arc::new(Mutex::new(Vec::new()));

        let mut stall = TaskGraph::new();
        let h = stall.register();
        {
            let gate = gate.clone();
            let started = started.clone();
            stall.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                started.store(1, Ordering::SeqCst);
                while gate.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                }
            });
        }
        let stall_h = rt.submit(stall);
        // Only queue the contenders once the single worker is provably
        // busy inside the stall task (otherwise it could pop one early).
        while started.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }

        let mk = |tag: usize, order: &Arc<Mutex<Vec<usize>>>| {
            let mut g = TaskGraph::new();
            let h = g.register();
            let o = order.clone();
            g.submit(TaskKind::GEMM, &[(h, Access::RW)], 0, move || {
                o.lock().unwrap().push(tag);
            });
            g
        };
        let low = rt.submit_with_priority(mk(0, &order), 0);
        let high = rt.submit_with_priority(mk(1, &order), 5);
        gate.store(1, Ordering::SeqCst);
        stall_h.wait();
        low.wait();
        high.wait();
        assert_eq!(*order.lock().unwrap(), vec![1, 0]);
    }

    /// Worker thread names are `exa-worker-{w}`; parse back the index.
    fn current_worker_index() -> usize {
        std::thread::current()
            .name()
            .and_then(|n| n.strip_prefix("exa-worker-"))
            .and_then(|n| n.parse().ok())
            .expect("task ran off a runtime worker")
    }

    #[test]
    fn classed_tasks_run_only_on_their_class_workers() {
        // cpu:1,slow:1 -> worker 0 is Cpu, worker 1 is Slow.  Class
        // pinning is a hard guarantee under every policy: queues and
        // steals never cross classes.
        let spec = ClassSpec::parse("cpu:1,slow:1").unwrap();
        for policy in all_policies() {
            let rt = Runtime::new_with_classes(&spec, policy);
            assert_eq!(rt.nworkers(), 2);
            assert_eq!(rt.nclasses(), 2);
            assert_eq!(rt.worker_class_of(0), WorkerClass::Cpu);
            assert_eq!(rt.worker_class_of(1), WorkerClass::Slow);
            let hits = Arc::new(Mutex::new(Vec::new()));
            let mut g = TaskGraph::new();
            let hs = g.register_many(12);
            for (i, h) in hs.into_iter().enumerate() {
                let hits = hits.clone();
                let id = g.submit(TaskKind::GEMM, &[(h, Access::RW)], 0, move || {
                    hits.lock().unwrap().push((i, current_worker_index()));
                });
                g.set_class(
                    id,
                    if i % 2 == 0 {
                        WorkerClass::Cpu
                    } else {
                        WorkerClass::Slow
                    },
                );
            }
            rt.submit(g).wait();
            let hits = hits.lock().unwrap();
            assert_eq!(hits.len(), 12, "{policy:?}");
            for &(i, w) in hits.iter() {
                assert_eq!(w, i % 2, "{policy:?}: task {i} on wrong class worker");
            }
            let stats = rt.class_stats();
            assert_eq!(stats.len(), 2);
            assert_eq!(stats[0].class, WorkerClass::Cpu);
            assert_eq!(stats[0].tasks_executed, 6);
            assert_eq!(stats[1].class, WorkerClass::Slow);
            assert_eq!(stats[1].tasks_executed, 6);
            assert_eq!(stats[0].tasks_placed, 6);
            // heterogeneous runtimes learn per-(kind, class) costs
            let cm = rt.cost_model_by_class();
            assert!(cm.mean(TaskKind::GEMM, WorkerClass::Cpu).is_some());
            assert!(cm.mean(TaskKind::GEMM, WorkerClass::Slow).is_some());
            rt.shutdown();
        }
    }

    #[test]
    fn unknown_class_falls_back_to_default() {
        // A graph placed for a slow class still runs on a homogeneous
        // runtime (and on class-blind runtimes).
        for rt in [
            Runtime::new(2, Policy::Lws),
            Runtime::new_with_classes_blind(
                &ClassSpec::parse("cpu:1,slow:1").unwrap(),
                Policy::Lws,
            ),
        ] {
            assert_eq!(rt.nclasses(), 1);
            let counter = Arc::new(AtomicUsize::new(0));
            let mut g = TaskGraph::new();
            let hs = g.register_many(8);
            for h in hs {
                let c = counter.clone();
                let id = g.submit(TaskKind::SYRK, &[(h, Access::RW)], 0, move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                g.set_class(id, WorkerClass::Slow);
            }
            rt.submit(g).wait();
            assert_eq!(counter.load(Ordering::SeqCst), 8);
            // homogeneous runtimes never record class costs
            assert!(rt.cost_model_by_class().is_empty());
            rt.shutdown();
        }
    }

    #[test]
    fn prewarm_is_per_class() {
        // cpu:2,slow:1 -> the slow prewarm slot may only be filled by
        // the slow worker (index 2), and the two cpu slots only by cpu
        // workers — exactly one prewarm run per worker class member.
        let rt = Runtime::new_with_classes(&ClassSpec::parse("cpu:2,slow:1").unwrap(), Policy::Lws);
        let by_worker = Arc::new(Mutex::new(std::collections::HashMap::new()));
        {
            let by_worker = by_worker.clone();
            rt.prewarm_workers(move || {
                *by_worker
                    .lock()
                    .unwrap()
                    .entry(current_worker_index())
                    .or_insert(0usize) += 1;
            });
        }
        let by_worker = by_worker.lock().unwrap();
        let slow_runs = by_worker.get(&2).copied().unwrap_or(0);
        let cpu_runs: usize = by_worker
            .iter()
            .filter(|(&w, _)| w < 2)
            .map(|(_, &n)| n)
            .sum();
        assert_eq!(slow_runs, 1, "slow class warms on its own worker only");
        assert_eq!(cpu_runs, 2, "cpu class warms against its own count");
        rt.shutdown();
    }

    #[test]
    fn wait_result_reports_task_panic_as_typed_error() {
        let rt = Runtime::new(2, Policy::Eager);
        let mut g = TaskGraph::new();
        let h = g.register();
        g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, || {
            panic!("typed boom")
        });
        match rt.submit(g).wait_result() {
            Err(TaskError::Panic(msg)) => assert!(msg.contains("typed boom"), "{msg}"),
            other => panic!("expected Panic error, got {other:?}"),
        }
        // The runtime survives: a healthy job still completes cleanly.
        let counter = Arc::new(AtomicUsize::new(0));
        let prof = rt.submit(counting_graph(5, &counter)).wait_result().unwrap();
        assert_eq!(prof.total_tasks(), 5);
        assert_eq!(counter.load(Ordering::SeqCst), 5);
        rt.shutdown();
    }

    #[test]
    fn cancel_with_timeout_marks_job_timed_out() {
        let token = CancelToken::new();
        assert!(!token.is_cancelled() && !token.timed_out());
        token.cancel_with_timeout();
        assert!(token.is_cancelled() && token.timed_out());
        // Plain cancel never reports a timeout.
        let plain = CancelToken::new();
        plain.cancel();
        assert!(plain.is_cancelled() && !plain.timed_out());
    }

    #[test]
    fn watchdog_converts_stalled_job_into_timeout() {
        let _guard = crate::scheduler::faults::fault_test_lock();
        set_watchdog_override(Some(2.0));
        // Two workers: one pinned in a stall task (simulating a hang),
        // one free — so the watchdog's cancel can only be what stops
        // the queued successors, not worker starvation.
        let rt = Runtime::new(2, Policy::Eager);
        let gate = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let h = g.register();
        {
            let gate = gate.clone();
            g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                // Hang until released, far longer than the stall floor.
                while gate.load(Ordering::SeqCst) == 0 {
                    std::thread::sleep(Duration::from_millis(5));
                }
            });
        }
        // A successor that must be skipped once the watchdog fires.
        let ran = Arc::new(AtomicUsize::new(0));
        {
            let ran = ran.clone();
            g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                ran.fetch_add(1, Ordering::SeqCst);
            });
        }
        let handle = rt.submit(g);
        let token = handle.cancel_token().clone();
        // The watchdog (floor 250ms, no cost samples) flags the job.
        let deadline = Instant::now() + Duration::from_secs(10);
        while !token.timed_out() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(token.timed_out(), "watchdog never fired");
        gate.store(1, Ordering::SeqCst); // release the hung task
        match handle.wait_result() {
            Err(TaskError::Timeout(_)) => {}
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert_eq!(ran.load(Ordering::SeqCst), 0, "successor must be skipped");
        // A fresh job on the same runtime completes: one hang degraded
        // one job, not the process.
        let counter = Arc::new(AtomicUsize::new(0));
        rt.submit(counting_graph(4, &counter)).wait();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
        rt.shutdown();
        set_watchdog_override(None);
    }
}
