//! Deterministic fault injection (DESIGN.md §2j).
//!
//! Production fault tolerance is only trustworthy if it is *tested*
//! against faults, and faults are only testable if they are
//! deterministic and cheap to switch on.  This module is the single
//! switchboard: a seeded [`FaultPlan`] (rates per fault kind) armed
//! either from the `EXAGEOSTAT_FAULTS` environment knob
//! (`panic:0.01,io:0.01,stall:0.005@seed=42,stall_ms=20`) or
//! in-process via [`set_fault_plan`], consulted from exactly two kinds
//! of sites:
//!
//! * **task boundaries** — [`with_task_faults`] wraps every pipeline
//!   task body (runtime tasks, the serial TLR and spill sweeps).  The
//!   draw happens *before* the body runs, so an injected panic or
//!   stall never corrupts state and is always safe to retry — which is
//!   exactly what the wrapper does, up to [`task_retry_limit`] times.
//!   Genuine (non-injected) panics are retried only when the caller
//!   declares the body idempotent (e.g. a Generate-only group, which
//!   fully overwrites its output tile).
//! * **spill I/O** — [`maybe_io_error`] in `linalg::tile`'s read/write
//!   paths returns a synthetic `io::Error`, exercising the typed
//!   `TaskError::Io` propagation added in the same PR.
//!
//! The disarmed fast path is one relaxed atomic load
//! ([`faults_active`]), so the hooks cost the fault-free hot loop
//! nothing measurable (gated ≤ 2% in `ci/bench_baseline.json`).
//! Draws come from a splitmix64 stream over `(seed, global sequence)`:
//! a fixed seed yields a reproducible fault pattern for serial
//! executors and a statistically stable one under concurrency.
//!
//! Counters ([`injected_panics`], [`injected_io_errors`],
//! [`injected_stalls`], [`tasks_retried`]) are process-global and
//! monotone — tests assert deltas, and `Profile`/`CoordinatorStats`
//! surface them so chaos suites can prove faults actually fired.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, Once, OnceLock};
use std::time::Duration;

/// Injection rates and determinism seed for one fault campaign.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Probability a task body panics at its entry boundary.
    pub panic_rate: f64,
    /// Probability a spill read/write returns a synthetic I/O error.
    pub io_rate: f64,
    /// Probability a task stalls (sleeps [`FaultPlan::stall_ms`]) at
    /// its entry boundary — the hung-task case the watchdog converts
    /// into `TaskError::Timeout`.
    pub stall_rate: f64,
    /// Stall duration in milliseconds (bounded, so jobs always drain).
    pub stall_ms: u64,
    /// Seed of the deterministic draw stream.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            panic_rate: 0.0,
            io_rate: 0.0,
            stall_rate: 0.0,
            stall_ms: 20,
            seed: 0,
        }
    }
}

impl FaultPlan {
    /// Parse the `EXAGEOSTAT_FAULTS` syntax:
    /// `kind:rate[,kind:rate...][@key=val[,key=val...]]` with kinds
    /// `panic` / `io` / `stall` and keys `seed` / `stall_ms`.
    /// Returns `None` for an empty/unparseable spec or all-zero rates.
    pub fn parse(spec: &str) -> Option<FaultPlan> {
        let mut plan = FaultPlan::default();
        let (rates, opts) = match spec.split_once('@') {
            Some((r, o)) => (r, Some(o)),
            None => (spec, None),
        };
        for part in rates.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, rate) = part.split_once(':')?;
            let rate: f64 = rate.trim().parse().ok()?;
            if !(0.0..=1.0).contains(&rate) {
                return None;
            }
            match kind.trim() {
                "panic" => plan.panic_rate = rate,
                "io" => plan.io_rate = rate,
                "stall" => plan.stall_rate = rate,
                _ => return None,
            }
        }
        if let Some(opts) = opts {
            for part in opts.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let (key, val) = part.split_once('=')?;
                match key.trim() {
                    "seed" => plan.seed = val.trim().parse().ok()?,
                    "stall_ms" => plan.stall_ms = val.trim().parse().ok()?,
                    _ => return None,
                }
            }
        }
        if plan.panic_rate == 0.0 && plan.io_rate == 0.0 && plan.stall_rate == 0.0 {
            return None;
        }
        Some(plan)
    }
}

// The armed plan, decomposed into atomics so the draw path never takes
// a lock.  `ACTIVE` is written last (and checked first), so a torn
// read across fields can at worst misdraw during re-arming — benign
// for an injector.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static PANIC_BITS: AtomicU64 = AtomicU64::new(0);
static IO_BITS: AtomicU64 = AtomicU64::new(0);
static STALL_BITS: AtomicU64 = AtomicU64::new(0);
static STALL_MS: AtomicU64 = AtomicU64::new(20);
static SEED: AtomicU64 = AtomicU64::new(0);
/// Draw sequence number: combined with the seed, gives every
/// injection site its own deterministic sample.
static SEQ: AtomicU64 = AtomicU64::new(0);

static INJECTED_PANICS: AtomicU64 = AtomicU64::new(0);
static INJECTED_IO: AtomicU64 = AtomicU64::new(0);
static INJECTED_STALLS: AtomicU64 = AtomicU64::new(0);
static TASK_RETRIES: AtomicU64 = AtomicU64::new(0);

/// Injected task-boundary panics so far (pre-retry; a retried and
/// recovered injection still counts).
pub fn injected_panics() -> u64 {
    INJECTED_PANICS.load(Ordering::Relaxed)
}
/// Injected spill I/O errors so far.
pub fn injected_io_errors() -> u64 {
    INJECTED_IO.load(Ordering::Relaxed)
}
/// Injected task stalls so far.
pub fn injected_stalls() -> u64 {
    INJECTED_STALLS.load(Ordering::Relaxed)
}
/// All injected faults so far, across kinds.
pub fn faults_injected() -> u64 {
    injected_panics() + injected_io_errors() + injected_stalls()
}
/// Task-level retries performed by [`with_task_faults`] so far.
pub fn tasks_retried() -> u64 {
    TASK_RETRIES.load(Ordering::Relaxed)
}
/// Count one retry performed outside [`with_task_faults`] (the tile
/// store's bounded spill-read/write retry loop).
pub fn note_task_retry() {
    TASK_RETRIES.fetch_add(1, Ordering::Relaxed);
}

fn apply(plan: Option<FaultPlan>) {
    match plan {
        Some(p) => {
            PANIC_BITS.store(p.panic_rate.to_bits(), Ordering::Relaxed);
            IO_BITS.store(p.io_rate.to_bits(), Ordering::Relaxed);
            STALL_BITS.store(p.stall_rate.to_bits(), Ordering::Relaxed);
            STALL_MS.store(p.stall_ms, Ordering::Relaxed);
            SEED.store(p.seed, Ordering::Relaxed);
            ACTIVE.store(true, Ordering::Release);
        }
        None => ACTIVE.store(false, Ordering::Release),
    }
}

fn ensure_env_init() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        if let Ok(spec) = std::env::var("EXAGEOSTAT_FAULTS") {
            apply(FaultPlan::parse(&spec));
        }
    });
}

/// Arm (`Some`) or disarm (`None`) fault injection process-wide — the
/// in-process face of `EXAGEOSTAT_FAULTS`, for tests.  Hold
/// [`fault_test_lock`] across the armed window and disarm before
/// releasing it, mirroring `placement::set_class_override`.
pub fn set_fault_plan(plan: Option<FaultPlan>) {
    ensure_env_init(); // the env must not clobber an override later
    apply(plan);
}

/// Serializes tests that arm [`set_fault_plan`] (or the retry/
/// quarantine overrides) — process-global state needs process-global
/// test ordering.
pub fn fault_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    // A panicking armed test must not deadlock every later one.
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Is any fault plan armed?  The disarmed answer is one relaxed load.
#[inline]
pub fn faults_active() -> bool {
    ensure_env_init();
    ACTIVE.load(Ordering::Acquire)
}

/// splitmix64-derived uniform sample in `[0, 1)` for draw `n`.
fn sample(n: u64, salt: u64) -> f64 {
    let mut z = SEED
        .load(Ordering::Relaxed)
        .wrapping_add(salt)
        .wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// One task-boundary draw: may sleep (stall) inline; returns `true`
/// when a panic was drawn (the caller panics or retries).
fn draw_task_fault() -> bool {
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let stall_rate = f64::from_bits(STALL_BITS.load(Ordering::Relaxed));
    if stall_rate > 0.0 && sample(n, 0x5741) < stall_rate {
        INJECTED_STALLS.fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(STALL_MS.load(Ordering::Relaxed)));
    }
    let panic_rate = f64::from_bits(PANIC_BITS.load(Ordering::Relaxed));
    panic_rate > 0.0 && sample(n, 0x9A1C) < panic_rate
}

/// Spill I/O injection point: `Err` with probability `io_rate` when a
/// plan is armed, `Ok` otherwise.  `site` tags the error message.
pub fn maybe_io_error(site: &'static str) -> std::io::Result<()> {
    if !faults_active() {
        return Ok(());
    }
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let io_rate = f64::from_bits(IO_BITS.load(Ordering::Relaxed));
    if io_rate > 0.0 && sample(n, 0x10E7) < io_rate {
        INJECTED_IO.fetch_add(1, Ordering::Relaxed);
        return Err(std::io::Error::other(format!("injected i/o fault at {site}")));
    }
    Ok(())
}

/// Retry budget of [`with_task_faults`]: `EXAGEOSTAT_TASK_RETRIES`
/// (default 1), or the in-process override.
pub fn task_retry_limit() -> usize {
    let o = TASK_RETRY_OVERRIDE.load(Ordering::Relaxed);
    if o != u64::MAX {
        return o as usize;
    }
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("EXAGEOSTAT_TASK_RETRIES")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(1)
    })
}

static TASK_RETRY_OVERRIDE: AtomicU64 = AtomicU64::new(u64::MAX);

/// Test-facing override of [`task_retry_limit`] (`None` restores the
/// env/default).  Hold [`fault_test_lock`] while set.
pub fn set_task_retry_override(limit: Option<usize>) {
    TASK_RETRY_OVERRIDE.store(limit.map_or(u64::MAX, |l| l as u64), Ordering::Relaxed);
}

/// Run one task body under the armed fault plan, with bounded retry.
///
/// Injection happens at *entry* — before `body` has touched any state —
/// so an injected panic or stall is always safe to retry, regardless of
/// what the body does.  A genuine panic raised *by* the body is retried
/// only when `idempotent` (the body fully overwrites its outputs from
/// still-valid inputs, e.g. a Generate-only group); otherwise it
/// propagates to the caller's recovery layer (worker catch → typed
/// `TaskError::Panic` → whole-job retry at the coordinator).
///
/// Disarmed, this is a direct call after one atomic load.
pub fn with_task_faults<T>(idempotent: bool, mut body: impl FnMut() -> T) -> T {
    if !faults_active() {
        return body();
    }
    let budget = task_retry_limit();
    let mut attempt = 0usize;
    loop {
        if draw_task_fault() {
            INJECTED_PANICS.fetch_add(1, Ordering::Relaxed);
            if attempt < budget {
                attempt += 1;
                TASK_RETRIES.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            panic!("injected fault: task panic (retry budget {budget} exhausted)");
        }
        if !idempotent {
            return body();
        }
        match catch_unwind(AssertUnwindSafe(&mut body)) {
            Ok(v) => return v,
            Err(p) => {
                if attempt < budget {
                    attempt += 1;
                    TASK_RETRIES.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                resume_unwind(p);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_and_partial_specs() {
        let p = FaultPlan::parse("panic:0.01,io:0.02,stall:0.005@seed=42,stall_ms=7").unwrap();
        assert_eq!(p.panic_rate, 0.01);
        assert_eq!(p.io_rate, 0.02);
        assert_eq!(p.stall_rate, 0.005);
        assert_eq!(p.seed, 42);
        assert_eq!(p.stall_ms, 7);
        let p = FaultPlan::parse("io:1.0").unwrap();
        assert_eq!(p.io_rate, 1.0);
        assert_eq!(p.seed, 0);
        assert!(FaultPlan::parse("").is_none());
        assert!(FaultPlan::parse("panic:0,io:0").is_none(), "all-zero = off");
        assert!(FaultPlan::parse("panic:2.0").is_none(), "rate out of range");
        assert!(FaultPlan::parse("disk:0.1").is_none(), "unknown kind");
        assert!(FaultPlan::parse("panic:0.1@tick=3").is_none(), "unknown key");
    }

    #[test]
    fn disarmed_injector_is_inert() {
        let _serial = fault_test_lock();
        set_fault_plan(None);
        assert!(!faults_active());
        assert!(maybe_io_error("test").is_ok());
        let mut runs = 0;
        let v = with_task_faults(true, || {
            runs += 1;
            7
        });
        assert_eq!((v, runs), (7, 1));
    }

    #[test]
    fn certain_io_fault_fires_and_counts() {
        let _serial = fault_test_lock();
        set_fault_plan(FaultPlan::parse("io:1.0@seed=1"));
        let before = injected_io_errors();
        let err = maybe_io_error("unit").unwrap_err();
        assert!(err.to_string().contains("injected i/o fault at unit"));
        assert_eq!(injected_io_errors(), before + 1);
        set_fault_plan(None);
    }

    #[test]
    fn certain_panic_rate_retries_within_budget_then_gives_up() {
        let _serial = fault_test_lock();
        set_fault_plan(FaultPlan::parse("panic:1.0@seed=2"));
        set_task_retry_override(Some(3));
        let r0 = tasks_retried();
        let got = std::panic::catch_unwind(|| with_task_faults(false, || 1));
        let msg = got.unwrap_err();
        assert!(
            crate::scheduler::runtime::panic_message(msg.as_ref()).contains("injected fault"),
            "exhausted budget surfaces as an injected panic"
        );
        assert_eq!(tasks_retried(), r0 + 3, "all 3 retries consumed");
        set_task_retry_override(None);
        set_fault_plan(None);
    }

    #[test]
    fn idempotent_body_retries_real_panics() {
        let _serial = fault_test_lock();
        // Armed with a zero-rate-free plan (stall only, rate 0 is
        // rejected, so use a tiny rate that never fires at this seed
        // count) — the point is `faults_active()` gating the retry
        // wrapper on.
        set_fault_plan(Some(FaultPlan {
            panic_rate: 0.0,
            io_rate: 0.0,
            stall_rate: 1e-12,
            stall_ms: 1,
            seed: 3,
        }));
        set_task_retry_override(Some(2));
        let mut calls = 0;
        let v = with_task_faults(true, || {
            calls += 1;
            if calls < 3 {
                panic!("flaky body");
            }
            99
        });
        assert_eq!((v, calls), (99, 3));
        // Non-idempotent bodies never have real panics swallowed.
        let mut calls = 0;
        let got = std::panic::catch_unwind(AssertUnwindSafe(|| {
            with_task_faults(false, || {
                calls += 1;
                panic!("real bug");
            })
        }));
        assert!(got.is_err());
        assert_eq!(calls, 1);
        set_task_retry_override(None);
        set_fault_plan(None);
    }

    #[test]
    fn seeded_stream_is_reproducible() {
        let a: Vec<f64> = (0..32).map(|n| sample(n, 0x9A1C)).collect();
        let b: Vec<f64> = (0..32).map(|n| sample(n, 0x9A1C)).collect();
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (0.0..1.0).contains(&x)));
        let c: Vec<f64> = (0..32).map(|n| sample(n, 0x10E7)).collect();
        assert_ne!(a, c, "salts separate the streams");
    }
}
