//! Task-based runtime — the StarPU analogue (DESIGN.md §2, L3).
//!
//! ExaGeoStat expresses every linear-algebra operation as a *sequential task
//! flow* (STF): tasks are submitted in program order with data handles and
//! access modes, and the runtime infers the dependency DAG (read-after-
//! write, write-after-read, write-after-write) and executes it on a worker
//! pool under a pluggable scheduling policy.  This module implements that
//! model:
//!
//! * [`TaskGraph`] — STF submission + dependency inference.
//! * [`runtime`] — the persistent worker runtime: threads spawned once
//!   per hardware context (`starpu_init` analogue), task graphs submitted
//!   as concurrent *jobs* and interleaved under a pluggable policy.
//! * [`pool`] — the scheduling [`pool::Policy`] enum (`eager` central
//!   FIFO, `prio` priority heap, `lws` locality work stealing, `random`),
//!   mirroring StarPU's `STARPU_SCHED` choices used in the paper (§III-B),
//!   plus the one-shot `pool::run` convenience executor.
//! * [`profile`] — per-task timing and per-kind cost models (StarPU builds
//!   the same cost models to drive heterogeneous dispatch).
//! * [`placement`] — heterogeneous worker classes (`cpu`/`accel`/`slow`)
//!   and the HEFT-style [`placement::Placer`] that routes each task to the
//!   class best suited for it (DESIGN.md §2i).
//! * [`des`] — a discrete-event simulator that replays a measured task
//!   graph on modeled heterogeneous (GPU, Fig 6) or distributed (Fig 7)
//!   resources; see DESIGN.md "Hardware adaptation".
//! * [`faults`] — the seeded, deterministic fault injector
//!   (`EXAGEOSTAT_FAULTS`) firing at task boundaries and spill I/O,
//!   plus the bounded task-retry wrapper — the harness the failure
//!   model (DESIGN.md §2j) is validated against.

pub mod des;
pub mod faults;
pub mod placement;
pub mod pool;
pub mod profile;
pub mod runtime;

use std::collections::HashMap;

/// Data access mode for a task operand (StarPU: `STARPU_R` / `STARPU_W` /
/// `STARPU_RW`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Access {
    R,
    W,
    RW,
}

/// Opaque data handle registered with a [`TaskGraph`].
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct Handle(pub usize);

/// Static task classification, used by the `prio` policy and the profiler.
/// Priorities follow the critical path of the tiled Cholesky: POTRF releases
/// the most downstream work, GEMM the least.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct TaskKind {
    pub name: &'static str,
    pub priority: u8,
}

impl TaskKind {
    pub const POTRF: TaskKind = TaskKind { name: "potrf", priority: 4 };
    pub const TRSM: TaskKind = TaskKind { name: "trsm", priority: 3 };
    pub const SYRK: TaskKind = TaskKind { name: "syrk", priority: 2 };
    pub const GEMM: TaskKind = TaskKind { name: "gemm", priority: 1 };
    pub const DCMG: TaskKind = TaskKind { name: "dcmg", priority: 5 };
    pub const OTHER: TaskKind = TaskKind { name: "other", priority: 0 };
    /// Low-rank variants (TLR path).
    pub const LR_TRSM: TaskKind = TaskKind { name: "lr_trsm", priority: 3 };
    pub const LR_SYRK: TaskKind = TaskKind { name: "lr_syrk", priority: 2 };
    pub const LR_GEMM: TaskKind = TaskKind { name: "lr_gemm", priority: 1 };
    pub const COMPRESS: TaskKind = TaskKind { name: "compress", priority: 5 };
    /// Per-tile log-determinant reduction off POTRF's diagonal block
    /// (pipeline IR); priority matches POTRF — it sits on the same tile.
    pub const LOGDET: TaskKind = TaskKind { name: "logdet", priority: 4 };
}

/// A submitted task: closure + graph metadata.
pub struct TaskNode {
    pub kind: TaskKind,
    /// Bytes touched, for the DES transfer model (sum of operand sizes).
    pub bytes: usize,
    /// Handle of the output operand (first W/RW), for ownership mapping in
    /// the distributed DES.
    pub out_handle: Option<Handle>,
    /// Worker class this task must run on (`None` = the runtime's default
    /// class); set by [`TaskGraph::set_class`] from placement decisions.
    pub class: Option<placement::WorkerClass>,
    pub(crate) run: Option<Box<dyn FnOnce() + Send>>,
    pub(crate) succs: Vec<usize>,
    pub(crate) npred: usize,
}

/// Sequential-task-flow graph builder.
///
/// Dependencies are inferred from program order exactly like StarPU:
/// a reader depends on the last writer of each handle; a writer depends on
/// the last writer *and* every reader since (WAR + WAW hazards).
#[derive(Default)]
pub struct TaskGraph {
    pub(crate) tasks: Vec<TaskNode>,
    next_handle: usize,
    last_writer: HashMap<Handle, usize>,
    readers: HashMap<Handle, Vec<usize>>,
}

impl TaskGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new data handle (e.g. one tile).
    pub fn register(&mut self) -> Handle {
        let h = Handle(self.next_handle);
        self.next_handle += 1;
        h
    }

    /// Register `n` handles at once (e.g. a tile matrix).
    pub fn register_many(&mut self, n: usize) -> Vec<Handle> {
        (0..n).map(|_| self.register()).collect()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Submit a task accessing `operands`, to be executed as `run`.
    /// Returns the task id.
    pub fn submit(
        &mut self,
        kind: TaskKind,
        operands: &[(Handle, Access)],
        bytes: usize,
        run: impl FnOnce() + Send + 'static,
    ) -> usize {
        let id = self.tasks.len();
        let mut preds: Vec<usize> = Vec::new();
        let mut out_handle = None;
        for &(h, mode) in operands {
            match mode {
                Access::R => {
                    if let Some(&w) = self.last_writer.get(&h) {
                        preds.push(w);
                    }
                    self.readers.entry(h).or_default().push(id);
                }
                Access::W | Access::RW => {
                    if out_handle.is_none() {
                        out_handle = Some(h);
                    }
                    if let Some(&w) = self.last_writer.get(&h) {
                        preds.push(w);
                    }
                    if let Some(rs) = self.readers.remove(&h) {
                        preds.extend(rs);
                    }
                    self.last_writer.insert(h, id);
                }
            }
        }
        preds.sort_unstable();
        preds.dedup();
        preds.retain(|&p| p != id);
        let npred = preds.len();
        for p in &preds {
            self.tasks[*p].succs.push(id);
        }
        self.tasks.push(TaskNode {
            kind,
            bytes,
            out_handle,
            class: None,
            run: Some(Box::new(run)),
            succs: Vec::new(),
            npred,
        });
        id
    }

    /// Submit a task with *explicit* predecessor task ids, bypassing
    /// STF handle inference.  The pipeline planner uses this to lower a
    /// fused [`crate::pipeline::ExecutionPlan`]: fusion merges nodes
    /// whose handle sets STF would keep separate, so the planner's
    /// already-resolved group edges are authoritative.  Predecessors
    /// must be earlier task ids; later ones are dropped (defensively —
    /// a plan never produces them).  `last_writer`/`readers` state is
    /// untouched, so explicit-dep and STF submission must not be mixed
    /// on the same handles within one graph.
    pub fn submit_dep(
        &mut self,
        kind: TaskKind,
        preds: &[usize],
        bytes: usize,
        run: impl FnOnce() + Send + 'static,
    ) -> usize {
        let id = self.tasks.len();
        let mut preds: Vec<usize> = preds.to_vec();
        preds.sort_unstable();
        preds.dedup();
        preds.retain(|&p| p < id);
        let npred = preds.len();
        for p in &preds {
            self.tasks[*p].succs.push(id);
        }
        self.tasks.push(TaskNode {
            kind,
            bytes,
            out_handle: None,
            class: None,
            run: Some(Box::new(run)),
            succs: Vec::new(),
            npred,
        });
        id
    }

    /// Pin task `id` to a worker class (placement decision).  Runtimes
    /// without that class fall back to their default class, so a placed
    /// graph remains runnable anywhere.
    pub fn set_class(&mut self, id: usize, class: placement::WorkerClass) {
        self.tasks[id].class = Some(class);
    }

    /// Direct predecessor count of task `id` (for tests / DES).
    pub fn npred(&self, id: usize) -> usize {
        self.tasks[id].npred
    }

    /// Successor list of task `id`.
    pub fn succs(&self, id: usize) -> &[usize] {
        &self.tasks[id].succs
    }

    /// Execute the whole graph serially on the calling thread (reference
    /// executor; also used to warm cost models).  Returns a profile.
    pub fn run_serial(&mut self) -> profile::Profile {
        let mut prof = profile::Profile::new(1);
        let order: Vec<usize> = topo_order(self);
        for id in order {
            let t0 = std::time::Instant::now();
            if let Some(run) = self.tasks[id].run.take() {
                run();
            }
            prof.record(0, self.tasks[id].kind, t0.elapsed(), self.tasks[id].bytes);
        }
        prof
    }
}

/// Kahn topological order (panics on cycles — STF graphs are acyclic by
/// construction, so a cycle is a bug).
pub fn topo_order(g: &TaskGraph) -> Vec<usize> {
    let n = g.tasks.len();
    let mut indeg: Vec<usize> = g.tasks.iter().map(|t| t.npred).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(id) = ready.pop() {
        order.push(id);
        for &s in &g.tasks[id].succs {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(s);
            }
        }
    }
    assert_eq!(order.len(), n, "task graph has a cycle");
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn stf_infers_raw_war_waw() {
        let mut g = TaskGraph::new();
        let a = g.register();
        let t0 = g.submit(TaskKind::OTHER, &[(a, Access::W)], 0, || {}); // writer
        let t1 = g.submit(TaskKind::OTHER, &[(a, Access::R)], 0, || {}); // RAW on t0
        let t2 = g.submit(TaskKind::OTHER, &[(a, Access::R)], 0, || {}); // RAW on t0
        let t3 = g.submit(TaskKind::OTHER, &[(a, Access::RW)], 0, || {}); // WAR on t1,t2 (+ t0)
        let t4 = g.submit(TaskKind::OTHER, &[(a, Access::W)], 0, || {}); // WAW on t3
        assert_eq!(g.npred(t0), 0);
        assert_eq!(g.npred(t1), 1);
        assert_eq!(g.npred(t2), 1);
        assert_eq!(g.npred(t3), 3);
        assert_eq!(g.npred(t4), 1);
        assert!(g.succs(t0).contains(&t1) && g.succs(t0).contains(&t2));
        assert!(g.succs(t1).contains(&t3) && g.succs(t2).contains(&t3));
        assert!(g.succs(t3).contains(&t4));
        assert_eq!(g.succs(t4).len(), 0);
    }

    #[test]
    fn independent_handles_no_deps() {
        let mut g = TaskGraph::new();
        let a = g.register();
        let b = g.register();
        g.submit(TaskKind::OTHER, &[(a, Access::W)], 0, || {});
        let t1 = g.submit(TaskKind::OTHER, &[(b, Access::W)], 0, || {});
        assert_eq!(g.npred(t1), 0);
    }

    #[test]
    fn serial_execution_runs_everything_in_order() {
        let mut g = TaskGraph::new();
        let a = g.register();
        let counter = Arc::new(AtomicUsize::new(0));
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        for i in 0..10 {
            let c = counter.clone();
            let o = order.clone();
            g.submit(TaskKind::OTHER, &[(a, Access::RW)], 0, move || {
                c.fetch_add(1, Ordering::SeqCst);
                o.lock().unwrap().push(i);
            });
        }
        let prof = g.run_serial();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
        // RW chain => strict program order
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
        assert_eq!(prof.total_tasks(), 10);
    }

    #[test]
    fn topo_order_respects_deps() {
        let mut g = TaskGraph::new();
        let hs = g.register_many(4);
        // diamond: t0 -> (t1, t2) -> t3
        let t0 = g.submit(TaskKind::OTHER, &[(hs[0], Access::W)], 0, || {});
        let t1 = g.submit(
            TaskKind::OTHER,
            &[(hs[0], Access::R), (hs[1], Access::W)],
            0,
            || {},
        );
        let t2 = g.submit(
            TaskKind::OTHER,
            &[(hs[0], Access::R), (hs[2], Access::W)],
            0,
            || {},
        );
        let t3 = g.submit(
            TaskKind::OTHER,
            &[(hs[1], Access::R), (hs[2], Access::R), (hs[3], Access::W)],
            0,
            || {},
        );
        let order = topo_order(&g);
        let pos = |t: usize| order.iter().position(|&x| x == t).unwrap();
        assert!(pos(t0) < pos(t1) && pos(t0) < pos(t2));
        assert!(pos(t1) < pos(t3) && pos(t2) < pos(t3));
    }
}
