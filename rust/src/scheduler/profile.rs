//! Per-task profiling and per-kind cost models.
//!
//! StarPU records execution times per codelet and hardware to build the
//! cost models its schedulers use; we do the same.  The profile drives
//! (a) the per-kernel timings behind EXPERIMENTS.md §Kernel roofline and
//! §Time per iteration, and (b) the discrete-event simulator for the
//! GPU / distributed studies (Figs 6–7).

use super::TaskKind;
use std::collections::HashMap;
use std::time::Duration;

/// One recorded task execution.
#[derive(Copy, Clone, Debug)]
pub struct TaskRecord {
    pub worker: usize,
    pub kind: TaskKind,
    pub dur: Duration,
    pub bytes: usize,
}

/// Aggregated execution profile.
#[derive(Clone, Debug, Default)]
pub struct Profile {
    pub nworkers: usize,
    pub records: Vec<TaskRecord>,
    pub wall: Duration,
    /// Tasks retired without executing because their job was cancelled
    /// (`records` holds only tasks that actually ran).
    pub tasks_skipped: usize,
}

impl Profile {
    pub fn new(nworkers: usize) -> Self {
        Profile {
            nworkers,
            records: Vec::new(),
            wall: Duration::ZERO,
            tasks_skipped: 0,
        }
    }

    pub fn record(&mut self, worker: usize, kind: TaskKind, dur: Duration, bytes: usize) {
        self.records.push(TaskRecord {
            worker,
            kind,
            dur,
            bytes,
        });
    }

    pub fn merge(&mut self, other: Profile) {
        self.records.extend(other.records);
    }

    pub fn total_tasks(&self) -> usize {
        self.records.len()
    }

    /// Sum of task execution times (ignores idle/waiting).
    pub fn busy_time(&self) -> Duration {
        self.records.iter().map(|r| r.dur).sum()
    }

    /// Parallel efficiency: busy / (wall * nworkers).
    pub fn efficiency(&self) -> f64 {
        if self.wall.is_zero() || self.nworkers == 0 {
            return 0.0;
        }
        self.busy_time().as_secs_f64() / (self.wall.as_secs_f64() * self.nworkers as f64)
    }

    /// Build a per-kind cost model (mean seconds per task kind).
    pub fn cost_model(&self) -> CostModel {
        let mut sums: HashMap<&'static str, (f64, usize)> = HashMap::new();
        for r in &self.records {
            let e = sums.entry(r.kind.name).or_insert((0.0, 0));
            e.0 += r.dur.as_secs_f64();
            e.1 += 1;
        }
        CostModel {
            mean_secs: sums
                .into_iter()
                .map(|(k, (s, n))| (k, s / n as f64))
                .collect(),
        }
    }

    /// Human-readable per-kind summary (used by `--profile` CLI output).
    pub fn summary(&self) -> String {
        let mut sums: HashMap<&'static str, (f64, usize)> = HashMap::new();
        for r in &self.records {
            let e = sums.entry(r.kind.name).or_insert((0.0, 0));
            e.0 += r.dur.as_secs_f64();
            e.1 += 1;
        }
        let mut rows: Vec<_> = sums.into_iter().collect();
        rows.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0));
        let mut out = format!(
            "wall {:.3}s, {} tasks on {} workers, efficiency {:.1}%\n",
            self.wall.as_secs_f64(),
            self.total_tasks(),
            self.nworkers,
            100.0 * self.efficiency()
        );
        for (k, (s, n)) in rows {
            out.push_str(&format!(
                "  {k:<10} n={n:<6} total={s:>9.4}s mean={:>10.1}us\n",
                1e6 * s / n as f64
            ));
        }
        out
    }
}

/// Mean per-kind execution time, used by the DES and the hetero dispatch.
#[derive(Clone, Debug, Default)]
pub struct CostModel {
    pub mean_secs: HashMap<&'static str, f64>,
}

impl CostModel {
    pub fn cost(&self, kind: TaskKind) -> f64 {
        self.mean_secs.get(kind.name).copied().unwrap_or(1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_means() {
        let mut p = Profile::new(2);
        p.record(0, TaskKind::GEMM, Duration::from_micros(100), 0);
        p.record(1, TaskKind::GEMM, Duration::from_micros(300), 0);
        p.record(0, TaskKind::POTRF, Duration::from_micros(50), 0);
        let cm = p.cost_model();
        assert!((cm.cost(TaskKind::GEMM) - 200e-6).abs() < 1e-12);
        assert!((cm.cost(TaskKind::POTRF) - 50e-6).abs() < 1e-12);
        // unknown kind gets a small default, not zero (DES needs progress)
        assert!(cm.cost(TaskKind::DCMG) > 0.0);
    }

    #[test]
    fn efficiency_bounds() {
        let mut p = Profile::new(4);
        p.wall = Duration::from_secs(1);
        p.record(0, TaskKind::GEMM, Duration::from_secs(2), 0);
        let e = p.efficiency();
        assert!(e > 0.0 && e <= 1.0, "{e}");
    }
}
