//! Per-task profiling and per-kind cost models.
//!
//! StarPU records execution times per codelet and hardware to build the
//! cost models its schedulers use; we do the same.  The profile drives
//! (a) the per-kernel timings behind EXPERIMENTS.md §Kernel roofline and
//! §Time per iteration, and (b) the discrete-event simulator for the
//! GPU / distributed studies (Figs 6–7).

use super::placement::WorkerClass;
use super::TaskKind;
use std::collections::HashMap;
use std::time::Duration;

/// One recorded task execution.
#[derive(Copy, Clone, Debug)]
pub struct TaskRecord {
    pub worker: usize,
    pub kind: TaskKind,
    pub dur: Duration,
    pub bytes: usize,
}

/// Aggregated execution profile.
#[derive(Clone, Debug, Default)]
pub struct Profile {
    pub nworkers: usize,
    pub records: Vec<TaskRecord>,
    pub wall: Duration,
    /// Tasks retired without executing because their job was cancelled
    /// (`records` holds only tasks that actually ran).
    pub tasks_skipped: usize,
    /// Class of each worker index (empty = treat all workers as `Cpu`;
    /// serial profiles and pre-heterogeneity callers leave it empty).
    pub worker_classes: Vec<WorkerClass>,
    /// Faults the injection harness (`scheduler::faults`) fired while
    /// this job ran (0 when the injector is disarmed — the default).
    /// Best-effort attribution under concurrent jobs: counters are
    /// process-global, so a neighbour job's faults can be included.
    pub faults_injected: u64,
    /// Task-level retries the harness performed while this job ran
    /// (same attribution caveat as `faults_injected`).
    pub tasks_retried: u64,
}

impl Profile {
    pub fn new(nworkers: usize) -> Self {
        Profile {
            nworkers,
            records: Vec::new(),
            wall: Duration::ZERO,
            tasks_skipped: 0,
            worker_classes: Vec::new(),
            faults_injected: 0,
            tasks_retried: 0,
        }
    }

    fn class_of_worker(&self, w: usize) -> WorkerClass {
        self.worker_classes
            .get(w)
            .copied()
            .unwrap_or(WorkerClass::Cpu)
    }

    pub fn record(&mut self, worker: usize, kind: TaskKind, dur: Duration, bytes: usize) {
        self.records.push(TaskRecord {
            worker,
            kind,
            dur,
            bytes,
        });
    }

    pub fn merge(&mut self, other: Profile) {
        self.records.extend(other.records);
    }

    pub fn total_tasks(&self) -> usize {
        self.records.len()
    }

    /// Sum of task execution times (ignores idle/waiting).
    pub fn busy_time(&self) -> Duration {
        self.records.iter().map(|r| r.dur).sum()
    }

    /// Parallel efficiency: busy / (wall * nworkers).
    pub fn efficiency(&self) -> f64 {
        if self.wall.is_zero() || self.nworkers == 0 {
            return 0.0;
        }
        self.busy_time().as_secs_f64() / (self.wall.as_secs_f64() * self.nworkers as f64)
    }

    /// Per-class busy time and utilization: `(class, workers, busy,
    /// busy / (wall * workers))`, one row per class present in
    /// `worker_classes` (all-`Cpu` when unset), in first-worker order.
    pub fn class_utilization(&self) -> Vec<(WorkerClass, usize, Duration, f64)> {
        let mut rows: Vec<(WorkerClass, usize, Duration)> = Vec::new();
        for w in 0..self.nworkers {
            let c = self.class_of_worker(w);
            if !rows.iter().any(|r| r.0 == c) {
                rows.push((c, 0, Duration::ZERO));
            }
            rows.iter_mut().find(|r| r.0 == c).unwrap().1 += 1;
        }
        for r in &self.records {
            let c = self.class_of_worker(r.worker);
            match rows.iter_mut().find(|row| row.0 == c) {
                Some(row) => row.2 += r.dur,
                None => rows.push((c, 0, r.dur)),
            }
        }
        rows.into_iter()
            .map(|(c, nw, busy)| {
                let util = if self.wall.is_zero() || nw == 0 {
                    0.0
                } else {
                    busy.as_secs_f64() / (self.wall.as_secs_f64() * nw as f64)
                };
                (c, nw, busy, util)
            })
            .collect()
    }

    /// Build the per-(kind, class) cost model from this profile's records
    /// (feeds [`super::placement::Placer`] and the DES projection).
    pub fn class_cost_model(&self) -> ClassCostModel {
        let mut cm = ClassCostModel::default();
        for r in &self.records {
            cm.record(r.kind, self.class_of_worker(r.worker), r.dur.as_secs_f64());
        }
        cm
    }

    /// Build a per-kind cost model (mean seconds per task kind).
    pub fn cost_model(&self) -> CostModel {
        let mut sums: HashMap<&'static str, (f64, usize)> = HashMap::new();
        for r in &self.records {
            let e = sums.entry(r.kind.name).or_insert((0.0, 0));
            e.0 += r.dur.as_secs_f64();
            e.1 += 1;
        }
        CostModel {
            mean_secs: sums
                .into_iter()
                .map(|(k, (s, n))| (k, s / n as f64))
                .collect(),
        }
    }

    /// Human-readable per-kind summary (used by `--profile` CLI output).
    pub fn summary(&self) -> String {
        let mut sums: HashMap<&'static str, (f64, usize)> = HashMap::new();
        for r in &self.records {
            let e = sums.entry(r.kind.name).or_insert((0.0, 0));
            e.0 += r.dur.as_secs_f64();
            e.1 += 1;
        }
        let mut rows: Vec<_> = sums.into_iter().collect();
        rows.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0));
        let mut out = format!(
            "wall {:.3}s, {} tasks on {} workers, efficiency {:.1}%\n",
            self.wall.as_secs_f64(),
            self.total_tasks(),
            self.nworkers,
            100.0 * self.efficiency()
        );
        for (k, (s, n)) in rows {
            out.push_str(&format!(
                "  {k:<10} n={n:<6} total={s:>9.4}s mean={:>10.1}us\n",
                1e6 * s / n as f64
            ));
        }
        out
    }
}

/// Mean per-kind execution time, used by the DES and the hetero dispatch.
#[derive(Clone, Debug, Default)]
pub struct CostModel {
    pub mean_secs: HashMap<&'static str, f64>,
}

impl CostModel {
    pub fn cost(&self, kind: TaskKind) -> f64 {
        self.mean_secs.get(kind.name).copied().unwrap_or(1e-6)
    }
}

/// Measured per-(kind, class) execution-time sums — the heterogeneous
/// cost model StarPU keeps per codelet per architecture.  The runtime
/// accumulates one of these across jobs; [`super::placement::est_cost`]
/// consumes it with static-factor fallback.
#[derive(Clone, Debug, Default)]
pub struct ClassCostModel {
    /// (kind name, class) -> (total seconds, samples)
    sums: HashMap<(&'static str, WorkerClass), (f64, u64)>,
}

impl ClassCostModel {
    pub fn record(&mut self, kind: TaskKind, class: WorkerClass, secs: f64) {
        let e = self.sums.entry((kind.name, class)).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    /// Mean seconds of `kind` on `class`, if ever measured there.
    pub fn mean(&self, kind: TaskKind, class: WorkerClass) -> Option<f64> {
        self.sums
            .get(&(kind.name, class))
            .map(|&(s, n)| s / n as f64)
    }

    pub fn samples(&self, kind: TaskKind, class: WorkerClass) -> u64 {
        self.sums.get(&(kind.name, class)).map_or(0, |&(_, n)| n)
    }

    pub fn is_empty(&self) -> bool {
        self.sums.is_empty()
    }

    /// Global mean task cost across every (kind, class) measured so far
    /// — the runtime watchdog's stall baseline.  `None` before any
    /// sample has landed.
    pub fn mean_all(&self) -> Option<f64> {
        let (s, n) = self
            .sums
            .values()
            .fold((0.0f64, 0u64), |(s, n), &(cs, cn)| (s + cs, n + cn));
        (n > 0).then(|| s / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_means() {
        let mut p = Profile::new(2);
        p.record(0, TaskKind::GEMM, Duration::from_micros(100), 0);
        p.record(1, TaskKind::GEMM, Duration::from_micros(300), 0);
        p.record(0, TaskKind::POTRF, Duration::from_micros(50), 0);
        let cm = p.cost_model();
        assert!((cm.cost(TaskKind::GEMM) - 200e-6).abs() < 1e-12);
        assert!((cm.cost(TaskKind::POTRF) - 50e-6).abs() < 1e-12);
        // unknown kind gets a small default, not zero (DES needs progress)
        assert!(cm.cost(TaskKind::DCMG) > 0.0);
    }

    #[test]
    fn class_utilization_and_cost_model() {
        let mut p = Profile::new(3);
        p.worker_classes = vec![WorkerClass::Cpu, WorkerClass::Cpu, WorkerClass::Slow];
        p.wall = Duration::from_secs(1);
        p.record(0, TaskKind::GEMM, Duration::from_millis(100), 0);
        p.record(1, TaskKind::GEMM, Duration::from_millis(300), 0);
        p.record(2, TaskKind::GEMM, Duration::from_millis(800), 0);
        let rows = p.class_utilization();
        assert_eq!(rows.len(), 2);
        let cpu = rows.iter().find(|r| r.0 == WorkerClass::Cpu).unwrap();
        let slow = rows.iter().find(|r| r.0 == WorkerClass::Slow).unwrap();
        assert_eq!(cpu.1, 2);
        assert_eq!(slow.1, 1);
        assert!((cpu.3 - 0.2).abs() < 1e-9, "{}", cpu.3);
        assert!((slow.3 - 0.8).abs() < 1e-9, "{}", slow.3);
        let cm = p.class_cost_model();
        assert!((cm.mean(TaskKind::GEMM, WorkerClass::Cpu).unwrap() - 0.2).abs() < 1e-12);
        assert!((cm.mean(TaskKind::GEMM, WorkerClass::Slow).unwrap() - 0.8).abs() < 1e-12);
        assert_eq!(cm.mean(TaskKind::POTRF, WorkerClass::Cpu), None);
        assert_eq!(cm.samples(TaskKind::GEMM, WorkerClass::Cpu), 2);
        // unmapped workers default to Cpu
        let mut q = Profile::new(1);
        q.record(0, TaskKind::POTRF, Duration::from_millis(10), 0);
        assert!(q.class_cost_model().mean(TaskKind::POTRF, WorkerClass::Cpu).is_some());
    }

    #[test]
    fn efficiency_bounds() {
        let mut p = Profile::new(4);
        p.wall = Duration::from_secs(1);
        p.record(0, TaskKind::GEMM, Duration::from_secs(2), 0);
        let e = p.efficiency();
        assert!(e > 0.0 && e <= 1.0, "{e}");
    }
}
