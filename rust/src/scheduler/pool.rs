//! Scheduling policies + the one-shot graph executor.
//!
//! The persistent worker machinery lives in [`super::runtime`]
//! ([`super::runtime::Runtime`]): workers are spawned once per hardware
//! context and every task graph is multiplexed onto them as a job.
//! [`run`] remains as the *one-shot* convenience for tests and tools
//! that execute a single graph and do not hold a context — it stands up
//! a temporary runtime, submits the graph as its only job and tears the
//! runtime down again.  Hot paths (likelihood pipelines, simulation,
//! kriging) go through `ExecCtx::run_graph`, which reuses the context's
//! long-lived runtime instead.

use super::profile::Profile;
use super::runtime::Runtime;
use super::TaskGraph;
use std::time::Instant;

/// Scheduling policy (paper/StarPU names: eager, prio, lws "locality work
/// stealing"; `random` is StarPU's random-dispatch policy).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Single central FIFO queue.
    Eager,
    /// Central priority heap ordered by [`super::TaskKind::priority`]
    /// (critical-path first), with the job priority as tie-break.
    Prio,
    /// Per-worker LIFO deques with random stealing.
    Lws,
    /// Random worker assignment at ready time.
    Random,
}

impl Policy {
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        Ok(match s {
            "eager" => Policy::Eager,
            "prio" => Policy::Prio,
            "lws" => Policy::Lws,
            "random" => Policy::Random,
            other => anyhow::bail!("unknown scheduler policy {other:?} (eager|prio|lws|random)"),
        })
    }
}

/// Execute `graph` once on a **temporary** `nworkers`-thread runtime under
/// `policy`; returns the merged execution profile (wall time + per-task
/// records).  `nworkers <= 1` runs serially on the calling thread, as
/// before.
///
/// This is the one-shot compatibility path: it spawns and joins threads
/// per call.  Anything that executes more than one graph should hold a
/// [`Runtime`] (or an `ExecCtx`, which owns one) and submit jobs to it.
pub fn run(graph: &mut TaskGraph, nworkers: usize, policy: Policy) -> Profile {
    if graph.tasks.is_empty() {
        return Profile::new(nworkers.max(1));
    }
    if nworkers <= 1 {
        let t0 = Instant::now();
        let mut p = graph.run_serial();
        p.wall = t0.elapsed();
        p.nworkers = 1;
        return p;
    }
    let rt = Runtime::new(nworkers, policy);
    let g = std::mem::take(graph);
    let prof = rt.submit(g).wait();
    rt.shutdown();
    prof
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Access, TaskKind};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    fn all_policies() -> [Policy; 4] {
        [Policy::Eager, Policy::Prio, Policy::Lws, Policy::Random]
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("eager").unwrap(), Policy::Eager);
        assert_eq!(Policy::parse("lws").unwrap(), Policy::Lws);
        assert!(Policy::parse("bogus").is_err());
    }

    #[test]
    fn runs_all_tasks_every_policy() {
        for policy in all_policies() {
            let mut g = TaskGraph::new();
            let hs = g.register_many(16);
            let counter = Arc::new(AtomicUsize::new(0));
            for i in 0..200 {
                let c = counter.clone();
                g.submit(
                    TaskKind::GEMM,
                    &[(hs[i % 16], Access::RW)],
                    0,
                    move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    },
                );
            }
            let prof = run(&mut g, 4, policy);
            assert_eq!(counter.load(Ordering::SeqCst), 200, "{policy:?}");
            assert_eq!(prof.total_tasks(), 200, "{policy:?}");
        }
    }

    #[test]
    fn dependency_order_respected_under_parallelism() {
        // Chain per handle: completion stamps must be increasing.
        for policy in all_policies() {
            let mut g = TaskGraph::new();
            let hs = g.register_many(8);
            let clock = Arc::new(AtomicUsize::new(0));
            let stamps = Arc::new(Mutex::new(vec![Vec::new(); 8]));
            for round in 0..20 {
                for (hi, &h) in hs.iter().enumerate() {
                    let clock = clock.clone();
                    let stamps = stamps.clone();
                    g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                        let t = clock.fetch_add(1, Ordering::SeqCst);
                        stamps.lock().unwrap()[hi].push((round, t));
                    });
                }
            }
            run(&mut g, 4, policy);
            let stamps = stamps.lock().unwrap();
            for chain in stamps.iter() {
                assert_eq!(chain.len(), 20);
                for w in chain.windows(2) {
                    assert!(w[0].0 < w[1].0, "{policy:?}: round order");
                    assert!(w[0].1 < w[1].1, "{policy:?}: time order");
                }
            }
        }
    }

    #[test]
    fn single_worker_falls_back_to_serial() {
        let mut g = TaskGraph::new();
        let h = g.register();
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..5 {
            let c = c.clone();
            g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let prof = run(&mut g, 1, Policy::Lws);
        assert_eq!(c.load(Ordering::SeqCst), 5);
        assert_eq!(prof.nworkers, 1);
    }

    #[test]
    fn parallel_speedup_on_independent_work() {
        // Coarse sanity: 4 workers should beat 1 worker on embarrassingly
        // parallel CPU-bound tasks.  Generous threshold to avoid flakes.
        let build = || {
            let mut g = TaskGraph::new();
            let hs = g.register_many(64);
            for &h in &hs {
                g.submit(TaskKind::GEMM, &[(h, Access::RW)], 0, move || {
                    // ~1 ms of real work the optimizer cannot elide
                    let mut acc = std::hint::black_box(1.0f64);
                    for _ in 0..400_000 {
                        acc = std::hint::black_box(acc + acc.sqrt() * 1e-9);
                    }
                    std::hint::black_box(acc);
                });
            }
            g
        };
        let mut g1 = build();
        let t1 = run(&mut g1, 1, Policy::Lws).wall;
        let mut g4 = build();
        let t4 = run(&mut g4, 4, Policy::Lws).wall;
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        if cores >= 4 {
            assert!(
                t4.as_secs_f64() < 0.8 * t1.as_secs_f64(),
                "1w {t1:?} vs 4w {t4:?}"
            );
        } else {
            // Single-core testbed (see DESIGN.md "Hardware adaptation"):
            // we can only assert the pool does not pathologically slow down.
            assert!(
                t4.as_secs_f64() < 2.0 * t1.as_secs_f64(),
                "1w {t1:?} vs 4w {t4:?}"
            );
        }
    }
}
