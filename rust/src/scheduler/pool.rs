//! Worker-pool executor for [`super::TaskGraph`] with pluggable scheduling
//! policies (the StarPU `STARPU_SCHED` analogue, §III-B of the paper).

use super::profile::Profile;
use super::TaskGraph;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Scheduling policy (paper/StarPU names: eager, prio, lws "locality work
/// stealing"; `random` is StarPU's random-dispatch policy).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Single central FIFO queue.
    Eager,
    /// Central priority heap ordered by [`super::TaskKind::priority`]
    /// (critical-path first).
    Prio,
    /// Per-worker LIFO deques with random stealing.
    Lws,
    /// Random worker assignment at ready time.
    Random,
}

impl Policy {
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        Ok(match s {
            "eager" => Policy::Eager,
            "prio" => Policy::Prio,
            "lws" => Policy::Lws,
            "random" => Policy::Random,
            other => anyhow::bail!("unknown scheduler policy {other:?} (eager|prio|lws|random)"),
        })
    }
}

/// Ready-task entry for the priority heap.
#[derive(PartialEq, Eq)]
struct PrioEntry {
    prio: u8,
    /// tie-break on submission order (older first) for determinism
    id: std::cmp::Reverse<usize>,
}
impl Ord for PrioEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.prio, &self.id).cmp(&(other.prio, &other.id))
    }
}
impl PartialOrd for PrioEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Shared scheduler state.
struct Shared {
    /// eager / random: one FIFO per "slot" (eager uses slot 0 only).
    queues: Vec<Mutex<VecDeque<usize>>>,
    heap: Mutex<BinaryHeap<PrioEntry>>,
    cv: Condvar,
    cv_guard: Mutex<()>,
    remaining: AtomicUsize,
    policy: Policy,
    nworkers: usize,
    rng_state: AtomicUsize,
}

impl Shared {
    fn push(&self, id: usize, prio: u8, local: usize) {
        match self.policy {
            Policy::Eager => self.queues[0].lock().unwrap().push_back(id),
            Policy::Prio => self.heap.lock().unwrap().push(PrioEntry {
                prio,
                id: std::cmp::Reverse(id),
            }),
            Policy::Lws => self.queues[local].lock().unwrap().push_back(id),
            Policy::Random => {
                // xorshift over an atomic — cheap, contention-tolerant
                let s = self.rng_state.fetch_add(0x9E3779B9, Ordering::Relaxed);
                let mut x = s.wrapping_mul(0x2545F4914F6CDD1D) ^ 0x1234_5678;
                x ^= x >> 17;
                self.queues[x % self.nworkers].lock().unwrap().push_back(id)
            }
        }
        // wake one sleeper
        let _g = self.cv_guard.lock().unwrap();
        self.cv.notify_all();
    }

    fn pop(&self, me: usize) -> Option<usize> {
        match self.policy {
            Policy::Eager => self.queues[0].lock().unwrap().pop_front(),
            Policy::Prio => self.heap.lock().unwrap().pop().map(|e| e.id.0),
            Policy::Lws => {
                // local LIFO first (cache locality), then steal FIFO
                if let Some(id) = self.queues[me].lock().unwrap().pop_back() {
                    return Some(id);
                }
                for off in 1..self.nworkers {
                    let v = (me + off) % self.nworkers;
                    if let Some(id) = self.queues[v].lock().unwrap().pop_front() {
                        return Some(id);
                    }
                }
                None
            }
            Policy::Random => {
                if let Some(id) = self.queues[me].lock().unwrap().pop_front() {
                    return Some(id);
                }
                for off in 1..self.nworkers {
                    let v = (me + off) % self.nworkers;
                    if let Some(id) = self.queues[v].lock().unwrap().pop_front() {
                        return Some(id);
                    }
                }
                None
            }
        }
    }
}

/// Execute `graph` on `nworkers` threads under `policy`; returns the merged
/// execution profile (wall time + per-task records).
pub fn run(graph: &mut TaskGraph, nworkers: usize, policy: Policy) -> Profile {
    let n = graph.tasks.len();
    let mut prof = Profile::new(nworkers.max(1));
    if n == 0 {
        return prof;
    }
    if nworkers <= 1 {
        let t0 = Instant::now();
        let mut p = graph.run_serial();
        p.wall = t0.elapsed();
        p.nworkers = 1;
        return p;
    }

    // Take closures + build executable metadata.
    let mut runs: Vec<Option<Box<dyn FnOnce() + Send>>> = Vec::with_capacity(n);
    let mut preds: Vec<AtomicUsize> = Vec::with_capacity(n);
    for t in graph.tasks.iter_mut() {
        runs.push(t.run.take());
        preds.push(AtomicUsize::new(t.npred));
    }
    let kinds: Vec<_> = graph.tasks.iter().map(|t| (t.kind, t.bytes)).collect();
    let succs: Vec<&[usize]> = graph.tasks.iter().map(|t| t.succs.as_slice()).collect();
    // Cells the workers will take closures out of.  Mutex<Option<..>> keeps
    // this fully safe; the lock is uncontended (each task taken once).
    let cells: Vec<Mutex<Option<Box<dyn FnOnce() + Send>>>> =
        runs.into_iter().map(Mutex::new).collect();

    let nslots = match policy {
        Policy::Eager | Policy::Prio => 1,
        _ => nworkers,
    };
    let shared = Shared {
        queues: (0..nslots.max(nworkers)).map(|_| Mutex::new(VecDeque::new())).collect(),
        heap: Mutex::new(BinaryHeap::new()),
        cv: Condvar::new(),
        cv_guard: Mutex::new(()),
        remaining: AtomicUsize::new(n),
        policy,
        nworkers,
        rng_state: AtomicUsize::new(0x5DEECE66),
    };

    // Seed initial ready set.
    for id in 0..n {
        if preds[id].load(Ordering::Relaxed) == 0 {
            shared.push(id, kinds[id].0.priority, id % nworkers);
        }
    }

    let t0 = Instant::now();
    let profiles: Vec<Profile> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..nworkers {
            let shared = &shared;
            let preds = &preds;
            let kinds = &kinds;
            let succs = &succs;
            let cells = &cells;
            handles.push(scope.spawn(move || {
                let mut local = Profile::new(1);
                loop {
                    if shared.remaining.load(Ordering::Acquire) == 0 {
                        break;
                    }
                    let Some(id) = shared.pop(w) else {
                        // Sleep until new work or completion.
                        let g = shared.cv_guard.lock().unwrap();
                        if shared.remaining.load(Ordering::Acquire) == 0 {
                            break;
                        }
                        let _ = shared
                            .cv
                            .wait_timeout(g, std::time::Duration::from_micros(200))
                            .unwrap();
                        continue;
                    };
                    let run = cells[id].lock().unwrap().take();
                    let ts = Instant::now();
                    if let Some(f) = run {
                        f();
                    }
                    local.record(w, kinds[id].0, ts.elapsed(), kinds[id].1);
                    // Release successors.
                    for &s in succs[id] {
                        if preds[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                            shared.push(s, kinds[s].0.priority, w);
                        }
                    }
                    if shared.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        // last task: wake all sleepers so they exit
                        let _g = shared.cv_guard.lock().unwrap();
                        shared.cv.notify_all();
                    }
                }
                local
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for p in profiles {
        prof.merge(p);
    }
    prof.wall = t0.elapsed();
    prof
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Access, TaskKind};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn all_policies() -> [Policy; 4] {
        [Policy::Eager, Policy::Prio, Policy::Lws, Policy::Random]
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("eager").unwrap(), Policy::Eager);
        assert_eq!(Policy::parse("lws").unwrap(), Policy::Lws);
        assert!(Policy::parse("bogus").is_err());
    }

    #[test]
    fn runs_all_tasks_every_policy() {
        for policy in all_policies() {
            let mut g = TaskGraph::new();
            let hs = g.register_many(16);
            let counter = Arc::new(AtomicUsize::new(0));
            for i in 0..200 {
                let c = counter.clone();
                g.submit(
                    TaskKind::GEMM,
                    &[(hs[i % 16], Access::RW)],
                    0,
                    move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    },
                );
            }
            let prof = run(&mut g, 4, policy);
            assert_eq!(counter.load(Ordering::SeqCst), 200, "{policy:?}");
            assert_eq!(prof.total_tasks(), 200, "{policy:?}");
        }
    }

    #[test]
    fn dependency_order_respected_under_parallelism() {
        // Chain per handle: completion stamps must be increasing.
        for policy in all_policies() {
            let mut g = TaskGraph::new();
            let hs = g.register_many(8);
            let clock = Arc::new(AtomicUsize::new(0));
            let stamps = Arc::new(Mutex::new(vec![Vec::new(); 8]));
            for round in 0..20 {
                for (hi, &h) in hs.iter().enumerate() {
                    let clock = clock.clone();
                    let stamps = stamps.clone();
                    g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                        let t = clock.fetch_add(1, Ordering::SeqCst);
                        stamps.lock().unwrap()[hi].push((round, t));
                    });
                }
            }
            run(&mut g, 4, policy);
            let stamps = stamps.lock().unwrap();
            for chain in stamps.iter() {
                assert_eq!(chain.len(), 20);
                for w in chain.windows(2) {
                    assert!(w[0].0 < w[1].0, "{policy:?}: round order");
                    assert!(w[0].1 < w[1].1, "{policy:?}: time order");
                }
            }
        }
    }

    #[test]
    fn single_worker_falls_back_to_serial() {
        let mut g = TaskGraph::new();
        let h = g.register();
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..5 {
            let c = c.clone();
            g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let prof = run(&mut g, 1, Policy::Lws);
        assert_eq!(c.load(Ordering::SeqCst), 5);
        assert_eq!(prof.nworkers, 1);
    }

    #[test]
    fn parallel_speedup_on_independent_work() {
        // Coarse sanity: 4 workers should beat 1 worker on embarrassingly
        // parallel CPU-bound tasks.  Generous threshold to avoid flakes.
        let build = || {
            let mut g = TaskGraph::new();
            let hs = g.register_many(64);
            for &h in &hs {
                g.submit(TaskKind::GEMM, &[(h, Access::RW)], 0, move || {
                    // ~1 ms of real work the optimizer cannot elide
                    let mut acc = std::hint::black_box(1.0f64);
                    for _ in 0..400_000 {
                        acc = std::hint::black_box(acc + acc.sqrt() * 1e-9);
                    }
                    std::hint::black_box(acc);
                });
            }
            g
        };
        let mut g1 = build();
        let t1 = run(&mut g1, 1, Policy::Lws).wall;
        let mut g4 = build();
        let t4 = run(&mut g4, 4, Policy::Lws).wall;
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        if cores >= 4 {
            assert!(
                t4.as_secs_f64() < 0.8 * t1.as_secs_f64(),
                "1w {t1:?} vs 4w {t4:?}"
            );
        } else {
            // Single-core testbed (see DESIGN.md "Hardware adaptation"):
            // we can only assert the pool does not pathologically slow down.
            assert!(
                t4.as_secs_f64() < 2.0 * t1.as_secs_f64(),
                "1w {t1:?} vs 4w {t4:?}"
            );
        }
    }
}
