//! Discrete-event simulator: replays a *real* task graph on *modeled*
//! hardware.
//!
//! The paper evaluates on 8× NVIDIA K80 GPUs (Fig 6) and the Shaheen II
//! Cray XC40 (Fig 7).  Neither exists on this testbed, so — per the
//! substitution rule in DESIGN.md — we keep the task graph and the measured
//! per-kind CPU cost model real, and simulate only the hardware: resource
//! speed factors (GPU ≫ CPU for gemm-class tasks), memory domains, and a
//! latency/bandwidth transfer model.  Scheduling is greedy
//! earliest-finish-time (EFT) list scheduling, which is what StarPU's
//! `dmda`-class schedulers approximate with their cost models.

use super::placement::{est_cost, WorkerClass};
use super::profile::{ClassCostModel, CostModel};
use super::{topo_order, Handle, TaskGraph};
use crate::pipeline::execution_plan::ExecutionPlan;
use crate::pipeline::shard::ShardGrid;
use std::sync::Arc;

/// A simulated execution resource (one CPU core, one GPU stream, ...).
#[derive(Copy, Clone, Debug)]
pub struct Resource {
    /// Task-time divisor relative to the measured CPU cost model.
    pub speed: f64,
    /// Memory domain (node id or CPU/GPU space); transfers between
    /// different domains pay the communication cost.
    pub domain: usize,
}

/// Latency/bandwidth communication model between memory domains.
#[derive(Copy, Clone, Debug)]
pub struct CommModel {
    /// Per-message latency in seconds.
    pub latency: f64,
    /// Bandwidth in bytes/second.
    pub bandwidth: f64,
}

impl CommModel {
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }
    /// No-op comms (shared memory).
    pub fn zero() -> Self {
        CommModel {
            latency: 0.0,
            bandwidth: f64::INFINITY,
        }
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Makespan in seconds.
    pub makespan: f64,
    /// Per-resource busy seconds.
    pub busy: Vec<f64>,
    /// Total bytes moved between domains.
    pub bytes_moved: f64,
}

impl SimResult {
    pub fn efficiency(&self) -> f64 {
        let total_busy: f64 = self.busy.iter().sum();
        total_busy / (self.makespan * self.busy.len() as f64)
    }
}

/// Simulate `graph` on `resources` with greedy EFT list scheduling.
///
/// `owner`: optional placement constraint mapping a task's output handle to
/// a required domain (2-D block-cyclic tile ownership in the distributed
/// study); unconstrained tasks may run anywhere.
pub fn simulate(
    graph: &TaskGraph,
    cost: &CostModel,
    resources: &[Resource],
    comm: &CommModel,
    owner: Option<&dyn Fn(super::Handle) -> usize>,
) -> SimResult {
    assert!(!resources.is_empty());
    let n = graph.tasks.len();
    let order = topo_order(graph);
    // Per-task: (finish time, domain it ran in).
    let mut finish = vec![0.0f64; n];
    let mut domain = vec![0usize; n];
    let mut free_at = vec![0.0f64; resources.len()];
    let mut busy = vec![0.0f64; resources.len()];
    let mut bytes_moved = 0.0f64;

    // Predecessor lists (invert succs once).
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, t) in graph.tasks.iter().enumerate() {
        for &s in &t.succs {
            preds[s].push(id);
        }
    }

    for &id in &order {
        let t = &graph.tasks[id];
        let required_domain = owner.and_then(|f| t.out_handle.map(f));
        // Choose the resource with the earliest finish time.
        let mut best: Option<(f64, usize, f64)> = None; // (finish, res, comm_bytes)
        for (r, res) in resources.iter().enumerate() {
            if let Some(dom) = required_domain {
                if res.domain != dom {
                    continue;
                }
            }
            // Ready time on this resource: preds' finishes + transfer if
            // the pred ran in another domain.
            let mut ready = 0.0f64;
            let mut xfer_bytes = 0.0f64;
            for &p in &preds[id] {
                let mut avail = finish[p];
                if domain[p] != res.domain {
                    let b = graph.tasks[p].bytes.max(1);
                    avail += comm.transfer_time(b);
                    xfer_bytes += b as f64;
                }
                ready = ready.max(avail);
            }
            let start = ready.max(free_at[r]);
            let dur = cost.cost(t.kind) / res.speed;
            let fin = start + dur;
            if best.map_or(true, |(bf, _, _)| fin < bf) {
                best = Some((fin, r, xfer_bytes));
            }
        }
        let (fin, r, xfer) = best.expect("placement constraint matched no resource");
        let dur = cost.cost(t.kind) / resources[r].speed;
        finish[id] = fin;
        domain[id] = resources[r].domain;
        free_at[r] = fin;
        busy[r] += dur;
        bytes_moved += xfer;
    }

    SimResult {
        makespan: finish.iter().cloned().fold(0.0, f64::max),
        busy,
        bytes_moved,
    }
}

/// Heterogeneous projection: replay a **placed** [`ExecutionPlan`] on a
/// simulated machine with the same worker-class layout the live runtime
/// has, constraining every task to the class the
/// [`super::placement::Placer`] assigned — the exact constraint the
/// class queues enforce.  Task durations come from the measured
/// per-(kind, class) cost model with the same static-factor fallback the
/// placer uses ([`est_cost`]), so projected and measured makespans are
/// directly comparable (the placement bench records their ratio).
///
/// `classes` is `(class, worker count)` in range order (e.g. from
/// `Runtime::classes()`); unplaced tasks run on the `Cpu` class (or
/// class 0 when none exists).  Shared memory — no transfer model.
pub fn simulate_placed(
    plan: &ExecutionPlan,
    cost: &ClassCostModel,
    classes: &[(WorkerClass, usize)],
) -> SimResult {
    let live: Vec<(WorkerClass, usize)> = classes.iter().copied().filter(|c| c.1 > 0).collect();
    assert!(!live.is_empty(), "simulate_placed needs at least one class");
    let default_class = live
        .iter()
        .position(|c| c.0 == WorkerClass::Cpu)
        .unwrap_or(0);
    // One simulated lane per worker; lane ranges tile classes in order.
    let starts: Vec<usize> = live
        .iter()
        .scan(0usize, |acc, c| {
            let s = *acc;
            *acc += c.1;
            Some(s)
        })
        .collect();
    let nlanes: usize = live.iter().map(|c| c.1).sum();
    let mut free_at = vec![0.0f64; nlanes];
    let mut busy = vec![0.0f64; nlanes];
    let mut finish = vec![0.0f64; plan.tasks.len()];
    for (id, t) in plan.tasks.iter().enumerate() {
        let ci = t
            .class
            .and_then(|c| live.iter().position(|e| e.0 == c))
            .unwrap_or(default_class);
        let dur = est_cost(cost, t.kind, t.bytes, live[ci].0);
        let ready = t.preds.iter().map(|&p| finish[p]).fold(0.0f64, f64::max);
        // Earliest-finish lane within the assigned class only.
        let lanes = starts[ci]..starts[ci] + live[ci].1;
        let lane = lanes
            .clone()
            .min_by(|&a, &b| free_at[a].total_cmp(&free_at[b]))
            .expect("class has workers");
        let fin = ready.max(free_at[lane]) + dur;
        finish[id] = fin;
        free_at[lane] = fin;
        busy[lane] += dur;
    }
    SimResult {
        makespan: finish.iter().cloned().fold(0.0, f64::max),
        busy,
        bytes_moved: 0.0,
    }
}

/// Convenience: a homogeneous shared-memory machine with `ncores` cores.
pub fn cpu_machine(ncores: usize) -> Vec<Resource> {
    (0..ncores)
        .map(|_| Resource {
            speed: 1.0,
            domain: 0,
        })
        .collect()
}

/// A CPU + GPU machine: `ncpu` cores (domain 0) plus `ngpu` accelerators
/// (domain 1..) with `gpu_speed`× per-task throughput — mirrors the
/// Intel Broadwell + K80 testbed of Example 3.
pub fn gpu_machine(ncpu: usize, ngpu: usize, gpu_speed: f64) -> Vec<Resource> {
    let mut r = cpu_machine(ncpu);
    for g in 0..ngpu {
        r.push(Resource {
            speed: gpu_speed,
            domain: 1 + g,
        });
    }
    r
}

/// The 2-D block-cyclic placement constraint of the distributed study:
/// handle `h` (whose tile coordinate is `coords[h.0]`) is owned by
/// domain `grid.owner_of(i, j)`.
///
/// This is the *same* [`ShardGrid`] the sharding pass
/// (`pipeline::shard`) and `TiledSpec::owner` use, so the DES model,
/// the IR lowering, and the live sharded executor cannot drift apart.
/// Handles outside `coords` (scalars, segments) are unconstrained
/// tiles at (0, 0).
pub fn block_cyclic_owner(
    grid: ShardGrid,
    coords: Arc<Vec<(usize, usize)>>,
) -> impl Fn(Handle) -> usize {
    move |h: Handle| {
        let (i, j) = coords.get(h.0).copied().unwrap_or((0, 0));
        grid.owner_of(i, j)
    }
}

/// A `p x q` node grid with `ncores` per node — mirrors the Shaheen II
/// runs of Example 4 (each node is one memory domain).
pub fn cluster_machine(p: usize, q: usize, ncores: usize) -> Vec<Resource> {
    let mut r = Vec::new();
    for node in 0..p * q {
        for _ in 0..ncores {
            r.push(Resource {
                speed: 1.0,
                domain: node,
            });
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Access, TaskGraph, TaskKind};

    /// Build a graph of `chains` independent chains of length `len`,
    /// with every task 1 KB.
    fn chain_graph(chains: usize, len: usize) -> TaskGraph {
        let mut g = TaskGraph::new();
        for _ in 0..chains {
            let h = g.register();
            for _ in 0..len {
                g.submit(TaskKind::GEMM, &[(h, Access::RW)], 1024, || {});
            }
        }
        g
    }

    fn unit_cost() -> CostModel {
        let mut cm = CostModel::default();
        cm.mean_secs.insert("gemm", 1.0);
        cm
    }

    #[test]
    fn serial_chain_is_sum_of_costs() {
        let g = chain_graph(1, 10);
        let r = simulate(&g, &unit_cost(), &cpu_machine(4), &CommModel::zero(), None);
        assert!((r.makespan - 10.0).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    fn independent_chains_scale_with_cores() {
        let g = chain_graph(4, 5);
        let r1 = simulate(&g, &unit_cost(), &cpu_machine(1), &CommModel::zero(), None);
        let r4 = simulate(&g, &unit_cost(), &cpu_machine(4), &CommModel::zero(), None);
        assert!((r1.makespan - 20.0).abs() < 1e-9);
        assert!((r4.makespan - 5.0).abs() < 1e-9);
        assert!(r4.efficiency() > 0.99);
    }

    #[test]
    fn faster_resource_attracts_work() {
        let g = chain_graph(1, 4);
        let machine = gpu_machine(1, 1, 10.0);
        let r = simulate(&g, &unit_cost(), &machine, &CommModel::zero(), None);
        // all 4 tasks on the 10x GPU: makespan 0.4
        assert!((r.makespan - 0.4).abs() < 1e-9, "{}", r.makespan);
        assert!(r.busy[0] < 1e-12 && r.busy[1] > 0.39);
    }

    #[test]
    fn transfer_cost_discourages_migration() {
        // One chain; moving between domains costs 10s per hop, so EFT
        // keeps the chain on one resource even if another is idle.
        let g = chain_graph(1, 6);
        let machine = vec![
            Resource { speed: 1.0, domain: 0 },
            Resource { speed: 1.0, domain: 1 },
        ];
        let comm = CommModel {
            latency: 10.0,
            bandwidth: 1e9,
        };
        let r = simulate(&g, &unit_cost(), &machine, &comm, None);
        assert!((r.makespan - 6.0).abs() < 1e-9, "{}", r.makespan);
        assert_eq!(r.bytes_moved, 0.0);
    }

    #[test]
    fn ownership_constraint_respected() {
        let mut g = TaskGraph::new();
        let h0 = g.register();
        let h1 = g.register();
        g.submit(TaskKind::GEMM, &[(h0, Access::RW)], 1024, || {});
        g.submit(TaskKind::GEMM, &[(h1, Access::RW)], 1024, || {});
        let machine = cluster_machine(1, 2, 1); // 2 nodes, 1 core each
        // handle 0 is tile (0,0) -> node 0, handle 1 is tile (1,0) -> node 1
        // on a 2x1 grid (the shared block-cyclic implementation).
        let owner = block_cyclic_owner(ShardGrid::new(2, 1), Arc::new(vec![(0, 0), (1, 0)]));
        let r = simulate(
            &g,
            &unit_cost(),
            &machine,
            &CommModel {
                latency: 1.0,
                bandwidth: 1e6,
            },
            Some(&owner),
        );
        // both tasks run in parallel on their owner nodes
        assert!((r.makespan - 1.0).abs() < 1e-9);
        assert!(r.busy[0] > 0.9 && r.busy[1] > 0.9);
    }

    #[test]
    fn block_cyclic_owner_matches_grid_formula() {
        let grid = ShardGrid::new(2, 3);
        let coords: Vec<(usize, usize)> =
            (0..5).flat_map(|i| (0..5).map(move |j| (i, j))).collect();
        let f = block_cyclic_owner(grid, Arc::new(coords.clone()));
        for (h, &(i, j)) in coords.iter().enumerate() {
            assert_eq!(f(Handle(h)), (i % 2) * 3 + (j % 3));
        }
        // Out-of-range handles (scalars/segments) default to tile (0,0).
        assert_eq!(f(Handle(coords.len() + 7)), 0);
    }

    #[test]
    fn placed_projection_respects_class_constraint() {
        use crate::pipeline::execution_plan::{ExecutionPlan, PlanTask};
        let mk = |kind, class, preds: Vec<usize>| PlanTask {
            ops: Vec::new(),
            kind,
            bytes: 1 << 20,
            preds,
            class,
        };
        let mut cm = ClassCostModel::default();
        cm.record(TaskKind::GEMM, WorkerClass::Cpu, 1.0);
        cm.record(TaskKind::GEMM, WorkerClass::Slow, 4.0);
        let layout = [(WorkerClass::Cpu, 1), (WorkerClass::Slow, 1)];
        // Two independent gemms pinned Cpu serialize on the one cpu lane
        // while the slow-pinned one runs in parallel at 4x cost.
        let plan = ExecutionPlan {
            tasks: vec![
                mk(TaskKind::GEMM, Some(WorkerClass::Cpu), vec![]),
                mk(TaskKind::GEMM, Some(WorkerClass::Cpu), vec![]),
                mk(TaskKind::GEMM, Some(WorkerClass::Slow), vec![]),
            ],
        };
        let r = simulate_placed(&plan, &cm, &layout);
        assert!((r.makespan - 4.0).abs() < 1e-9, "{}", r.makespan);
        assert!((r.busy[0] - 2.0).abs() < 1e-9 && (r.busy[1] - 4.0).abs() < 1e-9);
        // Dependence edges delay the successor even across classes.
        let plan = ExecutionPlan {
            tasks: vec![
                mk(TaskKind::GEMM, Some(WorkerClass::Cpu), vec![]),
                mk(TaskKind::GEMM, Some(WorkerClass::Slow), vec![0]),
            ],
        };
        let r = simulate_placed(&plan, &cm, &layout);
        assert!((r.makespan - 5.0).abs() < 1e-9, "{}", r.makespan);
        // Unplaced tasks default to the Cpu class wherever it is listed.
        let plan = ExecutionPlan {
            tasks: vec![mk(TaskKind::GEMM, None, vec![])],
        };
        let r = simulate_placed(&plan, &cm, &[(WorkerClass::Slow, 1), (WorkerClass::Cpu, 1)]);
        assert!((r.makespan - 1.0).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    fn comm_model_transfer_time() {
        let c = CommModel {
            latency: 1e-3,
            bandwidth: 1e9,
        };
        assert!((c.transfer_time(1_000_000) - (1e-3 + 1e-3)).abs() < 1e-12);
    }
}
