//! Heterogeneous worker classes + cost-model-driven task placement
//! (DESIGN.md §2i).
//!
//! The paper's large-scale results come from StarPU placing each tiled-
//! Cholesky task on the worker *class* best suited to it (CPU cores vs GPU
//! streams, §Performance / arxiv 1708.02835).  This module is that policy
//! layer for our runtime:
//!
//! * [`WorkerClass`] — the class enum (`Cpu`, `Accel`, plus a throttled
//!   `Slow` simulation class that validates placement without hardware).
//! * [`ClassSpec`] — an ordered `class:count` layout, parsed from
//!   `EXAGEOSTAT_WORKER_CLASSES=cpu:6,slow:2` (env) or `--worker-classes`
//!   (CLI), and scaled to a runtime's core count with [`ClassSpec::fit`].
//! * [`eligible`] — static eligibility: DCMG generation and off-diagonal
//!   GEMM/SYRK may run on `Accel`/`Slow`; POTRF, TRSM, reductions, solves
//!   and small tiles are pinned to `Cpu`.
//! * [`Placer`] — HEFT-style earliest-finish placement over an
//!   [`ExecutionPlan`], using measured per-(kind, class) cost means from
//!   [`profile::ClassCostModel`] when available and static class speed
//!   factors otherwise.
//!
//! The default configuration is a single all-`Cpu` class, which degenerates
//! to exactly the homogeneous scheduling the runtime had before classes
//! existed — same queue indices, same steal order, bit-for-bit.

use std::sync::{Mutex, MutexGuard, OnceLock};

use super::profile::ClassCostModel;
use super::TaskKind;
use crate::pipeline::execution_plan::ExecutionPlan;

/// A worker class.  Every runtime worker belongs to exactly one class;
/// queues and work-stealing are confined within a class.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum WorkerClass {
    /// General-purpose CPU core: eligible for every task kind.
    Cpu,
    /// Accelerator lane (the PJRT backend seam): eligible for DCMG
    /// generation and off-diagonal GEMM/SYRK only.
    Accel,
    /// Simulated slow worker (`EXAGEOSTAT_SLOW_FACTOR`x throttle): same
    /// eligibility as `Accel`, used to validate placement policy without
    /// accelerator hardware.
    Slow,
}

impl WorkerClass {
    pub const ALL: [WorkerClass; 3] = [WorkerClass::Cpu, WorkerClass::Accel, WorkerClass::Slow];

    pub fn name(self) -> &'static str {
        match self {
            WorkerClass::Cpu => "cpu",
            WorkerClass::Accel => "accel",
            WorkerClass::Slow => "slow",
        }
    }

    pub fn parse(s: &str) -> Option<WorkerClass> {
        match s.trim().to_ascii_lowercase().as_str() {
            "cpu" => Some(WorkerClass::Cpu),
            "accel" | "gpu" => Some(WorkerClass::Accel),
            "slow" => Some(WorkerClass::Slow),
            _ => None,
        }
    }

    /// Static relative execution-time factor (1.0 = CPU) used by the
    /// placer and the DES projection when no measured cost exists.
    pub fn static_factor(self) -> f64 {
        match self {
            WorkerClass::Cpu => 1.0,
            WorkerClass::Accel => 0.5,
            WorkerClass::Slow => slow_factor(),
        }
    }
}

/// Can `kind` run on `class`?  `Cpu` runs everything; non-CPU classes take
/// only the kinds the paper offloads: covariance generation and the
/// off-diagonal BLAS3 updates.  POTRF (critical path), TRSM, reductions
/// and triangular solves stay on CPU.
pub fn eligible(kind: TaskKind, class: WorkerClass) -> bool {
    match class {
        WorkerClass::Cpu => true,
        WorkerClass::Accel | WorkerClass::Slow => {
            matches!(kind.name, "dcmg" | "gemm" | "syrk" | "lr_gemm" | "lr_syrk")
        }
    }
}

/// Tasks touching fewer bytes than this stay on `Cpu` regardless of
/// eligibility: offload latency dominates for small tiles.
pub const SMALL_TILE_BYTES: usize = 16 * 1024;

/// Throttle factor for the `Slow` class (relative task duration).
/// `EXAGEOSTAT_SLOW_FACTOR` overrides; default 4.0.
pub fn slow_factor() -> f64 {
    static F: OnceLock<f64> = OnceLock::new();
    *F.get_or_init(|| {
        std::env::var("EXAGEOSTAT_SLOW_FACTOR")
            .ok()
            .and_then(|v| v.trim().parse::<f64>().ok())
            .filter(|f| f.is_finite() && *f >= 1.0)
            .unwrap_or(4.0)
    })
}

/// An ordered worker-class layout: `(class, worker count)` entries in
/// declaration order.  Order matters — class 0 hosts tasks with no class
/// annotation (unless a `Cpu` class exists, which always wins the
/// default), so list `cpu` first.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClassSpec {
    pub classes: Vec<(WorkerClass, usize)>,
}

impl ClassSpec {
    /// The pre-heterogeneity layout: all workers in one `Cpu` class.
    pub fn homogeneous(nworkers: usize) -> ClassSpec {
        ClassSpec {
            classes: vec![(WorkerClass::Cpu, nworkers.max(1))],
        }
    }

    /// Parse `"cpu:6,slow:2"`.  Duplicate class names merge; counts of 0
    /// are kept (and later dropped by [`fit`](Self::fit)).  Returns `None`
    /// on any malformed entry or an all-zero total.
    pub fn parse(s: &str) -> Option<ClassSpec> {
        let mut classes: Vec<(WorkerClass, usize)> = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, count) = match part.split_once(':') {
                Some((n, c)) => (WorkerClass::parse(n)?, c.trim().parse::<usize>().ok()?),
                // bare "cpu" means one worker of that class
                None => (WorkerClass::parse(part)?, 1),
            };
            match classes.iter_mut().find(|e| e.0 == name) {
                Some(e) => e.1 += count,
                None => classes.push((name, count)),
            }
        }
        if classes.iter().map(|e| e.1).sum::<usize>() == 0 {
            return None;
        }
        Some(ClassSpec { classes })
    }

    pub fn total(&self) -> usize {
        self.classes.iter().map(|e| e.1).sum()
    }

    /// Number of non-empty classes.
    pub fn nclasses(&self) -> usize {
        self.classes.iter().filter(|e| e.1 > 0).count()
    }

    pub fn is_homogeneous_cpu(&self) -> bool {
        self.nclasses() == 1
            && self
                .classes
                .iter()
                .all(|e| e.1 == 0 || e.0 == WorkerClass::Cpu)
    }

    /// Scale the spec proportionally so the total worker count is exactly
    /// `ncores` (largest-remainder apportionment; ties go to the
    /// earlier-listed class).  This keeps thread counts identical to the
    /// homogeneous runtime no matter what ratio the spec declares —
    /// `cpu:1,slow:1` on 3 cores becomes `cpu:2,slow:1`.  Classes scaled
    /// to 0 workers are dropped.
    pub fn fit(&self, ncores: usize) -> ClassSpec {
        let ncores = ncores.max(1);
        let total = self.total();
        if total == 0 {
            return ClassSpec::homogeneous(ncores);
        }
        let mut out: Vec<(WorkerClass, usize)> = Vec::with_capacity(self.classes.len());
        let mut rems: Vec<(usize, usize)> = Vec::new(); // (remainder, index)
        let mut assigned = 0usize;
        for (i, &(class, count)) in self.classes.iter().enumerate() {
            let share = ncores * count;
            out.push((class, share / total));
            assigned += share / total;
            rems.push((share % total, i));
        }
        // Hand the leftover seats to the largest remainders, earlier
        // classes first on ties.
        rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut leftover = ncores - assigned;
        for &(_, i) in &rems {
            if leftover == 0 {
                break;
            }
            out[i].1 += 1;
            leftover -= 1;
        }
        out.retain(|e| e.1 > 0);
        ClassSpec { classes: out }
    }
}

static CLASS_OVERRIDE: Mutex<Option<ClassSpec>> = Mutex::new(None);
static CLASS_ENV: OnceLock<Option<ClassSpec>> = OnceLock::new();

/// Process-wide class-spec override (CLI `--worker-classes`, tests).
/// `Some(spec)` wins over the environment; `None` restores env/default
/// resolution.  Pass `ClassSpec::parse("cpu:1")` to force the homogeneous
/// layout regardless of `EXAGEOSTAT_WORKER_CLASSES` (a single-entry spec
/// fits to all-CPU at any core count).
pub fn set_class_override(spec: Option<ClassSpec>) {
    *CLASS_OVERRIDE.lock().unwrap() = spec;
}

/// Tests mutating the override (or relying on its absence) serialize on
/// this lock — the override is process-global and `cargo test` runs tests
/// concurrently.
#[doc(hidden)]
pub fn class_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Resolve the worker-class layout for a runtime of `ncores` workers:
/// override > `EXAGEOSTAT_WORKER_CLASSES` > homogeneous all-`Cpu`.
/// Always fitted so the total is exactly `ncores`.
pub fn class_spec_for(ncores: usize) -> ClassSpec {
    if let Some(spec) = CLASS_OVERRIDE.lock().unwrap().clone() {
        return spec.fit(ncores);
    }
    let env = CLASS_ENV.get_or_init(|| {
        let raw = std::env::var("EXAGEOSTAT_WORKER_CLASSES").ok()?;
        match ClassSpec::parse(&raw) {
            Some(s) => Some(s),
            None => {
                eprintln!(
                    "exageostat: ignoring malformed EXAGEOSTAT_WORKER_CLASSES={:?} \
                     (expected e.g. \"cpu:6,slow:2\")",
                    raw
                );
                None
            }
        }
    });
    match env {
        Some(spec) => spec.fit(ncores),
        None => ClassSpec::homogeneous(ncores),
    }
}

// ---------------------------------------------------------------------------
// Class quarantine (DESIGN.md §2j).  Process-global per-class failure
// counters: the runtime worker loop notes each caught task panic against
// the class whose worker it ran on, and once a non-CPU class exceeds the
// threshold the placer stops routing work there — its tasks fall back to
// `Cpu`, the graceful-degradation path a flaky accelerator needs.  `Cpu`
// is never quarantined: it is the fallback.

use std::sync::atomic::{AtomicU64, Ordering};

static CLASS_FAILURES: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

/// Quarantine-threshold override for tests (`u64::MAX` = use the env).
static QUARANTINE_OVERRIDE: AtomicU64 = AtomicU64::new(u64::MAX);

fn class_slot(class: WorkerClass) -> usize {
    WorkerClass::ALL.iter().position(|&c| c == class).unwrap()
}

/// Record one task failure against `class` (called by the runtime's
/// worker loop on every caught task panic).
pub fn note_class_failure(class: WorkerClass) {
    CLASS_FAILURES[class_slot(class)].fetch_add(1, Ordering::Relaxed);
}

/// Task failures recorded against `class` since process start (or the
/// last [`reset_class_failures`]).
pub fn class_failures(class: WorkerClass) -> u64 {
    CLASS_FAILURES[class_slot(class)].load(Ordering::Relaxed)
}

/// Zero all per-class failure counters (tests; serialize on
/// [`class_test_lock`]).
pub fn reset_class_failures() {
    for c in &CLASS_FAILURES {
        c.store(0, Ordering::Relaxed);
    }
}

/// Failure count at which a non-CPU class is quarantined.
/// `EXAGEOSTAT_QUARANTINE_AFTER` overrides; default 16; 0 disables
/// quarantine entirely.
pub fn quarantine_threshold() -> u64 {
    let ov = QUARANTINE_OVERRIDE.load(Ordering::SeqCst);
    if ov != u64::MAX {
        return ov;
    }
    static ENV: OnceLock<u64> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("EXAGEOSTAT_QUARANTINE_AFTER")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(16)
    })
}

/// Force (`Some(n)`) or clear (`None`) the quarantine threshold for
/// tests, ignoring the environment.  Serialize on [`class_test_lock`].
pub fn set_quarantine_override(n: Option<u64>) {
    QUARANTINE_OVERRIDE.store(n.unwrap_or(u64::MAX), Ordering::SeqCst);
}

/// Is `class` currently quarantined?  `Cpu` never is (it is the
/// fallback target); other classes are once their failure count
/// reaches the threshold (and the threshold is nonzero).
pub fn is_quarantined(class: WorkerClass) -> bool {
    if class == WorkerClass::Cpu {
        return false;
    }
    let thr = quarantine_threshold();
    thr > 0 && class_failures(class) >= thr
}

/// Per-class runtime counters (satellite of `CoordinatorStats`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClassStat {
    pub class: WorkerClass,
    pub workers: usize,
    /// Tasks routed to this class's queues at push time.
    pub tasks_placed: u64,
    /// Tasks executed by this class's workers.
    pub tasks_executed: u64,
    /// Intra-class steals (lws/random victim pops).
    pub steals: u64,
}

/// Estimated execution time of `kind` on `class`, in seconds.  Prefers the
/// measured per-(kind, class) mean; falls back to scaling a measured CPU
/// mean by the class's static factor; last resort is a bytes-proportional
/// synthetic cost so relative placement still reflects task size.
pub fn est_cost(cost: &ClassCostModel, kind: TaskKind, bytes: usize, class: WorkerClass) -> f64 {
    if let Some(m) = cost.mean(kind, class) {
        return m;
    }
    if let Some(m) = cost.mean(kind, WorkerClass::Cpu) {
        return m * class.static_factor();
    }
    (bytes.max(1) as f64) * 1e-9 * class.static_factor()
}

/// HEFT-style placer: walks an [`ExecutionPlan`] in (topological) task
/// order and annotates each task with the eligible class giving the
/// earliest estimated finish, modeling each class as `workers` parallel
/// lanes with an aggregate load.
pub struct Placer {
    classes: Vec<(WorkerClass, usize)>,
    cost: ClassCostModel,
    small_tile_bytes: usize,
}

impl Placer {
    /// `classes` is the runtime's live layout (non-empty counts), e.g.
    /// from `Runtime::classes()`.
    pub fn new(classes: &[(WorkerClass, usize)]) -> Placer {
        Placer {
            classes: classes.iter().copied().filter(|e| e.1 > 0).collect(),
            cost: ClassCostModel::default(),
            small_tile_bytes: SMALL_TILE_BYTES,
        }
    }

    /// Feed measured per-(kind, class) costs (e.g.
    /// `Runtime::cost_model_by_class()`); without this the placer uses
    /// static eligibility + class speed factors only.
    pub fn with_cost(mut self, cost: ClassCostModel) -> Placer {
        self.cost = cost;
        self
    }

    #[allow(dead_code)]
    pub fn small_tile_bytes(mut self, bytes: usize) -> Placer {
        self.small_tile_bytes = bytes;
        self
    }

    fn class_eligible(&self, kind: TaskKind, bytes: usize, class: WorkerClass) -> bool {
        if class != WorkerClass::Cpu && bytes < self.small_tile_bytes {
            return false;
        }
        if is_quarantined(class) {
            return false;
        }
        eligible(kind, class)
    }

    /// Annotate every task in `plan` with a class.  Returns per-class
    /// placement counts (same order as the layout).  With fewer than two
    /// classes this is a no-op: tasks keep `class: None` and the runtime
    /// routes them to its only class, exactly as before.
    pub fn place(&self, plan: &mut ExecutionPlan) -> Vec<(WorkerClass, usize)> {
        let mut counts: Vec<(WorkerClass, usize)> =
            self.classes.iter().map(|&(c, _)| (c, 0)).collect();
        if self.classes.len() < 2 {
            return counts;
        }
        // Aggregate outstanding work per class (seconds of serial work).
        let mut load = vec![0.0f64; self.classes.len()];
        // Estimated finish time per plan task, for predecessor readiness.
        let mut finish: Vec<f64> = Vec::with_capacity(plan.tasks.len());
        for t in plan.tasks.iter_mut() {
            let ready = t
                .preds
                .iter()
                .map(|&p| finish[p])
                .fold(0.0f64, f64::max);
            let mut best: Option<(f64, usize)> = None;
            for (ci, &(class, nw)) in self.classes.iter().enumerate() {
                if !self.class_eligible(t.kind, t.bytes, class) {
                    continue;
                }
                let dur = est_cost(&self.cost, t.kind, t.bytes, class);
                let start = ready.max(load[ci] / nw as f64);
                let fin = start + dur;
                if best.map_or(true, |(bf, _)| fin < bf) {
                    best = Some((fin, ci));
                }
            }
            // Nothing eligible (layout without a Cpu class): place on the
            // least-loaded class so the plan still runs.
            let (fin, ci) = best.unwrap_or_else(|| {
                let mut pick = 0usize;
                for ci in 1..self.classes.len() {
                    if load[ci] < load[pick] {
                        pick = ci;
                    }
                }
                let dur = est_cost(&self.cost, t.kind, t.bytes, self.classes[pick].0);
                (ready.max(load[pick] / self.classes[pick].1 as f64) + dur, pick)
            });
            t.class = Some(self.classes[ci].0);
            load[ci] += est_cost(&self.cost, t.kind, t.bytes, self.classes[ci].0);
            counts[ci].1 += 1;
            finish.push(fin);
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_merge() {
        let s = ClassSpec::parse("cpu:6,slow:2").unwrap();
        assert_eq!(
            s.classes,
            vec![(WorkerClass::Cpu, 6), (WorkerClass::Slow, 2)]
        );
        assert_eq!(s.total(), 8);
        assert_eq!(s.nclasses(), 2);
        // duplicates merge, bare names count 1, gpu aliases accel
        let s = ClassSpec::parse("cpu:2, cpu:1, gpu").unwrap();
        assert_eq!(
            s.classes,
            vec![(WorkerClass::Cpu, 3), (WorkerClass::Accel, 1)]
        );
        assert!(ClassSpec::parse("cpu:x").is_none());
        assert!(ClassSpec::parse("warp:3").is_none());
        assert!(ClassSpec::parse("cpu:0,slow:0").is_none());
    }

    #[test]
    fn fit_preserves_total_and_proportion() {
        let s = ClassSpec::parse("cpu:1,slow:1").unwrap();
        // 3 cores: cpu gets the tie-break seat
        assert_eq!(
            s.fit(3).classes,
            vec![(WorkerClass::Cpu, 2), (WorkerClass::Slow, 1)]
        );
        assert_eq!(s.fit(2).classes, s.classes);
        // 1 core: slow drops out entirely
        assert_eq!(s.fit(1).classes, vec![(WorkerClass::Cpu, 1)]);
        let s = ClassSpec::parse("cpu:6,slow:2").unwrap();
        assert_eq!(
            s.fit(4).classes,
            vec![(WorkerClass::Cpu, 3), (WorkerClass::Slow, 1)]
        );
        for n in 1..=16 {
            assert_eq!(s.fit(n).total(), n, "fit must hit ncores exactly");
        }
        assert!(ClassSpec::homogeneous(4).is_homogeneous_cpu());
        assert!(ClassSpec::parse("cpu:1").unwrap().fit(8).is_homogeneous_cpu());
    }

    #[test]
    fn eligibility_pins_critical_path_to_cpu() {
        for class in [WorkerClass::Accel, WorkerClass::Slow] {
            assert!(!eligible(TaskKind::POTRF, class));
            assert!(!eligible(TaskKind::TRSM, class));
            assert!(!eligible(TaskKind::LOGDET, class));
            assert!(eligible(TaskKind::GEMM, class));
            assert!(eligible(TaskKind::SYRK, class));
            assert!(eligible(TaskKind::DCMG, class));
        }
        for kind in [
            TaskKind::POTRF,
            TaskKind::TRSM,
            TaskKind::GEMM,
            TaskKind::SYRK,
            TaskKind::DCMG,
            TaskKind::OTHER,
        ] {
            assert!(eligible(kind, WorkerClass::Cpu));
        }
    }

    #[test]
    fn override_wins_over_default() {
        let _g = class_test_lock();
        set_class_override(ClassSpec::parse("cpu:1,slow:1"));
        let s = class_spec_for(4);
        assert_eq!(
            s.classes,
            vec![(WorkerClass::Cpu, 2), (WorkerClass::Slow, 2)]
        );
        set_class_override(None);
        // Without the env var, default is homogeneous; with it, the env
        // spec applies — either way the total matches ncores.
        assert_eq!(class_spec_for(4).total(), 4);
    }

    #[test]
    fn quarantined_class_loses_placement_until_reset() {
        let _g = class_test_lock();
        reset_class_failures();
        set_quarantine_override(Some(3));
        assert!(!is_quarantined(WorkerClass::Slow));
        for _ in 0..3 {
            note_class_failure(WorkerClass::Slow);
        }
        assert!(is_quarantined(WorkerClass::Slow));
        assert_eq!(class_failures(WorkerClass::Slow), 3);
        // Cpu is the fallback: it can never be quarantined.
        for _ in 0..10 {
            note_class_failure(WorkerClass::Cpu);
        }
        assert!(!is_quarantined(WorkerClass::Cpu));
        // The placer routes everything to Cpu while Slow is out.
        let classes = [(WorkerClass::Cpu, 2), (WorkerClass::Slow, 2)];
        let placer = Placer::new(&classes);
        let mut plan = ExecutionPlan::default();
        for _ in 0..6 {
            plan.tasks.push(crate::pipeline::execution_plan::PlanTask {
                ops: Vec::new(),
                kind: TaskKind::GEMM,
                bytes: 1 << 20,
                preds: Vec::new(),
                class: None,
            });
        }
        let counts = placer.place(&mut plan);
        assert_eq!(counts, vec![(WorkerClass::Cpu, 6), (WorkerClass::Slow, 0)]);
        assert!(plan.tasks.iter().all(|t| t.class == Some(WorkerClass::Cpu)));
        // Threshold 0 disables quarantine; reset clears the counters.
        set_quarantine_override(Some(0));
        assert!(!is_quarantined(WorkerClass::Slow));
        set_quarantine_override(None);
        reset_class_failures();
        assert_eq!(class_failures(WorkerClass::Slow), 0);
        assert_eq!(class_failures(WorkerClass::Cpu), 0);
    }

    #[test]
    fn est_cost_prefers_measured_then_scales_cpu_mean() {
        let mut cm = ClassCostModel::default();
        cm.record(TaskKind::GEMM, WorkerClass::Cpu, 0.010);
        cm.record(TaskKind::GEMM, WorkerClass::Slow, 0.050);
        assert!((est_cost(&cm, TaskKind::GEMM, 1 << 20, WorkerClass::Slow) - 0.050).abs() < 1e-12);
        // no slow measurement for trsm: cpu mean x static factor
        cm.record(TaskKind::TRSM, WorkerClass::Cpu, 0.008);
        let e = est_cost(&cm, TaskKind::TRSM, 1 << 20, WorkerClass::Slow);
        assert!((e - 0.008 * slow_factor()).abs() < 1e-9);
        // nothing measured: bytes-proportional
        let a = est_cost(&ClassCostModel::default(), TaskKind::SYRK, 1 << 20, WorkerClass::Cpu);
        let b = est_cost(&ClassCostModel::default(), TaskKind::SYRK, 1 << 21, WorkerClass::Cpu);
        assert!(b > a && a > 0.0);
    }
}
