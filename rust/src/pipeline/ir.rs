//! Typed task-graph IR for the tiled likelihood pipelines.
//!
//! Every pipeline (exact / DST / MP / TLR, plus simulation and kriging)
//! lowers into the same small vocabulary of tile operations with explicit
//! data-dependence edges.  The IR is *semantic*: a node says "TRSM of
//! panel tile (i, k) against diagonal factor k", not "run this closure" —
//! which is what lets the planner fuse producer→consumer pairs and what
//! will let the follow-on sharding passes reassign `owner`s without a new
//! graph type.
//!
//! Edges are inferred exactly like the scheduler's sequential task flow
//! (RAW / WAR / WAW over logical resources), so an unfused plan executes
//! the same dependence structure the legacy emitters in
//! [`crate::linalg::cholesky`] produced.

use super::shard::ShardGrid;
use crate::scheduler::profile::CostModel;
use crate::scheduler::TaskKind;
use std::collections::HashMap;

/// One typed tile operation.  Coordinates are tile indices (`i >= j`,
/// `k` the panel); `Solve*` ops act on segments of the right-hand-side
/// vector.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Op {
    /// Fill tile (i, j) from the covariance kernel (`dcmg`).
    Generate { i: usize, j: usize },
    /// Cholesky of diagonal tile k.
    Potrf { k: usize },
    /// Panel solve: tile (i, k) against the factor of diagonal k.
    Trsm { k: usize, i: usize },
    /// Trailing symmetric update of diagonal tile (i, i) by panel k.
    Syrk { k: usize, i: usize },
    /// Trailing update of tile (i, j) by panel k (`k < j < i`).
    Gemm { k: usize, i: usize, j: usize },
    /// Partial log-determinant of diagonal factor k (per-tile ln-sum;
    /// the host adds the partials in `k` order, so fused and unfused
    /// plans share one summation tree and stay bit-identical).
    LogDetReduce { k: usize },
    /// Forward-solve update: segment i -= L(i, j) * segment j.
    SolveGemv { i: usize, j: usize },
    /// Forward-solve triangular step on segment i.
    SolveTrsv { i: usize },
}

impl Op {
    /// Scheduler/profiler classification — the cost-model hook: a
    /// planner pass prices a node via
    /// [`CostModel::cost`]`(op.task_kind())`.
    pub fn task_kind(&self) -> TaskKind {
        match self {
            Op::Generate { .. } => TaskKind::DCMG,
            Op::Potrf { .. } => TaskKind::POTRF,
            Op::Trsm { .. } => TaskKind::TRSM,
            Op::Syrk { .. } => TaskKind::SYRK,
            Op::Gemm { .. } => TaskKind::GEMM,
            Op::LogDetReduce { .. } => TaskKind::LOGDET,
            // Solve ops reuse the dense kinds, matching the legacy
            // emitters (gemv submitted as GEMM, trsv as TRSM).
            Op::SolveGemv { .. } => TaskKind::GEMM,
            Op::SolveTrsv { .. } => TaskKind::TRSM,
        }
    }

    /// The factor-matrix tiles this op touches, as `(i, j)` lower-tile
    /// coordinates (up to three: the Gemm operand set).  This is the
    /// out-of-core executor's pin set — kept next to the op definitions
    /// so a new op cannot silently run unpinned.  Solve ops touch
    /// segments of the RHS vector too, but segments are never spilled
    /// (the vector is O(n), the matrix O(n²)).
    pub fn tile_operands(&self) -> TileOperands {
        let mut t = TileOperands::default();
        match *self {
            Op::Generate { i, j } => t.push(i, j),
            Op::Potrf { k } | Op::LogDetReduce { k } => t.push(k, k),
            Op::Trsm { k, i } => {
                t.push(k, k);
                t.push(i, k);
            }
            Op::Syrk { k, i } => {
                t.push(i, k);
                t.push(i, i);
            }
            Op::Gemm { k, i, j } => {
                t.push(i, k);
                t.push(j, k);
                t.push(i, j);
            }
            Op::SolveGemv { i, j } => t.push(i, j),
            Op::SolveTrsv { i } => t.push(i, i),
        }
        t
    }
}

/// Up to three lower-tile coordinates (inline, no allocation — this is
/// walked per task in the out-of-core executor's hot loop).
#[derive(Copy, Clone, Debug, Default)]
pub struct TileOperands {
    tiles: [(usize, usize); 3],
    len: usize,
}

impl TileOperands {
    fn push(&mut self, i: usize, j: usize) {
        self.tiles[self.len] = (i, j);
        self.len += 1;
    }
    /// The operand coordinates, in op order.
    pub fn as_slice(&self) -> &[(usize, usize)] {
        &self.tiles[..self.len]
    }
}

/// Storage/compute precision of a node's output tile.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Precision {
    F64,
    /// MP off-band tile: f32 storage, f32 micro-kernel compute.
    F32,
    /// TLR compressed tile (`U V^T`).
    LowRank,
}

/// One IR node: a typed op plus placement metadata and explicit edges.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    /// Precision of the output operand.
    pub prec: Precision,
    /// Placement domain of the output tile (worker class / shard id;
    /// single-node plans put everything on owner 0).  Follow-on
    /// sharding passes partition on this without a new graph type.
    pub owner: usize,
    /// Bytes touched (operand sizes, mirroring the legacy emitters) —
    /// the DES transfer model's input.
    pub bytes: usize,
    /// Direct predecessors (ascending node ids).
    pub preds: Vec<usize>,
    /// Direct successors.
    pub succs: Vec<usize>,
}

impl Node {
    /// Modeled execution cost in seconds under a measured per-kind
    /// cost model (the `scheduler::profile` hook).
    pub fn cost(&self, model: &CostModel) -> f64 {
        model.cost(self.op.task_kind())
    }
}

/// The lowered graph.
#[derive(Clone, Debug, Default)]
pub struct TaskIR {
    pub nodes: Vec<Node>,
}

impl TaskIR {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Node count per task kind name (test/telemetry helper).
    pub fn kind_counts(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            *m.entry(n.op.task_kind().name).or_insert(0) += 1;
        }
        m
    }
}

/// Logical resources the STF-style edge inference runs over.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
enum Key {
    /// Lower tile (i, j) of the factor matrix.
    Tile(usize, usize),
    /// Segment i of the solve vector.
    Seg(usize),
    /// Per-panel log-determinant slot.
    Scalar(usize),
}

#[derive(Copy, Clone, PartialEq, Eq)]
enum Mode {
    R,
    W,
    Rw,
}

/// STF edge inference over logical keys: readers depend on the last
/// writer; writers additionally on every reader since (WAR + WAW) —
/// byte-for-byte the scheduler's `TaskGraph::submit` rule, applied at
/// the IR level so plans can rewire execution without re-deriving
/// hazards.
#[derive(Default)]
struct IrBuilder {
    nodes: Vec<Node>,
    last_writer: HashMap<Key, usize>,
    readers: HashMap<Key, Vec<usize>>,
}

impl IrBuilder {
    fn push(
        &mut self,
        op: Op,
        prec: Precision,
        owner: usize,
        bytes: usize,
        operands: &[(Key, Mode)],
    ) -> usize {
        let id = self.nodes.len();
        let mut preds: Vec<usize> = Vec::new();
        for &(key, mode) in operands {
            match mode {
                Mode::R => {
                    if let Some(&w) = self.last_writer.get(&key) {
                        preds.push(w);
                    }
                    self.readers.entry(key).or_default().push(id);
                }
                Mode::W | Mode::Rw => {
                    if let Some(&w) = self.last_writer.get(&key) {
                        preds.push(w);
                    }
                    if let Some(rs) = self.readers.remove(&key) {
                        preds.extend(rs);
                    }
                    self.last_writer.insert(key, id);
                }
            }
        }
        preds.sort_unstable();
        preds.dedup();
        preds.retain(|&p| p != id);
        for &p in &preds {
            self.nodes[p].succs.push(id);
        }
        self.nodes.push(Node {
            op,
            prec,
            owner,
            bytes,
            preds,
            succs: Vec::new(),
        });
        id
    }
}

/// What to lower.  Pure data: the same spec always produces the same
/// IR, and planner unit tests build specs without touching any real
/// tile storage.
#[derive(Copy, Clone, Debug)]
pub struct TiledSpec {
    /// Matrix dimension.
    pub n: usize,
    /// Tile size.
    pub ts: usize,
    /// Structural band (DST): `None` keeps every lower tile; `Some(b)`
    /// retains tiles with `i - j <= b` in generation and factorization.
    pub band: Option<usize>,
    /// MP storage band: tiles with `i - j > b` are f32-stored (their
    /// nodes carry [`Precision::F32`] and half-width byte counts).
    pub mp_band: Option<usize>,
    /// Low-rank off-diagonal tiles (TLR): off-diagonal nodes carry
    /// [`Precision::LowRank`]; byte counts stay the dense upper bound
    /// (ranks are theta-dependent).
    pub tlr: bool,
    /// Lower a forward solve (`y <- L^{-1} y`) after the factorization.
    pub with_solve: bool,
    /// Lower per-panel [`Op::LogDetReduce`] nodes after each POTRF.
    pub with_logdet: bool,
    /// Placement domains for `owner` assignment (2-D block-cyclic over
    /// the squarest `p x q` grid with `p * q == owners` — the shared
    /// [`ShardGrid`] implementation the DES cluster model and the
    /// sharding pass also use); single-node execution passes 1.
    pub owners: usize,
}

impl TiledSpec {
    fn nt(&self) -> usize {
        self.n.div_ceil(self.ts)
    }
    fn dim(&self, i: usize) -> usize {
        self.ts.min(self.n - i * self.ts)
    }
    fn in_band(&self, i: usize, j: usize) -> bool {
        crate::linalg::cholesky::in_band(self.band, i, j)
    }
    fn prec(&self, i: usize, j: usize) -> Precision {
        if self.tlr && i != j {
            Precision::LowRank
        } else if matches!(self.mp_band, Some(b) if !crate::linalg::tile::mp_tile_is_f64(b, i, j)) {
            Precision::F32
        } else {
            Precision::F64
        }
    }
    /// Bytes of tile (i, j), mirroring `TileMatrix::tile_bytes_at`
    /// (f32-stored MP tiles count half-width).
    fn tile_bytes(&self, i: usize, j: usize) -> usize {
        let elems = self.dim(i) * self.dim(j);
        match self.prec(i, j) {
            Precision::F32 => elems * std::mem::size_of::<f32>(),
            _ => elems * std::mem::size_of::<f64>(),
        }
    }
    /// 2-D block-cyclic owner of tile (i, j).  Historically this was a
    /// 1-D row cycle (`i % owners`) despite the doc contract and the
    /// DES model both promising 2-D block-cyclic; all three now route
    /// through one [`ShardGrid`].
    fn owner(&self, i: usize, j: usize) -> usize {
        if self.owners <= 1 {
            0
        } else {
            ShardGrid::from_total(self.owners).owner_of(i, j)
        }
    }
}

/// Lower a tiled pipeline into the IR.  Emission order follows the
/// legacy STF program order exactly — generation sweep, then the
/// right-looking Cholesky panels, then the forward solve — so node ids
/// ascend topologically and an unfused plan reproduces the legacy task
/// structure (plus the explicit [`Op::LogDetReduce`] nodes the legacy
/// path computed host-side).
pub fn lower_tiled(spec: &TiledSpec) -> TaskIR {
    let nt = spec.nt();
    let mut b = IrBuilder::default();

    // Generation sweep (dcmg): every retained lower tile.
    for i in 0..nt {
        for j in 0..=i {
            if !spec.in_band(i, j) {
                continue;
            }
            b.push(
                Op::Generate { i, j },
                spec.prec(i, j),
                spec.owner(i, j),
                spec.tile_bytes(i, j),
                &[(Key::Tile(i, j), Mode::W)],
            );
        }
    }

    // Right-looking tiled Cholesky, band-restricted like the legacy
    // emitter (GEMM additionally requires both its operand tiles in
    // band).
    for k in 0..nt {
        b.push(
            Op::Potrf { k },
            Precision::F64,
            spec.owner(k, k),
            spec.tile_bytes(k, k),
            &[(Key::Tile(k, k), Mode::Rw)],
        );
        if spec.with_logdet {
            b.push(
                Op::LogDetReduce { k },
                Precision::F64,
                spec.owner(k, k),
                spec.tile_bytes(k, k),
                &[(Key::Tile(k, k), Mode::R), (Key::Scalar(k), Mode::W)],
            );
        }
        for i in k + 1..nt {
            if !spec.in_band(i, k) {
                continue;
            }
            b.push(
                Op::Trsm { k, i },
                spec.prec(i, k),
                spec.owner(i, k),
                spec.tile_bytes(k, k) + spec.tile_bytes(i, k),
                &[(Key::Tile(k, k), Mode::R), (Key::Tile(i, k), Mode::Rw)],
            );
        }
        for i in k + 1..nt {
            if !spec.in_band(i, k) {
                continue;
            }
            b.push(
                Op::Syrk { k, i },
                spec.prec(i, i),
                spec.owner(i, i),
                spec.tile_bytes(i, k) + spec.tile_bytes(i, i),
                &[(Key::Tile(i, k), Mode::R), (Key::Tile(i, i), Mode::Rw)],
            );
            for j in k + 1..i {
                if !spec.in_band(i, j) || !spec.in_band(j, k) {
                    continue;
                }
                b.push(
                    Op::Gemm { k, i, j },
                    spec.prec(i, j),
                    spec.owner(i, j),
                    spec.tile_bytes(i, k) + spec.tile_bytes(j, k) + spec.tile_bytes(i, j),
                    &[
                        (Key::Tile(i, k), Mode::R),
                        (Key::Tile(j, k), Mode::R),
                        (Key::Tile(i, j), Mode::Rw),
                    ],
                );
            }
        }
    }

    // Forward solve against the factor (band-aware, like the legacy
    // `submit_tiled_forward_solve_banded`).
    if spec.with_solve {
        for i in 0..nt {
            for j in 0..i {
                if !spec.in_band(i, j) {
                    continue;
                }
                b.push(
                    Op::SolveGemv { i, j },
                    Precision::F64,
                    spec.owner(i, j),
                    spec.tile_bytes(i, j),
                    &[
                        (Key::Tile(i, j), Mode::R),
                        (Key::Seg(j), Mode::R),
                        (Key::Seg(i), Mode::Rw),
                    ],
                );
            }
            b.push(
                Op::SolveTrsv { i },
                Precision::F64,
                spec.owner(i, i),
                spec.tile_bytes(i, i),
                &[(Key::Tile(i, i), Mode::R), (Key::Seg(i), Mode::Rw)],
            );
        }
    }

    TaskIR { nodes: b.nodes }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_spec(n: usize, ts: usize) -> TiledSpec {
        TiledSpec {
            n,
            ts,
            band: None,
            mp_band: None,
            tlr: false,
            with_solve: true,
            with_logdet: true,
            owners: 1,
        }
    }

    #[test]
    fn dense_counts_match_closed_forms() {
        // nt = 3: 6 generates, 3 potrf, 3 logdet, 3 trsm, 3 syrk,
        // 1 gemm, 3 solve-gemv, 3 solve-trsv.
        let ir = lower_tiled(&dense_spec(48, 16));
        let c = ir.kind_counts();
        assert_eq!(c.get("dcmg"), Some(&6));
        assert_eq!(c.get("potrf"), Some(&3));
        assert_eq!(c.get("logdet"), Some(&3));
        // trsm kind covers panel trsm (3) + solve trsv (3)
        assert_eq!(c.get("trsm"), Some(&6));
        // gemm kind covers trailing gemm (1) + solve gemv (3)
        assert_eq!(c.get("gemm"), Some(&4));
        assert_eq!(c.get("syrk"), Some(&3));
        assert_eq!(ir.len(), 25);
    }

    #[test]
    fn edges_ascend_and_generates_have_one_successor() {
        let ir = lower_tiled(&dense_spec(64, 16));
        for (id, n) in ir.nodes.iter().enumerate() {
            for &p in &n.preds {
                assert!(p < id, "pred {p} !< node {id}");
            }
            if let Op::Generate { .. } = n.op {
                assert!(n.preds.is_empty(), "generate {id} has preds");
                assert_eq!(n.succs.len(), 1, "generate {id}: {:?}", n.succs);
            }
        }
    }

    #[test]
    fn logdet_depends_only_on_its_potrf() {
        let ir = lower_tiled(&dense_spec(48, 16));
        for (id, n) in ir.nodes.iter().enumerate() {
            if let Op::LogDetReduce { k } = n.op {
                assert_eq!(n.preds.len(), 1, "node {id}");
                assert_eq!(ir.nodes[n.preds[0]].op, Op::Potrf { k });
            }
        }
    }

    #[test]
    fn dst_band_gates_offband_work() {
        let mut spec = dense_spec(64, 16); // nt = 4
        spec.band = Some(1);
        let ir = lower_tiled(&spec);
        for n in &ir.nodes {
            let (i, j) = match n.op {
                Op::Generate { i, j } | Op::SolveGemv { i, j } | Op::Gemm { i, j, .. } => (i, j),
                Op::Trsm { k, i } | Op::Syrk { k, i } => (i, k),
                _ => continue,
            };
            assert!(i - j <= 1, "off-band node {:?}", n.op);
        }
    }

    #[test]
    fn mp_band_tags_precision_and_halves_bytes() {
        let mut spec = dense_spec(48, 16);
        spec.mp_band = Some(0);
        let ir = lower_tiled(&spec);
        let gen = |i: usize, j: usize| {
            ir.nodes
                .iter()
                .find(|n| n.op == Op::Generate { i, j })
                .unwrap()
        };
        assert_eq!(gen(1, 1).prec, Precision::F64);
        assert_eq!(gen(2, 0).prec, Precision::F32);
        assert_eq!(gen(2, 0).bytes * 2, gen(1, 1).bytes);
    }

    #[test]
    fn owners_assign_2d_block_cyclic() {
        // owners = 4 factors as a 2x2 grid: owner(i, j) = (i%2)*2 + j%2.
        // The old 1-D row cycle (i % owners) would put Generate{2, 0}
        // on owner 2; the 2-D grid puts it back on owner 0.
        let mut spec = dense_spec(64, 16);
        spec.owners = 4;
        let ir = lower_tiled(&spec);
        for n in &ir.nodes {
            let (i, j) = match n.op {
                Op::Generate { i, j } | Op::SolveGemv { i, j } | Op::Gemm { i, j, .. } => (i, j),
                Op::Potrf { k } | Op::LogDetReduce { k } => (k, k),
                Op::Trsm { k, i } => (i, k),
                Op::Syrk { i, .. } | Op::SolveTrsv { i } => (i, i),
            };
            assert_eq!(n.owner, (i % 2) * 2 + (j % 2), "{:?}", n.op);
        }
        // owners = 2 degenerates to a 1x2 grid: a pure *column* cycle.
        spec.owners = 2;
        let ir = lower_tiled(&spec);
        for n in &ir.nodes {
            if let Op::Generate { i, j } = n.op {
                assert_eq!(n.owner, j % 2, "Generate{{{i},{j}}}");
            }
        }
    }
}
