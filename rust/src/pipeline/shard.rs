//! Sharding pass: partition an [`ExecutionPlan`] across N in-process
//! runtimes ("shards") along the IR's 2-D block-cyclic tile ownership,
//! inserting explicit transfer edges wherever a consumer task's shard
//! differs from its producer's — the in-process model of ExaGeoStat's
//! distributed runs (arxiv 1708.02835 Fig. 7), where the same
//! block-cyclic distribution places tiles on cluster nodes and boundary
//! panels move over the interconnect.
//!
//! Three pieces:
//!
//! * [`ShardGrid`] — the one 2-D block-cyclic owner function
//!   (`owner(i, j) = (i mod p)·q + (j mod q)`), shared by
//!   `TiledSpec::owner`, the DES cluster model
//!   ([`crate::scheduler::des::block_cyclic_owner`]) and this pass, so
//!   the simulated distribution and the executed one cannot drift.
//! * [`ShardPlan::partition`] — assigns every plan task to the shard
//!   owning its output tile, levels the plan into *stages* such that
//!   every cross-shard edge strictly increases the stage, and derives
//!   the transfer-edge set (one [`TileMailbox`] slot per producer with a
//!   consumer in another shard).
//! * [`execute_sharded`] — drives the per-shard stage jobs concurrently
//!   from a single-threaded event loop, gating each stage on its
//!   cross-shard inputs through the lock-free mailbox.
//!
//! **Deadlock freedom.** Stages are defined by
//! `stage(t) = max over preds p of (stage(p) + [shard(p) != shard(t)])`,
//! so a stage's cross-shard inputs always come from strictly earlier
//! stages.  By induction on the stage number, the lowest unfinished
//! stage of any shard always has every awaited slot published, hence is
//! submittable — no worker ever blocks on a mailbox (workers never poll
//! it at all; only the event loop does, between jobs).
//!
//! **Determinism.** Sharding reorders nothing that matters: every plan
//! edge is preserved (same-stage intra-shard edges become explicit graph
//! edges, earlier-stage intra-shard edges ride the per-shard sequential
//! stage order, cross-shard edges ride the mailbox gate), so each tile
//! still sees its writes in plan order and the per-panel log-det
//! partials are still summed host-side in panel order.  f64 pipelines
//! are therefore bit-identical across shard counts — the property
//! `rust/tests/sharded.rs` pins.

use super::execution_plan::{ExecutionPlan, OpRunner};
use super::ir::{Op, TaskIR};
use crate::scheduler::pool::Policy;
use crate::scheduler::runtime::{CancelToken, JobHandle, Runtime};
use crate::scheduler::TaskGraph;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, OnceLock};

/// The 2-D block-cyclic process grid (ScaLAPACK/ExaGeoStat style): tile
/// (i, j) belongs to domain `(i mod p) * q + (j mod q)`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct ShardGrid {
    pub p: usize,
    pub q: usize,
}

impl ShardGrid {
    pub fn new(p: usize, q: usize) -> ShardGrid {
        ShardGrid {
            p: p.max(1),
            q: q.max(1),
        }
    }

    /// Squarest `p x q` factorization of `n` with `p <= q` (the usual
    /// choice for block-cyclic grids: it balances both the row and the
    /// column cycle).
    pub fn from_total(n: usize) -> ShardGrid {
        let n = n.max(1);
        let mut p = 1;
        let mut d = 1;
        while d * d <= n {
            if n % d == 0 {
                p = d;
            }
            d += 1;
        }
        ShardGrid { p, q: n / p }
    }

    /// Number of placement domains (`p * q`).
    pub fn domains(&self) -> usize {
        self.p * self.q
    }

    /// Owner domain of tile (i, j).
    pub fn owner_of(&self, i: usize, j: usize) -> usize {
        (i % self.p) * self.q + (j % self.q)
    }
}

/// Tile coordinate an op's output is associated with (the mailbox key's
/// spatial half).  Solve ops are keyed by the factor tile they read —
/// their true output is vector segment `i`, which has no (i, j) home.
pub fn output_coord(op: Op) -> (usize, usize) {
    match op {
        Op::Generate { i, j } | Op::SolveGemv { i, j } => (i, j),
        Op::Potrf { k } | Op::LogDetReduce { k } => (k, k),
        Op::Trsm { k, i } => (i, k),
        Op::Syrk { i, .. } => (i, i),
        Op::Gemm { i, j, .. } => (i, j),
        Op::SolveTrsv { i } => (i, i),
    }
}

/// One cross-shard dependence edge of the partitioned plan.
#[derive(Clone, Debug)]
pub struct TransferEdge {
    /// Mailbox slot the producer publishes (shared by every consumer of
    /// the same producer).
    pub slot: usize,
    /// Producer / consumer plan-task indices.
    pub from_task: usize,
    pub to_task: usize,
    /// Their shard assignments (`from_shard != to_shard` by
    /// construction).
    pub from_shard: usize,
    pub to_shard: usize,
    /// Tile coordinate of the producer's output.
    pub coord: (usize, usize),
}

/// A plan partitioned across shards: per-task shard and stage labels,
/// the per-shard stage rosters, and the transfer-edge set.
pub struct ShardPlan {
    pub nshards: usize,
    /// Shard of each plan task (the owner of its final op's output).
    pub shard: Vec<usize>,
    /// Stage level of each plan task (cross-shard edges strictly
    /// increase it; intra-shard edges never decrease it).
    pub stage: Vec<usize>,
    pub nstages: usize,
    /// `stages[s][g]`: plan-task indices of shard `s`, stage `g`, in
    /// ascending plan order (a valid intra-job order: plan preds only
    /// point backwards).
    pub stages: Vec<Vec<Vec<usize>>>,
    /// Every cross-shard plan edge, in plan order of the consumer.
    pub transfers: Vec<TransferEdge>,
    /// `publishes[t]`: mailbox slots task `t` must publish on completion
    /// (empty for tasks without cross-shard consumers).
    pub publishes: Vec<Vec<usize>>,
    /// `awaits[s][g]`: slots that must be published before shard `s`
    /// may submit stage `g`.
    pub awaits: Vec<Vec<Vec<usize>>>,
    /// Total mailbox slots (== producers with >= 1 cross-shard consumer).
    pub nslots: usize,
}

impl ShardPlan {
    /// Partition `plan` over `nshards` shards.  Task placement is the IR
    /// owner of the task's *last* op (its output op) reduced mod
    /// `nshards`; fusion may group ops whose owner hints differ, in
    /// which case the output op's owner wins and the transfer edges —
    /// which are derived from the *task*-level placement, never from the
    /// per-op hints — stay exact.
    pub fn partition(ir: &TaskIR, plan: &ExecutionPlan, nshards: usize) -> ShardPlan {
        let nshards = nshards.max(1);
        let ntasks = plan.tasks.len();
        let mut shard = Vec::with_capacity(ntasks);
        for t in &plan.tasks {
            let last = *t.ops.last().expect("plan task has at least one op");
            shard.push(ir.nodes[last].owner % nshards);
        }

        // Stage leveling: every cross-shard edge steps the stage up, so
        // a stage's awaited slots always belong to earlier stages.
        let mut stage = vec![0usize; ntasks];
        for (t, task) in plan.tasks.iter().enumerate() {
            let mut lvl = 0;
            for &p in &task.preds {
                lvl = lvl.max(stage[p] + usize::from(shard[p] != shard[t]));
            }
            stage[t] = lvl;
        }
        let nstages = stage.iter().map(|&g| g + 1).max().unwrap_or(0);

        let mut stages = vec![vec![Vec::new(); nstages]; nshards];
        for t in 0..ntasks {
            stages[shard[t]][stage[t]].push(t);
        }

        let mut slot_of: HashMap<usize, usize> = HashMap::new();
        let mut transfers = Vec::new();
        let mut publishes = vec![Vec::new(); ntasks];
        let mut awaits = vec![vec![Vec::new(); nstages]; nshards];
        for (t, task) in plan.tasks.iter().enumerate() {
            for &p in &task.preds {
                if shard[p] == shard[t] {
                    continue;
                }
                let next = slot_of.len();
                let slot = *slot_of.entry(p).or_insert(next);
                if publishes[p].is_empty() {
                    publishes[p].push(slot);
                }
                let out = *plan.tasks[p].ops.last().expect("plan task has ops");
                transfers.push(TransferEdge {
                    slot,
                    from_task: p,
                    to_task: t,
                    from_shard: shard[p],
                    to_shard: shard[t],
                    coord: output_coord(ir.nodes[out].op),
                });
                let gate = &mut awaits[shard[t]][stage[t]];
                if !gate.contains(&slot) {
                    gate.push(slot);
                }
            }
        }
        let nslots = slot_of.len();
        ShardPlan {
            nshards,
            shard,
            stage,
            nstages,
            stages,
            transfers,
            publishes,
            awaits,
            nslots,
        }
    }
}

/// Lock-free mailbox for cross-shard boundary tiles: one slot per
/// publishing plan task, keyed by (tile coordinate, plan step).  In this
/// in-process setting the tile payload itself lives in the shared tile
/// storage, so a "transfer" is a release-store publication that the
/// event loop acquires before submitting the consuming stage — exactly
/// the fence a cross-address-space implementation would pair with the
/// actual copy.  Workers never touch the mailbox from inside a task
/// wait; producers store on completion, the event loop polls between
/// jobs.
pub struct TileMailbox {
    slots: Vec<AtomicU32>,
    keys: Vec<(usize, usize, usize)>,
}

impl TileMailbox {
    pub fn new(sp: &ShardPlan) -> TileMailbox {
        let mut keys = vec![(0, 0, 0); sp.nslots];
        for e in &sp.transfers {
            keys[e.slot] = (e.coord.0, e.coord.1, e.from_task);
        }
        TileMailbox {
            slots: (0..sp.nslots).map(|_| AtomicU32::new(0)).collect(),
            keys,
        }
    }

    /// Producer side: mark the slot's tile as complete (release: the
    /// tile writes of the publishing task happen-before any consumer
    /// that observes the flag).
    pub fn publish(&self, slot: usize) {
        self.slots[slot].store(1, Ordering::Release);
    }

    pub fn is_published(&self, slot: usize) -> bool {
        self.slots[slot].load(Ordering::Acquire) == 1
    }

    pub fn all_published(&self, slots: &[usize]) -> bool {
        slots.iter().all(|&s| self.is_published(s))
    }

    /// `(tile i, tile j, producing plan step)` of a slot.
    pub fn key(&self, slot: usize) -> (usize, usize, usize) {
        self.keys[slot]
    }
}

/// A set of shard runtimes plus the grid that places tiles on them.
/// Attached to an `ExecCtx` (or a coordinator), it switches `run_tiled`
/// to sharded execution for plans with at least `min_nt` tile rows.
pub struct ShardSet {
    runtimes: Vec<Arc<Runtime>>,
    pub grid: ShardGrid,
    /// Minimum tile-grid side before a plan is worth partitioning:
    /// below it the whole plan runs on shard 0's runtime (a 1-tile
    /// matrix cannot be distributed usefully).
    pub min_nt: usize,
}

impl ShardSet {
    /// Spawn `nshards` fresh runtimes of `ncores_per_shard` workers each.
    pub fn new(nshards: usize, ncores_per_shard: usize, policy: Policy) -> ShardSet {
        let n = nshards.max(1);
        ShardSet {
            runtimes: (0..n)
                .map(|_| Arc::new(Runtime::new(ncores_per_shard.max(1), policy)))
                .collect(),
            grid: ShardGrid::from_total(n),
            min_nt: 2,
        }
    }

    /// Wrap existing runtimes (the sharded coordinator hands its member
    /// coordinators' runtimes here; it stays responsible for shutting
    /// them down).
    pub fn from_runtimes(runtimes: Vec<Arc<Runtime>>, min_nt: usize) -> ShardSet {
        assert!(!runtimes.is_empty(), "shard set needs at least one runtime");
        let grid = ShardGrid::from_total(runtimes.len());
        ShardSet {
            runtimes,
            grid,
            min_nt,
        }
    }

    pub fn nshards(&self) -> usize {
        self.runtimes.len()
    }

    pub fn runtime(&self, shard: usize) -> &Arc<Runtime> {
        &self.runtimes[shard]
    }

    /// Shut down every shard runtime.  Only for sets that own their
    /// runtimes (`new`), never for wrapped ones (`from_runtimes`).
    pub fn shutdown(&self) {
        for r in &self.runtimes {
            r.shutdown();
        }
    }
}

static ENV_SHARDS: OnceLock<Option<Arc<ShardSet>>> = OnceLock::new();

/// Process-wide shard set from `EXAGEOSTAT_SHARDS=N` (N >= 2), attached
/// to every context built through `ExecCtx::new` / `with_engine` so the
/// whole conformance suite can run sharded without code changes (the CI
/// build-test job does exactly that).  `None` when the variable is
/// unset, `< 2`, or unparseable (one-time stderr warning on garbage —
/// the same surfacing as `EXAGEOSTAT_BACKEND`).  Contexts built over an
/// explicit runtime (`with_runtime`, the coordinator route) are *not*
/// affected; the coordinator layer decides its own sharding.
pub fn shard_set_from_env() -> Option<Arc<ShardSet>> {
    ENV_SHARDS
        .get_or_init(|| {
            let raw = std::env::var("EXAGEOSTAT_SHARDS").ok()?;
            match raw.trim().parse::<usize>() {
                Ok(n) if n >= 2 => Some(Arc::new(ShardSet::new(n, 1, Policy::Lws))),
                Ok(_) => None,
                Err(_) => {
                    eprintln!(
                        "warning: EXAGEOSTAT_SHARDS={raw:?} is not an integer; running unsharded"
                    );
                    None
                }
            }
        })
        .clone()
}

struct Cursor {
    next_stage: usize,
    inflight: Option<JobHandle>,
}

/// Drive a partitioned plan to completion across the set's runtimes.
///
/// Single-threaded event loop on the calling thread: each shard runs its
/// stages strictly in order, one job per stage, and a stage is submitted
/// only once every mailbox slot it awaits has been published.  Workers
/// therefore never block on cross-shard data — the gate lives entirely
/// in this loop — which is what makes the scheme deadlock-free (see the
/// module docs for the induction).
///
/// Returns the number of tasks skipped: `> 0` exactly when `cancel`
/// fired mid-run (the same contract as `Profile::tasks_skipped` on the
/// single-runtime path).
pub fn execute_sharded<R: OpRunner + Send + Sync + 'static>(
    plan: &ExecutionPlan,
    ir: &TaskIR,
    runner: Arc<R>,
    set: &ShardSet,
    job_prio: u8,
    cancel: &CancelToken,
) -> usize {
    let sp = ShardPlan::partition(ir, plan, set.nshards());
    let mailbox = Arc::new(TileMailbox::new(&sp));
    let mut cursors: Vec<Cursor> = (0..sp.nshards)
        .map(|_| Cursor {
            next_stage: 0,
            inflight: None,
        })
        .collect();
    let mut skipped = 0usize;
    let mut idle_rounds = 0u32;
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for (s, cur) in cursors.iter_mut().enumerate() {
            if let Some(h) = cur.inflight.take() {
                if h.is_done() {
                    // Reap: re-raises a task panic here, like run_graph.
                    skipped += h.wait().tasks_skipped;
                    progressed = true;
                } else {
                    cur.inflight = Some(h);
                    all_done = false;
                    continue;
                }
            }
            while cur.next_stage < sp.nstages && sp.stages[s][cur.next_stage].is_empty() {
                cur.next_stage += 1;
            }
            if cur.next_stage >= sp.nstages {
                continue;
            }
            all_done = false;
            if cancel.is_cancelled() {
                // The runtimes would skip these tasks anyway; account
                // for them here and stop submitting.  Producers that
                // were skipped never publish, but no shard waits on
                // them: every shard takes this branch on its next pass.
                for g in cur.next_stage..sp.nstages {
                    skipped += sp.stages[s][g].len();
                }
                cur.next_stage = sp.nstages;
                progressed = true;
            } else if mailbox.all_published(&sp.awaits[s][cur.next_stage]) {
                let g = stage_graph(plan, ir, &sp, &mailbox, s, cur.next_stage, &runner);
                cur.inflight = Some(set.runtime(s).submit_job(g, job_prio, cancel.clone()));
                cur.next_stage += 1;
                progressed = true;
            }
        }
        if all_done {
            break;
        }
        if progressed {
            idle_rounds = 0;
        } else {
            // Waiting on worker progress: yield first, then back off to
            // a micro-sleep so the loop doesn't burn a core while a
            // long stage runs.
            idle_rounds += 1;
            if idle_rounds < 64 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        }
    }
    skipped
}

/// Build the task graph for one (shard, stage) job.  Same-stage
/// intra-shard plan edges become explicit graph edges; earlier-stage
/// intra-shard predecessors are sequenced by the per-shard stage order,
/// and cross-shard predecessors by the mailbox gate that admitted this
/// stage.  Publishing tasks flag their slots at the end of their
/// closure, after their ops' tile writes.
fn stage_graph<R: OpRunner + Send + Sync + 'static>(
    plan: &ExecutionPlan,
    ir: &TaskIR,
    sp: &ShardPlan,
    mailbox: &Arc<TileMailbox>,
    s: usize,
    g: usize,
    runner: &Arc<R>,
) -> TaskGraph {
    let mut graph = TaskGraph::new();
    let mut local: HashMap<usize, usize> = HashMap::new();
    for &t in &sp.stages[s][g] {
        let task = &plan.tasks[t];
        let preds: Vec<usize> = task
            .preds
            .iter()
            .filter_map(|p| local.get(p).copied())
            .collect();
        let ops: Vec<Op> = task.ops.iter().map(|&o| ir.nodes[o].op).collect();
        let pubs = sp.publishes[t].clone();
        let r = runner.clone();
        let mb = mailbox.clone();
        let id = graph.submit_dep(task.kind, &preds, task.bytes, move || {
            for op in &ops {
                r.run_op(*op);
            }
            for &slot in &pubs {
                mb.publish(slot);
            }
        });
        local.insert(t, id);
    }
    graph
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{lower_tiled, planner, PlanKnobs, TiledSpec};
    use std::sync::atomic::AtomicUsize;

    fn spec(n: usize, ts: usize, owners: usize) -> TiledSpec {
        TiledSpec {
            n,
            ts,
            band: None,
            mp_band: None,
            tlr: false,
            with_solve: true,
            with_logdet: true,
            owners,
        }
    }

    #[test]
    fn grid_factors_squarest_and_matches_formula() {
        assert_eq!(ShardGrid::from_total(1), ShardGrid::new(1, 1));
        assert_eq!(ShardGrid::from_total(2), ShardGrid::new(1, 2));
        assert_eq!(ShardGrid::from_total(4), ShardGrid::new(2, 2));
        assert_eq!(ShardGrid::from_total(6), ShardGrid::new(2, 3));
        assert_eq!(ShardGrid::from_total(7), ShardGrid::new(1, 7));
        assert_eq!(ShardGrid::from_total(12), ShardGrid::new(3, 4));
        let g = ShardGrid::new(2, 3);
        for i in 0..7 {
            for j in 0..7 {
                assert_eq!(g.owner_of(i, j), (i % 2) * 3 + (j % 3));
                assert!(g.owner_of(i, j) < g.domains());
            }
        }
    }

    /// Over tile grids that do *not* divide n: every cross-shard plan
    /// edge gets a transfer (slot + strictly increasing stage), no
    /// intra-shard edge does, and the stage rosters partition the plan.
    #[test]
    fn transfer_edges_cover_exactly_the_cross_shard_plan_edges() {
        for (n, ts, owners) in [(54, 16, 2), (75, 11, 4), (90, 24, 3)] {
            let ir = lower_tiled(&spec(n, ts, owners));
            let plan = planner::plan(&ir, &PlanKnobs { fuse: true });
            let sp = ShardPlan::partition(&ir, &plan, owners);
            let mut expected = 0;
            for (t, task) in plan.tasks.iter().enumerate() {
                for &p in &task.preds {
                    if sp.shard[p] != sp.shard[t] {
                        expected += 1;
                        assert!(
                            sp.stage[t] > sp.stage[p],
                            "cross-shard edge {p}->{t} must climb stages"
                        );
                        assert!(
                            sp.transfers
                                .iter()
                                .any(|e| e.from_task == p && e.to_task == t),
                            "missing transfer for cross-shard edge {p}->{t}"
                        );
                        assert_eq!(sp.publishes[p].len(), 1, "producer {p} publishes one slot");
                        assert!(
                            sp.awaits[sp.shard[t]][sp.stage[t]].contains(&sp.publishes[p][0]),
                            "stage of {t} must await producer {p}'s slot"
                        );
                    } else {
                        assert!(sp.stage[t] >= sp.stage[p]);
                        let transferred = sp
                            .transfers
                            .iter()
                            .any(|e| e.from_task == p && e.to_task == t);
                        assert!(!transferred, "intra-shard edge {p}->{t} must not transfer");
                    }
                }
            }
            assert_eq!(sp.transfers.len(), expected);
            assert!(sp.nslots > 0, "a dense multi-shard plan must transfer");
            let mut seen = vec![0usize; plan.tasks.len()];
            for (s, per_stage) in sp.stages.iter().enumerate() {
                for roster in per_stage {
                    for &t in roster {
                        seen[t] += 1;
                        assert_eq!(sp.shard[t], s);
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "stage rosters partition tasks");
        }
    }

    #[test]
    fn single_shard_has_no_transfers_and_one_stage() {
        let ir = lower_tiled(&spec(54, 16, 1));
        let plan = planner::plan(&ir, &PlanKnobs { fuse: true });
        let sp = ShardPlan::partition(&ir, &plan, 1);
        assert_eq!(sp.nstages, 1);
        assert!(sp.transfers.is_empty());
        assert_eq!(sp.nslots, 0);
    }

    #[test]
    fn mailbox_keys_and_publication() {
        let ir = lower_tiled(&spec(48, 16, 2));
        let plan = planner::plan(&ir, &PlanKnobs { fuse: true });
        let sp = ShardPlan::partition(&ir, &plan, 2);
        let mb = TileMailbox::new(&sp);
        let e = &sp.transfers[0];
        assert!(!mb.is_published(e.slot));
        assert!(!mb.all_published(&[e.slot]));
        mb.publish(e.slot);
        assert!(mb.is_published(e.slot));
        assert!(mb.all_published(&[e.slot]));
        let (i, j, step) = mb.key(e.slot);
        assert_eq!((i, j), e.coord);
        assert_eq!(step, e.from_task);
    }

    struct CountRunner(AtomicUsize);
    impl OpRunner for CountRunner {
        fn run_op(&self, _: Op) {
            self.0.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[test]
    fn sharded_execution_runs_every_op_exactly_once() {
        for fuse in [false, true] {
            let ir = lower_tiled(&spec(54, 11, 3));
            let plan = planner::plan(&ir, &PlanKnobs { fuse });
            let set = ShardSet::new(3, 1, Policy::Lws);
            let runner = Arc::new(CountRunner(AtomicUsize::new(0)));
            let cancel = CancelToken::new();
            let skipped = execute_sharded(&plan, &ir, runner.clone(), &set, 0, &cancel);
            assert_eq!(skipped, 0);
            assert_eq!(runner.0.load(Ordering::Relaxed), ir.len());
            set.shutdown();
        }
    }

    #[test]
    fn precancelled_sharded_execution_skips_everything() {
        let ir = lower_tiled(&spec(48, 16, 2));
        let plan = planner::plan(&ir, &PlanKnobs { fuse: true });
        let set = ShardSet::new(2, 1, Policy::Lws);
        let runner = Arc::new(CountRunner(AtomicUsize::new(0)));
        let cancel = CancelToken::new();
        cancel.cancel();
        let skipped = execute_sharded(&plan, &ir, runner.clone(), &set, 0, &cancel);
        assert_eq!(skipped, plan.len());
        assert_eq!(runner.0.load(Ordering::Relaxed), 0);
        set.shutdown();
    }
}
