//! The fusion planner: a pure, deterministic function of the IR and the
//! knobs.
//!
//! Three passes, in fixed order, each scanning nodes in ascending id:
//!
//! 1. **A — log-det into POTRF**: `LogDetReduce{k}` merges into the
//!    group of its sole predecessor `Potrf{k}` (the diagonal factor is
//!    hot in cache when the reduction runs).
//! 2. **B — TRSM into its trailing update**: `Trsm{k,i}` merges into
//!    `Syrk{k,i}`, the trailing consumer of the panel tile it just
//!    wrote, so the tile never round-trips through the store.
//! 3. **C — generation into the first consumer**: a `Generate` node has
//!    exactly one successor under STF inference (the first read-write op
//!    on its tile); the generate joins that group.
//!
//! **Legality.** Merging `u -> v` is safe iff no *other* path from `u`
//! reaches `v`.  Emission order is topological (every edge ascends node
//! ids), so anything reachable from a group has an id greater than the
//! group's minimum member; pass B therefore requires every other
//! predecessor group of `v` to sit entirely below `u`'s group
//! (`max_id(pred group) < min_id(u's group)`), which makes an indirect
//! path impossible.  Pass A's target has a single predecessor and pass
//! C's sources have none, so both are unconditionally safe.
//!
//! The plan orders fused groups by Kahn's algorithm with a
//! minimum-member-id heap tie-break: a pure function of the IR — two
//! runs over the same graph and knobs produce byte-identical plans.

use super::execution_plan::{ExecutionPlan, PlanTask};
use super::ir::{Op, TaskIR};
use std::collections::BinaryHeap;
use std::cmp::Reverse;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

// ---------------------------------------------------------------------
// The EXAGEOSTAT_FUSE knob (mirrors the SIMD dispatch override idiom:
// environment default resolved once, in-process override on top for
// fused-vs-unfused parity tests).
// ---------------------------------------------------------------------

/// 0 = no override, 1 = force off, 2 = force on.
static FUSE_OVERRIDE: AtomicU8 = AtomicU8::new(0);
static FUSE_ENV: OnceLock<bool> = OnceLock::new();

/// Force fusion on/off in-process, overriding `EXAGEOSTAT_FUSE`; pass
/// `None` to fall back to the environment.  Conformance tests toggle
/// this around evaluations to compare fused and unfused plans without
/// respawning the process.
pub fn set_fuse_override(fuse: Option<bool>) {
    let v = match fuse {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    FUSE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Serializes tests that toggle the process-global override: without
/// this, two tests in the same binary can interleave their
/// `set_fuse_override` / evaluate windows and observe each other's mode.
#[cfg(test)]
pub(crate) fn fuse_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Is producer→consumer fusion enabled?  Override first, then
/// `EXAGEOSTAT_FUSE=on|off` (default on).
pub fn fuse_enabled() -> bool {
    match FUSE_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => *FUSE_ENV.get_or_init(|| {
            !matches!(
                std::env::var("EXAGEOSTAT_FUSE").as_deref(),
                Ok("off") | Ok("0") | Ok("false") | Ok("no")
            )
        }),
    }
}

/// Planner knobs.  A plan is a pure function of `(IR, PlanKnobs)`.
#[derive(Copy, Clone, Debug)]
pub struct PlanKnobs {
    pub fuse: bool,
}

impl PlanKnobs {
    /// Resolve from the process environment / override.
    pub fn from_env() -> PlanKnobs {
        PlanKnobs {
            fuse: fuse_enabled(),
        }
    }
}

/// Union-find with the group root pinned to the minimum member id and
/// min/max member ids tracked per root (the legality certificate).
struct Groups {
    parent: Vec<usize>,
    min_id: Vec<usize>,
    max_id: Vec<usize>,
}

impl Groups {
    fn new(n: usize) -> Groups {
        Groups {
            parent: (0..n).collect(),
            min_id: (0..n).collect(),
            max_id: (0..n).collect(),
        }
    }
    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        let (keep, drop) = if ra < rb { (ra, rb) } else { (rb, ra) };
        self.parent[drop] = keep;
        self.min_id[keep] = self.min_id[keep].min(self.min_id[drop]);
        self.max_id[keep] = self.max_id[keep].max(self.max_id[drop]);
    }
}

/// Plan the IR: fuse (when enabled), then flatten groups into a
/// topologically ordered [`ExecutionPlan`].
pub fn plan(ir: &TaskIR, knobs: &PlanKnobs) -> ExecutionPlan {
    let n = ir.len();
    let mut g = Groups::new(n);

    if knobs.fuse {
        // Pass A: LogDetReduce{k} -> Potrf{k} (sole predecessor).
        for (id, node) in ir.nodes.iter().enumerate() {
            if let Op::LogDetReduce { .. } = node.op {
                if node.preds.len() == 1 {
                    g.union(node.preds[0], id);
                }
            }
        }
        // Pass B: Trsm{k,i} -> Syrk{k,i}.
        for (id, node) in ir.nodes.iter().enumerate() {
            let Op::Trsm { k, i } = node.op else {
                continue;
            };
            let Some(&v) = node
                .succs
                .iter()
                .find(|&&s| ir.nodes[s].op == Op::Syrk { k, i })
            else {
                continue;
            };
            // Legality: every other predecessor group of the SYRK must
            // lie entirely below this TRSM's group.
            let u_min = {
                let r = g.find(id);
                g.min_id[r]
            };
            let legal = ir.nodes[v].preds.iter().all(|&p| {
                if g.find(p) == g.find(id) {
                    return true;
                }
                let rp = g.find(p);
                g.max_id[rp] < u_min
            });
            if legal {
                g.union(id, v);
            }
        }
        // Pass C: Generate -> its sole successor (sources never create
        // cycles).
        for (id, node) in ir.nodes.iter().enumerate() {
            if let Op::Generate { .. } = node.op {
                if node.succs.len() == 1 {
                    g.union(id, node.succs[0]);
                }
            }
        }
    }

    // Collect members per group root, ascending ids (execution order
    // within a fused task; valid because edges ascend ids).
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n];
    for id in 0..n {
        let r = g.find(id);
        members[r].push(id);
    }

    // Group-level edges + Kahn with a min-member-id heap: deterministic
    // topological emission.
    let roots: Vec<usize> = (0..n).filter(|&id| g.find(id) == id).collect();
    let mut indeg: Vec<usize> = vec![0; n];
    let mut gsuccs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &r in &roots {
        let mut preds: Vec<usize> = members[r]
            .iter()
            .flat_map(|&m| ir.nodes[m].preds.iter().map(|&p| g.find(p)))
            .filter(|&pr| pr != r)
            .collect();
        preds.sort_unstable();
        preds.dedup();
        indeg[r] = preds.len();
        for p in preds {
            gsuccs[p].push(r);
        }
    }
    let mut heap: BinaryHeap<Reverse<usize>> = roots
        .iter()
        .copied()
        .filter(|&r| indeg[r] == 0)
        .map(Reverse)
        .collect();
    let mut order: Vec<usize> = Vec::with_capacity(roots.len());
    let mut pos: Vec<usize> = vec![usize::MAX; n];
    while let Some(Reverse(r)) = heap.pop() {
        pos[r] = order.len();
        order.push(r);
        for &s in &gsuccs[r] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                heap.push(Reverse(s));
            }
        }
    }
    assert_eq!(order.len(), roots.len(), "fusion produced a cyclic plan");

    // Flatten into PlanTasks.
    let tasks: Vec<PlanTask> = order
        .iter()
        .map(|&r| {
            let ops = members[r].clone();
            let kind = ops
                .iter()
                .map(|&m| ir.nodes[m].op.task_kind())
                .max_by_key(|k| k.priority)
                .expect("non-empty group");
            let bytes = ops.iter().map(|&m| ir.nodes[m].bytes).sum();
            let mut preds: Vec<usize> = ops
                .iter()
                .flat_map(|&m| ir.nodes[m].preds.iter().map(|&p| pos[g.find(p)]))
                .filter(|&p| p != pos[r])
                .collect();
            preds.sort_unstable();
            preds.dedup();
            PlanTask {
                ops,
                kind,
                bytes,
                preds,
                class: None,
            }
        })
        .collect();
    ExecutionPlan { tasks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::ir::{lower_tiled, TiledSpec};
    use std::collections::HashMap;

    fn dense_spec(n: usize, ts: usize) -> TiledSpec {
        TiledSpec {
            n,
            ts,
            band: None,
            mp_band: None,
            tlr: false,
            with_solve: true,
            with_logdet: true,
            owners: 1,
        }
    }

    fn graph_kind_counts(g: &crate::scheduler::TaskGraph) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for t in &g.tasks {
            *m.entry(t.kind.name).or_insert(0) += 1;
        }
        m
    }

    #[test]
    fn unfused_plan_is_one_task_per_node() {
        let ir = lower_tiled(&dense_spec(48, 16));
        let p = plan(&ir, &PlanKnobs { fuse: false });
        assert_eq!(p.tasks.len(), ir.len());
        assert!(p.tasks.iter().all(|t| t.ops.len() == 1));
    }

    #[test]
    fn fused_counts_on_known_shape() {
        // nt = 3 dense with solve: 25 IR nodes; fusion merges
        // 3 logdet->potrf + 3 trsm->syrk + 6 generate->consumer = 12,
        // leaving 13 tasks.
        let ir = lower_tiled(&dense_spec(48, 16));
        assert_eq!(ir.len(), 25);
        let p = plan(&ir, &PlanKnobs { fuse: true });
        assert_eq!(p.tasks.len(), 13);
        let merged: usize = p.tasks.iter().map(|t| t.ops.len() - 1).sum();
        assert_eq!(merged, 12);
        // The densest group: Generate(1,0), Generate(1,1), Trsm{0,1},
        // Syrk{0,1} execute as one task.
        assert!(p.tasks.iter().any(|t| t.ops.len() == 4));
    }

    #[test]
    fn plans_are_deterministic_and_topological() {
        let ir = lower_tiled(&dense_spec(96, 16));
        let knobs = PlanKnobs { fuse: true };
        let p1 = plan(&ir, &knobs);
        let p2 = plan(&ir, &knobs);
        for (a, b) in p1.tasks.iter().zip(&p2.tasks) {
            assert_eq!(a.ops, b.ops);
            assert_eq!(a.preds, b.preds);
        }
        // preds reference earlier plan positions only, and every IR
        // edge is honoured across groups.
        let task_of: HashMap<usize, usize> = p1
            .tasks
            .iter()
            .enumerate()
            .flat_map(|(ti, t)| t.ops.iter().map(move |&o| (o, ti)))
            .collect();
        for (ti, t) in p1.tasks.iter().enumerate() {
            for &p in &t.preds {
                assert!(p < ti);
            }
            for &o in &t.ops {
                for &pr in &ir.nodes[o].preds {
                    let pt = task_of[&pr];
                    assert!(
                        pt == ti || t.preds.contains(&pt),
                        "edge {pr}->{o} not honoured"
                    );
                }
            }
            // within-task order ascends (topological by construction)
            assert!(t.ops.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn unfused_plan_reproduces_legacy_task_counts() {
        // Build the legacy graph with the real emitters and compare
        // per-kind counts: identical, except the IR makes the host-side
        // log-det reduction explicit (+nt "logdet" nodes).
        use crate::likelihood::exact::submit_generation_with;
        use crate::likelihood::testutil::small_problem;
        use crate::linalg::cholesky::{
            new_fail_flag, submit_tiled_forward_solve_banded, submit_tiled_potrf, TileHandles,
        };
        use crate::linalg::tile::{TileMatrix, TileVector};
        use crate::scheduler::TaskGraph;

        let (n, ts) = (48, 16);
        let p = small_problem(n, 5);
        let theta = [1.0, 0.1, 0.5];
        for band in [None, Some(1)] {
            let a = TileMatrix::zeros(n, ts);
            let y = TileVector::from_slice(&p.z, ts);
            let mut g = TaskGraph::new();
            let hs = TileHandles::register(&mut g, a.nt());
            let engine = crate::backend::default_engine();
            submit_generation_with(&mut g, &a, &hs, &p, &theta, band, &engine, None);
            let fail = new_fail_flag();
            submit_tiled_potrf(&mut g, &a, &hs, band, &fail);
            let yh = g.register_many(y.nt());
            submit_tiled_forward_solve_banded(&mut g, &a, &hs, &y, &yh, band);
            let legacy = graph_kind_counts(&g);

            let mut spec = dense_spec(n, ts);
            spec.band = band;
            let ir = lower_tiled(&spec);
            let unfused = plan(&ir, &PlanKnobs { fuse: false });
            let mut got: HashMap<&'static str, usize> = HashMap::new();
            for t in &unfused.tasks {
                assert_eq!(t.ops.len(), 1);
                *got.entry(ir.nodes[t.ops[0]].op.task_kind().name).or_insert(0) += 1;
            }
            let nt = n.div_ceil(ts);
            assert_eq!(got.remove("logdet"), Some(nt), "band {band:?}");
            assert_eq!(got, legacy, "band {band:?}");
            assert_eq!(unfused.tasks.len(), g.len() + nt, "band {band:?}");
        }
    }

    #[test]
    fn env_override_wins_over_default() {
        let _serial = fuse_test_lock();
        set_fuse_override(Some(false));
        assert!(!fuse_enabled());
        assert!(!PlanKnobs::from_env().fuse);
        set_fuse_override(Some(true));
        assert!(fuse_enabled());
        set_fuse_override(None);
        let _ = fuse_enabled(); // env default; value depends on process env
        set_fuse_override(None);
    }
}
