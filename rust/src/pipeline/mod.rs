//! Pipeline lowering layer: every likelihood variant (exact / DST / MP /
//! TLR), simulation and kriging lowers into one typed task-graph IR
//! ([`ir`]), a pure planner pass fuses producer→consumer tile pairs
//! ([`planner`], `EXAGEOSTAT_FUSE=on|off`), and the flattened
//! [`ExecutionPlan`] executes on the existing runtime via
//! `ExecCtx::run_graph` ([`execution_plan`]).  No pipeline emits raw
//! `TaskGraph` nodes anymore; the legacy emitters in
//! [`crate::linalg::cholesky`] remain as the reference/test layer the
//! planner's parity suite compares against.
//!
//! Three executors share the IR:
//!
//! * [`run_tiled`] — dense-tile storage ([`TileMatrix`]): exact, DST
//!   (structural band), MP (precision dispatch on the tile's storage),
//!   simulation (factor only) and kriging (factor + solve).  Fused
//!   groups run as single runtime tasks.
//! * the out-of-core spill executor — a [`run_tiled`] call whose matrix
//!   carries a budget-bounded `TileStore` runs the plan serially in plan
//!   order, pinning each task's tile set resident first and prefetching
//!   the next panel on a dedicated I/O thread; bit-identical to the
//!   resident path on f64 exact/DST because the op bodies and their
//!   dependency-ordered inputs are unchanged.
//! * [`run_tlr`] — low-rank tiles mutate rank-adaptive heap storage, so
//!   the plan executes serially on the calling thread in plan order
//!   (valid because plans are topologically ordered), polling the
//!   context's cancellation token between tasks.
//!
//! A context carrying a [`shard::ShardSet`] routes [`run_tiled`] through
//! the sharding pass instead: the plan is partitioned 2-D block-cyclic
//! across N runtimes with explicit transfer edges at shard boundaries
//! ([`shard`] module docs), preserving every plan edge — so sharded and
//! single-runtime execution are bit-identical on f64 paths.
//!
//! The log-determinant is an explicit [`Op::LogDetReduce`] node in both
//! fused and unfused plans: each computes one diagonal tile's partial
//! ln-sum, and the host adds the partials in panel order — one summation
//! tree, so fused ≡ unfused bit-identically on f64 paths.

pub mod execution_plan;
pub mod ir;
pub mod planner;
pub mod shard;

pub use execution_plan::{ExecutionPlan, OpRunner, PlanTask};
pub use ir::{lower_tiled, Op, Precision, TaskIR, TiledSpec};
pub use planner::{fuse_enabled, plan, set_fuse_override, PlanKnobs};
pub use shard::{execute_sharded, ShardGrid, ShardPlan, ShardSet, TileMailbox};

use crate::api::ApiError;
use crate::backend::{ArcEngine, Engine as _};
use crate::covariance::{CovKernel, DistBlock, DistCache, DistanceMetric, Location};
use crate::likelihood::{ExecCtx, Problem};
use crate::linalg::blas::{
    dgemv_f32a, dgemv_raw, dpotrf_raw, dtrsm_rltn_raw, dtrsv_ln, gemm_mp, syrk_ln_mp,
    trsm_rltn_mp, with_stage_f64, MatMut, MatRef, Trans,
};
use crate::linalg::cholesky::{check_fail, new_fail_flag, FailFlag};
use crate::linalg::lowrank::{LrOpts, LrTile};
use crate::linalg::matrix::Matrix;
use crate::linalg::tile::{TileMatrix, TilePtr, TileVector};
use crate::scheduler::faults;
use crate::scheduler::runtime::TaskError;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The pipeline's `Err` cases map onto the context's cancel token: a
/// token fired for a deadline/watchdog reason reports `Timeout`, an
/// ordinary cancellation reports `Cancelled`.
fn cancel_error(ctx: &ExecCtx) -> ApiError {
    if ctx.cancel.timed_out() {
        ApiError::Timeout
    } else {
        ApiError::Cancelled
    }
}

/// Wrap a job-level [`TaskError`] for the anyhow chain.  Timeouts gain
/// an [`ApiError::Timeout`] marker so `api::error::is_timeout` matches
/// them; other kinds keep their typed payload downcastable.
fn task_error(e: TaskError) -> anyhow::Error {
    if matches!(e, TaskError::Timeout(_)) {
        anyhow::Error::new(e).context(ApiError::Timeout)
    } else {
        anyhow::Error::new(e)
    }
}

/// Result of a tiled pipeline run.  A non-SPD pivot is a *value*, not an
/// `Err` — callers format their variant-specific diagnostics; `Err` is
/// reserved for cancellation.
pub struct TiledOutcome {
    /// Global pivot index of the first non-positive-definite pivot.
    pub not_spd: Option<usize>,
    /// `log det Sigma` (0.0 when lowered without log-det nodes).
    pub logdet: f64,
}

#[inline]
fn tri(i: usize, j: usize) -> usize {
    i * (i + 1) / 2 + j
}

/// Executes IR ops against dense tile storage: one runner serves exact,
/// DST, MP, simulation and kriging — MP needs no flag because every op
/// body dispatches on the tile's storage precision, exactly like the
/// legacy emitters did.
struct TiledRunner {
    n: usize,
    ts: usize,
    /// Lower-packed tile pointers (`tri(i, j)`).
    ptrs: Vec<TilePtr>,
    /// Per-tile distance blocks of a warm session (same packing).
    blocks: Vec<Option<Arc<DistBlock>>>,
    /// Solve-vector segment pointers (empty when no solve is lowered).
    y: Vec<TilePtr>,
    kernel: Arc<dyn CovKernel>,
    locs: Arc<Vec<Location>>,
    metric: DistanceMetric,
    theta: Arc<Vec<f64>>,
    engine: ArcEngine,
    fail: FailFlag,
    /// Per-panel log-det partials (f64 bits; each slot written by
    /// exactly one `LogDetReduce` task).
    logdet: Vec<AtomicU64>,
}

impl TiledRunner {
    fn new(
        problem: &Problem,
        theta: &[f64],
        engine: &ArcEngine,
        dist: Option<&DistCache>,
        a: &TileMatrix,
        y: Option<&TileVector>,
    ) -> TiledRunner {
        let nt = a.nt();
        let spilled = a.store().is_some();
        let mut ptrs = Vec::with_capacity(nt * (nt + 1) / 2);
        let mut blocks = Vec::with_capacity(nt * (nt + 1) / 2);
        for i in 0..nt {
            for j in 0..=i {
                // Out-of-core matrix: pointers are only stable while
                // pinned, so the table starts as placeholders and the
                // spill executor installs the real pointer per task.
                ptrs.push(if spilled {
                    TilePtr::dangling()
                } else {
                    a.tile_ptr(i, j)
                });
                blocks.push(dist.and_then(|c| c.block(i, j)));
            }
        }
        let y = y
            .map(|v| (0..v.nt()).map(|i| v.seg_ptr(i)).collect())
            .unwrap_or_default();
        TiledRunner {
            n: a.n(),
            ts: a.ts(),
            ptrs,
            blocks,
            y,
            kernel: problem.kernel.clone(),
            locs: problem.locs.clone(),
            metric: problem.metric,
            theta: Arc::new(theta.to_vec()),
            engine: engine.clone(),
            fail: new_fail_flag(),
            logdet: (0..nt).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    #[inline]
    fn dim(&self, i: usize) -> usize {
        self.ts.min(self.n - i * self.ts)
    }

    /// Host-side sum of the per-panel partials, in panel order (the one
    /// summation tree both fused and unfused plans share).
    fn logdet(&self) -> f64 {
        2.0 * self
            .logdet
            .iter()
            .map(|s| f64::from_bits(s.load(Ordering::Acquire)))
            .sum::<f64>()
    }
}

impl OpRunner for TiledRunner {
    fn run_op(&self, op: Op) {
        let ts = self.ts;
        match op {
            Op::Generate { i, j } => {
                let (h, w) = (self.dim(i), self.dim(j));
                let block = self.blocks[tri(i, j)].as_deref();
                // SAFETY: plan ordering gives exclusive access to the tile.
                match unsafe { self.ptrs[tri(i, j)].mat_mut() } {
                    MatMut::F64(out) => {
                        self.engine.fill_tile(
                            self.kernel.as_ref(),
                            &self.theta,
                            &self.locs,
                            self.metric,
                            i * ts,
                            j * ts,
                            h,
                            w,
                            block,
                            out,
                        );
                    }
                    // MP off-band tile: evaluate into a reusable f64
                    // stage (the kernels are f64 code), demote on store.
                    MatMut::F32(out) => {
                        with_stage_f64(h * w, |stage| {
                            self.engine.fill_tile(
                                self.kernel.as_ref(),
                                &self.theta,
                                &self.locs,
                                self.metric,
                                i * ts,
                                j * ts,
                                h,
                                w,
                                block,
                                stage,
                            );
                            for (d, s) in out.iter_mut().zip(stage.iter()) {
                                *d = *s as f32;
                            }
                        });
                    }
                }
            }
            Op::Potrf { k } => {
                let hk = self.dim(k);
                // SAFETY: plan ordering gives exclusive access; diagonal
                // tiles are always f64.
                let t = unsafe { self.ptrs[tri(k, k)].as_mut() };
                if let Err(e) = dpotrf_raw(hk, t, hk) {
                    let _ = self.fail.compare_exchange(
                        0,
                        (k * ts) as i64 + e.pivot as i64 + 1,
                        Ordering::AcqRel,
                        Ordering::Relaxed,
                    );
                }
            }
            Op::LogDetReduce { k } => {
                let hk = self.dim(k);
                // SAFETY: plan ordering — the factor of tile (k, k) is
                // complete and no later writer exists.
                let t = unsafe { self.ptrs[tri(k, k)].as_ref() };
                let mut partial = 0.0;
                for d in 0..hk {
                    partial += t[d * hk + d].ln();
                }
                self.logdet[k].store(partial.to_bits(), Ordering::Release);
            }
            Op::Trsm { k, i } => {
                let (hk, hi) = (self.dim(k), self.dim(i));
                // SAFETY: plan ordering.  Diagonal factors are always
                // f64; the panel tile may be an MP off-band f32 tile.
                let lt = unsafe { self.ptrs[tri(k, k)].as_ref() };
                match unsafe { self.ptrs[tri(i, k)].mat_mut() } {
                    MatMut::F64(bt) => dtrsm_rltn_raw(hi, hk, lt, hk, bt, hi),
                    MatMut::F32(bt) => trsm_rltn_mp(hi, hk, lt, hk, bt, hi),
                }
            }
            Op::Syrk { k, i } => {
                let (hk, hi) = (self.dim(k), self.dim(i));
                // SAFETY: plan ordering.  syrk_ln_mp fast-paths all-f64.
                let s = unsafe { self.ptrs[tri(i, k)].mat_ref() };
                let d = unsafe { self.ptrs[tri(i, i)].mat_mut() };
                syrk_ln_mp(hi, hk, -1.0, s, hi, 1.0, d, hi);
            }
            Op::Gemm { k, i, j } => {
                let (hk, hi, hj) = (self.dim(k), self.dim(i), self.dim(j));
                // SAFETY: plan ordering.  gemm_mp fast-paths all-f64.
                let a_ = unsafe { self.ptrs[tri(i, k)].mat_ref() };
                let b_ = unsafe { self.ptrs[tri(j, k)].mat_ref() };
                let c_ = unsafe { self.ptrs[tri(i, j)].mat_mut() };
                gemm_mp(Trans::N, Trans::T, hi, hj, hk, -1.0, a_, hi, b_, hj, 1.0, c_, hi);
            }
            Op::SolveGemv { i, j } => {
                let (hi, wj) = (self.dim(i), self.dim(j));
                // SAFETY: plan ordering.  Off-band factor tiles may be
                // f32-stored (MP); vector segments are f64.
                let yjs = unsafe { self.y[j].as_ref() };
                let yis = unsafe { self.y[i].as_mut() };
                match unsafe { self.ptrs[tri(i, j)].mat_ref() } {
                    MatRef::F64(lt) => dgemv_raw(Trans::N, hi, wj, -1.0, lt, hi, yjs, 1.0, yis),
                    MatRef::F32(lt) => dgemv_f32a(hi, wj, -1.0, lt, hi, yjs, yis),
                }
            }
            Op::SolveTrsv { i } => {
                let hi = self.dim(i);
                // SAFETY: plan ordering.
                let lt = unsafe { self.ptrs[tri(i, i)].as_ref() };
                let ys = unsafe { self.y[i].as_mut() };
                dtrsv_ln(hi, lt, hi, ys);
            }
        }
    }
}

/// Lower → plan → execute a dense-tile pipeline on the context's runtime.
///
/// * `band` is the *structural* DST band (tiles outside it are never
///   generated or updated); MP's precision band rides on `a`'s storage
///   layout (`TileMatrix::zeros_mp`), not on this parameter.
/// * `y = Some` lowers the forward solve `y <- L^{-1} y` after the
///   factorization; `with_logdet` lowers the per-panel log-det nodes.
///
/// Returns `Err` only on cancellation (the context's token fired and the
/// runtime skipped tasks); a non-SPD pivot comes back as a value for the
/// caller to wrap in its variant-specific message.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled(
    problem: &Problem,
    theta: &[f64],
    ctx: &ExecCtx,
    dist: Option<&DistCache>,
    a: &TileMatrix,
    y: Option<&TileVector>,
    band: Option<usize>,
    with_logdet: bool,
) -> anyhow::Result<TiledOutcome> {
    // An out-of-core matrix runs on the budget-bounded spill executor —
    // it wins over sharding: a budgeted workspace means this machine
    // cannot hold the tile set, so fanning the plan out across runtimes
    // that share its memory would defeat the budget.
    if a.store().is_some() {
        return run_tiled_spilled(problem, theta, ctx, dist, a, y, band, with_logdet);
    }
    // A context carrying a shard set partitions the plan 2-D
    // block-cyclically across the set's runtimes (tile grids below the
    // set's `min_nt` threshold are not worth splitting and run whole on
    // the context's own runtime).
    let shards = match &ctx.shards {
        Some(s) if s.nshards() > 1 && a.nt() >= s.min_nt => Some(s),
        _ => None,
    };
    let spec = TiledSpec {
        n: a.n(),
        ts: a.ts(),
        band,
        mp_band: a.mp_band(),
        tlr: false,
        with_solve: y.is_some(),
        with_logdet,
        owners: shards.map_or(1, |s| s.nshards()),
    };
    let ir = lower_tiled(&spec);
    let mut plan = planner::plan(&ir, &PlanKnobs::from_env());
    let runner = Arc::new(TiledRunner::new(problem, theta, &ctx.engine, dist, a, y));
    let skipped = if let Some(set) = shards {
        // Sharded execution stays class-blind: each shard runtime runs
        // its partition on whatever workers it has.
        shard::execute_sharded(&plan, &ir, runner.clone(), set, ctx.job_prio, &ctx.cancel)
    } else {
        // Heterogeneous runtime: place each plan task on a worker class
        // (HEFT over the runtime's accumulated per-(kind, class) costs;
        // static eligibility before any costs exist).  Placement only
        // decides *where* tasks run — op bodies, dependency edges and
        // the host-side log-det summation are untouched, so results are
        // bit-identical to the unplaced schedule.
        if ctx.runtime.nclasses() > 1 {
            crate::scheduler::placement::Placer::new(&ctx.runtime.classes())
                .with_cost(ctx.runtime.cost_model_by_class())
                .place(&mut plan);
        }
        let g = plan.instantiate(&ir, runner.clone());
        // Typed failure (a task panic past its retry budget, an
        // injected fault) propagates as a value so the coordinator's
        // whole-job retry can see it — not as a re-raised panic.
        ctx.run_graph_result(g).map_err(task_error)?.tasks_skipped
    };
    if skipped > 0 {
        // Cancelled mid-flight: the factor is incomplete, so neither the
        // fail flag nor the log-det slots are meaningful.
        return Err(cancel_error(ctx).into());
    }
    let not_spd = check_fail(&runner.fail).err().map(|e| e.pivot);
    let logdet = if with_logdet && not_spd.is_none() {
        runner.logdet()
    } else {
        0.0
    };
    Ok(TiledOutcome { not_spd, logdet })
}

/// Prefetch horizon of the spill executor, in plan steps: tiles first
/// needed this many tasks ahead are requested on the I/O lane.  Deep
/// enough to cover one disk read per compute task, shallow enough that
/// prefetched tiles don't crowd the budget.
const SPILL_LOOKAHEAD: usize = 8;

/// Plan-derived residency schedule for one spill run: when each tile is
/// used, so eviction is Belady-exact and write-only first touches skip
/// the read-back.
struct SpillSchedule {
    /// Per slot (`tri(i, j)`): ascending plan steps touching the tile.
    /// The executor pops the front as steps retire; the front is the
    /// tile's next-use the store evicts against.
    uses: Vec<std::collections::VecDeque<u32>>,
    /// Per slot: the step whose Generate fully overwrites the tile
    /// (`u32::MAX` if the plan never generates it) — pinned for write,
    /// skipping the spill-file read.
    gen_step: Vec<u32>,
    /// Per step: deduped slots touched, in first-touch order (the pin
    /// set; also the prefetch request list for the lookahead window).
    step_tiles: Vec<Vec<u32>>,
}

fn build_spill_schedule(plan: &ExecutionPlan, ir: &TaskIR, nslots: usize) -> SpillSchedule {
    let mut uses = vec![std::collections::VecDeque::new(); nslots];
    let mut gen_step = vec![u32::MAX; nslots];
    let mut step_tiles = Vec::with_capacity(plan.tasks.len());
    for (s, task) in plan.tasks.iter().enumerate() {
        let mut tiles: Vec<u32> = Vec::new();
        for &id in &task.ops {
            let op = ir.nodes[id].op;
            for &(i, j) in op.tile_operands().as_slice() {
                let t = tri(i, j) as u32;
                if !tiles.contains(&t) {
                    tiles.push(t);
                }
                if matches!(op, Op::Generate { .. }) {
                    gen_step[t as usize] = s as u32;
                }
            }
        }
        for &t in &tiles {
            uses[t as usize].push_back(s as u32);
        }
        step_tiles.push(tiles);
    }
    SpillSchedule {
        uses,
        gen_step,
        step_tiles,
    }
}

/// Queue prefetches for every tile step `target` touches — except tiles
/// that step regenerates (their pin is write-only; reading stale spill
/// data back would be wasted I/O and budget).
fn send_prefetches(tx: &std::sync::mpsc::Sender<u32>, sched: &SpillSchedule, target: usize) {
    for &t in &sched.step_tiles[target] {
        if sched.gen_step[t as usize] != target as u32 {
            let _ = tx.send(t);
        }
    }
}

/// The out-of-core executor: the plan runs serially on the calling
/// thread in plan order (topologically valid, same as [`run_tlr`]),
/// each task pinning its tile set in the budget-bounded [`TileStore`]
/// before running and feeding next-use/dead hints back afterwards, while
/// a dedicated I/O thread prefetches the tiles of the next
/// [`SPILL_LOOKAHEAD`] steps.  Serial op execution means every op sees
/// exactly the operand values of the resident executor's dependency
/// order, so f64 exact/DST results are bit-identical to the resident
/// path — spill round-trips are byte-exact — and the host-side log-det
/// summation tree is shared via the same [`TiledRunner`].
#[allow(clippy::too_many_arguments)]
fn run_tiled_spilled(
    problem: &Problem,
    theta: &[f64],
    ctx: &ExecCtx,
    dist: Option<&DistCache>,
    a: &TileMatrix,
    y: Option<&TileVector>,
    band: Option<usize>,
    with_logdet: bool,
) -> anyhow::Result<TiledOutcome> {
    let store = a.store().expect("run_tiled_spilled needs an out-of-core matrix");
    let spec = TiledSpec {
        n: a.n(),
        ts: a.ts(),
        band,
        mp_band: a.mp_band(),
        tlr: false,
        with_solve: y.is_some(),
        with_logdet,
        owners: 1,
    };
    let ir = lower_tiled(&spec);
    let plan = planner::plan(&ir, &PlanKnobs::from_env());
    let mut runner = TiledRunner::new(problem, theta, &ctx.engine, dist, a, y);
    let mut sched = build_spill_schedule(&plan, &ir, runner.ptrs.len());
    // Reset residual next-use state from any previous eval on this
    // workspace: every slot gets its first step under the new plan, and
    // slots the plan never touches (off-band DST tiles) go dead — a warm
    // re-eval starts from a clean, minimal residency.
    for t in 0..runner.ptrs.len() {
        store.set_next_use(t, sched.uses[t].front().map(|&s| s as u64));
    }
    let cancelled = std::thread::scope(|sc| -> anyhow::Result<bool> {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        // The I/O lane: drains prefetch requests until the executor
        // drops `tx`; the scope joins it on exit.  A failed prefetch
        // rolled its reservation back and left the slot spilled, so
        // the demand pin retries the read itself — the lane just stops
        // (dropping `rx`; later sends are silently ignored) and lets
        // the executor's own pin be the authoritative failure point.
        sc.spawn(move || {
            for t in rx {
                if store.prefetch(t as usize).is_err() {
                    break;
                }
            }
        });
        for s in 1..SPILL_LOOKAHEAD.min(plan.tasks.len()) {
            send_prefetches(&tx, &sched, s);
        }
        let mut pinned: Vec<u32> = Vec::with_capacity(4);
        for (step, task) in plan.tasks.iter().enumerate() {
            if ctx.cancel.is_cancelled() {
                return Ok(true);
            }
            pinned.clear();
            for &id in &task.ops {
                for &(i, j) in ir.nodes[id].op.tile_operands().as_slice() {
                    let t = tri(i, j) as u32;
                    if !pinned.contains(&t) {
                        // First touch by this task's Generate: the op
                        // overwrites the whole tile, so materialize
                        // without reading stale spill data back.
                        let res = if sched.gen_step[t as usize] == step as u32 {
                            store.pin_for_write(t as usize)
                        } else {
                            store.pin(t as usize)
                        };
                        match res {
                            Ok(ptr) => {
                                runner.ptrs[t as usize] = ptr;
                                pinned.push(t);
                            }
                            Err(e) => {
                                // Release this step's pins so the store
                                // stays evictable (the session keeps the
                                // workspace across requests), then
                                // surface the typed I/O failure.
                                for &p in &pinned {
                                    store.unpin(p as usize);
                                }
                                return Err(task_error(TaskError::Io(format!(
                                    "tile spill at plan step {step}: {e}"
                                ))));
                            }
                        }
                    }
                }
            }
            // Injected faults fire at this serial task boundary exactly
            // as they do on runtime workers; a *real* panic of a
            // non-idempotent group propagates and is typed below.
            let idem = task.ops.iter().all(|&id| {
                matches!(
                    ir.nodes[id].op,
                    Op::Generate { .. } | Op::LogDetReduce { .. }
                )
            });
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                faults::with_task_faults(idem, || {
                    for &id in &task.ops {
                        runner.run_op(ir.nodes[id].op);
                    }
                })
            }));
            if let Err(p) = run {
                let msg = crate::scheduler::runtime::panic_message(p.as_ref());
                for &t in &pinned {
                    store.unpin(t as usize);
                }
                return Err(task_error(TaskError::Panic(msg)));
            }
            for &t in &pinned {
                let q = &mut sched.uses[t as usize];
                while q.front() == Some(&(step as u32)) {
                    q.pop_front();
                }
                // Hint before unpin: a tile with no further use is
                // dropped by the unpin itself (eager panel release).
                store.set_next_use(t as usize, q.front().map(|&s| s as u64));
                store.unpin(t as usize);
            }
            let target = step + SPILL_LOOKAHEAD;
            if target < plan.tasks.len() {
                send_prefetches(&tx, &sched, target);
            }
        }
        Ok(false)
    })?;
    if cancelled {
        return Err(cancel_error(ctx).into());
    }
    let not_spd = check_fail(&runner.fail).err().map(|e| e.pivot);
    let logdet = if with_logdet && not_spd.is_none() {
        runner.logdet()
    } else {
        0.0
    };
    Ok(TiledOutcome { not_spd, logdet })
}

/// Result of a TLR pipeline run (same contract as [`TiledOutcome`]).
pub struct TlrOutcome {
    pub not_spd: Option<usize>,
    pub logdet: f64,
}

/// Lower → plan → execute the TLR pipeline serially on the calling
/// thread.  `problem` must already be Morton-permuted and `y` loaded
/// with the (permuted) observations; on return `y` holds `L^{-1} y`.
/// The context's cancellation token is polled between plan tasks.
pub fn run_tlr(
    problem: &Problem,
    theta: &[f64],
    opts: LrOpts,
    ctx: &ExecCtx,
    dist: Option<&DistCache>,
    y: &mut [f64],
) -> anyhow::Result<TlrOutcome> {
    let n = problem.dim();
    let ts = ctx.ts;
    let nt = n.div_ceil(ts);
    let dim = |i: usize| ts.min(n - i * ts);
    let low_index = |i: usize, j: usize| i * (i - 1) / 2 + j;
    let spec = TiledSpec {
        n,
        ts,
        band: None,
        mp_band: None,
        tlr: true,
        with_solve: true,
        with_logdet: true,
        owners: 1,
    };
    let ir = lower_tiled(&spec);
    let plan = planner::plan(&ir, &PlanKnobs::from_env());

    let mut diag: Vec<Matrix> = (0..nt).map(|i| Matrix::zeros(dim(i), dim(i))).collect();
    let mut low: Vec<LrTile> = (0..nt)
        .flat_map(|i| (0..i).map(move |j| (i, j)))
        .map(|(i, j)| LrTile::zero(dim(i), dim(j)))
        .collect();
    let mut buf = vec![0.0f64; ts * ts];
    let mut logdet_parts = vec![0.0f64; nt];
    let mut pivot_err: Option<usize> = None;

    'outer: for task in &plan.tasks {
        if ctx.cancel.is_cancelled() {
            return Err(cancel_error(ctx).into());
        }
        // Fault-injection boundary (panic/stall draw, bounded retry).
        // TLR ops mutate rank-adaptive heap state in place, so bodies
        // are never re-run here — only the pre-body injection point is
        // exercised; a budget-exhausted injection surfaces typed.
        if let Err(p) = std::panic::catch_unwind(|| faults::with_task_faults(false, || ())) {
            let msg = crate::scheduler::runtime::panic_message(p.as_ref());
            return Err(task_error(TaskError::Panic(msg)));
        }
        for &id in &task.ops {
            match ir.nodes[id].op {
                Op::Generate { i, j } => {
                    let (h, w) = (dim(i), dim(j));
                    let block = dist.and_then(|c| c.block(i, j));
                    ctx.engine.fill_tile(
                        problem.kernel.as_ref(),
                        theta,
                        &problem.locs,
                        problem.metric,
                        i * ts,
                        j * ts,
                        h,
                        w,
                        block.as_deref(),
                        &mut buf,
                    );
                    if i == j {
                        diag[i] = Matrix::from_col_major(h, h, &buf[..h * h]);
                    } else {
                        low[low_index(i, j)] = LrTile::compress_aca(h, w, &buf[..h * w], opts);
                    }
                }
                Op::Potrf { k } => {
                    let d = &mut diag[k];
                    let h = d.rows();
                    if let Err(e) = dpotrf_raw(h, d.as_mut_slice(), h) {
                        pivot_err = Some(k * ts + e.pivot);
                        break 'outer;
                    }
                    d.zero_upper();
                }
                Op::LogDetReduce { k } => {
                    let d = &diag[k];
                    logdet_parts[k] = (0..d.rows()).map(|i| d[(i, i)].ln()).sum();
                }
                Op::Trsm { k, i } => {
                    let (l, h) = (diag[k].as_slice(), diag[k].rows());
                    low[low_index(i, k)].trsm_right_lt(l, h);
                }
                Op::Syrk { k, i } => {
                    low[low_index(i, k)].syrk_into(&mut diag[i]);
                }
                Op::Gemm { k, i, j } => {
                    let prod = LrTile::lr_abt(&low[low_index(i, k)], &low[low_index(j, k)]);
                    low[low_index(i, j)].add_scaled(-1.0, &prod, opts);
                }
                Op::SolveGemv { i, j } => {
                    let (lo, hi) = (i * ts, n.min(i * ts + ts));
                    let (jlo, jhi) = (j * ts, n.min(j * ts + ts));
                    // split-borrow y: [jlo..jhi] read, [lo..hi] written
                    let (head, tail) = y.split_at_mut(lo);
                    low[low_index(i, j)].gemv_sub(&head[jlo..jhi], &mut tail[..hi - lo]);
                }
                Op::SolveTrsv { i } => {
                    let (lo, hi) = (i * ts, n.min(i * ts + ts));
                    let d = &diag[i];
                    dtrsv_ln(hi - lo, d.as_slice(), d.rows(), &mut y[lo..hi]);
                }
            }
        }
    }
    Ok(TlrOutcome {
        not_spd: pivot_err,
        logdet: if pivot_err.is_none() {
            2.0 * logdet_parts.iter().sum::<f64>()
        } else {
            0.0
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::likelihood::testutil::{dense_oracle, small_problem};
    use crate::scheduler::pool::Policy;

    /// run_tiled under both planner modes, all dense knob combinations,
    /// against the dense oracle — the in-crate half of the fused-vs-
    /// unfused conformance wall (the cross-variant half lives in
    /// `tests/conformance.rs`).
    #[test]
    fn fused_and_unfused_match_oracle_bit_identically() {
        let _serial = planner::fuse_test_lock();
        let p = small_problem(54, 41);
        let theta = [1.2, 0.12, 0.5];
        let ctx = ExecCtx::new(2, 16, Policy::Lws);
        let oracle = dense_oracle(&p, &theta);
        let mut results = Vec::new();
        for fuse in [false, true] {
            set_fuse_override(Some(fuse));
            let a = TileMatrix::zeros(p.dim(), ctx.ts);
            let y = TileVector::from_slice(&p.z, ctx.ts);
            let out = run_tiled(&p, &theta, &ctx, None, &a, Some(&y), None, true).unwrap();
            assert_eq!(out.not_spd, None);
            results.push((out.logdet, y.dot_self()));
        }
        set_fuse_override(None);
        assert!((results[0].0 - oracle.logdet).abs() < 1e-8);
        assert!((results[0].1 - oracle.sse).abs() < 1e-8);
        // f64 task bodies are identical closures over identical inputs:
        // fused and unfused runs must agree to the bit.
        assert_eq!(results[0].0.to_bits(), results[1].0.to_bits(), "logdet");
        assert_eq!(results[0].1.to_bits(), results[1].1.to_bits(), "sse");
    }

    /// Sharded execution preserves every plan edge and the host-side
    /// log-det summation order, so it must reproduce the single-runtime
    /// result to the bit (the cross-variant half lives in
    /// `tests/sharded.rs`).
    #[test]
    fn sharded_run_tiled_matches_single_runtime_bit_identically() {
        let p = small_problem(54, 44);
        let theta = [1.1, 0.11, 0.5];
        let mut results = Vec::new();
        for nshards in [1usize, 3] {
            let mut ctx = ExecCtx::new(2, 11, Policy::Lws);
            let owned = if nshards > 1 {
                let set = Arc::new(shard::ShardSet::new(nshards, 1, Policy::Lws));
                ctx.shards = Some(set.clone());
                Some(set)
            } else {
                None
            };
            let a = TileMatrix::zeros(p.dim(), ctx.ts);
            let y = TileVector::from_slice(&p.z, ctx.ts);
            let out = run_tiled(&p, &theta, &ctx, None, &a, Some(&y), None, true).unwrap();
            assert_eq!(out.not_spd, None);
            results.push((out.logdet, y.dot_self()));
            if let Some(set) = owned {
                set.shutdown();
            }
        }
        assert_eq!(results[0].0.to_bits(), results[1].0.to_bits(), "logdet");
        assert_eq!(results[0].1.to_bits(), results[1].1.to_bits(), "sse");
    }

    /// The out-of-core executor preserves every op body and the
    /// dependency-ordered inputs, so a run under a tiny tile budget must
    /// reproduce the resident result to the bit — while never holding
    /// more than the budget resident.
    #[test]
    fn spilled_run_tiled_matches_resident_bit_identically() {
        let _serial = planner::fuse_test_lock();
        let p = small_problem(54, 45);
        let theta = [1.15, 0.13, 0.5];
        let ctx = ExecCtx::new(2, 16, Policy::Lws);
        let mut results = Vec::new();
        for budget in [None, Some(1usize)] {
            let a = match budget {
                None => TileMatrix::zeros(p.dim(), ctx.ts),
                Some(b) => TileMatrix::zeros_spill(p.dim(), ctx.ts, None, b).unwrap(),
            };
            let y = TileVector::from_slice(&p.z, ctx.ts);
            let out = run_tiled(&p, &theta, &ctx, None, &a, Some(&y), None, true).unwrap();
            assert_eq!(out.not_spd, None);
            results.push((out.logdet, y.dot_self()));
            if let Some(st) = a.store() {
                assert!(st.peak_resident_bytes() <= st.budget());
                assert!(st.budget() < a.n() * a.n() * 8, "budget must bind");
            }
        }
        assert_eq!(results[0].0.to_bits(), results[1].0.to_bits(), "logdet");
        assert_eq!(results[0].1.to_bits(), results[1].1.to_bits(), "sse");
    }

    #[test]
    fn non_spd_pivot_is_reported_as_value() {
        // Duplicate locations without nugget => singular covariance.
        let mut p = small_problem(12, 42);
        let mut locs = (*p.locs).clone();
        locs[5] = locs[4];
        p.locs = Arc::new(locs);
        let ctx = ExecCtx::new(1, 4, Policy::Eager);
        let a = TileMatrix::zeros(p.dim(), ctx.ts);
        let out = run_tiled(&p, &[1.0, 0.1, 0.5], &ctx, None, &a, None, None, false).unwrap();
        assert!(out.not_spd.is_some());
    }

    #[test]
    fn precancelled_context_reports_cancelled() {
        let p = small_problem(32, 43);
        let mut ctx = ExecCtx::new(1, 8, Policy::Eager);
        ctx.cancel.cancel();
        let a = TileMatrix::zeros(p.dim(), ctx.ts);
        let err = run_tiled(&p, &[1.0, 0.1, 0.5], &ctx, None, &a, None, None, true).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ApiError>(), Some(ApiError::Cancelled)),
            "{err:#}"
        );
        let mut y = (*p.z).clone();
        let opts = LrOpts { tol: 1e-7, max_rank: usize::MAX };
        let err = run_tlr(&p, &[1.0, 0.1, 0.5], opts, &ctx, None, &mut y).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ApiError>(), Some(ApiError::Cancelled)),
            "{err:#}"
        );
    }
}
