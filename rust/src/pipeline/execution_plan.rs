//! The flattened output of the planner: an [`ExecutionPlan`] is a list
//! of [`PlanTask`]s in topological order, each a fused group of IR ops
//! executed back-to-back on one worker (the tile a producer writes is
//! still hot when its consumer runs).
//!
//! The plan is runtime-agnostic data.  [`ExecutionPlan::instantiate`]
//! lowers it onto the existing STF [`TaskGraph`] through an
//! [`OpRunner`] — the object that knows how to execute a single IR op
//! against concrete tile storage — so `ExecCtx::run_graph` and the
//! whole scheduler stack (priorities, profiling, cancellation) apply
//! unchanged.  Pipelines whose op bodies are not `Send` (TLR's
//! rank-mutating tiles) instead walk `plan.tasks` serially in order,
//! which is valid for the same reason `instantiate` is: `preds` only
//! reference earlier plan positions.

use super::ir::{Op, TaskIR};
use crate::scheduler::faults;
use crate::scheduler::placement::WorkerClass;
use crate::scheduler::{TaskGraph, TaskKind};
use std::collections::HashMap;
use std::sync::Arc;

/// One schedulable task: a fused group of IR ops.
#[derive(Clone, Debug)]
pub struct PlanTask {
    /// IR node ids, ascending — a valid execution order within the
    /// group because every IR edge ascends node ids.
    pub ops: Vec<usize>,
    /// Scheduler kind of the group: the highest-priority member's kind,
    /// so a fused `generate+potrf` still sorts as a critical-path POTRF.
    pub kind: TaskKind,
    /// Total bytes moved by the group (sum of member estimates); feeds
    /// the same locality heuristics as unfused tasks.
    pub bytes: usize,
    /// Indices of earlier plan tasks this one depends on (deduplicated,
    /// ascending, all `<` this task's own index).
    pub preds: Vec<usize>,
    /// Worker class assigned by the [`crate::scheduler::placement::Placer`]
    /// (`None` until placed / on homogeneous runtimes — the runtime's
    /// default class runs the task).
    pub class: Option<WorkerClass>,
}

/// A topologically ordered, fused task list ready for the runtime.
#[derive(Clone, Debug, Default)]
pub struct ExecutionPlan {
    pub tasks: Vec<PlanTask>,
}

/// Executes one IR op against concrete storage.  Implementations carry
/// the tile pointers / buffers; the plan carries only op identities.
pub trait OpRunner {
    fn run_op(&self, op: Op);
}

impl ExecutionPlan {
    pub fn len(&self) -> usize {
        self.tasks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Per-kind task counts (the fused analogue of counting a
    /// `TaskGraph`'s nodes by kind).
    pub fn kind_counts(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for t in &self.tasks {
            *m.entry(t.kind.name).or_insert(0) += 1;
        }
        m
    }

    /// Lower the plan onto an STF [`TaskGraph`]: one graph task per
    /// plan task, dependence edges wired explicitly from `preds`
    /// (the planner already resolved them from the IR, so no handle
    /// re-inference is needed or wanted — fusion deliberately collapses
    /// handles that STF would treat as distinct).
    ///
    /// Each task body runs inside the fault-injection boundary
    /// (`scheduler::faults::with_task_faults`): groups whose every op
    /// is **idempotent** — `Generate` overwrites its whole tile and
    /// `LogDetReduce` overwrites its partial slot, so re-running from
    /// still-valid inputs reproduces the same bytes — get a bounded
    /// in-place retry on a real panic; all groups get the pre-body
    /// injection point (free when the injector is disarmed).
    pub fn instantiate<R: OpRunner + Send + Sync + 'static>(
        &self,
        ir: &TaskIR,
        runner: Arc<R>,
    ) -> TaskGraph {
        let mut g = TaskGraph::new();
        let mut tid: Vec<usize> = Vec::with_capacity(self.tasks.len());
        for t in &self.tasks {
            let preds: Vec<usize> = t.preds.iter().map(|&p| tid[p]).collect();
            let ops: Vec<Op> = t.ops.iter().map(|&o| ir.nodes[o].op).collect();
            let idem = ops
                .iter()
                .all(|op| matches!(op, Op::Generate { .. } | Op::LogDetReduce { .. }));
            let r = runner.clone();
            let id = g.submit_dep(t.kind, &preds, t.bytes, move || {
                faults::with_task_faults(idem, || {
                    for op in &ops {
                        r.run_op(*op);
                    }
                });
            });
            if let Some(c) = t.class {
                g.set_class(id, c);
            }
            tid.push(id);
        }
        g
    }
}
