//! Async job layer: [`Client`] wraps a [`Dispatch`]er (a
//! [`crate::coordinator::Coordinator`], or the sharded fan-out
//! [`crate::coordinator::ShardedCoordinator`]) with non-blocking
//! `submit(Request) -> Ticket`.
//!
//! [`crate::coordinator::Coordinator::run`] is synchronous — it occupies the caller's
//! thread for the whole request.  A [`Client`] owns a small pool of
//! request-runner threads (cheap drivers; the heavy tile work still
//! runs on the coordinator's shared worker runtime) and hands back a
//! [`Ticket`] per submission:
//!
//! * [`Ticket::wait`] blocks for the outcome ([`Completion`]);
//! * [`Ticket::try_wait`] polls without blocking;
//! * [`Ticket::cancel`] fires the request's [`CancelToken`] —
//!   a still-queued request is skipped entirely, a running one stops
//!   between optimizer evaluations and its not-yet-started runtime
//!   tasks are skipped by the workers (see
//!   `scheduler::runtime::Runtime::submit_job`), and `wait` reports
//!   [`Completion::Cancelled`].
//!
//! Every later scale-out (distributed coordinator, GPU worker class —
//! see ROADMAP.md) slots in as a new backend behind this same
//! submit/ticket surface.

use super::{Dispatch, Request, Response};
use crate::api::{is_cancelled, is_timeout};
use crate::scheduler::runtime::CancelToken;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Final outcome of a submitted request.
#[derive(Clone, Debug)]
pub enum Completion {
    /// The request ran to completion.
    Done(Response),
    /// The request was cancelled (before or during execution).
    Cancelled,
    /// The request exceeded its deadline (or the runtime watchdog fired)
    /// and was cancelled with a timeout reason.
    TimedOut,
    /// The request failed; the formatted error chain.
    Failed(String),
}

struct TicketState {
    cancel: CancelToken,
    /// Absolute expiry stamped at submission from the request's
    /// `deadline_ms` (`None` = unbounded).  Enforced cooperatively: a
    /// blocked [`Ticket::wait`] and the serve loop's reaper both fire
    /// the timeout cancellation once it passes.
    deadline: Option<Instant>,
    slot: Mutex<Option<Completion>>,
    cv: Condvar,
}

/// Handle to one in-flight request (see module docs).
pub struct Ticket {
    id: u64,
    state: Arc<TicketState>,
}

impl Ticket {
    /// Client-local submission id (ordering of `submit` calls).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation (idempotent; losing the race against
    /// completion is fine — the outcome is whatever landed first).
    pub fn cancel(&self) {
        self.state.cancel.cancel();
    }

    /// Has [`Ticket::cancel`] been called?
    pub fn is_cancelled(&self) -> bool {
        self.state.cancel.is_cancelled()
    }

    /// Cancel with a *timeout* reason: [`Ticket::wait`] reports
    /// [`Completion::TimedOut`] instead of `Cancelled`.  What the
    /// deadline machinery fires; also useful for caller-side timers.
    pub fn cancel_timeout(&self) {
        self.state.cancel.cancel_with_timeout();
    }

    /// The absolute deadline stamped at submission (`None` = none).
    pub fn deadline(&self) -> Option<Instant> {
        self.state.deadline
    }

    /// Fire the timeout cancellation if the deadline has passed with the
    /// request still unfinished; returns whether it fired.  The serve
    /// loop's reaper calls this each sweep so deadlines are enforced
    /// even when nobody blocks in [`Ticket::wait`].
    pub fn enforce_deadline(&self) -> bool {
        match self.state.deadline {
            Some(d) if Instant::now() >= d && self.try_wait().is_none() => {
                self.state.cancel.cancel_with_timeout();
                true
            }
            _ => false,
        }
    }

    /// Non-blocking poll: `Some(outcome)` once the request finished.
    pub fn try_wait(&self) -> Option<Completion> {
        self.state.slot.lock().unwrap().clone()
    }

    /// Block until the request finishes and return its outcome.  A
    /// ticket with a deadline fires the timeout cancellation the moment
    /// the deadline passes, then keeps blocking — the runner observes
    /// the token at its next boundary and fills the slot promptly
    /// (normally with [`Completion::TimedOut`]; a result that wins the
    /// race is kept as [`Completion::Done`]).
    pub fn wait(&self) -> Completion {
        let mut slot = self.state.slot.lock().unwrap();
        loop {
            if let Some(c) = slot.clone() {
                return c;
            }
            match self.state.deadline {
                None => slot = self.state.cv.wait(slot).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        self.state.cancel.cancel_with_timeout();
                        slot = self.state.cv.wait(slot).unwrap();
                    } else {
                        let (s, _) = self.state.cv.wait_timeout(slot, d - now).unwrap();
                        slot = s;
                    }
                }
            }
        }
    }

    /// Block up to `timeout` for the outcome; `None` when the request is
    /// still in flight afterwards.  Purely observational — expiring here
    /// cancels nothing (use a request `deadline_ms` or
    /// [`Ticket::cancel_timeout`] to bound the job itself).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Completion> {
        let until = Instant::now() + timeout;
        let mut slot = self.state.slot.lock().unwrap();
        loop {
            if slot.is_some() {
                return slot.clone();
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (s, _) = self.state.cv.wait_timeout(slot, until - now).unwrap();
            slot = s;
        }
    }
}

struct Submission {
    state: Arc<TicketState>,
    req: Request,
}

/// Non-blocking submit/ticket front-end over a shared [`Dispatch`]er
/// (see module docs).
pub struct Client {
    coord: Arc<dyn Dispatch>,
    tx: Option<Sender<Submission>>,
    runners: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Client {
    /// Spawn `runners.max(1)` request-runner threads over `coord` (a
    /// [`crate::coordinator::Coordinator`] or any other
    /// [`Dispatch`]er).  The runner count
    /// bounds how many requests *drive* concurrently; their task graphs
    /// all interleave on the coordinator's runtime(s).
    pub fn new<D: Dispatch + 'static>(coord: Arc<D>, runners: usize) -> Client {
        Client::from_dispatch(coord, runners)
    }

    /// [`Client::new`] over an already-erased dispatcher (what
    /// `exageostat serve` builds when `--shards` picks the coordinator
    /// flavor at runtime).
    pub fn from_dispatch(coord: Arc<dyn Dispatch>, runners: usize) -> Client {
        let (tx, rx) = channel::<Submission>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..runners.max(1))
            .map(|i| {
                let rx = rx.clone();
                let coord = coord.clone();
                std::thread::Builder::new()
                    .name(format!("exa-client-{i}"))
                    .spawn(move || runner_loop(&*coord, &rx))
                    .expect("spawn client runner")
            })
            .collect();
        Client {
            coord,
            tx: Some(tx),
            runners: handles,
            next_id: AtomicU64::new(0),
        }
    }

    /// The dispatcher this client submits to.
    pub fn coordinator(&self) -> &Arc<dyn Dispatch> {
        &self.coord
    }

    /// Enqueue a request and return its ticket immediately.
    ///
    /// # Panics
    /// Panics if called after [`Client::shutdown`].
    pub fn submit(&self, req: Request) -> Ticket {
        let state = Arc::new(TicketState {
            cancel: CancelToken::new(),
            deadline: req
                .deadline_ms
                .map(|ms| Instant::now() + Duration::from_millis(ms)),
            slot: Mutex::new(None),
            cv: Condvar::new(),
        });
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("Client::submit after shutdown")
            .send(Submission {
                state: state.clone(),
                req,
            })
            .expect("client runners alive");
        Ticket { id, state }
    }

    /// Submit a built [`crate::api::GeoModel`] as an MLE request — the
    /// asynchronous twin of [`crate::api::GeoModel::fit`].
    pub fn submit_model(&self, model: &crate::api::GeoModel, priority: u8) -> Ticket {
        self.submit(Request::mle_from_model(model, priority))
    }

    /// Drain the queue and join the runner threads.  Does **not** shut
    /// down the coordinator (other clients may share it); already-issued
    /// tickets complete first.
    pub fn shutdown(mut self) {
        self.close();
    }

    fn close(&mut self) {
        self.tx.take(); // runners' recv() errors out once drained
        for h in self.runners.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        self.close();
    }
}

fn runner_loop(coord: &dyn Dispatch, rx: &Mutex<Receiver<Submission>>) {
    loop {
        // Hold the lock only for the recv, not while serving.
        let sub = match rx.lock().unwrap().recv() {
            Ok(sub) => sub,
            Err(_) => break, // channel closed and drained
        };
        let Submission { state, req } = sub;
        // A deadline that expired while the request sat in the queue is
        // a timeout, not a user cancellation.
        if let Some(d) = state.deadline {
            if Instant::now() >= d {
                state.cancel.cancel_with_timeout();
            }
        }
        let outcome = if state.cancel.is_cancelled() {
            // Cancelled while queued: never reaches the coordinator.
            if state.cancel.timed_out() {
                Completion::TimedOut
            } else {
                Completion::Cancelled
            }
        } else {
            // A panicking request (e.g. a task panic re-raised by
            // JobHandle::wait) must not kill the runner: the ticket's
            // slot would never fill and its waiter would hang forever.
            // AssertUnwindSafe: the request is consumed either way and a
            // failed outcome is never retried on shared state.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                coord.run_with_cancel(req, &state.cancel)
            }));
            match run {
                Ok(Ok(resp)) => Completion::Done(resp),
                Ok(Err(e)) if is_timeout(&e) => Completion::TimedOut,
                Ok(Err(e)) if is_cancelled(&e) => Completion::Cancelled,
                Ok(Err(e)) => Completion::Failed(format!("{e:#}")),
                Err(p) => Completion::Failed(format!(
                    "request panicked: {}",
                    crate::scheduler::runtime::panic_message(p.as_ref())
                )),
            }
        };
        let mut slot = state.slot.lock().unwrap();
        *slot = Some(outcome);
        state.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Hardware, MleOptions};
    use crate::coordinator::{Coordinator, DataSpec, Outcome, RequestKind};
    use crate::likelihood::Variant;
    use crate::scheduler::pool::Policy;

    fn hw(ncores: usize, ts: usize) -> Hardware {
        Hardware {
            ncores,
            ts,
            policy: Policy::Prio,
            ..Hardware::default()
        }
    }

    fn sim_req(n: usize, seed: u64) -> Request {
        Request {
            data: DataSpec {
                n,
                seed,
                ..DataSpec::default()
            }
            .into(),
            kind: RequestKind::Simulate,
            priority: 0,
            deadline_ms: None,
        }
    }

    #[test]
    fn tickets_resolve_out_of_submission_order() {
        let coord = Arc::new(Coordinator::new(hw(2, 32)));
        let client = Client::new(coord.clone(), 3);
        let tickets: Vec<Ticket> = (0..6).map(|i| client.submit(sim_req(60, i))).collect();
        for (i, t) in tickets.iter().enumerate() {
            assert_eq!(t.id(), i as u64);
            match t.wait() {
                Completion::Done(r) => {
                    assert!(matches!(r.outcome, Outcome::Simulated { n: 60 }))
                }
                other => panic!("ticket {i}: {other:?}"),
            }
            // wait() is idempotent; try_wait agrees afterwards
            assert!(t.try_wait().is_some());
        }
        client.shutdown();
        coord.shutdown();
    }

    #[test]
    fn failed_requests_report_through_tickets() {
        let coord = Arc::new(Coordinator::new(hw(1, 16)));
        let client = Client::new(coord.clone(), 1);
        let mut bad = sim_req(30, 0);
        if let crate::coordinator::DataSource::Spec(spec) = &mut bad.data {
            spec.kernel = "no-such-kernel".into();
        }
        let t = client.submit(bad);
        match t.wait() {
            Completion::Failed(msg) => assert!(msg.contains("no-such-kernel"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // the client keeps serving after a failure
        let ok = client.submit(sim_req(30, 1));
        assert!(matches!(ok.wait(), Completion::Done(_)));
        client.shutdown();
        coord.shutdown();
    }

    #[test]
    fn cancel_while_queued_skips_the_request_entirely() {
        let coord = Arc::new(Coordinator::new(hw(1, 32)));
        // One runner: the MLE occupies it while we cancel the queued one.
        let client = Client::new(coord.clone(), 1);
        // Heavy enough that the runner is still on it long after the
        // victim below is cancelled, even on a loaded machine.
        let mle = Request {
            data: DataSpec {
                n: 200,
                seed: 3,
                ..DataSpec::default()
            }
            .into(),
            kind: RequestKind::Mle {
                variant: Variant::Exact,
                opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-5, 40),
            },
            priority: 0,
            deadline_ms: None,
        };
        let busy = client.submit(mle);
        let victim = client.submit(sim_req(500, 9));
        victim.cancel();
        assert!(victim.is_cancelled());
        assert!(matches!(victim.wait(), Completion::Cancelled));
        assert!(matches!(busy.wait(), Completion::Done(_)));
        // the victim never simulated: its dataset is not in the cache
        let again = client.submit(sim_req(500, 9));
        match again.wait() {
            Completion::Done(r) => assert!(!r.data_cache_hit, "victim must not have run"),
            other => panic!("{other:?}"),
        }
        client.shutdown();
        coord.shutdown();
    }
}
