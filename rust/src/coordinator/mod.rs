//! Concurrent job coordinator — the serving layer on top of the
//! persistent [`Runtime`] (the paper's L3 coordination contribution, and
//! the ROADMAP north star of serving many concurrent requests).
//!
//! ExaGeoStat initializes one StarPU context per hardware configuration
//! and multiplexes every task DAG onto it; the [`Coordinator`] does the
//! same at request granularity: it owns **one** runtime plus a session
//! cache, accepts [`Request`]s (`mle` / `predict` / `simulate`) from any
//! number of client threads concurrently, runs each request's task
//! graphs as jobs on the shared workers (fair interleaving under the
//! context's scheduling policy, with the request's `priority` as the
//! `prio`-policy tie-break) and reports per-request stats.
//!
//! Two caches keep repeated requests cheap:
//!
//! * **dataset cache** — simulated `GeoData` keyed by its generation
//!   spec, so an MLE + predict pair over the same `(n, seed, kernel,
//!   theta)` shares one simulation;
//! * **session cache** — warm [`EvalSession`]s keyed by (dataset,
//!   variant, tile size): a repeated MLE request skips the Morton /
//!   distance-cache / workspace setup and starts on warm iterations.
//!   Identical concurrent MLE requests serialize on their shared
//!   session (they would race its workspaces otherwise); distinct
//!   requests run fully concurrently.
//!
//! Both caches are FIFO-bounded ([`MAX_CACHED_DATASETS`] /
//! [`MAX_CACHED_SESSIONS`]) so a long-running serve process cannot
//! grow without bound — each session pins O(n^2) workspace.  Evicted
//! entries stay alive for requests already holding their `Arc`.
//!
//! The `exageostat serve --requests file.jsonl` subcommand drives this
//! layer from the command line (one JSON object per line — see
//! [`parse_request`]), and `rust/benches/serving_throughput.rs` measures
//! it against sequential per-job pools.

use crate::api::{mle_with_session, Hardware, MleOptions, MleResult};
use crate::backend::{self, ArcEngine};
use crate::covariance::{kernel_by_name, CovKernel, DistanceMetric, Location};
use crate::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use crate::optimizer::Method;
use crate::prediction;
use crate::scheduler::runtime::Runtime;
use crate::simulation::{self, GeoData};
use anyhow::Context as _;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cache capacity bounds (FIFO eviction; an evicted entry stays alive
/// for any request already holding its `Arc`).  A proper
/// memory-footprint LRU is a ROADMAP open item.
const MAX_CACHED_DATASETS: usize = 32;
const MAX_CACHED_SESSIONS: usize = 8;

/// A FIFO-bounded keyed cache: the minimal eviction policy that keeps
/// a long-running serve process from growing without bound (each
/// session entry pins O(n^2) workspace).
struct BoundedCache<V> {
    map: HashMap<String, V>,
    order: VecDeque<String>,
    cap: usize,
}

impl<V: Clone> BoundedCache<V> {
    fn new(cap: usize) -> Self {
        BoundedCache {
            map: HashMap::new(),
            order: VecDeque::new(),
            cap,
        }
    }

    fn get(&self, key: &str) -> Option<V> {
        self.map.get(key).cloned()
    }

    /// Insert unless the key raced in already; returns the cached value
    /// (the winner's, so concurrent requests share one `Arc`).
    fn insert(&mut self, key: String, value: V) -> V {
        if let Some(existing) = self.map.get(&key) {
            return existing.clone();
        }
        while self.map.len() >= self.cap {
            match self.order.pop_front() {
                Some(old) => {
                    self.map.remove(&old);
                }
                None => break,
            }
        }
        self.map.insert(key.clone(), value.clone());
        self.order.push_back(key);
        value
    }
}

/// How a request's dataset is produced: simulated from a kernel + seed
/// (the serving benchmark's workload; file-backed data goes through the
/// library API instead).
#[derive(Clone, Debug)]
pub struct DataSpec {
    pub n: usize,
    pub seed: u64,
    pub kernel: String,
    pub dmetric: String,
    /// Generating parameter vector (the simulation truth).
    pub theta: Vec<f64>,
}

impl Default for DataSpec {
    fn default() -> Self {
        DataSpec {
            n: 400,
            seed: 0,
            kernel: "ugsm-s".into(),
            dmetric: "euclidean".into(),
            theta: vec![1.0, 0.1, 0.5],
        }
    }
}

impl DataSpec {
    fn key(&self) -> String {
        format!(
            "{}|{}|{}|{}|{:?}",
            self.n, self.seed, self.kernel, self.dmetric, self.theta
        )
    }
}

/// What to do with the dataset.
#[derive(Clone, Debug)]
pub enum RequestKind {
    /// Simulate (and cache) the dataset only.
    Simulate,
    /// Fit the variant's MLE on the dataset.
    Mle { variant: Variant, opt: MleOptions },
    /// Krige a `grid x grid` lattice over the unit square from the
    /// dataset at its generating `theta`.
    Predict { grid: usize },
}

/// One client request.
#[derive(Clone, Debug)]
pub struct Request {
    pub data: DataSpec,
    pub kind: RequestKind,
    /// Job-priority tie-break under the `prio` policy (higher = sooner).
    pub priority: u8,
}

/// Request outcome payload.
#[derive(Clone, Debug)]
pub enum Outcome {
    Simulated { n: usize },
    Mle(MleResult),
    Predicted { npoints: usize, mean_abs: f64 },
}

/// Per-request result + stats.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub kind: &'static str,
    /// Wall-clock seconds from acceptance to completion (queueing on a
    /// busy runtime included — this is the serving latency).
    pub wall_s: f64,
    pub data_cache_hit: bool,
    pub session_cache_hit: bool,
    pub outcome: Outcome,
}

/// Aggregate serving stats.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub requests: u64,
    pub errors: u64,
    pub data_cache_hits: u64,
    pub session_cache_hits: u64,
    /// Tasks executed by the shared runtime (all jobs, all requests).
    pub tasks_executed: u64,
    pub worker_threads: usize,
}

/// The serving coordinator (see module docs).
pub struct Coordinator {
    hw: Hardware,
    engine: ArcEngine,
    runtime: Arc<Runtime>,
    data_cache: Mutex<BoundedCache<Arc<GeoData>>>,
    sessions: Mutex<BoundedCache<Arc<Mutex<EvalSession>>>>,
    next_id: AtomicU64,
    requests: AtomicU64,
    errors: AtomicU64,
    data_hits: AtomicU64,
    session_hits: AtomicU64,
}

impl Coordinator {
    /// Spawn the shared runtime (`hw.ncores` workers, `hw.policy`) and an
    /// empty cache.
    pub fn new(hw: Hardware) -> Coordinator {
        let runtime = Arc::new(Runtime::new(hw.ncores.max(1), hw.policy));
        Coordinator {
            hw,
            engine: backend::default_engine(),
            runtime,
            data_cache: Mutex::new(BoundedCache::new(MAX_CACHED_DATASETS)),
            sessions: Mutex::new(BoundedCache::new(MAX_CACHED_SESSIONS)),
            next_id: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            data_hits: AtomicU64::new(0),
            session_hits: AtomicU64::new(0),
        }
    }

    /// The shared runtime (for tests / introspection).
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Execution context bound to the shared runtime, with the request's
    /// priority as the job tie-break.
    fn ctx_with_priority(&self, priority: u8) -> ExecCtx {
        let mut ctx = ExecCtx::with_runtime(self.runtime.clone(), self.hw.ts, self.engine.clone());
        ctx.job_prio = priority;
        ctx
    }

    /// Fetch (or simulate-and-cache) the dataset of `spec`.  Returns the
    /// data and whether it was a cache hit.
    fn dataset(&self, spec: &DataSpec, ctx: &ExecCtx) -> anyhow::Result<(Arc<GeoData>, bool)> {
        let key = spec.key();
        if let Some(d) = self.data_cache.lock().unwrap().get(&key) {
            self.data_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((d, true));
        }
        // Simulate outside the lock (it is the expensive part); if two
        // requests race, the first insert wins and both share it.
        let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(&spec.kernel)?);
        let metric = DistanceMetric::parse(&spec.dmetric)?;
        let data = Arc::new(simulation::simulate_data_exact(
            kernel, &spec.theta, spec.n, metric, spec.seed, ctx,
        )?);
        let entry = self.data_cache.lock().unwrap().insert(key, data);
        Ok((entry, false))
    }

    /// Fetch (or build-and-cache) the warm evaluation session for an MLE
    /// request.
    fn session_for(
        &self,
        spec: &DataSpec,
        variant: Variant,
        data: &Arc<GeoData>,
        ctx: &ExecCtx,
    ) -> anyhow::Result<(Arc<Mutex<EvalSession>>, bool)> {
        let key = format!("{}|{:?}|ts{}", spec.key(), variant, self.hw.ts);
        if let Some(s) = self.sessions.lock().unwrap().get(&key) {
            self.session_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((s, true));
        }
        let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(&spec.kernel)?);
        let metric = DistanceMetric::parse(&spec.dmetric)?;
        let problem = Problem {
            kernel,
            locs: Arc::new(data.locs.clone()),
            z: Arc::new(data.z.clone()),
            metric,
        };
        let session = Arc::new(Mutex::new(EvalSession::new(&problem, variant, ctx)?));
        let entry = self.sessions.lock().unwrap().insert(key, session);
        Ok((entry, false))
    }

    /// Serve one request.  Safe to call from many threads concurrently;
    /// each request's task graphs interleave on the shared workers.
    pub fn run(&self, req: Request) -> anyhow::Result<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let r = self.dispatch(&req);
        if r.is_err() {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let (kind, data_cache_hit, session_cache_hit, outcome) = r?;
        Ok(Response {
            id,
            kind,
            wall_s: t0.elapsed().as_secs_f64(),
            data_cache_hit,
            session_cache_hit,
            outcome,
        })
    }

    fn dispatch(&self, req: &Request) -> anyhow::Result<(&'static str, bool, bool, Outcome)> {
        let ctx = self.ctx_with_priority(req.priority);
        match &req.kind {
            RequestKind::Simulate => {
                let (d, hit) = self.dataset(&req.data, &ctx)?;
                Ok(("simulate", hit, false, Outcome::Simulated { n: d.n() }))
            }
            RequestKind::Mle { variant, opt } => {
                let (d, hit) = self.dataset(&req.data, &ctx)?;
                let (session, shit) = self.session_for(&req.data, *variant, &d, &ctx)?;
                let mut s = session.lock().unwrap();
                // A cached session captured the priority of the request
                // that built it; this request's priority wins.
                s.set_job_prio(req.priority);
                let r = mle_with_session(&mut s, opt)?;
                Ok(("mle", hit, shit, Outcome::Mle(r)))
            }
            RequestKind::Predict { grid } => {
                let (d, hit) = self.dataset(&req.data, &ctx)?;
                let g = (*grid).max(1);
                let new_locs: Vec<Location> = (0..g * g)
                    .map(|k| {
                        Location::new(
                            (k % g) as f64 / (g - 1).max(1) as f64,
                            (k / g) as f64 / (g - 1).max(1) as f64,
                        )
                    })
                    .collect();
                let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(&req.data.kernel)?);
                let metric = DistanceMetric::parse(&req.data.dmetric)?;
                let p = prediction::exact_predict_ctx(
                    kernel,
                    &req.data.theta,
                    &d.locs,
                    &d.z,
                    &new_locs,
                    metric,
                    true,
                    &ctx,
                )?;
                let mean_abs =
                    p.mean.iter().map(|v| v.abs()).sum::<f64>() / p.mean.len().max(1) as f64;
                Ok((
                    "predict",
                    hit,
                    false,
                    Outcome::Predicted {
                        npoints: new_locs.len(),
                        mean_abs,
                    },
                ))
            }
        }
    }

    /// Aggregate serving stats so far.
    pub fn stats(&self) -> CoordinatorStats {
        CoordinatorStats {
            requests: self.requests.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            data_cache_hits: self.data_hits.load(Ordering::Relaxed),
            session_cache_hits: self.session_hits.load(Ordering::Relaxed),
            tasks_executed: self.runtime.tasks_executed(),
            worker_threads: self.runtime.nworkers(),
        }
    }

    /// Drain in-flight jobs and join the shared workers (the
    /// `exageostat_finalize` of the serving layer).
    pub fn shutdown(&self) {
        self.runtime.shutdown();
    }
}

// ---------------------------------------------------------------------
// JSONL request parsing (offline substitute for serde — flat JSON
// objects with string / number / bool / number-array values).
// ---------------------------------------------------------------------

/// Minimal JSON value (what the request grammar needs).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl JsonParser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(c),
            "expected {:?} at byte {}",
            c as char,
            self.i
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number");
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| anyhow::anyhow!("bad number {s:?} at byte {start}"))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                anyhow::bail!("unterminated string")
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        anyhow::bail!("bad escape")
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow::anyhow!("bad \\u escape {hex:?}"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => anyhow::bail!("unknown escape \\{}", other as char),
                    }
                }
                _ => {
                    // copy the raw byte run (UTF-8 passes through intact)
                    let start = self.i - 1;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

/// Parse one JSON document.
pub fn parse_json(src: &str) -> anyhow::Result<Json> {
    let mut p = JsonParser {
        b: src.as_bytes(),
        i: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.i == p.b.len(), "trailing characters at byte {}", p.i);
    Ok(v)
}

fn field<'a>(obj: &'a [(String, Json)], key: &str) -> Option<&'a Json> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

fn get_num(obj: &[(String, Json)], key: &str, default: f64) -> anyhow::Result<f64> {
    match field(obj, key) {
        None => Ok(default),
        Some(Json::Num(v)) => Ok(*v),
        Some(other) => anyhow::bail!("field {key:?} must be a number, got {other:?}"),
    }
}

fn get_usize(obj: &[(String, Json)], key: &str, default: usize) -> anyhow::Result<usize> {
    let v = get_num(obj, key, default as f64)?;
    anyhow::ensure!(
        v >= 0.0 && v.fract() == 0.0,
        "field {key:?} must be a non-negative integer, got {v}"
    );
    Ok(v as usize)
}

fn get_str(obj: &[(String, Json)], key: &str, default: &str) -> anyhow::Result<String> {
    match field(obj, key) {
        None => Ok(default.to_string()),
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(other) => anyhow::bail!("field {key:?} must be a string, got {other:?}"),
    }
}

fn get_f64_arr(obj: &[(String, Json)], key: &str) -> anyhow::Result<Option<Vec<f64>>> {
    match field(obj, key) {
        None => Ok(None),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|j| match j {
                Json::Num(v) => Ok(*v),
                other => anyhow::bail!("field {key:?} must hold numbers, got {other:?}"),
            })
            .collect::<anyhow::Result<Vec<f64>>>()
            .map(Some),
        Some(other) => anyhow::bail!("field {key:?} must be an array, got {other:?}"),
    }
}

/// Parse one request line, e.g.
/// `{"type":"mle","n":400,"seed":1,"variant":"dst","band":2,"max_iters":50}`.
///
/// Recognized fields: `type` (`mle`|`predict`|`simulate`, default `mle`),
/// dataset (`n`, `seed`, `kernel`, `dmetric`, `theta`), MLE (`variant`,
/// `band`, `tlr_tol`, `max_rank`, `clb`, `cub`, `tol`, `max_iters`,
/// `method`), predict (`grid`), and `priority`.
pub fn parse_request(line: &str) -> anyhow::Result<Request> {
    let Json::Obj(obj) = parse_json(line)? else {
        anyhow::bail!("request line must be a JSON object");
    };
    let data = DataSpec {
        n: get_usize(&obj, "n", 400)?,
        seed: get_usize(&obj, "seed", 0)? as u64,
        kernel: get_str(&obj, "kernel", "ugsm-s")?,
        dmetric: get_str(&obj, "dmetric", "euclidean")?,
        theta: get_f64_arr(&obj, "theta")?.unwrap_or_else(|| vec![1.0, 0.1, 0.5]),
    };
    // Reject absurd sizes at parse time: a runaway `n` would otherwise
    // attempt an O(n^2) allocation inside a client thread and take the
    // whole serve run down instead of failing this one request.
    anyhow::ensure!(
        (1..=1_000_000).contains(&data.n),
        "n must be in 1..=1000000, got {}",
        data.n
    );
    let priority = get_usize(&obj, "priority", 0)?.min(u8::MAX as usize) as u8;
    let ty = get_str(&obj, "type", "mle")?;
    let kind = match ty.as_str() {
        "simulate" => RequestKind::Simulate,
        "predict" => {
            let grid = get_usize(&obj, "grid", 8)?;
            anyhow::ensure!(
                (1..=1024).contains(&grid),
                "grid must be in 1..=1024, got {grid}"
            );
            RequestKind::Predict { grid }
        }
        "mle" => {
            let variant = match get_str(&obj, "variant", "exact")?.as_str() {
                "exact" => Variant::Exact,
                "dst" => Variant::Dst {
                    band: get_usize(&obj, "band", 1)?,
                },
                "tlr" => Variant::Tlr {
                    tol: get_num(&obj, "tlr_tol", 1e-7)?,
                    max_rank: get_usize(&obj, "max_rank", usize::MAX)?,
                },
                "mp" => Variant::Mp {
                    band: get_usize(&obj, "band", 1)?,
                },
                other => anyhow::bail!("unknown variant {other:?} (exact|dst|tlr|mp)"),
            };
            let nparams = kernel_by_name(&data.kernel)?.nparams();
            let opt = MleOptions {
                clb: get_f64_arr(&obj, "clb")?.unwrap_or_else(|| vec![0.001; nparams]),
                cub: get_f64_arr(&obj, "cub")?.unwrap_or_else(|| vec![5.0; nparams]),
                tol: get_num(&obj, "tol", 1e-4)?,
                max_iters: get_usize(&obj, "max_iters", 0)?,
                method: Method::parse(&get_str(&obj, "method", "bobyqa")?)?,
            };
            RequestKind::Mle { variant, opt }
        }
        other => anyhow::bail!("unknown request type {other:?} (mle|predict|simulate)"),
    };
    Ok(Request {
        data,
        kind,
        priority,
    })
}

/// Parse a whole JSONL request file (blank lines and `#` comments are
/// skipped).
pub fn parse_requests_jsonl(text: &str) -> anyhow::Result<Vec<Request>> {
    text.lines()
        .map(str::trim)
        .enumerate()
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'))
        .map(|(i, l)| parse_request(l).with_context(|| format!("request at line {}", i + 1)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::pool::Policy;

    fn hw(ncores: usize, ts: usize) -> Hardware {
        Hardware {
            ncores,
            ts,
            policy: Policy::Prio,
            ..Hardware::default()
        }
    }

    #[test]
    fn json_parser_round_trips_request_shapes() {
        let j = parse_json(r#"{"a": 1.5, "b": [1, 2.25, -3e-1], "c": "x\ny", "d": true}"#).unwrap();
        let Json::Obj(obj) = j else { panic!("obj") };
        assert_eq!(field(&obj, "a"), Some(&Json::Num(1.5)));
        assert_eq!(
            field(&obj, "b"),
            Some(&Json::Arr(vec![
                Json::Num(1.0),
                Json::Num(2.25),
                Json::Num(-0.3)
            ]))
        );
        assert_eq!(field(&obj, "c"), Some(&Json::Str("x\ny".into())));
        assert_eq!(field(&obj, "d"), Some(&Json::Bool(true)));
        assert!(parse_json("{\"unterminated\": ").is_err());
        assert!(parse_json("{} trailing").is_err());
    }

    #[test]
    fn request_lines_parse_with_defaults() {
        let r = parse_request(r#"{"type":"mle","n":100,"variant":"dst","band":2}"#).unwrap();
        assert_eq!(r.data.n, 100);
        assert_eq!(r.data.kernel, "ugsm-s");
        match r.kind {
            RequestKind::Mle { variant, ref opt } => {
                assert_eq!(variant, Variant::Dst { band: 2 });
                assert_eq!(opt.clb.len(), 3);
                assert_eq!(opt.max_iters, 0);
            }
            ref other => panic!("wrong kind {other:?}"),
        }
        let p = parse_request(r#"{"type":"predict","grid":5,"priority":3}"#).unwrap();
        assert_eq!(p.priority, 3);
        assert!(matches!(p.kind, RequestKind::Predict { grid: 5 }));
        assert!(parse_request(r#"{"type":"nope"}"#).is_err());
        assert!(parse_request(r#"[1,2]"#).is_err());

        let reqs = parse_requests_jsonl(
            "# comment\n\n{\"type\":\"simulate\",\"n\":50}\n{\"type\":\"mle\",\"max_iters\":5}\n",
        )
        .unwrap();
        assert_eq!(reqs.len(), 2);
        assert!(matches!(reqs[0].kind, RequestKind::Simulate));
    }

    #[test]
    fn coordinator_caches_dataset_and_session() {
        let coord = Coordinator::new(hw(2, 32));
        let data = DataSpec {
            n: 80,
            seed: 11,
            ..DataSpec::default()
        };
        let sim = Request {
            data: data.clone(),
            kind: RequestKind::Simulate,
            priority: 0,
        };
        let r0 = coord.run(sim.clone()).unwrap();
        assert!(!r0.data_cache_hit);
        let r1 = coord.run(sim).unwrap();
        assert!(r1.data_cache_hit);

        let mle = Request {
            data: data.clone(),
            kind: RequestKind::Mle {
                variant: Variant::Exact,
                opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 8),
            },
            priority: 0,
        };
        let m0 = coord.run(mle.clone()).unwrap();
        assert!(!m0.session_cache_hit);
        let m1 = coord.run(mle).unwrap();
        assert!(m1.session_cache_hit, "second identical MLE reuses session");
        let (Outcome::Mle(a), Outcome::Mle(b)) = (&m0.outcome, &m1.outcome) else {
            panic!("mle outcomes");
        };
        assert_eq!(a.loglik.to_bits(), b.loglik.to_bits());

        let st = coord.stats();
        assert_eq!(st.requests, 4);
        assert_eq!(st.errors, 0);
        assert!(st.data_cache_hits >= 3);
        assert_eq!(st.session_cache_hits, 1);
        assert!(st.tasks_executed > 0);
        assert_eq!(st.worker_threads, 2);
        coord.shutdown();
    }

    #[test]
    fn bounded_cache_evicts_fifo_and_dedups_racers() {
        let mut c: BoundedCache<Arc<usize>> = BoundedCache::new(2);
        let a = c.insert("a".into(), Arc::new(1));
        assert_eq!(*a, 1);
        // racing insert under the same key keeps the winner
        let a2 = c.insert("a".into(), Arc::new(99));
        assert_eq!(*a2, 1);
        c.insert("b".into(), Arc::new(2));
        c.insert("c".into(), Arc::new(3)); // evicts "a" (oldest)
        assert!(c.get("a").is_none());
        assert!(c.get("b").is_some() && c.get("c").is_some());
        assert!(c.map.len() <= 2);
    }

    #[test]
    fn request_size_bounds_enforced() {
        assert!(parse_request(r#"{"type":"simulate","n":1e18}"#).is_err());
        assert!(parse_request(r#"{"type":"simulate","n":0}"#).is_err());
        assert!(parse_request(r#"{"type":"predict","grid":100000}"#).is_err());
        assert!(parse_request(r#"{"type":"predict","grid":8}"#).is_ok());
    }

    #[test]
    fn coordinator_reports_errors_and_stays_usable() {
        let coord = Coordinator::new(hw(1, 16));
        let bad = Request {
            data: DataSpec {
                kernel: "no-such-kernel".into(),
                ..DataSpec::default()
            },
            kind: RequestKind::Simulate,
            priority: 0,
        };
        assert!(coord.run(bad).is_err());
        let ok = Request {
            data: DataSpec {
                n: 40,
                ..DataSpec::default()
            },
            kind: RequestKind::Predict { grid: 3 },
            priority: 0,
        };
        let r = coord.run(ok).unwrap();
        let Outcome::Predicted { npoints, .. } = r.outcome else {
            panic!("predict outcome");
        };
        assert_eq!(npoints, 9);
        let st = coord.stats();
        assert_eq!(st.errors, 1);
        assert_eq!(st.requests, 2);
    }
}
