//! Concurrent job coordinator — the serving layer on top of the
//! persistent [`Runtime`] (the paper's L3 coordination contribution, and
//! the ROADMAP north star of serving many concurrent requests).
//!
//! ExaGeoStat initializes one StarPU context per hardware configuration
//! and multiplexes every task DAG onto it; the [`Coordinator`] does the
//! same at request granularity: it owns **one** runtime plus a session
//! cache, accepts [`Request`]s (`mle` / `predict` / `simulate`) from any
//! number of client threads concurrently, runs each request's task
//! graphs as jobs on the shared workers (fair interleaving under the
//! context's scheduling policy, with the request's `priority` as the
//! `prio`-policy tie-break) and reports per-request stats.
//!
//! Two caches keep repeated requests cheap:
//!
//! * **dataset cache** — datasets keyed by their generation spec (or by
//!   content hash for caller-provided inline data), so an MLE + predict
//!   pair over the same dataset shares one simulation;
//! * **session cache** — warm [`EvalSession`]s keyed by (dataset,
//!   variant, tile size): a repeated MLE request skips the Morton /
//!   distance-cache / workspace setup and starts on warm iterations.
//!   Identical concurrent MLE requests serialize on their shared
//!   session (they would race its workspaces otherwise); distinct
//!   requests run fully concurrently.
//!
//! Both caches are **LRU, bounded by memory footprint** (doubles
//! pinned: `3n + len(z)` per dataset, [`EvalSession::dist_storage_len`]
//! per session), so a long-running serve process cannot grow without
//! bound.
//! Evicted entries stay alive for requests already holding their `Arc`;
//! hit/miss/eviction counts are reported in [`CoordinatorStats`].
//!
//! On top of [`Coordinator::run`] (synchronous, caller's thread) sit
//! the async job layer — [`Client`] / [`Ticket`] with cancellation —
//! and the streaming admission loop [`serve_stream`]; the
//! `exageostat serve` subcommand drives the whole stack from a JSONL
//! file, stdin or a unix socket (one JSON object per line — see
//! [`parse_request`]), and `rust/benches/serving_throughput.rs`
//! measures it against sequential per-job pools.

pub mod client;
pub mod serve;
pub mod sharded;

pub use client::{Client, Completion, Ticket};
pub use serve::{serve_socket, serve_stream, ServeOptions, ServeSummary};
pub use sharded::ShardedCoordinator;

use crate::api::{
    is_cancelled, is_timeout, mle_with_session, ApiError, Hardware, MleOptions, MleResult,
};
use crate::backend::{self, ArcEngine};
use crate::covariance::{kernel_by_name, CovKernel, DistanceMetric, Location};
use crate::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use crate::optimizer::Method;
use crate::pipeline::shard::ShardSet;
use crate::prediction::{self, Prediction};
use crate::scheduler::faults;
use crate::scheduler::placement::ClassStat;
use crate::scheduler::runtime::{panic_message, CancelToken, Runtime, TaskError};
use crate::simulation;
use anyhow::Context as _;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// The request-dispatch surface [`Client`] and [`serve_stream`] sit on:
/// one [`Coordinator`] and the sharded fan-out [`ShardedCoordinator`]
/// both implement it, so every serving front-end (tickets, streams,
/// sockets, benches) works unchanged across shard counts — the
/// scale-out seam the ROADMAP names.
pub trait Dispatch: Send + Sync {
    /// Serve one request synchronously under a cancellation token
    /// (see [`Coordinator::run_with_cancel`]).
    fn run_with_cancel(&self, req: Request, cancel: &CancelToken) -> anyhow::Result<Response>;
    /// Ready tasks currently queued across the dispatcher's runtimes
    /// (the admission-control backpressure signal).
    fn queue_depth(&self) -> usize;
    /// Total worker threads across the dispatcher's runtimes.
    fn nworkers(&self) -> usize;
    /// Aggregate serving stats (field-wise summed across shards).
    fn stats(&self) -> CoordinatorStats;
    /// Drain in-flight jobs and join every runtime's workers.
    fn shutdown_dispatch(&self);
}

/// Default cache budgets, in doubles pinned (×8 for bytes): 32 MB of
/// datasets, 256 MB of session distance caches.  Override with
/// [`Coordinator::with_cache_budgets`].
const DATA_CACHE_BUDGET: usize = 4 << 20;
const SESSION_CACHE_BUDGET: usize = 32 << 20;

/// One cached value with its footprint and recency stamp.
struct LruEntry<V> {
    value: V,
    cost: usize,
    last_used: u64,
}

/// A keyed LRU cache bounded by total *cost* (memory footprint in
/// doubles), not entry count: one n=10k session weighs as much as a
/// hundred n=1k ones, which is what actually matters for a long-running
/// serve process.  `get` and re-`insert` both refresh recency.
///
/// Recency is a monotone stamp per entry, so `get` is O(1) — these
/// calls run under the coordinator's cache mutex on every request, so
/// they must not scan.  Eviction scans for the minimum stamp, which is
/// O(entries) but only runs when an insert exceeds the budget.
struct LruCache<V> {
    map: HashMap<String, LruEntry<V>>,
    budget: usize,
    used: usize,
    tick: u64,
    evictions: u64,
}

impl<V: Clone> LruCache<V> {
    fn new(budget: usize) -> Self {
        LruCache {
            map: HashMap::new(),
            budget,
            used: 0,
            tick: 0,
            evictions: 0,
        }
    }

    fn get(&mut self, key: &str) -> Option<V> {
        self.tick += 1;
        let now = self.tick;
        self.map.get_mut(key).map(|e| {
            e.last_used = now;
            e.value.clone()
        })
    }

    /// Insert unless the key raced in already; returns the cached value
    /// (the winner's, so concurrent requests share one `Arc`).  Evicts
    /// least-recently-used entries until `cost` fits the budget; an
    /// entry larger than the whole budget still caches (alone) rather
    /// than thrash on every request.
    fn insert(&mut self, key: String, value: V, cost: usize) -> V {
        self.tick += 1;
        let now = self.tick;
        if let Some(e) = self.map.get_mut(&key) {
            e.last_used = now;
            return e.value.clone();
        }
        while self.used + cost > self.budget && !self.map.is_empty() {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            let e = self.map.remove(&victim).expect("victim present");
            self.used -= e.cost;
            self.evictions += 1;
        }
        self.used += cost;
        self.map.insert(
            key,
            LruEntry {
                value: value.clone(),
                cost,
                last_used: now,
            },
        );
        value
    }

    /// Drop `key`, returning whether it was present.  The failure path
    /// uses this: a request that died mid-MLE must not leave its
    /// (possibly half-mutated) session or a suspect dataset behind for
    /// the next request — or its own retry — to trip over.
    fn remove(&mut self, key: &str) -> bool {
        match self.map.remove(key) {
            Some(e) => {
                self.used -= e.cost;
                true
            }
            None => false,
        }
    }
}

/// Whole-request retry budget after a non-cancellation failure
/// (`EXAGEOSTAT_JOB_RETRIES`, default 0 = fail fast).  This is the
/// recovery tier above per-task retry: failures of non-idempotent work
/// (a panic mid-factorization, an I/O error the tile store's bounded
/// retry could not ride out) abandon the attempt, evict the request's
/// possibly half-built cache state and re-run the request from scratch
/// under capped exponential backoff.
static JOB_RETRY_OVERRIDE: AtomicU64 = AtomicU64::new(u64::MAX);

/// Test hook: force the whole-job retry budget (`None` restores the
/// `EXAGEOSTAT_JOB_RETRIES` environment default).
pub fn set_job_retry_override(v: Option<u64>) {
    JOB_RETRY_OVERRIDE.store(v.unwrap_or(u64::MAX), Ordering::Relaxed);
}

fn job_retry_limit() -> u64 {
    let o = JOB_RETRY_OVERRIDE.load(Ordering::Relaxed);
    if o != u64::MAX {
        return o;
    }
    static ENV: OnceLock<u64> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("EXAGEOSTAT_JOB_RETRIES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0)
    })
}

/// How a request's dataset is produced: simulated from a kernel + seed
/// (the serving benchmark's workload; file-backed data goes through the
/// library API instead).
#[derive(Clone, Debug)]
pub struct DataSpec {
    pub n: usize,
    pub seed: u64,
    pub kernel: String,
    pub dmetric: String,
    /// Generating parameter vector (the simulation truth).
    pub theta: Vec<f64>,
}

impl Default for DataSpec {
    fn default() -> Self {
        DataSpec {
            n: 400,
            seed: 0,
            kernel: "ugsm-s".into(),
            dmetric: "euclidean".into(),
            theta: vec![1.0, 0.1, 0.5],
        }
    }
}

impl DataSpec {
    fn key(&self) -> String {
        format!(
            "{}|{}|{}|{}|{:?}",
            self.n, self.seed, self.kernel, self.dmetric, self.theta
        )
    }
}

/// The coordinator's shared dataset form: `Arc`'d site and observation
/// vectors, so cache entries, sessions and kriging all share one
/// allocation.
pub type DataArc = (Arc<Vec<Location>>, Arc<Vec<f64>>);

/// FNV-1a over the raw f64 bits — the content hash keying inline
/// datasets, so two requests built from equal data share cache entries.
fn content_hash(locs: &[Location], z: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |v: f64| {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for l in locs {
        eat(l.x);
        eat(l.y);
        eat(l.t);
    }
    for &v in z {
        eat(v);
    }
    h
}

/// Where a request's dataset comes from.
#[derive(Clone, Debug)]
pub enum DataSource {
    /// Simulate from a [`DataSpec`] (the JSONL serving workload).
    Spec(DataSpec),
    /// Caller-provided data (the `GeoModel` / [`Client`] route), cached
    /// under a content-hash key.  The vectors are shared with the
    /// `GeoModel` that built the request — no copy on submit.
    Inline {
        /// Cache key (content hash — see [`Request::mle_from_model`]).
        key: String,
        /// Observation sites, shared with the caller.
        locs: Arc<Vec<Location>>,
        /// Observation vector, shared with the caller.
        z: Arc<Vec<f64>>,
        /// Kernel registry name for sessions over this data.
        kernel: String,
        /// Distance-metric name for sessions over this data.
        dmetric: String,
    },
}

impl DataSource {
    /// Kernel registry name.
    pub fn kernel(&self) -> &str {
        match self {
            DataSource::Spec(s) => &s.kernel,
            DataSource::Inline { kernel, .. } => kernel,
        }
    }

    /// Distance-metric name.
    pub fn dmetric(&self) -> &str {
        match self {
            DataSource::Spec(s) => &s.dmetric,
            DataSource::Inline { dmetric, .. } => dmetric,
        }
    }

    fn key(&self) -> String {
        match self {
            DataSource::Spec(s) => s.key(),
            DataSource::Inline { key, .. } => key.clone(),
        }
    }
}

impl From<DataSpec> for DataSource {
    fn from(spec: DataSpec) -> DataSource {
        DataSource::Spec(spec)
    }
}

/// What to do with the dataset.
#[derive(Clone, Debug)]
pub enum RequestKind {
    /// Simulate (and cache) the dataset only.
    Simulate,
    /// Fit the variant's MLE on the dataset.
    Mle { variant: Variant, opt: MleOptions },
    /// Krige a `grid x grid` lattice over the unit square from the
    /// dataset at its generating `theta` (spec-backed datasets only).
    Predict { grid: usize },
    /// Krige explicit target locations at an explicit `theta` (the
    /// typed `exact_predict` route; works for inline data too).
    PredictAt {
        /// Target locations to predict at.
        new_locs: Vec<Location>,
        /// Covariance parameters to krige under.
        theta: Vec<f64>,
        /// Also compute per-point kriging variance?
        with_variance: bool,
    },
}

/// One client request.
#[derive(Clone, Debug)]
pub struct Request {
    pub data: DataSource,
    pub kind: RequestKind,
    /// Job-priority tie-break under the `prio` policy (higher = sooner).
    pub priority: u8,
    /// Soft deadline in milliseconds (`None` = none).  Enforced by the
    /// serving layers ([`Client::submit`]'s ticket reaper, `serve
    /// --deadline`): on expiry the request's token is cancelled with a
    /// timeout reason and the ticket reports [`Completion::TimedOut`].
    pub deadline_ms: Option<u64>,
}

impl Request {
    /// An MLE request over a built [`crate::api::GeoModel`]: the
    /// asynchronous twin of [`crate::api::GeoModel::fit`], carrying the
    /// model's data inline (content-hash cached).
    pub fn mle_from_model(model: &crate::api::GeoModel, priority: u8) -> Request {
        Request {
            data: Request::inline_source(model),
            kind: RequestKind::Mle {
                variant: model.variant(),
                opt: model.options().clone(),
            },
            priority,
            deadline_ms: None,
        }
    }

    /// A kriging request over a model's dataset at explicit targets and
    /// `theta` (the asynchronous `exact_predict`).
    pub fn predict_at(
        model: &crate::api::GeoModel,
        new_locs: Vec<Location>,
        theta: Vec<f64>,
        with_variance: bool,
        priority: u8,
    ) -> Request {
        Request {
            data: Request::inline_source(model),
            kind: RequestKind::PredictAt {
                new_locs,
                theta,
                with_variance,
            },
            priority,
            deadline_ms: None,
        }
    }

    fn inline_source(model: &crate::api::GeoModel) -> DataSource {
        // Kernel and metric are part of the key: the session cache key
        // derives from this one, and a session's distance cache is
        // resolved for one (kernel, metric) pair — two models over the
        // same data but different metrics must never share a session.
        DataSource::Inline {
            key: format!(
                "inline|{}|{}|{}|{:016x}",
                model.kernel_name(),
                model.metric_name(),
                model.n(),
                content_hash(model.locs(), model.z())
            ),
            locs: model.locs().clone(),
            z: model.z().clone(),
            kernel: model.kernel_name().to_string(),
            dmetric: model.metric_name().to_string(),
        }
    }
}

/// Request outcome payload.
#[derive(Clone, Debug)]
pub enum Outcome {
    Simulated { n: usize },
    Mle(MleResult),
    Predicted { npoints: usize, mean_abs: f64 },
    /// Full kriging output (the [`RequestKind::PredictAt`] result).
    Prediction(Prediction),
}

/// Per-request result + stats.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub kind: &'static str,
    /// Wall-clock seconds from acceptance to completion (queueing on a
    /// busy runtime included — this is the serving latency).
    pub wall_s: f64,
    pub data_cache_hit: bool,
    pub session_cache_hit: bool,
    pub outcome: Outcome,
}

/// Aggregate serving stats.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub requests: u64,
    pub errors: u64,
    /// Requests that ended in cancellation (not counted as errors).
    pub cancelled: u64,
    /// Requests that ended in a deadline/watchdog timeout (counted
    /// separately from both `errors` and `cancelled`).
    pub timeouts: u64,
    /// Whole-request retries performed after non-cancellation failures
    /// (`EXAGEOSTAT_JOB_RETRIES` tier).
    pub job_retries: u64,
    /// Faults fired by the active injection plan (process-global
    /// counter — see [`crate::scheduler::faults`]).
    pub faults_injected: u64,
    /// Task-level retries performed (process-global counter).
    pub tasks_retried: u64,
    pub data_cache_hits: u64,
    pub data_cache_misses: u64,
    pub data_cache_evictions: u64,
    pub session_cache_hits: u64,
    pub session_cache_misses: u64,
    pub session_cache_evictions: u64,
    /// Tasks executed by the shared runtime (all jobs, all requests).
    pub tasks_executed: u64,
    /// Tasks retired unrun because their request was cancelled (client
    /// disconnect, speculative-race loser) — work the runtime saved.
    pub tasks_skipped: u64,
    pub worker_threads: usize,
    /// Per-worker-class placement/execution/steal counters (one entry
    /// per class of the shared runtime; single entry when homogeneous).
    pub class_stats: Vec<ClassStat>,
}

impl CoordinatorStats {
    /// Field-wise accumulate (how [`ShardedCoordinator`] aggregates its
    /// members' stats).
    pub fn accumulate(&mut self, o: &CoordinatorStats) {
        self.requests += o.requests;
        self.errors += o.errors;
        self.cancelled += o.cancelled;
        self.timeouts += o.timeouts;
        self.job_retries += o.job_retries;
        // The fault counters are process-global (every shard reads the
        // same atomics); summing them across members would multiply the
        // truth by the shard count.
        self.faults_injected = self.faults_injected.max(o.faults_injected);
        self.tasks_retried = self.tasks_retried.max(o.tasks_retried);
        self.data_cache_hits += o.data_cache_hits;
        self.data_cache_misses += o.data_cache_misses;
        self.data_cache_evictions += o.data_cache_evictions;
        self.session_cache_hits += o.session_cache_hits;
        self.session_cache_misses += o.session_cache_misses;
        self.session_cache_evictions += o.session_cache_evictions;
        self.tasks_executed += o.tasks_executed;
        self.tasks_skipped += o.tasks_skipped;
        self.worker_threads += o.worker_threads;
        // Merge class counters by class (shard members may differ in
        // layout; a class missing here is appended).
        for s in &o.class_stats {
            match self.class_stats.iter_mut().find(|m| m.class == s.class) {
                Some(m) => {
                    m.workers += s.workers;
                    m.tasks_placed += s.tasks_placed;
                    m.tasks_executed += s.tasks_executed;
                    m.steals += s.steals;
                }
                None => self.class_stats.push(s.clone()),
            }
        }
    }
}

/// The serving coordinator (see module docs).
pub struct Coordinator {
    hw: Hardware,
    engine: ArcEngine,
    runtime: Arc<Runtime>,
    /// Set once by [`Coordinator::attach_shards`]: every request context
    /// this coordinator hands out carries the shard set, so large tiled
    /// pipelines partition across the member runtimes
    /// (`pipeline::shard::execute_sharded`).
    shards: OnceLock<Arc<ShardSet>>,
    /// Out-of-core tile budget (bytes) stamped onto every request
    /// context: sessions built under it allocate spill-backed
    /// workspaces.  `None` = fully resident.  Defaults to the
    /// `EXAGEOSTAT_TILE_BUDGET` env; [`Coordinator::with_mem_budget`]
    /// sets it from the unified serve budget.
    tile_budget: Option<usize>,
    data_cache: Mutex<LruCache<DataArc>>,
    sessions: Mutex<LruCache<Arc<Mutex<EvalSession>>>>,
    next_id: AtomicU64,
    requests: AtomicU64,
    errors: AtomicU64,
    cancelled: AtomicU64,
    timeouts: AtomicU64,
    job_retries: AtomicU64,
    data_hits: AtomicU64,
    data_misses: AtomicU64,
    session_hits: AtomicU64,
    session_misses: AtomicU64,
}

impl Coordinator {
    /// Spawn the shared runtime (`hw.ncores` workers, `hw.policy`) and
    /// empty caches at the default memory budgets.
    pub fn new(hw: Hardware) -> Coordinator {
        Coordinator::with_cache_budgets(hw, DATA_CACHE_BUDGET, SESSION_CACHE_BUDGET)
    }

    /// [`Coordinator::new`] with explicit cache budgets, in doubles
    /// pinned (a dataset costs `3n + len(z)`, a session costs its
    /// [`EvalSession::dist_storage_len`]).
    pub fn with_cache_budgets(
        hw: Hardware,
        data_budget: usize,
        session_budget: usize,
    ) -> Coordinator {
        let spec = crate::scheduler::placement::class_spec_for(hw.ncores.max(1));
        let runtime = Arc::new(Runtime::new_with_classes(&spec, hw.policy));
        Coordinator {
            hw,
            engine: backend::default_engine(),
            runtime,
            shards: OnceLock::new(),
            tile_budget: crate::linalg::tile::tile_budget_from_env(),
            data_cache: Mutex::new(LruCache::new(data_budget)),
            sessions: Mutex::new(LruCache::new(session_budget)),
            next_id: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            job_retries: AtomicU64::new(0),
            data_hits: AtomicU64::new(0),
            data_misses: AtomicU64::new(0),
            session_hits: AtomicU64::new(0),
            session_misses: AtomicU64::new(0),
        }
    }

    /// [`Coordinator::new`] under one unified memory budget of
    /// `total_bytes`, split proportionally across the three pools that
    /// hold per-request state: half to the out-of-core tile workspace
    /// (the dominant allocation — sessions built here spill instead of
    /// growing resident), three-eighths to the session distance-cache
    /// LRU and one-eighth to the dataset LRU (both bounded in doubles,
    /// hence the ÷8).  This is what `serve --mem-budget` constructs; an
    /// `EXAGEOSTAT_TILE_BUDGET` env still wins for the tile share so
    /// operators can tune the spill threshold independently.
    pub fn with_mem_budget(hw: Hardware, total_bytes: usize) -> Coordinator {
        let data_budget = (total_bytes / 8 / 8).max(1);
        let session_budget = (total_bytes * 3 / 8 / 8).max(1);
        let mut c = Coordinator::with_cache_budgets(hw, data_budget, session_budget);
        if c.tile_budget.is_none() {
            c.tile_budget = Some((total_bytes / 2).max(1));
        }
        c
    }

    /// The shared runtime (for tests / introspection).
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// The tile budget request contexts carry (`None` = resident).
    pub fn tile_budget(&self) -> Option<usize> {
        self.tile_budget
    }

    /// Attach a shard set: from now on every request context carries it,
    /// so tiled pipelines over enough tiles (`set.min_nt`) partition 2-D
    /// block-cyclic across the set's runtimes.  One-shot — a second call
    /// is ignored (the set is wired at construction by
    /// [`ShardedCoordinator`]).
    pub fn attach_shards(&self, set: Arc<ShardSet>) {
        let _ = self.shards.set(set);
    }

    /// Execution context bound to the shared runtime, with the request's
    /// priority as the job tie-break.
    fn ctx_with_priority(&self, priority: u8) -> ExecCtx {
        let mut ctx = ExecCtx::with_runtime(self.runtime.clone(), self.hw.ts, self.engine.clone());
        ctx.job_prio = priority;
        ctx.shards = self.shards.get().cloned();
        ctx.tile_budget = self.tile_budget;
        ctx
    }

    /// Fetch (or produce-and-cache) the dataset of `src`.  Returns the
    /// shared data vectors and whether it was a cache hit.
    fn dataset(&self, src: &DataSource, ctx: &ExecCtx) -> anyhow::Result<(DataArc, bool)> {
        let key = src.key();
        if let Some(d) = self.data_cache.lock().unwrap().get(&key) {
            self.data_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((d, true));
        }
        self.data_misses.fetch_add(1, Ordering::Relaxed);
        // Simulate outside the lock (it is the expensive part); if two
        // requests race, the first insert wins and both share it.
        let data: DataArc = match src {
            DataSource::Spec(spec) => {
                let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(&spec.kernel)?);
                let metric = DistanceMetric::parse(&spec.dmetric)?;
                // A token fired mid-simulation skips runtime tasks; the
                // pipeline detects that and reports `Cancelled` itself,
                // so an `Ok` here is a fully-generated buffer, safe to
                // cache — no racy re-read of the token needed.
                let sim = simulation::simulate_data_exact(
                    kernel, &spec.theta, spec.n, metric, spec.seed, ctx,
                )?;
                (Arc::new(sim.locs), Arc::new(sim.z))
            }
            DataSource::Inline { locs, z, .. } => (locs.clone(), z.clone()),
        };
        // Pinned footprint in doubles: x, y, t per site plus the
        // observation vector (which is longer than n for multivariate
        // kernels).
        let cost = (3 * data.0.len() + data.1.len()).max(1);
        let entry = self.data_cache.lock().unwrap().insert(key, data, cost);
        Ok((entry, false))
    }

    /// Fetch (or build-and-cache) the warm evaluation session for an MLE
    /// request.
    fn session_for(
        &self,
        src: &DataSource,
        variant: Variant,
        data: &DataArc,
        ctx: &ExecCtx,
    ) -> anyhow::Result<(Arc<Mutex<EvalSession>>, bool)> {
        let key = format!("{}|{:?}|ts{}", src.key(), variant, self.hw.ts);
        if let Some(s) = self.sessions.lock().unwrap().get(&key) {
            self.session_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((s, true));
        }
        self.session_misses.fetch_add(1, Ordering::Relaxed);
        let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(src.kernel())?);
        let metric = DistanceMetric::parse(src.dmetric())?;
        let problem = Problem {
            kernel,
            locs: data.0.clone(),
            z: data.1.clone(),
            metric,
        };
        let session = EvalSession::new(&problem, variant, ctx)?;
        // Memory-footprint cost: the distance cache dominates a warm
        // session's pinned memory (ROADMAP "cache eviction" item).
        let cost = session.dist_storage_len().max(1);
        let session = Arc::new(Mutex::new(session));
        let entry = self.sessions.lock().unwrap().insert(key, session, cost);
        Ok((entry, false))
    }

    /// Serve one request.  Safe to call from many threads concurrently;
    /// each request's task graphs interleave on the shared workers.
    pub fn run(&self, req: Request) -> anyhow::Result<Response> {
        self.run_with_cancel(req, &CancelToken::new())
    }

    /// [`Coordinator::run`] bound to a cancellation token (what
    /// [`Client`] tickets use).  When the token fires, not-yet-started
    /// runtime tasks of this request are skipped, the optimizer stops
    /// between evaluations, and the request reports
    /// [`ApiError::Cancelled`] — or [`ApiError::Timeout`] when the token
    /// was fired with a timeout reason (deadline reaper, runtime
    /// watchdog).  Cancellations and timeouts count in
    /// `stats().cancelled` / `stats().timeouts`, not as errors.
    ///
    /// Any other failure (a task panic, an unrecovered spill I/O error)
    /// is retried whole — up to `EXAGEOSTAT_JOB_RETRIES` times with
    /// capped exponential backoff — after evicting the request's
    /// possibly half-built dataset and session cache entries, so each
    /// attempt rebuilds from scratch.  The eviction also runs on final
    /// failure: a dead request must never leave a poisoned session
    /// behind for the next request over the same data.
    pub fn run_with_cancel(&self, req: Request, cancel: &CancelToken) -> anyhow::Result<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let retries = job_retry_limit();
        let mut attempt: u64 = 0;
        let r = loop {
            let r = if cancel.is_cancelled() {
                // Cancelled while queued: skip the work entirely.
                Err(if cancel.timed_out() {
                    ApiError::Timeout.into()
                } else {
                    ApiError::Cancelled.into()
                })
            } else {
                // Whether the token interrupted the work is decided
                // *inside* the layers that can observe it (the pipeline
                // sees skipped tasks, the optimizer latches an observed
                // stop) — never by re-reading the token here.  A token
                // that fires after the request completed must leave its
                // `Done` result alone, or `cancelled` double-counts
                // against a successful response.
                self.dispatch_guarded(&req, cancel)
            };
            match &r {
                Err(e) if !is_cancelled(e) && !is_timeout(e) && attempt < retries => {
                    self.evict_request_state(&req);
                    self.job_retries.fetch_add(1, Ordering::Relaxed);
                    attempt += 1;
                    // 10ms, 20ms, 40ms, ... capped at 200ms: enough to
                    // let a transient I/O condition clear without
                    // stalling the serving thread for seconds.
                    std::thread::sleep(Duration::from_millis(
                        (10u64 << (attempt - 1).min(4)).min(200),
                    ));
                }
                _ => break r,
            }
        };
        match &r {
            Err(e) if is_cancelled(e) => {
                self.cancelled.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) if is_timeout(e) => {
                self.timeouts.fetch_add(1, Ordering::Relaxed);
                self.evict_request_state(&req);
            }
            Err(_) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                self.evict_request_state(&req);
            }
            Ok(_) => {}
        }
        let (kind, data_cache_hit, session_cache_hit, outcome) = r?;
        Ok(Response {
            id,
            kind,
            wall_s: t0.elapsed().as_secs_f64(),
            data_cache_hit,
            session_cache_hit,
            outcome,
        })
    }

    /// [`Coordinator::dispatch`] behind a panic guard: a panic escaping
    /// a request (worker-task panics propagate through the job handle on
    /// the submitting thread) becomes a typed [`TaskError::Panic`]
    /// failure of *this* request instead of tearing down the serving
    /// thread — the accept loop and every other in-flight request keep
    /// going.
    fn dispatch_guarded(
        &self,
        req: &Request,
        cancel: &CancelToken,
    ) -> anyhow::Result<(&'static str, bool, bool, Outcome)> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.dispatch(req, cancel)))
        {
            Ok(r) => r,
            Err(p) => Err(anyhow::Error::new(TaskError::Panic(panic_message(p.as_ref())))),
        }
    }

    /// Evict every cache entry a failed request may have left half-built:
    /// its dataset, and every session keyed over that dataset (any
    /// variant / tile size — session keys are prefixed by the data key).
    /// A session whose job died mid-factorization holds garbage in its
    /// workspace (and a poisoned mutex if the death was a panic); the
    /// next request over this data must rebuild, not reuse.
    fn evict_request_state(&self, req: &Request) {
        let dkey = req.data.key();
        self.data_cache.lock().unwrap().remove(&dkey);
        let mut sessions = self.sessions.lock().unwrap();
        let prefix = format!("{dkey}|");
        let stale: Vec<String> = sessions
            .map
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .cloned()
            .collect();
        for k in stale {
            sessions.remove(&k);
        }
    }

    fn dispatch(
        &self,
        req: &Request,
        cancel: &CancelToken,
    ) -> anyhow::Result<(&'static str, bool, bool, Outcome)> {
        let mut ctx = self.ctx_with_priority(req.priority);
        ctx.cancel = cancel.clone();
        match &req.kind {
            RequestKind::Simulate => {
                let (d, hit) = self.dataset(&req.data, &ctx)?;
                Ok(("simulate", hit, false, Outcome::Simulated { n: d.0.len() }))
            }
            RequestKind::Mle { variant, opt } => {
                let (d, hit) = self.dataset(&req.data, &ctx)?;
                let (session, shit) = self.session_for(&req.data, *variant, &d, &ctx)?;
                let mut s = session.lock().unwrap();
                // A cached session captured the priority and token of
                // the request that built it; this request's win.
                s.set_job_prio(req.priority);
                s.set_cancel(cancel.clone());
                let r = mle_with_session(&mut s, opt)?;
                Ok(("mle", hit, shit, Outcome::Mle(r)))
            }
            RequestKind::Predict { grid } => {
                let DataSource::Spec(spec) = &req.data else {
                    anyhow::bail!(
                        "grid predict needs a simulated dataset spec (its generating theta); \
                         use PredictAt for inline data"
                    );
                };
                let (d, hit) = self.dataset(&req.data, &ctx)?;
                let g = (*grid).max(1);
                let new_locs: Vec<Location> = (0..g * g)
                    .map(|k| {
                        Location::new(
                            (k % g) as f64 / (g - 1).max(1) as f64,
                            (k / g) as f64 / (g - 1).max(1) as f64,
                        )
                    })
                    .collect();
                let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(&spec.kernel)?);
                let metric = DistanceMetric::parse(&spec.dmetric)?;
                let p = prediction::exact_predict_ctx(
                    kernel,
                    &spec.theta,
                    &d.0,
                    &d.1,
                    &new_locs,
                    metric,
                    true,
                    &ctx,
                )?;
                let mean_abs =
                    p.mean.iter().map(|v| v.abs()).sum::<f64>() / p.mean.len().max(1) as f64;
                Ok((
                    "predict",
                    hit,
                    false,
                    Outcome::Predicted {
                        npoints: new_locs.len(),
                        mean_abs,
                    },
                ))
            }
            RequestKind::PredictAt {
                new_locs,
                theta,
                with_variance,
            } => {
                let (d, hit) = self.dataset(&req.data, &ctx)?;
                let kernel: Arc<dyn CovKernel> = Arc::from(kernel_by_name(req.data.kernel())?);
                let metric = DistanceMetric::parse(req.data.dmetric())?;
                let p = prediction::exact_predict_ctx(
                    kernel,
                    theta,
                    &d.0,
                    &d.1,
                    new_locs,
                    metric,
                    *with_variance,
                    &ctx,
                )?;
                Ok(("predict_at", hit, false, Outcome::Prediction(p)))
            }
        }
    }

    /// Aggregate serving stats so far.
    pub fn stats(&self) -> CoordinatorStats {
        let (data_ev, session_ev) = (
            self.data_cache.lock().unwrap().evictions,
            self.sessions.lock().unwrap().evictions,
        );
        CoordinatorStats {
            requests: self.requests.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            job_retries: self.job_retries.load(Ordering::Relaxed),
            faults_injected: faults::faults_injected(),
            tasks_retried: faults::tasks_retried(),
            data_cache_hits: self.data_hits.load(Ordering::Relaxed),
            data_cache_misses: self.data_misses.load(Ordering::Relaxed),
            data_cache_evictions: data_ev,
            session_cache_hits: self.session_hits.load(Ordering::Relaxed),
            session_cache_misses: self.session_misses.load(Ordering::Relaxed),
            session_cache_evictions: session_ev,
            tasks_executed: self.runtime.tasks_executed(),
            tasks_skipped: self.runtime.tasks_skipped(),
            worker_threads: self.runtime.nworkers(),
            class_stats: self.runtime.class_stats(),
        }
    }

    /// Drain in-flight jobs and join the shared workers (the
    /// `exageostat_finalize` of the serving layer).
    pub fn shutdown(&self) {
        self.runtime.shutdown();
    }
}

impl Dispatch for Coordinator {
    fn run_with_cancel(&self, req: Request, cancel: &CancelToken) -> anyhow::Result<Response> {
        Coordinator::run_with_cancel(self, req, cancel)
    }
    fn queue_depth(&self) -> usize {
        self.runtime.queue_depth()
    }
    fn nworkers(&self) -> usize {
        self.runtime.nworkers()
    }
    fn stats(&self) -> CoordinatorStats {
        Coordinator::stats(self)
    }
    fn shutdown_dispatch(&self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// JSONL request parsing (offline substitute for serde — flat JSON
// objects with string / number / bool / number-array values).
// ---------------------------------------------------------------------

/// Minimal JSON value (what the request grammar needs).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl JsonParser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(c),
            "expected {:?} at byte {}",
            c as char,
            self.i
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number");
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| anyhow::anyhow!("bad number {s:?} at byte {start}"))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                anyhow::bail!("unterminated string")
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        anyhow::bail!("bad escape")
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow::anyhow!("bad \\u escape {hex:?}"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => anyhow::bail!("unknown escape \\{}", other as char),
                    }
                }
                _ => {
                    // copy the raw byte run (UTF-8 passes through intact)
                    let start = self.i - 1;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

/// Parse one JSON document.
pub fn parse_json(src: &str) -> anyhow::Result<Json> {
    let mut p = JsonParser {
        b: src.as_bytes(),
        i: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.i == p.b.len(), "trailing characters at byte {}", p.i);
    Ok(v)
}

fn field<'a>(obj: &'a [(String, Json)], key: &str) -> Option<&'a Json> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

fn get_num(obj: &[(String, Json)], key: &str, default: f64) -> anyhow::Result<f64> {
    match field(obj, key) {
        None => Ok(default),
        Some(Json::Num(v)) => Ok(*v),
        Some(other) => anyhow::bail!("field {key:?} must be a number, got {other:?}"),
    }
}

fn get_usize(obj: &[(String, Json)], key: &str, default: usize) -> anyhow::Result<usize> {
    let v = get_num(obj, key, default as f64)?;
    anyhow::ensure!(
        v >= 0.0 && v.fract() == 0.0,
        "field {key:?} must be a non-negative integer, got {v}"
    );
    Ok(v as usize)
}

fn get_str(obj: &[(String, Json)], key: &str, default: &str) -> anyhow::Result<String> {
    match field(obj, key) {
        None => Ok(default.to_string()),
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(other) => anyhow::bail!("field {key:?} must be a string, got {other:?}"),
    }
}

fn get_f64_arr(obj: &[(String, Json)], key: &str) -> anyhow::Result<Option<Vec<f64>>> {
    match field(obj, key) {
        None => Ok(None),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|j| match j {
                Json::Num(v) => Ok(*v),
                other => anyhow::bail!("field {key:?} must hold numbers, got {other:?}"),
            })
            .collect::<anyhow::Result<Vec<f64>>>()
            .map(Some),
        Some(other) => anyhow::bail!("field {key:?} must be an array, got {other:?}"),
    }
}

/// Parse one request line, e.g.
/// `{"type":"mle","n":400,"seed":1,"variant":"dst","band":2,"max_iters":50}`.
///
/// Recognized fields: `type` (`mle`|`predict`|`simulate`, default `mle`),
/// dataset (`n`, `seed`, `kernel`, `dmetric`, `theta`), MLE (`variant`,
/// `band`, `tlr_tol`, `max_rank`, `clb`, `cub`, `tol`, `max_iters`,
/// `method`), predict (`grid`), `priority`, and `deadline_ms` (soft
/// per-request deadline, enforced by the serving layers).
pub fn parse_request(line: &str) -> anyhow::Result<Request> {
    let Json::Obj(obj) = parse_json(line)? else {
        anyhow::bail!("request line must be a JSON object");
    };
    let data = DataSpec {
        n: get_usize(&obj, "n", 400)?,
        seed: get_usize(&obj, "seed", 0)? as u64,
        kernel: get_str(&obj, "kernel", "ugsm-s")?,
        dmetric: get_str(&obj, "dmetric", "euclidean")?,
        theta: get_f64_arr(&obj, "theta")?.unwrap_or_else(|| vec![1.0, 0.1, 0.5]),
    };
    // Reject absurd sizes at parse time: a runaway `n` would otherwise
    // attempt an O(n^2) allocation inside a client thread and take the
    // whole serve run down instead of failing this one request.
    anyhow::ensure!(
        (1..=1_000_000).contains(&data.n),
        "n must be in 1..=1000000, got {}",
        data.n
    );
    let priority = get_usize(&obj, "priority", 0)?.min(u8::MAX as usize) as u8;
    let deadline_ms = match field(&obj, "deadline_ms") {
        None => None,
        Some(_) => Some(get_usize(&obj, "deadline_ms", 0)? as u64),
    };
    let ty = get_str(&obj, "type", "mle")?;
    let kind = match ty.as_str() {
        "simulate" => RequestKind::Simulate,
        "predict" => {
            let grid = get_usize(&obj, "grid", 8)?;
            anyhow::ensure!(
                (1..=1024).contains(&grid),
                "grid must be in 1..=1024, got {grid}"
            );
            RequestKind::Predict { grid }
        }
        "mle" => {
            let variant = match get_str(&obj, "variant", "exact")?.as_str() {
                "exact" => Variant::Exact,
                "dst" => Variant::Dst {
                    band: get_usize(&obj, "band", 1)?,
                },
                "tlr" => Variant::Tlr {
                    tol: get_num(&obj, "tlr_tol", 1e-7)?,
                    max_rank: get_usize(&obj, "max_rank", usize::MAX)?,
                },
                "mp" => Variant::Mp {
                    band: get_usize(&obj, "band", 1)?,
                },
                other => anyhow::bail!("unknown variant {other:?} (exact|dst|tlr|mp)"),
            };
            let nparams = kernel_by_name(&data.kernel)?.nparams();
            let opt = MleOptions {
                clb: get_f64_arr(&obj, "clb")?.unwrap_or_else(|| vec![0.001; nparams]),
                cub: get_f64_arr(&obj, "cub")?.unwrap_or_else(|| vec![5.0; nparams]),
                tol: get_num(&obj, "tol", 1e-4)?,
                max_iters: get_usize(&obj, "max_iters", 0)?,
                method: Method::parse(&get_str(&obj, "method", "bobyqa")?)?,
            };
            RequestKind::Mle { variant, opt }
        }
        other => anyhow::bail!("unknown request type {other:?} (mle|predict|simulate)"),
    };
    Ok(Request {
        data: data.into(),
        kind,
        priority,
        deadline_ms,
    })
}

/// Parse a whole JSONL request file (blank lines and `#` comments are
/// skipped).
pub fn parse_requests_jsonl(text: &str) -> anyhow::Result<Vec<Request>> {
    text.lines()
        .map(str::trim)
        .enumerate()
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'))
        .map(|(i, l)| parse_request(l).with_context(|| format!("request at line {}", i + 1)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::pool::Policy;

    fn hw(ncores: usize, ts: usize) -> Hardware {
        Hardware {
            ncores,
            ts,
            policy: Policy::Prio,
            ..Hardware::default()
        }
    }

    #[test]
    fn json_parser_round_trips_request_shapes() {
        let j = parse_json(r#"{"a": 1.5, "b": [1, 2.25, -3e-1], "c": "x\ny", "d": true}"#).unwrap();
        let Json::Obj(obj) = j else { panic!("obj") };
        assert_eq!(field(&obj, "a"), Some(&Json::Num(1.5)));
        assert_eq!(
            field(&obj, "b"),
            Some(&Json::Arr(vec![
                Json::Num(1.0),
                Json::Num(2.25),
                Json::Num(-0.3)
            ]))
        );
        assert_eq!(field(&obj, "c"), Some(&Json::Str("x\ny".into())));
        assert_eq!(field(&obj, "d"), Some(&Json::Bool(true)));
        assert!(parse_json("{\"unterminated\": ").is_err());
        assert!(parse_json("{} trailing").is_err());
    }

    #[test]
    fn request_lines_parse_with_defaults() {
        let r = parse_request(r#"{"type":"mle","n":100,"variant":"dst","band":2}"#).unwrap();
        let DataSource::Spec(spec) = &r.data else {
            panic!("jsonl requests are spec-backed");
        };
        assert_eq!(spec.n, 100);
        assert_eq!(spec.kernel, "ugsm-s");
        match r.kind {
            RequestKind::Mle { variant, ref opt } => {
                assert_eq!(variant, Variant::Dst { band: 2 });
                assert_eq!(opt.clb.len(), 3);
                assert_eq!(opt.max_iters, 0);
            }
            ref other => panic!("wrong kind {other:?}"),
        }
        let p = parse_request(r#"{"type":"predict","grid":5,"priority":3}"#).unwrap();
        assert_eq!(p.priority, 3);
        assert!(matches!(p.kind, RequestKind::Predict { grid: 5 }));
        assert!(parse_request(r#"{"type":"nope"}"#).is_err());
        assert!(parse_request(r#"[1,2]"#).is_err());

        let reqs = parse_requests_jsonl(
            "# comment\n\n{\"type\":\"simulate\",\"n\":50}\n{\"type\":\"mle\",\"max_iters\":5}\n",
        )
        .unwrap();
        assert_eq!(reqs.len(), 2);
        assert!(matches!(reqs[0].kind, RequestKind::Simulate));
    }

    #[test]
    fn coordinator_caches_dataset_and_session() {
        let coord = Coordinator::new(hw(2, 32));
        let data = DataSpec {
            n: 80,
            seed: 11,
            ..DataSpec::default()
        };
        let sim = Request {
            data: data.clone().into(),
            kind: RequestKind::Simulate,
            priority: 0,
            deadline_ms: None,
        };
        let r0 = coord.run(sim.clone()).unwrap();
        assert!(!r0.data_cache_hit);
        let r1 = coord.run(sim).unwrap();
        assert!(r1.data_cache_hit);

        let mle = Request {
            data: data.clone().into(),
            kind: RequestKind::Mle {
                variant: Variant::Exact,
                opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 8),
            },
            priority: 0,
            deadline_ms: None,
        };
        let m0 = coord.run(mle.clone()).unwrap();
        assert!(!m0.session_cache_hit);
        let m1 = coord.run(mle).unwrap();
        assert!(m1.session_cache_hit, "second identical MLE reuses session");
        let (Outcome::Mle(a), Outcome::Mle(b)) = (&m0.outcome, &m1.outcome) else {
            panic!("mle outcomes");
        };
        assert_eq!(a.loglik.to_bits(), b.loglik.to_bits());

        let st = coord.stats();
        assert_eq!(st.requests, 4);
        assert_eq!(st.errors, 0);
        assert!(st.data_cache_hits >= 3);
        assert_eq!(st.session_cache_hits, 1);
        assert!(st.tasks_executed > 0);
        assert_eq!(st.worker_threads, 2);
        coord.shutdown();
    }

    #[test]
    fn lru_cache_evicts_by_cost_and_recency() {
        // budget 10: "a"(4) + "b"(4) fit; "c"(4) must evict the LRU.
        let mut c: LruCache<Arc<usize>> = LruCache::new(10);
        let a = c.insert("a".into(), Arc::new(1), 4);
        assert_eq!(*a, 1);
        // racing insert under the same key keeps the winner
        let a2 = c.insert("a".into(), Arc::new(99), 4);
        assert_eq!(*a2, 1);
        c.insert("b".into(), Arc::new(2), 4);
        // touch "a" so "b" becomes least-recently used
        assert!(c.get("a").is_some());
        c.insert("c".into(), Arc::new(3), 4); // evicts "b", not "a"
        assert!(c.get("b").is_none());
        assert!(c.get("a").is_some() && c.get("c").is_some());
        assert_eq!(c.evictions, 1);
        assert!(c.used <= 10);

        // an entry bigger than the whole budget still caches, alone
        c.insert("huge".into(), Arc::new(7), 100);
        assert!(c.get("huge").is_some());
        assert!(c.get("a").is_none() && c.get("c").is_none());
        assert_eq!(c.map.len(), 1);
        assert_eq!(c.evictions, 3);
    }

    #[test]
    fn coordinator_session_cache_evicts_by_footprint() {
        // Session budget far below one session's distance cache: every
        // MLE misses and the previous session is evicted.
        let coord = Coordinator::with_cache_budgets(hw(1, 16), DATA_CACHE_BUDGET, 1);
        let mle = |seed: u64| Request {
            data: DataSpec {
                n: 40,
                seed,
                ..DataSpec::default()
            }
            .into(),
            kind: RequestKind::Mle {
                variant: Variant::Exact,
                opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-2, 4),
            },
            priority: 0,
            deadline_ms: None,
        };
        coord.run(mle(1)).unwrap();
        coord.run(mle(2)).unwrap();
        coord.run(mle(1)).unwrap(); // would hit under an unbounded cache
        let st = coord.stats();
        assert_eq!(st.session_cache_hits, 0);
        assert_eq!(st.session_cache_misses, 3);
        assert!(st.session_cache_evictions >= 2, "{st:?}");
        coord.shutdown();
    }

    #[test]
    fn request_size_bounds_enforced() {
        assert!(parse_request(r#"{"type":"simulate","n":1e18}"#).is_err());
        assert!(parse_request(r#"{"type":"simulate","n":0}"#).is_err());
        assert!(parse_request(r#"{"type":"predict","grid":100000}"#).is_err());
        assert!(parse_request(r#"{"type":"predict","grid":8}"#).is_ok());
    }

    #[test]
    fn coordinator_reports_errors_and_stays_usable() {
        let coord = Coordinator::new(hw(1, 16));
        let bad = Request {
            data: DataSpec {
                kernel: "no-such-kernel".into(),
                ..DataSpec::default()
            }
            .into(),
            kind: RequestKind::Simulate,
            priority: 0,
            deadline_ms: None,
        };
        assert!(coord.run(bad).is_err());
        let ok = Request {
            data: DataSpec {
                n: 40,
                ..DataSpec::default()
            }
            .into(),
            kind: RequestKind::Predict { grid: 3 },
            priority: 0,
            deadline_ms: None,
        };
        let r = coord.run(ok).unwrap();
        let Outcome::Predicted { npoints, .. } = r.outcome else {
            panic!("predict outcome");
        };
        assert_eq!(npoints, 9);
        let st = coord.stats();
        assert_eq!(st.errors, 1);
        assert_eq!(st.requests, 2);
    }
}
