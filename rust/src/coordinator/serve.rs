//! Streaming serve loop: read JSONL requests **incrementally** — from a
//! file, a pipe/stdin, or a unix socket — and admit them as they
//! arrive, under a bounded in-flight window with runtime-queue-depth
//! backpressure.
//!
//! The pre-streaming `serve` read the whole request file up front; a
//! pipe had to reach EOF before the first request even started.  This
//! loop instead:
//!
//! 1. reads one line, parses it, and **admits** it through a
//!    [`Client`] ticket (non-blocking submit);
//! 2. before each admission, if the in-flight window is full *or* the
//!    runtime's ready-task queue is deeper than `depth_limit`
//!    (tasks already outnumber what the workers can start — admitting
//!    more only grows latency), blocks on the oldest ticket first;
//! 3. emits every completion through the caller's callback as soon as
//!    it is reaped — long before EOF on a live stream.
//!
//! The returned [`ServeSummary`] carries ok/failed/cancelled counts and
//! the completed-request latencies (sorted, for percentile reporting).

use super::client::{Client, Completion};
use super::parse_request;
use std::collections::VecDeque;
use std::io::BufRead;
use std::time::Duration;

/// Admission-control knobs for [`serve_stream`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Max requests in flight at once (ticketed but not reaped).
    pub window: usize,
    /// Hold admissions while the runtime has more than this many ready
    /// tasks queued; `None` derives `4 * workers` from the runtime.
    pub depth_limit: Option<usize>,
    /// Default per-request deadline in milliseconds (`serve --deadline`):
    /// applied to every admitted request that does not carry its own
    /// `deadline_ms`.  `None` = unbounded.
    pub deadline_ms: Option<u64>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            window: 8,
            depth_limit: None,
            deadline_ms: None,
        }
    }
}

/// Outcome counts + latency telemetry of one [`serve_stream`] run.
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    /// Requests admitted (parsed and submitted).
    pub submitted: usize,
    /// Requests that completed successfully.
    pub ok: usize,
    /// Requests that failed.
    pub failed: usize,
    /// Requests that ended cancelled.
    pub cancelled: usize,
    /// Requests that exceeded their deadline and were reaped as
    /// [`Completion::TimedOut`].
    pub timed_out: usize,
    /// Lines that did not parse as a request (skipped, not fatal).
    pub parse_errors: usize,
    /// Wall-clock latencies (seconds) of the successful requests,
    /// sorted ascending — feed to `testkit::percentile`.
    pub latencies_s: Vec<f64>,
}

/// Drive a [`Client`] from an incremental JSONL stream (see module
/// docs).  Blank lines and `#` comments are skipped; unparsable lines
/// are counted and skipped.  `on_done(submission_index, completion)`
/// fires for every reaped request, in reap order.
///
/// Errors only on transport failure (`reader` I/O); request-level
/// failures are reported through the callback and the summary.
pub fn serve_stream(
    client: &Client,
    reader: &mut dyn BufRead,
    opts: &ServeOptions,
    mut on_done: impl FnMut(u64, &Completion),
) -> anyhow::Result<ServeSummary> {
    let window = opts.window.max(1);
    let depth_limit = opts
        .depth_limit
        .unwrap_or_else(|| 4 * client.coordinator().nworkers());
    let mut inflight: VecDeque<super::Ticket> = VecDeque::new();
    let mut summary = ServeSummary::default();
    let mut line = String::new();

    let mut reap = |summary: &mut ServeSummary,
                    inflight: &mut VecDeque<super::Ticket>,
                    on_done: &mut dyn FnMut(u64, &Completion)| {
        if let Some(t) = inflight.pop_front() {
            // Reap the oldest ticket, sweeping deadlines across the
            // whole window while blocked: a ticket *behind* the oldest
            // must still time out on schedule even though it is not the
            // one being waited on (its own `wait` only runs once it
            // reaches the front).
            let done = loop {
                if let Some(c) = t.wait_timeout(Duration::from_millis(50)) {
                    break c;
                }
                t.enforce_deadline();
                for other in inflight.iter() {
                    other.enforce_deadline();
                }
            };
            match &done {
                Completion::Done(r) => {
                    summary.ok += 1;
                    summary.latencies_s.push(r.wall_s);
                }
                Completion::Cancelled => summary.cancelled += 1,
                Completion::TimedOut => summary.timed_out += 1,
                Completion::Failed(_) => summary.failed += 1,
            }
            on_done(t.id(), &done);
        }
    };

    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break; // EOF
        }
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let mut req = match parse_request(trimmed) {
            Ok(r) => r,
            Err(e) => {
                summary.parse_errors += 1;
                eprintln!("serve: skipping unparsable request: {e:#}");
                continue;
            }
        };
        // `serve --deadline` default; a request's own deadline_ms wins.
        if req.deadline_ms.is_none() {
            req.deadline_ms = opts.deadline_ms;
        }
        // Admission control: the window bounds client-side in-flight
        // requests; the queue-depth check holds admissions while the
        // workers are already saturated with ready tasks.
        while inflight.len() >= window
            || (!inflight.is_empty() && client.coordinator().queue_depth() > depth_limit)
        {
            reap(&mut summary, &mut inflight, &mut on_done);
        }
        inflight.push_back(client.submit(req));
        summary.submitted += 1;
    }
    while !inflight.is_empty() {
        reap(&mut summary, &mut inflight, &mut on_done);
    }
    summary.latencies_s.sort_by(f64::total_cmp);
    Ok(summary)
}

impl ServeSummary {
    /// Fold another connection's summary into this one (counters sum,
    /// latencies merge sorted) — how [`serve_socket`] aggregates across
    /// its accept loop.
    pub fn merge(&mut self, o: ServeSummary) {
        self.submitted += o.submitted;
        self.ok += o.ok;
        self.failed += o.failed;
        self.cancelled += o.cancelled;
        self.timed_out += o.timed_out;
        self.parse_errors += o.parse_errors;
        self.latencies_s.extend(o.latencies_s);
        self.latencies_s.sort_by(f64::total_cmp);
    }
}

/// Removes the bound socket path on drop, so *every* exit path — clean
/// EOF, transport error mid-connection, panic unwinding — cleans up.
/// (The pre-RAII serve leaked the path whenever `serve_stream` errored.)
struct SocketGuard(std::path::PathBuf);

impl Drop for SocketGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

/// Serve JSONL requests over a unix socket: bind `path`, then accept
/// connections **in a loop** — each connection is one [`serve_stream`]
/// to its EOF — until `max_conns` is reached (`None` = loop until the
/// process is killed).  Per-connection summaries are merged.
///
/// Binding is careful about pre-existing paths:
///
/// * a **live** socket (something accepts our probe connection) is an
///   error — silently stealing the path would orphan the running
///   server's clients;
/// * a **stale** socket (connect fails: the owner is gone) is removed
///   and rebound — the normal recovery after a `kill -9`;
/// * the bound path is removed on all exit paths via an RAII guard.
pub fn serve_socket(
    client: &Client,
    path: &str,
    opts: &ServeOptions,
    max_conns: Option<usize>,
    mut on_done: impl FnMut(u64, &Completion),
) -> anyhow::Result<ServeSummary> {
    use std::os::unix::net::{UnixListener, UnixStream};

    if std::path::Path::new(path).exists() {
        match UnixStream::connect(path) {
            Ok(_) => anyhow::bail!(
                "socket {path} is owned by a live server — refusing to steal it \
                 (stop the other process or pick another path)"
            ),
            Err(_) => {
                // Nobody accepts: a stale path from a killed process.
                std::fs::remove_file(path)
                    .map_err(|e| anyhow::anyhow!("removing stale socket {path}: {e}"))?;
            }
        }
    }
    let listener = UnixListener::bind(path)
        .map_err(|e| anyhow::anyhow!("binding unix socket {path}: {e}"))?;
    let _guard = SocketGuard(std::path::PathBuf::from(path));

    let mut total = ServeSummary::default();
    let mut served = 0usize;
    while max_conns.map_or(true, |m| served < m) {
        let (conn, _) = listener
            .accept()
            .map_err(|e| anyhow::anyhow!("accepting on {path}: {e}"))?;
        let mut reader = std::io::BufReader::new(conn);
        let s = serve_stream(client, &mut reader, opts, &mut on_done)?;
        total.merge(s);
        served += 1;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Hardware;
    use crate::coordinator::Coordinator;
    use crate::scheduler::pool::Policy;
    use std::io::{BufReader, Read};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    fn hw(ncores: usize, ts: usize) -> Hardware {
        Hardware {
            ncores,
            ts,
            policy: Policy::Prio,
            ..Hardware::default()
        }
    }

    #[test]
    fn stream_processes_all_lines_with_mixed_outcomes() {
        let coord = Arc::new(Coordinator::new(hw(2, 32)));
        let client = Client::new(coord.clone(), 2);
        let jsonl = "\
# comment line
{\"type\":\"simulate\",\"n\":60,\"seed\":1}

{\"type\":\"mle\",\"n\":60,\"seed\":1,\"max_iters\":4,\"tol\":1e-2}
{\"type\":\"predict\",\"n\":60,\"seed\":1,\"grid\":3}
this is not json
{\"type\":\"simulate\",\"n\":60,\"seed\":2}
";
        let mut reader = BufReader::new(jsonl.as_bytes());
        let seen = std::cell::Cell::new(0usize);
        let summary = serve_stream(
            &client,
            &mut reader,
            &ServeOptions {
                window: 2,
                depth_limit: None,
                deadline_ms: None,
            },
            |_, _| seen.set(seen.get() + 1),
        )
        .unwrap();
        assert_eq!(summary.submitted, 4);
        assert_eq!(summary.ok, 4);
        assert_eq!(summary.failed, 0);
        assert_eq!(summary.cancelled, 0);
        assert_eq!(summary.parse_errors, 1);
        assert_eq!(summary.latencies_s.len(), 4);
        assert!(summary.latencies_s.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(seen.get(), 4);
        client.shutdown();
        coord.shutdown();
    }

    /// A reader that refuses to serve its final line until at least one
    /// completion has been observed: if the serve loop required EOF
    /// before producing its first response, this would deadlock (the
    /// 20s cap turns that bug into a loud failure instead).
    struct GatedReader {
        parts: Vec<Vec<u8>>,
        next: usize,
        gate_at: usize,
        completions: Arc<AtomicUsize>,
        timed_out: bool,
    }

    impl Read for GatedReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.next >= self.parts.len() {
                return Ok(0); // EOF
            }
            if self.next == self.gate_at {
                let t0 = Instant::now();
                while self.completions.load(Ordering::SeqCst) == 0 {
                    if t0.elapsed() > Duration::from_secs(20) {
                        self.timed_out = true;
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            let part = &self.parts[self.next];
            assert!(buf.len() >= part.len(), "test parts are line-sized");
            buf[..part.len()].copy_from_slice(part);
            self.next += 1;
            Ok(part.len())
        }
    }

    #[test]
    fn first_response_arrives_before_eof() {
        let coord = Arc::new(Coordinator::new(hw(2, 32)));
        let client = Client::new(coord.clone(), 2);
        let completions = Arc::new(AtomicUsize::new(0));
        let lines = [
            "{\"type\":\"simulate\",\"n\":50,\"seed\":1}\n",
            "{\"type\":\"simulate\",\"n\":50,\"seed\":2}\n",
            "{\"type\":\"simulate\",\"n\":50,\"seed\":3}\n",
        ];
        let gated = GatedReader {
            parts: lines.iter().map(|l| l.as_bytes().to_vec()).collect(),
            next: 0,
            gate_at: 2, // the last line waits for a completion
            completions: completions.clone(),
            timed_out: false,
        };
        let mut reader = BufReader::new(gated);
        let completions_cb = completions.clone();
        // window 1 forces a reap (and therefore a response) between
        // admissions — the streaming property under test.
        let summary = serve_stream(
            &client,
            &mut reader,
            &ServeOptions {
                window: 1,
                depth_limit: None,
                deadline_ms: None,
            },
            move |_, _| {
                completions_cb.fetch_add(1, Ordering::SeqCst);
            },
        )
        .unwrap();
        assert!(
            !reader.into_inner().timed_out,
            "no response was produced before EOF — serve is not streaming"
        );
        assert_eq!(summary.submitted, 3);
        assert_eq!(summary.ok, 3);
        client.shutdown();
        coord.shutdown();
    }

    fn sock_path(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("exa-serve-{}-{tag}.sock", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn connect_retry(path: &str) -> std::os::unix::net::UnixStream {
        let t0 = Instant::now();
        loop {
            match std::os::unix::net::UnixStream::connect(path) {
                Ok(c) => return c,
                Err(_) if t0.elapsed() < Duration::from_secs(20) => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => panic!("connecting {path}: {e}"),
            }
        }
    }

    /// The headline socket bugfix: the accept loop serves a *second*
    /// connection (the old serve exited after one), and the socket file
    /// is gone afterwards.
    #[test]
    fn socket_serves_two_sequential_connections_and_cleans_up() {
        let coord = Arc::new(Coordinator::new(hw(2, 32)));
        let client = Client::new(coord.clone(), 2);
        let path = sock_path("two-conns");
        let _ = std::fs::remove_file(&path);
        let wpath = path.clone();
        let writer = std::thread::spawn(move || {
            use std::io::Write;
            for seed in [1u64, 2] {
                let mut conn = connect_retry(&wpath);
                writeln!(conn, "{{\"type\":\"simulate\",\"n\":50,\"seed\":{seed}}}").unwrap();
                conn.shutdown(std::net::Shutdown::Write).unwrap();
            }
        });
        let summary =
            serve_socket(&client, &path, &ServeOptions::default(), Some(2), |_, _| {}).unwrap();
        writer.join().unwrap();
        assert_eq!(summary.submitted, 2);
        assert_eq!(summary.ok, 2);
        assert_eq!(summary.latencies_s.len(), 2);
        assert!(
            !std::path::Path::new(&path).exists(),
            "socket file must be removed on exit"
        );
        client.shutdown();
        coord.shutdown();
    }

    /// The stale-cleanup bugfix, both halves: a path owned by a live
    /// listener is refused (not silently stolen), while a stale path
    /// left by a killed process is removed and rebound.
    #[test]
    fn live_socket_is_refused_and_stale_socket_is_recovered() {
        let coord = Arc::new(Coordinator::new(hw(1, 16)));
        let client = Client::new(coord.clone(), 1);
        let path = sock_path("probe");
        let _ = std::fs::remove_file(&path);

        // Live owner: serve_socket must refuse and leave the path alone.
        let live = std::os::unix::net::UnixListener::bind(&path).unwrap();
        let err = serve_socket(&client, &path, &ServeOptions::default(), Some(1), |_, _| {})
            .unwrap_err();
        assert!(err.to_string().contains("live server"), "{err:#}");
        assert!(
            std::path::Path::new(&path).exists(),
            "the live owner's socket must not be deleted"
        );
        drop(live);

        // The dropped listener leaves a stale file; serve_socket removes
        // it, rebinds, and serves.
        assert!(std::path::Path::new(&path).exists());
        let wpath = path.clone();
        let writer = std::thread::spawn(move || {
            use std::io::Write;
            let mut conn = connect_retry(&wpath);
            writeln!(conn, "{{\"type\":\"simulate\",\"n\":40,\"seed\":7}}").unwrap();
            conn.shutdown(std::net::Shutdown::Write).unwrap();
        });
        let summary =
            serve_socket(&client, &path, &ServeOptions::default(), Some(1), |_, _| {}).unwrap();
        writer.join().unwrap();
        assert_eq!(summary.ok, 1);
        assert!(!std::path::Path::new(&path).exists());
        client.shutdown();
        coord.shutdown();
    }

    #[test]
    fn deep_queue_holds_admissions() {
        // depth_limit 0 + an in-flight request forces the loop down the
        // backpressure path (reap before admit) whenever any ready task
        // is queued; with window 4 the summary still completes fully.
        let coord = Arc::new(Coordinator::new(hw(1, 16)));
        let client = Client::new(coord.clone(), 2);
        let jsonl = (0..6)
            .map(|i| format!("{{\"type\":\"simulate\",\"n\":80,\"seed\":{i}}}\n"))
            .collect::<String>();
        let mut reader = BufReader::new(jsonl.as_bytes());
        let summary = serve_stream(
            &client,
            &mut reader,
            &ServeOptions {
                window: 4,
                depth_limit: Some(0),
                deadline_ms: None,
            },
            |_, _| {},
        )
        .unwrap();
        assert_eq!(summary.submitted, 6);
        assert_eq!(summary.ok, 6);
        client.shutdown();
        coord.shutdown();
    }
}
