//! Sharded fan-out coordinator: N member [`Coordinator`]s, each owning
//! its own runtime, behind the one [`Dispatch`] surface.
//!
//! Two concurrency regimes compose here (mirroring the paper's
//! distributed runs, where a 2-D block-cyclic tile distribution spreads
//! one Cholesky across nodes while independent requests land on
//! different nodes):
//!
//! * **Across requests** — each request is routed *whole* to one member
//!   by a stable hash of its dataset key, so repeated requests over the
//!   same data keep hitting that member's warm dataset/session caches
//!   (shard affinity).  Distinct datasets spread across members and run
//!   fully concurrently on disjoint worker pools.
//! * **Within a request** — every member carries the one shared
//!   [`ShardSet`] over *all* members' runtimes
//!   ([`Coordinator::attach_shards`]); a tiled pipeline with at least
//!   [`MIN_NT`] tiles per side partitions 2-D block-cyclic across every
//!   runtime (`pipeline::shard::execute_sharded`), exchanging boundary
//!   tiles through the lock-free mailbox.  Small pipelines stay on
//!   their routed member — sharding a 2×2 tile grid would only pay
//!   transfer overhead.
//!
//! Results are bit-identical to a single [`Coordinator`] for f64
//! exact/DST work — the sharded executor preserves every plan edge and
//! the host-side reduction order (`rust/tests/sharded.rs`).

use super::{Coordinator, CoordinatorStats, Dispatch, Request, Response};
use crate::api::Hardware;
use crate::pipeline::shard::ShardSet;
use crate::scheduler::runtime::CancelToken;
use std::sync::Arc;

/// Tile-grid side below which a routed request's pipelines run whole on
/// their member runtime instead of sharding across all of them: with
/// fewer than 16 tiles per side the per-stage mailbox round-trips cost
/// more than the added workers buy.
const MIN_NT: usize = 16;

/// See module docs.
pub struct ShardedCoordinator {
    members: Vec<Arc<Coordinator>>,
}

impl ShardedCoordinator {
    /// Build `nshards` member coordinators splitting `hw.ncores` worker
    /// threads evenly (`hw.ncores` is the TOTAL across members; each
    /// member gets at least one), and wire the shared [`ShardSet`] into
    /// every member.
    pub fn new(hw: Hardware, nshards: usize) -> ShardedCoordinator {
        ShardedCoordinator::build(hw, nshards, None)
    }

    /// [`ShardedCoordinator::new`] under one unified memory budget of
    /// `total_bytes` for the whole set: each member gets an equal slice,
    /// split internally by [`Coordinator::with_mem_budget`].
    pub fn with_mem_budget(hw: Hardware, nshards: usize, total_bytes: usize) -> ShardedCoordinator {
        let per = (total_bytes / nshards.max(1)).max(1);
        ShardedCoordinator::build(hw, nshards, Some(per))
    }

    fn build(hw: Hardware, nshards: usize, member_budget: Option<usize>) -> ShardedCoordinator {
        let nshards = nshards.max(1);
        let per_shard = (hw.ncores.max(1) / nshards).max(1);
        let members: Vec<Arc<Coordinator>> = (0..nshards)
            .map(|_| {
                let mut mhw = hw.clone();
                mhw.ncores = per_shard;
                Arc::new(match member_budget {
                    Some(b) => Coordinator::with_mem_budget(mhw, b),
                    None => Coordinator::new(mhw),
                })
            })
            .collect();
        let runtimes = members.iter().map(|m| m.runtime().clone()).collect();
        let set = Arc::new(ShardSet::from_runtimes(runtimes, MIN_NT));
        for m in &members {
            m.attach_shards(set.clone());
        }
        ShardedCoordinator { members }
    }

    pub fn nshards(&self) -> usize {
        self.members.len()
    }

    /// The member a request's dataset routes to (for tests).
    pub fn route_of(&self, req: &Request) -> usize {
        // FNV-1a over the dataset key: stable across runs (cache
        // affinity must survive reconnects), independent of HashMap's
        // randomized state.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in req.data.key().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h % self.members.len() as u64) as usize
    }

    /// Member coordinator `i` (for tests / introspection).
    pub fn member(&self, i: usize) -> &Arc<Coordinator> {
        &self.members[i]
    }
}

impl Dispatch for ShardedCoordinator {
    fn run_with_cancel(&self, req: Request, cancel: &CancelToken) -> anyhow::Result<Response> {
        let m = self.route_of(&req);
        self.members[m].run_with_cancel(req, cancel)
    }
    fn queue_depth(&self) -> usize {
        self.members.iter().map(|m| m.runtime().queue_depth()).sum()
    }
    fn nworkers(&self) -> usize {
        self.members.iter().map(|m| m.runtime().nworkers()).sum()
    }
    fn stats(&self) -> CoordinatorStats {
        let mut total = CoordinatorStats::default();
        for m in &self.members {
            total.accumulate(&m.stats());
        }
        total
    }
    fn shutdown_dispatch(&self) {
        for m in &self.members {
            m.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{DataSpec, Outcome, RequestKind};
    use crate::scheduler::pool::Policy;

    fn hw(ncores: usize, ts: usize) -> Hardware {
        Hardware {
            ncores,
            ts,
            policy: Policy::Lws,
            ..Hardware::default()
        }
    }

    fn sim_req(n: usize, seed: u64) -> Request {
        Request {
            data: DataSpec {
                n,
                seed,
                ..DataSpec::default()
            }
            .into(),
            kind: RequestKind::Simulate,
            priority: 0,
            deadline_ms: None,
        }
    }

    #[test]
    fn routing_is_stable_and_cache_affine() {
        let sc = ShardedCoordinator::new(hw(2, 32), 2);
        let a = sim_req(60, 1);
        let b = sim_req(60, 2);
        assert_eq!(sc.route_of(&a), sc.route_of(&sim_req(60, 1)));
        // Serve `a` twice: the second hit lands on the same member's
        // warm dataset cache.
        let r1 = sc.run_with_cancel(a.clone(), &CancelToken::new()).unwrap();
        let r2 = sc.run_with_cancel(a, &CancelToken::new()).unwrap();
        assert!(!r1.data_cache_hit);
        assert!(r2.data_cache_hit);
        assert!(matches!(r2.outcome, Outcome::Simulated { n: 60 }));
        let _ = sc.run_with_cancel(b, &CancelToken::new()).unwrap();
        // Aggregate stats sum across members.
        let st = sc.stats();
        assert_eq!(st.requests, 3);
        assert_eq!(st.data_cache_hits, 1);
        assert_eq!(st.data_cache_misses, 2);
        assert_eq!(st.worker_threads, 2);
        sc.shutdown_dispatch();
    }

    #[test]
    fn total_cores_split_across_members() {
        let sc = ShardedCoordinator::new(hw(4, 32), 2);
        assert_eq!(sc.nshards(), 2);
        assert_eq!(sc.member(0).runtime().nworkers(), 2);
        assert_eq!(Dispatch::nworkers(&sc), 4);
        // Oversplit still gives each member one worker.
        let tiny = ShardedCoordinator::new(hw(1, 32), 3);
        assert_eq!(Dispatch::nworkers(&tiny), 3);
        tiny.shutdown_dispatch();
        sc.shutdown_dispatch();
    }
}
