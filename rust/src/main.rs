//! `exageostat` CLI — the launcher for the reproduction: simulation, MLE
//! (all four variants), prediction, Fisher, MLOE/MMOM, the SST tutorial
//! and the structure dump.
//!
//! Examples:
//! ```text
//! exageostat simulate --n 1600 --theta 1,0.1,0.5 --seed 0 --out data.csv
//! exageostat mle --data data.csv --variant exact --ncores 4 --ts 160
//! exageostat mle --n 1600 --theta 1,0.1,0.5 --variant tlr --tlr-tol 1e-7
//! exageostat predict --data data.csv --theta 1,0.1,0.5 --grid 40
//! exageostat fisher --n 400 --theta 1,0.1,0.5
//! exageostat sst --days 4
//! exageostat structures --n 1024 --ts 128
//! exageostat serve --requests requests.jsonl --clients 4 --ncores 4
//! tail -f requests.jsonl | exageostat serve --stdin --clients 4
//! exageostat serve --socket /tmp/exa.sock --window 8
//! exageostat serve --socket /tmp/exa.sock --shards 2 --ncores 4 --once
//! ```

use anyhow::Context;
use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::cli::Args;
use exageostat::covariance::Location;
use exageostat::data::{csv, sst};
use exageostat::likelihood::Variant;
use exageostat::scheduler::pool::Policy;
use std::path::PathBuf;

fn hardware(args: &Args) -> anyhow::Result<Hardware> {
    // --worker-classes cpu:6,slow:2 partitions the worker pool into
    // heterogeneous classes (see DESIGN.md §2i).  The spec is fitted to
    // --ncores by largest-remainder apportionment, so the total worker
    // count is still exactly ncores.  Omitting the flag falls back to
    // EXAGEOSTAT_WORKER_CLASSES, then to an all-CPU pool.
    if let Some(spec) = args.get("worker-classes") {
        let parsed = exageostat::scheduler::placement::ClassSpec::parse(spec)
            .with_context(|| format!("bad --worker-classes {spec:?} (want e.g. cpu:6,slow:2)"))?;
        exageostat::scheduler::placement::set_class_override(Some(parsed));
    }
    Ok(Hardware {
        // Default: all available hardware threads (EXAGEOSTAT_NCORES
        // overrides); --ncores pins it explicitly.
        ncores: args.get_usize("ncores", exageostat::api::default_ncores())?,
        ngpus: args.get_usize("ngpus", 0)?,
        ts: args.get_usize("ts", 320)?,
        pgrid: args.get_usize("pgrid", 1)?,
        qgrid: args.get_usize("qgrid", 1)?,
        policy: Policy::parse(&args.get_or("sched", "lws"))?,
    })
}

fn variant(args: &Args) -> anyhow::Result<Variant> {
    Ok(match args.get_or("variant", "exact").as_str() {
        "exact" => Variant::Exact,
        "dst" => Variant::Dst {
            band: args.get_usize("band", 1)?,
        },
        "tlr" => Variant::Tlr {
            tol: args.get_f64("tlr-tol", 1e-7)?,
            max_rank: args.get_usize("max-rank", usize::MAX)?,
        },
        "mp" => Variant::Mp {
            band: args.get_usize("band", 1)?,
        },
        other => anyhow::bail!("unknown variant {other:?} (exact|dst|tlr|mp)"),
    })
}

fn load_or_simulate(
    args: &Args,
    exa: &ExaGeoStat,
) -> anyhow::Result<exageostat::simulation::GeoData> {
    if let Some(path) = args.get("data") {
        csv::read_geodata(&PathBuf::from(path)).with_context(|| format!("reading {path}"))
    } else {
        let n = args.get_usize("n", 1600)?;
        let theta = args.get_f64_list("theta", &[1.0, 0.1, 0.5])?;
        let seed = args.get_usize("seed", 0)? as u64;
        exa.simulate_data_exact(
            &args.get_or("kernel", "ugsm-s"),
            &theta,
            &args.get_or("dmetric", "euclidean"),
            n,
            seed,
        )
    }
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let exa = ExaGeoStat::init(hardware(args)?);
    let data = load_or_simulate(args, &exa)?;
    let out = args.get_or("out", "data.csv");
    csv::write_geodata(&PathBuf::from(&out), &data)?;
    println!(
        "wrote {} locations to {out} (z mean {:.4}, sd {:.4})",
        data.n(),
        mean(&data.z),
        sd(&data.z)
    );
    Ok(())
}

fn cmd_mle(args: &Args) -> anyhow::Result<()> {
    let exa = ExaGeoStat::init(hardware(args)?);
    let data = load_or_simulate(args, &exa)?;
    let kernel = args.get_or("kernel", "ugsm-s");
    let nparams = exageostat::covariance::kernel_by_name(&kernel)?.nparams();
    let opt = MleOptions {
        clb: args.get_f64_list("clb", &vec![0.001; nparams])?,
        cub: args.get_f64_list("cub", &vec![5.0; nparams])?,
        tol: args.get_f64("tol", 1e-4)?,
        max_iters: args.get_usize("max-iters", 0)?,
        method: exageostat::optimizer::Method::parse(&args.get_or("method", "bobyqa"))?,
    };
    let v = variant(args)?;
    let r = exa.mle(&data, &kernel, &args.get_or("dmetric", "euclidean"), &opt, v)?;
    println!("variant         : {v:?}");
    println!("theta_hat       : {:?}", r.theta);
    println!("loglik          : {:.6}", r.loglik);
    println!("iterations      : {}", r.iters);
    println!("time_per_iter   : {:.4} s", r.time_per_iter);
    println!("total_time      : {:.4} s", r.total_time);
    Ok(())
}

fn cmd_predict(args: &Args) -> anyhow::Result<()> {
    let exa = ExaGeoStat::init(hardware(args)?);
    let data = load_or_simulate(args, &exa)?;
    let theta = args.get_f64_list("theta", &[1.0, 0.1, 0.5])?;
    let g = args.get_usize("grid", 20)?;
    let new_locs: Vec<Location> = (0..g * g)
        .map(|k| {
            Location::new(
                (k % g) as f64 / (g - 1).max(1) as f64,
                (k / g) as f64 / (g - 1).max(1) as f64,
            )
        })
        .collect();
    let pred = exa.exact_predict(
        &data,
        &new_locs,
        &args.get_or("kernel", "ugsm-s"),
        &args.get_or("dmetric", "euclidean"),
        &theta,
        true,
    )?;
    let var = pred.variance.unwrap();
    println!(
        "predicted {} grid points: mean in [{:.3}, {:.3}], kriging sd in [{:.3}, {:.3}]",
        new_locs.len(),
        pred.mean.iter().cloned().fold(f64::INFINITY, f64::min),
        pred.mean.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        var.iter().cloned().fold(f64::INFINITY, f64::min).sqrt(),
        var.iter().cloned().fold(f64::NEG_INFINITY, f64::max).sqrt(),
    );
    if let Some(out) = args.get("out") {
        let gd = exageostat::simulation::GeoData {
            locs: new_locs,
            z: pred.mean,
        };
        csv::write_geodata(&PathBuf::from(out), &gd)?;
        println!("wrote predictions to {out}");
    }
    Ok(())
}

fn cmd_fisher(args: &Args) -> anyhow::Result<()> {
    let exa = ExaGeoStat::init(hardware(args)?);
    let data = load_or_simulate(args, &exa)?;
    let theta = args.get_f64_list("theta", &[1.0, 0.1, 0.5])?;
    let r = exa.exact_fisher(
        &data.locs,
        &args.get_or("kernel", "ugsm-s"),
        &args.get_or("dmetric", "euclidean"),
        &theta,
    )?;
    println!("Fisher information at theta = {theta:?}:");
    for i in 0..theta.len() {
        let row: Vec<String> = (0..theta.len())
            .map(|j| format!("{:>12.4}", r.fisher[(i, j)]))
            .collect();
        println!("  [{}]", row.join(", "));
    }
    println!("asymptotic std errs: {:?}", r.std_errs);
    Ok(())
}

fn cmd_mloe_mmom(args: &Args) -> anyhow::Result<()> {
    let exa = ExaGeoStat::init(hardware(args)?);
    let data = load_or_simulate(args, &exa)?;
    let theta_true = args.get_f64_list("theta", &[1.0, 0.1, 0.5])?;
    let theta_approx = args.get_f64_list("theta-approx", &[1.0, 0.2, 1.0])?;
    let g = args.get_usize("grid", 8)?;
    let new_locs: Vec<Location> = (0..g * g)
        .map(|k| {
            Location::new(
                (k % g) as f64 / (g - 1).max(1) as f64,
                (k / g) as f64 / (g - 1).max(1) as f64,
            )
        })
        .collect();
    let r = exa.exact_mloe_mmom(
        &data.locs,
        &new_locs,
        &args.get_or("kernel", "ugsm-s"),
        &args.get_or("dmetric", "euclidean"),
        &theta_true,
        &theta_approx,
    )?;
    println!("MLOE = {:.6}, MMOM = {:.6}", r.mloe, r.mmom);
    Ok(())
}

fn cmd_structures(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 1024)?;
    let ts = args.get_usize("ts", 128)?;
    for (name, band) in [("exact", None), ("dst band=1", Some(1))] {
        println!("{name}: lower tile map (D = dense, . = annihilated)");
        for row in exageostat::likelihood::exact::structure_map(n, ts, band) {
            println!("  {row}");
        }
    }
    println!("mp band=1: as dst map but '.' tiles stored in f32 instead of zeroed");
    println!("tlr: per-tile ranks — see `cargo bench --bench ablation_variants`");
    Ok(())
}

fn cmd_sst(args: &Args) -> anyhow::Result<()> {
    // Thin wrapper over the tutorial driver (examples/sst_tutorial.rs has
    // the full annotated version with kriging + Table VI summary).
    let days = args.get_usize("days", 4)?;
    let cfg = sst::SstConfig {
        days,
        ..sst::SstConfig::default()
    };
    let exa = ExaGeoStat::init(hardware(args)?);
    let ctx = exa.ctx();
    // Stream days one at a time: only the day being fitted is resident.
    for d in sst::stream_days(&cfg, &ctx) {
        let d = d?;
        let day = d.day;
        let (locs, z) = d.valid_observations();
        if d.valid_fraction() < 0.5 {
            println!(
                "day {day}: {:.0}% missing — skipped",
                100.0 * (1.0 - d.valid_fraction())
            );
            continue;
        }
        let (coef, resid) = sst::ols_linear_mean(&locs, &z);
        let train = exageostat::simulation::GeoData { locs, z: resid };
        let opt = MleOptions::new(vec![0.01, 0.01, 0.01], vec![20.0, 20.0, 5.0], 1e-4, 20);
        let r = exa.exact_mle(&train, "ugsm-s", "euclidean", &opt)?;
        println!(
            "day {day}: mean=({:.2},{:.3},{:.3}) theta_hat=({:.2},{:.2},{:.2}) truth=({:.2},{:.2},{:.2}) [{} iters, {:.2}s/iter]",
            coef[0], coef[1], coef[2],
            r.theta[0], r.theta[1], r.theta[2],
            d.theta_true[0], d.theta_true[1], d.theta_true[2],
            r.iters, r.time_per_iter
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use exageostat::coordinator::{
        serve_socket, serve_stream, Client, Completion, Coordinator, Dispatch, ServeOptions,
        ShardedCoordinator,
    };
    use exageostat::testkit::percentile;
    use std::io::BufReader;
    use std::sync::Arc;

    let hw = hardware(args)?;
    let clients = args.get_usize("clients", 4)?.max(1);
    let shards = args.get_usize("shards", 1)?.max(1);
    // One unified knob for every serve-side memory pool: tile workspace
    // (spill threshold), session cache and dataset cache split a single
    // budget proportionally (`Coordinator::with_mem_budget`).  Accepts
    // K/M/G suffixes; "off" (or omitting the flag) keeps the defaults
    // with fully-resident workspaces.
    let mem_budget = args
        .get("mem-budget")
        .and_then(|v| exageostat::linalg::tile::parse_budget(v));
    let opts = ServeOptions {
        window: args.get_usize("window", 2 * clients)?.max(1),
        depth_limit: match args.get("depth-limit") {
            Some(_) => Some(args.get_usize("depth-limit", 0)?),
            None => None,
        },
        // `--deadline MS` is a *default*: a request carrying its own
        // `deadline_ms` keeps it.
        deadline_ms: match args.get("deadline") {
            Some(_) => Some(args.get_usize("deadline", 0)? as u64),
            None => None,
        },
    };
    // `--retries N` opts whole-job retry in for every served request
    // (infrastructure failures only; cancelled/timed-out jobs are never
    // retried).  Same knob as EXAGEOSTAT_JOB_RETRIES.
    if args.get("retries").is_some() {
        exageostat::coordinator::set_job_retry_override(Some(
            args.get_usize("retries", 0)? as u64,
        ));
    }
    println!(
        "serving with {clients} client runners, window {} on {} workers ({:?}, ts {}){}{}",
        opts.window,
        hw.ncores.max(1),
        hw.policy,
        hw.ts,
        if shards > 1 {
            format!(", {shards} shards")
        } else {
            String::new()
        },
        match mem_budget {
            Some(b) => format!(", {:.0} MiB memory budget", b as f64 / (1 << 20) as f64),
            None => String::new(),
        }
    );

    // --shards N > 1 splits the worker pool into N member coordinators:
    // requests spread across them by dataset affinity, and large tiled
    // pipelines partition 2-D block-cyclic over all N runtimes.
    let coord: Arc<dyn Dispatch> = match (shards > 1, mem_budget) {
        (true, Some(b)) => Arc::new(ShardedCoordinator::with_mem_budget(hw, shards, b)),
        (true, None) => Arc::new(ShardedCoordinator::new(hw, shards)),
        (false, Some(b)) => Arc::new(Coordinator::with_mem_budget(hw, b)),
        (false, None) => Arc::new(Coordinator::new(hw)),
    };
    let client = Client::from_dispatch(coord.clone(), clients);
    let on_done = |id: u64, c: &Completion| match c {
        Completion::Done(r) => println!(
            "  [{id:>3}] {:<10} {:>8.3}s{}{}",
            r.kind,
            r.wall_s,
            if r.data_cache_hit { "  data*" } else { "" },
            if r.session_cache_hit { "  session*" } else { "" },
        ),
        Completion::Cancelled => println!("  [{id:>3}] cancelled"),
        Completion::TimedOut => println!("  [{id:>3}] timed out"),
        Completion::Failed(msg) => eprintln!("  [{id:>3}] error: {msg}"),
    };

    let t0 = std::time::Instant::now();
    let summary = if args.has("stdin") {
        // Incremental: each line is admitted as it arrives on the pipe;
        // responses stream back long before EOF.
        let mut reader = std::io::stdin().lock();
        serve_stream(&client, &mut reader, &opts, on_done)?
    } else if let Some(sock) = args.get("socket") {
        let sock = sock.to_string();
        // Accept loop: each connection serves to its EOF, then the next
        // is accepted — `--once` stops after one, `--max-conns N` after
        // N, default runs until the process is killed.  Stale sockets
        // are probed before binding (a live owner is an error, not a
        // silent steal) and the path is removed on every exit path.
        let max_conns = if args.has("once") {
            Some(1)
        } else {
            match args.get("max-conns") {
                Some(_) => Some(args.get_usize("max-conns", 1)?.max(1)),
                None => None,
            }
        };
        match max_conns {
            Some(m) => println!("listening on unix socket {sock} (up to {m} connection(s))"),
            None => println!("listening on unix socket {sock} (accepting until killed)"),
        }
        serve_socket(&client, &sock, &opts, max_conns, on_done)?
    } else {
        let path = args
            .get("requests")
            .context("serve needs --requests <file.jsonl>, --stdin, or --socket <path>")?
            .to_string();
        let file = std::fs::File::open(&path).with_context(|| format!("reading {path}"))?;
        let mut reader = BufReader::new(file);
        serve_stream(&client, &mut reader, &opts, on_done)?
    };
    let total_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        summary.submitted > 0,
        "no requests in the stream ({} unparsable)",
        summary.parse_errors
    );

    let lat = &summary.latencies_s; // sorted by serve_stream
    let st = coord.stats();
    println!(
        "{} ok, {} failed, {} cancelled, {} timed out in {total_s:.3}s — {:.2} req/s, \
         latency p50 {:.3}s / p95 {:.3}s / p99 {:.3}s",
        summary.ok,
        summary.failed,
        summary.cancelled,
        summary.timed_out,
        summary.ok as f64 / total_s.max(1e-9),
        percentile(lat, 0.50),
        percentile(lat, 0.95),
        percentile(lat, 0.99),
    );
    println!(
        "cache: data {}/{} hit ({} evicted), session {}/{} hit ({} evicted); \
         {} tasks on {} workers",
        st.data_cache_hits,
        st.data_cache_hits + st.data_cache_misses,
        st.data_cache_evictions,
        st.session_cache_hits,
        st.session_cache_hits + st.session_cache_misses,
        st.session_cache_evictions,
        st.tasks_executed,
        st.worker_threads
    );
    if st.tasks_skipped > 0 {
        println!(
            "cancellation skipped {} queued task(s) before they ran",
            st.tasks_skipped
        );
    }
    if st.job_retries + st.faults_injected + st.tasks_retried > 0 {
        println!(
            "fault handling: {} fault(s) injected, {} task retr(ies), {} whole-job retr(ies)",
            st.faults_injected, st.tasks_retried, st.job_retries
        );
    }
    // Only worth a line when the pool is actually heterogeneous: a single
    // all-CPU class is the default and adds no information.
    if st.class_stats.len() > 1 {
        let parts: Vec<String> = st
            .class_stats
            .iter()
            .map(|c| {
                format!(
                    "{} x{} ({} tasks, {} steals)",
                    c.class.name(),
                    c.workers,
                    c.tasks_executed,
                    c.steals
                )
            })
            .collect();
        println!("worker classes: {}", parts.join(", "));
    }
    if let Some(out) = args.get("out") {
        let json = format!(
            "{{\n  \"requests\": {},\n  \"ok\": {},\n  \"failed\": {},\n  \
             \"cancelled\": {},\n  \"parse_errors\": {},\n  \
             \"total_s\": {total_s},\n  \"req_per_s\": {},\n  \"p50_s\": {},\n  \
             \"p95_s\": {},\n  \"p99_s\": {},\n  \"data_cache_hits\": {},\n  \
             \"data_cache_evictions\": {},\n  \"session_cache_hits\": {},\n  \
             \"session_cache_evictions\": {},\n  \"tasks_executed\": {},\n  \
             \"tasks_skipped\": {},\n  \"timed_out\": {},\n  \
             \"job_retries\": {}\n}}\n",
            summary.submitted,
            summary.ok,
            summary.failed,
            summary.cancelled,
            summary.parse_errors,
            summary.ok as f64 / total_s.max(1e-9),
            percentile(lat, 0.50),
            percentile(lat, 0.95),
            percentile(lat, 0.99),
            st.data_cache_hits,
            st.data_cache_evictions,
            st.session_cache_hits,
            st.session_cache_evictions,
            st.tasks_executed,
            st.tasks_skipped,
            summary.timed_out,
            st.job_retries,
        );
        std::fs::write(out, json).with_context(|| format!("writing {out}"))?;
        println!("stats written to {out}");
    }
    client.shutdown();
    coord.shutdown_dispatch();
    anyhow::ensure!(summary.failed == 0, "{} request(s) failed", summary.failed);
    Ok(())
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len() as f64
}
fn sd(v: &[f64]) -> f64 {
    let m = mean(v);
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
}

fn main() {
    let args = Args::parse();
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("mle") => cmd_mle(&args),
        Some("predict") => cmd_predict(&args),
        Some("fisher") => cmd_fisher(&args),
        Some("mloe-mmom") => cmd_mloe_mmom(&args),
        Some("structures") => cmd_structures(&args),
        Some("sst") => cmd_sst(&args),
        Some("serve") => cmd_serve(&args),
        _ => {
            eprintln!(
                "usage: exageostat <simulate|mle|predict|fisher|mloe-mmom|structures|sst|serve> [--flags]\n\
                 common flags: --ncores N --ts N --sched eager|prio|lws|random\n\
                 \x20             [--worker-classes cpu:6,slow:2]\n\
                 serve input:  --requests file.jsonl | --stdin | --socket path.sock\n\
                 serve flags:  --clients K --window W --shards N [--depth-limit D]\n\
                 \x20             [--mem-budget 2G] [--deadline MS] [--retries N]\n\
                 \x20             [--once | --max-conns N] [--out stats.json]\n\
                 see rust/src/main.rs header for examples"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
