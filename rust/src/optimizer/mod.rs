//! Bound-constrained optimizers for the MLE (the NLopt / `optim` analogue,
//! Table IV of the paper).
//!
//! * [`bobyqa`] — a BOBYQA-style derivative-free trust-region method with
//!   quadratic interpolation models (ExaGeoStatR's optimizer);
//! * [`nelder_mead`] — the `optim` method GeoR's `likfit` uses;
//! * [`bfgs`] — the quasi-Newton method fields' `MLESpatialProcess` uses
//!   (finite-difference gradients, projected line search).
//!
//! All three minimize; MLE callers pass the *negative* log-likelihood.
//! Iteration here = one objective evaluation (that is what "time per
//! iteration" measures in the paper: each iteration is dominated by one
//! `O(n^3)` likelihood evaluation).

pub mod bfgs;
pub mod bobyqa;
pub mod nelder_mead;

use crate::scheduler::runtime::CancelToken;
use std::cell::Cell;
use std::time::Instant;

/// Box constraints (the `clb` / `cub` vectors of the R API).
#[derive(Clone, Debug)]
pub struct Bounds {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl Bounds {
    pub fn new(lo: Vec<f64>, hi: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(lo.len() == hi.len(), "bounds length mismatch");
        for (l, h) in lo.iter().zip(&hi) {
            anyhow::ensure!(l < h, "lower bound {l} >= upper bound {h}");
        }
        Ok(Bounds { lo, hi })
    }
    pub fn dim(&self) -> usize {
        self.lo.len()
    }
    pub fn clamp(&self, x: &mut [f64]) {
        for i in 0..x.len() {
            x[i] = x[i].clamp(self.lo[i], self.hi[i]);
        }
    }
    pub fn contains(&self, x: &[f64]) -> bool {
        x.iter()
            .zip(self.lo.iter().zip(&self.hi))
            .all(|(v, (l, h))| v >= l && v <= h)
    }
    pub fn width(&self, i: usize) -> f64 {
        self.hi[i] - self.lo[i]
    }
}

/// Common stopping options (the `optimization = list(...)` of the R API).
#[derive(Clone, Debug)]
pub struct OptOptions {
    /// Absolute tolerance on the objective improvement.
    pub tol: f64,
    /// Max objective evaluations; `0` = unlimited (paper: `max_iters = 0`
    /// "to avoid non-optimized results").
    pub max_iters: usize,
    /// Starting point; the R package starts at `clb` — callers replicate
    /// that by passing `lo.clone()`.
    pub init: Vec<f64>,
    /// External stop signal, checked between objective evaluations: the
    /// serving layer's job-cancellation token.  `None` = never stops
    /// early.  Once fired, the loops exit at their next iteration check
    /// and any further [`Instrumented::eval`] returns `+inf` without
    /// touching the objective.
    pub stop: Option<CancelToken>,
}

impl OptOptions {
    pub fn effective_max(&self) -> usize {
        if self.max_iters == 0 {
            100_000
        } else {
            self.max_iters
        }
    }

    /// Has the external stop signal fired?
    pub fn stopped(&self) -> bool {
        self.stop.as_ref().is_some_and(|t| t.is_cancelled())
    }
}

/// Optimization outcome + telemetry (the `result$...` fields of the R API).
#[derive(Clone, Debug)]
pub struct OptResult {
    pub x: Vec<f64>,
    pub fx: f64,
    /// Objective evaluations performed.
    pub iters: usize,
    pub total_time: f64,
    pub time_per_iter: f64,
    /// Best objective value after each evaluation.
    pub history: Vec<f64>,
    /// Whether the external stop signal was *observed* by the optimizer
    /// loop (as opposed to firing after the last check).  Callers use
    /// this — not a re-read of the token — to decide whether the run
    /// was cancelled: re-reading races with tokens that fire just after
    /// a run completes normally.
    pub stopped: bool,
}

/// Wraps a raw objective with bounds clamping, counting and timing.
pub struct Instrumented<'a> {
    f: Box<dyn FnMut(&[f64]) -> f64 + 'a>,
    pub bounds: Bounds,
    pub evals: usize,
    pub best: f64,
    pub best_x: Vec<f64>,
    pub history: Vec<f64>,
    /// External stop signal (from [`OptOptions::stop`]): when fired,
    /// `eval` stops invoking the wrapped objective.
    pub stop: Option<CancelToken>,
    /// Latched the first time `stop_requested` observes a fired token.
    stop_seen: Cell<bool>,
    started: Instant,
}

impl<'a> Instrumented<'a> {
    pub fn new(f: impl FnMut(&[f64]) -> f64 + 'a, bounds: Bounds) -> Self {
        let d = bounds.dim();
        Instrumented {
            f: Box::new(f),
            bounds,
            evals: 0,
            best: f64::INFINITY,
            best_x: vec![f64::NAN; d],
            history: Vec::new(),
            stop: None,
            stop_seen: Cell::new(false),
            started: Instant::now(),
        }
    }

    /// Has the external stop signal fired?  Observing a fired token here
    /// latches [`OptResult::stopped`].
    pub fn stop_requested(&self) -> bool {
        let fired = self.stop.as_ref().is_some_and(|t| t.is_cancelled());
        if fired {
            self.stop_seen.set(true);
        }
        fired
    }

    /// Evaluate at `x` (clamped into bounds first).  A fired stop
    /// signal short-circuits to `+inf` without calling the objective
    /// (uncounted), so in-flight batches of evaluations — interpolation
    /// set builds, simplex shrinks, gradient stencils — cost nothing
    /// past the cancellation point.
    pub fn eval(&mut self, x: &[f64]) -> f64 {
        if self.stop_requested() {
            return f64::INFINITY;
        }
        let mut xc = x.to_vec();
        self.bounds.clamp(&mut xc);
        let v = (self.f)(&xc);
        self.evals += 1;
        // NaN (e.g. non-SPD covariance) treated as +inf for minimization.
        let v = if v.is_nan() { f64::INFINITY } else { v };
        if v < self.best {
            self.best = v;
            self.best_x = xc;
        }
        self.history.push(self.best);
        v
    }

    pub fn finish(self) -> OptResult {
        let total = self.started.elapsed().as_secs_f64();
        let iters = self.evals.max(1);
        OptResult {
            x: self.best_x,
            fx: self.best,
            iters: self.evals,
            total_time: total,
            time_per_iter: total / iters as f64,
            history: self.history,
            stopped: self.stop_seen.get(),
        }
    }
}

/// Optimizer selector (Table IV "default optimization method" row).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Method {
    Bobyqa,
    NelderMead,
    Bfgs,
}

impl Method {
    pub fn parse(s: &str) -> anyhow::Result<Method> {
        Ok(match s {
            "bobyqa" => Method::Bobyqa,
            "nelder-mead" => Method::NelderMead,
            "bfgs" => Method::Bfgs,
            other => anyhow::bail!("unknown method {other:?} (bobyqa|nelder-mead|bfgs)"),
        })
    }
}

/// Minimize `f` over `bounds` with the chosen method.
pub fn minimize(
    method: Method,
    f: impl FnMut(&[f64]) -> f64,
    bounds: Bounds,
    opts: &OptOptions,
) -> OptResult {
    match method {
        Method::Bobyqa => bobyqa::minimize(f, bounds, opts),
        Method::NelderMead => nelder_mead::minimize(f, bounds, opts),
        Method::Bfgs => bfgs::minimize(f, bounds, opts),
    }
}

#[cfg(test)]
pub(crate) mod testfns {
    /// Sphere: minimum 0 at the given center.
    pub fn sphere(center: &[f64]) -> impl Fn(&[f64]) -> f64 + '_ {
        move |x| {
            x.iter()
                .zip(center)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        }
    }

    /// Rosenbrock (2-D), minimum 0 at (1, 1).
    pub fn rosenbrock(x: &[f64]) -> f64 {
        let (a, b) = (x[0], x[1]);
        (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
    }
}

#[cfg(test)]
mod tests {
    use super::testfns::*;
    use super::*;

    fn unit_bounds(d: usize) -> Bounds {
        Bounds::new(vec![-5.0; d], vec![5.0; d]).unwrap()
    }

    fn opts(init: Vec<f64>) -> OptOptions {
        OptOptions {
            tol: 1e-10,
            max_iters: 0,
            init,
            stop: None,
        }
    }

    #[test]
    fn all_methods_solve_sphere() {
        let center = [1.5, -2.0, 0.5];
        for m in [Method::Bobyqa, Method::NelderMead, Method::Bfgs] {
            let r = minimize(m, sphere(&center), unit_bounds(3), &opts(vec![4.0, 4.0, 4.0]));
            for i in 0..3 {
                assert!(
                    (r.x[i] - center[i]).abs() < 1e-4,
                    "{m:?}: x[{i}] = {} want {}",
                    r.x[i],
                    center[i]
                );
            }
            assert!(r.fx < 1e-7, "{m:?}: fx {}", r.fx);
        }
    }

    #[test]
    fn all_methods_respect_bounds() {
        // optimum at (10, 10) is outside [−1, 2]^2: solution on boundary.
        let center = [10.0, 10.0];
        let bounds = Bounds::new(vec![-1.0, -1.0], vec![2.0, 2.0]).unwrap();
        for m in [Method::Bobyqa, Method::NelderMead, Method::Bfgs] {
            let r = minimize(m, sphere(&center), bounds.clone(), &opts(vec![0.0, 0.0]));
            assert!(bounds.contains(&r.x), "{m:?}: {:?}", r.x);
            assert!(
                (r.x[0] - 2.0).abs() < 1e-3 && (r.x[1] - 2.0).abs() < 1e-3,
                "{m:?}: {:?}",
                r.x
            );
        }
    }

    #[test]
    fn bobyqa_and_bfgs_handle_rosenbrock() {
        for m in [Method::Bobyqa, Method::Bfgs] {
            let r = minimize(
                m,
                rosenbrock,
                unit_bounds(2),
                &OptOptions {
                    tol: 1e-12,
                    max_iters: 5000,
                    init: vec![-1.2, 1.0],
                    stop: None,
                },
            );
            assert!(
                r.fx < 1e-3,
                "{m:?}: fx {} at {:?} after {} evals",
                r.fx,
                r.x,
                r.iters
            );
        }
    }

    #[test]
    fn history_is_monotone_best_trace() {
        let r = minimize(
            Method::Bobyqa,
            sphere(&[0.0, 0.0]),
            unit_bounds(2),
            &opts(vec![3.0, 3.0]),
        );
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0]);
        }
        assert_eq!(r.history.len(), r.iters);
    }

    #[test]
    fn max_iters_enforced() {
        for m in [Method::Bobyqa, Method::NelderMead, Method::Bfgs] {
            let r = minimize(
                m,
                rosenbrock,
                unit_bounds(2),
                &OptOptions {
                    tol: 1e-16,
                    max_iters: 25,
                    init: vec![-1.2, 1.0],
                    stop: None,
                },
            );
            assert!(r.iters <= 30, "{m:?}: {} evals", r.iters); // small slack for gradient stencils
        }
    }

    #[test]
    fn nan_objective_treated_as_inf() {
        // objective NaN outside a disc: optimizer must still make progress
        let f = |x: &[f64]| {
            let r2 = x[0] * x[0] + x[1] * x[1];
            if r2 > 9.0 {
                f64::NAN
            } else {
                r2
            }
        };
        let r = minimize(Method::Bobyqa, f, unit_bounds(2), &opts(vec![2.0, 2.0]));
        assert!(r.fx < 1e-4, "fx {}", r.fx);
    }

    #[test]
    fn stop_token_halts_between_evaluations() {
        // The token fires inside the third objective call; every method
        // must stop without evaluating the objective again.
        for m in [Method::Bobyqa, Method::NelderMead, Method::Bfgs] {
            let token = CancelToken::new();
            let fire = token.clone();
            let calls = std::cell::Cell::new(0usize);
            let r = minimize(
                m,
                |x| {
                    calls.set(calls.get() + 1);
                    if calls.get() == 3 {
                        fire.cancel();
                    }
                    x.iter().map(|v| v * v).sum()
                },
                unit_bounds(2),
                &OptOptions {
                    tol: 1e-12,
                    max_iters: 0,
                    init: vec![4.0, 4.0],
                    stop: Some(token),
                },
            );
            assert_eq!(calls.get(), 3, "{m:?}: objective called after stop");
            assert_eq!(r.iters, 3, "{m:?}");
            assert!(r.stopped, "{m:?}: observed stop must latch into result");
        }
    }

    #[test]
    fn unstopped_runs_report_stopped_false() {
        // Even with a token wired in, a run that converges before the
        // token fires must not report `stopped` — and a token fired
        // *after* the run must not retroactively flip it.
        let token = CancelToken::new();
        let r = minimize(
            Method::Bobyqa,
            sphere(&[0.0, 0.0]),
            unit_bounds(2),
            &OptOptions {
                tol: 1e-10,
                max_iters: 0,
                init: vec![3.0, 3.0],
                stop: Some(token.clone()),
            },
        );
        token.cancel(); // too late: run already finished
        assert!(!r.stopped);
        assert!(r.fx < 1e-7);
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("bobyqa").unwrap(), Method::Bobyqa);
        assert!(Method::parse("adam").is_err());
    }
}
