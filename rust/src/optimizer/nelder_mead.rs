//! Nelder–Mead simplex with box projection — the default `optim` method
//! GeoR's `likfit` uses (Table IV).  Standard coefficients
//! (reflection 1, expansion 2, contraction 1/2, shrink 1/2).

use super::{Bounds, Instrumented, OptOptions, OptResult};

pub fn minimize(
    f: impl FnMut(&[f64]) -> f64,
    bounds: Bounds,
    opts: &OptOptions,
) -> OptResult {
    let d = bounds.dim();
    assert_eq!(opts.init.len(), d, "init dimension mismatch");
    let max_evals = opts.effective_max();
    let mut obj = Instrumented::new(f, bounds);
    obj.stop = opts.stop.clone();

    // Initial simplex: init + per-coordinate offsets (5% of box width).
    let mut x0 = opts.init.clone();
    obj.bounds.clamp(&mut x0);
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(d + 1);
    let fx0 = obj.eval(&x0);
    simplex.push((x0.clone(), fx0));
    for i in 0..d {
        let mut xi = x0.clone();
        let step = 0.05 * obj.bounds.width(i);
        // step inward if at the upper bound
        xi[i] = if xi[i] + step <= obj.bounds.hi[i] {
            xi[i] + step
        } else {
            xi[i] - step
        };
        let v = obj.eval(&xi);
        simplex.push((xi, v));
    }

    while obj.evals < max_evals && !obj.stop_requested() {
        simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
        let fbest = simplex[0].1;
        let fworst = simplex[d].1;
        // convergence: value spread and simplex diameter
        let spread = (fworst - fbest).abs();
        let diam = (0..d)
            .map(|i| {
                let mn = simplex.iter().map(|(x, _)| x[i]).fold(f64::INFINITY, f64::min);
                let mx = simplex
                    .iter()
                    .map(|(x, _)| x[i])
                    .fold(f64::NEG_INFINITY, f64::max);
                mx - mn
            })
            .fold(0.0, f64::max);
        if spread < opts.tol && diam < opts.tol.sqrt() * 1e-2 {
            break;
        }

        // centroid of all but worst
        let mut c = vec![0.0; d];
        for (x, _) in simplex.iter().take(d) {
            for i in 0..d {
                c[i] += x[i] / d as f64;
            }
        }
        let worst = simplex[d].0.clone();
        // Candidates are clamped into the box *before* entering the
        // simplex: otherwise reflections drift outside where the clamped
        // objective is flat and the simplex degenerates.
        let bounds = obj.bounds.clone();
        let reflect = move |alpha: f64| -> Vec<f64> {
            let mut x: Vec<f64> =
                (0..d).map(|i| c[i] + alpha * (c[i] - worst[i])).collect();
            bounds.clamp(&mut x);
            x
        };

        let xr = reflect(1.0);
        let fr = obj.eval(&xr);
        if fr < simplex[0].1 {
            // try expansion
            let xe = reflect(2.0);
            let fe = obj.eval(&xe);
            simplex[d] = if fe < fr { (xe, fe) } else { (xr, fr) };
        } else if fr < simplex[d - 1].1 {
            simplex[d] = (xr, fr);
        } else {
            // contraction (outside if fr better than worst, else inside)
            let xc = if fr < simplex[d].1 {
                reflect(0.5)
            } else {
                reflect(-0.5)
            };
            let fc = obj.eval(&xc);
            if fc < simplex[d].1.min(fr) {
                simplex[d] = (xc, fc);
            } else {
                // shrink toward best
                let best = simplex[0].0.clone();
                for k in 1..=d {
                    let xs: Vec<f64> = (0..d)
                        .map(|i| best[i] + 0.5 * (simplex[k].0[i] - best[i]))
                        .collect();
                    let fs = obj.eval(&xs);
                    simplex[k] = (xs, fs);
                    if obj.evals >= max_evals {
                        break;
                    }
                }
            }
        }
    }
    obj.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::testfns::sphere;

    #[test]
    fn quadratic_1d_exact_boundary_start() {
        let b = Bounds::new(vec![0.0], vec![10.0]).unwrap();
        let r = minimize(
            sphere(&[3.0]),
            b,
            &OptOptions {
                tol: 1e-12,
                max_iters: 0,
                init: vec![0.0], // starts at the lower bound like the R API
                stop: None,
            },
        );
        assert!((r.x[0] - 3.0).abs() < 1e-5, "{:?}", r.x);
    }

    #[test]
    fn telemetry_populated() {
        let b = Bounds::new(vec![-1.0, -1.0], vec![1.0, 1.0]).unwrap();
        let r = minimize(
            sphere(&[0.2, -0.3]),
            b,
            &OptOptions {
                tol: 1e-10,
                max_iters: 0,
                init: vec![0.9, 0.9],
                stop: None,
            },
        );
        assert!(r.iters > 5);
        assert!(r.total_time >= 0.0);
        assert!(r.time_per_iter * r.iters as f64 <= r.total_time * 1.01 + 1e-9);
    }
}
