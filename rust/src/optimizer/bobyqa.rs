//! BOBYQA-style bound-constrained derivative-free optimizer
//! (Powell 2009 family) — ExaGeoStat's optimizer choice.
//!
//! Like Powell's BOBYQA this method maintains an interpolation set, fits a
//! quadratic model, and takes trust-region steps subject to the bound
//! constraints; unlike Powell's implementation we refit the full quadratic
//! by (regularized) least squares each iteration instead of performing
//! minimum-Frobenius-norm updates — for the 3–10 parameter problems of
//! geostatistical MLE the `O(m^3)` refit is negligible next to one
//! `O(n^3)` likelihood evaluation, and the resulting iterates match
//! BOBYQA's qualitative behaviour (robust to boundary starts, no
//! derivative noise — the properties Table V / Fig 4 measure).

use super::{Bounds, Instrumented, OptOptions, OptResult};
use crate::linalg::blas::{dpotrf_raw, dtrsv_ln, dtrsv_lt};

/// Quadratic model basis size for dimension `d`.
fn basis_len(d: usize) -> usize {
    1 + d + d * (d + 1) / 2
}

/// Evaluate the quadratic basis at displacement `s`:
/// `[1, s_i..., 0.5 s_i^2..., s_i s_j (i<j)...]`.
fn basis(s: &[f64], out: &mut [f64]) {
    let d = s.len();
    out[0] = 1.0;
    out[1..1 + d].copy_from_slice(s);
    let mut k = 1 + d;
    for i in 0..d {
        out[k] = 0.5 * s[i] * s[i];
        k += 1;
    }
    for i in 0..d {
        for j in i + 1..d {
            out[k] = s[i] * s[j];
            k += 1;
        }
    }
}

/// Unpack fitted coefficients into (gradient, dense Hessian).
fn unpack(coef: &[f64], d: usize) -> (Vec<f64>, Vec<f64>) {
    let g = coef[1..1 + d].to_vec();
    let mut h = vec![0.0; d * d];
    let mut k = 1 + d;
    for i in 0..d {
        h[i + i * d] = coef[k];
        k += 1;
    }
    for i in 0..d {
        for j in i + 1..d {
            h[i + j * d] = coef[k];
            h[j + i * d] = coef[k];
            k += 1;
        }
    }
    (g, h)
}

/// Least-squares quadratic fit via regularized normal equations.
fn fit_quadratic(pts: &[(Vec<f64>, f64)], center: &[f64], scale: f64) -> Option<(Vec<f64>, Vec<f64>)> {
    let d = center.len();
    let m = basis_len(d);
    let npts = pts.len();
    // design matrix rows
    let mut at_a = vec![0.0; m * m];
    let mut at_f = vec![0.0; m];
    let mut row = vec![0.0; m];
    let mut s = vec![0.0; d];
    // Non-finite objective values (non-SPD covariance regions) are mapped
    // to a large finite penalty so they repel the model without poisoning
    // the normal equations.
    let finite: Vec<f64> = pts.iter().map(|p| p.1).filter(|v| v.is_finite()).collect();
    let fmax = finite.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let fmin = finite.iter().cloned().fold(f64::INFINITY, f64::min);
    let penalty = if finite.is_empty() {
        1e10
    } else {
        fmax + 10.0 * (fmax - fmin).max(1.0)
    };
    let pts: Vec<(Vec<f64>, f64)> = pts
        .iter()
        .map(|(x, v)| (x.clone(), if v.is_finite() { *v } else { penalty }))
        .collect();
    for (x, fx) in &pts {
        for i in 0..d {
            s[i] = (x[i] - center[i]) / scale;
        }
        basis(&s, &mut row);
        for j in 0..m {
            at_f[j] += row[j] * fx;
            for i in 0..m {
                at_a[i + j * m] += row[i] * row[j];
            }
        }
    }
    // ridge for safety (degenerate geometry happens near bounds)
    let ridge = 1e-10 * (1.0 + npts as f64);
    for i in 0..m {
        at_a[i + i * m] += ridge;
    }
    dpotrf_raw(m, &mut at_a, m).ok()?;
    dtrsv_ln(m, &at_a, m, &mut at_f);
    dtrsv_lt(m, &at_a, m, &mut at_f);
    Some((at_f.clone(), {
        let (_, h) = unpack(&at_f, d);
        h
    }))
}

/// Minimize the quadratic `g.s + 0.5 s'Hs` over the box
/// `max(lo-x, -delta) <= s <= min(hi-x, delta)` (scaled units) by projected
/// gradient descent — exact enough for the small dimensions of MLE.
fn solve_trust_region(
    g: &[f64],
    h: &[f64],
    smin: &[f64],
    smax: &[f64],
) -> Vec<f64> {
    let d = g.len();
    let mut s = vec![0.0; d];
    // Lipschitz estimate from the Hessian Frobenius norm
    let hf: f64 = h.iter().map(|v| v * v).sum::<f64>().sqrt();
    let step = 1.0 / (hf + 1.0);
    let qval = |s: &[f64]| -> f64 {
        let mut q = 0.0;
        for i in 0..d {
            q += g[i] * s[i];
            for j in 0..d {
                q += 0.5 * s[i] * h[i + j * d] * s[j];
            }
        }
        q
    };
    let mut best = s.clone();
    let mut best_q = 0.0;
    for _ in 0..200 {
        // gradient of q at s
        let mut gq = g.to_vec();
        for i in 0..d {
            for j in 0..d {
                gq[i] += h[i + j * d] * s[j];
            }
        }
        let mut moved = 0.0;
        for i in 0..d {
            let ns = (s[i] - step * gq[i]).clamp(smin[i], smax[i]);
            moved += (ns - s[i]).abs();
            s[i] = ns;
        }
        let q = qval(&s);
        if q < best_q {
            best_q = q;
            best.copy_from_slice(&s);
        }
        if moved < 1e-14 {
            break;
        }
    }
    best
}

/// Build the initial interpolation set around `x0` with per-coordinate
/// offset `frac * width`.
fn build_point_set(
    obj: &mut Instrumented,
    x0: &[f64],
    frac: f64,
) -> Vec<(Vec<f64>, f64)> {
    let d = x0.len();
    let delta0: Vec<f64> = (0..d).map(|i| frac * obj.bounds.width(i)).collect();
    let mut pts: Vec<(Vec<f64>, f64)> = Vec::with_capacity(basis_len(d));
    let fx0 = obj.eval(x0);
    pts.push((x0.to_vec(), fx0));
    for i in 0..d {
        // Two extra levels per axis.  If the minus point would clamp onto
        // x0 (boundary start — the R package's default), use +delta/2
        // instead so the axis still has three distinct levels and the
        // quadratic (g_i, H_ii) pair stays identifiable.
        let plus = (x0[i] + delta0[i]).min(obj.bounds.hi[i]);
        let minus_raw = x0[i] - delta0[i];
        let second = if minus_raw >= obj.bounds.lo[i] {
            minus_raw
        } else {
            (x0[i] + 0.5 * delta0[i]).min(obj.bounds.hi[i])
        };
        for target in [plus, second] {
            if (target - x0[i]).abs() < 1e-12 * (1.0 + x0[i].abs()) {
                continue;
            }
            let mut x = x0.to_vec();
            x[i] = target;
            let v = obj.eval(&x);
            pts.push((x, v));
        }
    }
    let inward = |x: &mut Vec<f64>, i: usize, dlt: f64, obj: &Instrumented| {
        // step that stays inside the box, flipping direction if needed
        if x[i] + dlt <= obj.bounds.hi[i] {
            x[i] += dlt;
        } else {
            x[i] -= dlt;
        }
    };
    for i in 0..d {
        for j in i + 1..d {
            let mut x = x0.to_vec();
            inward(&mut x, i, delta0[i], obj);
            inward(&mut x, j, delta0[j], obj);
            let v = obj.eval(&x);
            pts.push((x, v));
        }
    }
    pts
}

pub fn minimize(
    f: impl FnMut(&[f64]) -> f64,
    bounds: Bounds,
    opts: &OptOptions,
) -> OptResult {
    let d = bounds.dim();
    assert_eq!(opts.init.len(), d, "init dimension mismatch");
    let max_evals = opts.effective_max();
    let mut obj = Instrumented::new(f, bounds);
    obj.stop = opts.stop.clone();

    let mut x0 = opts.init.clone();
    obj.bounds.clamp(&mut x0);

    // Outer restart loop: each round builds a fresh interpolation set
    // around the incumbent and runs the trust-region loop to its radius
    // floor, starting with a tighter radius each time.  Powell's BOBYQA
    // achieves final accuracy by shrinking rho_end; restarts are the
    // simple-and-robust equivalent for the refit formulation.
    let mut round_frac = 0.1;
    let mut round_delta = 0.25f64;
    for _round in 0..4 {
        let f_before = if obj.best.is_finite() { obj.best } else { f64::INFINITY };
        trust_region_round(&mut obj, &x0, round_frac, round_delta, opts, max_evals);
        let improved = f_before - obj.best;
        x0 = obj.best_x.clone();
        if obj.evals >= max_evals
            || obj.stop_requested()
            || (improved.abs() < opts.tol && _round > 0)
        {
            break;
        }
        round_frac *= 0.1;
        round_delta *= 0.2;
    }
    obj.finish()
}

fn trust_region_round(
    obj: &mut Instrumented,
    x0: &[f64],
    frac: f64,
    delta_init: f64,
    opts: &OptOptions,
    max_evals: usize,
) {
    let d = x0.len();
    let mut pts = build_point_set(obj, x0, frac);

    // scale-free radius (fraction of box width per coordinate)
    let mut delta = delta_init;
    let min_delta = (opts.tol.max(1e-14)).sqrt() * 1e-4;
    let max_pts = 2 * basis_len(d);
    let mut geom_counter: u64 = 0x9E3779B97F4A7C15;
    while obj.evals < max_evals && delta > min_delta && !obj.stop_requested() {
        let (bi, _) = pts
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
            .unwrap();
        let xbest = pts[bi].0.clone();
        let fbest = pts[bi].1;

        // fit model in scaled coordinates around xbest
        let scale = 1.0; // widths folded into per-coord s bounds below
        let Some((coef, h)) = fit_quadratic(&pts, &xbest, scale) else {
            break;
        };
        let (g, _) = unpack(&coef, d);

        // per-coordinate step box: trust region ∩ bounds
        let mut smin = vec![0.0; d];
        let mut smax = vec![0.0; d];
        for i in 0..d {
            let w = obj.bounds.width(i);
            smin[i] = (obj.bounds.lo[i] - xbest[i]).max(-delta * w);
            smax[i] = (obj.bounds.hi[i] - xbest[i]).min(delta * w);
        }
        let s = solve_trust_region(&g, &h, &smin, &smax);
        let slen: f64 = s
            .iter()
            .enumerate()
            .map(|(i, v)| (v / obj.bounds.width(i)).abs())
            .fold(0.0, f64::max);
        if slen < 1e-14 {
            delta *= 0.5;
            continue;
        }
        let xn: Vec<f64> = xbest.iter().zip(&s).map(|(a, b)| a + b).collect();
        let fn_ = obj.eval(&xn);

        // predicted reduction from the model
        let mut pred = 0.0;
        for i in 0..d {
            pred -= coef[1 + i] * s[i];
        }
        {
            let (_, hm) = unpack(&coef, d);
            for i in 0..d {
                for j in 0..d {
                    pred -= 0.5 * s[i] * hm[i + j * d] * s[j];
                }
            }
        }
        let actual = fbest - fn_;
        let rho = if pred.abs() > 1e-300 { actual / pred } else { -1.0 };

        // update the point set: replace the worst point
        let (wi, _) = pts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
            .unwrap();
        if pts.len() >= max_pts {
            pts[wi] = (xn, fn_);
        } else {
            pts.push((xn, fn_));
        }

        // trust-region radius update
        if rho < 0.1 {
            delta *= 0.5;
            // Geometry-refresh step (the ALTMOV role in Powell's BOBYQA):
            // a poor ratio usually means the interpolation set has
            // degenerated; add a quasi-random point inside the TR box.
            if obj.evals < max_evals {
                geom_counter = geom_counter.wrapping_mul(6364136223846793005).wrapping_add(1);
                let mut xg = xbest.clone();
                let mut state = geom_counter;
                for i in 0..d {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    let u = (state >> 11) as f64 / (1u64 << 53) as f64;
                    xg[i] = (xbest[i] + (u - 0.5) * 2.0 * delta * obj.bounds.width(i))
                        .clamp(obj.bounds.lo[i], obj.bounds.hi[i]);
                }
                let fg = obj.eval(&xg);
                let (wi2, _) = pts
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
                    .unwrap();
                if pts.len() >= max_pts {
                    pts[wi2] = (xg, fg);
                } else {
                    pts.push((xg, fg));
                }
            }
        } else if rho > 0.7 && slen > 0.9 * delta {
            delta = (delta * 2.0).min(0.5);
        }
        if actual.abs() < opts.tol && rho > 0.0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::testfns::sphere;

    #[test]
    fn basis_roundtrip() {
        let s = [0.3, -0.7, 1.1];
        let mut b = vec![0.0; basis_len(3)];
        basis(&s, &mut b);
        assert_eq!(b[0], 1.0);
        assert_eq!(&b[1..4], &s);
        assert!((b[4] - 0.5 * 0.09).abs() < 1e-15);
        // cross terms
        assert!((b[7] - 0.3 * -0.7).abs() < 1e-15);
        assert_eq!(b.len(), 10);
    }

    #[test]
    fn fit_recovers_exact_quadratic() {
        // f(x) = 3 + 2 x0 - x1 + 0.5(4 x0^2 + x1^2) + 1.5 x0 x1
        let f = |x: &[f64]| {
            3.0 + 2.0 * x[0] - x[1] + 0.5 * (4.0 * x[0] * x[0] + x[1] * x[1]) + 1.5 * x[0] * x[1]
        };
        let mut pts = Vec::new();
        for i in -2..=2 {
            for j in -2..=2 {
                let x = vec![i as f64 * 0.3, j as f64 * 0.3];
                let v = f(&x);
                pts.push((x, v));
            }
        }
        let (coef, h) = fit_quadratic(&pts, &[0.0, 0.0], 1.0).unwrap();
        assert!((coef[0] - 3.0).abs() < 1e-6);
        assert!((coef[1] - 2.0).abs() < 1e-6);
        assert!((coef[2] + 1.0).abs() < 1e-6);
        assert!((h[0] - 4.0).abs() < 1e-6);
        assert!((h[3] - 1.0).abs() < 1e-6);
        assert!((h[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn trust_region_hits_unconstrained_newton_point() {
        // q(s) = -s0 + 0.5 s0^2  => min at s0 = 1
        let g = [-1.0, 0.0];
        let h = [1.0, 0.0, 0.0, 1.0];
        let s = solve_trust_region(&g, &h, &[-2.0, -2.0], &[2.0, 2.0]);
        assert!((s[0] - 1.0).abs() < 1e-6, "{s:?}");
        assert!(s[1].abs() < 1e-9);
    }

    #[test]
    fn trust_region_respects_box() {
        let g = [-1.0];
        let h = [0.0];
        let s = solve_trust_region(&g, &h, &[-0.3], &[0.3]);
        assert!((s[0] - 0.3).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn boundary_start_like_the_r_package() {
        // The R API starts at clb; BOBYQA must escape the corner.
        let b = Bounds::new(vec![0.001, 0.001, 0.001], vec![5.0, 5.0, 5.0]).unwrap();
        let r = minimize(
            sphere(&[1.0, 0.1, 0.5]),
            b,
            &OptOptions {
                tol: 1e-12,
                max_iters: 0,
                init: vec![0.001, 0.001, 0.001],
                stop: None,
            },
        );
        for (got, want) in r.x.iter().zip(&[1.0, 0.1, 0.5]) {
            assert!((got - want).abs() < 1e-4, "{:?}", r.x);
        }
    }
}
